//! Checkpoint/resume correctness.
//!
//! The heart of the suite is deterministic: a synthetic seeded trainer
//! (Adam-shaped update driven by an RNG whose cursor is checkpointed)
//! runs once uninterrupted and once interrupted-and-resumed through a
//! `TrainState` + manifest roundtrip — final parameters must be
//! **bit-identical**. This pins down exactly what the real trainer
//! serializes: params, both optimizer moments, the counters, and the RNG
//! cursor. A missing piece in any of them breaks the equality.
//!
//! A runtime-gated scenario then exercises the same path end-to-end
//! through `coordinator::run` (thread interleaving makes batch
//! composition nondeterministic there, so the full run asserts
//! continuation semantics — step counts, counters — while the bit-level
//! property is carried by the deterministic tier).

use pipeline_rl::config::RunConfig;
use pipeline_rl::coordinator;
use pipeline_rl::data::task::TaskKind;
use pipeline_rl::model::checkpoint::{
    read_manifest, AsyncCheckpointer, CkptFault, TrainState,
};
use pipeline_rl::runtime::HostTensor;
// the shared deterministic trainer: everything that affects its
// trajectory lives in `TrainState`, which is exactly what these tests pin
use pipeline_rl::testkit::synth::SynthTrainer as SyntheticTrainer;
use pipeline_rl::testkit::{self, runtime_or_skip};
use pipeline_rl::util::Rng;
use std::path::Path;

#[test]
fn resume_replays_uninterrupted_run_bit_identically() {
    let seed = 0x5eed;
    let total = 12;
    let cut = 6;

    // run A: straight through
    let mut a = SyntheticTrainer::new(seed);
    for _ in 0..total {
        a.step();
    }

    // run B: interrupted at `cut`, persisted through the manifest path,
    // resumed in a fresh instance
    let dir = std::env::temp_dir().join("prl_resume_equiv");
    std::fs::remove_dir_all(&dir).ok();
    let mut b1 = SyntheticTrainer::new(seed);
    for _ in 0..cut {
        b1.step();
    }
    b1.to_state().save_with_manifest(&dir, 0).unwrap();
    drop(b1); // the first incarnation is gone for good

    let mut b2 = SyntheticTrainer::from_state(TrainState::load_resume(&dir).unwrap());
    assert_eq!(b2.step, cut as u64);
    for _ in 0..(total - cut) {
        b2.step();
    }

    assert_eq!(
        a.params, b2.params,
        "resumed run must be bit-identical to the uninterrupted run"
    );
    assert_eq!(a.m, b2.m, "optimizer first moment must match");
    assert_eq!(a.v, b2.v, "optimizer second moment must match");
    assert_eq!(a.samples, b2.samples);
    assert_eq!(a.tokens, b2.tokens);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn dropping_any_state_piece_breaks_the_replay() {
    // negative control: resuming without the RNG cursor (or with zeroed
    // optimizer moments) must NOT reproduce the uninterrupted run — i.e.
    // every field TrainState carries is load-bearing.
    let seed = 0x5eed;
    let total = 12;
    let cut = 6;
    let mut a = SyntheticTrainer::new(seed);
    for _ in 0..total {
        a.step();
    }

    let mut b1 = SyntheticTrainer::new(seed);
    for _ in 0..cut {
        b1.step();
    }
    let mut st = b1.to_state();
    st.rng = Rng::new(999).state_words(); // lose the cursor
    let mut b2 = SyntheticTrainer::from_state(st);
    for _ in 0..(total - cut) {
        b2.step();
    }
    assert_ne!(a.params, b2.params, "a lost RNG cursor must be detectable");

    let mut st = b1.to_state();
    for t in &mut st.opt_m {
        *t = HostTensor::zeros_f32(t.shape());
    }
    let mut b3 = SyntheticTrainer::from_state(st);
    for _ in 0..(total - cut) {
        b3.step();
    }
    assert_ne!(a.params, b3.params, "zeroed optimizer state must be detectable");
}

/// Everything the durability property needs to hold after a crash at an
/// arbitrary protocol stage: the manifest (if present) parses, every
/// state it names loads fully, and its latest state is the last save
/// that *completed* — a crash can lose the newest state, never corrupt
/// the recoverable one.
fn assert_recoverable(dir: &Path, expect_latest: u64) -> Result<(), String> {
    let (latest, history) =
        read_manifest(dir).map_err(|e| format!("manifest unreadable after crash: {e}"))?;
    for name in history.iter().chain(std::iter::once(&latest)) {
        let st = TrainState::load(&dir.join(name))
            .map_err(|e| format!("manifest names unloadable state {name}: {e}"))?;
        if TrainState::file_name(st.step) != *name {
            return Err(format!("state {name} claims step {}", st.step));
        }
    }
    let st = TrainState::load_latest(dir).map_err(|e| format!("load_latest: {e}"))?;
    if st.step != expect_latest {
        return Err(format!(
            "latest resolves to step {}, want {expect_latest}",
            st.step
        ));
    }
    Ok(())
}

/// Satellite: the crash-window property — inject a failure at *each*
/// stage of the submit → write → fsync → rename protocol, at a random
/// point in a sequence of checkpoints, and the manifest must never name
/// a state file that was not fully fsynced. Exercises the prune-after-
/// rename ordering too (keep_last windows small enough to prune).
#[test]
fn property_manifest_never_names_an_unfsynced_state() {
    const FAULTS: [CkptFault; 5] = [
        CkptFault::StateWrite,
        CkptFault::StateFsync,
        CkptFault::ManifestWrite,
        CkptFault::ManifestFsync,
        CkptFault::ManifestRename,
    ];
    testkit::check("ckpt crash-window", 60, 0xc4a5_11, 16, |c| {
        let dir = std::env::temp_dir().join(format!(
            "prl_crashwin_{}_{}",
            std::process::id(),
            c.rng.next_u64()
        ));
        std::fs::remove_dir_all(&dir).ok();
        let keep_last = c.usize_in(0, 3);
        let n_good = c.usize_in(1, 5);
        let fault = *c.rng.choice(&FAULTS);
        let mut trainer = SyntheticTrainer::new(0x5eed ^ n_good as u64);
        let mut last_good = 0u64;
        for _ in 0..n_good {
            trainer.step();
            trainer
                .to_state()
                .save_with_manifest(&dir, keep_last)
                .map_err(|e| format!("good save failed: {e}"))?;
            last_good = trainer.step;
        }
        // the crash: one more checkpoint dies mid-protocol
        trainer.step();
        let crashed = trainer
            .to_state()
            .save_with_manifest_faulted(&dir, keep_last, Some(fault));
        if crashed.is_ok() {
            return Err(format!("injected {fault:?} did not surface"));
        }
        let res = assert_recoverable(&dir, last_good);
        std::fs::remove_dir_all(&dir).ok();
        res.map_err(|e| format!("after {fault:?} at step {}: {e}", last_good + 1))
    });
}

/// The async writer path hits the same crash windows through its own
/// thread: the injected fault surfaces at finish(), and the directory
/// still resolves to the last fully-written state.
#[test]
fn async_writer_crash_window_leaves_recoverable_state() {
    for fault in [CkptFault::StateFsync, CkptFault::ManifestRename] {
        let dir = std::env::temp_dir().join(format!(
            "prl_acrash_{}_{fault:?}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        let mut trainer = SyntheticTrainer::new(9);
        // retries disabled: the crash-window property needs the injected
        // fault to surface, not be absorbed (the one-shot fault models a
        // transient error the retry path would otherwise recover from)
        let w = AsyncCheckpointer::new(dir.clone(), 2, 0);
        trainer.step();
        w.submit(trainer.to_state());
        // wait for the good write to land before injecting the crash:
        // latest-wins would otherwise supersede it
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while read_manifest(&dir).is_err() {
            assert!(std::time::Instant::now() < deadline, "first write never landed");
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        w.inject_fault_next(fault);
        trainer.step();
        w.submit(trainer.to_state());
        let err = w.finish();
        assert!(err.is_err(), "{fault:?} must surface at finish()");
        assert_recoverable(&dir, 1).unwrap_or_else(|e| panic!("{fault:?}: {e}"));
        std::fs::remove_dir_all(&dir).ok();
    }
}

#[test]
fn full_run_checkpoints_then_resumes() {
    if !runtime_or_skip("full_run_checkpoints_then_resumes") {
        return;
    }
    let dir = std::env::temp_dir().join("prl_full_resume");
    std::fs::remove_dir_all(&dir).ok();

    let mut cfg = RunConfig::default();
    cfg.variant = "tiny".into();
    cfg.sft_steps = 8;
    cfg.rl_steps = 6;
    cfg.group_size = 2;
    cfg.max_new_tokens = 16;
    cfg.task.kinds = vec![TaskKind::Copy];
    cfg.task.max_operand = 9;
    cfg.log_every = 0;
    cfg.checkpoint.every = 2;
    cfg.checkpoint.dir = Some(dir.to_string_lossy().to_string());
    let first = coordinator::run(cfg.clone(), None).expect("first run");
    // async writer books: every submitted state was written or superseded
    // by a newer one (latest-wins), and the final state always lands
    assert_eq!(first.report.counters["checkpoints_submitted"], 3.0);
    let written = first.report.counters["checkpoints_written"];
    let superseded = first.report.counters.get("checkpoints_superseded").copied().unwrap_or(0.0);
    assert_eq!(written + superseded, 3.0);
    assert!(written >= 1.0);
    let latest = TrainState::load_latest(Path::new(&dir)).unwrap();
    assert_eq!(latest.step, 6);

    // resume: skips warmup, continues at step 7, runs 7..=10
    let mut cfg2 = cfg.clone();
    cfg2.rl_steps = 10;
    cfg2.checkpoint.resume_from = Some(dir.to_string_lossy().to_string());
    let resumed = coordinator::run(cfg2, None).expect("resumed run");
    assert_eq!(
        resumed.report.series("train/loss").unwrap().points.len(),
        4,
        "resumed trainer runs exactly the remaining steps"
    );
    assert_eq!(resumed.report.counters["resumed_from_step"], 6.0);
    assert!(resumed.report.counters["samples_trained"] > 0.0);
    // the resumed run kept checkpointing past the cut
    let newest = TrainState::load_latest(Path::new(&dir)).unwrap();
    assert_eq!(newest.step, 10);
    std::fs::remove_dir_all(&dir).ok();
}
