//! Checkpoint/resume correctness.
//!
//! The heart of the suite is deterministic: a synthetic seeded trainer
//! (Adam-shaped update driven by an RNG whose cursor is checkpointed)
//! runs once uninterrupted and once interrupted-and-resumed through a
//! `TrainState` + manifest roundtrip — final parameters must be
//! **bit-identical**. This pins down exactly what the real trainer
//! serializes: params, both optimizer moments, the counters, and the RNG
//! cursor. A missing piece in any of them breaks the equality.
//!
//! A runtime-gated scenario then exercises the same path end-to-end
//! through `coordinator::run` (thread interleaving makes batch
//! composition nondeterministic there, so the full run asserts
//! continuation semantics — step counts, counters — while the bit-level
//! property is carried by the deterministic tier).

use pipeline_rl::config::RunConfig;
use pipeline_rl::coordinator;
use pipeline_rl::data::task::TaskKind;
use pipeline_rl::model::checkpoint::TrainState;
use pipeline_rl::runtime::HostTensor;
use pipeline_rl::testkit::runtime_or_skip;
use pipeline_rl::util::Rng;
use std::path::Path;

/// Minimal deterministic "trainer": Adam-ish update on a small parameter
/// set, gradients synthesized from a seeded RNG. Everything that affects
/// the trajectory lives in `TrainState`.
struct SyntheticTrainer {
    variant: String,
    step: u64,
    params: Vec<HostTensor>,
    m: Vec<HostTensor>,
    v: Vec<HostTensor>,
    samples: f64,
    tokens: f64,
    rng: Rng,
}

impl SyntheticTrainer {
    fn new(seed: u64) -> Self {
        let n = 6;
        let mut rng = Rng::new(seed);
        let init: Vec<f32> = (0..n).map(|_| rng.f32() - 0.5).collect();
        SyntheticTrainer {
            variant: "synthetic".into(),
            step: 0,
            params: vec![HostTensor::from_f32(&[n], init)],
            m: vec![HostTensor::zeros_f32(&[n])],
            v: vec![HostTensor::zeros_f32(&[n])],
            samples: 0.0,
            tokens: 0.0,
            rng,
        }
    }

    fn step(&mut self) {
        self.step += 1;
        let lr = 0.05f32;
        for i in 0..self.params.len() {
            let n = self.params[i].numel();
            let grads: Vec<f32> = (0..n).map(|_| self.rng.f32() - 0.5).collect();
            let p = self.params[i].f32s_mut().unwrap();
            let m = self.m[i].f32s_mut().unwrap();
            let v = self.v[i].f32s_mut().unwrap();
            for j in 0..p.len() {
                m[j] = 0.9 * m[j] + 0.1 * grads[j];
                v[j] = 0.99 * v[j] + 0.01 * grads[j] * grads[j];
                p[j] -= lr * m[j] / (v[j].sqrt() + 1e-8);
            }
        }
        self.samples += 16.0;
        self.tokens += 512.0;
    }

    fn to_state(&self) -> TrainState {
        TrainState {
            variant: self.variant.clone(),
            step: self.step,
            params: self.params.clone(),
            opt_m: self.m.clone(),
            opt_v: self.v.clone(),
            samples_total: self.samples,
            tokens_total: self.tokens,
            rng: self.rng.state_words(),
        }
    }

    fn from_state(st: TrainState) -> Self {
        SyntheticTrainer {
            variant: st.variant,
            step: st.step,
            params: st.params,
            m: st.opt_m,
            v: st.opt_v,
            samples: st.samples_total,
            tokens: st.tokens_total,
            rng: Rng::from_state_words(st.rng),
        }
    }
}

#[test]
fn resume_replays_uninterrupted_run_bit_identically() {
    let seed = 0x5eed;
    let total = 12;
    let cut = 6;

    // run A: straight through
    let mut a = SyntheticTrainer::new(seed);
    for _ in 0..total {
        a.step();
    }

    // run B: interrupted at `cut`, persisted through the manifest path,
    // resumed in a fresh instance
    let dir = std::env::temp_dir().join("prl_resume_equiv");
    std::fs::remove_dir_all(&dir).ok();
    let mut b1 = SyntheticTrainer::new(seed);
    for _ in 0..cut {
        b1.step();
    }
    b1.to_state().save_with_manifest(&dir, 0).unwrap();
    drop(b1); // the first incarnation is gone for good

    let mut b2 = SyntheticTrainer::from_state(TrainState::load_resume(&dir).unwrap());
    assert_eq!(b2.step, cut as u64);
    for _ in 0..(total - cut) {
        b2.step();
    }

    assert_eq!(
        a.params, b2.params,
        "resumed run must be bit-identical to the uninterrupted run"
    );
    assert_eq!(a.m, b2.m, "optimizer first moment must match");
    assert_eq!(a.v, b2.v, "optimizer second moment must match");
    assert_eq!(a.samples, b2.samples);
    assert_eq!(a.tokens, b2.tokens);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn dropping_any_state_piece_breaks_the_replay() {
    // negative control: resuming without the RNG cursor (or with zeroed
    // optimizer moments) must NOT reproduce the uninterrupted run — i.e.
    // every field TrainState carries is load-bearing.
    let seed = 0x5eed;
    let total = 12;
    let cut = 6;
    let mut a = SyntheticTrainer::new(seed);
    for _ in 0..total {
        a.step();
    }

    let mut b1 = SyntheticTrainer::new(seed);
    for _ in 0..cut {
        b1.step();
    }
    let mut st = b1.to_state();
    st.rng = Rng::new(999).state_words(); // lose the cursor
    let mut b2 = SyntheticTrainer::from_state(st);
    for _ in 0..(total - cut) {
        b2.step();
    }
    assert_ne!(a.params, b2.params, "a lost RNG cursor must be detectable");

    let mut st = b1.to_state();
    for t in &mut st.opt_m {
        *t = HostTensor::zeros_f32(t.shape());
    }
    let mut b3 = SyntheticTrainer::from_state(st);
    for _ in 0..(total - cut) {
        b3.step();
    }
    assert_ne!(a.params, b3.params, "zeroed optimizer state must be detectable");
}

#[test]
fn full_run_checkpoints_then_resumes() {
    if !runtime_or_skip("full_run_checkpoints_then_resumes") {
        return;
    }
    let dir = std::env::temp_dir().join("prl_full_resume");
    std::fs::remove_dir_all(&dir).ok();

    let mut cfg = RunConfig::default();
    cfg.variant = "tiny".into();
    cfg.sft_steps = 8;
    cfg.rl_steps = 6;
    cfg.group_size = 2;
    cfg.max_new_tokens = 16;
    cfg.task.kinds = vec![TaskKind::Copy];
    cfg.task.max_operand = 9;
    cfg.log_every = 0;
    cfg.checkpoint.every = 2;
    cfg.checkpoint.dir = Some(dir.to_string_lossy().to_string());
    let first = coordinator::run(cfg.clone(), None).expect("first run");
    // async writer books: every submitted state was written or superseded
    // by a newer one (latest-wins), and the final state always lands
    assert_eq!(first.report.counters["checkpoints_submitted"], 3.0);
    let written = first.report.counters["checkpoints_written"];
    let superseded = first.report.counters.get("checkpoints_superseded").copied().unwrap_or(0.0);
    assert_eq!(written + superseded, 3.0);
    assert!(written >= 1.0);
    let latest = TrainState::load_latest(Path::new(&dir)).unwrap();
    assert_eq!(latest.step, 6);

    // resume: skips warmup, continues at step 7, runs 7..=10
    let mut cfg2 = cfg.clone();
    cfg2.rl_steps = 10;
    cfg2.checkpoint.resume_from = Some(dir.to_string_lossy().to_string());
    let resumed = coordinator::run(cfg2, None).expect("resumed run");
    assert_eq!(
        resumed.report.series("train/loss").unwrap().points.len(),
        4,
        "resumed trainer runs exactly the remaining steps"
    );
    assert_eq!(resumed.report.counters["resumed_from_step"], 6.0);
    assert!(resumed.report.counters["samples_trained"] > 0.0);
    // the resumed run kept checkpointing past the cut
    let newest = TrainState::load_latest(Path::new(&dir)).unwrap();
    assert_eq!(newest.step, 10);
    std::fs::remove_dir_all(&dir).ok();
}
