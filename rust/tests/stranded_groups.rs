//! Regression tests for the stranded-group bug (ROADMAP, PR 1 triage):
//! a saturated `DropOldest` rollout ring can evict a killed actor's
//! `Aborted` rollouts before the preprocessor sees them, leaving their
//! groupmates parked in `GroupCollector.pending` forever. The collector
//! now force-completes incomplete groups on a timeout and bounds the
//! pending map — these tests reproduce the eviction scenario end-to-end
//! (device-free: rollouts are synthesized, no engine involved).

use pipeline_rl::broker::{topic, Policy};
use pipeline_rl::config::RunConfig;
use pipeline_rl::coordinator::preprocessor::{run_preprocessor, PreprocessorArgs};
use pipeline_rl::coordinator::GroupCollector;
use pipeline_rl::metrics::MetricsHub;
use pipeline_rl::rl::{FinishReason, Rollout};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn rollout(seq_id: u64, group_id: u64, finish: FinishReason) -> Rollout {
    let n = 6;
    Rollout {
        seq_id,
        problem_id: 1,
        group_id,
        actor_id: 0,
        prompt_tokens: vec![1, 10, 11],
        gen_tokens: if matches!(finish, FinishReason::Aborted) {
            Vec::new()
        } else {
            vec![5; n]
        },
        behavior_lp: if matches!(finish, FinishReason::Aborted) {
            Vec::new()
        } else {
            vec![-0.5; n]
        },
        token_version: if matches!(finish, FinishReason::Aborted) {
            Vec::new()
        } else {
            vec![1; n]
        },
        reward: 1.0,
        finish,
        t_start: 0.0,
        t_end: 0.1,
    }
}

/// The core scenario at collector level: a group of 4 whose Aborted
/// member was ring-evicted. Only 3 members ever arrive; the timeout must
/// salvage them.
#[test]
fn timed_out_group_is_force_completed() {
    let hub = MetricsHub::new();
    let mut gc = GroupCollector::with_limits(4, false, 0.03, 0);
    for i in 0..3 {
        assert!(
            gc.add(rollout(i, 70, FinishReason::Eos), &hub).is_empty(),
            "incomplete group must not complete early"
        );
    }
    assert_eq!(gc.n_pending(), 1);
    assert!(gc.evict_stale(&hub).is_empty(), "not stale yet");
    std::thread::sleep(Duration::from_millis(60));
    let salvaged = gc.evict_stale(&hub);
    assert_eq!(salvaged.len(), 3, "present members are salvaged");
    assert_eq!(gc.n_pending(), 0, "no group remains stranded");
    assert_eq!(hub.counter("groups_evicted_stale"), 1.0);
    assert_eq!(hub.counter("groups_completed"), 1.0);
    // group-mean baseline over the present members only
    for (_, adv) in &salvaged {
        assert!(adv.is_finite());
    }
    // a straggler of the force-completed group is discarded, not
    // re-pended as an uncompletable fragment group
    assert!(gc.add(rollout(3, 70, FinishReason::Eos), &hub).is_empty());
    assert_eq!(gc.n_pending(), 0, "late member must not re-pend its group");
    assert_eq!(hub.counter("rollouts_late_after_eviction"), 1.0);
}

/// Staleness is measured from the *last* arrival: a slow group that
/// keeps making progress is never split by the timeout.
#[test]
fn slow_but_progressing_group_is_not_split() {
    let hub = MetricsHub::new();
    let mut gc = GroupCollector::with_limits(4, true, 0.2, 0);
    for i in 0..3 {
        assert!(gc.add(rollout(i, 8, FinishReason::Eos), &hub).is_empty());
        // each gap stays well below the 200ms staleness timeout, but the
        // total exceeds it — a first-arrival clock would evict here
        std::thread::sleep(Duration::from_millis(80));
        assert!(gc.evict_stale(&hub).is_empty(), "progressing group must survive");
    }
    let done = gc.add(rollout(3, 8, FinishReason::Eos), &hub);
    assert_eq!(done.len(), 4, "group completes normally despite being slow");
    assert_eq!(hub.counter("groups_evicted_stale"), 0.0);
}

/// Complete groups are unaffected by the eviction machinery, including
/// ones completed by Aborted members (the healthy halt path).
#[test]
fn complete_groups_do_not_trip_eviction() {
    let hub = MetricsHub::new();
    let mut gc = GroupCollector::with_limits(4, false, 0.02, 2);
    for i in 0..3 {
        assert!(gc.add(rollout(i, 5, FinishReason::Eos), &hub).is_empty());
    }
    let done = gc.add(rollout(3, 5, FinishReason::Aborted), &hub);
    assert_eq!(done.len(), 3, "aborted member completes the group, filtered from advantages");
    assert_eq!(gc.n_pending(), 0);
    std::thread::sleep(Duration::from_millis(40));
    assert!(gc.evict_stale(&hub).is_empty());
    assert_eq!(hub.counter("groups_evicted_stale"), 0.0);
}

/// The pending-map cap evicts oldest-first even before any timeout.
#[test]
fn pending_overflow_evicts_oldest_groups() {
    let hub = MetricsHub::new();
    let mut gc = GroupCollector::with_limits(4, false, 0.0, 2);
    for gid in 0..5u64 {
        gc.add(rollout(gid * 10, gid, FinishReason::Eos), &hub);
        std::thread::sleep(Duration::from_millis(2)); // distinct ages
    }
    assert_eq!(gc.n_pending(), 5);
    let salvaged = gc.evict_stale(&hub);
    assert_eq!(gc.n_pending(), 2, "trimmed to the cap");
    assert_eq!(salvaged.len(), 3, "each evicted group salvages its lone member");
    assert_eq!(hub.counter("groups_evicted_overflow"), 3.0);
    // the oldest groups went first: gids 0..3 evicted, 3 and 4 retained
    assert!(gc.add(rollout(100, 3, FinishReason::Eos), &hub).is_empty());
    assert_eq!(gc.n_pending(), 2, "gid 3 still pending (was not evicted)");
}

/// End-to-end through the real ring + preprocessor thread: a killed
/// actor's Aborted member is evicted from the saturated DropOldest ring,
/// its groupmates arrive, and the preprocessor still drains the group —
/// nothing stays pending, batches keep flowing.
#[test]
fn preprocessor_recovers_group_stranded_by_ring_eviction() {
    let mut cfg = RunConfig::default();
    cfg.group_size = 4;
    cfg.group_timeout_s = 0.15;
    cfg.max_pending_groups = 64;

    // ring so small that the burst below must evict its head
    let (tx, rx) = topic::<Rollout>("rollouts", 4, Policy::DropOldest);
    let (btx, brx) = topic("batches", 64, Policy::Block);
    let hub = MetricsHub::new();
    let stop = Arc::new(AtomicBool::new(false));

    // the killed actor's Aborted member enters the ring first...
    tx.send(rollout(1, 900, FinishReason::Aborted)).unwrap();
    // ...and a burst of unrelated complete groups saturates the ring
    // while no consumer is attached yet, deterministically evicting the
    // Aborted head (the exact failure mode from the ROADMAP note)
    let mut dropped = 0;
    for g in 0..4u64 {
        for s in 0..4u64 {
            dropped += tx.send(rollout(100 + g * 4 + s, g, FinishReason::Eos)).unwrap();
        }
    }
    assert!(dropped >= 13, "burst must overflow the ring ({dropped} dropped)");

    let args = PreprocessorArgs {
        cfg: cfg.clone(),
        b: 4,
        t: 64,
        rollout_rx: rx,
        batch_tx: btx,
        hub: hub.clone(),
        stop: stop.clone(),
        conv: None,
        scorer: None,
    };
    let handle = std::thread::spawn(move || run_preprocessor(args).unwrap());

    // the stranded groupmates arrive later, after the drain catches up
    std::thread::sleep(Duration::from_millis(30));
    for s in 0..3u64 {
        tx.send(rollout(200 + s, 900, FinishReason::Eos)).unwrap();
    }

    // the group must not stay pending: the timeout salvages it
    let deadline = Instant::now() + Duration::from_secs(5);
    while hub.counter("groups_evicted_stale") < 1.0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(
        hub.counter("groups_evicted_stale") >= 1.0,
        "stranded group must be evicted (counters: {:?})",
        hub.snapshot().counters
    );

    stop.store(true, Ordering::Relaxed);
    drop(tx);
    handle.join().unwrap();
    // the salvaged members made it into packed batches (groups_completed
    // counts the salvaged group too)
    assert!(hub.counter("groups_completed") >= 1.0);
    drop(brx);
}
