//! Shared-prefix paged KV memory, scheduler-driven preemption and
//! coalesced replay — the engine-gated acceptance suite.
//!
//! Three tiers mirror tests/migration.rs:
//!
//! * device-free allocator properties live in `engine/kvcache.rs` (unit
//!   tests + property tests for refcount conservation, no double-free
//!   and fork-on-write never aliasing);
//! * this file's scenarios need a PJRT runtime + AOT artifacts and gate
//!   on `runtime_or_skip`:
//!   - **prefix sharing**: a group of G rollouts over one prompt holds
//!     ceil(prompt/block_size) shared blocks once (refcount G), not G
//!     times, and the books rebalance to empty when the group finishes;
//!   - **preempt/resume equivalence**: a sequence preempted under
//!     synthetic block pressure and later resumed emits the same
//!     remaining tokens and version tags as an uninterrupted run;
//!   - **coalesced replay**: importing N snapshots triggers at most
//!     ceil(N/replay_batch) replays, proven by `stats.import_replays`;
//!   - **per-row replay**: an import admitted while residents are
//!     mid-generation rebuilds *only its own row*
//!     (`stats.replay_rows_skipped` counts the untouched neighbors) and
//!     the residents' streams come out identical to a run with no
//!     import at all.

use pipeline_rl::data::task::TaskGen;
use pipeline_rl::engine::{Engine, EngineCfg};
use pipeline_rl::model::Tokenizer;
use pipeline_rl::rl::Rollout;
use pipeline_rl::runtime::Runtime;
use pipeline_rl::sched::PreemptPolicy;
use pipeline_rl::testkit::runtime_or_skip;
use pipeline_rl::util::Rng;

/// Greedy decode (zero Gumbel): token streams depend only on weights and
/// the per-row inputs, never on RNG draw order or co-resident rows — the
/// determinism the preemption-equivalence proof rests on (interruption
/// changes both).
fn greedy_cfg(block_size: usize) -> EngineCfg {
    let mut c = EngineCfg::new("tiny");
    c.max_new_tokens = 8;
    c.greedy = true;
    c.block_size = block_size;
    c
}

/// Reference rollout: the problem decoded greedily, alone, with an
/// exactly-sized pool (no pressure possible).
fn solo_reference(rt: &mut Runtime, pid: u64, block_size: usize) -> Rollout {
    let gen = TaskGen::curriculum_small();
    let tk = Tokenizer::new();
    let p = gen.problem(pid);
    let toks = tk.encode(&p.prompt).unwrap();
    let params = init_params(rt);
    let mut eng = Engine::new(rt, greedy_cfg(block_size), &params, 0, Rng::new(3)).unwrap();
    eng.set_weights(1, &params).unwrap();
    eng.add_request(p, toks, 1000 + pid);
    for _ in 0..500 {
        if let Some(r) = eng.step().unwrap().finished.into_iter().next() {
            return r;
        }
    }
    panic!("reference rollout for problem {pid} never finished");
}

fn init_params(rt: &mut Runtime) -> Vec<pipeline_rl::runtime::HostTensor> {
    rt.init_params("tiny", 1).unwrap()
}

#[test]
fn group_holds_shared_prompt_blocks_once() {
    if !runtime_or_skip("group_holds_shared_prompt_blocks_once") {
        return;
    }
    let mut rt = Runtime::new().unwrap();
    let params = init_params(&mut rt);
    let gen = TaskGen::curriculum_small();
    let tk = Tokenizer::new();
    let bs = 4usize;
    let mut eng = Engine::new(&mut rt, greedy_cfg(bs), &params, 0, Rng::new(9)).unwrap();
    eng.set_weights(1, &params).unwrap();
    let g = eng.n_slots().min(4);
    if g < 2 {
        eprintln!("SKIP group_holds_shared_prompt_blocks_once: engine has {g} slot(s)");
        return;
    }
    let p = gen.problem(5);
    let toks = tk.encode(&p.prompt).unwrap();
    let stream_len = toks.len() + 1; // + BOS
    for _ in 0..g {
        eng.add_request(p.clone(), toks.clone(), 777);
    }
    // first step admits the whole group (and decodes one position —
    // still prefill, nothing divergent yet)
    assert!(!eng.step().unwrap().idle);
    let per = stream_len.div_ceil(bs);
    assert_eq!(
        eng.kv_shared_saved_blocks(),
        (g - 1) * per,
        "G members reference ceil(prompt/bs) = {per} blocks once, not {g} times"
    );
    assert_eq!(eng.kv_held_blocks(), per, "prompt blocks held exactly once");
    eng.kv_check().unwrap();

    // run the group to completion: members diverge (copy-on-write forks
    // when the first sampled token lands in a shared partial block) and
    // everything rebalances to an empty pool
    let mut finished: Vec<Rollout> = Vec::new();
    for _ in 0..1000 {
        finished.extend(eng.step().unwrap().finished);
        if finished.len() == g {
            break;
        }
    }
    assert_eq!(finished.len(), g, "every group member finishes");
    eng.kv_check().unwrap();
    assert_eq!(eng.kv_free_blocks(), eng.kv_total_blocks(), "all blocks returned");
    assert_eq!(eng.kv_shared_saved_blocks(), 0);
    // the first sampled token's K/V is written while producing the
    // second, so divergence into the shared partial last prompt block
    // needs gen_len >= 2 (an immediate EOS never writes it)
    if stream_len % bs != 0 && finished[0].gen_tokens.len() >= 2 {
        assert_eq!(
            eng.kv_cow_forks(),
            (g - 1) as u64,
            "divergence forks all but the sole remaining holder"
        );
    }
}

#[test]
fn preempted_sequence_matches_uninterrupted() {
    if !runtime_or_skip("preempted_sequence_matches_uninterrupted") {
        return;
    }
    let mut rt = Runtime::new().unwrap();
    let params = init_params(&mut rt);
    let gen = TaskGen::curriculum_small();
    let tk = Tokenizer::new();
    let bs = 2usize;

    // find two problems with enough sampled tokens and similar stream
    // lengths: co-resident peak demand then exceeds what either needs
    // alone, so a pool sized one block short of the peak forces a
    // preemption while both can still finish solo
    let mut refs: Vec<(u64, Rollout)> = Vec::new();
    for pid in 0..16u64 {
        let r = solo_reference(&mut rt, pid, bs);
        if r.gen_tokens.len() >= 3 {
            refs.push((pid, r));
        }
    }
    let mut pair = None;
    'outer: for i in 0..refs.len() {
        for j in (i + 1)..refs.len() {
            let li = refs[i].1.prompt_tokens.len() + refs[i].1.gen_tokens.len();
            let lj = refs[j].1.prompt_tokens.len() + refs[j].1.gen_tokens.len();
            let (lmin, lmax) = (li.min(lj), li.max(lj));
            // one block short of the co-resident peak: pressure strikes
            // before the shorter finishes
            let pool = 2 * lmin.div_ceil(bs) - 1;
            let admit_both = refs[i].1.prompt_tokens.len().div_ceil(bs)
                + refs[j].1.prompt_tokens.len().div_ceil(bs)
                <= pool;
            // ... while each still fits (and can resume) alone
            if admit_both && pool >= lmax.div_ceil(bs) {
                pair = Some((i, j, pool));
                break 'outer;
            }
        }
    }
    let Some((i, j, pool)) = pair else {
        eprintln!("SKIP preempted_sequence_matches_uninterrupted: no suitable problem pair");
        return;
    };
    let (pid_a, ref_a) = (refs[i].0, refs[i].1.clone());
    let (pid_b, ref_b) = (refs[j].0, refs[j].1.clone());

    let mut cfg = greedy_cfg(bs);
    cfg.kv_blocks = Some(pool);
    cfg.preempt = PreemptPolicy::Youngest;
    let mut eng = Engine::new(&mut rt, cfg, &params, 0, Rng::new(3)).unwrap();
    if eng.n_slots() < 2 {
        eprintln!("SKIP preempted_sequence_matches_uninterrupted: single-slot engine");
        return;
    }
    eng.set_weights(1, &params).unwrap();
    let pa = gen.problem(pid_a);
    let pb = gen.problem(pid_b);
    eng.add_request(pa.clone(), tk.encode(&pa.prompt).unwrap(), 11);
    eng.add_request(pb.clone(), tk.encode(&pb.prompt).unwrap(), 22);

    let mut finished: Vec<Rollout> = Vec::new();
    for _ in 0..3000 {
        finished.extend(eng.step().unwrap().finished);
        if finished.len() == 2 {
            break;
        }
    }
    assert_eq!(finished.len(), 2, "both sequences finish under block pressure");
    assert!(
        eng.stats.preemptions >= 1,
        "the undersized pool must have forced a preemption"
    );
    assert!(
        eng.stats.import_replays >= 1,
        "the parked sequence resumed through a coalesced replay"
    );
    assert!(
        eng.stats.replay_rows_rebuilt >= 1,
        "every re-admission rebuilt the victim's row"
    );
    eng.kv_check().unwrap();

    // equivalence: preemption + resume is invisible in the output
    for (gid, r) in [(11u64, &ref_a), (22u64, &ref_b)] {
        let got = finished.iter().find(|f| f.group_id == gid).expect("rollout present");
        assert_eq!(got.gen_tokens, r.gen_tokens, "same tokens as the uninterrupted run");
        assert_eq!(got.token_version, r.token_version, "same version tags");
    }
}

#[test]
fn importing_n_snapshots_coalesces_replays() {
    if !runtime_or_skip("importing_n_snapshots_coalesces_replays") {
        return;
    }
    let mut rt = Runtime::new().unwrap();
    let params = init_params(&mut rt);
    let gen = TaskGen::curriculum_small();
    let tk = Tokenizer::new();

    // donor: saturate every slot, make some progress, export everything
    let mut donor = Engine::new(&mut rt, greedy_cfg(16), &params, 0, Rng::new(4)).unwrap();
    donor.set_weights(1, &params).unwrap();
    let slots = donor.n_slots();
    if slots < 3 {
        eprintln!("SKIP importing_n_snapshots_coalesces_replays: engine has {slots} slot(s)");
        return;
    }
    for i in 0..slots {
        let p = gen.problem(30 + i as u64);
        let toks = tk.encode(&p.prompt).unwrap();
        donor.add_request(p, toks, 500 + i as u64);
    }
    for _ in 0..2 {
        assert!(!donor.step().unwrap().idle);
    }
    let snaps = donor.export_snapshots();
    let n = snaps.len();
    if n < 2 {
        eprintln!("SKIP importing_n_snapshots_coalesces_replays: only {n} in flight");
        return;
    }
    assert!(snaps.iter().all(|s| s.pos > 0), "every snapshot carries progress");

    // importer: its own sequences occupy every slot and finish at
    // staggered times — the serial-replay worst case (one slot frees at
    // a time) that coalescing exists for
    let batch = 4usize;
    let mut cfg = greedy_cfg(16);
    cfg.replay_batch = batch;
    let mut imp = Engine::new(&mut rt, cfg, &params, 1, Rng::new(5)).unwrap();
    imp.set_weights(1, &params).unwrap();
    for i in 0..slots {
        let p = gen.problem(60 + i as u64);
        let toks = tk.encode(&p.prompt).unwrap();
        imp.add_request(p, toks, 900 + i as u64);
    }
    assert!(!imp.step().unwrap().idle); // seat the locals
    for s in &snaps {
        imp.import_snapshot(s, gen.problem(s.problem_id)).unwrap();
    }

    let want_groups: Vec<u64> = snaps.iter().map(|s| s.group_id).collect();
    let mut done: Vec<u64> = Vec::new();
    for _ in 0..5000 {
        for r in imp.step().unwrap().finished {
            if want_groups.contains(&r.group_id) {
                // migrated prefix preserved verbatim
                let s = snaps.iter().find(|s| s.group_id == r.group_id).unwrap();
                assert_eq!(&r.gen_tokens[..s.gen_tokens.len()], &s.gen_tokens[..]);
                done.push(r.group_id);
            }
        }
        if done.len() == n {
            break;
        }
    }
    assert_eq!(done.len(), n, "every imported sequence finishes");
    let bound = n.div_ceil(batch) as u64;
    assert!(
        (1..=bound).contains(&imp.stats.import_replays),
        "coalescing: {} imports took {} replays, bound {bound}",
        n,
        imp.stats.import_replays
    );
    assert_eq!(imp.stats.snapshots_imported, n as u64);
    assert_eq!(
        imp.stats.replay_rows_rebuilt,
        n as u64,
        "per-row replay: each import is rebuilt exactly once, locals never"
    );
    imp.kv_check().unwrap();
}

#[test]
fn per_row_replay_skips_residents_and_leaves_their_streams_intact() {
    if !runtime_or_skip("per_row_replay_skips_residents_and_leaves_their_streams_intact") {
        return;
    }
    let mut rt = Runtime::new().unwrap();
    let params = init_params(&mut rt);
    let gen = TaskGen::curriculum_small();
    let tk = Tokenizer::new();

    // donor: one sequence with real progress, exported as a snapshot
    let mut donor = Engine::new(&mut rt, greedy_cfg(16), &params, 0, Rng::new(4)).unwrap();
    donor.set_weights(1, &params).unwrap();
    if donor.n_slots() < 2 {
        eprintln!("SKIP per_row_replay: single-slot engine");
        return;
    }
    let pd = gen.problem(40);
    donor.add_request(pd.clone(), tk.encode(&pd.prompt).unwrap(), 400);
    for _ in 0..3 {
        assert!(!donor.step().unwrap().idle);
    }
    let snaps = donor.export_snapshots();
    assert_eq!(snaps.len(), 1);
    let snap = &snaps[0];
    assert!(snap.pos > 0, "the snapshot carries progress to replay");

    // locals fill all slots but one; the free slot is the import's seat,
    // so the replay provably fires while every local is mid-generation
    let n_locals = donor.n_slots() - 1;
    let seat_locals = |eng: &mut Engine| {
        for i in 0..n_locals {
            let p = gen.problem(60 + i as u64);
            let toks = tk.encode(&p.prompt).unwrap();
            eng.add_request(p, toks, 900 + i as u64);
        }
    };
    let finish = |eng: &mut Engine, want: usize| -> Vec<Rollout> {
        let mut out = Vec::new();
        for _ in 0..3000 {
            out.extend(eng.step().unwrap().finished);
            if out.len() == want {
                break;
            }
        }
        out
    };

    // control: the locals alone — their reference streams
    let mut ctrl = Engine::new(&mut rt, greedy_cfg(16), &params, 1, Rng::new(5)).unwrap();
    ctrl.set_weights(1, &params).unwrap();
    seat_locals(&mut ctrl);
    let ctrl_done = finish(&mut ctrl, n_locals);
    assert_eq!(ctrl_done.len(), n_locals);
    assert_eq!(ctrl.stats.replay_rows_rebuilt, 0, "nothing to replay without imports");

    // probe: same locals, plus the import one step in
    let mut imp = Engine::new(&mut rt, greedy_cfg(16), &params, 2, Rng::new(6)).unwrap();
    imp.set_weights(1, &params).unwrap();
    seat_locals(&mut imp);
    assert!(!imp.step().unwrap().idle); // locals seated, streams moving
    imp.import_snapshot(snap, gen.problem(snap.problem_id)).unwrap();
    let done = finish(&mut imp, n_locals + 1);
    assert_eq!(done.len(), n_locals + 1, "locals and the import all finish");

    // the replay rebuilt exactly the imported row and skipped every
    // resident neighbor — the redundant work the legacy full-batch
    // replay performed
    assert_eq!(imp.stats.import_replays, 1);
    assert_eq!(imp.stats.replay_rows_rebuilt, 1, "only the import was re-fed");
    assert_eq!(
        imp.stats.replay_rows_skipped,
        n_locals as u64,
        "every mid-generation resident stayed out of the replay"
    );

    // ...and skipping them is safe: their streams match the no-import
    // control bit for bit (greedy decode — any KV corruption from the
    // replay's parked scatters would fork the tokens)
    for c in &ctrl_done {
        let got = done
            .iter()
            .find(|r| r.group_id == c.group_id)
            .expect("local rollout present");
        assert_eq!(got.gen_tokens, c.gen_tokens, "resident streams untouched by the replay");
        assert_eq!(got.token_version, c.token_version);
    }
    // migrated prefix preserved verbatim through the per-row rebuild
    let m = done.iter().find(|r| r.group_id == 400).expect("import finishes");
    assert_eq!(&m.gen_tokens[..snap.gen_tokens.len()], &snap.gen_tokens[..]);
    imp.kv_check().unwrap();
}
