//! Serving-gateway integration: trait conformance + the open-loop SLO
//! acceptance scenario (ROADMAP direction 1).
//!
//! Two layers:
//!
//! 1. **GenerationService conformance** — one generic suite drives every
//!    implementation of the paper's three-endpoint API through the same
//!    obligations: submissions complete, load/slots books balance,
//!    `export_snapshots`/`import_snapshot` round-trips preserve
//!    generated prefixes, KV pressure stays within the pool, and an
//!    in-flight `request_weight_update` never drops a sequence. It runs
//!    device-free against [`SimService`] and a gateway-fronted
//!    `Gateway<SimService>`, and against the real [`Engine`] when a PJRT
//!    runtime is present (`runtime_or_skip`, see tier1.sh).
//!
//! 2. **Bursty SLO acceptance** — a seeded open-loop arrival trace
//!    (`simcluster::arrival`, Poisson base + 8x flash-crowd windows)
//!    submits interactive traffic against a gateway whose slots are kept
//!    saturated with house batch rollouts. Device-free and fully
//!    deterministic, it proves the tentpole claims: interactive p99
//!    admission-to-first-token holds the configured SLO *through* the
//!    bursts, batch degrades gracefully (QoS preemptions park victims
//!    losslessly) and recovers (every batch rollout still completes),
//!    and the gateway park's conservation books close with zero
//!    salvageable tokens lost.

use pipeline_rl::config::GatewayConfig;
use pipeline_rl::data::task::{Problem, TaskGen};
use pipeline_rl::engine::{CompletionRequest, Engine, EngineCfg, GenerationService};
use pipeline_rl::gateway::{Gateway, SimService};
use pipeline_rl::model::Tokenizer;
use pipeline_rl::runtime::{HostTensor, Runtime};
use pipeline_rl::simcluster::{due_at, poisson_trace, ArrivalCfg};
use pipeline_rl::testkit::runtime_or_skip;
use pipeline_rl::util::Rng;

const SIM_SEED: u64 = 0x6a7e_0001;

/// Deterministic problems (and thus prompts) shared by every service
/// under test; ids must be unique per request so KV prefix-sharing keys
/// (group ids) never alias across different prompts.
fn problem_of(id: u64) -> Problem {
    TaskGen::curriculum_small().problem(id)
}

fn rollout_req(id: u64) -> CompletionRequest {
    let p = problem_of(id);
    let toks = Tokenizer::new().encode(&p.prompt).expect("task prompt tokenizes");
    CompletionRequest::rollout(p, toks, id)
}

fn interactive_req(id: u64, tenant: u64) -> CompletionRequest {
    let p = problem_of(id);
    let toks = Tokenizer::new().encode(&p.prompt).expect("task prompt tokenizes");
    CompletionRequest::interactive(p, toks, id, tenant)
}

fn sim() -> SimService {
    SimService::new(4, 64, 4, 8, SIM_SEED)
}

// ---------------------------------------------------------------------
// 1. conformance suite
// ---------------------------------------------------------------------

/// Trait-level obligations every GenerationService must meet. `params`
/// is whatever the service accepts as a weight payload (empty for the
/// device-free sim; real host tensors for the engine). Request/problem
/// ids are drawn from `base..` so repeated runs in one process never
/// alias groups.
fn conformance<S: GenerationService>(svc: &mut S, params: &[HostTensor], base: u64, name: &str) {
    assert!(svc.slots() > 0, "{name}: a service must expose decode slots");
    assert_eq!(svc.load(), 0, "{name}: fresh service is idle");
    svc.init_process_group("conformance").unwrap();

    // -- every submission completes, and load counts queued work --
    let n = svc.slots().min(4);
    for i in 0..n as u64 {
        svc.submit(rollout_req(base + i)).unwrap();
    }
    assert_eq!(svc.load(), n, "{name}: load counts submitted work");
    let kv = svc.kv_pressure();
    assert!(
        kv.free_blocks <= kv.total_blocks && kv.held_blocks <= kv.total_blocks,
        "{name}: KV books within the pool"
    );
    let mut done = Vec::new();
    for step in 0.. {
        assert!(step < 4000, "{name}: run did not complete");
        done.extend(svc.step().unwrap());
        // an in-flight weight update must not drop sequences
        if step == 1 {
            svc.request_weight_update(1, params).unwrap();
        }
        if svc.load() == 0 {
            break;
        }
    }
    assert_eq!(done.len(), n, "{name}: every submission completes");
    for r in &done {
        r.validate().unwrap();
        assert!(!r.gen_tokens.is_empty(), "{name}: rollouts carry tokens");
    }

    // -- export/import round-trips preserve generated prefixes --
    let m = 2u64;
    for i in 0..m {
        svc.submit(rollout_req(base + 100 + i)).unwrap();
    }
    let mut early = Vec::new();
    for _ in 0..3 {
        early.extend(svc.step().unwrap());
    }
    let snaps = svc.export_snapshots();
    assert_eq!(svc.load(), 0, "{name}: export drains the service");
    assert_eq!(
        early.len() + snaps.len(),
        m as usize,
        "{name}: finished + exported covers every submission"
    );
    for sn in &snaps {
        sn.validate().unwrap();
        svc.import_snapshot(sn, problem_of(sn.problem_id)).unwrap();
    }
    let mut late = Vec::new();
    for step in 0.. {
        assert!(step < 4000, "{name}: resumed run did not complete");
        late.extend(svc.step().unwrap());
        if svc.load() == 0 {
            break;
        }
    }
    assert_eq!(late.len(), snaps.len(), "{name}: every import completes");
    for sn in &snaps {
        let r = late
            .iter()
            .find(|r| r.group_id == sn.group_id)
            .expect("re-imported sequence finished");
        assert!(
            r.gen_tokens.len() >= sn.gen_tokens.len()
                && r.gen_tokens[..sn.gen_tokens.len()] == sn.gen_tokens[..],
            "{name}: parked prefix survives at the front of the rollout"
        );
    }
    let kv = svc.kv_pressure();
    assert_eq!(kv.free_blocks, kv.total_blocks, "{name}: idle service holds no blocks");
}

#[test]
fn sim_service_conforms() {
    conformance(&mut sim(), &[], 1000, "SimService");
}

#[test]
fn gateway_front_conforms() {
    // the gateway wraps a service and *is* one: same obligations, with
    // its admission queue and park folded into the load/export books
    let mut gw = Gateway::new(sim(), GatewayConfig::default());
    conformance(&mut gw, &[], 2000, "Gateway<SimService>");
    assert_eq!(gw.stats().shed_batch, 0, "conformance traffic never sheds");
}

#[test]
fn engine_conforms() {
    if !runtime_or_skip("engine_conforms") {
        return;
    }
    let mut rt = Runtime::new().expect("runtime");
    let params = rt.init_params("tiny", 7).unwrap();
    let mut cfg = EngineCfg::new("tiny");
    cfg.max_new_tokens = 8;
    let mut eng = Engine::new(&mut rt, cfg, &params, 0, Rng::new(1)).unwrap();
    eng.set_weights(0, &params).unwrap();
    conformance(&mut eng, &params, 3000, "Engine");
}

#[test]
fn gateway_fronted_engine_conforms() {
    if !runtime_or_skip("gateway_fronted_engine_conforms") {
        return;
    }
    let mut rt = Runtime::new().expect("runtime");
    let params = rt.init_params("tiny", 7).unwrap();
    let mut cfg = EngineCfg::new("tiny");
    cfg.max_new_tokens = 8;
    let eng = Engine::new(&mut rt, cfg, &params, 0, Rng::new(1)).unwrap();
    let mut gw = Gateway::new(eng, GatewayConfig::default());
    gw.svc_mut().set_weights(0, &params).unwrap();
    conformance(&mut gw, &params, 4000, "Gateway<Engine>");
}

// ---------------------------------------------------------------------
// 2. bursty open-loop SLO acceptance
// ---------------------------------------------------------------------

fn percentile(sorted: &[u64], p: f64) -> u64 {
    assert!(!sorted.is_empty());
    let idx = ((sorted.len() - 1) as f64 * p).ceil() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

#[test]
fn interactive_p99_holds_slo_under_bursts_while_batch_recovers() {
    // 8 slots, 128 KV blocks; interactive turns are short (<= 5 tokens,
    // a chat-style reply), batch rollouts run the full length range
    let slots = 8usize;
    let max_new = 16usize;
    let svc = SimService::new(slots, 64, 4, max_new, SIM_SEED);
    let cfg = GatewayConfig::default(); // preempt on, slo_p99_ticks = 25
    let slo = cfg.slo_p99_ticks;
    let mut gw = Gateway::new(svc, cfg);

    // interactive problems: ids picked so the sim's deterministic
    // generation length is short — SLO traffic is short-turn by design
    let mut inter_pids =
        (10_000u64..).filter(|p| SimService::target_len(SIM_SEED, *p, max_new) <= 5);

    // open-loop arrivals: Poisson base with 8x flash crowds covering 20%
    // of the horizon — the trace the SLO must survive
    let arrivals = ArrivalCfg {
        rate: 0.06,
        horizon: 600,
        tenants: 4,
        burst_every: 150,
        burst_len: 30,
        burst_mult: 8.0,
    };
    let trace = poisson_trace(&arrivals, SIM_SEED);
    assert!(trace.len() > 30, "trace dense enough to mean anything");
    let mut cursor = 0usize;

    let mut inter_tickets = Vec::new();
    let mut next_batch_pid = 100_000u64;
    let outstanding_batch = |gw: &Gateway<SimService>| {
        let st = gw.stats();
        (st.submitted_batch - st.finished_batch - st.shed_batch) as usize
    };

    // phase 1: the open-loop horizon. House batch keeps the engine
    // saturated (12 outstanding >= 8 slots), so every burst admission
    // exercises the preemption path.
    for tick in 0..arrivals.horizon {
        for a in due_at(&trace, &mut cursor, tick) {
            let pid = inter_pids.next().expect("infinite id stream");
            inter_tickets.push(gw.submit(interactive_req(pid, a.tenant)).unwrap());
        }
        while outstanding_batch(&gw) < 12 {
            gw.submit(rollout_req(next_batch_pid)).unwrap();
            next_batch_pid += 1;
        }
        gw.step().unwrap();
    }

    // phase 2: drain — no new traffic; everything in custody completes
    for step in 0.. {
        assert!(step < 4000, "drain did not quiesce");
        gw.step().unwrap();
        if gw.load() == 0 {
            break;
        }
    }

    let st = *gw.stats();

    // every interactive request was served (the queue bound never bit)
    assert_eq!(st.shed_interactive, 0, "no interactive request shed");
    assert_eq!(st.finished_interactive, inter_tickets.len() as u64);

    // p99 admission-to-first-token within the SLO, measured through the
    // bursts: first-token step comes from the service (its step clock
    // advances with the gateway tick), arrival from the ticket ledger
    let mut att: Vec<u64> = inter_tickets
        .iter()
        .map(|&tid| {
            let t = gw.ticket(tid).expect("ticket retained");
            assert!(!t.shed && t.finished_tick.is_some());
            let seq = t.engine_seq.expect("admitted");
            let first = gw.svc().first_token_step(seq).expect("generated");
            first - t.arrived_tick
        })
        .collect();
    att.sort_unstable();
    let p50 = percentile(&att, 0.50);
    let p99 = percentile(&att, 0.99);
    assert!(
        (p99 as f64) <= slo,
        "interactive p99 admission-to-first-token {p99} ticks > SLO {slo} (p50 {p50})"
    );

    // batch degraded gracefully: bursts forced preemptions, every parked
    // victim was reclaimed, and the conservation books closed with zero
    // salvageable tokens lost
    assert!(st.qos_preemptions > 0, "bursts must exercise the preemption path");
    assert_eq!(st.reclaimed, st.qos_preemptions, "every victim came home");
    let hub = gw.parked();
    assert_eq!(
        hub.deposited(),
        hub.claimed() + hub.discarded() + hub.depth() as u64
    );
    assert_eq!(hub.depth(), 0);
    assert_eq!(hub.discarded(), 0, "no parked rollout was abandoned");
    let (dep_tokens, claimed_tokens) = hub.token_counts();
    assert_eq!(dep_tokens, claimed_tokens, "zero salvageable tokens lost");

    // ... and recovered: every batch rollout the run submitted finished
    assert_eq!(st.finished_batch, st.submitted_batch - st.shed_batch);
    assert!(st.finished_batch > 0);
    assert_eq!(gw.in_custody(), 0);
}
