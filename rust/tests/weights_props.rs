//! Property tests (testkit::check) for the weight bus:
//!
//! * versions observed by any receiver are **strictly monotonic** under
//!   concurrent publishers — `fetch_if_newer` can skip versions (that is
//!   the in-flight design: actors jump to the freshest weights) but can
//!   never deliver one twice or out of order;
//! * `bytes_fetched` accounting is exact: it equals the sum of `nbytes`
//!   over every parameter set actually handed to a receiver.

use pipeline_rl::runtime::HostTensor;
use pipeline_rl::testkit::check;
use pipeline_rl::weights::WeightBus;
use std::sync::{Arc, Mutex};

fn params_for(version: u64, base_len: usize) -> Arc<Vec<HostTensor>> {
    // version-dependent sizes make the byte accounting non-trivial
    let len = base_len + (version as usize % 3);
    Arc::new(vec![
        HostTensor::from_f32(&[len], vec![version as f32; len]),
        HostTensor::from_f32(&[2], vec![0.0, version as f32]),
    ])
}

#[test]
fn prop_versions_strictly_monotonic_and_bytes_exact() {
    check("weight bus monotonic fetch + exact bytes", 20, 0x3b5, 24, |c| {
        let n_pub = c.usize_in(1, 3);
        let n_recv = c.usize_in(1, 3);
        let last = c.usize_in(5, 5 + c.size.min(40)) as u64;
        let base_len = c.usize_in(1, 8);
        let bus = WeightBus::new();
        // concurrent publishers draw strictly increasing versions from a
        // shared counter; the draw+publish pair is atomic so the stream
        // of published versions is increasing
        let next = Arc::new(Mutex::new(1u64));
        let mut pubs = Vec::new();
        for _ in 0..n_pub {
            let bus = bus.clone();
            let next = next.clone();
            pubs.push(std::thread::spawn(move || loop {
                let mut g = next.lock().unwrap();
                let v = *g;
                if v > last {
                    return;
                }
                *g += 1;
                bus.publish(v, params_for(v, base_len));
                drop(g);
                std::thread::yield_now();
            }));
        }
        let mut recvs = Vec::new();
        for _ in 0..n_recv {
            let bus = bus.clone();
            recvs.push(std::thread::spawn(move || {
                let mut have = 0u64;
                let mut bytes = 0u64;
                let mut fetches = 0u64;
                while have < last {
                    if let Some(w) = bus.fetch_if_newer(have) {
                        assert!(
                            w.version > have,
                            "non-monotonic fetch: {} after {}",
                            w.version,
                            have
                        );
                        have = w.version;
                        bytes += w.params.iter().map(|t| t.nbytes() as u64).sum::<u64>();
                        fetches += 1;
                    } else {
                        std::thread::yield_now();
                    }
                }
                (bytes, fetches)
            }));
        }
        for p in pubs {
            p.join().unwrap();
        }
        let mut receiver_bytes = 0u64;
        let mut receiver_fetches = 0u64;
        for r in recvs {
            let (b, f) = r.join().unwrap();
            receiver_bytes += b;
            receiver_fetches += f;
        }
        if bus.publishes() != last {
            return Err(format!("publishes {} != {last}", bus.publishes()));
        }
        if bus.latest_version() != last {
            return Err(format!("latest {} != {last}", bus.latest_version()));
        }
        if receiver_fetches == 0 {
            return Err("receivers fetched nothing".into());
        }
        if bus.bytes_fetched() != receiver_bytes {
            return Err(format!(
                "byte accounting drifted: bus says {}, receivers counted {}",
                bus.bytes_fetched(),
                receiver_bytes
            ));
        }
        Ok(())
    });
}
