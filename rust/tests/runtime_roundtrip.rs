//! Integration spike: the python-AOT -> rust-load -> execute path.
//!
//! Verifies, against the tiny variant, that every exported graph loads,
//! compiles and produces sane numerics on the PJRT CPU client:
//!   * init produces params with the manifest's shapes
//!   * decode: forced tokens echo back, logprobs normalize, KV advances
//!   * train: runs a step, metrics vector matches manifest layout
//!   * sft: loss decreases over a few steps on a trivial corpus
//!   * score: teacher-forced logprobs agree with the decode-path logprobs
//!     for an identical context (the decode/train consistency the IS
//!     weights in Eq. 5 rely on).

use pipeline_rl::runtime::{check_params, HostTensor, Runtime};

const V: &str = "tiny";

use pipeline_rl::testkit::runtime_or_skip;

fn setup() -> (Runtime, Vec<HostTensor>) {
    let mut rt = Runtime::new().expect("runtime (did you run `make artifacts`?)");
    let params = rt.init_params(V, 42).unwrap();
    (rt, params)
}

#[test]
fn init_matches_manifest() {
    if !runtime_or_skip("init_matches_manifest") {
        return;
    }
    let (rt, params) = setup();
    let v = rt.manifest.variant(V).unwrap();
    check_params(v, &params).unwrap();
    // embed is random-normal*0.02: sanity-check the spread
    let embed = params[0].f32s().unwrap();
    let mean: f32 = embed.iter().sum::<f32>() / embed.len() as f32;
    assert!(mean.abs() < 0.01, "embed mean {mean}");
    assert!(embed.iter().any(|&x| x != 0.0));
}

#[test]
fn decode_forced_tokens_echo_and_logprobs_normalize() {
    if !runtime_or_skip("decode_forced_tokens_echo_and_logprobs_normalize") {
        return;
    }
    let (mut rt, params) = setup();
    let v = rt.manifest.variant(V).unwrap().clone();
    let g = rt.graph(V, "decode").unwrap();
    let b = v.gen_batch;
    let vocab = v.vocab;

    let kv = HostTensor::zeros_f32(&v.kv_shape());
    let pos = HostTensor::zeros_i32(&[b]);
    let cur = HostTensor::from_i32(&[b], vec![1; b]); // BOS
    let gumbel = HostTensor::zeros_f32(&[b, vocab]);
    let force_tok = HostTensor::from_i32(&[b], (0..b as i32).map(|i| 5 + i).collect());
    let force_mask = HostTensor::from_f32(&[b], vec![1.0; b]);
    let temp = HostTensor::scalar_f32(1.0);

    let mut inputs = params.clone();
    inputs.extend([kv, pos, cur, gumbel, force_tok, force_mask, temp]);
    let out = g.run_host(&inputs).unwrap();
    // outputs: next_tok[B], chosen_lp[B], lp_all[B,V], kv', ent[B]
    assert_eq!(out.len(), 5);
    let next = out[0].i32s().unwrap();
    for (i, &t) in next.iter().enumerate() {
        assert_eq!(t, 5 + i as i32, "forced token must echo");
    }
    let lp_all = out[2].f32s().unwrap();
    for row in lp_all.chunks(vocab) {
        let z: f32 = row.iter().map(|lp| lp.exp()).sum();
        assert!((z - 1.0).abs() < 1e-3, "softmax normalizes, got {z}");
    }
    // KV at pos 0 must now be nonzero for every slot
    let kv_out = out[3].f32s().unwrap();
    assert!(kv_out.iter().any(|&x| x != 0.0));
    let ent = out[4].f32s().unwrap();
    for &e in ent {
        assert!(e > 0.0 && e <= (vocab as f32).ln() + 1e-3, "entropy {e}");
    }
}

#[test]
fn sft_loss_decreases() {
    if !runtime_or_skip("sft_loss_decreases") {
        return;
    }
    let (mut rt, mut params) = setup();
    let v = rt.manifest.variant(V).unwrap().clone();
    let g = rt.graph(V, "sft").unwrap();
    let (b, t) = (v.train_batch, v.seq_len);

    let mut m = rt.zero_opt_state(V).unwrap();
    let mut vv = rt.zero_opt_state(V).unwrap();

    // trivial corpus: BOS 5 6 7 8 ... repeated; mask on the first 10 targets
    let mut tokens = vec![0i32; b * t];
    let mut seg = vec![0i32; b * t];
    let mut pos = vec![0i32; b * t];
    let mut mask = vec![0f32; b * t];
    for row in 0..b {
        tokens[row * t] = 1; // BOS
        seg[row * t] = 1;
        for i in 1..12 {
            tokens[row * t + i] = 4 + (i as i32 % 8);
            seg[row * t + i] = 1;
            pos[row * t + i] = i as i32;
        }
        for i in 0..11 {
            mask[row * t + i] = 1.0;
        }
    }

    let mut losses = Vec::new();
    for step in 1..=8 {
        let mut inputs = params.clone();
        inputs.extend(m.clone());
        inputs.extend(vv.clone());
        inputs.push(HostTensor::scalar_f32(step as f32));
        inputs.push(HostTensor::from_i32(&[b, t], tokens.clone()));
        inputs.push(HostTensor::from_i32(&[b, t], seg.clone()));
        inputs.push(HostTensor::from_i32(&[b, t], pos.clone()));
        inputs.push(HostTensor::from_f32(&[b, t], mask.clone()));
        inputs.push(HostTensor::scalar_f32(0.01));
        let out = g.run_host(&inputs).unwrap();
        let p = v.params.len();
        assert_eq!(out.len(), 3 * p + 1);
        params = out[0..p].to_vec();
        m = out[p..2 * p].to_vec();
        vv = out[2 * p..3 * p].to_vec();
        let metrics = out[3 * p].f32s().unwrap();
        losses.push(metrics[0]);
    }
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "sft loss should fall: {losses:?}"
    );
}

#[test]
fn train_step_runs_and_metrics_layout_matches() {
    if !runtime_or_skip("train_step_runs_and_metrics_layout_matches") {
        return;
    }
    let (mut rt, params) = setup();
    let v = rt.manifest.variant(V).unwrap().clone();
    let g = rt.graph(V, "train").unwrap();
    let (b, t) = (v.train_batch, v.seq_len);
    let p = v.params.len();

    let m = rt.zero_opt_state(V).unwrap();
    let vv = rt.zero_opt_state(V).unwrap();

    let mut tokens = vec![0i32; b * t];
    let mut seg = vec![0i32; b * t];
    let mut pos = vec![0i32; b * t];
    let mut mask = vec![0f32; b * t];
    for row in 0..b {
        tokens[row * t] = 1;
        seg[row * t] = 1;
        for i in 1..20 {
            tokens[row * t + i] = 3 + ((row + i) as i32 % 10);
            seg[row * t + i] = 1;
            pos[row * t + i] = i as i32;
        }
        for i in 0..19 {
            mask[row * t + i] = 1.0;
        }
    }
    // exactly on-policy: behavior_lp == current lp => ESS must be 1
    let score = rt.graph(V, "score").unwrap();
    let mut sin = params.clone();
    sin.push(HostTensor::from_i32(&[b, t], tokens.clone()));
    sin.push(HostTensor::from_i32(&[b, t], seg.clone()));
    sin.push(HostTensor::from_i32(&[b, t], pos.clone()));
    let sout = score.run_host(&sin).unwrap();
    let behavior_lp = sout[0].clone();

    let mut inputs = params.clone();
    inputs.extend(m);
    inputs.extend(vv);
    inputs.push(HostTensor::scalar_f32(1.0));
    inputs.push(HostTensor::from_i32(&[b, t], tokens));
    inputs.push(HostTensor::from_i32(&[b, t], seg));
    inputs.push(HostTensor::from_i32(&[b, t], pos));
    inputs.push(behavior_lp);
    inputs.push(HostTensor::from_f32(&[b, t], vec![1.0; b * t])); // adv
    inputs.push(HostTensor::from_f32(&[b, t], vec![1.0; b * t])); // reward (per-token)
    inputs.push(HostTensor::from_f32(&[b, t], mask));
    inputs.push(HostTensor::scalar_f32(1e-3)); // lr
    inputs.push(HostTensor::scalar_f32(5.0)); // clip_c
    inputs.push(HostTensor::scalar_f32(0.0)); // adv_mode: input advantage
    inputs.push(HostTensor::scalar_f32(0.5)); // vf_coef
    let out = g.run_host(&inputs).unwrap();
    assert_eq!(out.len(), 3 * p + 1);
    let metrics = out[3 * p].f32s().unwrap();
    assert_eq!(metrics.len(), rt.manifest.metric_names.len());

    let idx = |n: &str| rt.manifest.metric_index(n).unwrap();
    let ess = metrics[idx("ess")];
    assert!((ess - 1.0).abs() < 1e-3, "on-policy ESS must be 1, got {ess}");
    let kl = metrics[idx("mean_kl")];
    assert!(kl.abs() < 1e-4, "on-policy KL ~ 0, got {kl}");
    assert!(metrics[idx("grad_norm")] > 0.0);
    assert_eq!(metrics[idx("n_tokens")], 19.0 * b as f32);
    // params actually changed
    let delta: f32 = out[0]
        .f32s()
        .unwrap()
        .iter()
        .zip(params[0].f32s().unwrap())
        .map(|(a, b)| (a - b).abs())
        .sum();
    assert!(delta > 0.0, "params must move");
}

#[test]
fn decode_chain_matches_teacher_forced_score() {
    if !runtime_or_skip("decode_chain_matches_teacher_forced_score") {
        return;
    }
    let (mut rt, params) = setup();
    let v = rt.manifest.variant(V).unwrap().clone();
    let decode = rt.graph(V, "decode").unwrap();
    let score = rt.graph(V, "score").unwrap();
    let (b, t, vocab) = (v.gen_batch, v.seq_len, v.vocab);

    // force a fixed token sequence through the decode path, collecting the
    // chosen-token logprobs at every step
    let forced: Vec<i32> = vec![5, 9, 12, 7, 4, 11, 6, 8];
    let mut kv = HostTensor::zeros_f32(&v.kv_shape());
    let mut cur = vec![1i32; b]; // BOS
    let mut decode_lps: Vec<Vec<f32>> = Vec::new();
    for (i, &ftok) in forced.iter().enumerate() {
        let mut inputs = params.clone();
        inputs.push(kv);
        inputs.push(HostTensor::from_i32(&[b], vec![i as i32; b]));
        inputs.push(HostTensor::from_i32(&[b], cur.clone()));
        inputs.push(HostTensor::zeros_f32(&[b, vocab]));
        inputs.push(HostTensor::from_i32(&[b], vec![ftok; b]));
        inputs.push(HostTensor::from_f32(&[b], vec![1.0; b]));
        inputs.push(HostTensor::scalar_f32(1.0));
        let out = decode.run_host(&inputs).unwrap();
        decode_lps.push(out[1].f32s().unwrap().to_vec());
        kv = out[3].clone();
        cur = out[0].i32s().unwrap().to_vec();
    }

    // teacher-forced scoring of the same sequence (score batch = train_batch;
    // take row 0 and compare against decode slot 0)
    let bt = v.train_batch;
    let mut tokens = vec![0i32; bt * t];
    let mut seg = vec![0i32; bt * t];
    let mut pos = vec![0i32; bt * t];
    for row in 0..bt {
        tokens[row * t] = 1;
        seg[row * t] = 1;
        for (i, &f) in forced.iter().enumerate() {
            tokens[row * t + i + 1] = f;
            seg[row * t + i + 1] = 1;
            pos[row * t + i + 1] = (i + 1) as i32;
        }
    }
    let mut sin = params.clone();
    sin.push(HostTensor::from_i32(&[bt, t], tokens));
    sin.push(HostTensor::from_i32(&[bt, t], seg));
    sin.push(HostTensor::from_i32(&[bt, t], pos));
    let sout = score.run_host(&sin).unwrap();
    let lp = sout[0].f32s().unwrap();

    for (i, step_lps) in decode_lps.iter().enumerate() {
        let want = lp[i]; // row 0, position i predicts forced[i] == tokens[i+1]
        let got = step_lps[0];
        assert!(
            (want - got).abs() < 2e-3,
            "decode/score logprob mismatch at step {i}: {got} vs {want}"
        );
    }
}
