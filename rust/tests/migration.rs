//! Portable in-flight rollouts: migration, scheduling and autoscaling.
//!
//! Three tiers:
//!
//! * **Device-free properties**: `SeqSnapshot` round-trips bit-exactly
//!   through its byte format (the process-boundary contract).
//! * **Substrate scenarios** (always run): the acceptance case — one of
//!   three actors is slow-killed mid-run over the real supervisor /
//!   `MigrationHub` machinery and *zero salvageable tokens are lost*:
//!   every in-flight sequence of the victim completes on another actor
//!   (same group id, prefix preserved) or is accounted as deliberately
//!   discarded. Plus the supervisor-level autoscaler: the pool grows
//!   under a sustained rollout-queue backlog and shrinks back once the
//!   backlog clears and the supply topic saturates.
//! * **Full-pipeline scenarios** (gated on `runtime_available()`): the
//!   migration-equivalence proof — a sequence migrated mid-generation
//!   across engines emits the same remaining tokens and version tags as
//!   one that was never interrupted — and an end-to-end chaos run whose
//!   migration books balance.

use pipeline_rl::broker::{topic, Policy, Publisher};
use pipeline_rl::config::RunConfig;
use pipeline_rl::coordinator;
use pipeline_rl::coordinator::supervisor::{
    run_supervisor, ActorPool, SpawnFn, SupervisorArgs,
};
use pipeline_rl::data::task::{TaskGen, TaskKind};
use pipeline_rl::engine::{Engine, EngineCfg};
use pipeline_rl::metrics::MetricsHub;
use pipeline_rl::model::Tokenizer;
use pipeline_rl::rl::{FinishReason, Rollout};
use pipeline_rl::runtime::Runtime;
use pipeline_rl::sched::{AutoScaleCfg, AutoScaler, MigrationHub, SeqSnapshot};
use pipeline_rl::testkit::{self, chaos::ChaosSchedule, runtime_or_skip};
use pipeline_rl::util::Rng;
use pipeline_rl::weights::WeightBus;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------
// device-free properties
// ---------------------------------------------------------------------

#[test]
fn property_snapshot_roundtrips_bit_exactly() {
    testkit::check("snapshot byte roundtrip", 300, 0x54a9, 64, |c| {
        let prompt_len = c.usize_in(1, 12);
        let gen_len = c.usize_in(0, 16);
        let prompt: Vec<i32> = (0..prompt_len)
            .map(|_| c.rng.range(-1_000_000, 1_000_000) as i32)
            .collect();
        let gen_tokens: Vec<i32> =
            (0..gen_len).map(|_| c.rng.range(0, 65_535) as i32).collect();
        let behavior_lp: Vec<f32> = (0..gen_len).map(|_| -c.rng.f32() * 20.0).collect();
        let token_version: Vec<u64> = (0..gen_len).map(|_| c.rng.next_u64()).collect();
        let pos = if gen_len == 0 {
            c.rng.below(prompt_len)
        } else {
            prompt_len - 1 + gen_len
        };
        let snap = SeqSnapshot {
            seq_id: c.rng.next_u64(),
            group_id: c.rng.next_u64(),
            problem_id: c.rng.next_u64(),
            prompt,
            gen_tokens,
            behavior_lp,
            token_version,
            pos,
            max_new: gen_len + c.rng.below(32),
            rng_words: [
                c.rng.next_u64(),
                c.rng.next_u64(),
                c.rng.next_u64(),
                c.rng.next_u64(),
            ],
            t_start: c.rng.f64() * 1e6,
        };
        snap.validate().map_err(|e| format!("generated snapshot invalid: {e}"))?;
        let bytes = snap.to_bytes();
        let back = SeqSnapshot::from_bytes(&bytes).map_err(|e| format!("decode: {e}"))?;
        if back != snap {
            return Err("decoded snapshot differs from original".into());
        }
        if back.to_bytes() != bytes {
            return Err("re-serialization is not byte-identical".into());
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// substrate scenarios (always run)
// ---------------------------------------------------------------------

const GEN_TARGET: usize = 8;

fn fresh_snap(actor: usize, n: u64) -> SeqSnapshot {
    SeqSnapshot {
        seq_id: n,
        group_id: ((actor as u64 + 1) << 40) | n,
        problem_id: n,
        prompt: vec![1, 2, 3],
        gen_tokens: Vec::new(),
        behavior_lp: Vec::new(),
        token_version: Vec::new(),
        pos: 0,
        max_new: GEN_TARGET,
        rng_words: [0; 4],
        t_start: 0.0,
    }
}

/// Synthetic actor for migration tests: keeps 3 sequences "in flight"
/// (one token per tick, actor-flavored token values), claims orphans
/// from the migration hub ahead of fresh work — mirroring the real
/// actor's metrics — and deposits its in-flight set when halted mid-run.
fn migrating_spawn(
    bus: WeightBus,
    tx: Publisher<Rollout>,
    hub: MetricsHub,
    hub_m: Arc<MigrationHub>,
    deposited_log: Arc<Mutex<Vec<SeqSnapshot>>>,
) -> SpawnFn {
    Arc::new(move |ctx| {
        let name = format!("actor-{}", ctx.actor_id);
        bus.init_process_group(&name);
        let mut next_local = 0u64;
        let mut inflight: Vec<SeqSnapshot> = Vec::new();
        while !ctx.stop.load(Ordering::Relaxed) && !ctx.halt.load(Ordering::Relaxed) {
            // adopt migrated work first (the real actor does the same)
            while inflight.len() < 3 {
                if let Some(s) = hub_m.claim(1).pop() {
                    hub.add("migrations_completed", 1.0);
                    hub.add("snapshot_tokens_salvaged", s.salvaged_tokens() as f64);
                    inflight.push(s);
                } else {
                    inflight.push(fresh_snap(ctx.actor_id, next_local));
                    next_local += 1;
                }
            }
            // one decode tick per in-flight sequence
            let mut i = 0;
            while i < inflight.len() {
                let s = &mut inflight[i];
                let tok = (ctx.actor_id as i32) * 1000 + 100 + s.gen_tokens.len() as i32;
                s.gen_tokens.push(tok);
                s.behavior_lp.push(-0.5);
                s.token_version.push(bus.latest_version());
                s.pos = s.prompt.len() - 1 + s.gen_tokens.len();
                if s.gen_tokens.len() >= GEN_TARGET {
                    let done = inflight.swap_remove(i);
                    let r = Rollout {
                        seq_id: done.seq_id,
                        problem_id: done.problem_id,
                        group_id: done.group_id,
                        actor_id: ctx.actor_id,
                        prompt_tokens: done.prompt,
                        gen_tokens: done.gen_tokens,
                        behavior_lp: done.behavior_lp,
                        token_version: done.token_version,
                        reward: 0.0,
                        finish: FinishReason::Eos,
                        t_start: 0.0,
                        t_end: 0.0,
                    };
                    if tx.send(r).is_err() {
                        bus.leave_process_group(&name);
                        return Ok(());
                    }
                } else {
                    i += 1;
                }
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        // kill/descale mid-run: hand the in-flight set over, like the
        // real actor's export_snapshots path. Run shutdown discards.
        if ctx.halt.load(Ordering::Relaxed)
            && !ctx.stop.load(Ordering::Relaxed)
            && !inflight.is_empty()
        {
            deposited_log.lock().unwrap().extend(inflight.iter().cloned());
            hub_m.deposit(inflight);
        }
        bus.leave_process_group(&name);
        Ok(())
    })
}

/// The acceptance scenario: one of three actors slow-killed mid-run,
/// zero salvageable tokens lost — every in-flight sequence of the victim
/// completes on a *different* actor with its group id and generated
/// prefix intact, and the books (deposited == claimed, nothing
/// discarded) balance in the metrics.
#[test]
fn chaos_kill_one_of_three_loses_no_salvageable_tokens() {
    // slow kill (satellite: latency-injected, not instant): fires once
    // the version clock passes 2, halt lands 10ms later. with_seed puts
    // the replay seed in the failure output on every path.
    let schedule = ChaosSchedule::slow_kill(2, 10);
    testkit::with_seed("chaos_kill_one_of_three", schedule.seed, move |_| {
        let hub = MetricsHub::new();
        let bus = WeightBus::new();
        bus.publish(1, Arc::new(vec![]));
        let (tx, rx) = topic::<Rollout>("rollouts", 1024, Policy::DropOldest);
        let stop = Arc::new(AtomicBool::new(false));
        let hub_m = Arc::new(MigrationHub::new());
        let deposited = Arc::new(Mutex::new(Vec::new()));

        let pool = ActorPool::new(
            migrating_spawn(
                bus.clone(),
                tx.clone(),
                hub.clone(),
                hub_m.clone(),
                deposited.clone(),
            ),
            stop.clone(),
            hub.clone(),
            3,     // initial
            2,     // min: the victim is retired, survivors adopt
            4,     // max
            4,     // respawn budget
            false, // tolerate churn
        )
        .unwrap();
        let sup_args = SupervisorArgs {
            pool,
            bus: bus.clone(),
            rollout_tx: tx.clone(),
            schedule: Some(schedule),
            stop: stop.clone(),
            hub: hub.clone(),
            poll: Duration::from_millis(2),
            migrate: Some(hub_m.clone()),
            autoscale: None,
            trainer: None,
            control: None,
        };
        let sup = std::thread::spawn(move || run_supervisor(sup_args));

        // fake trainer: consume rollouts, advance the version clock, and run
        // until every deposited snapshot provably completed elsewhere
        let deadline = Instant::now() + Duration::from_secs(30);
        let mut consumed: Vec<Rollout> = Vec::new();
        let mut version = 1u64;
        loop {
            assert!(
                Instant::now() < deadline,
                "migration did not complete: {} consumed, {} deposited, {} claimed",
                consumed.len(),
                hub_m.deposited(),
                hub_m.claimed()
            );
            if let Ok(r) = rx.recv(Duration::from_millis(500)) {
                consumed.push(r);
                if consumed.len() % 25 == 0 {
                    version += 1;
                    bus.publish(version, Arc::new(vec![]));
                }
            }
            let dep = deposited.lock().unwrap();
            let all_completed_elsewhere = !dep.is_empty()
                && hub_m.depth() == 0
                && dep.iter().all(|s| {
                    consumed.iter().any(|r| {
                        r.group_id == s.group_id
                            && r.actor_id != 0
                            && r.gen_tokens.len() >= s.gen_tokens.len()
                            && r.gen_tokens[..s.gen_tokens.len()] == s.gen_tokens[..]
                    })
                });
            if all_completed_elsewhere {
                break;
            }
        }
        stop.store(true, Ordering::Relaxed);
        drop(tx);
        sup.join().unwrap().unwrap();

        // zero salvageable tokens lost, asserted via the accounting
        let (tok_dep, tok_claim) = hub_m.token_counts();
        assert_eq!(hub_m.claimed(), hub_m.deposited(), "every snapshot adopted");
        assert_eq!(hub_m.discarded(), 0, "nothing thrown away mid-run");
        assert_eq!(tok_dep, tok_claim, "every salvaged token re-entered decode");
        assert!(hub_m.deposited() >= 1, "the victim had work in flight");
        // ... and via the new MetricsHub counters
        assert_eq!(hub.counter("migrations_completed"), hub_m.claimed() as f64);
        assert_eq!(hub.counter("snapshot_tokens_salvaged"), tok_claim as f64);
        assert_eq!(hub.counter("chaos_events_fired"), 1.0);
        assert!(hub.counter("chaos_slow_kills_landed") >= 1.0, "slow kill landed");
    });
}

/// Byzantine chaos (satellite): `CorruptSnapshot` events feed
/// bit-flipped `PRLSNAP1` bytes through the migration hub while three
/// actors keep claiming from it. `SeqSnapshot::from_bytes` rejects every
/// blob at claim time, the hub's conservation books still balance
/// (deposited == claimed + discarded + depth, with the corrupt deposits
/// in `discarded`), and the actor pool survives untouched.
#[test]
fn byzantine_corrupt_snapshots_rejected_books_balance_actors_survive() {
    let schedule = ChaosSchedule::byzantine(2, 3);
    testkit::with_seed("byzantine_corrupt_snapshots", schedule.seed, move |_| {
        let hub = MetricsHub::new();
        let bus = WeightBus::new();
        bus.publish(1, Arc::new(vec![]));
        let (tx, rx) = topic::<Rollout>("rollouts", 1024, Policy::DropOldest);
        let stop = Arc::new(AtomicBool::new(false));
        let hub_m = Arc::new(MigrationHub::new());
        let deposited = Arc::new(Mutex::new(Vec::new()));

        let pool = ActorPool::new(
            migrating_spawn(
                bus.clone(),
                tx.clone(),
                hub.clone(),
                hub_m.clone(),
                deposited.clone(),
            ),
            stop.clone(),
            hub.clone(),
            3,
            3,
            3,
            0, // no respawn budget: a byzantine blob crashing an actor would fail the run
            false,
        )
        .unwrap();
        const N_POISON: usize = 3;
        assert_eq!(schedule.events.len(), N_POISON);
        let sup_args = SupervisorArgs {
            pool,
            bus: bus.clone(),
            rollout_tx: tx.clone(),
            schedule: Some(schedule),
            stop: stop.clone(),
            hub: hub.clone(),
            poll: Duration::from_millis(2),
            migrate: Some(hub_m.clone()),
            autoscale: None,
            trainer: None,
            control: None,
        };
        let sup = std::thread::spawn(move || run_supervisor(sup_args));

        // drive the version clock past every event and wait for the poison
        // to be injected and rejected
        let deadline = Instant::now() + Duration::from_secs(30);
        let mut consumed = 0usize;
        let mut version = 1u64;
        while hub_m.corrupt_rejected() < N_POISON as u64 || hub_m.depth() > 0 {
            assert!(
                Instant::now() < deadline,
                "poison never fully rejected: {} injected, {} rejected, depth {}",
                hub.counter("chaos_corrupt_snapshots_injected"),
                hub_m.corrupt_rejected(),
                hub_m.depth()
            );
            if let Ok(_r) = rx.recv(Duration::from_millis(200)) {
                consumed += 1;
                if consumed % 10 == 0 {
                    version += 1;
                    bus.publish(version, Arc::new(vec![]));
                }
            }
        }
        stop.store(true, Ordering::Relaxed);
        drop(tx);
        sup.join().unwrap().expect("supervisor exits clean: no actor died");

        assert_eq!(hub.counter("chaos_corrupt_snapshots_injected"), N_POISON as f64);
        assert_eq!(hub_m.corrupt_rejected(), N_POISON as u64);
        // books: every deposit (all of them poison) accounted as discarded
        assert_eq!(
            hub_m.deposited(),
            hub_m.claimed() + hub_m.discarded(),
            "conservation holds with byzantine deposits in the mix"
        );
        assert_eq!(hub_m.discarded(), N_POISON as u64);
        let (tok_dep, tok_claim) = hub_m.token_counts();
        assert_eq!((tok_dep, tok_claim), (0, 0), "no phantom salvage from poison");
        // the pool was never perturbed: no crashes, no restarts
        assert_eq!(hub.counter("actor_crashes"), 0.0);
        assert_eq!(hub.counter("actor_restarts"), 0.0);
        assert_eq!(hub.counter("pool_size"), 3.0);
    });
}

#[test]
fn supervisor_autoscales_pool_from_backlog_then_saturation() {
    // idle synthetic actors: the signals are driven entirely by the test
    let hub = MetricsHub::new();
    let bus = WeightBus::new();
    bus.publish(1, Arc::new(vec![]));
    let (tx, rx) = topic::<Rollout>("rollouts", 8, Policy::DropOldest);
    let stop = Arc::new(AtomicBool::new(false));
    let hub_m = Arc::new(MigrationHub::new());
    let spawn: SpawnFn = Arc::new(|ctx| {
        while !ctx.stop.load(Ordering::Relaxed) && !ctx.halt.load(Ordering::Relaxed) {
            std::thread::sleep(Duration::from_millis(1));
        }
        Ok(())
    });
    let pool = ActorPool::new(spawn, stop.clone(), hub.clone(), 1, 1, 4, 0, false).unwrap();
    let scaler = AutoScaler::new(AutoScaleCfg {
        enabled: true,
        backlog_per_actor: 2.0,
        supply_high_frac: 0.75,
        up_patience: 2,
        down_patience: 2,
        cooldown: 1,
        max_lag_steps: 0.0,
        ess_floor: 0.0,
        min_batch_fill: 0.0,
        eval_every_ms: 2,
    });
    let sup_args = SupervisorArgs {
        pool,
        bus: bus.clone(),
        rollout_tx: tx.clone(),
        schedule: None,
        stop: stop.clone(),
        hub: hub.clone(),
        poll: Duration::from_millis(1),
        migrate: Some(hub_m.clone()),
        autoscale: Some(scaler),
        trainer: None,
        control: None,
    };
    let sup = std::thread::spawn(move || run_supervisor(sup_args));

    // sustained rollout-queue backlog: 20 orphaned snapshots nobody claims
    hub_m.deposit((0..20).map(|i| fresh_snap(7, i)).collect());
    let deadline = Instant::now() + Duration::from_secs(15);
    while hub.counter("autoscale_ups") < 2.0 {
        assert!(Instant::now() < deadline, "pool never grew under backlog");
        std::thread::sleep(Duration::from_millis(2));
    }
    assert!(hub.counter("pool_size") >= 2.0, "grown pool visible as a gauge");

    // backlog clears; the supply topic saturates (no consumer drains it):
    // generation is outrunning training, shed actors back to the floor
    hub_m.claim(1000);
    for i in 0..8u64 {
        tx.send(Rollout {
            seq_id: i,
            problem_id: i,
            group_id: (8u64 << 40) | i,
            actor_id: 7,
            prompt_tokens: vec![1],
            gen_tokens: vec![2],
            behavior_lp: vec![-0.1],
            token_version: vec![1],
            reward: 0.0,
            finish: FinishReason::Eos,
            t_start: 0.0,
            t_end: 0.0,
        })
        .unwrap();
    }
    let deadline = Instant::now() + Duration::from_secs(15);
    loop {
        assert!(Instant::now() < deadline, "pool never shrank back");
        if hub.counter("autoscale_downs") >= 1.0 && hub.counter("pool_size") <= 1.0 {
            break;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    // hysteresis: with the backlog gone and supply saturated, no further
    // scale-ups fire (the saturation guard kills the thrash loop)
    let ups_before = hub.counter("autoscale_ups");
    std::thread::sleep(Duration::from_millis(60));
    assert_eq!(hub.counter("autoscale_ups"), ups_before, "no flapping");

    stop.store(true, Ordering::Relaxed);
    drop(tx);
    drop(rx);
    sup.join().unwrap().unwrap();
}

// ---------------------------------------------------------------------
// full-pipeline scenarios (need PJRT runtime + AOT artifacts)
// ---------------------------------------------------------------------

/// Satellite acceptance: a sequence migrated mid-generation emits the
/// same remaining tokens and version tags as one that was never
/// interrupted (same weight versions throughout).
#[test]
fn migrated_sequence_matches_uninterrupted() {
    if !runtime_or_skip("migrated_sequence_matches_uninterrupted") {
        return;
    }
    let mut rt = Runtime::new().unwrap();
    let params = rt.init_params("tiny", 1).unwrap();
    let gen = TaskGen::curriculum_small();
    let tk = Tokenizer::new();
    let mk_cfg = || {
        let mut c = EngineCfg::new("tiny");
        c.max_new_tokens = 10;
        c
    };
    let run_to_finish = |eng: &mut Engine| -> Option<Rollout> {
        for _ in 0..500 {
            let out = eng.step().unwrap();
            if let Some(r) = out.finished.into_iter().next() {
                return Some(r);
            }
        }
        None
    };

    // uninterrupted reference: first problem whose rollout samples >= 3
    // tokens (so an interruption after 2 leaves work to migrate)
    let mut chosen = None;
    for pid in 0..12u64 {
        let p = gen.problem(pid);
        let toks = tk.encode(&p.prompt).unwrap();
        let mut a = Engine::new(&mut rt, mk_cfg(), &params, 0, Rng::new(7)).unwrap();
        a.set_weights(1, &params).unwrap();
        a.add_request(p.clone(), toks.clone(), 77);
        let r = run_to_finish(&mut a).expect("reference finishes");
        if r.gen_len() >= 3 {
            chosen = Some((p, toks, r));
            break;
        }
    }
    let (p, toks, reference) = chosen.expect("some problem samples >= 3 tokens");

    // interrupted twin: identical engine/seed, stopped after 2 sampled
    // tokens, drained as a portable snapshot
    let j = 2usize;
    let prefill_steps = reference.prompt_tokens.len() - 1;
    let mut b = Engine::new(&mut rt, mk_cfg(), &params, 0, Rng::new(7)).unwrap();
    b.set_weights(1, &params).unwrap();
    b.add_request(p.clone(), toks.clone(), 77);
    for _ in 0..(prefill_steps + j) {
        assert!(!b.step().unwrap().idle);
    }
    let snaps = b.export_snapshots();
    assert_eq!(snaps.len(), 1);
    assert_eq!(b.stats.snapshots_exported, 1);
    assert_eq!(snaps[0].gen_tokens.len(), j);
    assert_eq!(snaps[0].gen_tokens[..], reference.gen_tokens[..j]);

    // cross the process boundary in bytes, resume on a fresh engine that
    // continues the exporter's RNG cursor
    let snap = SeqSnapshot::from_bytes(&snaps[0].to_bytes()).unwrap();
    let mut c =
        Engine::new(&mut rt, mk_cfg(), &params, 9, Rng::from_state_words(snap.rng_words))
            .unwrap();
    c.set_weights(1, &params).unwrap();
    c.import_snapshot(&snap, p.clone()).unwrap();
    let resumed = run_to_finish(&mut c).expect("migrated sequence finishes");

    assert_eq!(resumed.group_id, reference.group_id, "group id preserved");
    assert_eq!(resumed.gen_tokens, reference.gen_tokens, "same remaining tokens");
    assert_eq!(resumed.token_version, reference.token_version, "same version tags");
    for (x, y) in resumed.behavior_lp.iter().zip(&reference.behavior_lp) {
        assert!((x - y).abs() < 1e-5, "behavior logprob continuity: {x} vs {y}");
    }
    assert_eq!(c.stats.snapshots_imported, 1);
    assert!(c.stats.import_replays >= 1, "import forced a KV replay");
    assert!(c.stats.kv_recomputes >= 1);
}

/// Adopting a migrated snapshot triggers a full KV replay over every
/// active slot; the replay must leave *healthy neighbors* bit-identical
/// — in particular, rows that finish their stream before `max_pos` must
/// park their per-position KV writes off the live cache instead of
/// clobbering the neighbor's position 0 (the decode graph scatters at
/// `pos[b]` for every row unconditionally).
#[test]
fn import_replay_leaves_neighbor_sequences_intact() {
    if !runtime_or_skip("import_replay_leaves_neighbor_sequences_intact") {
        return;
    }
    let mut rt = Runtime::new().unwrap();
    let params = rt.init_params("tiny", 1).unwrap();
    let gen = TaskGen::curriculum_small();
    let tk = Tokenizer::new();
    let mk_cfg = || {
        let mut c = EngineCfg::new("tiny");
        c.max_new_tokens = 10;
        c
    };
    // find a "neighbor" problem with a reasonably long uninterrupted
    // rollout (so it is still mid-flight when the import lands)
    let mut chosen = None;
    for pid in 20..32u64 {
        let p = gen.problem(pid);
        let toks = tk.encode(&p.prompt).unwrap();
        let mut r_eng = Engine::new(&mut rt, mk_cfg(), &params, 0, Rng::new(11)).unwrap();
        r_eng.set_weights(1, &params).unwrap();
        r_eng.add_request(p.clone(), toks.clone(), 7);
        let mut reference = None;
        for _ in 0..500 {
            if let Some(r) = r_eng.step().unwrap().finished.into_iter().next() {
                reference = Some(r);
                break;
            }
        }
        let r = reference.expect("neighbor finishes");
        if r.gen_len() >= 4 {
            chosen = Some((p, toks, r));
            break;
        }
    }
    let (px, toks_x, x_ref) = chosen.expect("some neighbor samples >= 4 tokens");

    // a donor engine produces a mid-generation snapshot to migrate (skip
    // donor problems whose first sampled token is already EOS)
    let mut donated = None;
    for pid in 50..62u64 {
        let pb = gen.problem(pid);
        let toks_b = tk.encode(&pb.prompt).unwrap();
        let mut donor = Engine::new(&mut rt, mk_cfg(), &params, 1, Rng::new(5)).unwrap();
        donor.set_weights(1, &params).unwrap();
        donor.add_request(pb.clone(), toks_b.clone(), 9);
        for _ in 0..(toks_b.len() + 1) {
            // prefill (toks + BOS - 1 forced steps) plus one sampled token
            assert!(!donor.step().unwrap().idle);
        }
        let mut snaps = donor.export_snapshots();
        if snaps.len() == 1 && snaps[0].salvaged_tokens() == 1 {
            donated = Some((pb, snaps.remove(0)));
            break;
        }
    }
    let (pb, snap) = donated.expect("some donor survives its first sampled token");
    let snap = &snap;

    // twin of the reference engine, interrupted by an adoption: after the
    // neighbor's first sampled token, the migrated sequence arrives and
    // forces a replay; the neighbor's remaining tokens must not change
    let mut c = Engine::new(&mut rt, mk_cfg(), &params, 0, Rng::new(11)).unwrap();
    c.set_weights(1, &params).unwrap();
    c.add_request(px.clone(), toks_x.clone(), 7);
    for _ in 0..(x_ref.prompt_tokens.len() - 1 + 1) {
        assert!(!c.step().unwrap().idle);
    }
    c.import_snapshot(snap, pb.clone()).unwrap();
    let mut finished = Vec::new();
    for _ in 0..1000 {
        finished.extend(c.step().unwrap().finished);
        if finished.iter().any(|r: &Rollout| r.group_id == 7)
            && finished.iter().any(|r: &Rollout| r.group_id == 9)
        {
            break;
        }
    }
    assert!(c.stats.import_replays >= 1, "adoption forced a replay");
    let x_after = finished
        .iter()
        .find(|r| r.group_id == 7)
        .expect("neighbor finishes alongside the migrant");
    assert_eq!(
        x_after.gen_tokens, x_ref.gen_tokens,
        "replay must not perturb a healthy neighbor's tokens"
    );
    assert_eq!(x_after.token_version, x_ref.token_version);
    let migrant = finished
        .iter()
        .find(|r| r.group_id == 9)
        .expect("migrated sequence finishes");
    assert_eq!(
        migrant.gen_tokens[..snap.gen_tokens.len()],
        snap.gen_tokens[..],
        "migrated prefix preserved"
    );
}

#[test]
fn scenario_slow_kill_migrates_work_end_to_end() {
    if !runtime_or_skip("scenario_slow_kill_migrates_work_end_to_end") {
        return;
    }
    let mut cfg = RunConfig::default();
    cfg.variant = "tiny".into();
    cfg.sft_steps = 8;
    cfg.rl_steps = 6;
    cfg.group_size = 2;
    cfg.max_new_tokens = 16;
    cfg.task.kinds = vec![TaskKind::Copy];
    cfg.task.max_operand = 9;
    cfg.log_every = 0;
    cfg.n_actors = 3;
    cfg.elastic.enabled = true;
    cfg.elastic.min_actors = 2;
    cfg.elastic.max_actors = 4;
    // migration is the elastic default; slow-kill one of the three
    let schedule = ChaosSchedule::slow_kill(2, 5);
    let summary =
        coordinator::run_with_chaos(cfg, None, Some(schedule)).expect("chaos run completes");
    let c = |k: &str| summary.report.counters.get(k).copied().unwrap_or(0.0);
    assert_eq!(
        summary.report.series("train/loss").unwrap().points.len(),
        6,
        "all optimizer steps ran despite the slow kill"
    );
    assert!(c("migration_snaps_exported") > 0.0, "the victim was mid-flight");
    // zero salvageable sequences lost: every export was adopted or
    // deliberately discarded at shutdown
    assert_eq!(
        c("migration_snaps_exported"),
        c("migrations_completed") + c("migration_snaps_discarded"),
        "migration books must balance"
    );
    assert!(
        c("snapshot_tokens_salvaged") <= c("migration_tokens_exported"),
        "salvage accounting is consistent"
    );
    // (rollouts_aborted_on_halt may still be nonzero: the global-stop
    // shutdown path deliberately aborts — only mid-run kills migrate)
}
