//! Device-free property tests for the decode hot path's staging
//! machinery (runs everywhere, including against the vendored no-PJRT
//! `xla` stub):
//!
//! * [`StepArena`] — the reusable input-staging arena: slot writes never
//!   alias across slots, buffer shapes are fixed for the arena's life,
//!   and `reset` restores the idle defaults;
//! * [`ShadowSet`] — the double-buffered weight set: the active set is
//!   only ever replaced by a *complete* shadow set, atomically, at a
//!   commit; partial staging, aborts, and version jumps never perturb it.

use pipeline_rl::engine::StepArena;
use pipeline_rl::testkit::check;
use pipeline_rl::weights::ShadowSet;

const PAD: i32 = 0;
/// idle-row cache position (the engine passes max_seq - 1)
const PARK: i32 = 95;

#[test]
fn arena_slot_writes_never_alias() {
    check("arena slot writes never alias", 64, 0xA1, 16, |c| {
        let b = c.usize_in(1, 12);
        let v = c.usize_in(1, 8);
        let mut arena = StepArena::new(b, v, PAD, 1.0, PARK);
        // shadow model: independent per-slot vectors
        let mut pos = vec![PARK; b];
        let mut cur = vec![PAD; b];
        let mut ftok = vec![PAD; b];
        let mut fmask = vec![1.0f32; b];
        let mut cap = vec![0usize; b];
        for _ in 0..c.usize_in(1, 48) {
            let i = c.usize_in(0, b - 1);
            let p = c.usize_in(0, 500);
            let tok = c.usize_in(0, 63) as i32;
            let forced = if c.rng.f32() < 0.5 { Some(tok + 1) } else { None };
            let kv_cap = c.usize_in(1, 600);
            arena.set_slot(i, p, tok, forced, kv_cap);
            pos[i] = p as i32;
            cur[i] = tok;
            cap[i] = kv_cap;
            match forced {
                Some(t) => {
                    ftok[i] = t;
                    fmask[i] = 1.0;
                }
                None => {
                    ftok[i] = PAD;
                    fmask[i] = 0.0;
                }
            }
        }
        if arena.pos != pos
            || arena.cur != cur
            || arena.ftok != ftok
            || arena.fmask != fmask
            || arena.cap != cap
        {
            return Err(format!(
                "slot write leaked across slots: arena ({:?} {:?} {:?} {:?} {:?}) vs model \
                 ({pos:?} {cur:?} {ftok:?} {fmask:?} {cap:?})",
                arena.pos, arena.cur, arena.ftok, arena.fmask, arena.cap
            ));
        }
        Ok(())
    });
}

#[test]
fn arena_shapes_fixed_and_reset_restores_defaults() {
    check("arena shapes fixed, reset restores", 48, 0xA2, 16, |c| {
        let b = c.usize_in(1, 10);
        let v = c.usize_in(1, 6);
        let mut arena = StepArena::new(b, v, PAD, 0.7, PARK);
        for _ in 0..c.usize_in(0, 20) {
            let i = c.usize_in(0, b - 1);
            arena.set_slot(i, c.usize_in(0, 99), 3, None, 100);
        }
        for g in arena.gumbel.iter_mut() {
            *g = c.rng.f32();
        }
        let lits = arena.to_literals().map_err(|e| e.to_string())?;
        let pos_shape = lits.pos.array_shape().map_err(|e| e.to_string())?;
        if pos_shape.dims() != &[b as i64] {
            return Err(format!("pos shape drifted: {:?}", pos_shape.dims()));
        }
        let gum_shape = lits.gumbel.array_shape().map_err(|e| e.to_string())?;
        if gum_shape.dims() != &[b as i64, v as i64] {
            return Err(format!("gumbel shape drifted: {:?}", gum_shape.dims()));
        }
        // buffer lengths never change
        if arena.pos.len() != b || arena.gumbel.len() != b * v {
            return Err("arena buffer length changed".into());
        }
        arena.reset();
        if arena.pos != vec![PARK; b]
            || arena.cur != vec![PAD; b]
            || arena.ftok != vec![PAD; b]
            || arena.fmask != vec![1.0f32; b]
        {
            return Err("reset did not restore idle defaults".into());
        }
        Ok(())
    });
}

#[test]
fn shadow_set_swap_is_atomic_at_boundaries() {
    check("shadow swap atomic", 96, 0xB2, 32, |c| {
        let mut s: ShadowSet<u64> = ShadowSet::new();
        // shadow model of the invariant-relevant state
        let mut active: Vec<u64> = Vec::new();
        let mut active_version = 0u64;
        let mut staged: Vec<u64> = Vec::new();
        let mut staging = false;
        let mut expect = 0usize;
        let mut version = 0u64;
        let mut next_val = 0u64;
        for _ in 0..c.usize_in(1, 64) {
            match c.usize_in(0, 3) {
                0 => {
                    // begin: a new version jumps past the current one and
                    // discards any partial shadow
                    version += 1 + c.usize_in(0, 3) as u64;
                    expect = c.usize_in(1, 6);
                    s.begin(version, expect);
                    staged.clear();
                    staging = true;
                }
                1 => {
                    if staging && staged.len() < expect {
                        next_val += 1;
                        let ready = s.push(next_val).map_err(|e| e.to_string())?;
                        staged.push(next_val);
                        if ready != (staged.len() == expect) {
                            return Err("push readiness mismatch".into());
                        }
                    } else if s.push(999).is_ok() {
                        return Err("push must fail outside an open shadow set".into());
                    }
                }
                2 => {
                    let should_commit = staging && staged.len() == expect;
                    match s.commit() {
                        Some(v) => {
                            if !should_commit {
                                return Err("committed a partial shadow set".into());
                            }
                            if v != version {
                                return Err(format!("committed version {v}, want {version}"));
                            }
                            active = staged.clone();
                            active_version = version;
                            staged.clear();
                            staging = false;
                        }
                        None => {
                            if should_commit {
                                return Err("refused to commit a complete set".into());
                            }
                        }
                    }
                }
                _ => {
                    s.abort();
                    staged.clear();
                    staging = false;
                }
            }
            // the invariant: the active set only ever changes via a
            // complete commit
            if s.active() != active.as_slice() {
                return Err(format!(
                    "active set perturbed outside commit: {:?} vs {:?}",
                    s.active(),
                    active
                ));
            }
            if s.active_version() != active_version {
                return Err("active version perturbed outside commit".into());
            }
        }
        Ok(())
    });
}
