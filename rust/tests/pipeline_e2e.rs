//! End-to-end coordinator runs on the tiny variant: both modes complete,
//! produce coherent metrics, and the in-flight machinery engages.

use pipeline_rl::config::{Mode, RunConfig};
use pipeline_rl::coordinator;
use pipeline_rl::data::task::TaskKind;

use pipeline_rl::testkit::runtime_or_skip;

fn base_cfg() -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.variant = "tiny".into();
    cfg.sft_steps = 12;
    cfg.rl_steps = 6;
    cfg.group_size = 4;
    cfg.max_new_tokens = 24;
    cfg.task.kinds = vec![TaskKind::Copy];
    cfg.task.max_operand = 9; // single digits: short sequences, fast test
    cfg.log_every = 0;
    cfg.seed = 3;
    cfg
}

#[test]
fn pipeline_mode_end_to_end() {
    if !runtime_or_skip("pipeline_mode_end_to_end") {
        return;
    }
    let cfg = base_cfg();
    let summary = coordinator::run(cfg, None).expect("pipeline run");
    let rep = &summary.report;

    // all six optimizer steps happened with full metric series
    let loss = rep.series("train/loss").expect("loss series");
    assert_eq!(loss.points.len(), 6);
    let ess = rep.series("train/ess").unwrap();
    for p in &ess.points {
        assert!(p.value > 0.0 && p.value <= 1.0 + 1e-6, "ess {}", p.value);
    }
    // sft warmup ran
    assert_eq!(rep.series("sft/loss").unwrap().points.len(), 12);
    // rewards recorded against samples and time
    assert!(rep.series("reward_vs_samples").unwrap().points.len() == 6);
    // weights flowed: initial publish + one per step
    assert_eq!(rep.counters["weight_bus_publishes"], 7.0);
    assert!(rep.counters.get("weight_updates_received").copied().unwrap_or(0.0) >= 1.0);
    // generation actually sampled tokens
    assert!(rep.counters["gen_tokens_sampled"] > 0.0);
    // params differ from initial
    let d: f32 = summary
        .final_params[0]
        .f32s()
        .unwrap()
        .iter()
        .zip(summary.initial_params[0].f32s().unwrap())
        .map(|(a, b)| (a - b).abs())
        .sum();
    assert!(d > 0.0);
}

#[test]
fn conventional_mode_end_to_end() {
    if !runtime_or_skip("conventional_mode_end_to_end") {
        return;
    }
    let mut cfg = base_cfg();
    cfg.mode = Mode::Conventional { g: 2 };
    cfg.rl_steps = 4;
    let summary = coordinator::run(cfg, None).expect("conventional run");
    let rep = &summary.report;

    let loss = rep.series("train/loss").unwrap();
    assert_eq!(loss.points.len(), 4);
    // conventional publishes only at RL-step boundaries: fewer publishes
    // than optimizer steps (+1 for the initial weights)
    assert!(rep.counters["weight_bus_publishes"] < 5.0);
    // buffer accounting happened
    assert!(rep.series("conv/buffer_seqs").is_some());
    // in conventional mode sequences are single-policy: every trained
    // token's version matches within a sequence, so mean version span = 0.
    // (We can't see rollouts here, but max lag must be >= 1 for later
    // batches of an RL step while staying bounded by g.)
    let max_lag = rep.series("train/max_lag").unwrap();
    for p in &max_lag.points {
        assert!(p.value <= 2.0 + 1e-9, "lag bounded by g: {}", p.value);
    }
}
