//! Property tests (testkit::check) for the broker topic invariants:
//!
//! * conservation — every published item is accounted for exactly once:
//!   `published == consumed + dropped + depth`;
//! * `DropOldest` evicts from the stale end only — the newest items
//!   always survive, in order;
//! * `Block` never drops.
//!
//! Failures print the case seed; replay with `testkit::check_one`.

use pipeline_rl::broker::{topic, Policy, RecvError};
use pipeline_rl::testkit::check;
use std::time::Duration;

#[test]
fn prop_drop_oldest_conserves_and_keeps_newest() {
    check("drop-oldest conservation + newest survive", 40, 0xb10c, 64, |c| {
        let cap = c.usize_in(1, 16);
        let n = c.usize_in(1, 64.min(c.size * 4).max(1));
        let (tx, rx) = topic("t", cap, Policy::DropOldest);
        for i in 0..n {
            tx.send(i).unwrap();
        }
        let s = tx.stats();
        if s.published != (s.consumed + s.dropped) + s.depth as u64 {
            return Err(format!("conservation pre-drain: {s:?}"));
        }
        let kept = cap.min(n);
        let got = rx.recv_exact(kept, Duration::from_millis(200));
        // the surviving window must be exactly the newest `kept` items
        let want: Vec<usize> = (n - kept..n).collect();
        if got != want {
            return Err(format!("evicted a newer item: got {got:?}, want {want:?}"));
        }
        let s = rx.stats();
        if s.published != s.consumed + s.dropped + s.depth as u64 {
            return Err(format!("conservation post-drain: {s:?}"));
        }
        if s.dropped != (n.saturating_sub(cap)) as u64 {
            return Err(format!("dropped {} != overflow {}", s.dropped, n - cap));
        }
        Ok(())
    });
}

#[test]
fn prop_concurrent_drop_oldest_conserves_with_partial_drain() {
    check("mpmc drop-oldest conservation", 25, 0xb20c, 32, |c| {
        let cap = c.usize_in(1, 12);
        let n_pub = c.usize_in(1, 4);
        let per = c.usize_in(1, 32.min(c.size * 2).max(1));
        let (tx, rx) = topic("t", cap, Policy::DropOldest);
        let mut pubs = Vec::new();
        for p in 0..n_pub {
            let tx = tx.clone();
            pubs.push(std::thread::spawn(move || {
                for i in 0..per {
                    tx.send(p * per + i).unwrap();
                }
            }));
        }
        for p in pubs {
            p.join().unwrap();
        }
        // consumer drains only part of the queue: depth term stays nonzero
        let q = c.rng.below(cap + 1);
        let got = rx.recv_exact(q.min(rx.depth()), Duration::from_millis(200));
        let s = rx.stats();
        if s.published != (n_pub * per) as u64 {
            return Err(format!("published {} != sent {}", s.published, n_pub * per));
        }
        if s.consumed != got.len() as u64 {
            return Err(format!("consumed {} != received {}", s.consumed, got.len()));
        }
        if s.published != s.consumed + s.dropped + s.depth as u64 {
            return Err(format!("conservation violated: {s:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_block_never_drops_under_concurrency() {
    check("block policy never drops", 20, 0xb30c, 32, |c| {
        let cap = c.usize_in(1, 8);
        let n_pub = c.usize_in(1, 4);
        let per = c.usize_in(1, 32.min(c.size * 2).max(1));
        let (tx, rx) = topic("t", cap, Policy::Block);
        let mut pubs = Vec::new();
        for p in 0..n_pub {
            let tx = tx.clone();
            pubs.push(std::thread::spawn(move || {
                for i in 0..per {
                    tx.send(p * per + i).unwrap();
                }
            }));
        }
        drop(tx);
        // concurrent consumer so blocked publishers make progress
        let consumer = std::thread::spawn(move || {
            let mut got = Vec::new();
            loop {
                match rx.recv(Duration::from_secs(10)) {
                    Ok(x) => got.push(x),
                    Err(RecvError::Closed) => break,
                    Err(RecvError::Timeout) => break,
                }
            }
            (got, rx.stats())
        });
        for p in pubs {
            p.join().unwrap();
        }
        let (mut got, s) = consumer.join().unwrap();
        if s.dropped != 0 {
            return Err(format!("Block dropped {} items", s.dropped));
        }
        if s.published != s.consumed + s.depth as u64 {
            return Err(format!("conservation violated: {s:?}"));
        }
        got.sort_unstable();
        let want: Vec<usize> = (0..n_pub * per).collect();
        if got != want {
            return Err("delivery was not exactly-once".into());
        }
        Ok(())
    });
}
