//! Integration: the generation engine over the real tiny decode artifact.
//!
//! Covers the vLLM-substitute behaviours the paper's coordination relies
//! on: continuous batching (in-flight admission), prefill-through-decode,
//! EOS/budget termination, in-flight weight updates (version tagging,
//! KV retained), and the KV-recompute ablation mode.

use pipeline_rl::data::task::TaskGen;
use pipeline_rl::engine::{Engine, EngineCfg};
use pipeline_rl::model::Tokenizer;
use pipeline_rl::rl::FinishReason;
use pipeline_rl::runtime::Runtime;
use pipeline_rl::util::Rng;

use pipeline_rl::testkit::runtime_or_skip;

fn mk_engine(cfg: EngineCfg) -> (Runtime, Engine) {
    let mut rt = Runtime::new().expect("runtime");
    let params = rt.init_params("tiny", 7).unwrap();
    let eng = Engine::new(&mut rt, cfg, &params, 0, Rng::new(1)).unwrap();
    (rt, eng)
}

fn submit_n(eng: &mut Engine, n: usize) {
    let gen = TaskGen::curriculum_small();
    let tk = Tokenizer::new();
    for i in 0..n {
        let p = gen.problem(i as u64 + 100);
        let toks = tk.encode(&p.prompt).unwrap();
        eng.add_request(p, toks, i as u64);
    }
}

#[test]
fn generates_until_budget_or_eos() {
    if !runtime_or_skip("generates_until_budget_or_eos") {
        return;
    }
    let mut cfg = EngineCfg::new("tiny");
    cfg.max_new_tokens = 12;
    let (_rt, mut eng) = mk_engine(cfg);
    submit_n(&mut eng, 4);
    let mut rollouts = Vec::new();
    for _ in 0..400 {
        let out = eng.step().unwrap();
        rollouts.extend(out.finished);
        if rollouts.len() >= 4 {
            break;
        }
    }
    assert_eq!(rollouts.len(), 4, "all requests finish");
    for r in &rollouts {
        r.validate().unwrap();
        assert!(r.gen_len() >= 1 && r.gen_len() <= 12);
        assert!(matches!(r.finish, FinishReason::Eos | FinishReason::Length));
        // behavior logprobs are genuine log-probabilities
        for &lp in &r.behavior_lp {
            assert!(lp <= 0.0 && lp > -30.0, "lp {lp}");
        }
        // untrained model at version 0
        assert!(r.token_version.iter().all(|&v| v == 0));
    }
}

#[test]
fn continuous_batching_admits_in_flight() {
    if !runtime_or_skip("continuous_batching_admits_in_flight") {
        return;
    }
    let mut cfg = EngineCfg::new("tiny");
    cfg.max_new_tokens = 6;
    let (_rt, mut eng) = mk_engine(cfg);
    // 9 requests for 4 slots: admission must refill as slots free
    submit_n(&mut eng, 9);
    assert_eq!(eng.n_active(), 0);
    let mut done = 0;
    let mut saw_mixed_admission = false;
    for _ in 0..2000 {
        let out = eng.step().unwrap();
        done += out.finished.len();
        // slots stay saturated while the backlog lasts
        if done >= 1 && done < 5 && eng.n_pending() > 0 {
            saw_mixed_admission = eng.n_active() == eng.n_slots();
        }
        if done == 9 {
            break;
        }
    }
    assert_eq!(done, 9);
    assert!(saw_mixed_admission, "slots must refill while others decode");
    assert_eq!(eng.load(), 0);
}

#[test]
fn inflight_weight_update_tags_versions_and_keeps_kv() {
    if !runtime_or_skip("inflight_weight_update_tags_versions_and_keeps_kv") {
        return;
    }
    let mut cfg = EngineCfg::new("tiny");
    cfg.max_new_tokens = 16;
    let (mut rt, mut eng) = mk_engine(cfg);
    submit_n(&mut eng, 4);
    // run a few steps under v0 (prefill + first samples)
    for _ in 0..10 {
        eng.step().unwrap();
    }
    // in-flight update to different weights (different seed)
    let params_v1 = rt.init_params("tiny", 8).unwrap();
    eng.set_weights(1, &params_v1).unwrap();
    let mut rollouts = Vec::new();
    for _ in 0..600 {
        let out = eng.step().unwrap();
        rollouts.extend(out.finished);
        if rollouts.len() >= 4 {
            break;
        }
    }
    assert!(rollouts.len() >= 4);
    // at least one sequence must span both versions (mixed-policy!)
    let mixed = rollouts.iter().filter(|r| r.version_span() > 0).count();
    assert!(mixed >= 1, "in-flight update must produce mixed-policy sequences");
    for r in &rollouts {
        // versions are monotone within a sequence
        for w in r.token_version.windows(2) {
            assert!(w[0] <= w[1]);
        }
    }
    assert_eq!(eng.stats.weight_updates, 1);
    assert_eq!(eng.stats.kv_recomputes, 0, "default keeps stale KV");
}

#[test]
fn kv_recompute_mode_runs_replay() {
    if !runtime_or_skip("kv_recompute_mode_runs_replay") {
        return;
    }
    let mut cfg = EngineCfg::new("tiny");
    cfg.max_new_tokens = 16;
    cfg.recompute_kv_on_update = true;
    let (mut rt, mut eng) = mk_engine(cfg);
    submit_n(&mut eng, 4);
    for _ in 0..12 {
        eng.step().unwrap();
    }
    let params_v1 = rt.init_params("tiny", 9).unwrap();
    eng.set_weights(1, &params_v1).unwrap();
    assert_eq!(eng.stats.kv_recomputes, 1);
    assert!(eng.stats.recompute_steps > 0);
    // engine still generates fine afterwards
    let mut done = 0;
    for _ in 0..600 {
        done += eng.step().unwrap().finished.len();
        if done >= 4 {
            break;
        }
    }
    assert!(done >= 4);
}

#[test]
fn capture_dist_records_rows() {
    if !runtime_or_skip("capture_dist_records_rows") {
        return;
    }
    let mut cfg = EngineCfg::new("tiny");
    cfg.max_new_tokens = 5;
    cfg.capture_dist = true;
    let (_rt, mut eng) = mk_engine(cfg);
    submit_n(&mut eng, 2);
    let mut done = 0;
    for _ in 0..300 {
        done += eng.step().unwrap().finished.len();
        if done >= 2 {
            break;
        }
    }
    assert!(!eng.captured.is_empty());
    let v = eng.variant().vocab;
    for row in &eng.captured {
        assert_eq!(row.logdist.len(), v);
        let z: f32 = row.logdist.iter().map(|lp| lp.exp()).sum();
        assert!((z - 1.0).abs() < 1e-3, "captured dist normalizes: {z}");
    }
}

#[test]
fn greedy_decoding_is_deterministic_at_zero_temperature() {
    if !runtime_or_skip("greedy_decoding_is_deterministic_at_zero_temperature") {
        return;
    }
    // temperature ~ 0 via gumbel=0 is not exposed; instead check that the
    // same seed reproduces identical rollouts end-to-end.
    let mk = || {
        let mut cfg = EngineCfg::new("tiny");
        cfg.max_new_tokens = 8;
        let (_rt, mut eng) = mk_engine(cfg);
        submit_n(&mut eng, 3);
        let mut rs = Vec::new();
        for _ in 0..400 {
            rs.extend(eng.step().unwrap().finished);
            if rs.len() >= 3 {
                break;
            }
        }
        rs.into_iter().map(|r| r.gen_tokens).collect::<Vec<_>>()
    };
    assert_eq!(mk(), mk(), "same seeds => same generations");
}

/// In-flight weight-swap equivalence: the overlapped path (shadow
/// staging spread across decode steps + commit at a step boundary) must
/// produce *identical* rollouts — same tokens, same per-token version
/// tags — as the eager path swapping at the same boundary. This is the
/// behavior-preservation proof for the zero-stall swap semantics.
#[test]
fn overlapped_swap_matches_eager_swap() {
    if !runtime_or_skip("overlapped_swap_matches_eager_swap") {
        return;
    }
    let run = |overlapped: bool| {
        let mut cfg = EngineCfg::new("tiny");
        cfg.max_new_tokens = 16;
        let mut rt = Runtime::new().expect("runtime");
        let params0 = rt.init_params("tiny", 7).unwrap();
        let mut eng = Engine::new(&mut rt, cfg, &params0, 0, Rng::new(1)).unwrap();
        submit_n(&mut eng, 4);
        let params1 = rt.init_params("tiny", 8).unwrap();
        // six steps under v0; the swap lands at the boundary after them
        let mut staged = 0usize;
        for step in 0..6 {
            if overlapped {
                if step == 2 {
                    eng.begin_weight_update(1, params1.len()).unwrap();
                }
                if step >= 2 {
                    // stage a couple of tensor chunks between steps
                    for _ in 0..2 {
                        if staged < params1.len() {
                            eng.stage_weight_tensor(&params1[staged]).unwrap();
                            staged += 1;
                        }
                    }
                }
            }
            eng.step().unwrap();
        }
        if overlapped {
            while staged < params1.len() {
                eng.stage_weight_tensor(&params1[staged]).unwrap();
                staged += 1;
            }
            assert!(eng.weight_update_ready());
            let v = eng.commit_weights().unwrap();
            assert_eq!(v, Some(1));
        } else {
            eng.set_weights(1, &params1).unwrap();
        }
        let mut rollouts = Vec::new();
        for _ in 0..600 {
            rollouts.extend(eng.step().unwrap().finished);
            if rollouts.len() >= 4 {
                break;
            }
        }
        assert_eq!(rollouts.len(), 4);
        rollouts.sort_by_key(|r| r.seq_id);
        let tokens: Vec<Vec<i32>> = rollouts.iter().map(|r| r.gen_tokens.clone()).collect();
        let versions: Vec<Vec<u64>> =
            rollouts.iter().map(|r| r.token_version.clone()).collect();
        (tokens, versions, eng.stats.clone())
    };
    let (tok_eager, ver_eager, stats_eager) = run(false);
    let (tok_over, ver_over, stats_over) = run(true);
    assert_eq!(tok_eager, tok_over, "identical token streams");
    assert_eq!(ver_eager, ver_over, "identical per-token version tags");
    assert_eq!(stats_eager.weight_updates, 1);
    assert_eq!(stats_over.weight_updates, 1);
    assert_eq!(stats_eager.overlapped_commits, 0);
    assert_eq!(stats_over.overlapped_commits, 1);
    assert_eq!(
        stats_over.weight_stall_us, 0,
        "overlapped swaps must record zero decode stall"
    );
}

/// Aborting a partially staged update must leave the active weights (and
/// generation behavior) untouched; a jump-to-latest re-begin must land
/// the newest version only.
#[test]
fn aborted_and_superseded_staging_leave_weights_intact() {
    if !runtime_or_skip("aborted_and_superseded_staging_leave_weights_intact") {
        return;
    }
    let mut cfg = EngineCfg::new("tiny");
    cfg.max_new_tokens = 8;
    let (mut rt, mut eng) = mk_engine(cfg);
    submit_n(&mut eng, 2);
    for _ in 0..4 {
        eng.step().unwrap();
    }
    let params1 = rt.init_params("tiny", 8).unwrap();
    assert!(
        eng.begin_weight_update(1, params1.len() + 1).is_err(),
        "wrong param count must fail loudly at begin"
    );
    eng.begin_weight_update(1, params1.len()).unwrap();
    eng.stage_weight_tensor(&params1[0]).unwrap();
    assert_eq!(eng.commit_weights().unwrap(), None, "partial set must not commit");
    assert_eq!(eng.current_version(), 0);
    eng.abort_weight_update();
    assert!(!eng.weight_update_ready());
    assert_eq!(eng.stats.weight_updates, 0);
    // supersede: begin v2 discards v1's partial staging
    eng.begin_weight_update(1, params1.len()).unwrap();
    eng.stage_weight_tensor(&params1[0]).unwrap();
    eng.begin_weight_update(2, params1.len()).unwrap();
    for t in &params1 {
        eng.stage_weight_tensor(t).unwrap();
    }
    assert_eq!(eng.commit_weights().unwrap(), Some(2));
    assert_eq!(eng.current_version(), 2);
    assert_eq!(eng.stats.weight_updates, 1);
    // engine still generates
    let mut done = 0;
    for _ in 0..300 {
        done += eng.step().unwrap().finished.len();
        if done >= 2 {
            break;
        }
    }
    assert!(done >= 2);
}

/// Steady-state decode keeps the KV cache off the host: once the engine
/// is warm, `kv_restages` stays frozen when outputs are untupled (real
/// PJRT), and degrades gracefully to once-per-step on tuple-fallback
/// builds.
#[test]
fn kv_cache_stays_device_resident_in_steady_state() {
    if !runtime_or_skip("kv_cache_stays_device_resident_in_steady_state") {
        return;
    }
    let mut cfg = EngineCfg::new("tiny");
    cfg.max_new_tokens = 64;
    let (_rt, mut eng) = mk_engine(cfg);
    submit_n(&mut eng, 4);
    for _ in 0..4 {
        eng.step().unwrap();
    }
    let restages_warm = eng.stats.kv_restages;
    let steps_warm = eng.stats.steps;
    for _ in 0..16 {
        eng.step().unwrap();
    }
    let delta_restages = eng.stats.kv_restages - restages_warm;
    let delta_steps = eng.stats.steps - steps_warm;
    if eng.kv_on_device() {
        assert_eq!(delta_restages, 0, "device-resident KV must not restage");
    } else {
        assert_eq!(delta_restages, delta_steps, "tuple fallback restages per step");
    }
    assert!(eng.stats.execute_us > 0, "stats breakdown must accumulate");
}

#[test]
fn drain_aborts_in_flight() {
    if !runtime_or_skip("drain_aborts_in_flight") {
        return;
    }
    let mut cfg = EngineCfg::new("tiny");
    cfg.max_new_tokens = 32;
    let (_rt, mut eng) = mk_engine(cfg);
    submit_n(&mut eng, 6);
    for _ in 0..8 {
        eng.step().unwrap();
    }
    let drained = eng.drain();
    assert_eq!(drained.len(), 6);
    assert!(drained.iter().any(|r| matches!(r.finish, FinishReason::Aborted)));
    assert_eq!(eng.load(), 0);
    // allocator must be clean: a fresh batch can be admitted
    submit_n(&mut eng, 4);
    let out = eng.step().unwrap();
    assert!(!out.idle);
}

#[test]
fn resume_cursors_continue_the_sampling_stream() {
    if !runtime_or_skip("resume_cursors_continue_the_sampling_stream") {
        return;
    }
    // The PRLCKPT3 cursor contract on the real engine: exporting
    // (rng_words, admission_cursor) from one engine and restoring them
    // into a fresh one continues the exact sampling stream and id space
    // — the full-run-resume building block the golden harness models
    // device-free (tests/determinism.rs).
    let mut cfg = EngineCfg::new("tiny");
    cfg.max_new_tokens = 10;
    let (mut rt, mut a) = mk_engine(cfg.clone());
    let params = rt.init_params("tiny", 7).unwrap();
    a.set_weights(1, &params).unwrap();
    submit_n(&mut a, 2);
    let mut finished = Vec::new();
    for _ in 0..400 {
        finished.extend(a.step().unwrap().finished);
        if finished.len() >= 2 {
            break;
        }
    }
    assert_eq!(finished.len(), 2);
    let words = a.rng_words();
    let cursor = a.admission_cursor();
    assert!(cursor >= 3, "two admissions moved the cursor past its start");

    // reference: the donor engine keeps going
    submit_n(&mut a, 2);
    let mut ref_rolls = Vec::new();
    for _ in 0..400 {
        ref_rolls.extend(a.step().unwrap().finished);
        if ref_rolls.len() >= 2 {
            break;
        }
    }

    // resumed twin: fresh engine, cursors restored — same ids, same
    // tokens, same logprobs as the donor's continuation
    let (_rt2, mut b) = mk_engine(cfg);
    b.set_weights(1, &params).unwrap();
    b.restore_rng(words).unwrap();
    b.restore_admission_cursor(cursor).unwrap();
    assert_eq!(b.admission_cursor(), cursor);
    submit_n(&mut b, 2);
    let mut res_rolls = Vec::new();
    for _ in 0..400 {
        res_rolls.extend(b.step().unwrap().finished);
        if res_rolls.len() >= 2 {
            break;
        }
    }
    ref_rolls.sort_by_key(|r| r.seq_id);
    res_rolls.sort_by_key(|r| r.seq_id);
    assert_eq!(ref_rolls.len(), res_rolls.len());
    for (x, y) in ref_rolls.iter().zip(&res_rolls) {
        assert_eq!(x.seq_id, y.seq_id, "admission cursor keeps the id space aligned");
        assert_eq!(x.gen_tokens, y.gen_tokens, "restored RNG continues the stream");
        assert_eq!(x.token_version, y.token_version);
    }

    // the guards: a rewound admission cursor and the degenerate all-zero
    // RNG cursor (the PRLCKPT2-compat sentinel) must both be refused
    assert!(b.restore_admission_cursor(0).is_err(), "rewind refused");
    assert!(b.restore_rng([0; 4]).is_err(), "zero RNG cursor refused");
}

// ---------------- chunked prefill ----------------

/// Submit `n` requests whose prompt lengths come from `lens` (cycled);
/// token values are deterministic and in-vocab.
fn submit_with_lens(eng: &mut Engine, lens: &[usize]) {
    let gen = TaskGen::curriculum_small();
    for (i, &len) in lens.iter().enumerate() {
        let p = gen.problem(i as u64 + 100);
        let toks: Vec<i32> =
            (0..len).map(|t| 3 + ((t as i32 * 7 + i as i32 * 3) % 40)).collect();
        eng.add_request(p, toks, i as u64);
    }
}

/// Skip chunk tests when the artifacts predate the `prefill_chunk`
/// graphs (the manifest records the compiled width).
fn chunk_width_or_skip(name: &str, need: usize) -> bool {
    if !runtime_or_skip(name) {
        return false;
    }
    let rt = Runtime::new().expect("runtime");
    let w = rt.manifest.variant("tiny").expect("tiny variant").prefill_chunk;
    if w < need {
        eprintln!("skipping {name}: artifacts compiled without prefill_chunk >= {need}");
        return false;
    }
    true
}

/// Chunked prompt ingestion must reproduce the legacy token-at-a-time
/// path exactly — same tokens, same behavior logprobs, same version tags
/// — for lockstep rows under sampling (equal prompt lengths keep every
/// row consuming the same per-step Gumbel draw in both paths).
#[test]
fn chunked_prefill_matches_legacy_sampled_lockstep() {
    if !chunk_width_or_skip("chunked_prefill_matches_legacy_sampled_lockstep", 4) {
        return;
    }
    let run = |w: usize| {
        let mut cfg = EngineCfg::new("tiny");
        cfg.max_new_tokens = 12;
        cfg.prefill_chunk = w;
        let (_rt, mut eng) = mk_engine(cfg);
        submit_with_lens(&mut eng, &[10, 10, 10, 10]);
        let mut rollouts = Vec::new();
        for _ in 0..600 {
            rollouts.extend(eng.step().unwrap().finished);
            if rollouts.len() >= 4 {
                break;
            }
        }
        assert_eq!(rollouts.len(), 4);
        rollouts.sort_by_key(|r| r.seq_id);
        let toks: Vec<Vec<i32>> = rollouts.iter().map(|r| r.gen_tokens.clone()).collect();
        let lps: Vec<Vec<f32>> = rollouts.iter().map(|r| r.behavior_lp.clone()).collect();
        let vers: Vec<Vec<u64>> = rollouts.iter().map(|r| r.token_version.clone()).collect();
        (toks, lps, vers, eng.stats.clone())
    };
    let (t1, l1, v1, s1) = run(1);
    let (tw, lw, vw, sw) = run(4);
    assert_eq!(t1, tw, "identical token streams");
    assert_eq!(l1, lw, "identical behavior logprobs (bitwise)");
    assert_eq!(v1, vw, "identical version tags");
    assert_eq!(s1.prefill_chunks, 0, "legacy path never chunk-dispatches");
    assert!(sw.prefill_chunks > 0, "W = 4 must ingest via chunk dispatches");
    assert!(sw.forced_steps_saved > 0);
    assert!(sw.steps < s1.steps, "chunking must reduce total dispatches");
}

/// Greedy decoding is draw-free, so the equivalence must also hold for
/// heterogeneous prompt lengths (rows mid-prefill ride chunk dispatches
/// while resident rows keep decoding on single-token lanes).
#[test]
fn chunked_prefill_greedy_heterogeneous_matches_legacy() {
    if !chunk_width_or_skip("chunked_prefill_greedy_heterogeneous_matches_legacy", 4) {
        return;
    }
    let run = |w: usize| {
        let mut cfg = EngineCfg::new("tiny");
        cfg.max_new_tokens = 10;
        cfg.greedy = true;
        cfg.prefill_chunk = w;
        let (_rt, mut eng) = mk_engine(cfg);
        submit_with_lens(&mut eng, &[3, 11, 6, 16]);
        let mut rollouts = Vec::new();
        for _ in 0..600 {
            rollouts.extend(eng.step().unwrap().finished);
            if rollouts.len() >= 4 {
                break;
            }
        }
        assert_eq!(rollouts.len(), 4);
        rollouts.sort_by_key(|r| r.seq_id);
        let toks: Vec<Vec<i32>> = rollouts.iter().map(|r| r.gen_tokens.clone()).collect();
        let lps: Vec<Vec<f32>> = rollouts.iter().map(|r| r.behavior_lp.clone()).collect();
        (toks, lps)
    };
    assert_eq!(run(1), run(4), "greedy streams identical across chunk widths");
}

/// The acceptance arithmetic: with `prefill_chunk = W`, ingesting a
/// stream of length L costs `ceil(L / W)` dispatches to the first
/// sampled token (legacy: L), and an N-row replay to position P costs
/// `ceil(P / W)` dispatches booking `P - ceil(P / W)` saved steps.
#[test]
fn chunked_prefill_dispatch_counts() {
    if !chunk_width_or_skip("chunked_prefill_dispatch_counts", 4) {
        return;
    }
    let w = 4usize;
    let prompt_len = 10usize; // stream = BOS + 10 tokens -> L = 11
    let l = prompt_len + 1;
    let mut cfg = EngineCfg::new("tiny");
    cfg.max_new_tokens = 16;
    cfg.prefill_chunk = w;
    cfg.recompute_kv_on_update = true;
    let (mut rt, mut eng) = mk_engine(cfg);
    submit_with_lens(&mut eng, &[prompt_len; 4]);
    let mut steps_to_first_sample = 0u64;
    for _ in 0..100 {
        let out = eng.step().unwrap();
        steps_to_first_sample += 1;
        if out.tokens_sampled > 0 {
            break;
        }
    }
    let expect_dispatches = l.div_ceil(w) as u64;
    assert_eq!(steps_to_first_sample, expect_dispatches, "ingestion is O(L/W)");
    assert_eq!(eng.stats.prefill_chunks, expect_dispatches);
    assert_eq!(eng.stats.forced_steps_saved, l as u64 - expect_dispatches);

    // replay accounting: every still-active row sits at pos = L after
    // the sampling round, so the coalesced recompute replays to P = L
    if eng.n_active() == 0 {
        return; // every first sample hit EOS — nothing to replay
    }
    let before = eng.stats.clone();
    let params_v1 = rt.init_params("tiny", 8).unwrap();
    eng.set_weights(1, &params_v1).unwrap();
    assert_eq!(eng.stats.kv_recomputes, before.kv_recomputes + 1);
    let p = l; // replay rebuilds positions 0..P-1, P = pos = L
    assert_eq!(
        eng.stats.recompute_steps - before.recompute_steps,
        p.div_ceil(w) as u64,
        "replay to P costs ceil(P/W) dispatches"
    );
    assert_eq!(
        eng.stats.forced_steps_saved - before.forced_steps_saved,
        (p - p.div_ceil(w)) as u64,
        "replay books P - ceil(P/W) saved dispatches"
    );
}

/// Regression (replay-window starvation): a closed coalesced-replay
/// window must hold only pos > 0 candidates — fresh prompts fill the
/// free slots instead of queueing behind imports they do not depend on.
#[test]
fn fresh_admissions_bypass_closed_replay_window() {
    if !runtime_or_skip("fresh_admissions_bypass_closed_replay_window") {
        return;
    }
    let mut cfg = EngineCfg::new("tiny");
    cfg.max_new_tokens = 32;
    cfg.replay_batch = 4;
    // donor: run four sequences a few steps, park them as snapshots
    let (_rt_a, mut donor) = mk_engine(cfg.clone());
    submit_n(&mut donor, 4);
    for _ in 0..6 {
        donor.step().unwrap();
    }
    let snaps = donor.export_snapshots();
    assert_eq!(snaps.len(), 4);
    assert!(snaps.iter().all(|s| s.total_len() > 1), "donors made progress");

    // receiver: two resident sequences leave two free slots; four
    // waiting replays need all four slots, so the window is closed
    let (_rt_b, mut eng) = mk_engine(cfg);
    submit_n(&mut eng, 2);
    eng.step().unwrap();
    assert_eq!(eng.n_active(), 2);
    let gen = TaskGen::curriculum_small();
    for s in &snaps {
        eng.import_snapshot(s, gen.problem(s.problem_id)).unwrap();
    }
    // two fresh prompts arrive behind the replay candidates
    let p1 = gen.problem(900);
    let p2 = gen.problem(901);
    let t1 = Tokenizer::new().encode(&p1.prompt).unwrap();
    let t2 = Tokenizer::new().encode(&p2.prompt).unwrap();
    eng.add_request(p1, t1, 900);
    eng.add_request(p2, t2, 901);
    assert_eq!(eng.n_pending(), 6);
    eng.step().unwrap();
    // the fix under test: fresh sequences admit while the replays wait
    assert_eq!(eng.n_active(), 4, "fresh prompts fill the surplus slots");
    assert_eq!(eng.n_pending(), 4, "replay candidates keep waiting");
    assert_eq!(eng.stats.import_replays, 0, "no partial replay batch ran");
}
