//! Golden-run conformance: whole-run perturbation equivalence, device-free.
//!
//! Three equivalence claims, each proven by digest equality against an
//! unperturbed run of the same seed (`testkit::golden`):
//!
//! 1. **Trainer failover** — kill the trainer at step k; the failover
//!    restores it from the latest checkpoint manifest in process, and
//!    the run's digest is unchanged.
//! 2. **Full-run bit-identical resume** — kill the whole run at *any*
//!    checkpoint boundary; the resumed process (PRLCKPT3 cursors: trainer
//!    RNG, engine sampling RNG, scheduler admission cursor, plus the
//!    in-flight `PRLSNAP1` sidecar) finishes with the uninterrupted
//!    run's digest.
//! 3. **Migration + preemption chaos** — a seeded schedule of actor
//!    kills, pool resizes, byzantine deposits and forced preemptions
//!    changes nothing: snapshots round-trip losslessly, so content is
//!    placement- and perturbation-invariant.
//!
//! Every test wraps its body in `testkit::with_seed`, so the replay seed
//! reaches the failure output unconditionally; on a digest mismatch the
//! first diverging event and both digests land in
//! `target/determinism/<name>-seed-*.txt` for CI to upload. Seeds vary
//! per run via `DETERMINISM_SEED` (tier1.sh loops three of them).
//!
//! A fourth scenario drives the *real* supervisor machinery: a
//! `TrainerSlot` trainer is chaos-killed mid-run and the supervisor's
//! manifest failover must reproduce the uninterrupted trainer's final
//! parameters bit-identically.
//!
//! The off-policyness dial adds three more claims: (4) every publish
//! cadence — pipeline (every step), periodic k=3, conventional-shaped
//! (per RL batch) — is chaos-equivalent *and* the three cadences yield
//! mutually distinct trajectories; (5) the truncated-IS weight lane is
//! arrival-order-invariant and degrades exactly to the uncorrected
//! batch when the scorer reports zero lag; (6) replaying a continuation
//! of an already-trained truncated prefix changes nothing (the
//! conservation books drop it before it reaches a group slot).
//!
//! The run control plane (PR 7) adds two more: (7) a **pause window**
//! is a uniform time shift — every in-flight sequence parks into the
//! migration hub at the window edge and is reclaimed at reopen, so the
//! digest is unchanged and the conservation books stay closed; (8) a
//! **guardrail rollback** is a pure retry — the trip run's digest
//! equals both the trip-free run and the kill-at-checkpoint + resume
//! twin, while a rollback that targets a *stale* manifest (sabotaged
//! cursors) must visibly fork.

use pipeline_rl::broker::{topic, Policy};
use pipeline_rl::config::{ControlConfig, GatewayConfig};
use pipeline_rl::control::{ControlPlane, RunState, RUN_STATE_GAUGE};
use pipeline_rl::coordinator::supervisor::{
    run_supervisor, ActorPool, SpawnFn, SupervisorArgs, TrainerCtx, TrainerSlot,
    TrainerSpawnFn,
};
use pipeline_rl::coordinator::trainer::TrainerExit;
use pipeline_rl::coordinator::{GroupCollector, Packer, TrainBatch};
use pipeline_rl::data::task::TaskGen;
use pipeline_rl::engine::{CompletionRequest, GenerationService};
use pipeline_rl::gateway::{Gateway, SimService};
use pipeline_rl::metrics::MetricsHub;
use pipeline_rl::model::checkpoint::TrainState;
use pipeline_rl::rl::{truncated_weights, FinishReason, Rollout};
use pipeline_rl::sched::{KvLayout, PreemptPolicy, SchedPolicy};
// shared deterministic trainer (Adam-shaped, checkpointed RNG cursor):
// one manifest save per step, publishing the version clock the chaos
// schedule fires on
use pipeline_rl::testkit::synth::SynthTrainer;
use pipeline_rl::testkit::chaos::ChaosSchedule;
use pipeline_rl::testkit::golden::{
    explain_divergence, fnv64, write_failure_report, EventLog, GoldenCfg,
    GoldenPipeline, Perturbation,
};
use pipeline_rl::testkit::with_seed;
use pipeline_rl::util::Rng;
use pipeline_rl::weights::WeightBus;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Seed source: `DETERMINISM_SEED` (decimal or 0x-hex) when set — the
/// tier1.sh loop runs this suite under three distinct seeds — else a
/// fixed default.
fn seed_from_env(default: u64) -> u64 {
    std::env::var("DETERMINISM_SEED")
        .ok()
        .and_then(|s| {
            let s = s.trim().to_string();
            match s.strip_prefix("0x") {
                Some(h) => u64::from_str_radix(h, 16).ok(),
                None => s.parse().ok(),
            }
        })
        .unwrap_or(default)
}

fn temp_dir(tag: &str, seed: u64) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "prl_det_{tag}_{}_{seed:x}",
        std::process::id()
    ));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

/// Digest equality with forensics: on mismatch, the first diverging
/// event and both digests are printed *and* persisted for CI.
fn assert_digest_eq(name: &str, seed: u64, baseline: &EventLog, perturbed: &[&EventLog]) {
    let want = baseline.digest();
    let got = perturbed
        .last()
        .expect("at least one perturbed segment")
        .digest();
    if want == got {
        return;
    }
    let body = format!(
        "baseline digest  {want}\nperturbed digest {got}\n{}",
        explain_divergence(baseline, perturbed)
    );
    let report = write_failure_report(name, seed, &body);
    panic!("{name}: digest mismatch (seed {seed:#x}, report {report:?})\n{body}");
}

// ---------------------------------------------------------------------
// equivalence 1: trainer failover
// ---------------------------------------------------------------------

#[test]
fn kill_trainer_with_failover_is_digest_equivalent() {
    let seed = seed_from_env(0xfa_11_0e_0e);
    with_seed("kill_trainer_failover", seed, |seed| {
        // checkpoint every step: the manifest is always at the trainer's
        // current step, so an in-process failover restores it exactly
        let mk_cfg = |dir: PathBuf| {
            let mut cfg = GoldenCfg::new(seed);
            cfg.steps = 12;
            cfg.checkpoint_every = 1;
            cfg.dir = Some(dir);
            cfg
        };
        let base_dir = temp_dir("ktf_base", seed);
        let base = GoldenPipeline::run(&mk_cfg(base_dir.clone()), &Perturbation::none())
            .expect("baseline run");

        for kill_at in [1u64, 4, 9] {
            let dir = temp_dir("ktf_pert", seed ^ kill_at);
            let pert = Perturbation::chaos(ChaosSchedule::kill_trainer(kill_at));
            let run = GoldenPipeline::run(&mk_cfg(dir.clone()), &pert)
                .expect("perturbed run");
            assert_eq!(
                run.stats.trainer_failovers, 1,
                "the kill at step {kill_at} must have fired"
            );
            assert_digest_eq(
                "kill_trainer_failover",
                seed,
                &base.log,
                &[&run.log],
            );
            std::fs::remove_dir_all(&dir).ok();
        }
        std::fs::remove_dir_all(&base_dir).ok();
    });
}

#[test]
fn stale_manifest_failover_is_detectable() {
    // negative control: with a sparse checkpoint cadence the failover
    // legitimately rewinds the trainer (steps since the last manifest
    // are re-run) — the digest MUST see that, or it could not prove the
    // every-step case above is exact
    let seed = seed_from_env(0x57a1e);
    with_seed("stale_manifest_failover", seed, |seed| {
        let mk_cfg = |dir: PathBuf, every: u64| {
            let mut cfg = GoldenCfg::new(seed);
            cfg.steps = 12;
            cfg.checkpoint_every = every;
            cfg.dir = Some(dir);
            cfg
        };
        let base_dir = temp_dir("stale_base", seed);
        let base = GoldenPipeline::run(&mk_cfg(base_dir.clone(), 3), &Perturbation::none())
            .expect("baseline run");
        let dir = temp_dir("stale_pert", seed);
        // kill at step 4: the newest manifest is step 3, so the failover
        // rewinds one step and the trajectory visibly forks
        let pert = Perturbation::chaos(ChaosSchedule::kill_trainer(4));
        let run = GoldenPipeline::run(&mk_cfg(dir.clone(), 3), &pert).expect("perturbed run");
        assert_eq!(run.stats.trainer_failovers, 1);
        assert_ne!(
            base.log.digest(),
            run.log.digest(),
            "a rewinding failover must be digest-visible"
        );
        std::fs::remove_dir_all(&base_dir).ok();
        std::fs::remove_dir_all(&dir).ok();
    });
}

// ---------------------------------------------------------------------
// equivalence 2: full-run bit-identical resume (PRLCKPT3 cursors)
// ---------------------------------------------------------------------

#[test]
fn checkpoint_kill_resume_at_any_boundary_is_digest_equivalent() {
    let seed = seed_from_env(0x2e5_0e3);
    with_seed("checkpoint_kill_resume", seed, |seed| {
        let mk_cfg = |dir: PathBuf| {
            let mut cfg = GoldenCfg::new(seed);
            cfg.steps = 8;
            cfg.checkpoint_every = 2;
            cfg.dir = Some(dir);
            // the non-default admission policy: the resume must also
            // restore *its* ordering inputs (gen-prefix lengths)
            cfg.sched = SchedPolicy::LongestPrefixFirst;
            cfg.preempt = PreemptPolicy::Youngest;
            cfg
        };
        let base_dir = temp_dir("ckr_base", seed);
        let base = GoldenPipeline::run(&mk_cfg(base_dir.clone()), &Perturbation::none())
            .expect("baseline run");

        // every checkpoint boundary of the run
        for kill_at in [2u64, 4, 6, 8] {
            let dir = temp_dir("ckr_pert", seed ^ kill_at);
            let cfg = mk_cfg(dir.clone());
            let killed =
                GoldenPipeline::run_until_checkpoint(&cfg, &Perturbation::none(), kill_at)
                    .expect("killed run");
            if kill_at < cfg.steps {
                assert_eq!(
                    killed.stopped_at_checkpoint,
                    Some(kill_at),
                    "the kill must land at the boundary"
                );
                let resumed = GoldenPipeline::resume(&cfg, &Perturbation::none())
                    .expect("resumed run");
                assert_eq!(resumed.steps_done, cfg.steps, "resume finishes the run");
                assert_digest_eq(
                    "checkpoint_kill_resume",
                    seed,
                    &base.log,
                    &[&killed.log, &resumed.log],
                );
            } else {
                // killing at the final boundary IS completion
                assert_digest_eq("checkpoint_kill_resume", seed, &base.log, &[&killed.log]);
            }
            std::fs::remove_dir_all(&dir).ok();
        }
        std::fs::remove_dir_all(&base_dir).ok();
    });
}

#[test]
fn dropping_a_prlckpt3_cursor_breaks_the_resume() {
    // negative control for the PRLCKPT3 fields: replace the engine RNG
    // cursor in the on-disk state with a foreign stream (losing the real
    // cursor, as a PRLCKPT2-era checkpoint would) and the resumed run
    // must fork — i.e. the new cursors are load-bearing, not decorative.
    let seed = seed_from_env(0xc0_13_05);
    with_seed("cursor_negative_control", seed, |seed| {
        let mk_cfg = |dir: PathBuf| {
            let mut cfg = GoldenCfg::new(seed);
            cfg.steps = 8;
            cfg.checkpoint_every = 2;
            cfg.dir = Some(dir);
            cfg
        };
        let base_dir = temp_dir("neg_base", seed);
        let base = GoldenPipeline::run(&mk_cfg(base_dir.clone()), &Perturbation::none())
            .expect("baseline run");

        let dir = temp_dir("neg_pert", seed);
        let cfg = mk_cfg(dir.clone());
        GoldenPipeline::run_until_checkpoint(&cfg, &Perturbation::none(), 4)
            .expect("killed run");
        // sabotage: swap the engine cursor for an unrelated stream
        let mut st = TrainState::load_latest(&dir).unwrap();
        assert_ne!(st.engine_rng, [0u64; 4], "golden checkpoints carry a live cursor");
        st.engine_rng = Rng::new(0x0dd_c0de).state_words();
        st.save_with_manifest(&dir, 0).unwrap();
        let resumed = GoldenPipeline::resume(&cfg, &Perturbation::none()).expect("resumes");
        assert_ne!(
            base.log.digest(),
            resumed.log.digest(),
            "a lost engine cursor must be digest-visible"
        );
        std::fs::remove_dir_all(&base_dir).ok();
        std::fs::remove_dir_all(&dir).ok();
    });
}

// ---------------------------------------------------------------------
// equivalence 3: migration + preemption chaos
// ---------------------------------------------------------------------

#[test]
fn migration_and_preemption_chaos_is_digest_equivalent() {
    let seed = seed_from_env(0x306a_70);
    with_seed("migration_preemption_chaos", seed, |seed| {
        let mut cfg = GoldenCfg::new(seed);
        cfg.steps = 14;
        cfg.n_actors = 3;
        cfg.live_target = 8;
        cfg.preempt = PreemptPolicy::Youngest;
        let base = GoldenPipeline::run(&cfg, &Perturbation::none()).expect("baseline");

        // hand-built worst case: churn-heavy kills/resizes + byzantine
        // deposits + forced preemptions, all mid-run
        let mut chaos = ChaosSchedule::kill_then_restart(2, 5);
        chaos.events.push(pipeline_rl::testkit::chaos::ChaosEvent {
            at_step: 4,
            kind: pipeline_rl::testkit::chaos::ChaosKind::RemoveActor,
        });
        chaos.events.push(pipeline_rl::testkit::chaos::ChaosEvent {
            at_step: 6,
            kind: pipeline_rl::testkit::chaos::ChaosKind::KillActor,
        });
        chaos.events.push(pipeline_rl::testkit::chaos::ChaosEvent {
            at_step: 7,
            kind: pipeline_rl::testkit::chaos::ChaosKind::CorruptSnapshot,
        });
        chaos.events.sort_by_key(|e| e.at_step);
        let pert = Perturbation {
            chaos: Some(chaos),
            preempt_ticks: vec![3, 9, 15, 21],
            ..Perturbation::default()
        };
        let run = GoldenPipeline::run(&cfg, &pert).expect("perturbed run");
        assert!(run.stats.migrated > 0, "kills moved live sequences");
        assert!(run.stats.preemptions > 0, "forced preemptions fired");
        assert_eq!(run.stats.corrupt_rejected, 1, "poison rejected at claim");
        assert_digest_eq("migration_preemption_chaos", seed, &base.log, &[&run.log]);

        // and a fully seed-generated schedule (mixed kinds, seeded
        // preempt ticks) — the "every existing chaos scenario becomes an
        // equivalence claim" form
        let gen = Perturbation::generate(seed, cfg.steps, 6, 3);
        let run2 = GoldenPipeline::run(&cfg, &gen).expect("generated-chaos run");
        assert_digest_eq("migration_preemption_chaos_gen", seed, &base.log, &[&run2.log]);
    });
}

/// The paged device-KV layout is an implementation detail, not a
/// behavior: a golden run threading every admission/growth/release
/// through the refcounted block-allocator shadow (CoW prompt forks,
/// per-tick conservation checks) produces the *same digest* as the
/// dense run — calm and under migration + preemption chaos alike.
#[test]
fn paged_kv_layout_is_digest_equivalent_to_dense() {
    let seed = seed_from_env(0x9a6e_d0);
    with_seed("paged_kv_layout", seed, |seed| {
        let mut cfg = GoldenCfg::new(seed);
        cfg.steps = 14;
        cfg.n_actors = 3;
        cfg.live_target = 8;
        cfg.preempt = PreemptPolicy::Youngest;
        let mut paged_cfg = cfg.clone();
        paged_cfg.kv_layout = KvLayout::Paged;

        // calm: same digest with and without the paged shadow
        let base = GoldenPipeline::run(&cfg, &Perturbation::none()).expect("dense baseline");
        let calm =
            GoldenPipeline::run(&paged_cfg, &Perturbation::none()).expect("paged baseline");
        assert_digest_eq("paged_kv_layout_calm", seed, &base.log, &[&calm.log]);
        assert_eq!(base.stats.kv_cow_forks, 0, "the dense arm runs no shadow");
        assert!(
            calm.stats.kv_cow_forks > 0,
            "2-token prompts on 4-token pages: a group member's first \
             divergent write must fork the shared prompt block"
        );
        assert!(calm.stats.kv_peak_blocks > 0, "the shadow held real blocks");

        // chaos: kills, pool resizes, byzantine deposits and forced
        // preemptions — block tables churn through release/re-admit and
        // a full allocator rebuild at every rollback, digest unchanged
        let mut chaos = ChaosSchedule::kill_then_restart(2, 5);
        chaos.events.push(pipeline_rl::testkit::chaos::ChaosEvent {
            at_step: 4,
            kind: pipeline_rl::testkit::chaos::ChaosKind::RemoveActor,
        });
        chaos.events.push(pipeline_rl::testkit::chaos::ChaosEvent {
            at_step: 7,
            kind: pipeline_rl::testkit::chaos::ChaosKind::CorruptSnapshot,
        });
        chaos.events.sort_by_key(|e| e.at_step);
        let pert = Perturbation {
            chaos: Some(chaos),
            preempt_ticks: vec![3, 9, 15, 21],
            ..Perturbation::default()
        };
        let run = GoldenPipeline::run(&paged_cfg, &pert).expect("paged chaos run");
        assert!(run.stats.migrated > 0, "kills moved live sequences");
        assert!(run.stats.preemptions > 0, "forced preemptions fired");
        assert_digest_eq("paged_kv_layout_chaos", seed, &base.log, &[&run.log]);
    });
}

/// Chunked prefill is a dispatch-count optimization, not a behavior:
/// a golden run billing prompt ingestion and snapshot re-seating in
/// W-wide chunks produces the *same digest* as the token-at-a-time run
/// — calm and under kill/preempt chaos — while its dispatch shadow
/// drops by exactly the coalesced forced steps.
#[test]
fn chunked_prefill_is_digest_equivalent_to_token_at_a_time() {
    let seed = seed_from_env(0xc4_0a_11);
    with_seed("chunked_prefill", seed, |seed| {
        let mut cfg = GoldenCfg::new(seed);
        cfg.steps = 14;
        cfg.n_actors = 3;
        cfg.live_target = 8;
        cfg.preempt = PreemptPolicy::Youngest;
        let mut chunk_cfg = cfg.clone();
        chunk_cfg.prefill_chunk = 4;

        // calm: same digest with and without chunked billing
        let base =
            GoldenPipeline::run(&cfg, &Perturbation::none()).expect("legacy baseline");
        let calm =
            GoldenPipeline::run(&chunk_cfg, &Perturbation::none()).expect("chunked baseline");
        assert_digest_eq("chunked_prefill_calm", seed, &base.log, &[&calm.log]);
        assert_eq!(base.stats.forced_steps_saved, 0, "W = 1 coalesces nothing");
        assert!(calm.stats.forced_steps_saved > 0, "W = 4 coalesces forced steps");
        assert!(
            calm.stats.prefill_dispatches < base.stats.prefill_dispatches,
            "chunking must cut prefill dispatches"
        );
        // identical seatings in both arms: the W = 1 dispatch bill splits
        // exactly into chunk dispatches plus the steps they absorbed
        assert_eq!(
            calm.stats.prefill_dispatches + calm.stats.forced_steps_saved,
            base.stats.prefill_dispatches,
            "dispatch accounting must conserve fed positions"
        );

        // chaos: kills and forced preemptions re-seat salvaged prefixes
        // through the chunked replay accounting — digest unchanged
        let pert = Perturbation::generate(seed, cfg.steps, 6, 3);
        let run = GoldenPipeline::run(&chunk_cfg, &pert).expect("chunked chaos run");
        let legacy = GoldenPipeline::run(&cfg, &pert).expect("legacy chaos run");
        assert_digest_eq("chunked_prefill_chaos", seed, &base.log, &[&run.log]);
        assert_digest_eq("chunked_prefill_chaos_legacy", seed, &base.log, &[&legacy.log]);
        assert_eq!(
            run.stats.prefill_dispatches + run.stats.forced_steps_saved,
            legacy.stats.prefill_dispatches,
            "conservation holds under chaos re-seating too"
        );
    });
}

// ---------------------------------------------------------------------
// the real supervisor: TrainerSlot failover, bit-identical parameters
// ---------------------------------------------------------------------

#[test]
fn supervisor_failover_reproduces_uninterrupted_trainer_bit_identically() {
    const TOTAL: u64 = 16;
    const KILL_AT: u64 = 3;
    let seed = seed_from_env(0x5e1f_0a11);
    with_seed("supervisor_trainer_failover", seed, |seed| {
        // uninterrupted reference trajectory
        let mut reference = SynthTrainer::new(seed);
        for _ in 0..TOTAL {
            reference.step();
        }

        let dir = temp_dir("supfail", seed);
        let hub = MetricsHub::new();
        let bus = WeightBus::new();
        bus.publish(1, Arc::new(vec![]));
        let (tx, rx) = topic::<Rollout>("rollouts", 64, Policy::DropOldest);
        let stop = Arc::new(AtomicBool::new(false));
        let idle: SpawnFn = Arc::new(|ctx| {
            while !ctx.stop.load(Ordering::Relaxed) && !ctx.halt.load(Ordering::Relaxed) {
                std::thread::sleep(Duration::from_millis(1));
            }
            Ok(())
        });
        let pool = ActorPool::new(idle, stop.clone(), hub.clone(), 1, 1, 2, 0, false).unwrap();

        let dir_t = dir.clone();
        let bus_t = bus.clone();
        let stop_t = stop.clone();
        let spawn: TrainerSpawnFn = Arc::new(move |ctx: TrainerCtx| {
            let mut t = if ctx.resume_latest {
                match TrainState::load_resume(&dir_t) {
                    Ok(st) => SynthTrainer::from_state(st),
                    Err(_) => SynthTrainer::new(seed),
                }
            } else {
                SynthTrainer::new(seed)
            };
            while t.step < TOTAL {
                if stop_t.load(Ordering::Relaxed) {
                    return Ok(TrainerExit::Completed(t.params));
                }
                if ctx.halt.load(Ordering::Relaxed) {
                    return Ok(TrainerExit::Halted);
                }
                // pace the run so the chaos kill lands mid-flight even
                // on a loaded CI box (the supervisor polls at 1ms)
                std::thread::sleep(Duration::from_millis(10));
                t.step();
                t.to_state().save_with_manifest(&dir_t, 0).unwrap();
                bus_t.publish(t.step + 1, Arc::new(vec![]));
            }
            Ok(TrainerExit::Completed(t.params))
        });
        let slot = TrainerSlot::new(spawn, 2).unwrap();

        let sup_args = SupervisorArgs {
            pool,
            bus: bus.clone(),
            rollout_tx: tx.clone(),
            schedule: Some(ChaosSchedule::kill_trainer(KILL_AT)),
            stop: stop.clone(),
            hub: hub.clone(),
            poll: Duration::from_millis(1),
            migrate: None,
            autoscale: None,
            trainer: Some(slot),
            control: None,
        };
        let sup = std::thread::spawn(move || run_supervisor(sup_args));
        let final_params = sup
            .join()
            .unwrap()
            .expect("supervisor exits clean")
            .expect("failover supervisor returns the trainer's parameters");
        drop(tx);
        drop(rx);

        assert_eq!(
            hub.counter("trainer_failovers"),
            1.0,
            "exactly one failover fired"
        );
        assert_eq!(
            final_params, reference.params,
            "failover trajectory must be bit-identical to the uninterrupted one"
        );
        let latest = TrainState::load_latest(&dir).unwrap();
        assert_eq!(latest.step, TOTAL, "the respawned trainer checkpointed to the end");
        std::fs::remove_dir_all(&dir).ok();
    });
}

#[test]
fn guardrail_trip_rolls_back_supervised_trainer_bit_identically() {
    // the control plane on the real supervisor machinery: a
    // chaos-injected guardrail trip mid-run pauses the actors through
    // the gate, restores the trainer from the latest checkpoint manifest
    // via the failover slot, and resumes — the final parameters must be
    // bit-identical to the uninterrupted trajectory (every step is
    // checkpointed, so the rollback is a pure retry), and the run ends
    // Completed, not Drained or Failed.
    const TOTAL: u64 = 16;
    const TRIP_AT: u64 = 3;
    let seed = seed_from_env(0x60a2_d1);
    with_seed("supervisor_guardrail_rollback", seed, |seed| {
        let mut reference = SynthTrainer::new(seed);
        for _ in 0..TOTAL {
            reference.step();
        }

        let dir = temp_dir("supguard", seed);
        let hub = MetricsHub::new();
        let bus = WeightBus::new();
        bus.publish(1, Arc::new(vec![]));
        let (tx, rx) = topic::<Rollout>("rollouts", 64, Policy::DropOldest);
        let stop = Arc::new(AtomicBool::new(false));
        let idle: SpawnFn = Arc::new(|ctx| {
            while !ctx.stop.load(Ordering::Relaxed) && !ctx.halt.load(Ordering::Relaxed) {
                std::thread::sleep(Duration::from_millis(1));
            }
            Ok(())
        });
        let pool = ActorPool::new(idle, stop.clone(), hub.clone(), 1, 1, 2, 0, false).unwrap();

        let dir_t = dir.clone();
        let bus_t = bus.clone();
        let stop_t = stop.clone();
        let spawn: TrainerSpawnFn = Arc::new(move |ctx: TrainerCtx| {
            let mut t = if ctx.resume_latest {
                match TrainState::load_resume(&dir_t) {
                    Ok(st) => SynthTrainer::from_state(st),
                    Err(_) => SynthTrainer::new(seed),
                }
            } else {
                SynthTrainer::new(seed)
            };
            while t.step < TOTAL {
                if stop_t.load(Ordering::Relaxed) {
                    return Ok(TrainerExit::Completed(t.params));
                }
                if ctx.halt.load(Ordering::Relaxed) {
                    return Ok(TrainerExit::Halted);
                }
                // pace the run so the trip lands mid-flight even on a
                // loaded CI box (the supervisor polls at 1ms)
                std::thread::sleep(Duration::from_millis(10));
                t.step();
                t.to_state().save_with_manifest(&dir_t, 0).unwrap();
                bus_t.publish(t.step + 1, Arc::new(vec![]));
            }
            Ok(TrainerExit::Completed(t.params))
        });
        let slot = TrainerSlot::new(spawn, 2).unwrap();

        let mut ctl_cfg = ControlConfig::default();
        ctl_cfg.enabled = true;
        ctl_cfg.retry_backoff_ms = 1;
        let sup_args = SupervisorArgs {
            pool,
            bus: bus.clone(),
            rollout_tx: tx.clone(),
            schedule: Some(ChaosSchedule::guardrail_trip(TRIP_AT)),
            stop: stop.clone(),
            hub: hub.clone(),
            poll: Duration::from_millis(1),
            migrate: None,
            autoscale: None,
            trainer: Some(slot),
            control: Some(ControlPlane::new(ctl_cfg)),
        };
        let sup = std::thread::spawn(move || run_supervisor(sup_args));
        let final_params = sup
            .join()
            .unwrap()
            .expect("supervisor exits clean")
            .expect("rolled-back supervisor returns the trainer's parameters");
        drop(tx);
        drop(rx);

        assert_eq!(hub.counter("chaos_guardrail_trips"), 1.0, "the trip fired once");
        assert_eq!(hub.counter("control_rollbacks"), 1.0, "resolved by one rollback");
        assert_eq!(hub.counter("trainer_failovers"), 1.0);
        assert_eq!(hub.counter("control_failsafe_drains"), 0.0, "budget never exhausted");
        assert_eq!(
            final_params, reference.params,
            "rollback trajectory must be bit-identical to the uninterrupted one"
        );
        assert_eq!(
            hub.series_last(RUN_STATE_GAUGE).unwrap().value,
            RunState::Completed.gauge(),
            "a recovered run terminates Completed"
        );
        // the trip left a forensics report for CI to upload
        assert!(
            std::path::Path::new("target/control/chaos_guardrail_trip-injected.txt").exists(),
            "guardrail trips must write a target/control/ report"
        );
        let latest = TrainState::load_latest(&dir).unwrap();
        assert_eq!(latest.step, TOTAL, "the rolled-back trainer checkpointed to the end");
        std::fs::remove_dir_all(&dir).ok();
    });
}

// ---------------------------------------------------------------------
// equivalence 4: the off-policyness dial — all three run modes survive
// chaos at their own publish cadence, and the cadences are distinct
// ---------------------------------------------------------------------

#[test]
fn publish_cadence_matrix_is_digest_equivalent_under_chaos() {
    let seed = seed_from_env(0xca_de_2c_e5);
    with_seed("publish_cadence_matrix", seed, |seed| {
        let mk_cfg = |publish_every: u64| {
            let mut cfg = GoldenCfg::new(seed);
            cfg.steps = 12;
            cfg.n_actors = 3;
            cfg.live_target = 8;
            cfg.preempt = PreemptPolicy::Youngest;
            cfg.publish_every = publish_every;
            cfg
        };
        // pipeline publishes every step, periodic(k=3) every third,
        // conventional-shaped cadence once per 6-step RL batch
        let modes = [("pipeline", 1u64), ("periodic_k3", 3), ("conventional", 6)];
        let mut digests = Vec::new();
        for (tag, publish_every) in modes {
            let cfg = mk_cfg(publish_every);
            let base = GoldenPipeline::run(&cfg, &Perturbation::none())
                .unwrap_or_else(|e| panic!("{tag}: baseline run: {e:?}"));
            let pert = Perturbation {
                chaos: Some(ChaosSchedule::kill_then_restart(2, 5)),
                preempt_ticks: vec![3, 9, 15],
                ..Perturbation::default()
            };
            let run = GoldenPipeline::run(&cfg, &pert)
                .unwrap_or_else(|e| panic!("{tag}: perturbed run: {e:?}"));
            assert!(run.stats.migrated > 0, "{tag}: kills moved live sequences");
            assert_digest_eq(
                &format!("publish_cadence_{tag}"),
                seed,
                &base.log,
                &[&run.log],
            );
            digests.push((tag, base.log.digest()));
        }
        // the cadence is load-bearing: staler weights reach actors under
        // sparser publishing, so the three trajectories must all differ
        for i in 0..digests.len() {
            for j in i + 1..digests.len() {
                assert_ne!(
                    digests[i].1, digests[j].1,
                    "{} and {} cadences must produce distinct trajectories",
                    digests[i].0, digests[j].0
                );
            }
        }
    });
}

// ---------------------------------------------------------------------
// equivalence 5: IS weight lane + truncated-rollout conservation
// (device-free through the real GroupCollector + Packer hot path)
// ---------------------------------------------------------------------

fn synth_rollout(rng: &mut Rng, seq_id: u64, group_id: u64, finish: FinishReason) -> Rollout {
    let n = 4 + rng.below(5);
    Rollout {
        seq_id,
        problem_id: seq_id,
        group_id,
        actor_id: 0,
        prompt_tokens: vec![1, 7],
        gen_tokens: (0..n).map(|_| 2 + rng.below(96) as i32).collect(),
        behavior_lp: (0..n).map(|_| -0.05 - 2.0 * rng.f32()).collect(),
        token_version: vec![1 + seq_id % 4; n],
        reward: rng.f32(),
        finish,
        t_start: 0.0,
        t_end: 0.0,
    }
}

/// Canonical content digest over everything the trainer consumes from a
/// packed batch: token stream, segment ids, mask, advantages, behavior
/// logprobs, the IS weight lane, and the host-weighted flag.
fn digest_batches(batches: &[TrainBatch]) -> u64 {
    let mut bytes = Vec::new();
    for b in batches {
        for &v in &b.tokens {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        for &v in &b.seg {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        for &v in &b.mask {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        for &v in &b.adv {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        for &v in &b.behavior_lp {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        for &v in &b.is_w {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        bytes.push(b.host_weighted as u8);
    }
    fnv64(&bytes)
}

#[test]
fn is_weight_lane_is_arrival_order_invariant() {
    let seed = seed_from_env(0x15_c0_4e);
    with_seed("is_weight_lane", seed, |seed| {
        let mut rng = Rng::with_stream(seed, 0x15);
        let rollouts: Vec<Rollout> = (0..4)
            .map(|i| synth_rollout(&mut rng, i, 100 + i / 2, FinishReason::Eos))
            .collect();
        let clip_c = 2.0f32;
        // a lagged current policy: deterministic per-token drift away
        // from the behavior logprobs, as stale weights would produce
        let lagged = |r: &Rollout| -> Vec<f32> {
            r.behavior_lp
                .iter()
                .enumerate()
                .map(|(j, lp)| lp + 0.25 * (j as f32) - 0.4)
                .collect()
        };
        // lag-free scorer: lp_pi == lp_mu, so every weight is exactly 1
        let unit = |r: &Rollout| -> Vec<f32> { r.behavior_lp.clone() };
        type Scorer<'a> = Option<&'a dyn Fn(&Rollout) -> Vec<f32>>;
        let pack = |order: &[usize], scorer: Scorer| -> TrainBatch {
            let hub = MetricsHub::new();
            let mut gc = GroupCollector::with_limits(2, false, 0.0, 0);
            let mut ready = Vec::new();
            for &i in order {
                ready.extend(gc.add(rollouts[i].clone(), &hub));
            }
            assert_eq!(ready.len(), 4, "both groups complete");
            // canonical pack order, as placement-independent packing
            // would produce regardless of which actor finished first
            ready.sort_by_key(|(r, _)| r.seq_id);
            let mut p = Packer::new(4, 32);
            for (r, adv) in &ready {
                let w = scorer.map(|s| truncated_weights(&s(r), &r.behavior_lp, clip_c));
                assert!(p.try_add_weighted(r, *adv, w.as_deref()));
            }
            p.flush()
        };
        let a = pack(&[0, 1, 2, 3], Some(&lagged));
        let b = pack(&[2, 0, 3, 1], Some(&lagged)); // interleaved arrival
        assert!(a.host_weighted);
        // the lane is clipped and neutral off-mask
        for (slot, &w) in a.is_w.iter().enumerate() {
            assert!(
                w > 0.0 && w <= clip_c,
                "slot {slot}: weight {w} outside (0, clip_c]"
            );
            if a.mask[slot] == 0.0 {
                assert_eq!(w, 1.0, "slot {slot}: off-mask weight must stay neutral");
            }
        }
        assert_eq!(
            digest_batches(std::slice::from_ref(&a)),
            digest_batches(std::slice::from_ref(&b)),
            "arrival order must not leak into the IS weight lane"
        );
        // degradation: unit weights reproduce the uncorrected batch
        // bit-for-bit, modulo the host_weighted flag itself
        let mut lag_free = pack(&[0, 1, 2, 3], Some(&unit));
        let uncorrected = pack(&[0, 1, 2, 3], None);
        assert!(lag_free.host_weighted && !uncorrected.host_weighted);
        lag_free.host_weighted = false;
        assert_eq!(
            digest_batches(std::slice::from_ref(&lag_free)),
            digest_batches(std::slice::from_ref(&uncorrected)),
            "a lag-free scorer must degrade to the uncorrected batch"
        );
    });
}

#[test]
fn truncated_continuation_replay_is_digest_equivalent() {
    let seed = seed_from_env(0x7bc5);
    with_seed("truncated_conservation", seed, |seed| {
        let mut rng = Rng::with_stream(seed, 0x7b);
        let prefix = synth_rollout(&mut rng, 10, 500, FinishReason::Truncated);
        let sibling = synth_rollout(&mut rng, 11, 500, FinishReason::Eos);
        // the same sequence finishing later: its gen stream extends the
        // already-trained prefix verbatim by one token
        let mut cont = prefix.clone();
        cont.seq_id = 12;
        cont.finish = FinishReason::Eos;
        cont.gen_tokens.push(42);
        cont.behavior_lp.push(-0.25);
        cont.token_version.push(9);

        let run = |inject: bool| -> (u64, MetricsHub) {
            let hub = MetricsHub::new();
            let mut gc =
                GroupCollector::with_limits(2, false, 0.0, 0).admit_truncated(true);
            let mut ready = Vec::new();
            ready.extend(gc.add(prefix.clone(), &hub));
            if inject {
                ready.extend(gc.add(cont.clone(), &hub));
            }
            ready.extend(gc.add(sibling.clone(), &hub));
            ready.sort_by_key(|(r, _)| r.seq_id);
            let mut p = Packer::new(4, 32);
            for (r, adv) in &ready {
                assert!(p.try_add_weighted(r, *adv, None));
            }
            (digest_batches(&[p.flush()]), hub)
        };
        let (base, _) = run(false);
        let (pert, hub) = run(true);
        assert_eq!(
            base, pert,
            "a replayed continuation of a trained prefix must train nothing"
        );
        assert_eq!(hub.counter("rollouts_continuation_dropped"), 1.0);
        assert_eq!(hub.counter("rollouts_truncated_admitted"), 1.0);
        assert_eq!(hub.counter("groups_completed"), 1.0);
    });
}

// ---------------------------------------------------------------------
// equivalence 7: control-plane pause windows are a uniform time shift
// ---------------------------------------------------------------------

#[test]
fn pause_windows_are_digest_equivalent() {
    let seed = seed_from_env(0x9a_05ed);
    with_seed("pause_windows", seed, |seed| {
        let cfg = GoldenCfg::new(seed);
        let base = GoldenPipeline::run(&cfg, &Perturbation::none()).expect("baseline");
        // two pause windows mid-run. Pauses shift the tick clock, so the
        // perturbation carries *only* pauses — tick-keyed preemptions
        // would rightly land on different sequences and fork the digest.
        let pert = Perturbation::pauses(vec![(4, 10), (14, 17)]);
        let run = GoldenPipeline::run(&cfg, &pert).expect("paused run");
        // window 1 always opens; window 2 only if the (seed-dependent)
        // run is still in flight at tick 14
        assert!(run.stats.pauses >= 1, "at least the first window opened");
        assert!(run.stats.parked > 0, "the pause had sequences in flight");
        assert_eq!(run.steps_done, cfg.steps, "the paused run still finishes");
        // conservation: every parked snapshot was reclaimed or (at
        // teardown) deliberately discarded — no token lost in a pause
        assert_eq!(
            run.stats.hub_deposited,
            run.stats.hub_claimed + run.stats.hub_discarded,
            "pause parking must close the conservation books"
        );
        assert_digest_eq("pause_windows", seed, &base.log, &[&run.log]);
    });
}

// ---------------------------------------------------------------------
// equivalence 8: a guardrail rollback is a pure retry — and a stale
// manifest is digest-visible
// ---------------------------------------------------------------------

#[test]
fn guardrail_rollback_matches_fresh_from_checkpoint_twin() {
    let seed = seed_from_env(0xb0_11_ba_c4);
    with_seed("guardrail_rollback", seed, |seed| {
        let mk_cfg = |dir: PathBuf| {
            let mut cfg = GoldenCfg::new(seed);
            cfg.steps = 8;
            cfg.checkpoint_every = 2;
            cfg.dir = Some(dir);
            cfg
        };
        // twin A: the trip never fires
        let base_dir = temp_dir("grb_base", seed);
        let base = GoldenPipeline::run(&mk_cfg(base_dir.clone()), &Perturbation::none())
            .expect("baseline run");
        // twin B: the run is killed at the checkpoint the trip will
        // target, then resumed fresh from that manifest
        let twin_dir = temp_dir("grb_twin", seed);
        let twin_cfg = mk_cfg(twin_dir.clone());
        let killed =
            GoldenPipeline::run_until_checkpoint(&twin_cfg, &Perturbation::none(), 4)
                .expect("killed twin");
        assert_eq!(killed.stopped_at_checkpoint, Some(4));
        let resumed =
            GoldenPipeline::resume(&twin_cfg, &Perturbation::none()).expect("resumed twin");
        assert_digest_eq("guardrail_rollback", seed, &base.log, &[&killed.log, &resumed.log]);
        // the trip run: a guardrail fires right after step 4 publishes,
        // rolls the whole pipeline image back to the step-4 cut, and
        // replays — in process, mid-run
        let trip_dir = temp_dir("grb_trip", seed);
        let pert = Perturbation::chaos(ChaosSchedule::guardrail_trip(4));
        let run = GoldenPipeline::run(&mk_cfg(trip_dir.clone()), &pert).expect("trip run");
        assert_eq!(run.stats.guardrail_trips, 1, "the trip fired");
        assert_eq!(run.stats.rollbacks, 1, "and resolved by rolling back");
        assert!(!run.drained, "budget left: no fail-safe drain");
        assert_eq!(run.steps_done, 8, "the rolled-back run finishes every step");
        assert_eq!(
            run.stats.hub_deposited,
            run.stats.hub_claimed + run.stats.hub_discarded,
            "rollback quiescing must close the conservation books"
        );
        assert_digest_eq("guardrail_rollback", seed, &base.log, &[&run.log]);
        std::fs::remove_dir_all(&base_dir).ok();
        std::fs::remove_dir_all(&twin_dir).ok();
        std::fs::remove_dir_all(&trip_dir).ok();
    });
}

#[test]
fn stale_manifest_rollback_must_diverge() {
    // negative control for the pure-retry claim: sabotage the manifest
    // the rollback will target (swap the engine admission cursor for a
    // foreign stream, as a stale pre-PRLCKPT3 state would present) and
    // the recovered run must fork — proving the rollback equivalence
    // above is carried by the restored cursors, not by luck
    let seed = seed_from_env(0x57a_1e2);
    with_seed("stale_manifest_rollback", seed, |seed| {
        let mk_cfg = |dir: PathBuf| {
            let mut cfg = GoldenCfg::new(seed);
            cfg.steps = 8;
            cfg.checkpoint_every = 2;
            cfg.dir = Some(dir);
            cfg
        };
        let base_dir = temp_dir("smr_base", seed);
        let base = GoldenPipeline::run(&mk_cfg(base_dir.clone()), &Perturbation::none())
            .expect("baseline run");
        let dir = temp_dir("smr_pert", seed);
        let cfg = mk_cfg(dir.clone());
        GoldenPipeline::run_until_checkpoint(&cfg, &Perturbation::none(), 4)
            .expect("killed run");
        // sabotage the step-4 manifest state in place
        let mut st = TrainState::load_latest(&dir).unwrap();
        assert_eq!(st.step, 4);
        st.engine_rng = Rng::new(0xbad_5eed).state_words();
        st.save_with_manifest(&dir, 0).unwrap();
        // resume under a trip that fires before any fresh checkpoint can
        // land (version is already 5 > 2 at the first chaos poll), so the
        // rollback re-targets the very manifest we just poisoned
        let pert = Perturbation::chaos(ChaosSchedule::guardrail_trip(2));
        let run = GoldenPipeline::resume(&cfg, &pert).expect("recovered run");
        assert_eq!(run.stats.guardrail_trips, 1);
        assert_eq!(run.stats.rollbacks, 1, "the stale manifest was rolled back to");
        assert_eq!(run.steps_done, 8, "the run still completes — just elsewhere");
        assert_ne!(
            base.log.digest(),
            run.log.digest(),
            "a rollback onto a stale manifest must be digest-visible"
        );
        std::fs::remove_dir_all(&base_dir).ok();
        std::fs::remove_dir_all(&dir).ok();
    });
}

// ---------------------------------------------------------------------
// equivalence 9: the serving gateway front is digest-invisible
// ---------------------------------------------------------------------

/// Shared workload: a burst of batch rollouts, one more request landing
/// mid-backlog, run to quiescence. The exact same submit/step call
/// sequence drives a bare service and a gateway-fronted one.
fn drive_gateway_workload<S: GenerationService>(svc: &mut S, interactive: bool) {
    let gen = TaskGen::curriculum_small();
    let prompt = vec![2, 3, 4, 5];
    for i in 1..=6u64 {
        svc.submit(CompletionRequest::rollout(gen.problem(i), prompt.clone(), i))
            .unwrap();
    }
    svc.step().unwrap();
    // a seventh request lands while the backlog is still queued
    let p = gen.problem(77);
    let req = if interactive {
        CompletionRequest::interactive(p, prompt, 77, 9)
    } else {
        CompletionRequest::rollout(p, prompt, 77)
    };
    svc.submit(req).unwrap();
    for step in 0.. {
        assert!(step < 5000, "gateway workload did not complete");
        svc.step().unwrap();
        if svc.load() == 0 {
            break;
        }
    }
}

/// `[gateway] enabled = false` constructs no gateway at all (the
/// orchestrator only records a gauge), so the stronger claim is pinned
/// here: even *with* a gateway fronting the run's own batch-class
/// traffic, admission is FIFO pass-through — the wrapped service sees
/// the same submissions, in the same order, seated at the same steps,
/// so its token-stream digest is bit-identical to the bare service's
/// under every rotated seed. The negative control proves the digest is
/// *sensitive* to QoS scheduling: flipping the mid-backlog request to
/// interactive reorders admission (jumping the batch queue, preempting
/// a seated victim when slots are full), and the digest must fork.
#[test]
fn gateway_front_is_digest_identical_for_batch_traffic() {
    let seed = seed_from_env(0x6a7e_d161);
    with_seed("gateway_passthrough", seed, |seed| {
        let sim = |seed| SimService::new(2, 32, 4, 6, seed).with_digest(EventLog::new());
        let mut bare = sim(seed);
        drive_gateway_workload(&mut bare, false);
        let mut gw = Gateway::new(sim(seed), GatewayConfig::default());
        drive_gateway_workload(&mut gw, false);
        let bare_log = bare.event_log().expect("digest hook attached");
        let gw_log = gw.svc().event_log().expect("digest hook attached");
        assert_digest_eq("gateway_passthrough", seed, bare_log, &[gw_log]);

        // negative control: QoS reordering is digest-visible
        let mut qos = Gateway::new(sim(seed), GatewayConfig::default());
        drive_gateway_workload(&mut qos, true);
        assert_ne!(
            bare_log.digest(),
            qos.svc().event_log().expect("digest hook attached").digest(),
            "an interactive arrival must reorder admission visibly"
        );
    });
}
