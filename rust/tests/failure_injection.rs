//! Scenario-driven failure injection over the coordination substrates.
//!
//! Two tiers:
//!
//! * **Substrate scenarios** (always run): the supervision machinery —
//!   [`ActorPool`] + [`run_supervisor`] + [`ChaosSchedule`] — driven with
//!   synthetic actors over the real broker topics and weight bus, so the
//!   kill / restart / hot-attach / restart-budget logic is exercised even
//!   without a PJRT runtime. Plus the classic ring-buffer and
//!   backpressure cases.
//! * **Full-pipeline scenarios** (gated on `runtime_available()`): the
//!   same chaos schedules injected into a real `coordinator::run` — an
//!   actor is killed and restarted mid-run and training still completes.
//!
//! Chaos schedules are pure functions of their seed; a failing run's
//! printed seed replays the identical fault sequence.

use pipeline_rl::broker::{topic, Policy, RecvError};
use pipeline_rl::config::{ControlConfig, RunConfig};
use pipeline_rl::control::{ControlPlane, RunCommand, RunController, RunState, RUN_STATE_GAUGE};
use pipeline_rl::coordinator::supervisor::{
    run_supervisor, ActorPool, SpawnFn, SupervisorArgs,
};
use pipeline_rl::coordinator;
use pipeline_rl::data::task::{TaskGen, TaskKind};
use pipeline_rl::engine::{Engine, EngineCfg};
use pipeline_rl::metrics::MetricsHub;
use pipeline_rl::model::checkpoint::TrainState;
use pipeline_rl::model::Tokenizer;
use pipeline_rl::rl::{FinishReason, Rollout};
use pipeline_rl::runtime::Runtime;
use pipeline_rl::testkit::chaos::ChaosSchedule;
use pipeline_rl::testkit::{runtime_or_skip, with_seed};
use pipeline_rl::util::Rng;
use pipeline_rl::weights::WeightBus;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

// ---------------------------------------------------------------------
// substrate scenarios (always run)
// ---------------------------------------------------------------------

#[test]
fn ring_buffer_absorbs_slow_consumer() {
    // DropOldest topic with a fast producer and a stalled consumer: the
    // producer never blocks and the consumer sees the freshest items.
    let (tx, rx) = topic("rollouts", 8, Policy::DropOldest);
    for i in 0..100 {
        tx.send(i).unwrap();
    }
    // consumer wakes up late
    let got = rx.recv_exact(8, Duration::from_millis(200));
    assert_eq!(got, (92..100).collect::<Vec<_>>(), "freshest survive");
    assert_eq!(rx.stats().dropped, 92);
}

#[test]
fn block_topic_applies_backpressure_and_recovers() {
    let (tx, rx) = topic("batches", 2, Policy::Block);
    let producer = std::thread::spawn(move || {
        for i in 0..50 {
            tx.send(i).unwrap();
        }
        "done"
    });
    std::thread::sleep(Duration::from_millis(50));
    // producer must be blocked well below 50 items in
    assert!(rx.depth() <= 2);
    let mut got = Vec::new();
    while got.len() < 50 {
        match rx.recv(Duration::from_secs(2)) {
            Ok(x) => got.push(x),
            Err(RecvError::Closed) => break,
            Err(RecvError::Timeout) => panic!("producer stuck"),
        }
    }
    assert_eq!(producer.join().unwrap(), "done");
    assert_eq!(got, (0..50).collect::<Vec<_>>());
}

fn dummy_rollout(actor_id: usize, n: u64) -> Rollout {
    Rollout {
        seq_id: n,
        problem_id: n,
        group_id: (actor_id as u64 + 1) << 40 | n,
        actor_id,
        prompt_tokens: vec![1, 2],
        gen_tokens: vec![3],
        behavior_lp: vec![-0.5],
        token_version: vec![1],
        reward: 0.0,
        finish: FinishReason::Eos,
        t_start: 0.0,
        t_end: 0.0,
    }
}

/// Synthetic actor for supervision tests: hot-joins the bus, streams
/// dummy rollouts until halted. No PJRT runtime involved.
fn synthetic_spawn(bus: WeightBus, tx: pipeline_rl::broker::Publisher<Rollout>) -> SpawnFn {
    Arc::new(move |ctx| {
        let name = format!("actor-{}", ctx.actor_id);
        bus.init_process_group(&name);
        let mut have = 0u64;
        let mut n = 0u64;
        while !ctx.stop.load(Ordering::Relaxed) && !ctx.halt.load(Ordering::Relaxed) {
            if let Some(w) = bus.fetch_if_newer(have) {
                have = w.version;
            }
            if tx.send(dummy_rollout(ctx.actor_id, n)).is_err() {
                break;
            }
            n += 1;
            std::thread::sleep(Duration::from_millis(1));
        }
        bus.leave_process_group(&name);
        Ok(())
    })
}

#[test]
fn chaos_kill_then_restart_keeps_pipeline_alive() {
    // The canonical scenario on the real supervision machinery with
    // synthetic actors: one actor, killed at step 3, replacement added at
    // step 6, a fake trainer advancing the version clock to 10. The run
    // must keep producing rollouts throughout — no deadlock, no Closed.
    // with_seed: the replay seed reaches the output even if an assertion
    // fires before the supervisor prints its schedule banner.
    let schedule = ChaosSchedule::kill_then_restart(3, 6);
    with_seed("chaos_kill_then_restart", schedule.seed, move |_| {
        let hub = MetricsHub::new();
        let bus = WeightBus::new();
        bus.publish(1, Arc::new(vec![]));
        let (tx, rx) = topic::<Rollout>("rollouts", 64, Policy::DropOldest);
        let stop = Arc::new(AtomicBool::new(false));

        let pool = ActorPool::new(
            synthetic_spawn(bus.clone(), tx.clone()),
            stop.clone(),
            hub.clone(),
            1,     // initial
            1,     // min
            4,     // max
            2,     // respawn budget
            false, // tolerate crashes
        )
        .unwrap();
        let sup_args = SupervisorArgs {
            pool,
            bus: bus.clone(),
            rollout_tx: tx.clone(),
            schedule: Some(schedule),
            stop: stop.clone(),
            hub: hub.clone(),
            poll: Duration::from_millis(2),
            migrate: None,
            autoscale: None,
            trainer: None,
            control: None,
        };
        let sup = std::thread::spawn(move || run_supervisor(sup_args));

        // fake trainer: 20 rollouts per "optimizer step", 10 steps
        let mut consumed = 0u64;
        let mut version = 1u64;
        while version <= 10 {
            match rx.recv(Duration::from_secs(10)) {
                Ok(_) => {
                    consumed += 1;
                    if consumed % 20 == 0 {
                        version += 1;
                        bus.publish(version, Arc::new(vec![]));
                    }
                }
                Err(e) => panic!("pipeline stalled at version {version}: {e:?}"),
            }
        }
        stop.store(true, Ordering::Relaxed);
        drop(tx);
        sup.join().unwrap().unwrap();

        assert!(consumed >= 200, "rollouts flowed the whole run: {consumed}");
        assert_eq!(hub.counter("chaos_events_fired"), 2.0);
        assert!(hub.counter("actors_killed") >= 1.0, "kill event fired");
        // initial + (floor top-up after the kill) + scheduled add
        assert!(hub.counter("actors_spawned") >= 2.0);
        // every incarnation de-registered on halt
        assert!(bus.receivers().is_empty(), "left: {:?}", bus.receivers());
    });
}

#[test]
fn control_plane_pause_resume_drain_lifecycle() {
    // the operator command channel against the real supervisor with
    // synthetic actors: pause and resume flip the admission gate and
    // the run/state gauge, a second Pause while paused is a no-op, and
    // Drain quiesces the run into the Drained terminal state — the
    // supervisor winds itself down without the test raising `stop`
    let hub = MetricsHub::new();
    let bus = WeightBus::new();
    bus.publish(1, Arc::new(vec![]));
    let (tx, _rx) = topic::<Rollout>("rollouts", 64, Policy::DropOldest);
    let stop = Arc::new(AtomicBool::new(false));
    let pool = ActorPool::new(
        synthetic_spawn(bus.clone(), tx.clone()),
        stop.clone(),
        hub.clone(),
        1,
        1,
        2,
        0,
        false,
    )
    .unwrap();
    let controller = RunController::new();
    let mut ctl_cfg = ControlConfig::default();
    ctl_cfg.enabled = true;
    let plane = ControlPlane::with_controller(ctl_cfg, controller.clone());
    let sup_args = SupervisorArgs {
        pool,
        bus: bus.clone(),
        rollout_tx: tx.clone(),
        schedule: None,
        stop: stop.clone(),
        hub: hub.clone(),
        poll: Duration::from_millis(2),
        migrate: None,
        autoscale: None,
        trainer: None,
        control: Some(plane),
    };
    let sup = std::thread::spawn(move || run_supervisor(sup_args));
    let wait_for = |counter: &str| {
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while hub.counter(counter) < 1.0 {
            assert!(std::time::Instant::now() < deadline, "{counter} never fired");
            std::thread::sleep(Duration::from_millis(2));
        }
    };
    let gauge = || hub.series_last(RUN_STATE_GAUGE).expect("gauge recorded").value;

    controller.send(RunCommand::Pause);
    wait_for("control_pauses");
    assert_eq!(gauge(), RunState::Paused.gauge());
    controller.send(RunCommand::Pause); // ignored: already paused
    controller.send(RunCommand::Resume);
    wait_for("control_resumes");
    assert_eq!(gauge(), RunState::Running.gauge());
    controller.send(RunCommand::Drain);
    wait_for("control_drains");
    let out = sup.join().unwrap().expect("a drained run is a clean exit");
    assert!(out.is_none(), "no supervisor-owned trainer, no params");
    drop(tx);
    assert_eq!(hub.counter("control_pauses"), 1.0, "re-pause while paused is a no-op");
    assert_eq!(hub.counter("control_resumes"), 1.0);
    assert_eq!(gauge(), RunState::Drained.gauge());
    assert!(bus.receivers().is_empty(), "actors de-registered on the drain");
}

#[test]
fn guardrail_trip_without_restartable_trainer_fails_safe_into_drain() {
    // chaos-injected guardrail trip with no supervisor-owned trainer:
    // nothing can roll back, so the control plane must fail safe —
    // admissions close, the run drains, and the terminal run/state is
    // Drained (never a crash, never a retry loop)
    with_seed("guardrail_failsafe_drain", 0x6a4d, |_| {
        let hub = MetricsHub::new();
        let bus = WeightBus::new();
        // version clock already past the trip step: the event fires on
        // the supervisor's first chaos poll
        bus.publish(5, Arc::new(vec![]));
        let (tx, _rx) = topic::<Rollout>("rollouts", 64, Policy::DropOldest);
        let stop = Arc::new(AtomicBool::new(false));
        let pool = ActorPool::new(
            synthetic_spawn(bus.clone(), tx.clone()),
            stop.clone(),
            hub.clone(),
            1,
            1,
            2,
            0,
            false,
        )
        .unwrap();
        let mut ctl_cfg = ControlConfig::default();
        ctl_cfg.enabled = true;
        let sup_args = SupervisorArgs {
            pool,
            bus: bus.clone(),
            rollout_tx: tx.clone(),
            schedule: Some(ChaosSchedule::guardrail_trip(2)),
            stop: stop.clone(),
            hub: hub.clone(),
            poll: Duration::from_millis(2),
            migrate: None,
            autoscale: None,
            trainer: None,
            control: Some(ControlPlane::new(ctl_cfg)),
        };
        let sup = std::thread::spawn(move || run_supervisor(sup_args));
        let out = sup.join().unwrap().expect("fail-safe drain is a clean exit");
        assert!(out.is_none());
        drop(tx);
        assert_eq!(hub.counter("chaos_guardrail_trips"), 1.0);
        assert_eq!(hub.counter("guardrail_trips"), 1.0);
        assert_eq!(hub.counter("control_failsafe_drains"), 1.0);
        assert_eq!(hub.counter("control_drains"), 1.0);
        assert_eq!(
            hub.series_last(RUN_STATE_GAUGE).unwrap().value,
            RunState::Drained.gauge(),
            "an unrecoverable trip must end the run as Drained"
        );
    });
}

#[test]
fn crash_restart_budget_is_enforced() {
    // Actors of generation < 2 crash instantly; the pool must restart
    // them through the budget and keep exactly one live actor.
    let hub = MetricsHub::new();
    let stop = Arc::new(AtomicBool::new(false));
    let spawn: SpawnFn = Arc::new(|ctx| {
        if ctx.generation < 2 {
            anyhow::bail!("injected crash (generation {})", ctx.generation);
        }
        while !ctx.stop.load(Ordering::Relaxed) && !ctx.halt.load(Ordering::Relaxed) {
            std::thread::sleep(Duration::from_millis(1));
        }
        Ok(())
    });
    let mut pool = ActorPool::new(spawn, stop.clone(), hub.clone(), 1, 1, 2, 10, false).unwrap();
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    while hub.counter("actor_restarts") < 2.0 {
        assert!(std::time::Instant::now() < deadline, "restarts never happened");
        pool.reap().unwrap();
        std::thread::sleep(Duration::from_millis(2));
    }
    // generation-2 incarnation stays alive
    std::thread::sleep(Duration::from_millis(20));
    pool.reap().unwrap();
    assert_eq!(pool.len(), 1);
    assert_eq!(hub.counter("actor_crashes"), 2.0);
    stop.store(true, Ordering::Relaxed);
    pool.shutdown().unwrap();
}

#[test]
fn pool_resize_respects_bounds() {
    let hub = MetricsHub::new();
    let stop = Arc::new(AtomicBool::new(false));
    let spawn: SpawnFn = Arc::new(|ctx| {
        while !ctx.stop.load(Ordering::Relaxed) && !ctx.halt.load(Ordering::Relaxed) {
            std::thread::sleep(Duration::from_millis(1));
        }
        Ok(())
    });
    let mut pool = ActorPool::new(spawn, stop.clone(), hub.clone(), 2, 1, 3, 0, false).unwrap();
    assert_eq!(pool.len(), 2);
    assert_eq!(pool.add_actor().unwrap(), Some(2));
    assert_eq!(pool.add_actor().unwrap(), None, "ceiling enforced");
    assert_eq!(pool.lowest_live(), Some(0));
    assert_eq!(pool.highest_live(), Some(2));
    assert!(pool.kill_actor(1));
    assert!(!pool.kill_actor(1), "already gone");
    assert_eq!(pool.len(), 2);
    assert!(pool.restart_actor(0).unwrap());
    assert_eq!(pool.len(), 2);
    stop.store(true, Ordering::Relaxed);
    pool.shutdown().unwrap();
}

// ---------------------------------------------------------------------
// full-pipeline scenarios (need PJRT runtime + AOT artifacts)
// ---------------------------------------------------------------------

fn small_pipeline_cfg() -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.variant = "tiny".into();
    cfg.sft_steps = 8;
    cfg.rl_steps = 5;
    cfg.group_size = 2;
    cfg.max_new_tokens = 16;
    cfg.task.kinds = vec![TaskKind::Copy];
    cfg.task.max_operand = 9;
    cfg.log_every = 0;
    cfg
}

#[test]
fn scenario_checkpoint_stall_does_not_deadlock_pipeline() {
    if !runtime_or_skip("scenario_checkpoint_stall") {
        return;
    }
    // per-step checkpointing (slow trainer) with a tiny rollout ring:
    // actors keep generating, stale rollouts fall off the ring, training
    // still completes all steps.
    let dir = std::env::temp_dir().join("prl_stall_ckpts");
    std::fs::remove_dir_all(&dir).ok();
    let mut cfg = small_pipeline_cfg();
    cfg.rollout_queue = 8; // tiny ring
    cfg.checkpoint.every = 1; // stall every step
    cfg.checkpoint.dir = Some(dir.to_string_lossy().to_string());
    let summary = coordinator::run(cfg, None).expect("run must complete");
    assert_eq!(summary.report.series("train/loss").unwrap().points.len(), 5);
    // async writer: every per-step state was submitted; written +
    // superseded (latest-wins) accounts for all of them and the final
    // state always lands
    assert_eq!(summary.report.counters["checkpoints_submitted"], 5.0);
    let c = |k: &str| summary.report.counters.get(k).copied().unwrap_or(0.0);
    assert_eq!(c("checkpoints_written") + c("checkpoints_superseded"), 5.0);
    assert!(c("checkpoints_written") >= 1.0);
    // full TrainStates + manifest landed on disk
    let latest = TrainState::load_latest(&dir).expect("manifest resolves");
    assert_eq!(latest.step, 5);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn scenario_multi_actor_pipeline_interleaves() {
    if !runtime_or_skip("scenario_multi_actor_pipeline") {
        return;
    }
    let mut cfg = small_pipeline_cfg();
    cfg.rl_steps = 4;
    cfg.n_actors = 2;
    let summary = coordinator::run(cfg, None).expect("multi-actor run");
    assert_eq!(summary.report.series("train/loss").unwrap().points.len(), 4);
    // both actors produced sequences
    assert!(summary.report.counters["gen_seqs_finished"] > 0.0);
    assert!(
        summary
            .report
            .counters
            .get("weight_updates_received")
            .copied()
            .unwrap_or(0.0)
            >= 2.0,
        "both engines should receive in-flight updates"
    );
}

#[test]
fn scenario_kill_and_restart_actor_mid_run() {
    if !runtime_or_skip("scenario_kill_and_restart_actor_mid_run") {
        return;
    }
    // the acceptance scenario: an actor dies mid-run, a replacement
    // hot-joins, and training still completes every optimizer step.
    let mut cfg = small_pipeline_cfg();
    cfg.rl_steps = 6;
    cfg.n_actors = 2;
    cfg.elastic.enabled = true;
    cfg.elastic.min_actors = 1;
    cfg.elastic.max_actors = 4;
    let schedule = ChaosSchedule::kill_then_restart(2, 4);
    let summary =
        coordinator::run_with_chaos(cfg, None, Some(schedule)).expect("chaos run completes");
    assert_eq!(
        summary.report.series("train/loss").unwrap().points.len(),
        6,
        "all optimizer steps ran despite the kill"
    );
    assert!(summary.report.counters["samples_trained"] > 0.0);
    assert!(summary.report.counters["chaos_events_fired"] >= 1.0);
    assert!(summary.report.counters["actors_killed"] >= 1.0);
}

#[test]
fn scenario_seeded_schedule_runs_to_completion() {
    if !runtime_or_skip("scenario_seeded_schedule") {
        return;
    }
    // a generated (seed-derived) schedule with mixed fault kinds. The
    // with_seed wrapper (not just the supervisor's banner, which only
    // prints once a supervisor is running) guarantees the replay seed
    // reaches the failure output from every path.
    with_seed("scenario_seeded_schedule", 0xdead_beef, |seed| {
        let mut cfg = small_pipeline_cfg();
        cfg.rl_steps = 6;
        cfg.n_actors = 2;
        cfg.elastic.enabled = true;
        let schedule = ChaosSchedule::generate(seed, 6, 4);
        let summary =
            coordinator::run_with_chaos(cfg, None, Some(schedule)).expect("seeded chaos run");
        assert_eq!(summary.report.series("train/loss").unwrap().points.len(), 6);
        assert!(summary.report.counters["samples_trained"] > 0.0);
    });
}

#[test]
fn kv_starvation_stalls_then_recovers() {
    if !runtime_or_skip("kv_starvation") {
        return;
    }
    let mut rt = Runtime::new().unwrap();
    let params = rt.init_params("tiny", 1).unwrap();
    // over-committed pool: 5 blocks of 8 = 40 token cells for 4 slots
    // wanting ~22 tokens each. Two sequences run, the third stalls on its
    // final block until the first releases; admission queues the rest.
    // (This is the legacy stall-in-place baseline — `[kv] preempt_policy
    // = "none"`, the default; tests/kvmem.rs covers the preempting path.
    // Same liveness guarantee as long as one sequence can always finish,
    // which max_new=12 ensures.)
    let mut cfg = EngineCfg::new("tiny");
    cfg.max_new_tokens = 12;
    cfg.block_size = 8;
    cfg.kv_blocks = Some(5);
    let mut eng = Engine::new(&mut rt, cfg, &params, 0, Rng::new(1)).unwrap();
    eng.set_weights(1, &params).unwrap();
    let gen = TaskGen::curriculum_small();
    let tk = Tokenizer::new();
    for i in 0..4 {
        let p = gen.problem(i as u64);
        let toks = tk.encode(&p.prompt).unwrap();
        eng.add_request(p, toks, i as u64);
    }
    let mut finished = 0;
    for _ in 0..3000 {
        finished += eng.step().unwrap().finished.len();
        if finished >= 4 {
            break;
        }
    }
    assert!(finished >= 4, "all sequences finish despite block pressure");
    assert!(eng.stats.stall_steps > 0, "starvation must have caused stalls");
}
