//! Failure injection and stress tests over the coordination substrates.
//!
//! * trainer checkpoint stall — the rollout ring buffer must absorb the
//!   pause by evicting the stalest samples (the paper's stated purpose of
//!   the ring buffers) and the run must still complete;
//! * slow-consumer backpressure on a Block topic;
//! * multi-actor pipeline run — rollouts from several engines interleave
//!   into coherent batches;
//! * KV-block starvation — an over-committed engine stalls sequences
//!   instead of corrupting state, and recovers.

use pipeline_rl::broker::{topic, Policy, RecvError};
use pipeline_rl::config::RunConfig;
use pipeline_rl::coordinator;
use pipeline_rl::data::task::{TaskGen, TaskKind};
use pipeline_rl::engine::{Engine, EngineCfg};
use pipeline_rl::model::Tokenizer;
use pipeline_rl::runtime::Runtime;
use pipeline_rl::util::Rng;
use std::time::Duration;

#[test]
fn ring_buffer_absorbs_slow_consumer() {
    // DropOldest topic with a fast producer and a stalled consumer: the
    // producer never blocks and the consumer sees the freshest items.
    let (tx, rx) = topic("rollouts", 8, Policy::DropOldest);
    for i in 0..100 {
        tx.send(i).unwrap();
    }
    // consumer wakes up late
    let got = rx.recv_exact(8, Duration::from_millis(200));
    assert_eq!(got, (92..100).collect::<Vec<_>>(), "freshest survive");
    assert_eq!(rx.stats().dropped, 92);
}

#[test]
fn block_topic_applies_backpressure_and_recovers() {
    let (tx, rx) = topic("batches", 2, Policy::Block);
    let producer = std::thread::spawn(move || {
        for i in 0..50 {
            tx.send(i).unwrap();
        }
        "done"
    });
    std::thread::sleep(Duration::from_millis(50));
    // producer must be blocked well below 50 items in
    assert!(rx.depth() <= 2);
    let mut got = Vec::new();
    while got.len() < 50 {
        match rx.recv(Duration::from_secs(2)) {
            Ok(x) => got.push(x),
            Err(RecvError::Closed) => break,
            Err(RecvError::Timeout) => panic!("producer stuck"),
        }
    }
    assert_eq!(producer.join().unwrap(), "done");
    assert_eq!(got, (0..50).collect::<Vec<_>>());
}

#[test]
fn checkpoint_stall_does_not_deadlock_pipeline() {
    // per-step checkpointing (slow trainer) with a tiny rollout ring:
    // actors keep generating, stale rollouts fall off the ring, training
    // still completes all steps.
    let dir = std::env::temp_dir().join("prl_stall_ckpts");
    std::fs::create_dir_all(&dir).unwrap();
    let mut cfg = RunConfig::default();
    cfg.variant = "tiny".into();
    cfg.sft_steps = 8;
    cfg.rl_steps = 5;
    cfg.group_size = 2;
    cfg.max_new_tokens = 16;
    cfg.task.kinds = vec![TaskKind::Copy];
    cfg.task.max_operand = 9;
    cfg.rollout_queue = 8; // tiny ring
    cfg.checkpoint_every = 1; // stall every step
    cfg.checkpoint_dir = Some(dir.to_string_lossy().to_string());
    cfg.log_every = 0;
    let summary = coordinator::run(cfg, None).expect("run must complete");
    assert_eq!(
        summary.report.series("train/loss").unwrap().points.len(),
        5
    );
    assert_eq!(summary.report.counters["checkpoints_written"], 5.0);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn multi_actor_pipeline_interleaves() {
    let mut cfg = RunConfig::default();
    cfg.variant = "tiny".into();
    cfg.sft_steps = 8;
    cfg.rl_steps = 4;
    cfg.n_actors = 2;
    cfg.group_size = 2;
    cfg.max_new_tokens = 16;
    cfg.task.kinds = vec![TaskKind::Copy];
    cfg.task.max_operand = 9;
    cfg.log_every = 0;
    let summary = coordinator::run(cfg, None).expect("multi-actor run");
    assert_eq!(summary.report.series("train/loss").unwrap().points.len(), 4);
    // both actors produced sequences
    assert!(summary.report.counters["gen_seqs_finished"] > 0.0);
    assert!(
        summary
            .report
            .counters
            .get("weight_updates_received")
            .copied()
            .unwrap_or(0.0)
            >= 2.0,
        "both engines should receive in-flight updates"
    );
}

#[test]
fn kv_starvation_stalls_then_recovers() {
    let mut rt = Runtime::new().unwrap();
    let params = rt.init_params("tiny", 1).unwrap();
    // over-committed pool: 5 blocks of 8 = 40 token cells for 4 slots
    // wanting ~22 tokens each. Two sequences run, the third stalls on its
    // final block until the first releases; admission queues the rest.
    // (vLLM would preempt; our engine stalls — same liveness guarantee as
    // long as one sequence can always finish, which max_new=12 ensures.)
    let mut cfg = EngineCfg::new("tiny");
    cfg.max_new_tokens = 12;
    cfg.block_size = 8;
    cfg.kv_blocks = Some(5);
    let mut eng = Engine::new(&mut rt, cfg, &params, 0, Rng::new(1)).unwrap();
    eng.set_weights(1, &params).unwrap();
    let gen = TaskGen::curriculum_small();
    let tk = Tokenizer::new();
    for i in 0..4 {
        let p = gen.problem(i as u64);
        let toks = tk.encode(&p.prompt).unwrap();
        eng.add_request(p, toks, i as u64);
    }
    let mut finished = 0;
    for _ in 0..3000 {
        finished += eng.step().unwrap().finished.len();
        if finished >= 4 {
            break;
        }
    }
    assert!(finished >= 4, "all sequences finish despite block pressure");
    assert!(eng.stats.stall_steps > 0, "starvation must have caused stalls");
}
