//! `pipeline-rl` — the command-line launcher.
//!
//! ```text
//! pipeline-rl train   [--config configs/pipeline_small.toml] [key=value ...]
//! pipeline-rl eval    --checkpoint path.ckpt [--n 100]
//! pipeline-rl sim     [--mode pipeline|conv] [--n 128] [--steps 100]
//! pipeline-rl pareto  [--n 128 --b 128]
//! pipeline-rl info
//! ```
//!
//! `train` runs the full coordinator from a TOML config with CLI
//! overrides and writes the metric series to --out (default runs/).

use anyhow::{bail, Result};
use pipeline_rl::config::RunConfig;
use pipeline_rl::coordinator::{self, eval};
use pipeline_rl::model::checkpoint::load_params_any;
use pipeline_rl::perfmodel::{search, throughput::Workload};
use pipeline_rl::runtime::Runtime;
use pipeline_rl::simcluster::{SimCfg, Simulator};
use pipeline_rl::util::cli::Args;
use pipeline_rl::util::logging::{self, Level};

fn main() -> Result<()> {
    logging::set_level(Level::Info);
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print_help();
        return Ok(());
    }
    let cmd = argv.remove(0);
    let args = Args::parse(argv.clone());
    match cmd.as_str() {
        "train" => train(&args, &argv),
        "eval" => evaluate(&args),
        "sim" => sim(&args),
        "pareto" => pareto(&args),
        "info" => info(),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command {other:?} — try `pipeline-rl help`"),
    }
}

fn train(args: &Args, argv: &[String]) -> Result<()> {
    let overrides: Vec<String> = argv
        .iter()
        .filter(|a| !a.starts_with("--") && a.contains('='))
        .cloned()
        .collect();
    let cfg = match args.flags.get("config") {
        Some(path) => RunConfig::from_file(std::path::Path::new(path), &overrides)?,
        None => {
            let mut doc = pipeline_rl::config::TomlDoc::default();
            doc.apply_overrides(&overrides)?;
            RunConfig::from_doc(&doc)?
        }
    };
    let out = args.str_or("out", "runs");
    println!(
        "training: variant={} mode={} steps={} actors={}",
        cfg.variant,
        cfg.mode.name(),
        cfg.rl_steps,
        cfg.n_actors
    );
    let summary = coordinator::run(cfg.clone(), None)?;
    let path = std::path::Path::new(&out)
        .join(format!("{}_{}.json", cfg.variant, cfg.mode.name()));
    summary.report.save_json(&path)?;
    println!("metrics written to {}", path.display());

    let mut rt = Runtime::new()?;
    let rep = eval::evaluate(&mut rt, &cfg, &summary.final_params, 60)?;
    println!(
        "held-out success: {:.1}%  (wall {:.1}s, samples {})",
        100.0 * rep.success_rate(),
        summary.wall_seconds,
        summary.report.counters.get("samples_trained").copied().unwrap_or(0.0),
    );
    if let Some(dir) = &cfg.checkpoint.dir {
        println!("checkpoints in {dir}");
    }
    Ok(())
}

fn evaluate(args: &Args) -> Result<()> {
    let path = args.require("checkpoint")?;
    let n = args.usize_or("n", 100)?;
    let (variant, step, params) = load_params_any(std::path::Path::new(path))?;
    let mut cfg = RunConfig::default();
    cfg.variant = variant;
    cfg.max_new_tokens = args.usize_or("max-new", 48)?;
    let mut rt = Runtime::new()?;
    let rep = eval::evaluate(&mut rt, &cfg, &params, n)?;
    println!(
        "checkpoint step {step}: success {:.1}% over {} problems",
        100.0 * rep.success_rate(),
        rep.n
    );
    for (k, (c, tot)) in rep.by_kind {
        println!("  {k:<8} {c}/{tot}");
    }
    Ok(())
}

fn sim(args: &Args) -> Result<()> {
    let n = args.usize_or("n", 128)?;
    let b = args.usize_or("b", 128)?;
    let l = args.usize_or("l", 512)?;
    let steps = args.usize_or("steps", 64)?;
    let mode = args.str_or("mode", "pipeline");
    let mut cfg = if mode == "pipeline" {
        let i = args.usize_or("i", n / 3)?;
        let h = args.usize_or("h", 192)?;
        SimCfg::pipeline(n, i, h, b, l)
    } else {
        let g = args.usize_or("g", 32)?;
        SimCfg::conventional(n, g, args.usize_or("h", 64)?, b, l)
    };
    cfg.rl_steps = steps;
    let r = Simulator::new(cfg).run();
    println!("mode {mode}: {steps} optimizer steps");
    println!("  wall time      : {:.0} flashes", r.t_end);
    println!("  tokens         : {:.0}", r.tokens);
    println!("  throughput     : {:.2} tokens/flash", r.throughput);
    println!(
        "  max lag        : {:.0} steps",
        r.max_lag.values().iter().cloned().fold(0.0, f64::max)
    );
    println!(
        "  lag by rel.pos : {:?}",
        r.lag_by_relpos
            .iter()
            .map(|x| (x * 10.0).round() / 10.0)
            .collect::<Vec<_>>()
    );
    Ok(())
}

fn pareto(args: &Args) -> Result<()> {
    let mut w = Workload::paper_a4();
    w.n = args.usize_or("n", 128)?;
    w.b = args.usize_or("b", 128)?;
    let cs = search::case_study(&w);
    println!(
        "best same-lag speedup: {:.2}x at g_max {} (pipeline H={} I={})",
        cs.speedup, cs.pipe.lag_steps, cs.pipe.h, cs.pipe.i
    );
    println!("run `cargo run --release --example pareto` for the full tables");
    Ok(())
}

fn info() -> Result<()> {
    let rt = Runtime::new()?;
    println!("PJRT platform : cpu");
    println!(
        "artifacts     : {}",
        pipeline_rl::runtime::artifacts_dir().display()
    );
    println!("variants:");
    for (name, v) in &rt.manifest.variants {
        println!(
            "  {name:<6} d={} L={} heads={} max_seq={} gen_batch={} train=[{}x{}] params={:.2}M graphs={}",
            v.d_model,
            v.n_layers,
            v.n_heads,
            v.max_seq,
            v.gen_batch,
            v.train_batch,
            v.seq_len,
            v.n_params as f64 / 1e6,
            v.artifacts.len()
        );
    }
    Ok(())
}

fn print_help() {
    println!(
        "pipeline-rl — PipelineRL reproduction (rust + JAX/Pallas AOT)\n\n\
         commands:\n\
         \x20 train   [--config FILE] [section.key=value ...] [--out DIR]\n\
         \x20 eval    --checkpoint FILE [--n N]\n\
         \x20 sim     [--mode pipeline|conv] [--n GPUS] [--steps N]\n\
         \x20 pareto  [--n GPUS] [--b BATCH]\n\
         \x20 info\n"
    );
}
