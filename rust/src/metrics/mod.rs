//! Metrics: thread-safe time-series recording shared by every pipeline
//! stage, plus JSON/CSV export for the figure harnesses.
//!
//! Every point is (wall_clock_seconds, x, value) where x is the natural
//! x-axis of the series (optimizer step, sample count, batch index...).
//! The figure benches slice these series exactly the way the paper's
//! plots do: reward-vs-time (Fig 5a), reward-vs-samples (Fig 5b),
//! samples-vs-time (Fig 5c), max-lag and ESS vs step (Fig 6).
//!
//! Per-series retention is bounded (ring-buffer semantics): once a
//! series exceeds its retention cap the oldest points are dropped in
//! amortized-O(1) chunks, so a multi-hour production run cannot grow the
//! hub without limit. The default cap (65536 points) is far above
//! anything the figure harnesses record; control-plane deployments can
//! tighten it via [`MetricsHub::with_retention`]. Sliding-window
//! consumers (the `control::Guardrail` health checks) read the newest
//! `n` points through [`MetricsHub::series_window`] without cloning the
//! whole history.

use crate::util::Json;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Point {
    pub t: f64,
    pub x: f64,
    pub value: f64,
}

#[derive(Debug, Clone, Default)]
pub struct Series {
    pub points: Vec<Point>,
}

impl Series {
    /// Append a point under the default ring-buffer retention
    /// ([`DEFAULT_RETENTION`]). This used to push unbounded, which let
    /// every direct caller (standalone harness series built outside a
    /// [`MetricsHub`]) bypass the retention the hub enforces — an
    /// open-loop producer like the serving gateway, pushing one point per
    /// admission tick for hours, would grow the series without limit.
    /// Custom caps (including the audited-unbounded `cap == 0`) are a hub
    /// policy, set via [`MetricsHub::with_retention`]; the raw series API
    /// deliberately no longer exposes one.
    pub fn push(&mut self, t: f64, x: f64, value: f64) {
        self.push_bounded(t, x, value, DEFAULT_RETENTION);
    }

    /// Push with ring-buffer retention: once the series holds `2 * cap`
    /// points everything but the newest `cap` is dropped in one drain —
    /// amortized O(1) per push, memory bounded by `2 * cap`, and the
    /// newest `cap` points are always intact (`cap == 0` disables the
    /// bound — reachable only through [`MetricsHub::with_retention`],
    /// never from this type's public surface).
    fn push_bounded(&mut self, t: f64, x: f64, value: f64, cap: usize) {
        self.points.push(Point { t, x, value });
        if cap > 0 && self.points.len() >= cap * 2 {
            let excess = self.points.len() - cap;
            self.points.drain(..excess);
        }
    }

    pub fn last(&self) -> Option<&Point> {
        self.points.last()
    }

    pub fn values(&self) -> Vec<f64> {
        self.points.iter().map(|p| p.value).collect()
    }

    /// Moving average of the last `window` values.
    pub fn tail_mean(&self, window: usize) -> f64 {
        if self.points.is_empty() {
            return f64::NAN;
        }
        let n = self.points.len().min(window);
        self.points[self.points.len() - n..]
            .iter()
            .map(|p| p.value)
            .sum::<f64>()
            / n as f64
    }

    /// First time the smoothed value crosses `threshold` (for
    /// "time-to-reward" comparisons, Fig 5a). Returns (t, x).
    pub fn first_crossing(&self, threshold: f64, window: usize) -> Option<(f64, f64)> {
        if self.points.is_empty() {
            return None;
        }
        let mut acc = 0.0;
        let mut buf = std::collections::VecDeque::new();
        for p in &self.points {
            buf.push_back(p.value);
            acc += p.value;
            if buf.len() > window {
                acc -= buf.pop_front().unwrap();
            }
            if acc / buf.len() as f64 >= threshold {
                return Some((p.t, p.x));
            }
        }
        None
    }
}

/// Default per-series retention (points). Generous: the figure benches
/// and every existing harness stay far below it, so only genuinely
/// unbounded producers (multi-hour runs) ever hit the ring.
pub const DEFAULT_RETENTION: usize = 65536;

#[derive(Debug)]
struct HubInner {
    series: BTreeMap<String, Series>,
    counters: BTreeMap<String, f64>,
    /// per-series point cap (ring-buffer retention; 0 = unbounded)
    retention: usize,
}

impl Default for HubInner {
    fn default() -> Self {
        HubInner {
            series: BTreeMap::new(),
            counters: BTreeMap::new(),
            retention: DEFAULT_RETENTION,
        }
    }
}

/// Clone-able, thread-safe metrics sink.
#[derive(Debug, Clone, Default)]
pub struct MetricsHub {
    inner: Arc<Mutex<HubInner>>,
}

impl MetricsHub {
    pub fn new() -> Self {
        Self::default()
    }

    /// A hub with a custom per-series retention cap (`0` = unbounded —
    /// the pre-bounded behavior, for harnesses that audit full history).
    pub fn with_retention(cap: usize) -> Self {
        let hub = Self::default();
        hub.inner.lock().unwrap().retention = cap;
        hub
    }

    pub fn record(&self, series: &str, t: f64, x: f64, value: f64) {
        let mut g = self.inner.lock().unwrap();
        let cap = g.retention;
        g.series
            .entry(series.to_string())
            .or_default()
            .push_bounded(t, x, value, cap);
    }

    pub fn add(&self, counter: &str, delta: f64) {
        let mut g = self.inner.lock().unwrap();
        *g.counters.entry(counter.to_string()).or_insert(0.0) += delta;
    }

    /// Gauge semantics: overwrite a counter with the current value (pool
    /// size, queue depth — things that go up *and* down).
    pub fn set(&self, counter: &str, value: f64) {
        let mut g = self.inner.lock().unwrap();
        g.counters.insert(counter.to_string(), value);
    }

    pub fn counter(&self, name: &str) -> f64 {
        self.inner.lock().unwrap().counters.get(name).copied().unwrap_or(0.0)
    }

    /// Latest point of a series without cloning its history — O(1) under
    /// the lock, safe for high-cadence pollers (the autoscaler reads the
    /// trainer's lag/fill series through this every evaluation).
    pub fn series_last(&self, name: &str) -> Option<Point> {
        self.inner
            .lock()
            .unwrap()
            .series
            .get(name)
            .and_then(|s| s.points.last().copied())
    }

    /// The newest `n` points of a series, oldest-first — the guardrail's
    /// sliding-window view. Clones only the window, not the history, and
    /// returns fewer (possibly zero) points when the series is shorter.
    pub fn series_window(&self, name: &str, n: usize) -> Vec<Point> {
        let g = self.inner.lock().unwrap();
        match g.series.get(name) {
            Some(s) => {
                let len = s.points.len();
                s.points[len.saturating_sub(n)..].to_vec()
            }
            None => Vec::new(),
        }
    }

    pub fn series(&self, name: &str) -> Series {
        self.inner
            .lock()
            .unwrap()
            .series
            .get(name)
            .cloned()
            .unwrap_or_default()
    }

    pub fn series_names(&self) -> Vec<String> {
        self.inner.lock().unwrap().series.keys().cloned().collect()
    }

    pub fn snapshot(&self) -> RunReport {
        let g = self.inner.lock().unwrap();
        RunReport {
            series: g.series.clone(),
            counters: g.counters.clone(),
        }
    }
}

/// Immutable result of a run: all series + counters.
#[derive(Debug, Clone, Default)]
pub struct RunReport {
    pub series: BTreeMap<String, Series>,
    pub counters: BTreeMap<String, f64>,
}

impl RunReport {
    pub fn series(&self, name: &str) -> Option<&Series> {
        self.series.get(name)
    }

    pub fn to_json(&self) -> Json {
        let series = self
            .series
            .iter()
            .map(|(k, s)| {
                (
                    k.clone(),
                    Json::Obj(vec![
                        ("t".into(), Json::arr_f64(&s.points.iter().map(|p| p.t).collect::<Vec<_>>())),
                        ("x".into(), Json::arr_f64(&s.points.iter().map(|p| p.x).collect::<Vec<_>>())),
                        ("v".into(), Json::arr_f64(&s.points.iter().map(|p| p.value).collect::<Vec<_>>())),
                    ]),
                )
            })
            .collect();
        let counters = self
            .counters
            .iter()
            .map(|(k, v)| (k.clone(), Json::Num(*v)))
            .collect();
        Json::Obj(vec![
            ("series".into(), Json::Obj(series)),
            ("counters".into(), Json::Obj(counters)),
        ])
    }

    pub fn save_json(&self, path: &std::path::Path) -> anyhow::Result<()> {
        if let Some(p) = path.parent() {
            std::fs::create_dir_all(p)?;
        }
        std::fs::write(path, self.to_json().to_string_compact())?;
        Ok(())
    }

    /// CSV with columns t,x,value for one series.
    pub fn series_csv(&self, name: &str) -> String {
        let mut out = String::from("t,x,value\n");
        if let Some(s) = self.series.get(name) {
            for p in &s.points {
                out.push_str(&format!("{},{},{}\n", p.t, p.x, p.value));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_snapshot() {
        let hub = MetricsHub::new();
        hub.record("reward", 0.1, 1.0, 0.2);
        hub.record("reward", 0.2, 2.0, 0.4);
        hub.add("samples", 8.0);
        hub.add("samples", 8.0);
        let rep = hub.snapshot();
        assert_eq!(rep.series("reward").unwrap().points.len(), 2);
        assert_eq!(rep.counters["samples"], 16.0);
    }

    #[test]
    fn gauge_set_overwrites_and_series_last_is_latest() {
        let hub = MetricsHub::new();
        hub.set("pool_size", 3.0);
        hub.set("pool_size", 2.0);
        assert_eq!(hub.counter("pool_size"), 2.0, "set overwrites, not adds");
        assert!(hub.series_last("nope").is_none());
        hub.record("lag", 0.1, 1.0, 5.0);
        hub.record("lag", 0.2, 2.0, 7.0);
        let p = hub.series_last("lag").unwrap();
        assert_eq!((p.x, p.value), (2.0, 7.0));
    }

    #[test]
    fn tail_mean_and_crossing() {
        let mut s = Series::default();
        for i in 0..10 {
            s.push(i as f64, i as f64, i as f64 * 0.1);
        }
        assert!((s.tail_mean(2) - 0.85).abs() < 1e-12);
        let (t, _x) = s.first_crossing(0.5, 1).unwrap();
        assert_eq!(t, 5.0);
        assert!(s.first_crossing(2.0, 1).is_none());
    }

    #[test]
    fn concurrent_recording() {
        let hub = MetricsHub::new();
        let mut handles = Vec::new();
        for th in 0..4 {
            let hub = hub.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..100 {
                    hub.record("s", th as f64, i as f64, 1.0);
                    hub.add("c", 1.0);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(hub.series("s").points.len(), 400);
        assert_eq!(hub.counter("c"), 400.0);
    }

    #[test]
    fn series_window_returns_newest_points_oldest_first() {
        let hub = MetricsHub::new();
        assert!(hub.series_window("missing", 4).is_empty());
        for i in 0..10 {
            hub.record("w", i as f64, i as f64, i as f64 * 2.0);
        }
        let win = hub.series_window("w", 3);
        assert_eq!(win.len(), 3);
        assert_eq!(
            win.iter().map(|p| p.value).collect::<Vec<_>>(),
            vec![14.0, 16.0, 18.0],
            "the newest 3, oldest-first"
        );
        // asking for more than exists returns what's there
        assert_eq!(hub.series_window("w", 100).len(), 10);
    }

    #[test]
    fn retention_bounds_series_and_keeps_the_newest() {
        let hub = MetricsHub::with_retention(8);
        for i in 0..1000 {
            hub.record("r", i as f64, i as f64, i as f64);
        }
        let s = hub.series("r");
        assert!(
            s.points.len() < 16,
            "ring retention must bound the series below 2*cap, got {}",
            s.points.len()
        );
        // the newest cap points survive intact and in order
        let win = hub.series_window("r", 8);
        assert_eq!(
            win.iter().map(|p| p.value).collect::<Vec<_>>(),
            (992..1000).map(|v| v as f64).collect::<Vec<_>>()
        );
        assert_eq!(hub.series_last("r").unwrap().value, 999.0);
        // retention 0 = unbounded (audit harnesses)
        let unbounded = MetricsHub::with_retention(0);
        for i in 0..1000 {
            unbounded.record("r", i as f64, i as f64, i as f64);
        }
        assert_eq!(unbounded.series("r").points.len(), 1000);
    }

    #[test]
    fn raw_series_push_is_retention_bounded() {
        // regression: `Series::push` was public *and* unbounded, so any
        // direct caller leaked past the hub's ring retention. It now
        // applies DEFAULT_RETENTION itself.
        let mut s = Series::default();
        let n = DEFAULT_RETENTION * 2 + 10;
        for i in 0..n {
            s.push(i as f64, i as f64, i as f64);
        }
        assert!(
            s.points.len() < DEFAULT_RETENTION * 2,
            "direct pushes must stay under 2*DEFAULT_RETENTION, got {}",
            s.points.len()
        );
        // the newest points survive intact, in order
        assert_eq!(s.last().unwrap().value, (n - 1) as f64);
        let tail: Vec<f64> =
            s.points[s.points.len() - 4..].iter().map(|p| p.value).collect();
        assert_eq!(
            tail,
            ((n - 4)..n).map(|v| v as f64).collect::<Vec<_>>()
        );
    }

    #[test]
    fn json_roundtrip_shape() {
        let hub = MetricsHub::new();
        hub.record("a", 1.0, 2.0, 3.0);
        let j = hub.snapshot().to_json();
        let parsed = Json::parse(&j.to_string_compact()).unwrap();
        let v = parsed
            .req("series").unwrap()
            .req("a").unwrap()
            .req("v").unwrap()
            .as_arr().unwrap();
        assert_eq!(v.len(), 1);
    }

    #[test]
    fn csv_export() {
        let hub = MetricsHub::new();
        hub.record("x", 0.5, 1.0, 2.0);
        let csv = hub.snapshot().series_csv("x");
        assert_eq!(csv, "t,x,value\n0.5,1,2\n");
    }
}
