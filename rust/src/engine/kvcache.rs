//! Paged KV-cache allocator with refcounted copy-on-write prefix sharing
//! (vLLM's PagedAttention bookkeeping, upgraded from per-sequence block
//! tables to a shared-block economy).
//!
//! The cache is divided into fixed-size blocks, a sequence references
//! ceil(len / block_size) blocks, and a request is admitted only when a
//! slot *and* enough blocks are available. Under the default dense device
//! layout (`[kv] layout = "dense"`) every slot physically owns a full
//! `max_seq` cache plane and this allocator is purely the
//! *admission-capacity model* layered on top; under the paged layout the
//! same tables are **real device addresses**: the engine exports them
//! with [`BlockAllocator::fill_table`] into the `decode_paged` graph's
//! per-row block-table operand, so the block ids here index the device
//! pool `[n_blocks, L, 2, block_size, H, hd]` directly and a freed or
//! shared block is freed/shared in HBM, not just in the books. With an
//! over-committed pool (`[kv] overcommit`) admission and growth throttle
//! exactly like a full HBM — which is what lets one actor run far more
//! concurrent long rollouts per GPU than the worst case would allow
//! (paper §4: KV memory is the binding resource at saturation).
//!
//! **Prefix sharing.** The G members of a GRPO group decode the same
//! prompt — the dominant KV cost for long prompts. The first member
//! admitted under a share key (the group id) registers its prompt blocks
//! as the key's shared prefix; every later fresh member admitted under
//! the same key *references the same physical blocks* (refcount G, held
//! once) instead of allocating its own copy. This is vLLM's
//! fork-on-sampling layout: only the divergent suffix costs memory.
//!
//! **Copy-on-write.** Shared blocks are read-only past the shared prefix
//! length: prefill and replay re-write prompt positions with identical
//! content (allowed — each slot's dense plane holds its own copy of the
//! identical prompt K/V), but a sequence's first *divergent* write (its
//! first sampled token landing in the partial last prompt block) forks
//! that block — a fresh block replaces the shared one in the writer's
//! table, the shared refcount drops by one, and divergent sequences never
//! alias a shared block again (property-tested below).
//!
//! **Preemption.** Growth returning `false` is the block-pressure signal;
//! the engine forwards it to the scheduler's victim hook
//! ([`crate::sched::Scheduler::pick_victim`]) instead of just stalling
//! the slot — the vLLM preempt/swap analogue, with
//! [`crate::sched::SeqSnapshot`] as the swap space.
//!
//! Invariants (property-tested): refcount conservation — every physical
//! block is either on the free list (refcount 0) or held (refcount ≥ 1),
//! free + held == total, and Σ table references == Σ refcounts; no
//! double-free; fork-on-write never leaves a shared block aliased across
//! divergent sequences.

use anyhow::{bail, Result};
use std::collections::{HashMap, HashSet};

/// A share key's registered prompt blocks. Lives while at least one
/// constituent block is still referenced; purged the moment any of them
/// drops to refcount 0 (the group is gone) or a sole holder diverges
/// into it (see [`BlockAllocator::grow`]).
#[derive(Debug)]
struct SharedPrefix {
    blocks: Vec<u32>,
    /// prompt length (tokens) this prefix covers; a later admission
    /// shares only on an exact match
    len: usize,
}

#[derive(Debug)]
struct SeqBlocks {
    table: Vec<u32>,
    /// tokens of this sequence's stream covered by a *shared* prefix
    /// (0 for private admissions): writes at positions >= shared_len into
    /// a block with refcount > 1 are divergent and fork
    shared_len: usize,
}

#[derive(Debug)]
pub struct BlockAllocator {
    block_size: usize,
    total: usize,
    free: Vec<u32>,
    /// per-physical-block reference count (0 = on the free list)
    refs: Vec<u32>,
    tables: HashMap<u64, SeqBlocks>,
    /// share key -> registered prompt prefix
    prefixes: HashMap<u64, SharedPrefix>,
    /// physical block -> owning share key, for the blocks currently
    /// registered in `prefixes` (purge index)
    block_home: HashMap<u32, u64>,
    /// copy-on-write forks performed (first divergent writes)
    cow_forks: u64,
    /// admissions that reused a registered prefix
    shared_admits: u64,
    /// (old, new) physical blocks of the fork performed by the most
    /// recent `grow` call, if any — the paged engine drains this into the
    /// decode graph's copy_src/copy_dst lanes so the device pool performs
    /// the same copy-on-write the books just recorded
    last_fork: Option<(u32, u32)>,
}

impl BlockAllocator {
    pub fn new(total_blocks: usize, block_size: usize) -> Self {
        assert!(block_size > 0 && total_blocks > 0);
        BlockAllocator {
            block_size,
            total: total_blocks,
            free: (0..total_blocks as u32).rev().collect(),
            refs: vec![0; total_blocks],
            tables: HashMap::new(),
            prefixes: HashMap::new(),
            block_home: HashMap::new(),
            cow_forks: 0,
            shared_admits: 0,
            last_fork: None,
        }
    }

    /// Pool sized for `slots` sequences of up to `max_seq` tokens
    /// (the non-overcommitted configuration).
    pub fn for_slots(slots: usize, max_seq: usize, block_size: usize) -> Self {
        let per_seq = max_seq.div_ceil(block_size);
        Self::new(slots * per_seq, block_size)
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn total_blocks(&self) -> usize {
        self.total
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    /// Distinct physical blocks currently referenced.
    pub fn held_blocks(&self) -> usize {
        self.refs.iter().filter(|r| **r > 0).count()
    }

    /// Block references summed over all sequence tables (what `held`
    /// would be without sharing).
    pub fn logical_blocks(&self) -> usize {
        self.tables.values().map(|t| t.table.len()).sum()
    }

    /// Physical blocks saved by prefix sharing right now: logical table
    /// references minus the distinct blocks behind them — and since every
    /// refcount comes from exactly one table reference (`check_invariants`
    /// enforces it), the distinct count is `held_blocks()`.
    pub fn shared_saved_blocks(&self) -> usize {
        self.logical_blocks() - self.held_blocks()
    }

    pub fn cow_forks(&self) -> u64 {
        self.cow_forks
    }

    pub fn shared_admits(&self) -> u64 {
        self.shared_admits
    }

    /// Allocated capacity of a live sequence, in tokens.
    pub fn capacity_tokens(&self, seq_id: u64) -> Option<usize> {
        self.tables
            .get(&seq_id)
            .map(|t| t.table.len() * self.block_size)
    }

    fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size)
    }

    fn alloc_one(&mut self) -> u32 {
        let b = self.free.pop().expect("caller checked free headroom");
        self.refs[b as usize] = 1;
        b
    }

    /// Decrement one reference; a block hitting zero returns to the free
    /// list, and if it was part of a registered shared prefix the whole
    /// registration is purged (its group is gone — nothing left to share
    /// with).
    fn dec_ref(&mut self, b: u32) {
        let r = &mut self.refs[b as usize];
        debug_assert!(*r > 0, "double free of block {b}");
        *r -= 1;
        if *r == 0 {
            if let Some(key) = self.block_home.get(&b).copied() {
                self.purge_prefix(key);
            }
            self.free.push(b);
        }
    }

    fn purge_prefix(&mut self, key: u64) {
        if let Some(p) = self.prefixes.remove(&key) {
            for b in p.blocks {
                self.block_home.remove(&b);
            }
        }
    }

    /// Can a new *private* sequence of `total_len` tokens be admitted now?
    pub fn can_admit(&self, total_len: usize) -> bool {
        self.blocks_for(total_len.max(1)) <= self.free.len()
    }

    /// Can a fresh sequence with `prompt_len` prompt tokens be admitted
    /// under `share_key`? A registered matching prefix costs zero new
    /// blocks.
    pub fn can_admit_shared(&self, share_key: u64, prompt_len: usize) -> bool {
        match self.prefixes.get(&share_key) {
            Some(p) if p.len == prompt_len => true,
            _ => self.can_admit(prompt_len),
        }
    }

    /// Register a new sequence and allocate private blocks for its whole
    /// stream (no sharing — imports carrying a generated prefix use this:
    /// their streams already diverged).
    pub fn admit(&mut self, seq_id: u64, total_len: usize) -> Result<()> {
        if self.tables.contains_key(&seq_id) {
            bail!("sequence {seq_id} already admitted");
        }
        let need = self.blocks_for(total_len.max(1));
        if need > self.free.len() {
            bail!("out of KV blocks: need {need}, free {}", self.free.len());
        }
        let table: Vec<u32> = (0..need).map(|_| self.alloc_one()).collect();
        self.tables.insert(seq_id, SeqBlocks { table, shared_len: 0 });
        Ok(())
    }

    /// Admit a *fresh* sequence (stream = its prompt, nothing generated)
    /// under a share key. The first admission under a key allocates and
    /// registers the prompt blocks; later admissions with the same
    /// `prompt_len` reference them (refcount += 1 each, zero new blocks).
    pub fn admit_shared(&mut self, seq_id: u64, share_key: u64, prompt_len: usize) -> Result<()> {
        if self.tables.contains_key(&seq_id) {
            bail!("sequence {seq_id} already admitted");
        }
        let prompt_len = prompt_len.max(1);
        if let Some(p) = self.prefixes.get(&share_key) {
            if p.len == prompt_len {
                let table = p.blocks.clone();
                for &b in &table {
                    self.refs[b as usize] += 1;
                }
                self.shared_admits += 1;
                self.tables
                    .insert(seq_id, SeqBlocks { table, shared_len: prompt_len });
                return Ok(());
            }
            // length skew (a diverged/shrunk registration): fall through
            // to a private admission — correctness over sharing
        }
        let need = self.blocks_for(prompt_len);
        if need > self.free.len() {
            bail!("out of KV blocks: need {need}, free {}", self.free.len());
        }
        let table: Vec<u32> = (0..need).map(|_| self.alloc_one()).collect();
        if !self.prefixes.contains_key(&share_key) {
            for &b in &table {
                self.block_home.insert(b, share_key);
            }
            self.prefixes
                .insert(share_key, SharedPrefix { blocks: table.clone(), len: prompt_len });
        }
        self.tables
            .insert(seq_id, SeqBlocks { table, shared_len: prompt_len });
        Ok(())
    }

    /// Grow a sequence so position `new_len - 1` is writable, acquiring
    /// tail blocks and **forking the write block** when it is shared and
    /// the write is divergent (position >= the shared prefix length —
    /// identical prompt re-writes during prefill/replay do not fork).
    /// Returns false (state unchanged) when the pool cannot cover the
    /// growth — the block-pressure signal the engine forwards to the
    /// scheduler's preemption hook (vLLM would preempt/swap here too).
    pub fn grow(&mut self, seq_id: u64, new_len: usize) -> Result<bool> {
        self.last_fork = None;
        let Some(sb) = self.tables.get(&seq_id) else {
            bail!("grow on unknown sequence {seq_id}");
        };
        let new_len = new_len.max(1);
        let need = self.blocks_for(new_len);
        let extra = need.saturating_sub(sb.table.len());
        let widx = (new_len - 1) / self.block_size;
        let divergent = new_len - 1 >= sb.shared_len;
        let fork = widx < sb.table.len()
            && divergent
            && self.refs[sb.table[widx] as usize] > 1;
        if extra + fork as usize > self.free.len() {
            return Ok(false);
        }
        for _ in 0..extra {
            let b = self.alloc_one();
            self.tables.get_mut(&seq_id).expect("checked above").table.push(b);
        }
        if fork {
            let nb = self.alloc_one();
            let sb = self.tables.get_mut(&seq_id).expect("checked above");
            let old = std::mem::replace(&mut sb.table[widx], nb);
            self.dec_ref(old);
            self.cow_forks += 1;
            self.last_fork = Some((old, nb));
        } else if divergent
            && !self.block_home.is_empty()
            && widx < self.tables[&seq_id].table.len()
        {
            // sole holder diverging into a still-registered shared block:
            // the registration no longer describes a clean prompt prefix
            // past this point — shrink it so later admissions cannot
            // alias the now-private content
            let b = self.tables[&seq_id].table[widx];
            if let Some(key) = self.block_home.get(&b).copied() {
                let p = self.prefixes.get_mut(&key).expect("block_home in sync");
                if let Some(at) = p.blocks.iter().position(|&x| x == b) {
                    for dropped in p.blocks.split_off(at) {
                        self.block_home.remove(&dropped);
                    }
                    p.len = p.len.min(at * self.block_size);
                    if p.blocks.is_empty() {
                        self.prefixes.remove(&key);
                    }
                }
            }
        }
        Ok(true)
    }

    /// Release every block reference of a finished/parked sequence.
    pub fn release(&mut self, seq_id: u64) -> Result<()> {
        let Some(sb) = self.tables.remove(&seq_id) else {
            bail!("release of unknown sequence {seq_id}");
        };
        for b in sb.table {
            self.dec_ref(b);
        }
        Ok(())
    }

    /// The block table of a live sequence (for tests/inspection).
    pub fn table(&self, seq_id: u64) -> Option<&[u32]> {
        self.tables.get(&seq_id).map(|t| t.table.as_slice())
    }

    /// Take the (old, new) block pair of the fork performed by the most
    /// recent `grow` call, if it forked. The paged engine drains this
    /// per step into the decode graph's copy_src/copy_dst operands so
    /// the device pool copies the shared block before the divergent
    /// write lands.
    pub fn take_last_fork(&mut self) -> Option<(u32, u32)> {
        self.last_fork.take()
    }

    /// Blocks in the sequence's table held by it alone (refcount 1) —
    /// the number of physical blocks its eviction would actually free,
    /// and the share-aware `SeqView::kv_blocks` cost signal the paged
    /// engine feeds the preemption victim rule.
    pub fn private_blocks(&self, seq_id: u64) -> Option<usize> {
        self.tables
            .get(&seq_id)
            .map(|t| t.table.iter().filter(|&&b| self.refs[b as usize] == 1).count())
    }

    /// Export a live sequence's block table into a device-literal lane:
    /// real entries first, every remaining row slot pointed at `trash`
    /// (the pool's sacrificial last block, where parked rows scatter).
    /// An unknown `seq_id` fills the whole lane with `trash` — exactly
    /// what an empty decode slot must present to the graph.
    pub fn fill_table(&self, seq_id: u64, out: &mut [i32], trash: i32) {
        let table = self.tables.get(&seq_id).map(|t| t.table.as_slice()).unwrap_or(&[]);
        for (i, slot) in out.iter_mut().enumerate() {
            *slot = table.get(i).map(|&b| b as i32).unwrap_or(trash);
        }
    }

    /// Invariant check used by the property tests: refcount conservation
    /// (free + held == total; Σ table references == Σ refcounts), free
    /// list exactly the refcount-0 blocks with no duplicates, and the
    /// share registry only pointing at live blocks.
    pub fn check_invariants(&self) -> Result<()> {
        let mut expect = vec![0u32; self.total];
        for sb in self.tables.values() {
            for &b in &sb.table {
                let Some(slot) = expect.get_mut(b as usize) else {
                    bail!("table references out-of-range block {b}");
                };
                *slot += 1;
            }
        }
        if expect != self.refs {
            bail!("refcounts drifted from table references: {:?} vs {:?}", self.refs, expect);
        }
        let mut seen = HashSet::new();
        for &b in &self.free {
            if !seen.insert(b) {
                bail!("block {b} on the free list twice");
            }
            if self.refs[b as usize] != 0 {
                bail!("block {b} free with refcount {}", self.refs[b as usize]);
            }
        }
        let held = self.held_blocks();
        if held + self.free.len() != self.total {
            bail!(
                "block leak: held {held} + free {} != total {}",
                self.free.len(),
                self.total
            );
        }
        for (key, p) in &self.prefixes {
            for &b in &p.blocks {
                if self.refs[b as usize] == 0 {
                    bail!("share key {key} registers freed block {b}");
                }
                if self.block_home.get(&b) != Some(key) {
                    bail!("block_home out of sync for block {b}");
                }
            }
        }
        if self.block_home.len() != self.prefixes.values().map(|p| p.blocks.len()).sum::<usize>() {
            bail!("block_home holds stale entries");
        }
        Ok(())
    }
}

/// Coalesced-replay admission window (see `Engine::admit`). Every
/// admitted pos>0 sequence (imported snapshot or preempted-and-parked
/// local) forces a full KV replay in the step that seats it, so N
/// sequences trickling into slots as they free cost up to N replays
/// where ceil(N/batch) would do. The window holds every free slot until
/// `free_slots` can seat `min(waiting, batch, n_slots)` of them at once,
/// so one replay covers the whole batch. `batch = 1` reproduces the
/// legacy admit-eagerly behavior exactly; the cap at `n_slots` keeps the
/// window satisfiable (a fully drained engine always opens it).
pub fn replay_window_open(waiting: usize, free_slots: usize, batch: usize, n_slots: usize) -> bool {
    if waiting == 0 {
        return true;
    }
    free_slots >= waiting.min(batch.max(1)).min(n_slots.max(1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;

    #[test]
    fn admit_grow_release_cycle() {
        let mut a = BlockAllocator::new(8, 16);
        a.admit(1, 10).unwrap(); // 1 block
        assert_eq!(a.table(1).unwrap().len(), 1);
        assert!(a.grow(1, 16).unwrap()); // still 1 block
        assert_eq!(a.table(1).unwrap().len(), 1);
        assert!(a.grow(1, 17).unwrap()); // 2 blocks
        assert_eq!(a.table(1).unwrap().len(), 2);
        a.release(1).unwrap();
        assert_eq!(a.free_blocks(), 8);
        a.check_invariants().unwrap();
    }

    #[test]
    fn admission_control() {
        let mut a = BlockAllocator::new(2, 16);
        assert!(a.can_admit(32));
        a.admit(1, 32).unwrap(); // takes both blocks
        assert!(!a.can_admit(1));
        assert!(a.admit(2, 1).is_err());
        a.release(1).unwrap();
        assert!(a.can_admit(32));
    }

    #[test]
    fn grow_exhaustion_is_graceful() {
        let mut a = BlockAllocator::new(2, 4);
        a.admit(1, 4).unwrap();
        a.admit(2, 4).unwrap();
        assert!(!a.grow(1, 5).unwrap(), "no blocks left: stall, not panic");
        a.check_invariants().unwrap();
    }

    #[test]
    fn double_admit_and_unknown_ops_error() {
        let mut a = BlockAllocator::new(4, 4);
        a.admit(1, 1).unwrap();
        assert!(a.admit(1, 1).is_err());
        assert!(a.admit_shared(1, 9, 1).is_err());
        assert!(a.release(99).is_err());
        assert!(a.grow(99, 10).is_err());
    }

    #[test]
    fn group_shares_prompt_blocks_once_with_refcount_g() {
        // the acceptance shape: G rollouts over a shared prompt hold
        // ceil(prompt/block_size) blocks once (refcount G), not G times
        let (g, prompt, bs) = (4usize, 37usize, 16usize);
        let per = prompt.div_ceil(bs);
        let mut a = BlockAllocator::new(32, bs);
        for i in 0..g {
            a.admit_shared(i as u64, 700, prompt).unwrap();
        }
        a.check_invariants().unwrap();
        assert_eq!(a.held_blocks(), per, "prompt blocks held once");
        assert_eq!(a.logical_blocks(), g * per);
        assert_eq!(a.shared_saved_blocks(), (g - 1) * per);
        assert_eq!(a.shared_admits() as usize, g - 1);
        let t0 = a.table(0).unwrap().to_vec();
        for i in 1..g {
            assert_eq!(a.table(i as u64).unwrap(), &t0[..], "identical shared tables");
        }
        // prefill re-writes (positions < prompt) never fork
        for i in 0..g {
            assert!(a.grow(i as u64, prompt).unwrap());
        }
        assert_eq!(a.cow_forks(), 0, "identical prompt re-writes are not divergent");
        a.check_invariants().unwrap();
    }

    #[test]
    fn first_divergent_write_forks_and_never_aliases() {
        let (g, prompt, bs) = (3usize, 20usize, 8usize);
        let mut a = BlockAllocator::new(32, bs);
        for i in 0..g {
            a.admit_shared(i as u64, 55, prompt).unwrap();
        }
        let shared_last = a.table(0).unwrap()[prompt.div_ceil(bs) - 1];
        // first sampled token of member 0 lands in the partial last
        // prompt block (position 20, block 2) -> copy-on-write fork
        assert!(a.grow(0, prompt + 1).unwrap());
        assert_eq!(a.cow_forks(), 1);
        a.check_invariants().unwrap();
        let forked = a.table(0).unwrap()[2];
        assert_ne!(forked, shared_last, "writer got a private copy");
        for i in 1..g {
            assert!(
                !a.table(i as u64).unwrap().contains(&forked),
                "forked block aliased into member {i}"
            );
            assert!(a.table(i as u64).unwrap().contains(&shared_last));
        }
        // the remaining members still share it (refcount g-1), and their
        // own divergence forks again
        assert!(a.grow(1, prompt + 1).unwrap());
        assert_eq!(a.cow_forks(), 2);
        // last holder diverges without a fork (sole owner keeps the block)
        assert!(a.grow(2, prompt + 1).unwrap());
        assert_eq!(a.cow_forks(), 2, "sole holder writes in place");
        a.check_invariants().unwrap();
        for i in 0..g {
            a.release(i as u64).unwrap();
        }
        assert_eq!(a.free_blocks(), 32);
        a.check_invariants().unwrap();
    }

    #[test]
    fn fork_is_reported_for_the_device_copy_lanes() {
        let (prompt, bs) = (6usize, 8usize); // partial block: divergence forks
        let mut a = BlockAllocator::new(4, bs);
        a.admit_shared(1, 9, prompt).unwrap();
        a.admit_shared(2, 9, prompt).unwrap();
        assert!(a.take_last_fork().is_none(), "nothing grew yet");
        let shared = a.table(1).unwrap()[0];
        assert!(a.grow(1, prompt + 1).unwrap());
        let (old, new) = a.take_last_fork().expect("divergent write forked");
        assert_eq!(old, shared, "copy source is the shared block");
        assert_eq!(new, a.table(1).unwrap()[0], "copy target is the private copy");
        assert!(a.take_last_fork().is_none(), "drained: a fork reports once");
        // a fork-free grow must not resurrect the stale report
        assert!(a.grow(1, bs + 1).unwrap());
        assert!(a.take_last_fork().is_none());
        a.check_invariants().unwrap();
    }

    #[test]
    fn fill_table_pads_with_trash_and_private_blocks_discount_sharing() {
        let bs = 8usize;
        let mut a = BlockAllocator::new(8, bs);
        a.admit_shared(1, 5, 12).unwrap(); // 2 blocks, both shared
        a.admit_shared(2, 5, 12).unwrap();
        let trash = 7i32;
        let mut lane = [0i32; 4];
        a.fill_table(1, &mut lane, trash);
        let t = a.table(1).unwrap().to_vec();
        assert_eq!(&lane[..2], &[t[0] as i32, t[1] as i32]);
        assert_eq!(&lane[2..], &[trash, trash], "unused row slots park at trash");
        // unknown sequence = empty decode slot: the whole lane is parked
        a.fill_table(99, &mut lane, trash);
        assert_eq!(lane, [trash; 4]);
        // fully shared tables free nothing on eviction...
        assert_eq!(a.private_blocks(1), Some(0));
        assert!(a.grow(1, 13).unwrap()); // divergent write -> CoW fork
        assert_eq!(a.cow_forks(), 1);
        // ...but the forked copy is a private block
        assert_eq!(a.private_blocks(1), Some(1));
        assert_eq!(a.private_blocks(2), Some(0));
        assert_eq!(a.private_blocks(99), None);
        a.check_invariants().unwrap();
    }

    #[test]
    fn fork_respects_pool_exhaustion() {
        let (prompt, bs) = (6usize, 8usize); // partial block: divergence forks
        let mut a = BlockAllocator::new(2, bs); // shared prompt takes 1, 1 spare
        a.admit_shared(1, 9, prompt).unwrap();
        a.admit_shared(2, 9, prompt).unwrap();
        assert!(a.grow(1, prompt + 1).unwrap(), "the fork fits the spare block");
        assert_eq!(a.cow_forks(), 1);
        // member 2's divergence also needs a fork and the pool is empty
        assert!(!a.grow(2, prompt + 1).unwrap(), "exhausted pool stalls, not panics");
        a.check_invariants().unwrap();
        // a release frees the forked copy and member 2 can proceed
        a.release(1).unwrap();
        assert!(a.grow(2, prompt + 1).unwrap());
        a.check_invariants().unwrap();
    }

    #[test]
    fn sole_holder_divergence_shrinks_the_registration() {
        let (prompt, bs) = (12usize, 8usize); // 2 blocks, second partial
        let mut a = BlockAllocator::new(8, bs);
        a.admit_shared(1, 4, prompt).unwrap();
        // sole member diverges into the partial block before anyone shares
        assert!(a.grow(1, prompt + 1).unwrap());
        assert_eq!(a.cow_forks(), 0);
        // a later member must not alias the diverged block: registration
        // shrank, so it admits privately (len mismatch)
        a.admit_shared(2, 4, prompt).unwrap();
        assert!(
            a.table(2).unwrap().iter().all(|b| !a.table(1).unwrap().contains(b)),
            "diverged content never aliased into a new member"
        );
        a.check_invariants().unwrap();
    }

    #[test]
    fn registry_purged_when_group_is_gone() {
        let (prompt, bs) = (16usize, 8usize);
        let mut a = BlockAllocator::new(4, bs);
        a.admit_shared(1, 3, prompt).unwrap();
        a.release(1).unwrap();
        a.check_invariants().unwrap();
        assert_eq!(a.free_blocks(), 4, "all blocks back");
        // the key is reusable afresh (no stale registration)
        a.admit_shared(2, 3, prompt).unwrap();
        a.check_invariants().unwrap();
        assert_eq!(a.held_blocks(), 2);
    }

    #[test]
    fn replay_window_semantics() {
        // no pending replays: always open
        assert!(replay_window_open(0, 0, 4, 8));
        // legacy batch=1: open whenever a slot is free
        assert!(replay_window_open(5, 1, 1, 8));
        assert!(!replay_window_open(5, 0, 1, 8));
        // batching holds slots until the window fills
        assert!(!replay_window_open(8, 3, 4, 8));
        assert!(replay_window_open(8, 4, 4, 8));
        // fewer waiting than the batch: the tail does not starve
        assert!(replay_window_open(2, 2, 4, 8));
        assert!(!replay_window_open(2, 1, 4, 8));
        // the slot cap keeps the window satisfiable on tiny engines
        assert!(replay_window_open(10, 2, 8, 2));
    }

    #[test]
    fn property_refcount_conservation_under_churn() {
        testkit::check("kv allocator invariants", 200, 0xb10c, 64, |c| {
            let total = c.usize_in(2, 24);
            let bs = c.usize_in(1, 8);
            let mut a = BlockAllocator::new(total, bs);
            let mut live: Vec<(u64, usize)> = Vec::new(); // (id, len)
            let mut next_id = 0u64;
            for _ in 0..c.usize_in(1, 60) {
                match c.rng.below(4) {
                    0 => {
                        let len = c.usize_in(1, bs * 4);
                        if a.can_admit(len) {
                            a.admit(next_id, len).map_err(|e| e.to_string())?;
                            live.push((next_id, len));
                            next_id += 1;
                        }
                    }
                    1 => {
                        // shared admission under a small key space so
                        // hits, misses and skewed lengths all occur
                        let key = c.rng.below(3) as u64 + 500;
                        let len = c.usize_in(1, bs * 3);
                        if a.can_admit_shared(key, len) {
                            a.admit_shared(next_id, key, len).map_err(|e| e.to_string())?;
                            live.push((next_id, len));
                            next_id += 1;
                        }
                    }
                    2 => {
                        if !live.is_empty() {
                            let idx = c.rng.below(live.len());
                            let (id, len) = live[idx];
                            let new_len = len + c.usize_in(0, bs * 2);
                            if a.grow(id, new_len).map_err(|e| e.to_string())? {
                                live[idx].1 = new_len;
                            }
                        }
                    }
                    _ => {
                        if !live.is_empty() {
                            let idx = c.rng.below(live.len());
                            let (id, _) = live.swap_remove(idx);
                            a.release(id).map_err(|e| e.to_string())?;
                        }
                    }
                }
                a.check_invariants().map_err(|e| e.to_string())?;
            }
            Ok(())
        });
    }

    #[test]
    fn property_divergent_writes_never_alias_shared_blocks() {
        testkit::check("cow fork never aliases", 150, 0xc0f0, 48, |c| {
            let bs = c.usize_in(1, 6);
            let prompt = c.usize_in(1, bs * 3);
            let g = c.usize_in(2, 5);
            // sized for the worst case: shared prompt blocks + one block
            // per divergent token (bs = 1) + one fork per member per
            // shared block — the property asserts growth never stalls
            let mut a = BlockAllocator::new(64, bs);
            for i in 0..g {
                a.admit_shared(i as u64, 1, prompt).map_err(|e| e.to_string())?;
            }
            // every member writes a random number of divergent tokens
            let mut lens = vec![prompt; g];
            for _ in 0..c.usize_in(1, 24) {
                let i = c.rng.below(g);
                lens[i] += 1;
                if !a.grow(i as u64, lens[i]).map_err(|e| e.to_string())? {
                    return Err("sized pool must never stall".into());
                }
                a.check_invariants().map_err(|e| e.to_string())?;
                // no divergent position's block may be shared with any
                // other member
                for i in 0..g {
                    if lens[i] == prompt {
                        continue;
                    }
                    let widx = (lens[i] - 1) / bs;
                    let b = a.table(i as u64).unwrap()[widx];
                    for j in 0..g {
                        if j != i && a.table(j as u64).unwrap().contains(&b) {
                            return Err(format!(
                                "divergent block {b} of member {i} aliased by member {j}"
                            ));
                        }
                    }
                }
            }
            Ok(())
        });
    }
}
