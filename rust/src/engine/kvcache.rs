//! Paged KV-cache block allocator (vLLM's PagedAttention bookkeeping).
//!
//! The device-side cache of the AOT decode graph is dense per slot, but
//! admission control and memory accounting work exactly like vLLM: the
//! cache is divided into fixed-size blocks; a sequence holds
//! ceil(len / block_size) blocks, acquired incrementally as it grows and
//! released when it finishes. A new request is admitted only when a slot
//! *and* enough blocks for its prompt are available — with an
//! over-committed pool this throttles admission exactly like a full HBM.
//!
//! Invariants (property-tested): no double-free, no leak: free +
//! held == total at all times; a sequence never holds more blocks than
//! ceil(max_seq / block_size).

use anyhow::{bail, Result};
use std::collections::HashMap;

#[derive(Debug)]
pub struct BlockAllocator {
    block_size: usize,
    total: usize,
    free: Vec<u32>,
    /// sequence id -> block table (ordered physical block ids)
    tables: HashMap<u64, Vec<u32>>,
}

impl BlockAllocator {
    pub fn new(total_blocks: usize, block_size: usize) -> Self {
        assert!(block_size > 0 && total_blocks > 0);
        BlockAllocator {
            block_size,
            total: total_blocks,
            free: (0..total_blocks as u32).rev().collect(),
            tables: HashMap::new(),
        }
    }

    /// Pool sized for `slots` sequences of up to `max_seq` tokens
    /// (the non-overcommitted configuration).
    pub fn for_slots(slots: usize, max_seq: usize, block_size: usize) -> Self {
        let per_seq = max_seq.div_ceil(block_size);
        Self::new(slots * per_seq, block_size)
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn held_blocks(&self) -> usize {
        self.tables.values().map(|t| t.len()).sum()
    }

    fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size)
    }

    /// Can a new sequence of `prompt_len` tokens be admitted now?
    pub fn can_admit(&self, prompt_len: usize) -> bool {
        self.blocks_for(prompt_len.max(1)) <= self.free.len()
    }

    /// Register a new sequence and allocate blocks for its prompt.
    pub fn admit(&mut self, seq_id: u64, prompt_len: usize) -> Result<()> {
        if self.tables.contains_key(&seq_id) {
            bail!("sequence {seq_id} already admitted");
        }
        let need = self.blocks_for(prompt_len.max(1));
        if need > self.free.len() {
            bail!("out of KV blocks: need {need}, free {}", self.free.len());
        }
        let table: Vec<u32> = (0..need).map(|_| self.free.pop().unwrap()).collect();
        self.tables.insert(seq_id, table);
        Ok(())
    }

    /// Grow a sequence to `new_len` tokens, acquiring blocks as needed.
    /// Returns false (and leaves state unchanged) if the pool is exhausted
    /// — the engine then stalls that sequence (vLLM would preempt/swap).
    pub fn grow(&mut self, seq_id: u64, new_len: usize) -> Result<bool> {
        let Some(table) = self.tables.get_mut(&seq_id) else {
            bail!("grow on unknown sequence {seq_id}");
        };
        let need = new_len.div_ceil(self.block_size);
        if need <= table.len() {
            return Ok(true);
        }
        let extra = need - table.len();
        if extra > self.free.len() {
            return Ok(false);
        }
        for _ in 0..extra {
            table.push(self.free.pop().unwrap());
        }
        Ok(true)
    }

    /// Release every block of a finished sequence.
    pub fn release(&mut self, seq_id: u64) -> Result<()> {
        let Some(table) = self.tables.remove(&seq_id) else {
            bail!("release of unknown sequence {seq_id}");
        };
        self.free.extend(table);
        Ok(())
    }

    /// The block table of a live sequence (for tests/inspection).
    pub fn table(&self, seq_id: u64) -> Option<&[u32]> {
        self.tables.get(&seq_id).map(|t| t.as_slice())
    }

    /// Invariant check used by the property tests.
    pub fn check_invariants(&self) -> Result<()> {
        let held = self.held_blocks();
        if held + self.free.len() != self.total {
            bail!(
                "block leak: held {held} + free {} != total {}",
                self.free.len(),
                self.total
            );
        }
        let mut seen = std::collections::HashSet::new();
        for b in self.free.iter().chain(self.tables.values().flatten()) {
            if !seen.insert(*b) {
                bail!("block {b} appears twice");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;

    #[test]
    fn admit_grow_release_cycle() {
        let mut a = BlockAllocator::new(8, 16);
        a.admit(1, 10).unwrap(); // 1 block
        assert_eq!(a.table(1).unwrap().len(), 1);
        assert!(a.grow(1, 16).unwrap()); // still 1 block
        assert_eq!(a.table(1).unwrap().len(), 1);
        assert!(a.grow(1, 17).unwrap()); // 2 blocks
        assert_eq!(a.table(1).unwrap().len(), 2);
        a.release(1).unwrap();
        assert_eq!(a.free_blocks(), 8);
        a.check_invariants().unwrap();
    }

    #[test]
    fn admission_control() {
        let mut a = BlockAllocator::new(2, 16);
        assert!(a.can_admit(32));
        a.admit(1, 32).unwrap(); // takes both blocks
        assert!(!a.can_admit(1));
        assert!(a.admit(2, 1).is_err());
        a.release(1).unwrap();
        assert!(a.can_admit(32));
    }

    #[test]
    fn grow_exhaustion_is_graceful() {
        let mut a = BlockAllocator::new(2, 4);
        a.admit(1, 4).unwrap();
        a.admit(2, 4).unwrap();
        assert!(!a.grow(1, 5).unwrap(), "no blocks left: stall, not panic");
        a.check_invariants().unwrap();
    }

    #[test]
    fn double_admit_and_unknown_ops_error() {
        let mut a = BlockAllocator::new(4, 4);
        a.admit(1, 1).unwrap();
        assert!(a.admit(1, 1).is_err());
        assert!(a.release(99).is_err());
        assert!(a.grow(99, 10).is_err());
    }

    #[test]
    fn property_no_leak_no_double_use() {
        testkit::check("kv allocator invariants", 200, 0xb10c, 64, |c| {
            let total = c.usize_in(2, 24);
            let bs = c.usize_in(1, 8);
            let mut a = BlockAllocator::new(total, bs);
            let mut live: Vec<u64> = Vec::new();
            let mut next_id = 0u64;
            for _ in 0..c.usize_in(1, 60) {
                match c.rng.below(3) {
                    0 => {
                        let len = c.usize_in(1, bs * 4);
                        if a.can_admit(len) {
                            a.admit(next_id, len).map_err(|e| e.to_string())?;
                            live.push(next_id);
                            next_id += 1;
                        }
                    }
                    1 => {
                        if !live.is_empty() {
                            let idx = c.rng.below(live.len());
                            let id = live[idx];
                            let len = c.usize_in(1, bs * 8);
                            a.grow(id, len).map_err(|e| e.to_string())?;
                        }
                    }
                    _ => {
                        if !live.is_empty() {
                            let idx = c.rng.below(live.len());
                            let id = live.swap_remove(idx);
                            a.release(id).map_err(|e| e.to_string())?;
                        }
                    }
                }
                a.check_invariants().map_err(|e| e.to_string())?;
            }
            Ok(())
        });
    }
}
