//! The paper's three-endpoint generation-service API, as a Rust trait.
//!
//! §4 ("Architecture and Implementation Details"): *"any generation
//! software that supports the three HTTP API endpoints that PipelineRL
//! requires can be easily integrated"* — the endpoints being
//! `/v1/chat/completions`, `/init_process_group` and
//! `/request_weight_update`. The actor is written against this trait, so
//! an alternative engine (or a real HTTP client) can be dropped in; the
//! in-process [`super::Engine`] is the reference implementation.

use crate::data::task::Problem;
use crate::rl::Rollout;
use crate::runtime::HostTensor;
use crate::sched::SeqSnapshot;
use anyhow::Result;

/// Quality-of-service class of a generation request. The serving gateway
/// (`crate::gateway`) schedules the two classes asymmetrically: interactive
/// requests admit first and may evict batch rollouts through the snapshot
/// park path; batch work is the first thing shed under queue pressure.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum QosClass {
    /// latency-sensitive user traffic (admission-to-first-token SLO)
    Interactive,
    /// throughput traffic: RL rollouts and offline generation — evictable
    /// (parked losslessly via [`SeqSnapshot`]) and sheddable
    #[default]
    Batch,
}

impl QosClass {
    pub fn name(&self) -> &'static str {
        match self {
            QosClass::Interactive => "interactive",
            QosClass::Batch => "batch",
        }
    }
}

/// The house tenant: the training run's own rollout traffic. Exempt from
/// per-tenant KV budgets (the run owns whatever the gateway doesn't lease
/// out to external tenants).
pub const ROLLOUT_TENANT: u64 = 0;

/// A generation request (the chat-completions analogue). QoS class and
/// tenant id ride along so one engine can serve user inference next to
/// rollouts; the engine itself ignores both — classing is the gateway's
/// admission concern, and every pre-gateway call site uses
/// [`CompletionRequest::rollout`], which pins the legacy behavior
/// (batch-class, house tenant) bit-for-bit.
#[derive(Debug, Clone)]
pub struct CompletionRequest {
    pub problem: Problem,
    pub prompt_tokens: Vec<i32>,
    pub group_id: u64,
    pub qos: QosClass,
    /// tenant id for KV budgeting ([`ROLLOUT_TENANT`] = the training run)
    pub tenant: u64,
}

impl CompletionRequest {
    /// A batch-class rollout request from the training loop itself — the
    /// legacy three-argument submission, unchanged in behavior.
    pub fn rollout(problem: Problem, prompt_tokens: Vec<i32>, group_id: u64) -> Self {
        CompletionRequest {
            problem,
            prompt_tokens,
            group_id,
            qos: QosClass::Batch,
            tenant: ROLLOUT_TENANT,
        }
    }

    /// A latency-sensitive user request from an external tenant.
    pub fn interactive(
        problem: Problem,
        prompt_tokens: Vec<i32>,
        group_id: u64,
        tenant: u64,
    ) -> Self {
        CompletionRequest {
            problem,
            prompt_tokens,
            group_id,
            qos: QosClass::Interactive,
            tenant,
        }
    }
}

/// Live KV-memory pressure of a generation service (the `/metrics`
/// analogue a coordinator polls to decide admission, migration and
/// autoscaling): paged-allocator occupancy, the savings bought by
/// prefix sharing, and how often the service had to shed load.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct KvPressure {
    pub total_blocks: usize,
    pub free_blocks: usize,
    /// distinct physical blocks held
    pub held_blocks: usize,
    /// block references deduplicated away by prefix sharing right now
    pub saved_blocks: usize,
    /// sequences parked under block pressure so far
    pub preemptions: u64,
}

pub trait GenerationService {
    /// `/v1/chat/completions` (streaming form): enqueue a request.
    fn submit(&mut self, req: CompletionRequest) -> Result<u64>;

    /// `/init_process_group`: join the weight-transfer group.
    fn init_process_group(&mut self, group: &str) -> Result<()>;

    /// `/request_weight_update`: receive new weights (in-flight).
    fn request_weight_update(&mut self, version: u64, params: &[HostTensor]) -> Result<()>;

    /// Advance generation by one engine step; completed sequences are
    /// returned as rollouts.
    fn step(&mut self) -> Result<Vec<Rollout>>;

    /// Sequences currently in flight (active + queued).
    fn load(&self) -> usize;

    fn slots(&self) -> usize;

    /// Drain every in-flight sequence into portable snapshots (the
    /// kill/descale hand-off — nothing is aborted). Group ids and
    /// generated prefixes are preserved, so the snapshots resume on any
    /// other service instance.
    fn export_snapshots(&mut self) -> Vec<SeqSnapshot>;

    /// Adopt a sequence exported from another service instance; its KV
    /// prefix is rebuilt locally. Returns the fresh local sequence id.
    fn import_snapshot(&mut self, snap: &SeqSnapshot, problem: Problem) -> Result<u64>;

    /// Live KV-memory pressure (see [`KvPressure`]).
    fn kv_pressure(&self) -> KvPressure;

    /// Externally-driven preemption (the gateway's latency-sensitive
    /// eviction): park one *active* sequence whose id is in `allowed` and
    /// hand its snapshot out — blocks freed, generated prefix, version
    /// tags and RNG cursor intact — instead of re-queueing it locally.
    /// The caller owns the parked sequence (typically depositing it into
    /// a `MigrationHub`) and re-imports it via
    /// [`GenerationService::import_snapshot`] when headroom returns, so
    /// no salvageable token is lost. Victim choice is the deterministic
    /// `PreemptPolicy::Youngest` rule over the allowed set — external
    /// eviction is gateway policy, independent of the engine's
    /// `[kv] preempt_policy` ablation setting. `None` when nothing in
    /// `allowed` is active (or the service cannot preempt).
    fn preempt_victim(&mut self, allowed: &[u64]) -> Option<SeqSnapshot> {
        let _ = allowed;
        None
    }
}

impl GenerationService for super::Engine {
    fn submit(&mut self, req: CompletionRequest) -> Result<u64> {
        Ok(self.add_request(req.problem, req.prompt_tokens, req.group_id))
    }

    fn init_process_group(&mut self, _group: &str) -> Result<()> {
        Ok(()) // single-process: the WeightBus handles registration
    }

    fn request_weight_update(&mut self, version: u64, params: &[HostTensor]) -> Result<()> {
        self.set_weights(version, params)
    }

    fn step(&mut self) -> Result<Vec<Rollout>> {
        Ok(self.step()?.finished)
    }

    fn load(&self) -> usize {
        self.load()
    }

    fn slots(&self) -> usize {
        self.n_slots()
    }

    fn export_snapshots(&mut self) -> Vec<SeqSnapshot> {
        self.export_snapshots()
    }

    fn import_snapshot(&mut self, snap: &SeqSnapshot, problem: Problem) -> Result<u64> {
        self.import_snapshot(snap, problem)
    }

    fn kv_pressure(&self) -> KvPressure {
        KvPressure {
            total_blocks: self.kv_total_blocks(),
            free_blocks: self.kv_free_blocks(),
            held_blocks: self.kv_held_blocks(),
            saved_blocks: self.kv_shared_saved_blocks(),
            preemptions: self.stats.preemptions,
        }
    }

    fn preempt_victim(&mut self, allowed: &[u64]) -> Option<SeqSnapshot> {
        // errors here are allocator-book invariant failures, which the
        // engine's own tests pin; an external caller treats them as
        // "nothing preemptable"
        self.preempt_external(allowed).ok().flatten()
    }
}
