//! The engine proper: continuous-batching decode loop over the AOT
//! decode graph, with in-flight request admission and in-flight weight
//! updates. See module docs in engine/mod.rs for the hot-path data flow.

use super::arena::StepArena;
use super::kvcache::BlockAllocator;
use super::sequence::SeqState;
use crate::data::task::Problem;
use crate::model::tokenizer::{EOS_ID, PAD_ID};
use crate::rl::Rollout;
use crate::runtime::{DeviceVal, HostTensor, Runtime, Variant};
use crate::util::timer::Stopwatch;
use crate::util::Rng;
use crate::weights::ShadowSet;
use anyhow::{bail, ensure, Context, Result};
use std::collections::VecDeque;
use std::rc::Rc;
use std::time::Instant;
use xla::{Literal, PjRtBuffer};

#[derive(Debug, Clone)]
pub struct EngineCfg {
    pub variant: String,
    pub temperature: f32,
    pub max_new_tokens: usize,
    /// KV page size for the block allocator
    pub block_size: usize,
    /// total KV blocks; None = exactly enough for all slots at max_seq
    pub kv_blocks: Option<usize>,
    /// record the full per-step log-distribution of sampled tokens
    /// (needed by the Fig 7 KL study; off on the hot path)
    pub capture_dist: bool,
    /// recompute the whole KV cache under new weights at every weight
    /// update (the paper's §5.1 ablation; costs throughput)
    pub recompute_kv_on_update: bool,
    /// greedy decoding: zero Gumbel noise (argmax) — used by the eval
    /// harness (Table 1 protocol)
    pub greedy: bool,
}

impl EngineCfg {
    pub fn new(variant: &str) -> Self {
        EngineCfg {
            variant: variant.to_string(),
            temperature: 1.0,
            max_new_tokens: 48,
            block_size: 16,
            kv_blocks: None,
            capture_dist: false,
            recompute_kv_on_update: false,
            greedy: false,
        }
    }
}

#[derive(Debug, Default, Clone)]
pub struct EngineStats {
    pub steps: u64,
    pub tokens_sampled: u64,
    pub tokens_forced: u64,
    pub weight_updates: u64,
    pub kv_recomputes: u64,
    pub recompute_steps: u64,
    pub stall_steps: u64,
    pub finished: u64,
    // ---- §Perf breakdown (accumulated microseconds) ----
    /// building + staging the per-step inputs (arena → device)
    pub stage_us: u64,
    /// decode-graph dispatch
    pub execute_us: u64,
    /// selective output readback (next_tok/chosen_lp, + lp_all when
    /// capturing distributions)
    pub readback_us: u64,
    /// decode-blocking time inside eager `set_weights` calls (the full
    /// transfer stall the overlapped path eliminates)
    pub weight_stall_us: u64,
    /// shadow-staging work done between decode steps by the overlapped
    /// path (off the stall path by construction)
    pub weight_stage_us: u64,
    /// weight swaps that landed via the overlapped (zero-stall) path
    pub overlapped_commits: u64,
    /// times the KV cache had to be staged from a host literal (engine
    /// init, recompute replay, or the tuple-readback fallback); the
    /// device-resident steady state keeps this at 1 total
    pub kv_restages: u64,
}

/// Captured distribution row (Fig 7): sampled token's full log-dist.
#[derive(Debug, Clone)]
pub struct DistRow {
    pub seq_id: u64,
    /// index within the generated part of the sequence
    pub gen_index: usize,
    pub logdist: Vec<f32>,
    pub version: u64,
}

#[derive(Debug, Default)]
pub struct StepOutcome {
    pub finished: Vec<Rollout>,
    pub tokens_sampled: usize,
    /// true when no slot had work
    pub idle: bool,
}

/// A staged parameter buffer with its source literal kept alive.
///
/// Buffer staging is asynchronous on the TFRT CPU client: the source
/// literal must outlive any in-flight host→device copy. Pairing the two
/// makes that structural, which is what lets weight staging skip the old
/// per-buffer blocking readback. The host copy is transient, not pinned:
/// the first execute that consumes the buffers awaits their readiness,
/// after which the engine drops the sources (`release_param_sources`) —
/// so steady state holds no host-side weight copy, same as before.
struct StagedParam {
    buf: PjRtBuffer,
    src: Option<Literal>,
}

/// Where the KV cache currently lives.
///
/// Steady state is `Device`: the previous step's KV output buffer is fed
/// straight back as the next step's operand — zero host traffic. `Host`
/// occurs at init, after a recompute replay seeds fresh zeros, and on
/// builds whose executable returns a single tuple (the readback
/// fallback); it costs one staging on the next step.
enum KvState {
    Device(PjRtBuffer),
    Host(Literal),
}

pub struct Engine {
    pub cfg: EngineCfg,
    variant: Variant,
    graph: Rc<crate::runtime::Graph>,
    /// double-buffered device-resident weights: the active set serves
    /// decode; incoming versions stage into the shadow set between steps
    /// and swap atomically at a step boundary (§Perf)
    params: ShadowSet<StagedParam>,
    kv: KvState,
    slots: Vec<Option<SeqState>>,
    stalled: Vec<bool>,
    pending: VecDeque<SeqState>,
    allocator: BlockAllocator,
    rng: Rng,
    clock: Stopwatch,
    next_seq_id: u64,
    actor_id: usize,
    pub stats: EngineStats,
    pub captured: Vec<DistRow>,
    /// reusable per-step input staging buffers (no hot-loop allocation)
    arena: StepArena,
    /// true between a weight commit and the first execute that consumes
    /// the new buffers (see `release_param_sources`)
    param_sources_pending: bool,
}

impl Engine {
    pub fn new(
        rt: &mut Runtime,
        cfg: EngineCfg,
        init_params: &[HostTensor],
        actor_id: usize,
        rng: Rng,
    ) -> Result<Engine> {
        let variant = rt.manifest.variant(&cfg.variant)?.clone();
        crate::runtime::check_params(&variant, init_params)?;
        let graph = rt.graph(&cfg.variant, "decode")?;
        let kv = KvState::Host(HostTensor::zeros_f32(&variant.kv_shape()).to_literal()?);
        let allocator = match cfg.kv_blocks {
            Some(n) => BlockAllocator::new(n, cfg.block_size),
            None => BlockAllocator::for_slots(variant.gen_batch, variant.max_seq, cfg.block_size),
        };
        let b = variant.gen_batch;
        let v = variant.vocab;
        let arena = StepArena::new(b, v, PAD_ID, cfg.temperature);
        let mut eng = Engine {
            cfg,
            slots: (0..b).map(|_| None).collect(),
            stalled: vec![false; b],
            pending: VecDeque::new(),
            allocator,
            rng,
            clock: Stopwatch::new(),
            next_seq_id: 1,
            actor_id,
            stats: EngineStats::default(),
            captured: Vec::new(),
            arena,
            variant,
            graph,
            params: ShadowSet::new(),
            kv,
            param_sources_pending: false,
        };
        // stage the initial parameter set (version 0) — not counted as a
        // weight update
        eng.params.begin(0, init_params.len());
        for t in init_params {
            eng.stage_tensor_into_shadow(t)?;
        }
        eng.params.commit().expect("initial parameter set complete");
        eng.param_sources_pending = true;
        Ok(eng)
    }

    pub fn variant(&self) -> &Variant {
        &self.variant
    }

    pub fn current_version(&self) -> u64 {
        self.params.active_version()
    }

    pub fn n_active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn n_pending(&self) -> usize {
        self.pending.len()
    }

    /// Total sequences in flight (active + queued).
    pub fn load(&self) -> usize {
        self.n_active() + self.n_pending()
    }

    pub fn n_slots(&self) -> usize {
        self.slots.len()
    }

    /// True while the KV cache is device-resident (steady decode state).
    pub fn kv_on_device(&self) -> bool {
        matches!(self.kv, KvState::Device(_))
    }

    /// Paper API `/v1/chat/completions` (enqueue form): submit a prompt.
    /// Rollouts sharing `group_id` form one advantage group.
    pub fn add_request(&mut self, problem: Problem, prompt_tokens: Vec<i32>, group_id: u64) -> u64 {
        let id = self.next_seq_id;
        self.next_seq_id += 1;
        let seq = SeqState::new(
            id,
            group_id,
            problem,
            prompt_tokens,
            crate::model::tokenizer::BOS_ID,
            self.cfg.max_new_tokens,
            self.clock.seconds(),
        );
        self.pending.push_back(seq);
        id
    }

    // ---------------- weight updates ----------------

    /// Validate and stage one tensor into the shadow set, pairing the
    /// buffer with its keep-alive source literal. Returns true when the
    /// shadow set became complete.
    fn stage_tensor_into_shadow(&mut self, t: &HostTensor) -> Result<bool> {
        let idx = self.params.staged();
        let specs = &self.variant.params;
        if idx >= specs.len() {
            bail!("weight update already fully staged ({} tensors)", specs.len());
        }
        let spec = &specs[idx];
        if t.shape() != spec.shape.as_slice() {
            bail!(
                "param '{}' shape mismatch: got {:?}, want {:?}",
                spec.name,
                t.shape(),
                spec.shape
            );
        }
        let lit = t.to_literal()?;
        let buf = self.graph.stage(&lit)?;
        self.params.push(StagedParam { buf, src: Some(lit) })
    }

    /// Drop the keep-alive source literals once the active buffers have
    /// been consumed by at least one execute (which awaits their
    /// readiness, so the async H2D copies are provably complete). Cheap
    /// no-op after the first post-commit call.
    fn release_param_sources(&mut self) {
        if !self.param_sources_pending {
            return;
        }
        for p in self.params.active_mut() {
            p.src = None;
        }
        self.param_sources_pending = false;
    }

    /// Swap the complete shadow set in and run the post-swap bookkeeping.
    /// The §5.1 recompute ablation, when enabled, blocks decoding on a
    /// full replay in *both* swap paths — that time is recorded as
    /// `weight_stall_us` here so the overlapped path's zero-stall claim
    /// stays honest about what it does (and does not) eliminate.
    fn finish_commit(&mut self) -> Result<()> {
        self.params.commit().expect("finish_commit requires a ready shadow set");
        self.param_sources_pending = true;
        self.stats.weight_updates += 1;
        if self.cfg.recompute_kv_on_update && self.n_active() > 0 {
            let t0 = Instant::now();
            self.recompute_kv()?;
            self.stats.weight_stall_us += t0.elapsed().as_micros() as u64;
        }
        Ok(())
    }

    /// Paper API `request_weight_update`, eager form: stage the whole set
    /// and swap before returning. Decoding stalls for the full transfer —
    /// the time lands in `stats.weight_stall_us`. KV cache is retained
    /// (default) or recomputed (cfg flag, §5.1).
    pub fn set_weights(&mut self, version: u64, params: &[HostTensor]) -> Result<()> {
        let t0 = Instant::now();
        crate::runtime::check_params(&self.variant, params)?;
        self.params.begin(version, params.len());
        for t in params {
            self.stage_tensor_into_shadow(t)?;
        }
        // the transfer stall (staging); recompute, if any, is accounted
        // inside finish_commit
        self.stats.weight_stall_us += t0.elapsed().as_micros() as u64;
        self.finish_commit()?;
        Ok(())
    }

    /// Overlapped form, step 1: open a shadow set for `version`.
    /// `n_params` is the size of the incoming set — validated up front so
    /// a malformed publish errors loudly here (like the eager path's
    /// `check_params`) instead of leaving a shadow set that can never
    /// complete. Any partially staged update is discarded.
    pub fn begin_weight_update(&mut self, version: u64, n_params: usize) -> Result<()> {
        let want = self.variant.params.len();
        if n_params != want {
            bail!("weight update param count mismatch: got {n_params}, manifest says {want}");
        }
        self.params.begin(version, want);
        Ok(())
    }

    /// Overlapped form, step 2: stage one tensor chunk between decode
    /// steps. Returns true once the shadow set is complete. The time
    /// lands in `stats.weight_stage_us` — interleaved with decoding, not
    /// a stall.
    pub fn stage_weight_tensor(&mut self, t: &HostTensor) -> Result<bool> {
        ensure!(
            self.params.staging(),
            "no weight update in progress (call begin_weight_update)"
        );
        let t0 = Instant::now();
        let ready = self.stage_tensor_into_shadow(t)?;
        self.stats.weight_stage_us += t0.elapsed().as_micros() as u64;
        Ok(ready)
    }

    /// True when a fully staged shadow set is waiting for `commit_weights`.
    pub fn weight_update_ready(&self) -> bool {
        self.params.ready()
    }

    /// Version currently staging into the shadow set, if any.
    pub fn weight_staging_version(&self) -> Option<u64> {
        if self.params.staging() {
            Some(self.params.staging_version())
        } else {
            None
        }
    }

    /// Drop an in-progress overlapped update (a newer version appeared).
    pub fn abort_weight_update(&mut self) {
        self.params.abort();
    }

    /// Overlapped form, step 3: atomically swap the staged set in at a
    /// step boundary. A pointer exchange — the transfer itself
    /// contributes zero to `weight_stall_us` (the opt-in §5.1 KV
    /// recompute, which stalls both paths equally, is still recorded).
    /// Returns the committed version, or None when the shadow set is not
    /// complete (nothing changes).
    pub fn commit_weights(&mut self) -> Result<Option<u64>> {
        if !self.params.ready() {
            return Ok(None);
        }
        self.finish_commit()?;
        self.stats.overlapped_commits += 1;
        Ok(Some(self.params.active_version()))
    }

    // ---------------- decode loop ----------------

    /// Admit pending sequences into free slots (in-flight adds).
    fn admit(&mut self) {
        for i in 0..self.slots.len() {
            if self.slots[i].is_some() {
                continue;
            }
            let Some(seq) = self.pending.front() else { break };
            if !self.allocator.can_admit(seq.total_len()) {
                break; // out of KV blocks: wait for a release
            }
            let seq = self.pending.pop_front().unwrap();
            self.allocator
                .admit(seq.seq_id, seq.total_len())
                .expect("can_admit checked");
            self.slots[i] = Some(seq);
            self.stalled[i] = false;
        }
    }

    /// One decode step for every busy slot. Returns finished rollouts.
    pub fn step(&mut self) -> Result<StepOutcome> {
        self.admit();
        let b = self.variant.gen_batch;
        let vsz = self.variant.vocab;
        if self.n_active() == 0 {
            return Ok(StepOutcome { idle: true, ..Default::default() });
        }

        // KV growth check: a slot whose next token needs a new block may
        // stall when the pool is over-committed (vLLM would preempt).
        for i in 0..b {
            if let Some(s) = &self.slots[i] {
                let ok = self.allocator.grow(s.seq_id, s.pos + 1).unwrap_or(false);
                self.stalled[i] = !ok;
                if !ok {
                    self.stats.stall_steps += 1;
                }
            }
        }

        // ---- build inputs in the reusable arena (no allocation) ----
        let t_stage = Instant::now();
        self.arena.reset();
        for (i, slot) in self.slots.iter().enumerate() {
            if let Some(s) = slot {
                if self.stalled[i] {
                    continue;
                }
                self.arena.set_slot(i, s.pos, s.cur_token(), s.forced_next());
            }
        }
        if self.cfg.greedy {
            self.arena.zero_gumbel();
        } else {
            self.rng.fill_gumbel(&mut self.arena.gumbel);
        }

        // NOTE: buffer staging is asynchronous on the TFRT CPU client —
        // the source literals must outlive the execute call (the upstream
        // crate's execute() awaits readiness for the same reason), so
        // `lits` is bound to a local that lives past run_buffers_b.
        let lits = self.arena.to_literals()?;
        let pos_b = self.graph.stage(&lits.pos)?;
        let cur_b = self.graph.stage(&lits.cur)?;
        let gum_b = self.graph.stage(&lits.gumbel)?;
        let ftok_b = self.graph.stage(&lits.ftok)?;
        let fmask_b = self.graph.stage(&lits.fmask)?;
        let temp_b = self.graph.stage(&lits.temp)?;
        // steady state feeds the previous step's KV output buffer straight
        // back; only a host-resident KV (init/recompute/fallback) stages
        let kv_staged: PjRtBuffer;
        let kv_ref: &PjRtBuffer = match &self.kv {
            KvState::Device(buf) => buf,
            KvState::Host(l) => {
                self.stats.kv_restages += 1;
                kv_staged = self.graph.stage(l)?;
                &kv_staged
            }
        };

        let mut inputs: Vec<&PjRtBuffer> = self.params.active().iter().map(|p| &p.buf).collect();
        let kv_idx = inputs.len();
        inputs.push(kv_ref);
        inputs.push(&pos_b);
        inputs.push(&cur_b);
        inputs.push(&gum_b);
        inputs.push(&ftok_b);
        inputs.push(&fmask_b);
        inputs.push(&temp_b);
        self.stats.stage_us += t_stage.elapsed().as_micros() as u64;

        let t_exec = Instant::now();
        let mut outs = self.graph.run_buffers_b(&inputs, &[kv_idx]).context("decode step")?;
        self.stats.execute_us += t_exec.elapsed().as_micros() as u64;

        // ---- selective readback ----
        // outputs: next_tok[B], chosen_lp[B], lp_all[B,V], kv', ent[B].
        // Only the O(B) outputs cross the boundary each step; lp_all only
        // under capture_dist, the KV and entropy never.
        let t_read = Instant::now();
        let next = outs.read_vec::<i32>(0)?;
        let lps = outs.read_vec::<f32>(1)?;
        let lp_all = if self.cfg.capture_dist {
            Some(outs.read_vec::<f32>(2)?)
        } else {
            None
        };
        self.stats.readback_us += t_read.elapsed().as_micros() as u64;
        drop(inputs);
        self.kv = match outs.take(3)? {
            DeviceVal::Buf(buf) => KvState::Device(buf),
            DeviceVal::Lit(l) => KvState::Host(l),
        };
        // the execute consumed the active param buffers: their keep-alive
        // host sources are no longer needed
        self.release_param_sources();
        self.stats.steps += 1;

        // advance states, collect finishes
        let mut outcome = StepOutcome::default();
        let t_now = self.clock.seconds();
        for i in 0..b {
            if self.stalled[i] {
                continue;
            }
            let Some(s) = self.slots[i].as_mut() else { continue };
            let was_forced = s.forced_next().is_some();
            if was_forced {
                self.stats.tokens_forced += 1;
            } else {
                self.stats.tokens_sampled += 1;
                outcome.tokens_sampled += 1;
                if let Some(all) = &lp_all {
                    self.captured.push(DistRow {
                        seq_id: s.seq_id,
                        gen_index: s.gen_len(),
                        logdist: all[i * vsz..(i + 1) * vsz].to_vec(),
                        version: self.params.active_version(),
                    });
                }
            }
            s.advance(
                next[i],
                lps[i],
                self.params.active_version(),
                EOS_ID,
                self.variant.max_seq,
            );
            if s.finished() {
                let s = self.slots[i].take().unwrap();
                self.allocator.release(s.seq_id).expect("release admitted seq");
                self.stats.finished += 1;
                outcome.finished.push(s.into_rollout(self.actor_id, t_now));
            }
        }
        Ok(outcome)
    }

    /// Rebuild the KV cache for all active sequences under the current
    /// weights by force-replaying their streams (Fig 7 "KV cache
    /// recomputed" mode). Does not touch sequence state or stats other
    /// than recompute counters. Cold path: keeps simple literal staging
    /// for the replay inputs, but hoists the loop-invariant literals and
    /// reuses the per-iteration index buffers.
    fn recompute_kv(&mut self) -> Result<()> {
        let b = self.variant.gen_batch;
        let vsz = self.variant.vocab;
        self.kv = KvState::Host(HostTensor::zeros_f32(&self.variant.kv_shape()).to_literal()?);
        let max_pos = self
            .slots
            .iter()
            .flatten()
            .map(|s| s.pos)
            .max()
            .unwrap_or(0);
        // loop-invariant inputs staged once per replay, not per position
        let zero_gum = HostTensor::zeros_f32(&[b, vsz]).to_literal()?;
        let ftok_l = HostTensor::from_i32(&[b], vec![PAD_ID; b]).to_literal()?;
        let fmask_l = HostTensor::from_f32(&[b], vec![1.0; b]).to_literal()?;
        let temp_l = HostTensor::scalar_f32(self.cfg.temperature).to_literal()?;
        let mut pos = vec![0i32; b];
        let mut cur = vec![PAD_ID; b];
        for p in 0..=max_pos {
            pos.iter_mut().for_each(|x| *x = 0);
            cur.iter_mut().for_each(|x| *x = PAD_ID);
            for (i, slot) in self.slots.iter().enumerate() {
                if let Some(s) = slot {
                    if p <= s.pos {
                        pos[i] = p as i32;
                        cur[i] = s.stream[p];
                    }
                }
            }
            let pos_l = Literal::vec1(&pos);
            let cur_l = Literal::vec1(&cur);
            let kv_staged: PjRtBuffer;
            let kv_ref: &PjRtBuffer = match &self.kv {
                KvState::Device(buf) => buf,
                KvState::Host(l) => {
                    self.stats.kv_restages += 1;
                    kv_staged = self.graph.stage(l)?;
                    &kv_staged
                }
            };
            let pos_b = self.graph.stage(&pos_l)?;
            let cur_b = self.graph.stage(&cur_l)?;
            let gum_b = self.graph.stage(&zero_gum)?;
            let ftok_b = self.graph.stage(&ftok_l)?;
            let fmask_b = self.graph.stage(&fmask_l)?;
            let temp_b = self.graph.stage(&temp_l)?;
            let mut inputs: Vec<&PjRtBuffer> =
                self.params.active().iter().map(|p| &p.buf).collect();
            let kv_idx = inputs.len();
            inputs.push(kv_ref);
            inputs.push(&pos_b);
            inputs.push(&cur_b);
            inputs.push(&gum_b);
            inputs.push(&ftok_b);
            inputs.push(&fmask_b);
            inputs.push(&temp_b);
            let mut outs = self.graph.run_buffers_b(&inputs, &[kv_idx])?;
            drop(inputs);
            self.kv = match outs.take(3)? {
                DeviceVal::Buf(buf) => KvState::Device(buf),
                DeviceVal::Lit(l) => KvState::Host(l),
            };
            self.stats.recompute_steps += 1;
        }
        // replay executes consumed the active param buffers
        self.release_param_sources();
        self.stats.kv_recomputes += 1;
        Ok(())
    }

    /// Abort everything in flight (shutdown path). Returns unfinished
    /// rollouts with `FinishReason::Aborted`.
    pub fn drain(&mut self) -> Vec<Rollout> {
        let t = self.clock.seconds();
        let mut out = Vec::new();
        for slot in self.slots.iter_mut() {
            if let Some(s) = slot.take() {
                self.allocator.release(s.seq_id).ok();
                out.push(s.into_rollout(self.actor_id, t));
            }
        }
        for s in self.pending.drain(..) {
            out.push(s.into_rollout(self.actor_id, t));
        }
        // clear stale stall flags: a drained slot must not carry its old
        // occupant's stall state into the next admission cycle
        for st in self.stalled.iter_mut() {
            *st = false;
        }
        out
    }
}
