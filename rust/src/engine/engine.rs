//! The engine proper: continuous-batching decode loop over the AOT
//! decode graph, with in-flight request admission (pluggable via
//! [`crate::sched::Scheduler`]), in-flight weight updates, and portable
//! in-flight sequences ([`crate::sched::SeqSnapshot`] export/import).
//! See module docs in engine/mod.rs for the hot-path data flow.

use super::arena::StepArena;
use super::kvcache::{replay_window_open, BlockAllocator};
use super::sequence::SeqState;
use crate::data::task::Problem;
use crate::model::tokenizer::{EOS_ID, PAD_ID};
use crate::rl::Rollout;
use crate::runtime::{
    run_decode_step, run_decode_step_paged, run_prefill_chunk, run_prefill_chunk_paged,
    ChunkInputs, DecodeInputs, DeviceVal, HostTensor, PagedInputs, Runtime, StagePlan, TablePlan,
    Variant,
};
use crate::sched::{KvLayout, PreemptPolicy, SchedPolicy, Scheduler, SeqSnapshot, SeqView};
use crate::util::timer::Stopwatch;
use crate::util::Rng;
use crate::weights::ShadowSet;
use anyhow::{bail, ensure, Context, Result};
use std::collections::VecDeque;
use std::rc::Rc;
use std::time::Instant;
use xla::{Literal, PjRtBuffer};

#[derive(Debug, Clone)]
pub struct EngineCfg {
    pub variant: String,
    pub temperature: f32,
    pub max_new_tokens: usize,
    /// device cache layout (`[kv] layout`): Dense keeps the legacy
    /// `[L, 2, B, Tmax, H, hd]` per-slot tensor and uses the block
    /// allocator as an accounting model only; Paged runs the
    /// `decode_paged` graph against the device block pool, with the
    /// allocator's tables shipped as a real graph operand every step
    pub kv_layout: KvLayout,
    /// KV page size for the block allocator
    pub block_size: usize,
    /// total KV blocks; None = sized from `overcommit`
    pub kv_blocks: Option<usize>,
    /// KV pool oversubscription factor (used when `kv_blocks` is None):
    /// the pool holds worst-case-demand / overcommit blocks. 1.0 = exact
    /// (every slot can reach max_seq); 2.0 = half the blocks — admission
    /// and growth then throttle exactly like a full HBM, and the
    /// preemption policy sheds load instead of stalling
    pub overcommit: f64,
    /// admission policy (see `sched::scheduler`); Fifo reproduces the
    /// legacy head-of-line behavior exactly
    pub sched: SchedPolicy,
    /// block-pressure victim rule (`[kv] preempt_policy`): None stalls
    /// the starved slot in place (legacy), Youngest parks the
    /// least-progressed active sequence through the snapshot path
    pub preempt: PreemptPolicy,
    /// coalesced-replay batch (`[kv] replay_batch`): pending pos>0
    /// sequences (imports, parked preemptees) are batch-admitted —
    /// admission holds free slots until min(waiting, batch, slots) can
    /// land in a single `recompute_kv` pass. 1 = legacy admit-eagerly
    pub replay_batch: usize,
    /// record the full per-step log-distribution of sampled tokens
    /// (needed by the Fig 7 KL study; off on the hot path)
    pub capture_dist: bool,
    /// recompute the whole KV cache under new weights at every weight
    /// update (the paper's §5.1 ablation; costs throughput)
    pub recompute_kv_on_update: bool,
    /// greedy decoding: zero Gumbel noise (argmax) — used by the eval
    /// harness (Table 1 protocol)
    pub greedy: bool,
    /// chunked-prefill width (`[kv] prefill_chunk`): rows with more than
    /// one forced token left ride `prefill_chunk` dispatches that ingest
    /// up to W stream tokens at once — ceil(P/W) dispatches for a
    /// P-token prefix — while resident rows keep decoding in the same
    /// dispatch. 1 = legacy token-at-a-time (bit-for-bit identical,
    /// single decode graph); > 1 requires the artifact's chunk graphs
    /// and must not exceed the manifest's compiled width.
    pub prefill_chunk: usize,
}

impl EngineCfg {
    pub fn new(variant: &str) -> Self {
        EngineCfg {
            variant: variant.to_string(),
            temperature: 1.0,
            max_new_tokens: 48,
            kv_layout: KvLayout::Dense,
            block_size: 16,
            kv_blocks: None,
            overcommit: 1.0,
            sched: SchedPolicy::Fifo,
            preempt: PreemptPolicy::None,
            replay_batch: 4,
            capture_dist: false,
            recompute_kv_on_update: false,
            greedy: false,
            prefill_chunk: 1,
        }
    }
}

#[derive(Debug, Default, Clone)]
pub struct EngineStats {
    pub steps: u64,
    pub tokens_sampled: u64,
    pub tokens_forced: u64,
    pub weight_updates: u64,
    pub kv_recomputes: u64,
    pub recompute_steps: u64,
    pub stall_steps: u64,
    /// active sequences parked under KV block pressure (scheduler-driven
    /// preemption): blocks freed, re-queued through the snapshot path,
    /// resumed later via a coalesced replay
    pub preemptions: u64,
    pub finished: u64,
    /// in-flight sequences exported as portable snapshots (drain/kill)
    pub snapshots_exported: u64,
    /// snapshots imported from another engine (migration adoptions)
    pub snapshots_imported: u64,
    /// replay passes triggered by admitting an imported/parked prefix
    pub import_replays: u64,
    /// per-row replay: slots whose KV was actually rebuilt by a replay
    /// pass (admitted imports/preemptees, or every active slot under the
    /// §5.1 full recompute)
    pub replay_rows_rebuilt: u64,
    /// per-row replay: active slots a replay pass left untouched because
    /// their device KV was already resident (the work the legacy
    /// full-batch replay redid every time)
    pub replay_rows_skipped: u64,
    /// gauge, refreshed every step: distinct physical KV blocks the
    /// allocator currently holds (== device pool blocks in use when the
    /// paged layout is active)
    pub kv_device_blocks_in_use: u64,
    // ---- §Perf breakdown (accumulated microseconds) ----
    /// building + staging the per-step inputs (arena → device)
    pub stage_us: u64,
    /// decode-graph dispatch
    pub execute_us: u64,
    /// selective output readback (next_tok/chosen_lp, + lp_all when
    /// capturing distributions)
    pub readback_us: u64,
    /// decode-blocking time inside eager `set_weights` calls (the full
    /// transfer stall the overlapped path eliminates)
    pub weight_stall_us: u64,
    /// shadow-staging work done between decode steps by the overlapped
    /// path (off the stall path by construction)
    pub weight_stage_us: u64,
    /// weight swaps that landed via the overlapped (zero-stall) path
    pub overlapped_commits: u64,
    /// times the KV cache had to be staged from a host literal (engine
    /// init, recompute replay, or the tuple-readback fallback); the
    /// device-resident steady state keeps this at 1 total
    pub kv_restages: u64,
    // ---- chunked prefill (prompt ingestion split out of decode) ----
    /// execute time of `prefill_chunk` dispatches (prompt ingestion and
    /// chunked replay), split out of `execute_us` so the decode-step
    /// latency the throughput model cares about stays clean
    pub prefill_us: u64,
    /// `prefill_chunk` dispatches issued (step interleave + replay)
    pub prefill_chunks: u64,
    /// single-token dispatches the chunking eliminated: each chunk
    /// dispatch covering K positions saves K - 1 of them, so
    /// prompt ingestion to position P books P - ceil(P/W) here
    pub forced_steps_saved: u64,
}

/// Captured distribution row (Fig 7): sampled token's full log-dist.
#[derive(Debug, Clone)]
pub struct DistRow {
    pub seq_id: u64,
    /// index within the generated part of the sequence
    pub gen_index: usize,
    pub logdist: Vec<f32>,
    pub version: u64,
}

#[derive(Debug, Default)]
pub struct StepOutcome {
    pub finished: Vec<Rollout>,
    pub tokens_sampled: usize,
    /// true when no slot had work
    pub idle: bool,
}

/// A staged parameter buffer with its source literal kept alive.
///
/// Buffer staging is asynchronous on the TFRT CPU client: the source
/// literal must outlive any in-flight host→device copy. Pairing the two
/// makes that structural, which is what lets weight staging skip the old
/// per-buffer blocking readback. The host copy is transient, not pinned:
/// the first execute that consumes the buffers awaits their readiness,
/// after which the engine drops the sources (`release_param_sources`) —
/// so steady state holds no host-side weight copy, same as before.
struct StagedParam {
    buf: PjRtBuffer,
    src: Option<Literal>,
}

pub struct Engine {
    pub cfg: EngineCfg,
    variant: Variant,
    graph: Rc<crate::runtime::Graph>,
    /// the `prefill_chunk` graph (loaded only when `cfg.prefill_chunk >
    /// 1): rounds where some row has more than one forced token left
    /// dispatch through this instead of W single decode steps
    chunk_graph: Option<Rc<crate::runtime::Graph>>,
    /// double-buffered device-resident weights: the active set serves
    /// decode; incoming versions stage into the shadow set between steps
    /// and swap atomically at a step boundary (§Perf)
    params: ShadowSet<StagedParam>,
    /// where the KV cache lives. Steady state is `Buf` (device): the
    /// previous step's KV output buffer feeds straight back as the next
    /// step's operand — zero host traffic. `Lit` (host) occurs at init,
    /// after a recompute replay seeds fresh zeros, and on builds whose
    /// executable returns a single tuple; it costs one staging.
    kv: DeviceVal,
    slots: Vec<Option<SeqState>>,
    stalled: Vec<bool>,
    pending: VecDeque<SeqState>,
    allocator: BlockAllocator,
    /// admission policy — owns the pending→slot decisions, including the
    /// KV-block gate that used to be inlined here
    scheduler: Box<dyn Scheduler>,
    /// reusable scheduler-view buffer (admission runs inside the decode
    /// hot loop: no per-step allocation, same rule as the StepArena)
    view_buf: Vec<SeqView>,
    rng: Rng,
    clock: Stopwatch,
    next_seq_id: u64,
    actor_id: usize,
    pub stats: EngineStats,
    pub captured: Vec<DistRow>,
    /// reusable per-step input staging buffers (no hot-loop allocation)
    arena: StepArena,
    /// loop-invariant replay/chunk literals, hoisted out of
    /// `recompute_rows` (they were rebuilt on every replay pass): zero
    /// Gumbel noise, the all-PAD forced-token lane, the all-ones force
    /// mask, and the scalar temperature
    zero_gum_l: Literal,
    pad_ftok_l: Literal,
    ones_fmask_l: Literal,
    temp_l: Literal,
    /// reusable per-row chunk lengths and last-written-position plan
    /// for the chunked dispatch path (no hot-loop allocation)
    chunk_len: Vec<usize>,
    chunk_plan_pos: Vec<i32>,
    /// true between a weight commit and the first execute that consumes
    /// the new buffers (see `release_param_sources`)
    param_sources_pending: bool,
}

impl Engine {
    pub fn new(
        rt: &mut Runtime,
        cfg: EngineCfg,
        init_params: &[HostTensor],
        actor_id: usize,
        rng: Rng,
    ) -> Result<Engine> {
        let variant = rt.manifest.variant(&cfg.variant)?.clone();
        crate::runtime::check_params(&variant, init_params)?;
        let paged = cfg.kv_layout == KvLayout::Paged;
        let graph = rt.graph(&cfg.variant, if paged { "decode_paged" } else { "decode" })?;
        ensure!(
            cfg.prefill_chunk >= 1,
            "[kv] prefill_chunk must be >= 1 (1 = token-at-a-time prefill)"
        );
        let chunk_graph = if cfg.prefill_chunk > 1 {
            ensure!(
                variant.prefill_chunk >= cfg.prefill_chunk,
                "[kv] prefill_chunk {} exceeds the compiled chunk width {} of \
                 variant '{}' — rebuild the artifacts with a wider \
                 ModelConfig.prefill_chunk or lower the setting",
                cfg.prefill_chunk,
                variant.prefill_chunk,
                cfg.variant
            );
            Some(rt.graph(
                &cfg.variant,
                if paged { "prefill_chunk_paged" } else { "prefill_chunk" },
            )?)
        } else {
            None
        };
        let kv = if paged {
            ensure!(
                variant.has_paged_pool(),
                "variant '{}' carries no paged-pool geometry — rebuild the \
                 artifacts (make artifacts) with an aot.py that lowers decode_paged",
                cfg.variant
            );
            ensure!(
                cfg.block_size == variant.kv_block_size,
                "[kv] block_size {} does not match the compiled page size {} — \
                 the block table is a graph operand, so the allocator must \
                 account in graph pages",
                cfg.block_size,
                variant.kv_block_size
            );
            DeviceVal::Lit(HostTensor::zeros_f32(&variant.kv_pool_shape()).to_literal()?)
        } else {
            DeviceVal::Lit(HostTensor::zeros_f32(&variant.kv_shape()).to_literal()?)
        };
        ensure!(
            cfg.overcommit > 0.0,
            "kv overcommit must be positive, got {}",
            cfg.overcommit
        );
        let allocator = match cfg.kv_blocks {
            Some(n) => BlockAllocator::new(n, cfg.block_size),
            None => {
                let full = variant.gen_batch * variant.max_seq.div_ceil(cfg.block_size);
                let n = ((full as f64 / cfg.overcommit).ceil() as usize).max(1);
                BlockAllocator::new(n, cfg.block_size)
            }
        };
        if paged {
            // every allocatable block must be backed by a device pool
            // block; the pool's trailing slot is the trash block and is
            // never handed out
            ensure!(
                allocator.total_blocks() <= variant.kv_pool_blocks - 1,
                "allocator wants {} KV blocks but the compiled pool backs only {} \
                 (+1 trash) — lower [kv] kv_blocks/overcommit or recompile",
                allocator.total_blocks(),
                variant.kv_pool_blocks - 1
            );
        }
        let scheduler = cfg.sched.build_with_preempt(cfg.preempt);
        let b = variant.gen_batch;
        let v = variant.vocab;
        // idle rows park their (discarded) KV write at max_seq - 1: the
        // decode graph scatters at pos[b] for every row, and position 0
        // holds live BOS K/V (see arena module docs)
        let park = (variant.max_seq - 1) as i32;
        let mut arena = StepArena::new(b, v, PAD_ID, cfg.temperature, park);
        if paged {
            arena.enable_paged(variant.kv_blocks_per_row, (variant.kv_pool_blocks - 1) as i32);
        }
        if chunk_graph.is_some() {
            // lanes are sized to the *compiled* width (the graph operand
            // shape); a smaller cfg.prefill_chunk just leaves the tail
            // lanes inert every dispatch
            arena.enable_chunk(variant.prefill_chunk);
        }
        // replay/chunk literals that never change over the engine's life:
        // zero gumbel (forced steps ignore sampling), all-PAD forcing,
        // all-ones force mask, temperature
        let zero_gum_l = HostTensor::zeros_f32(&[b, v]).to_literal()?;
        let pad_ftok_l = HostTensor::from_i32(&[b], vec![PAD_ID; b]).to_literal()?;
        let ones_fmask_l = HostTensor::from_f32(&[b], vec![1.0; b]).to_literal()?;
        let temp_l = HostTensor::scalar_f32(cfg.temperature).to_literal()?;
        let mut eng = Engine {
            cfg,
            slots: (0..b).map(|_| None).collect(),
            stalled: vec![false; b],
            pending: VecDeque::new(),
            allocator,
            scheduler,
            view_buf: Vec::new(),
            rng,
            clock: Stopwatch::new(),
            next_seq_id: 1,
            actor_id,
            stats: EngineStats::default(),
            captured: Vec::new(),
            arena,
            zero_gum_l,
            pad_ftok_l,
            ones_fmask_l,
            temp_l,
            chunk_len: vec![0; b],
            chunk_plan_pos: vec![park; b],
            variant,
            graph,
            chunk_graph,
            params: ShadowSet::new(),
            kv,
            param_sources_pending: false,
        };
        // stage the initial parameter set (version 0) — not counted as a
        // weight update
        eng.params.begin(0, init_params.len());
        for t in init_params {
            eng.stage_tensor_into_shadow(t)?;
        }
        eng.params.commit().expect("initial parameter set complete");
        eng.param_sources_pending = true;
        Ok(eng)
    }

    pub fn variant(&self) -> &Variant {
        &self.variant
    }

    pub fn current_version(&self) -> u64 {
        self.params.active_version()
    }

    pub fn n_active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn n_pending(&self) -> usize {
        self.pending.len()
    }

    /// Total sequences in flight (active + queued).
    pub fn load(&self) -> usize {
        self.n_active() + self.n_pending()
    }

    pub fn n_slots(&self) -> usize {
        self.slots.len()
    }

    /// True while the KV cache is device-resident (steady decode state).
    pub fn kv_on_device(&self) -> bool {
        self.kv.is_device()
    }

    /// Name of the active admission policy.
    pub fn sched_name(&self) -> &'static str {
        self.scheduler.name()
    }

    // ---- deterministic-resume cursors (PRLCKPT3) ----
    //
    // Together these two cursors are the engine's contribution to a
    // full-run bit-identical resume: the sampling-RNG cursor continues
    // the exact Gumbel stream, and the admission cursor keeps local
    // sequence ids (and therefore admission order and victim tie-breaks)
    // collision-free across the restart. Checkpoint harnesses carry them
    // in `TrainState::{engine_rng, sched_cursor}`.

    /// The sampling-RNG cursor ([`crate::util::Rng::state_words`]).
    pub fn rng_words(&self) -> [u64; 4] {
        self.rng.state_words()
    }

    /// Restore the sampling stream from a saved cursor. Refuses the
    /// all-zero cursor: that is the PRLCKPT2-compat sentinel for "this
    /// state carries no engine cursor", and a zero PCG state is
    /// degenerate (a constant stream; `below()` would spin forever).
    pub fn restore_rng(&mut self, words: [u64; 4]) -> Result<()> {
        ensure!(
            words != [0u64; 4],
            "all-zero engine RNG cursor (a PRLCKPT2-era state?) — refusing a \
             degenerate sampling stream"
        );
        self.rng = Rng::from_state_words(words);
        Ok(())
    }

    /// The scheduler admission cursor: the next local sequence id (==
    /// sequences ever enqueued on this engine).
    pub fn admission_cursor(&self) -> u64 {
        self.next_seq_id
    }

    /// Restore the admission cursor. Refuses to move backwards — a
    /// rewound cursor would hand out ids that collide with sequences
    /// already tracked by the allocator and scheduler.
    pub fn restore_admission_cursor(&mut self, cursor: u64) -> Result<()> {
        ensure!(
            cursor >= self.next_seq_id,
            "admission cursor {} would rewind below the engine's next id {}",
            cursor,
            self.next_seq_id
        );
        self.next_seq_id = cursor;
        Ok(())
    }

    // ---- KV-memory pressure (the allocator's live accounting) ----

    pub fn kv_total_blocks(&self) -> usize {
        self.allocator.total_blocks()
    }

    pub fn kv_free_blocks(&self) -> usize {
        self.allocator.free_blocks()
    }

    /// Distinct physical blocks currently held.
    pub fn kv_held_blocks(&self) -> usize {
        self.allocator.held_blocks()
    }

    /// Physical blocks saved right now by prefix sharing (logical table
    /// references minus distinct blocks).
    pub fn kv_shared_saved_blocks(&self) -> usize {
        self.allocator.shared_saved_blocks()
    }

    /// Copy-on-write forks performed (first divergent writes into a
    /// shared prompt block).
    pub fn kv_cow_forks(&self) -> u64 {
        self.allocator.cow_forks()
    }

    /// Run the allocator's conservation checks (tests/diagnostics).
    pub fn kv_check(&self) -> Result<()> {
        self.allocator.check_invariants()
    }

    /// Paper API `/v1/chat/completions` (enqueue form): submit a prompt.
    /// Rollouts sharing `group_id` form one advantage group — and since
    /// group members decode the same prompt, the group id doubles as the
    /// KV prefix-sharing key (callers must not reuse a group id across
    /// different prompts; everywhere in this codebase a group is one
    /// problem).
    pub fn add_request(&mut self, problem: Problem, prompt_tokens: Vec<i32>, group_id: u64) -> u64 {
        let id = self.next_seq_id;
        self.next_seq_id += 1;
        let seq = SeqState::new(
            id,
            group_id,
            problem,
            prompt_tokens,
            crate::model::tokenizer::BOS_ID,
            self.cfg.max_new_tokens,
            self.clock.seconds(),
        );
        self.pending.push_back(seq);
        id
    }

    // ---------------- portable in-flight sequences ----------------

    /// Adopt a sequence exported from another engine (migration). The
    /// snapshot joins the pending queue; when the scheduler admits it,
    /// its missing KV prefix is rebuilt by a full replay (the existing
    /// `recompute_kv` path — `stats.import_replays` counts). Group id and
    /// generated prefix are preserved verbatim; the engine assigns a
    /// fresh local sequence id, which is returned.
    pub fn import_snapshot(&mut self, snap: &SeqSnapshot, problem: Problem) -> Result<u64> {
        snap.validate()?;
        ensure!(
            problem.id == snap.problem_id,
            "problem {} does not match snapshot problem {}",
            problem.id,
            snap.problem_id
        );
        ensure!(
            snap.total_len() < self.variant.max_seq,
            "snapshot stream ({} tokens) leaves no room under max_seq {}",
            snap.total_len(),
            self.variant.max_seq
        );
        let id = self.next_seq_id;
        self.next_seq_id += 1;
        let seq = SeqState::from_snapshot(snap, id, problem, self.clock.seconds());
        self.pending.push_back(seq);
        self.stats.snapshots_imported += 1;
        Ok(id)
    }

    /// Drain every in-flight sequence (active slots + pending queue) into
    /// portable snapshots — the kill/descale path. Unlike [`Engine::drain`]
    /// nothing is aborted: the snapshots resume on another engine with
    /// group ids and generated prefixes intact. The engine is left empty.
    pub fn export_snapshots(&mut self) -> Vec<SeqSnapshot> {
        let words = self.rng.state_words();
        let mut out = Vec::new();
        for slot in self.slots.iter_mut() {
            if let Some(s) = slot.take() {
                self.allocator.release(s.seq_id).ok();
                out.push(s.to_snapshot(words));
            }
        }
        for s in self.pending.drain(..) {
            out.push(s.to_snapshot(words));
        }
        for st in self.stalled.iter_mut() {
            *st = false;
        }
        self.stats.snapshots_exported += out.len() as u64;
        out
    }

    // ---------------- weight updates ----------------

    /// Validate and stage one tensor into the shadow set, pairing the
    /// buffer with its keep-alive source literal. Returns true when the
    /// shadow set became complete.
    fn stage_tensor_into_shadow(&mut self, t: &HostTensor) -> Result<bool> {
        let idx = self.params.staged();
        let specs = &self.variant.params;
        if idx >= specs.len() {
            bail!("weight update already fully staged ({} tensors)", specs.len());
        }
        let spec = &specs[idx];
        if t.shape() != spec.shape.as_slice() {
            bail!(
                "param '{}' shape mismatch: got {:?}, want {:?}",
                spec.name,
                t.shape(),
                spec.shape
            );
        }
        let lit = t.to_literal()?;
        let buf = self.graph.stage(&lit)?;
        self.params.push(StagedParam { buf, src: Some(lit) })
    }

    /// Drop the keep-alive source literals once the active buffers have
    /// been consumed by at least one execute (which awaits their
    /// readiness, so the async H2D copies are provably complete). Cheap
    /// no-op after the first post-commit call.
    fn release_param_sources(&mut self) {
        if !self.param_sources_pending {
            return;
        }
        for p in self.params.active_mut() {
            p.src = None;
        }
        self.param_sources_pending = false;
    }

    /// Swap the complete shadow set in and run the post-swap bookkeeping.
    /// The §5.1 recompute ablation, when enabled, blocks decoding on a
    /// full replay in *both* swap paths — that time is recorded as
    /// `weight_stall_us` here so the overlapped path's zero-stall claim
    /// stays honest about what it does (and does not) eliminate.
    fn finish_commit(&mut self) -> Result<()> {
        self.params.commit().expect("finish_commit requires a ready shadow set");
        self.param_sources_pending = true;
        self.stats.weight_updates += 1;
        if self.cfg.recompute_kv_on_update && self.n_active() > 0 {
            let t0 = Instant::now();
            self.recompute_kv()?;
            self.stats.weight_stall_us += t0.elapsed().as_micros() as u64;
        }
        Ok(())
    }

    /// Paper API `request_weight_update`, eager form: stage the whole set
    /// and swap before returning. Decoding stalls for the full transfer —
    /// the time lands in `stats.weight_stall_us`. KV cache is retained
    /// (default) or recomputed (cfg flag, §5.1).
    pub fn set_weights(&mut self, version: u64, params: &[HostTensor]) -> Result<()> {
        let t0 = Instant::now();
        crate::runtime::check_params(&self.variant, params)?;
        self.params.begin(version, params.len());
        for t in params {
            self.stage_tensor_into_shadow(t)?;
        }
        // the transfer stall (staging); recompute, if any, is accounted
        // inside finish_commit
        self.stats.weight_stall_us += t0.elapsed().as_micros() as u64;
        self.finish_commit()?;
        Ok(())
    }

    /// Overlapped form, step 1: open a shadow set for `version`.
    /// `n_params` is the size of the incoming set — validated up front so
    /// a malformed publish errors loudly here (like the eager path's
    /// `check_params`) instead of leaving a shadow set that can never
    /// complete. Any partially staged update is discarded.
    pub fn begin_weight_update(&mut self, version: u64, n_params: usize) -> Result<()> {
        let want = self.variant.params.len();
        if n_params != want {
            bail!("weight update param count mismatch: got {n_params}, manifest says {want}");
        }
        self.params.begin(version, want);
        Ok(())
    }

    /// Overlapped form, step 2: stage one tensor chunk between decode
    /// steps. Returns true once the shadow set is complete. The time
    /// lands in `stats.weight_stage_us` — interleaved with decoding, not
    /// a stall.
    pub fn stage_weight_tensor(&mut self, t: &HostTensor) -> Result<bool> {
        ensure!(
            self.params.staging(),
            "no weight update in progress (call begin_weight_update)"
        );
        let t0 = Instant::now();
        let ready = self.stage_tensor_into_shadow(t)?;
        self.stats.weight_stage_us += t0.elapsed().as_micros() as u64;
        Ok(ready)
    }

    /// True when a fully staged shadow set is waiting for `commit_weights`.
    pub fn weight_update_ready(&self) -> bool {
        self.params.ready()
    }

    /// Version currently staging into the shadow set, if any.
    pub fn weight_staging_version(&self) -> Option<u64> {
        if self.params.staging() {
            Some(self.params.staging_version())
        } else {
            None
        }
    }

    /// Drop an in-progress overlapped update (a newer version appeared).
    pub fn abort_weight_update(&mut self) {
        self.params.abort();
    }

    /// Overlapped form, step 3: atomically swap the staged set in at a
    /// step boundary. A pointer exchange — the transfer itself
    /// contributes zero to `weight_stall_us` (the opt-in §5.1 KV
    /// recompute, which stalls both paths equally, is still recorded).
    /// Returns the committed version, or None when the shadow set is not
    /// complete (nothing changes).
    pub fn commit_weights(&mut self) -> Result<Option<u64>> {
        if !self.params.ready() {
            return Ok(None);
        }
        self.finish_commit()?;
        self.stats.overlapped_commits += 1;
        Ok(Some(self.params.active_version()))
    }

    // ---------------- decode loop ----------------

    /// Admit pending sequences into free slots (in-flight adds), one
    /// scheduler pick per free slot. Returns the slot indices of admitted
    /// sequences that carry progress made elsewhere (imported snapshots
    /// or parked preemptees), i.e. exactly the rows whose KV prefix must
    /// be replayed before the next decode step — resident neighbors stay
    /// out of the replay (per-row replay).
    ///
    /// **Coalesced replay**: every admitted pos>0 sequence forces the
    /// same full-batch `recompute_kv` pass, so N of them trickling into
    /// slots as they free would cost up to N replays where one would do.
    /// When any pos>0 sequence waits, admission holds *every* free slot
    /// until min(waiting, replay_batch, slots) can be seated together —
    /// then one replay covers the whole batch (`replay_batch = 1`
    /// reproduces the legacy admit-eagerly behavior exactly).
    ///
    /// **Prefix sharing**: fresh sequences (nothing generated) admit
    /// under their group id as the share key — the G members of a GRPO
    /// group reference one set of prompt blocks (refcount G) instead of
    /// allocating G copies; the gate the scheduler consults is
    /// share-aware, so a group member can be admissible when a
    /// same-length stranger is not.
    fn admit(&mut self) -> Vec<usize> {
        let mut replay_slots = Vec::new();
        let free_slots = self.slots.iter().filter(|s| s.is_none()).count();
        if free_slots == 0 || self.pending.is_empty() {
            return replay_slots;
        }
        let waiting_replay = self.pending.iter().filter(|s| s.pos > 0).count();
        // when the window is closed the hold applies to *replay
        // candidates only*: fresh (pos == 0) sequences trigger no replay,
        // so seating them costs the coalescing nothing — holding every
        // free slot for them too starved fresh prompts whenever imports
        // queued up (the gate below refuses pos > 0 while closed)
        let window_open = replay_window_open(
            waiting_replay,
            free_slots,
            self.cfg.replay_batch,
            self.slots.len(),
        );
        if !window_open && self.pending.iter().all(|s| s.pos > 0) {
            return replay_slots; // hold the slots for the coalesced batch
        }
        let mut views_built = false;
        // maps view_buf index -> pending index: identity when the window
        // is open; skips replay candidates while it is closed so a
        // pos > 0 head cannot head-of-line-block fresh prompts under FIFO
        let mut pend_idx: Vec<usize> = Vec::new();
        for i in 0..self.slots.len() {
            if self.slots[i].is_some() {
                continue;
            }
            if self.pending.is_empty() {
                break;
            }
            if !views_built {
                // built once per admit() into the reusable buffer, kept
                // in sync with `pending` as picks are removed below
                self.view_buf.clear();
                pend_idx.clear();
                let bs = self.cfg.block_size;
                for (pi, s) in self.pending.iter().enumerate() {
                    if !window_open && s.pos > 0 {
                        continue; // waits for the coalesced replay batch
                    }
                    pend_idx.push(pi);
                    self.view_buf.push(s.view(s.total_len().div_ceil(bs)));
                }
                views_built = true;
            }
            if self.view_buf.is_empty() {
                break; // only held replay candidates remain
            }
            let allocator = &self.allocator;
            let gate = |v: &SeqView| {
                if !window_open && v.pos > 0 {
                    return false; // replay candidates wait for the window
                }
                if v.gen_len == 0 {
                    allocator.can_admit_shared(v.group_id, v.total_len)
                } else {
                    allocator.can_admit(v.total_len)
                }
            };
            let Some(idx) = self.scheduler.pick(&self.view_buf, &gate) else {
                break; // policy admits nothing (e.g. out of KV blocks)
            };
            let pi = pend_idx.get(idx).copied().unwrap_or(idx);
            let Some(seq) = self.pending.remove(pi) else {
                debug_assert!(false, "scheduler picked out-of-range index {idx}");
                break;
            };
            self.view_buf.remove(idx);
            pend_idx.remove(idx);
            for x in pend_idx.iter_mut() {
                if *x > pi {
                    *x -= 1;
                }
            }
            if seq.gen_len() == 0 {
                self.allocator
                    .admit_shared(seq.seq_id, seq.group_id, seq.total_len())
                    .expect("scheduler picked an admissible sequence");
            } else {
                // imports/parked sequences already diverged: private blocks
                self.allocator
                    .admit(seq.seq_id, seq.total_len())
                    .expect("scheduler picked an admissible sequence");
            }
            if seq.pos > 0 {
                replay_slots.push(i);
            }
            self.slots[i] = Some(seq);
            self.stalled[i] = false;
        }
        replay_slots
    }

    /// Block pressure on slot `i`: ask the scheduler for victims to park
    /// until the starved sequence can grow (or the policy gives up).
    /// Returns whether the growth finally succeeded; if the victim was
    /// the starved sequence itself, its slot is simply left empty.
    fn preempt_for_growth(&mut self, i: usize) -> Result<bool> {
        loop {
            let mut slot_of = Vec::new();
            let mut views = Vec::new();
            let paged = self.arena.is_paged();
            for (slot, s) in self.slots.iter().enumerate() {
                if let Some(s) = s {
                    // block bill the victim rule weighs: under the paged
                    // layout the allocator's share-aware private count
                    // (parking a mostly-shared group member loses less
                    // resident KV); dense keeps the worst-case fill,
                    // which is order-equivalent to the legacy tie-break
                    let kvb = if paged {
                        self.allocator.private_blocks(s.seq_id).unwrap_or_else(|| {
                            s.total_len().div_ceil(self.cfg.block_size)
                        })
                    } else {
                        s.total_len().div_ceil(self.cfg.block_size)
                    };
                    slot_of.push(slot);
                    views.push(s.view(kvb));
                }
            }
            if views.len() <= 1 {
                return Ok(false); // parking the only sequence helps no one
            }
            let stalled_idx = slot_of
                .iter()
                .position(|&sl| sl == i)
                .expect("the starved slot is active");
            let Some(vidx) = self.scheduler.pick_victim(&views, stalled_idx) else {
                return Ok(false); // policy stalls in place (legacy)
            };
            let Some(&vslot) = slot_of.get(vidx) else {
                debug_assert!(false, "scheduler picked out-of-range victim {vidx}");
                return Ok(false);
            };
            self.park_slot(vslot)?;
            if vslot == i {
                return Ok(false); // the starved sequence itself was parked
            }
            let s = self.slots[i].as_ref().expect("starved sequence still resident");
            if self.allocator.grow(s.seq_id, s.pos + 1).unwrap_or(false) {
                return Ok(true);
            }
        }
    }

    /// Preempt one running sequence: release its blocks and send it back
    /// to the pending queue *through the snapshot path* — a park is
    /// exactly a migration export/import without the process boundary, so
    /// the parked sequence re-enters via the same coalesced replay as an
    /// imported one, with its generated prefix, version tags and phase
    /// intact. The local sequence id is retained (its allocator entry is
    /// gone, so nothing collides).
    fn park_slot(&mut self, slot: usize) -> Result<()> {
        let s = self.slots[slot].take().expect("park of an empty slot");
        self.allocator.release(s.seq_id)?;
        self.stalled[slot] = false;
        let snap = s.to_snapshot(self.rng.state_words());
        let parked = SeqState::from_snapshot(&snap, snap.seq_id, s.problem.clone(), s.t_start);
        self.pending.push_back(parked);
        self.stats.preemptions += 1;
        Ok(())
    }

    /// External preemption (the gateway's QoS eviction): park one active
    /// sequence whose id is in `allowed` and return its snapshot to the
    /// caller instead of re-queueing it locally — the first half of a
    /// migration, with the caller (not this engine's pending queue)
    /// owning the resume. Victim choice is the deterministic
    /// `PreemptPolicy::Youngest` rule over the allowed views only, so the
    /// `[kv] preempt_policy = none` ablation (which governs *block-
    /// pressure* stalls) cannot disable latency-sensitive eviction.
    pub fn preempt_external(&mut self, allowed: &[u64]) -> Result<Option<SeqSnapshot>> {
        let paged = self.arena.is_paged();
        let mut slot_of = Vec::new();
        let mut views = Vec::new();
        for (slot, s) in self.slots.iter().enumerate() {
            if let Some(s) = s {
                if !allowed.contains(&s.seq_id) {
                    continue;
                }
                let kvb = if paged {
                    self.allocator
                        .private_blocks(s.seq_id)
                        .unwrap_or_else(|| s.total_len().div_ceil(self.cfg.block_size))
                } else {
                    s.total_len().div_ceil(self.cfg.block_size)
                };
                slot_of.push(slot);
                views.push(s.view(kvb));
            }
        }
        let Some(vidx) = crate::sched::PreemptPolicy::Youngest.pick(&views) else {
            return Ok(None);
        };
        let vslot = slot_of[vidx];
        let s = self.slots[vslot].take().expect("victim slot is active");
        self.allocator.release(s.seq_id)?;
        self.stalled[vslot] = false;
        let snap = s.to_snapshot(self.rng.state_words());
        self.stats.preemptions += 1;
        Ok(Some(snap))
    }

    /// One decode step for every busy slot. Returns finished rollouts.
    pub fn step(&mut self) -> Result<StepOutcome> {
        let replay_slots = self.admit();
        let b = self.variant.gen_batch;
        let vsz = self.variant.vocab;
        if self.n_active() == 0 {
            return Ok(StepOutcome { idle: true, ..Default::default() });
        }
        if !replay_slots.is_empty() {
            // a migrated prefix has no KV on this device: rebuild it via
            // the replay path before decoding. Per-row replay — only the
            // just-admitted rows are re-fed; resident neighbors keep
            // their device KV instead of being redundantly rebuilt (the
            // §5.1 ablation still goes through the full-batch
            // `recompute_kv`, whose point is refreshing everyone).
            self.stats.import_replays += 1;
            self.recompute_rows(&replay_slots, false)?;
        }

        // KV growth check: a slot whose next token needs a new block (or
        // a copy-on-write fork) may hit an exhausted pool when it is
        // over-committed. With a preemption policy the scheduler picks a
        // victim to park (blocks freed through the snapshot path, vLLM's
        // preempt/swap) so the rest keep moving; without one the slot
        // stalls in place (legacy).
        let paged = self.arena.is_paged();
        let w_cfg = if self.chunk_graph.is_some() { self.cfg.prefill_chunk } else { 1 };
        // CoW forks surfaced by this step's growth, to be staged into the
        // copy lanes *after* `arena.reset()` below (which re-parks them)
        let mut forks: Vec<(usize, u32, u32)> = Vec::new();
        for i in 0..b {
            let Some(s) = &self.slots[i] else { continue };
            let (sid, need) = (s.seq_id, s.pos + 1);
            let mut ok = self.allocator.grow(sid, need).unwrap_or(false);
            if !ok {
                ok = self.preempt_for_growth(i)?;
            }
            // chunked prefill: back the whole chunk if the pool allows;
            // a refusal (all-or-nothing growth) just clamps this round's
            // chunk to the capacity already held. Rows with forced
            // tokens left have generated nothing (mid-stream rows sit at
            // pos == stream.len() - 1), so neither grow call here can
            // fork a shared block — the fork capture below stays a
            // single pair per row
            if ok && w_cfg > 1 {
                if let Some(s) = &self.slots[i] {
                    let remaining = s.stream.len() - s.pos;
                    if remaining > 1 {
                        let desired = w_cfg.min(remaining);
                        let _ = self.allocator.grow(s.seq_id, s.pos + desired);
                    }
                }
            }
            if paged {
                // the device copy must ride the same dispatch that first
                // uses the forked table, so capture it here per-row
                if let Some((old, new)) = self.allocator.take_last_fork() {
                    forks.push((i, old, new));
                }
            }
            if self.slots[i].is_none() {
                continue; // the starved sequence itself was parked
            }
            self.stalled[i] = !ok;
            if !ok {
                self.stats.stall_steps += 1;
            }
        }
        self.stats.kv_device_blocks_in_use = self.allocator.held_blocks() as u64;
        if self.n_active() == 0 {
            // preemption can park the last active sequence; it waits in
            // pending for the coalesced re-admission
            return Ok(StepOutcome { idle: true, ..Default::default() });
        }

        // ---- chunked-prefill round plan ----
        // n_i = stream tokens row i feeds this round: up to W for rows
        // still force-feeding a prefix (prompt ingestion), exactly 1 for
        // resident decode rows riding along, clamped to the block-backed
        // capacity. K = max n_i picks the dispatch: K == 1 keeps the
        // single decode graph — the bit-for-bit legacy hot path,
        // including its RNG consumption — and K > 1 rides one chunk
        // dispatch that replaces K single steps.
        let mut k_max = 1usize;
        for i in 0..b {
            self.chunk_len[i] = 0;
            if self.stalled[i] {
                continue;
            }
            let Some(s) = &self.slots[i] else { continue };
            let mut n = w_cfg.min(s.stream.len() - s.pos).max(1);
            if n > 1 {
                let cap = self.allocator.capacity_tokens(s.seq_id).unwrap_or(s.pos + 1);
                n = n.min(cap.saturating_sub(s.pos)).max(1);
            }
            self.chunk_len[i] = n;
            k_max = k_max.max(n);
        }
        let chunked = k_max > 1;

        // ---- build inputs in the reusable arena (no allocation) ----
        let t_arena = Instant::now();
        self.arena.reset();
        if chunked {
            for i in 0..b {
                let n = self.chunk_len[i];
                if n == 0 {
                    continue;
                }
                let s = self.slots[i].as_ref().expect("planned rows are active");
                let cap = self
                    .allocator
                    .capacity_tokens(s.seq_id)
                    .expect("active sequences hold a block table");
                // the forcing lanes describe the token *after* the chunk:
                // present -> the sampling head is masked to it (more
                // prefix left), absent -> the chunk's last lane samples
                let forced = s.stream.get(s.pos + n).copied();
                self.arena.set_chunk_row(i, s.pos, &s.stream[s.pos..s.pos + n], forced, cap);
            }
        } else {
            for (i, slot) in self.slots.iter().enumerate() {
                if let Some(s) = slot {
                    if self.stalled[i] {
                        continue;
                    }
                    let cap = self
                        .allocator
                        .capacity_tokens(s.seq_id)
                        .expect("active sequences hold a block table");
                    self.arena.set_slot(i, s.pos, s.cur_token(), s.forced_next(), cap);
                }
            }
        }
        if paged {
            // ship the allocator's tables as this step's block-table
            // operand (occupied rows only — reset just re-parked the
            // rest at trash), then stage the CoW copy lanes captured by
            // the growth loop. A fork whose row was parked meanwhile is
            // dropped: its blocks went back to the free pool.
            let trash = (self.variant.kv_pool_blocks - 1) as i32;
            for i in 0..b {
                if let Some(s) = &self.slots[i] {
                    self.allocator.fill_table(s.seq_id, self.arena.row_table(i), trash);
                }
            }
            for &(i, old, new) in &forks {
                if self.slots[i].is_some() {
                    self.arena.set_copy(i, old as i32, new as i32);
                }
            }
        }
        if self.cfg.greedy {
            self.arena.zero_gumbel();
        } else {
            // RNG-cursor pin: a chunk dispatch covering K positions
            // consumes exactly the K Gumbel fills the legacy path would
            // burn running K single steps (the last fill is the operand;
            // K == 1 is the legacy path verbatim), so token streams stay
            // identical between prefill_chunk = 1 and W whenever the
            // per-step draws match — always under greedy, and under
            // sampling whenever rows consume draw k at the same dispatch
            // (e.g. lockstep prompts)
            for _ in 0..k_max {
                self.rng.fill_gumbel(&mut self.arena.gumbel);
            }
        }
        // `lits` lives past the dispatch: staging inside run_decode_step
        // is asynchronous and reads from these literals
        let lits = self.arena.to_literals()?;
        let lanes = if paged { Some(self.arena.paged_literals()?) } else { None };
        let chunk_lits = if chunked { Some(self.arena.chunk_literals()?) } else { None };
        self.stats.stage_us += t_arena.elapsed().as_micros() as u64;

        let park = (self.variant.max_seq - 1) as i32;
        let param_bufs: Vec<&PjRtBuffer> =
            self.params.active().iter().map(|p| &p.buf).collect();
        let d = if let Some(cl) = &chunk_lits {
            // the chunk writes start..=start+n-1: the plan carries each
            // row's *last* written position so the existing capacity and
            // table entitlement checks cover every lane
            for i in 0..b {
                self.chunk_plan_pos[i] = match self.chunk_len[i] {
                    0 => park,
                    n => {
                        (self.slots[i].as_ref().expect("planned rows are active").pos + n - 1)
                            as i32
                    }
                };
            }
            let inputs = ChunkInputs {
                start: &cl.start,
                ctoks: &cl.ctoks,
                vlen: &cl.vlen,
                gumbel: &lits.gumbel,
                ftok: &lits.ftok,
                fmask: &lits.fmask,
                temp: &lits.temp,
            };
            let plan = StagePlan { park, pos: &self.chunk_plan_pos, cap: &self.arena.cap };
            let g = self.chunk_graph.as_ref().expect("chunked round requires the chunk graph");
            match &lanes {
                Some(lanes) => run_prefill_chunk_paged(
                    g,
                    &param_bufs,
                    &mut self.kv,
                    PagedInputs {
                        table: &lanes.table,
                        copy_src: &lanes.copy_src,
                        copy_dst: &lanes.copy_dst,
                    },
                    inputs,
                    Some(&plan),
                    Some(&TablePlan {
                        block_size: self.cfg.block_size,
                        blocks_per_row: self.variant.kv_blocks_per_row,
                        pool_blocks: self.variant.kv_pool_blocks,
                        table: &self.arena.table,
                        copy_src: &self.arena.copy_src,
                        copy_dst: &self.arena.copy_dst,
                    }),
                )
                .context("paged chunked prefill step")?,
                None => run_prefill_chunk(g, &param_bufs, &mut self.kv, inputs, Some(&plan))
                    .context("chunked prefill step")?,
            }
        } else {
            let inputs = DecodeInputs {
                pos: &lits.pos,
                cur: &lits.cur,
                gumbel: &lits.gumbel,
                ftok: &lits.ftok,
                fmask: &lits.fmask,
                temp: &lits.temp,
            };
            let plan = StagePlan { park, pos: &self.arena.pos, cap: &self.arena.cap };
            match &lanes {
                Some(lanes) => run_decode_step_paged(
                    &self.graph,
                    &param_bufs,
                    &mut self.kv,
                    PagedInputs {
                        table: &lanes.table,
                        copy_src: &lanes.copy_src,
                        copy_dst: &lanes.copy_dst,
                    },
                    inputs,
                    Some(&plan),
                    Some(&TablePlan {
                        block_size: self.cfg.block_size,
                        blocks_per_row: self.variant.kv_blocks_per_row,
                        pool_blocks: self.variant.kv_pool_blocks,
                        table: &self.arena.table,
                        copy_src: &self.arena.copy_src,
                        copy_dst: &self.arena.copy_dst,
                    }),
                )
                .context("paged decode step")?,
                None => {
                    run_decode_step(&self.graph, &param_bufs, &mut self.kv, inputs, Some(&plan))
                        .context("decode step")?
                }
            }
        };
        drop(param_bufs);
        self.stats.stage_us += d.stage_us;
        if chunked {
            // prompt-ingestion execute time is split out of the decode
            // latency; each chunk covering K positions replaced K - 1
            // single-token dispatches
            self.stats.prefill_us += d.execute_us;
            self.stats.prefill_chunks += 1;
            self.stats.forced_steps_saved += (k_max - 1) as u64;
        } else {
            self.stats.execute_us += d.execute_us;
        }
        // ~0 on untupled builds; the full tuple readback on fallback ones
        self.stats.readback_us += d.kv_take_us;
        if d.kv_restaged {
            self.stats.kv_restages += 1;
        }
        let mut outs = d.outs;

        // ---- selective readback ----
        // outputs: next_tok[B], chosen_lp[B], lp_all[B,V], kv', ent[B].
        // Only the O(B) outputs cross the boundary each step; lp_all only
        // under capture_dist, the KV (already threaded back) and entropy
        // never.
        let t_read = Instant::now();
        let next = outs.read_vec::<i32>(0)?;
        let lps = outs.read_vec::<f32>(1)?;
        let lp_all = if self.cfg.capture_dist {
            Some(outs.read_vec::<f32>(2)?)
        } else {
            None
        };
        self.stats.readback_us += t_read.elapsed().as_micros() as u64;
        // the execute consumed the active param buffers: their keep-alive
        // host sources are no longer needed
        self.release_param_sources();
        self.stats.steps += 1;

        // advance states, collect finishes. Each planned row advances by
        // its chunk length: the leading advances are forced (their
        // next/lp arguments are ignored — the stream already holds the
        // token), and only a chunk reaching the stream end consumes the
        // dispatch's sampled token, exactly like the K single steps it
        // replaced. `chunk_len == 1` for every row on legacy rounds.
        let mut outcome = StepOutcome::default();
        let t_now = self.clock.seconds();
        for i in 0..b {
            let n = self.chunk_len[i];
            if n == 0 {
                continue;
            }
            let Some(s) = self.slots[i].as_mut() else { continue };
            for _ in 0..n {
                let was_forced = s.forced_next().is_some();
                if was_forced {
                    self.stats.tokens_forced += 1;
                } else {
                    self.stats.tokens_sampled += 1;
                    outcome.tokens_sampled += 1;
                    if let Some(all) = &lp_all {
                        self.captured.push(DistRow {
                            seq_id: s.seq_id,
                            gen_index: s.gen_len(),
                            logdist: all[i * vsz..(i + 1) * vsz].to_vec(),
                            version: self.params.active_version(),
                        });
                    }
                }
                s.advance(
                    next[i],
                    lps[i],
                    self.params.active_version(),
                    EOS_ID,
                    self.variant.max_seq,
                );
            }
            if s.finished() {
                let s = self.slots[i].take().unwrap();
                self.allocator.release(s.seq_id).expect("release admitted seq");
                self.stats.finished += 1;
                outcome.finished.push(s.into_rollout(self.actor_id, t_now));
            }
        }
        Ok(outcome)
    }

    /// Rebuild the KV cache for all active sequences under the current
    /// weights by force-replaying their streams (Fig 7 "KV cache
    /// recomputed" mode — the §5.1 ablation, whose whole point is
    /// refreshing *every* row, so this zeroes the cache and replays the
    /// full batch).
    fn recompute_kv(&mut self) -> Result<()> {
        let rows: Vec<usize> = (0..self.slots.len())
            .filter(|&i| self.slots[i].is_some())
            .collect();
        self.recompute_rows(&rows, true)
    }

    /// Replay the token streams of `rows` (slot indices) to rebuild their
    /// KV, leaving every other active slot's resident KV untouched —
    /// the per-row replay behind snapshot imports and preemptee
    /// re-admission. Does not touch sequence state or stats other than
    /// replay counters. Cold path: the per-position dispatch goes through
    /// the same decode-step helpers as the hot loop, with the
    /// loop-invariant literals hoisted and the index vectors reused
    /// across positions.
    ///
    /// `zero_first` reseeds the cache with zeros before replaying (the
    /// full-batch recompute). The per-row path keeps the cache: a
    /// rebuilt row overwrites exactly its live prefix `0..pos-1`, and
    /// whatever stale data sits at positions `>= pos` (the slot's
    /// previous occupant, or the row's own pre-park tail) is never
    /// attended — attention at position p reads `0..=p` only.
    fn recompute_rows(&mut self, rows: &[usize], zero_first: bool) -> Result<()> {
        let b = self.variant.gen_batch;
        let paged = self.arena.is_paged();
        if zero_first {
            let shape =
                if paged { self.variant.kv_pool_shape() } else { self.variant.kv_shape() };
            self.kv = DeviceVal::Lit(HostTensor::zeros_f32(&shape).to_literal()?);
        }
        let mut rebuild = vec![false; b];
        for &i in rows {
            rebuild[i] = true;
        }
        let n_active = self.slots.iter().filter(|s| s.is_some()).count();
        self.stats.replay_rows_rebuilt += rows.len() as u64;
        self.stats.replay_rows_skipped += (n_active - rows.len()) as u64;
        let max_pos = rows
            .iter()
            .filter_map(|&i| self.slots[i].as_ref())
            .map(|s| s.pos)
            .max()
            .unwrap_or(0);
        if max_pos == 0 {
            // nothing to re-feed: the cache as it stands *is* the rebuilt
            // state, and with no dispatch the param sources must stay
            // alive for the next consuming execute
            self.stats.kv_recomputes += 1;
            return Ok(());
        }
        // loop-invariant inputs (zero gumbel, all-PAD forcing, all-ones
        // mask, temperature) are engine-owned literals built once at
        // construction — replay just borrows them
        // rows with no work at position p park at max_seq - 1 (writing
        // pos 0 would clobber the BOS K/V a shorter neighbor already
        // replayed — the heterogeneous-position case is the migration
        // mainline, not just the §5.1 ablation)
        let park = (self.variant.max_seq - 1) as i32;
        let mut pos = vec![park; b];
        let mut cur = vec![PAD_ID; b];
        // block-table capacities are loop-invariant: the allocator covers
        // every position the replay writes. The replay rebuilds positions
        // 0..pos-1 only — position `pos` has never been written (it is
        // the sequence's *next* write, landed by its next decode step
        // after the growth check backs it with a block), so staging it
        // here would both be redundant and trip the StagePlan validation
        // for a sequence sitting exactly at a block boundary (cap == pos)
        // or stalled.
        let caps: Vec<usize> = self
            .slots
            .iter()
            .enumerate()
            .map(|(i, slot)| {
                if !rebuild[i] {
                    return 0; // parked for the whole replay
                }
                slot.as_ref()
                    .and_then(|s| self.allocator.capacity_tokens(s.seq_id))
                    .unwrap_or(0)
            })
            .collect();
        // paged: the rebuilt rows' block tables are loop-invariant too
        // (no growth happens mid-replay), staged once via the arena.
        // Skipped rows keep trash tables — their parked scatter lands in
        // the trash block instead of touching their resident KV.
        let lanes = if paged {
            self.arena.reset();
            let trash = (self.variant.kv_pool_blocks - 1) as i32;
            for i in 0..b {
                if !rebuild[i] {
                    continue;
                }
                if let Some(s) = &self.slots[i] {
                    self.allocator.fill_table(s.seq_id, self.arena.row_table(i), trash);
                }
            }
            Some(self.arena.paged_literals()?)
        } else {
            None
        };
        // chunked replay: with the chunk graph loaded, W-strided rounds
        // rebuild the same prefixes in ceil(max_pos / W) dispatches
        // instead of max_pos. W == 1 (or no chunk graph) is the legacy
        // per-position loop, bit-for-bit. Neither path consumes RNG —
        // replay always forces, so the gumbel operand is all-zero.
        let w = if self.chunk_graph.is_some() { self.cfg.prefill_chunk.max(1) } else { 1 };
        let mut p = 0usize;
        while p < max_pos {
            // this round covers positions p .. p + k - 1 across the batch
            let k = w.min(max_pos - p);
            let param_bufs: Vec<&PjRtBuffer> =
                self.params.active().iter().map(|sp| &sp.buf).collect();
            let d = if k > 1 {
                for i in 0..b {
                    let vl = match &self.slots[i] {
                        Some(s) if rebuild[i] && s.pos > p => (s.pos - p).min(k),
                        _ => 0,
                    };
                    if vl == 0 {
                        // no work this round: inert lanes, parked write
                        self.arena.vlen[i] = 0;
                        self.arena.pos[i] = park;
                        self.chunk_plan_pos[i] = park;
                    } else {
                        let s = self.slots[i].as_ref().expect("vl > 0 implies occupied slot");
                        self.arena.set_chunk_row(
                            i,
                            p,
                            &s.stream[p..p + vl],
                            Some(PAD_ID),
                            caps[i],
                        );
                        self.chunk_plan_pos[i] = (p + vl - 1) as i32;
                    }
                }
                let cl = self.arena.chunk_literals()?;
                let inputs = ChunkInputs {
                    start: &cl.start,
                    ctoks: &cl.ctoks,
                    vlen: &cl.vlen,
                    gumbel: &self.zero_gum_l,
                    ftok: &self.pad_ftok_l,
                    fmask: &self.ones_fmask_l,
                    temp: &self.temp_l,
                };
                let plan = StagePlan { park, pos: &self.chunk_plan_pos, cap: &caps };
                let g = self
                    .chunk_graph
                    .as_ref()
                    .expect("k > 1 requires the chunk graph");
                let d = match &lanes {
                    Some(lanes) => run_prefill_chunk_paged(
                        g,
                        &param_bufs,
                        &mut self.kv,
                        PagedInputs {
                            table: &lanes.table,
                            copy_src: &lanes.copy_src,
                            copy_dst: &lanes.copy_dst,
                        },
                        inputs,
                        Some(&plan),
                        Some(&TablePlan {
                            block_size: self.cfg.block_size,
                            blocks_per_row: self.variant.kv_blocks_per_row,
                            pool_blocks: self.variant.kv_pool_blocks,
                            table: &self.arena.table,
                            copy_src: &self.arena.copy_src,
                            copy_dst: &self.arena.copy_dst,
                        }),
                    )?,
                    None => {
                        run_prefill_chunk(g, &param_bufs, &mut self.kv, inputs, Some(&plan))?
                    }
                };
                self.stats.prefill_us += d.execute_us;
                self.stats.prefill_chunks += 1;
                self.stats.forced_steps_saved += (k - 1) as u64;
                d
            } else {
                pos.iter_mut().for_each(|x| *x = park);
                cur.iter_mut().for_each(|x| *x = PAD_ID);
                for (i, slot) in self.slots.iter().enumerate() {
                    if let Some(s) = slot {
                        if rebuild[i] && p < s.pos {
                            pos[i] = p as i32;
                            cur[i] = s.stream[p];
                        }
                    }
                }
                let pos_l = Literal::vec1(&pos);
                let cur_l = Literal::vec1(&cur);
                let inputs = DecodeInputs {
                    pos: &pos_l,
                    cur: &cur_l,
                    gumbel: &self.zero_gum_l,
                    ftok: &self.pad_ftok_l,
                    fmask: &self.ones_fmask_l,
                    temp: &self.temp_l,
                };
                let plan = StagePlan { park, pos: &pos, cap: &caps };
                match &lanes {
                    Some(lanes) => run_decode_step_paged(
                        &self.graph,
                        &param_bufs,
                        &mut self.kv,
                        PagedInputs {
                            table: &lanes.table,
                            copy_src: &lanes.copy_src,
                            copy_dst: &lanes.copy_dst,
                        },
                        inputs,
                        Some(&plan),
                        Some(&TablePlan {
                            block_size: self.cfg.block_size,
                            blocks_per_row: self.variant.kv_blocks_per_row,
                            pool_blocks: self.variant.kv_pool_blocks,
                            table: &self.arena.table,
                            copy_src: &self.arena.copy_src,
                            copy_dst: &self.arena.copy_dst,
                        }),
                    )?,
                    None => run_decode_step(
                        &self.graph,
                        &param_bufs,
                        &mut self.kv,
                        inputs,
                        Some(&plan),
                    )?,
                }
            };
            drop(param_bufs);
            if d.kv_restaged {
                self.stats.kv_restages += 1;
            }
            self.stats.recompute_steps += 1;
            p += k;
        }
        // replay executes consumed the active param buffers
        self.release_param_sources();
        self.stats.kv_recomputes += 1;
        Ok(())
    }

    /// Abort everything in flight (run-shutdown path — the work is
    /// deliberately discarded). Returns unfinished rollouts with
    /// `FinishReason::Aborted`. For kill/descale paths that should *not*
    /// lose the work, use [`Engine::export_snapshots`] instead.
    pub fn drain(&mut self) -> Vec<Rollout> {
        let t = self.clock.seconds();
        let mut out = Vec::new();
        for slot in self.slots.iter_mut() {
            if let Some(s) = slot.take() {
                self.allocator.release(s.seq_id).ok();
                out.push(s.into_rollout(self.actor_id, t));
            }
        }
        for s in self.pending.drain(..) {
            out.push(s.into_rollout(self.actor_id, t));
        }
        // clear stale stall flags: a drained slot must not carry its old
        // occupant's stall state into the next admission cycle
        for st in self.stalled.iter_mut() {
            *st = false;
        }
        out
    }
}
