//! The engine proper: continuous-batching decode loop over the AOT
//! decode graph, with in-flight request admission and in-flight weight
//! updates. See module docs in engine/mod.rs.

use super::kvcache::BlockAllocator;
use super::sequence::SeqState;
use crate::data::task::Problem;
use crate::model::tokenizer::{EOS_ID, PAD_ID};
use crate::rl::Rollout;
use crate::runtime::{HostTensor, Runtime, Variant};
use crate::util::timer::Stopwatch;
use crate::util::Rng;
use anyhow::{Context, Result};
use std::collections::VecDeque;
use std::rc::Rc;
use xla::{Literal, PjRtBuffer};

#[derive(Debug, Clone)]
pub struct EngineCfg {
    pub variant: String,
    pub temperature: f32,
    pub max_new_tokens: usize,
    /// KV page size for the block allocator
    pub block_size: usize,
    /// total KV blocks; None = exactly enough for all slots at max_seq
    pub kv_blocks: Option<usize>,
    /// record the full per-step log-distribution of sampled tokens
    /// (needed by the Fig 7 KL study; off on the hot path)
    pub capture_dist: bool,
    /// recompute the whole KV cache under new weights at every weight
    /// update (the paper's §5.1 ablation; costs throughput)
    pub recompute_kv_on_update: bool,
    /// greedy decoding: zero Gumbel noise (argmax) — used by the eval
    /// harness (Table 1 protocol)
    pub greedy: bool,
}

impl EngineCfg {
    pub fn new(variant: &str) -> Self {
        EngineCfg {
            variant: variant.to_string(),
            temperature: 1.0,
            max_new_tokens: 48,
            block_size: 16,
            kv_blocks: None,
            capture_dist: false,
            recompute_kv_on_update: false,
            greedy: false,
        }
    }
}

#[derive(Debug, Default, Clone)]
pub struct EngineStats {
    pub steps: u64,
    pub tokens_sampled: u64,
    pub tokens_forced: u64,
    pub weight_updates: u64,
    pub kv_recomputes: u64,
    pub recompute_steps: u64,
    pub stall_steps: u64,
    pub finished: u64,
}

/// Captured distribution row (Fig 7): sampled token's full log-dist.
#[derive(Debug, Clone)]
pub struct DistRow {
    pub seq_id: u64,
    /// index within the generated part of the sequence
    pub gen_index: usize,
    pub logdist: Vec<f32>,
    pub version: u64,
}

#[derive(Debug, Default)]
pub struct StepOutcome {
    pub finished: Vec<Rollout>,
    pub tokens_sampled: usize,
    /// true when no slot had work
    pub idle: bool,
}

pub struct Engine {
    pub cfg: EngineCfg,
    variant: Variant,
    graph: Rc<crate::runtime::Graph>,
    /// weights staged once per in-flight update and kept device-resident
    /// across decode steps (loop-invariant — §Perf)
    params_bufs: Vec<PjRtBuffer>,
    version: u64,
    kv: Literal,
    slots: Vec<Option<SeqState>>,
    stalled: Vec<bool>,
    pending: VecDeque<SeqState>,
    allocator: BlockAllocator,
    rng: Rng,
    clock: Stopwatch,
    next_seq_id: u64,
    actor_id: usize,
    pub stats: EngineStats,
    pub captured: Vec<DistRow>,
    gumbel_buf: Vec<f32>,
}

/// Stage a parameter set, keeping the source literals alive until every
/// async host->device copy must have landed (we force completion by
/// reading one element back through a blocking call on the last buffer).
fn stage_params(
    graph: &crate::runtime::Graph,
    params: &[HostTensor],
) -> Result<Vec<PjRtBuffer>> {
    let lits = params
        .iter()
        .map(|t| t.to_literal())
        .collect::<Result<Vec<_>>>()?;
    let bufs = lits
        .iter()
        .map(|l| graph.stage(l))
        .collect::<Result<Vec<_>>>()?;
    // force every pending host->device copy to completion before the
    // source literals drop (a blocking readback per buffer; weights are
    // staged once per in-flight update, so this is off the decode loop)
    for b in &bufs {
        let _ = b.to_literal_sync()?;
    }
    drop(lits);
    Ok(bufs)
}

impl Engine {
    pub fn new(
        rt: &mut Runtime,
        cfg: EngineCfg,
        init_params: &[HostTensor],
        actor_id: usize,
        rng: Rng,
    ) -> Result<Engine> {
        let variant = rt.manifest.variant(&cfg.variant)?.clone();
        crate::runtime::check_params(&variant, init_params)?;
        let graph = rt.graph(&cfg.variant, "decode")?;
        let params_bufs = stage_params(&graph, init_params)?;
        let kv = HostTensor::zeros_f32(&variant.kv_shape()).to_literal()?;
        let allocator = match cfg.kv_blocks {
            Some(n) => BlockAllocator::new(n, cfg.block_size),
            None => BlockAllocator::for_slots(variant.gen_batch, variant.max_seq, cfg.block_size),
        };
        let b = variant.gen_batch;
        let v = variant.vocab;
        Ok(Engine {
            cfg,
            slots: (0..b).map(|_| None).collect(),
            stalled: vec![false; b],
            pending: VecDeque::new(),
            allocator,
            rng,
            clock: Stopwatch::new(),
            next_seq_id: 1,
            actor_id,
            stats: EngineStats::default(),
            captured: Vec::new(),
            gumbel_buf: vec![0.0; b * v],
            variant,
            graph,
            params_bufs,
            version: 0,
            kv,
        })
    }

    pub fn variant(&self) -> &Variant {
        &self.variant
    }

    pub fn current_version(&self) -> u64 {
        self.version
    }

    pub fn n_active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn n_pending(&self) -> usize {
        self.pending.len()
    }

    /// Total sequences in flight (active + queued).
    pub fn load(&self) -> usize {
        self.n_active() + self.n_pending()
    }

    pub fn n_slots(&self) -> usize {
        self.slots.len()
    }

    /// Paper API `/v1/chat/completions` (enqueue form): submit a prompt.
    /// Rollouts sharing `group_id` form one advantage group.
    pub fn add_request(&mut self, problem: Problem, prompt_tokens: Vec<i32>, group_id: u64) -> u64 {
        let id = self.next_seq_id;
        self.next_seq_id += 1;
        let seq = SeqState::new(
            id,
            group_id,
            problem,
            prompt_tokens,
            crate::model::tokenizer::BOS_ID,
            self.cfg.max_new_tokens,
            self.clock.seconds(),
        );
        self.pending.push_back(seq);
        id
    }

    /// Paper API `request_weight_update`: swap weights in-flight.
    /// KV cache is retained (default) or recomputed (cfg flag, §5.1).
    pub fn set_weights(&mut self, version: u64, params: &[HostTensor]) -> Result<()> {
        crate::runtime::check_params(&self.variant, params)?;
        self.params_bufs = stage_params(&self.graph, params)?;
        self.version = version;
        self.stats.weight_updates += 1;
        if self.cfg.recompute_kv_on_update && self.n_active() > 0 {
            self.recompute_kv()?;
        }
        Ok(())
    }

    /// Admit pending sequences into free slots (in-flight adds).
    fn admit(&mut self) {
        for i in 0..self.slots.len() {
            if self.slots[i].is_some() {
                continue;
            }
            let Some(seq) = self.pending.front() else { break };
            if !self.allocator.can_admit(seq.total_len()) {
                break; // out of KV blocks: wait for a release
            }
            let seq = self.pending.pop_front().unwrap();
            self.allocator
                .admit(seq.seq_id, seq.total_len())
                .expect("can_admit checked");
            self.slots[i] = Some(seq);
            self.stalled[i] = false;
        }
    }

    /// One decode step for every busy slot. Returns finished rollouts.
    pub fn step(&mut self) -> Result<StepOutcome> {
        self.admit();
        let b = self.variant.gen_batch;
        let vsz = self.variant.vocab;
        if self.n_active() == 0 {
            return Ok(StepOutcome { idle: true, ..Default::default() });
        }

        // KV growth check: a slot whose next token needs a new block may
        // stall when the pool is over-committed (vLLM would preempt).
        for i in 0..b {
            if let Some(s) = &self.slots[i] {
                let ok = self.allocator.grow(s.seq_id, s.pos + 1).unwrap_or(false);
                self.stalled[i] = !ok;
                if !ok {
                    self.stats.stall_steps += 1;
                }
            }
        }

        // build inputs
        let mut pos = vec![0i32; b];
        let mut cur = vec![PAD_ID; b];
        let mut ftok = vec![PAD_ID; b];
        let mut fmask = vec![1.0f32; b]; // idle/stalled slots: force PAD
        for (i, slot) in self.slots.iter().enumerate() {
            if let Some(s) = slot {
                if self.stalled[i] {
                    continue;
                }
                pos[i] = s.pos as i32;
                cur[i] = s.cur_token();
                match s.forced_next() {
                    Some(t) => {
                        ftok[i] = t;
                        fmask[i] = 1.0;
                    }
                    None => {
                        fmask[i] = 0.0;
                    }
                }
            }
        }
        if self.cfg.greedy {
            self.gumbel_buf.iter_mut().for_each(|g| *g = 0.0);
        } else {
            self.rng.fill_gumbel(&mut self.gumbel_buf);
        }

        // NOTE: buffer staging is asynchronous on the TFRT CPU client —
        // the source literal must outlive the execute call (the upstream
        // crate's execute() awaits readiness for the same reason), so the
        // per-step literals are bound to locals that live past run_buffers.
        let pos_l = HostTensor::from_i32(&[b], pos).to_literal()?;
        let cur_l = HostTensor::from_i32(&[b], cur).to_literal()?;
        let gum_l = HostTensor::from_f32(&[b, vsz], self.gumbel_buf.clone()).to_literal()?;
        let ftok_l = HostTensor::from_i32(&[b], ftok).to_literal()?;
        let fmask_l = HostTensor::from_f32(&[b], fmask.clone()).to_literal()?;
        let temp_l = HostTensor::scalar_f32(self.cfg.temperature).to_literal()?;
        let kv_b = self.graph.stage(&self.kv)?;
        let pos_b = self.graph.stage(&pos_l)?;
        let cur_b = self.graph.stage(&cur_l)?;
        let gum_b = self.graph.stage(&gum_l)?;
        let ftok_b = self.graph.stage(&ftok_l)?;
        let fmask_b = self.graph.stage(&fmask_l)?;
        let temp_b = self.graph.stage(&temp_l)?;

        let mut inputs: Vec<&PjRtBuffer> = self.params_bufs.iter().collect();
        inputs.push(&kv_b);
        inputs.push(&pos_b);
        inputs.push(&cur_b);
        inputs.push(&gum_b);
        inputs.push(&ftok_b);
        inputs.push(&fmask_b);
        inputs.push(&temp_b);

        let mut outs = self.graph.run_buffers(&inputs).context("decode step")?;
        // outputs: next_tok[B], chosen_lp[B], lp_all[B,V], kv', ent[B]
        let kv_new = outs.swap_remove(3);
        let next = outs[0].to_vec::<i32>()?;
        let lps = outs[1].to_vec::<f32>()?;
        let lp_all = if self.cfg.capture_dist {
            Some(outs[2].to_vec::<f32>()?)
        } else {
            None
        };
        self.kv = kv_new;
        self.stats.steps += 1;

        // advance states, collect finishes
        let mut outcome = StepOutcome::default();
        let t_now = self.clock.seconds();
        for i in 0..b {
            if self.stalled[i] {
                continue;
            }
            let Some(s) = self.slots[i].as_mut() else { continue };
            let was_forced = s.forced_next().is_some();
            if was_forced {
                self.stats.tokens_forced += 1;
            } else {
                self.stats.tokens_sampled += 1;
                outcome.tokens_sampled += 1;
                if let Some(all) = &lp_all {
                    self.captured.push(DistRow {
                        seq_id: s.seq_id,
                        gen_index: s.gen_len(),
                        logdist: all[i * vsz..(i + 1) * vsz].to_vec(),
                        version: self.version,
                    });
                }
            }
            s.advance(next[i], lps[i], self.version, EOS_ID, self.variant.max_seq);
            if s.finished() {
                let s = self.slots[i].take().unwrap();
                self.allocator.release(s.seq_id).expect("release admitted seq");
                self.stats.finished += 1;
                outcome.finished.push(s.into_rollout(self.actor_id, t_now));
            }
        }
        Ok(outcome)
    }

    /// Rebuild the KV cache for all active sequences under the current
    /// weights by force-replaying their streams (Fig 7 "KV cache
    /// recomputed" mode). Does not touch sequence state or stats other
    /// than recompute counters.
    fn recompute_kv(&mut self) -> Result<()> {
        let b = self.variant.gen_batch;
        let vsz = self.variant.vocab;
        self.kv = HostTensor::zeros_f32(&self.variant.kv_shape()).to_literal()?;
        let max_pos = self
            .slots
            .iter()
            .flatten()
            .map(|s| s.pos)
            .max()
            .unwrap_or(0);
        let zero_gum = HostTensor::zeros_f32(&[b, vsz]).to_literal()?;
        let temp_l = HostTensor::scalar_f32(self.cfg.temperature).to_literal()?;
        for p in 0..=max_pos {
            let mut pos = vec![0i32; b];
            let mut cur = vec![PAD_ID; b];
            for (i, slot) in self.slots.iter().enumerate() {
                if let Some(s) = slot {
                    if p <= s.pos {
                        pos[i] = p as i32;
                        cur[i] = s.stream[p];
                    }
                }
            }
            let pos_l = HostTensor::from_i32(&[b], pos).to_literal()?;
            let cur_l = HostTensor::from_i32(&[b], cur).to_literal()?;
            let ftok_l = HostTensor::from_i32(&[b], vec![PAD_ID; b]).to_literal()?;
            let fmask_l = HostTensor::from_f32(&[b], vec![1.0; b]).to_literal()?;
            let kv_b = self.graph.stage(&self.kv)?;
            let pos_b = self.graph.stage(&pos_l)?;
            let cur_b = self.graph.stage(&cur_l)?;
            let gum_b = self.graph.stage(&zero_gum)?;
            let ftok_b = self.graph.stage(&ftok_l)?;
            let fmask_b = self.graph.stage(&fmask_l)?;
            let temp_b = self.graph.stage(&temp_l)?;
            let mut inputs: Vec<&PjRtBuffer> = self.params_bufs.iter().collect();
            inputs.push(&kv_b);
            inputs.push(&pos_b);
            inputs.push(&cur_b);
            inputs.push(&gum_b);
            inputs.push(&ftok_b);
            inputs.push(&fmask_b);
            inputs.push(&temp_b);
            let mut outs = self.graph.run_buffers(&inputs)?;
            self.kv = outs.swap_remove(3);
            self.stats.recompute_steps += 1;
        }
        self.stats.kv_recomputes += 1;
        Ok(())
    }

    /// Abort everything in flight (shutdown path). Returns unfinished
    /// rollouts with `FinishReason::Aborted`.
    pub fn drain(&mut self) -> Vec<Rollout> {
        let t = self.clock.seconds();
        let mut out = Vec::new();
        for slot in self.slots.iter_mut() {
            if let Some(s) = slot.take() {
                self.allocator.release(s.seq_id).ok();
                out.push(s.into_rollout(self.actor_id, t));
            }
        }
        for s in self.pending.drain(..) {
            out.push(s.into_rollout(self.actor_id, t));
        }
        out
    }
}
