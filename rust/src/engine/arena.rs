//! Reusable input-staging arena for the decode hot loop.
//!
//! Before the §Perf pass, `Engine::step` allocated four fresh `Vec`s and
//! cloned the whole `[B, V]` Gumbel buffer on every step just to build
//! the per-step input literals. The arena owns those host buffers once,
//! with fixed shapes, and the step writes them *in place*; the only
//! per-step copies left are the literal constructions themselves (the
//! host→device edge, which is irreducible).
//!
//! Invariants (property-tested device-free via the vendored stub):
//! * buffer lengths are fixed at construction and never change;
//! * slot writes never alias — writing slot `i` leaves slot `j` intact;
//! * `reset` restores the idle defaults (pos parked, PAD tokens, force
//!   mask 1).
//!
//! **The parking position.** The decode graph writes K/V at `pos[b]` for
//! *every* row, every step (model.py's unconditional scatter) — including
//! rows with nothing to do (empty slots, stalled slots, and replay rows
//! that already finished their stream). Idle rows therefore must not
//! point at cache position 0: that would overwrite the resident BOS K/V
//! of whatever sequence owns the row (a stalled sequence resumes
//! attending over a corrupted position 0). Instead they park at
//! `max_seq - 1`, a position no real sequence ever writes or attends to
//! (sequences finish — and are removed — the moment `pos + 1 == max_seq`,
//! so the last position ever fed is `max_seq - 2`).

use anyhow::Result;
use xla::Literal;

/// Host-side staging buffers for one decode step, shaped `[B]` (plus the
/// `[B, V]` Gumbel noise and the scalar temperature).
#[derive(Debug)]
pub struct StepArena {
    b: usize,
    vocab: usize,
    pad: i32,
    /// cache position idle rows write their (discarded) K/V at — see the
    /// module docs
    park: i32,
    /// cache position per slot
    pub pos: Vec<i32>,
    /// current token per slot
    pub cur: Vec<i32>,
    /// forced next token per slot (prefill-through-decode)
    pub ftok: Vec<i32>,
    /// 1.0 = forced (idle/stalled slots force PAD), 0.0 = sample
    pub fmask: Vec<f32>,
    /// Gumbel noise, `[B, V]` row-major
    pub gumbel: Vec<f32>,
    /// per-slot allocated KV capacity in tokens (block table length ×
    /// block size; 0 for idle/parked rows) — not a graph operand, but
    /// staged alongside `pos` so `run_decode_step` can validate every KV
    /// write against the allocator's block tables (block-table-aware
    /// staging)
    pub cap: Vec<usize>,
    /// paged-layout lanes (empty until `enable_paged`): the `[B, NB]`
    /// block-table operand row-major, and the per-row CoW copy lanes.
    /// Idle table entries and copy-free rows point at the pool's trash
    /// block, so the graph's unconditional gather/copy is a harmless
    /// self-write there.
    pub table: Vec<i32>,
    pub copy_src: Vec<i32>,
    pub copy_dst: Vec<i32>,
    /// chunked-prefill lanes (empty until `enable_chunk`): the `[B, W]`
    /// forced-token matrix and the per-row valid length for the
    /// `prefill_chunk` graphs. Row i feeds `ctoks[i*W .. i*W+vlen[i]]` at
    /// positions `pos[i] + j`; lanes past `vlen[i]` are inert (the graph
    /// PAD-masks them and parks their scatter), so the tail may hold
    /// stale tokens.
    pub ctoks: Vec<i32>,
    pub vlen: Vec<i32>,
    /// compiled chunk width W when chunked prefill is on, 0 when off
    chunk_w: usize,
    /// blocks per row (NB) when paged, 0 when dense
    blocks_per_row: usize,
    /// the pool's sacrificial trailing block index
    trash: i32,
    temp: f32,
}

/// The step's input literals, in decode-graph operand order
/// (`pos, cur, gumbel, ftok, fmask, temp` — after params and KV).
pub struct StepLiterals {
    pub pos: Literal,
    pub cur: Literal,
    pub gumbel: Literal,
    pub ftok: Literal,
    pub fmask: Literal,
    pub temp: Literal,
}

/// The paged graph's extra operands, in `decode_paged` order (between
/// the pool and `pos`): block table `[B, NB]`, then the CoW copy lanes.
pub struct PagedLanes {
    pub table: Literal,
    pub copy_src: Literal,
    pub copy_dst: Literal,
}

/// The chunk graph's input literals, in `prefill_chunk` operand order
/// (`start, chunk_toks, vlen` — after params and the cache, before the
/// shared `gumbel, ftok, fmask, temp` tail from `StepLiterals`).
pub struct ChunkLanes {
    pub start: Literal,
    pub ctoks: Literal,
    pub vlen: Literal,
}

impl StepArena {
    /// `park` is the idle-row cache position (the engine passes
    /// `max_seq - 1` — see module docs).
    pub fn new(b: usize, vocab: usize, pad: i32, temp: f32, park: i32) -> StepArena {
        StepArena {
            b,
            vocab,
            pad,
            park,
            pos: vec![park; b],
            cur: vec![pad; b],
            ftok: vec![pad; b],
            fmask: vec![1.0; b],
            gumbel: vec![0.0; b * vocab],
            cap: vec![0; b],
            table: Vec::new(),
            copy_src: Vec::new(),
            copy_dst: Vec::new(),
            ctoks: Vec::new(),
            vlen: Vec::new(),
            chunk_w: 0,
            blocks_per_row: 0,
            trash: 0,
            temp,
        }
    }

    /// Switch the arena to the paged layout: size the `[B, NB]`
    /// block-table lane and the per-row copy lanes, all parked at the
    /// pool's `trash` block. Call once right after construction; the
    /// dense lanes keep working unchanged.
    pub fn enable_paged(&mut self, blocks_per_row: usize, trash: i32) {
        self.blocks_per_row = blocks_per_row;
        self.trash = trash;
        self.table = vec![trash; self.b * blocks_per_row];
        self.copy_src = vec![trash; self.b];
        self.copy_dst = vec![trash; self.b];
    }

    pub fn is_paged(&self) -> bool {
        self.blocks_per_row > 0
    }

    /// Size the chunked-prefill lanes for compiled width `w`. Call once
    /// right after construction when `[kv] prefill_chunk > 1`; the
    /// single-step lanes keep working unchanged (and stay the hot path
    /// on rounds where every row advances by one token).
    pub fn enable_chunk(&mut self, w: usize) {
        self.chunk_w = w;
        self.ctoks = vec![self.pad; self.b * w];
        self.vlen = vec![0; self.b];
    }

    pub fn chunk_width(&self) -> usize {
        self.chunk_w
    }

    pub fn batch(&self) -> usize {
        self.b
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// Restore idle defaults in place (no reallocation). The Gumbel
    /// buffer is left as-is: it is fully overwritten each step by either
    /// `fill_gumbel` or `zero_gumbel`.
    pub fn reset(&mut self) {
        self.pos.iter_mut().for_each(|x| *x = self.park);
        self.cur.iter_mut().for_each(|x| *x = self.pad);
        self.ftok.iter_mut().for_each(|x| *x = self.pad);
        self.fmask.iter_mut().for_each(|x| *x = 1.0);
        self.cap.iter_mut().for_each(|x| *x = 0);
        let trash = self.trash;
        self.table.iter_mut().for_each(|x| *x = trash);
        self.copy_src.iter_mut().for_each(|x| *x = trash);
        self.copy_dst.iter_mut().for_each(|x| *x = trash);
        let pad = self.pad;
        self.ctoks.iter_mut().for_each(|x| *x = pad);
        self.vlen.iter_mut().for_each(|x| *x = 0);
    }

    /// Zero the noise buffer (greedy decoding / replay).
    pub fn zero_gumbel(&mut self) {
        self.gumbel.iter_mut().for_each(|g| *g = 0.0);
    }

    /// Write one active slot's inputs. `forced` carries the prompt token
    /// still being force-fed, or None once the slot is sampling. `cap` is
    /// the slot's allocated KV capacity in tokens (the write at `pos`
    /// must be backed by a block: `pos < cap`, validated at dispatch).
    pub fn set_slot(&mut self, i: usize, pos: usize, cur: i32, forced: Option<i32>, cap: usize) {
        self.pos[i] = pos as i32;
        self.cur[i] = cur;
        self.cap[i] = cap;
        match forced {
            Some(t) => {
                self.ftok[i] = t;
                self.fmask[i] = 1.0;
            }
            None => {
                self.ftok[i] = self.pad;
                self.fmask[i] = 0.0;
            }
        }
    }

    /// Write one row's chunked-prefill inputs: `toks` are the forced
    /// tokens fed at cache positions `start + j` (at most W of them —
    /// the engine clamps), `forced` is the stream token after the chunk
    /// (None when the chunk reaches the stream end and the row samples),
    /// `cap` backs the last written position. Rows with no work this
    /// round stay at the reset defaults (`vlen = 0`, parked `pos`).
    pub fn set_chunk_row(
        &mut self,
        i: usize,
        start: usize,
        toks: &[i32],
        forced: Option<i32>,
        cap: usize,
    ) {
        let w = self.chunk_w;
        let pad = self.pad;
        debug_assert!(!toks.is_empty() && toks.len() <= w, "1..=W tokens per chunk row");
        self.pos[i] = start as i32;
        self.vlen[i] = toks.len() as i32;
        self.ctoks[i * w..i * w + toks.len()].copy_from_slice(toks);
        // inert tail lanes are PAD-masked in-graph; re-pad anyway so the
        // staged buffer never leaks a previous round's tokens
        self.ctoks[i * w + toks.len()..(i + 1) * w].iter_mut().for_each(|x| *x = pad);
        self.cap[i] = cap;
        match forced {
            Some(t) => {
                self.ftok[i] = t;
                self.fmask[i] = 1.0;
            }
            None => {
                self.ftok[i] = self.pad;
                self.fmask[i] = 0.0;
            }
        }
    }

    /// Build the chunk graph's extra input literals: start `[B]`, forced
    /// tokens `[B, W]`, valid lengths `[B]`. The `gumbel/ftok/fmask/temp`
    /// tail comes from `to_literals` (shared with the single-step path).
    pub fn chunk_literals(&self) -> Result<ChunkLanes> {
        debug_assert!(self.chunk_w > 0, "enable_chunk first");
        let b = self.b as i64;
        let w = self.chunk_w as i64;
        Ok(ChunkLanes {
            start: Literal::vec1(&self.pos),
            ctoks: Literal::vec1(&self.ctoks).reshape(&[b, w])?,
            vlen: Literal::vec1(&self.vlen),
        })
    }

    /// The mutable `[NB]` block-table lane of one row — the engine hands
    /// this straight to `BlockAllocator::fill_table`.
    pub fn row_table(&mut self, i: usize) -> &mut [i32] {
        let nb = self.blocks_per_row;
        &mut self.table[i * nb..(i + 1) * nb]
    }

    /// Stage one row's copy-on-write: the paged graph copies
    /// `pool[copy_src]` into `pool[copy_dst]` before the layer loop. Rows
    /// without a fork stay trash -> trash (a self-write no real block
    /// observes).
    pub fn set_copy(&mut self, i: usize, src: i32, dst: i32) {
        self.copy_src[i] = src;
        self.copy_dst[i] = dst;
    }

    /// Build the paged graph's extra input literals: block table
    /// `[B, NB]`, copy lanes `[B]`.
    pub fn paged_literals(&self) -> Result<PagedLanes> {
        debug_assert!(self.is_paged(), "enable_paged first");
        let b = self.b as i64;
        let nb = self.blocks_per_row as i64;
        Ok(PagedLanes {
            table: Literal::vec1(&self.table).reshape(&[b, nb])?,
            copy_src: Literal::vec1(&self.copy_src),
            copy_dst: Literal::vec1(&self.copy_dst),
        })
    }

    /// Build the step's input literals from the arena buffers. Shapes are
    /// fixed: `[B]` ×4, `[B, V]`, scalar.
    pub fn to_literals(&self) -> Result<StepLiterals> {
        let b = self.b as i64;
        let v = self.vocab as i64;
        Ok(StepLiterals {
            pos: Literal::vec1(&self.pos),
            cur: Literal::vec1(&self.cur),
            gumbel: Literal::vec1(&self.gumbel).reshape(&[b, v])?,
            ftok: Literal::vec1(&self.ftok),
            fmask: Literal::vec1(&self.fmask),
            temp: Literal::scalar(self.temp),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_and_reset() {
        let mut a = StepArena::new(3, 4, -7, 0.8, 95);
        assert_eq!(a.pos, vec![95, 95, 95], "idle rows park off the live cache");
        a.set_slot(1, 5, 42, None, 8);
        a.set_slot(2, 2, 9, Some(11), 16);
        assert_eq!(a.pos, vec![95, 5, 2]);
        assert_eq!(a.cur, vec![-7, 42, 9]);
        assert_eq!(a.ftok, vec![-7, -7, 11]);
        assert_eq!(a.fmask, vec![1.0, 0.0, 1.0]);
        assert_eq!(a.cap, vec![0, 8, 16]);
        a.reset();
        assert_eq!(a.pos, vec![95, 95, 95]);
        assert_eq!(a.cur, vec![-7, -7, -7]);
        assert_eq!(a.ftok, vec![-7, -7, -7]);
        assert_eq!(a.fmask, vec![1.0, 1.0, 1.0]);
        assert_eq!(a.cap, vec![0, 0, 0], "reset clears the staging capacities");
    }

    #[test]
    fn paged_lanes_default_to_trash_and_reset_clean() {
        let mut a = StepArena::new(2, 4, 0, 1.0, 95);
        assert!(!a.is_paged());
        a.enable_paged(3, 24);
        assert!(a.is_paged());
        assert_eq!(a.table, vec![24; 6], "idle tables park every entry at trash");
        assert_eq!(a.copy_src, vec![24, 24]);
        a.row_table(1).copy_from_slice(&[0, 5, 24]);
        a.set_copy(1, 5, 7);
        assert_eq!(a.table, vec![24, 24, 24, 0, 5, 24], "row 0 untouched");
        assert_eq!((a.copy_src[1], a.copy_dst[1]), (5, 7));
        let lanes = a.paged_literals().unwrap();
        assert_eq!(lanes.table.array_shape().unwrap().dims(), &[2, 3]);
        assert_eq!(lanes.copy_src.array_shape().unwrap().dims(), &[2]);
        a.reset();
        assert_eq!(a.table, vec![24; 6], "reset re-parks the table lane");
        assert_eq!(a.copy_src, vec![24, 24]);
        assert_eq!(a.copy_dst, vec![24, 24]);
    }

    #[test]
    fn chunk_lanes_stage_and_reset_clean() {
        let mut a = StepArena::new(3, 4, -7, 1.0, 95);
        assert_eq!(a.chunk_width(), 0);
        a.enable_chunk(4);
        assert_eq!(a.chunk_width(), 4);
        assert_eq!(a.ctoks, vec![-7; 12], "chunk lanes start PAD-parked");
        assert_eq!(a.vlen, vec![0, 0, 0]);
        // full-width prefill row, remainder row, and a decode rider
        a.set_chunk_row(0, 8, &[10, 11, 12, 13], Some(14), 16);
        a.set_chunk_row(1, 5, &[20, 21], None, 8);
        a.set_chunk_row(2, 3, &[30], None, 8);
        assert_eq!(a.pos, vec![8, 5, 3], "pos lane doubles as chunk start");
        assert_eq!(a.vlen, vec![4, 2, 1]);
        assert_eq!(a.ctoks, vec![10, 11, 12, 13, 20, 21, -7, -7, 30, -7, -7, -7]);
        assert_eq!(a.ftok, vec![14, -7, -7]);
        assert_eq!(a.fmask, vec![1.0, 0.0, 0.0]);
        assert_eq!(a.cap, vec![16, 8, 8]);
        // a shorter chunk re-pads the stale tail of the same row
        a.set_chunk_row(0, 12, &[40], None, 16);
        assert_eq!(&a.ctoks[..4], &[40, -7, -7, -7]);
        let lanes = a.chunk_literals().unwrap();
        assert_eq!(lanes.ctoks.array_shape().unwrap().dims(), &[3, 4]);
        assert_eq!(lanes.start.array_shape().unwrap().dims(), &[3]);
        assert_eq!(lanes.vlen.to_vec::<i32>().unwrap(), vec![1, 2, 1]);
        a.reset();
        assert_eq!(a.ctoks, vec![-7; 12], "reset re-parks the chunk lanes");
        assert_eq!(a.vlen, vec![0, 0, 0]);
        assert_eq!(a.pos, vec![95, 95, 95]);
    }

    #[test]
    fn literal_shapes_fixed() {
        let a = StepArena::new(2, 3, 0, 1.0, 95);
        let l = a.to_literals().unwrap();
        assert_eq!(l.pos.array_shape().unwrap().dims(), &[2]);
        assert_eq!(l.gumbel.array_shape().unwrap().dims(), &[2, 3]);
        assert_eq!(l.temp.array_shape().unwrap().dims(), &[] as &[i64]);
        assert_eq!(l.fmask.to_vec::<f32>().unwrap(), vec![1.0, 1.0]);
    }
}
