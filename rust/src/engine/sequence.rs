//! Per-sequence state inside the engine.
//!
//! Token-stream convention (must match the AOT decode graph, model.py):
//! the stream is `[BOS, prompt..., generated...]`; at cache position p the
//! engine feeds stream[p] as cur_tok and the graph predicts stream[p+1].
//! While p+1 still lies inside the prompt the prediction is *forced*
//! (prefill-through-decode); afterwards the Gumbel-max sample is taken
//! and its behavior logprob + weight version are recorded.

use crate::data::task::Problem;
use crate::rl::{FinishReason, Rollout};
use crate::sched::SeqSnapshot;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SeqPhase {
    /// still force-feeding prompt tokens
    Prefill,
    /// sampling new tokens
    Decode,
    Finished(FinishReason),
}

#[derive(Debug, Clone)]
pub struct SeqState {
    pub seq_id: u64,
    pub group_id: u64,
    pub problem: Problem,
    /// [BOS, prompt...]
    pub stream: Vec<i32>,
    pub prompt_len: usize, // len incl. BOS
    pub gen_tokens: Vec<i32>,
    pub behavior_lp: Vec<f32>,
    pub token_version: Vec<u64>,
    /// next cache position to write (== tokens fed so far)
    pub pos: usize,
    pub phase: SeqPhase,
    pub max_new: usize,
    pub t_start: f64,
}

impl SeqState {
    pub fn new(seq_id: u64, group_id: u64, problem: Problem, prompt_tokens: Vec<i32>,
               bos: i32, max_new: usize, t_start: f64) -> Self {
        let mut stream = Vec::with_capacity(prompt_tokens.len() + 1);
        stream.push(bos);
        stream.extend_from_slice(&prompt_tokens);
        SeqState {
            seq_id,
            group_id,
            problem,
            prompt_len: stream.len(),
            stream,
            gen_tokens: Vec::new(),
            behavior_lp: Vec::new(),
            token_version: Vec::new(),
            pos: 0,
            phase: SeqPhase::Prefill,
            max_new,
            t_start,
        }
    }

    /// The token to feed at the current position.
    pub fn cur_token(&self) -> i32 {
        self.stream[self.pos]
    }

    /// If the next position is still prompt, the forced token.
    pub fn forced_next(&self) -> Option<i32> {
        self.stream.get(self.pos + 1).copied()
    }

    pub fn total_len(&self) -> usize {
        self.stream.len()
    }

    pub fn gen_len(&self) -> usize {
        self.gen_tokens.len()
    }

    /// Scheduler-facing view of this sequence — what admission picks and
    /// preemption victim rules see (`sched::SeqView`). `kv_blocks` is the
    /// caller's allocator-side block bill for this sequence: the engine
    /// passes `ceil(total_len / block_size)` for queued sequences and the
    /// share-aware private-block count for seated ones, so the victim
    /// rule sees the real eviction cost.
    pub fn view(&self, kv_blocks: usize) -> crate::sched::SeqView {
        crate::sched::SeqView {
            seq_id: self.seq_id,
            group_id: self.group_id,
            total_len: self.total_len(),
            gen_len: self.gen_len(),
            pos: self.pos,
            kv_blocks,
        }
    }

    /// Advance after a decode step produced `next_tok` with `lp` under
    /// weight `version`. `eos`/`max_seq` close the sequence.
    pub fn advance(&mut self, next_tok: i32, lp: f32, version: u64, eos_id: i32, max_seq: usize) {
        debug_assert!(!matches!(self.phase, SeqPhase::Finished(_)));
        let forced = self.forced_next().is_some();
        if forced {
            self.pos += 1;
            if self.pos + 1 >= self.prompt_len {
                self.phase = SeqPhase::Decode;
            }
            return;
        }
        // sampled token
        self.stream.push(next_tok);
        self.gen_tokens.push(next_tok);
        self.behavior_lp.push(lp);
        self.token_version.push(version);
        self.pos += 1;
        if next_tok == eos_id {
            self.phase = SeqPhase::Finished(FinishReason::Eos);
        } else if self.gen_len() >= self.max_new || self.pos + 1 >= max_seq {
            self.phase = SeqPhase::Finished(FinishReason::Length);
        }
    }

    pub fn finished(&self) -> bool {
        matches!(self.phase, SeqPhase::Finished(_))
    }

    /// Export as a portable snapshot (see `sched::snapshot`). `rng_words`
    /// is the owning engine's RNG cursor at export time — a deterministic
    /// harness that resumes from it continues the exact sampling stream.
    pub fn to_snapshot(&self, rng_words: [u64; 4]) -> SeqSnapshot {
        debug_assert!(!self.finished(), "finished sequences leave via into_rollout");
        SeqSnapshot {
            seq_id: self.seq_id,
            group_id: self.group_id,
            problem_id: self.problem.id,
            prompt: self.stream[..self.prompt_len].to_vec(),
            gen_tokens: self.gen_tokens.clone(),
            behavior_lp: self.behavior_lp.clone(),
            token_version: self.token_version.clone(),
            pos: self.pos,
            max_new: self.max_new,
            rng_words,
            t_start: self.t_start,
        }
    }

    /// Rebuild an in-flight sequence from a snapshot exported elsewhere.
    /// `seq_id` is the *importing* engine's fresh id (snapshot ids are
    /// only unique per exporting engine); the group id travels verbatim.
    /// The phase is re-derived from the position, matching the transition
    /// in [`SeqState::advance`].
    pub fn from_snapshot(snap: &SeqSnapshot, seq_id: u64, problem: Problem, t_start: f64) -> SeqState {
        let mut stream = Vec::with_capacity(snap.total_len());
        stream.extend_from_slice(&snap.prompt);
        stream.extend_from_slice(&snap.gen_tokens);
        let phase = if snap.pos + 1 < snap.prompt.len() {
            SeqPhase::Prefill
        } else {
            SeqPhase::Decode
        };
        SeqState {
            seq_id,
            group_id: snap.group_id,
            problem,
            prompt_len: snap.prompt.len(),
            stream,
            gen_tokens: snap.gen_tokens.clone(),
            behavior_lp: snap.behavior_lp.clone(),
            token_version: snap.token_version.clone(),
            pos: snap.pos,
            phase,
            max_new: snap.max_new,
            t_start,
        }
    }

    pub fn into_rollout(self, actor_id: usize, t_end: f64) -> Rollout {
        let finish = match self.phase {
            SeqPhase::Finished(f) => f,
            _ => FinishReason::Aborted,
        };
        Rollout {
            seq_id: self.seq_id,
            problem_id: self.problem.id,
            group_id: self.group_id,
            actor_id,
            prompt_tokens: self.stream[..self.prompt_len].to_vec(),
            gen_tokens: self.gen_tokens,
            behavior_lp: self.behavior_lp,
            token_version: self.token_version,
            reward: 0.0, // filled by the actor after verification
            finish,
            t_start: self.t_start,
            t_end,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::task::TaskGen;

    fn seq(max_new: usize) -> SeqState {
        let p = TaskGen::curriculum_small().problem(1);
        SeqState::new(7, 1, p, vec![10, 11, 12], 1, max_new, 0.0)
    }

    #[test]
    fn prefill_forces_prompt_then_decodes() {
        let mut s = seq(8);
        assert_eq!(s.phase, SeqPhase::Prefill);
        assert_eq!(s.cur_token(), 1); // BOS
        assert_eq!(s.forced_next(), Some(10));
        s.advance(99, -0.1, 0, 2, 96); // forced: 99 ignored
        assert_eq!(s.cur_token(), 10);
        s.advance(99, -0.1, 0, 2, 96);
        s.advance(99, -0.1, 0, 2, 96);
        assert_eq!(s.phase, SeqPhase::Decode);
        assert_eq!(s.gen_len(), 0, "forced tokens are not recorded");
        // now sampling
        s.advance(42, -0.7, 3, 2, 96);
        assert_eq!(s.gen_tokens, vec![42]);
        assert_eq!(s.behavior_lp, vec![-0.7]);
        assert_eq!(s.token_version, vec![3]);
    }

    #[test]
    fn eos_finishes() {
        let mut s = seq(8);
        for _ in 0..3 {
            s.advance(0, 0.0, 0, 2, 96);
        }
        s.advance(2, -0.5, 1, 2, 96); // EOS
        assert_eq!(s.phase, SeqPhase::Finished(FinishReason::Eos));
        let r = s.into_rollout(0, 1.0);
        assert_eq!(r.gen_tokens, vec![2]);
        r.validate().unwrap();
    }

    #[test]
    fn budget_finishes_with_length() {
        let mut s = seq(2);
        for _ in 0..3 {
            s.advance(0, 0.0, 0, 2, 96);
        }
        s.advance(5, -0.5, 0, 2, 96);
        s.advance(6, -0.5, 0, 2, 96);
        assert_eq!(s.phase, SeqPhase::Finished(FinishReason::Length));
        assert_eq!(s.gen_len(), 2);
    }

    #[test]
    fn max_seq_caps_even_before_budget() {
        let mut s = seq(100);
        for _ in 0..3 {
            s.advance(0, 0.0, 0, 2, 8);
        }
        for i in 0..4 {
            s.advance(5 + i, -0.1, 0, 2, 8);
        }
        assert!(matches!(s.phase, SeqPhase::Finished(FinishReason::Length)));
        assert!(s.total_len() <= 8);
    }

    #[test]
    fn snapshot_roundtrip_resumes_mid_decode() {
        let mut s = seq(8);
        for _ in 0..3 {
            s.advance(0, 0.0, 0, 2, 96); // prefill
        }
        s.advance(42, -0.7, 3, 2, 96);
        s.advance(43, -0.9, 4, 2, 96);
        let words = [1, 2, 3, 4];
        let snap = s.to_snapshot(words);
        snap.validate().unwrap();
        assert_eq!(snap.prompt, vec![1, 10, 11, 12]);
        assert_eq!(snap.gen_tokens, vec![42, 43]);
        assert_eq!(snap.token_version, vec![3, 4]);
        assert_eq!(snap.rng_words, words);

        let p = TaskGen::curriculum_small().problem(snap.problem_id);
        let r = SeqState::from_snapshot(&snap, 99, p, 5.0);
        assert_eq!(r.seq_id, 99, "importer assigns its own id");
        assert_eq!(r.group_id, s.group_id, "group id travels verbatim");
        assert_eq!(r.stream, s.stream);
        assert_eq!(r.pos, s.pos);
        assert_eq!(r.phase, SeqPhase::Decode);
        assert_eq!(r.cur_token(), 43);
        assert_eq!(r.forced_next(), None, "resumes sampling, not forcing");
        // continues exactly where the exporter stopped
        let mut r = r;
        r.advance(2, -0.1, 5, 2, 96); // EOS
        let out = r.into_rollout(7, 6.0);
        assert_eq!(out.gen_tokens, vec![42, 43, 2]);
        assert_eq!(out.token_version, vec![3, 4, 5]);
        out.validate().unwrap();
    }

    #[test]
    fn snapshot_of_prefill_sequence_resumes_forcing() {
        let mut s = seq(8);
        s.advance(0, 0.0, 0, 2, 96); // one forced step: pos = 1
        let snap = s.to_snapshot([0; 4]);
        assert_eq!(snap.salvaged_tokens(), 0);
        let p = TaskGen::curriculum_small().problem(snap.problem_id);
        let r = SeqState::from_snapshot(&snap, 1, p, 0.0);
        assert_eq!(r.phase, SeqPhase::Prefill);
        assert_eq!(r.forced_next(), Some(11), "prompt forcing continues");
    }

    #[test]
    fn mixed_versions_recorded() {
        let mut s = seq(8);
        for _ in 0..3 {
            s.advance(0, 0.0, 0, 2, 96);
        }
        s.advance(5, -0.1, 1, 2, 96);
        s.advance(6, -0.1, 2, 2, 96);
        s.advance(7, -0.1, 2, 2, 96);
        let r = s.into_rollout(3, 2.0);
        assert_eq!(r.version_span(), 1);
        assert_eq!(r.actor_id, 3);
    }
}
