//! Generation engine — the vLLM substitute (DESIGN.md §2).
//!
//! Faithful to the coordination contract the paper relies on:
//!
//! * **continuous batching** — a fixed pool of `gen_batch` slots; new
//!   requests are admitted *in-flight* the moment a slot (and its KV
//!   blocks) frees, without stopping in-progress sequences. *Which*
//!   pending sequence enters a freed slot is a pluggable
//!   [`crate::sched::Scheduler`] policy (FIFO default; longest-prefix
//!   first to prioritize migrated work);
//! * **portable in-flight sequences** — [`Engine::export_snapshots`]
//!   drains every in-flight sequence into serializable
//!   [`crate::sched::SeqSnapshot`]s (prompt + generated prefix +
//!   per-token logprobs/versions + RNG cursor) instead of aborting them;
//!   [`Engine::import_snapshot`] adopts one on another engine, rebuilding
//!   its KV prefix with the existing replay path — no salvageable token
//!   is lost to actor churn or descaling;
//! * **paged KV accounting with shared-prefix memory** — a refcounted
//!   block allocator in the vLLM style ([`kvcache`]) gates admission and
//!   growth: the G members of a GRPO group reference one set of prompt
//!   blocks (copy-on-write, forking on first divergent write), an
//!   over-committed pool (`[kv] overcommit`) throttles exactly like a
//!   full HBM, and under block pressure the scheduler's preemption hook
//!   parks a victim through the snapshot path (blocks freed, resumed via
//!   a coalesced replay) instead of stalling the slot. Two device
//!   layouts back the same accounting (`[kv] layout`): **dense** (the
//!   default) keeps the legacy per-slot `[L, 2, B, Tmax, H, hd]` tensor,
//!   with the allocator as the admission-capacity model and its tables
//!   enforced at dispatch time (`runtime::StagePlan`); **paged** runs
//!   the `decode_paged` graph against a device block *pool*
//!   `[n_blocks, L, 2, block_size, H, hd]`, shipping the allocator's
//!   block tables as a per-step graph operand (plus CoW copy lanes for
//!   shared-prompt forks), so block indices are real device addresses —
//!   validated per dispatch by `runtime::TablePlan`. Replays after a
//!   park/import are **per-row**: only the re-admitted rows are re-fed
//!   (`stats.replay_rows_skipped` counts the resident neighbors the
//!   legacy full-batch replay would have redundantly rebuilt);
//! * **in-flight weight updates** — eager ([`Engine::set_weights`]) or
//!   overlapped ([`Engine::begin_weight_update`] /
//!   [`Engine::stage_weight_tensor`] / [`Engine::commit_weights`]) swaps
//!   between decode steps while *retaining* the KV cache (the paper's
//!   §5.1 design choice), tagging subsequent tokens with the new version;
//! * **prefill-through-decode, chunked** — prompts are force-fed through
//!   the decode path (the force_tok/force_mask inputs), so one compiled
//!   family of executables serves the whole request path. With
//!   `[kv] prefill_chunk = W` (> 1) the engine dispatches the
//!   `prefill_chunk`/`prefill_chunk_paged` graphs instead: each round
//!   feeds up to `W` forced tokens per row (`[B, W]` lanes in the
//!   [`arena::StepArena`]), so ingesting or replaying a prompt of `P`
//!   tokens costs `ceil(P/W)` dispatches instead of `P`
//!   (`stats.prefill_chunks` / `stats.forced_steps_saved` account for
//!   it, `stats.prefill_us` splits the execute time out of the decode
//!   path). Chunk rounds interleave with decode — rows mid-generation
//!   take their one sampled step in the same dispatch via the chunk
//!   graph's final lane, and the RNG cursor burns exactly the per-step
//!   Gumbel draws the token-at-a-time path would, so token streams,
//!   logprobs, version tags, and golden digests are identical between
//!   `W = 1` (bit-for-bit legacy) and any `W > 1`;
//! * the paper's three-endpoint service API as a trait ([`api`]).
//!
//! # Hot-path data flow (§Perf)
//!
//! What lives **on device** across decode steps:
//!
//! * the **active parameter buffers** — staged once per weight version
//!   into a [`crate::weights::ShadowSet`] and reused every step;
//! * the **KV cache** — the previous step's KV output buffer is fed
//!   straight back as the next step's operand
//!   ([`crate::runtime::Graph::run_buffers_b`] keeps outputs
//!   device-resident when the client untuples results). The KV tensor —
//!   by far the largest operand — crosses the host boundary only at
//!   engine init and recompute replays (`stats.kv_restages` counts).
//!
//! What crosses the boundary **per step**:
//!
//! * *host→device*: the `O(B)` index/force inputs and the `[B, V]`
//!   Gumbel noise, written in place into a reusable [`arena::StepArena`]
//!   (no per-step allocation) and staged as fresh literals;
//! * *device→host*: `next_tok[B]` and `chosen_lp[B]` only — `lp_all` is
//!   read back solely under `capture_dist`, the KV and entropy outputs
//!   never (selective readback via [`crate::runtime::ExecOut`]).
//!
//! Where the **weight swap** lands: the actor stages incoming tensors
//! into the shadow buffer set between decode steps
//! ([`crate::weights::WeightBus::begin_fetch`] chunks), then the swap is
//! a pointer exchange at a step boundary — `stats.weight_stall_us` stays
//! at zero for overlapped swaps, vs. the full transfer stall the eager
//! path records. On builds whose executable returns a single tuple
//! (no PJRT untupling), every path degrades gracefully to the legacy
//! stage-and-readback behavior.

pub mod api;
pub mod arena;
pub mod engine;
pub mod kvcache;
pub mod sequence;

pub use api::{CompletionRequest, GenerationService, KvPressure, QosClass, ROLLOUT_TENANT};
pub use arena::StepArena;
pub use engine::{Engine, EngineCfg, EngineStats, StepOutcome};
pub use kvcache::BlockAllocator;
pub use sequence::{SeqPhase, SeqState};
