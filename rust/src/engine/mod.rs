//! Generation engine — the vLLM substitute (DESIGN.md §2).
//!
//! Faithful to the coordination contract the paper relies on:
//!
//! * **continuous batching** — a fixed pool of `gen_batch` slots; new
//!   requests are admitted *in-flight* the moment a slot (and its KV
//!   blocks) frees, without stopping in-progress sequences;
//! * **paged KV accounting** — a block allocator in the vLLM style
//!   ([`kvcache`]) gates admission; the device-side cache itself is a
//!   dense per-slot tensor (the AOT decode graph's layout);
//! * **in-flight weight updates** — [`Engine::set_weights`] swaps the
//!   parameter set between decode steps while *retaining* the KV cache
//!   (the paper's §5.1 design choice), tagging subsequent tokens with the
//!   new weight version;
//! * **prefill-through-decode** — prompts are force-fed through the same
//!   decode graph (the force_tok/force_mask inputs), so one compiled
//!   executable serves the whole request path;
//! * the paper's three-endpoint service API as a trait ([`api`]).

pub mod api;
pub mod engine;
pub mod kvcache;
pub mod sequence;

pub use api::{CompletionRequest, GenerationService};
pub use engine::{Engine, EngineCfg, StepOutcome};
pub use kvcache::BlockAllocator;
pub use sequence::{SeqPhase, SeqState};
