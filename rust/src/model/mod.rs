//! Model-side host utilities: the tokenizer (mirroring the python vocab)
//! and checkpoint (de)serialization for parameter sets.

pub mod checkpoint;
pub mod tokenizer;

pub use tokenizer::Tokenizer;
