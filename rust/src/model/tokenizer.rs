//! Character-level tokenizer, the exact mirror of python/compile/vocab.py.
//!
//! The alphabet string below is load-bearing and must match ALPHABET in
//! vocab.py byte-for-byte; `Tokenizer::verify_against_artifact` checks the
//! generated artifacts/vocab.json at runtime so the two can never drift
//! silently (also exercised as a cargo test).

use crate::util::Json;
use anyhow::{bail, Result};
use std::collections::HashMap;
use std::path::Path;

pub const PAD_ID: i32 = 0;
pub const BOS_ID: i32 = 1;
pub const EOS_ID: i32 = 2;

/// Must match python/compile/vocab.py ALPHABET exactly.
pub const ALPHABET: &str = "0123456789+-*/=()<>.,:; \nabcdefghijklmnopqrstuvwxyz?_";

pub const V: usize = 64;

#[derive(Debug, Clone)]
pub struct Tokenizer {
    to_id: HashMap<char, i32>,
    to_char: Vec<Option<char>>,
}

impl Default for Tokenizer {
    fn default() -> Self {
        Self::new()
    }
}

impl Tokenizer {
    pub fn new() -> Self {
        let mut to_id = HashMap::new();
        let mut to_char = vec![None; V];
        for (i, c) in ALPHABET.chars().enumerate() {
            let id = 3 + i as i32;
            to_id.insert(c, id);
            to_char[id as usize] = Some(c);
        }
        Tokenizer { to_id, to_char }
    }

    pub fn vocab_size(&self) -> usize {
        V
    }

    pub fn encode(&self, text: &str) -> Result<Vec<i32>> {
        text.chars()
            .map(|c| {
                self.to_id
                    .get(&c)
                    .copied()
                    .ok_or_else(|| anyhow::anyhow!("char {c:?} not in alphabet"))
            })
            .collect()
    }

    /// Decode, stopping at EOS and skipping specials.
    pub fn decode(&self, ids: &[i32]) -> String {
        let mut out = String::new();
        for &id in ids {
            if id == EOS_ID {
                break;
            }
            if id == PAD_ID || id == BOS_ID {
                continue;
            }
            if let Some(Some(c)) = self.to_char.get(id as usize) {
                out.push(*c);
            }
        }
        out
    }

    /// Cross-check against the table emitted by aot.py.
    pub fn verify_against_artifact(&self, artifacts_dir: &Path) -> Result<()> {
        let text = std::fs::read_to_string(artifacts_dir.join("vocab.json"))?;
        let j = Json::parse(&text)?;
        let alphabet = j.req("alphabet")?.as_str()?;
        if alphabet != ALPHABET {
            bail!(
                "tokenizer drift: python alphabet {:?} != rust {:?}",
                alphabet,
                ALPHABET
            );
        }
        let table = j.req("table")?.as_arr()?;
        if table.len() != V {
            bail!("vocab table size {} != {V}", table.len());
        }
        for (i, entry) in table.iter().enumerate() {
            let s = entry.as_str()?;
            match self.to_char[i] {
                Some(c) => {
                    if s.chars().count() != 1 || s.chars().next() != Some(c) {
                        bail!("table[{i}] = {s:?}, rust has {c:?}");
                    }
                }
                None => {
                    if !s.starts_with('<') {
                        bail!("table[{i}] = {s:?}, rust has a special/unused");
                    }
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let tk = Tokenizer::new();
        let text = "12+34=46\nc:ok";
        let ids = tk.encode(text).unwrap();
        assert_eq!(tk.decode(&ids), text);
    }

    #[test]
    fn decode_stops_at_eos() {
        let tk = Tokenizer::new();
        let mut ids = tk.encode("42").unwrap();
        ids.push(EOS_ID);
        ids.extend(tk.encode("junk").unwrap());
        assert_eq!(tk.decode(&ids), "42");
    }

    #[test]
    fn rejects_unknown_chars() {
        let tk = Tokenizer::new();
        assert!(tk.encode("日本").is_err());
    }

    #[test]
    fn alphabet_fits_vocab() {
        assert!(ALPHABET.chars().count() + 3 <= V);
        // no duplicate characters
        let mut seen = std::collections::HashSet::new();
        for c in ALPHABET.chars() {
            assert!(seen.insert(c), "duplicate char {c:?}");
        }
    }

    #[test]
    fn matches_artifact_if_present() {
        let dir = crate::runtime::artifacts_dir();
        if dir.join("vocab.json").exists() {
            Tokenizer::new().verify_against_artifact(&dir).unwrap();
        }
    }
}
