//! Checkpoint (de)serialization for parameter / optimizer-state sets.
//!
//! Own compact binary format (offline env — no serde/safetensors):
//!
//! ```text
//! magic  "PRLCKPT1" / "PRLCKPT2" / "PRLCKPT3"   8 bytes
//! meta   u32 json_len, json bytes         variant name, step, tensor index
//! data   for each tensor: f32 LE values   (shapes live in the json index)
//! ```
//!
//! Two record types share the format:
//!
//! * [`Checkpoint`] (`PRLCKPT1`) — parameters only. Portable export used
//!   by `pipeline-rl eval` and anything that just needs weights.
//! * [`TrainState`] (`PRLCKPT3`, reading `PRLCKPT2` too) — the trainer's
//!   **full resume state**: parameters, both Adam moments, the
//!   sample/token counters and an RNG cursor. `PRLCKPT3` additionally
//!   carries the *generation-side* cursors — the engine sampling-RNG
//!   cursor and the scheduler admission cursor — which is what extends
//!   bit-identical resume from the optimizer trajectory to whole
//!   deterministic runs (see `testkit::golden` and tests/determinism.rs).
//!   A `PRLCKPT2` file loads with those cursors zeroed, so pre-existing
//!   checkpoints stay readable. A run resumed from a `TrainState`
//!   continues the optimizer trajectory exactly (see
//!   tests/checkpoint_resume.rs for the bit-identity property).
//!
//! `TrainState::save_with_manifest` additionally maintains a
//! `manifest.json` in the checkpoint directory (latest + history with
//! optional pruning) so `[checkpoint] resume_from = "<dir>"` can pick up
//! the newest state without knowing file names. State files are fsynced
//! *before* the manifest points at them, and the manifest itself is
//! fsynced and renamed into place — no reader ever resumes from a torn
//! state.
//!
//! The trainer no longer writes on its hot thread: it hands states to an
//! [`AsyncCheckpointer`] — a writer thread with a latest-wins queue — so
//! checkpoint I/O overlaps optimizer steps instead of stalling them (the
//! stall the broker's ring buffers used to absorb; the failure-injection
//! suite still exercises a synchronous-write stall via its own harness).

use crate::runtime::HostTensor;
use crate::util::Json;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

const MAGIC: &[u8; 8] = b"PRLCKPT1";
const MAGIC_STATE: &[u8; 8] = b"PRLCKPT2";
const MAGIC_STATE3: &[u8; 8] = b"PRLCKPT3";
const MANIFEST: &str = "manifest.json";

/// Write-path crash injection for the checkpoint durability property
/// (tests/checkpoint_resume.rs): abort the save at one specific stage of
/// the submit → write → fsync → rename protocol, leaving on disk exactly
/// what a crash at that point would. "Crash before fsync" is modeled by
/// truncating the file tail — the page-cache bytes a real crash loses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CkptFault {
    /// die mid-way through writing the state file (torn state)
    StateWrite,
    /// state bytes fully written but the fsync never ran
    StateFsync,
    /// die mid-way through writing the manifest sidecar
    ManifestWrite,
    /// manifest sidecar written but its fsync never ran
    ManifestFsync,
    /// durable sidecar, but the rename into place never happened
    ManifestRename,
}

fn shapes_json(tensors: &[HostTensor]) -> Json {
    Json::Arr(
        tensors
            .iter()
            .map(|t| Json::Arr(t.shape().iter().map(|&d| Json::Num(d as f64)).collect()))
            .collect(),
    )
}

fn write_tensor_data(f: &mut impl Write, tensors: &[HostTensor]) -> Result<()> {
    for t in tensors {
        let data = t.f32s().context("checkpoints hold f32 tensors")?;
        // SAFETY-free explicit LE encode
        let mut buf = Vec::with_capacity(data.len() * 4);
        for x in data {
            buf.extend_from_slice(&x.to_le_bytes());
        }
        f.write_all(&buf)?;
    }
    Ok(())
}

fn read_tensor_list(f: &mut impl Read, shapes: &Json) -> Result<Vec<HostTensor>> {
    let mut out = Vec::new();
    for tshape in shapes.as_arr()? {
        let shape: Vec<usize> = tshape
            .as_arr()?
            .iter()
            .map(|d| d.as_usize())
            .collect::<Result<_>>()?;
        let n: usize = shape.iter().product();
        let mut raw = vec![0u8; n * 4];
        f.read_exact(&mut raw)?;
        let data = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        out.push(HostTensor::F32 { shape, data });
    }
    Ok(out)
}

pub struct Checkpoint {
    pub variant: String,
    pub step: u64,
    pub params: Vec<HostTensor>,
}

impl Checkpoint {
    pub fn save(&self, path: &Path) -> Result<()> {
        let index = Json::Obj(vec![
            ("variant".into(), Json::Str(self.variant.clone())),
            ("step".into(), Json::Num(self.step as f64)),
            ("tensors".into(), shapes_json(&self.params)),
        ]);
        let meta = index.to_string_compact().into_bytes();
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        f.write_all(&(meta.len() as u32).to_le_bytes())?;
        f.write_all(&meta)?;
        write_tensor_data(&mut f, &self.params)?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?,
        );
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{path:?} is not a PipelineRL checkpoint");
        }
        let mut len4 = [0u8; 4];
        f.read_exact(&mut len4)?;
        let mut meta = vec![0u8; u32::from_le_bytes(len4) as usize];
        f.read_exact(&mut meta)?;
        let j = Json::parse(std::str::from_utf8(&meta)?)?;
        let variant = j.req("variant")?.as_str()?.to_string();
        let step = j.req("step")?.as_f64()? as u64;
        let params = read_tensor_list(&mut f, j.req("tensors")?)?;
        Ok(Checkpoint { variant, step, params })
    }
}

/// Full trainer resume state (`PRLCKPT3`; `PRLCKPT2` still loads with
/// the generation-side cursors zeroed): everything the trainer needs to
/// continue a run as if it had never stopped.
#[derive(Debug, Clone, PartialEq)]
pub struct TrainState {
    pub variant: String,
    /// last completed optimizer step
    pub step: u64,
    pub params: Vec<HostTensor>,
    /// Adam first moment
    pub opt_m: Vec<HostTensor>,
    /// Adam second moment
    pub opt_v: Vec<HostTensor>,
    pub samples_total: f64,
    pub tokens_total: f64,
    /// trainer RNG cursor ([`crate::util::Rng::state_words`]) for
    /// deterministic replay harnesses; all-zero when the producer owns no
    /// RNG.
    pub rng: [u64; 4],
    /// generation-side sampling/admission RNG cursor (the engine's
    /// stream): restoring it makes a resumed run draw the exact same
    /// prompts/samples the uninterrupted run would have (PRLCKPT3;
    /// all-zero when loaded from a PRLCKPT2 file).
    pub engine_rng: [u64; 4],
    /// scheduler admission cursor — how many sequences were ever admitted
    /// (the engine's next local sequence id). Restoring it keeps local
    /// ids and admission order collision-free across a full-run resume
    /// (PRLCKPT3; zero when loaded from a PRLCKPT2 file).
    pub sched_cursor: u64,
}

impl TrainState {
    /// Canonical file name for a step's state inside a checkpoint dir.
    pub fn file_name(step: u64) -> String {
        format!("step{step:05}.state")
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        self.save_faulted(path, None)
    }

    /// [`TrainState::save`] with an optional injected crash (see
    /// [`CkptFault`]). Test-facing: the durability property drives every
    /// stage of the write protocol through this hook.
    pub fn save_faulted(&self, path: &Path, fault: Option<CkptFault>) -> Result<()> {
        fn hex_words(w: &[u64; 4]) -> Json {
            // full-width u64 words: hex strings, f64 would truncate
            Json::Arr(w.iter().map(|w| Json::Str(format!("{w:016x}"))).collect())
        }
        let index = Json::Obj(vec![
            ("variant".into(), Json::Str(self.variant.clone())),
            ("step".into(), Json::Num(self.step as f64)),
            ("samples_total".into(), Json::Num(self.samples_total)),
            ("tokens_total".into(), Json::Num(self.tokens_total)),
            ("rng".into(), hex_words(&self.rng)),
            ("engine_rng".into(), hex_words(&self.engine_rng)),
            (
                "sched_cursor".into(),
                Json::Str(format!("{:016x}", self.sched_cursor)),
            ),
            ("params".into(), shapes_json(&self.params)),
            ("opt_m".into(), shapes_json(&self.opt_m)),
            ("opt_v".into(), shapes_json(&self.opt_v)),
        ]);
        let meta = index.to_string_compact().into_bytes();
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC_STATE3)?;
        f.write_all(&(meta.len() as u32).to_le_bytes())?;
        f.write_all(&meta)?;
        write_tensor_data(&mut f, &self.params)?;
        if fault == Some(CkptFault::StateWrite) {
            // die mid-write: flush what the kernel already has, skip the
            // rest — a torn state file with no fsync and no manifest entry
            f.flush()?;
            bail!("injected crash: state write torn at {path:?}");
        }
        write_tensor_data(&mut f, &self.opt_m)?;
        write_tensor_data(&mut f, &self.opt_v)?;
        // durability before visibility: the state file is fsynced here,
        // and save_with_manifest only points the manifest at it afterwards
        // — a crash mid-write can never leave the manifest naming a
        // torn state
        f.flush()?;
        if fault == Some(CkptFault::StateFsync) {
            // crash before the fsync: the tail of the write never left the
            // page cache
            let len = f.get_ref().metadata()?.len();
            f.get_ref().set_len(len.saturating_sub(8))?;
            bail!("injected crash: state file never fsynced at {path:?}");
        }
        f.get_ref().sync_all()?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<TrainState> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?,
        );
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        let v3 = &magic == MAGIC_STATE3;
        if !v3 && &magic != MAGIC_STATE {
            bail!("{path:?} is not a PipelineRL train state (PRLCKPT2/PRLCKPT3)");
        }
        let mut len4 = [0u8; 4];
        f.read_exact(&mut len4)?;
        let mut meta = vec![0u8; u32::from_le_bytes(len4) as usize];
        f.read_exact(&mut meta)?;
        let j = Json::parse(std::str::from_utf8(&meta)?)?;
        let read_words = |j: &Json, key: &str| -> Result<[u64; 4]> {
            let words = j.req(key)?.as_arr()?;
            if words.len() != 4 {
                bail!(
                    "{path:?}: {key} cursor must be 4 words, found {} — refusing a \
                     state that would silently break deterministic resume",
                    words.len()
                );
            }
            let mut out = [0u64; 4];
            for (i, w) in words.iter().enumerate() {
                out[i] = u64::from_str_radix(w.as_str()?, 16)
                    .with_context(|| format!("{key} cursor must be a hex word"))?;
            }
            Ok(out)
        };
        let rng = read_words(&j, "rng")?;
        // generation-side cursors: mandatory in PRLCKPT3, zeroed for a
        // legacy PRLCKPT2 file (which never carried them)
        let (engine_rng, sched_cursor) = if v3 {
            let cursor = j.req("sched_cursor")?.as_str()?;
            (
                read_words(&j, "engine_rng")?,
                u64::from_str_radix(cursor, 16)
                    .context("sched_cursor must be a hex word")?,
            )
        } else {
            ([0u64; 4], 0)
        };
        let params = read_tensor_list(&mut f, j.req("params")?)?;
        let opt_m = read_tensor_list(&mut f, j.req("opt_m")?)?;
        let opt_v = read_tensor_list(&mut f, j.req("opt_v")?)?;
        Ok(TrainState {
            variant: j.req("variant")?.as_str()?.to_string(),
            step: j.req("step")?.as_f64()? as u64,
            samples_total: j.req("samples_total")?.as_f64()?,
            tokens_total: j.req("tokens_total")?.as_f64()?,
            rng,
            engine_rng,
            sched_cursor,
            params,
            opt_m,
            opt_v,
        })
    }

    /// Save under the canonical name in `dir` and update `manifest.json`
    /// (latest pointer + history). With `keep_last > 0`, prunes the oldest
    /// state files beyond the window. Returns the state file path.
    pub fn save_with_manifest(&self, dir: &Path, keep_last: usize) -> Result<PathBuf> {
        self.save_with_manifest_faulted(dir, keep_last, None)
    }

    /// [`TrainState::save_with_manifest`] with an optional injected crash
    /// at one stage of the write protocol (see [`CkptFault`]). The
    /// durability invariant under test: *no matter where the crash lands,
    /// the manifest on disk only ever names fully-fsynced state files.*
    /// That is why pruning runs strictly **after** the manifest rename —
    /// deleting old states first would leave a crash window in which the
    /// still-current manifest names files that no longer exist.
    pub fn save_with_manifest_faulted(
        &self,
        dir: &Path,
        keep_last: usize,
        fault: Option<CkptFault>,
    ) -> Result<PathBuf> {
        std::fs::create_dir_all(dir)?;
        let name = Self::file_name(self.step);
        let path = dir.join(&name);
        self.save_faulted(&path, fault)?;

        let mut history = read_manifest(dir).map(|(_, h)| h).unwrap_or_default();
        history.retain(|h| h != &name);
        history.push(name.clone());
        let mut prune = Vec::new();
        if keep_last > 0 {
            while history.len() > keep_last {
                prune.push(history.remove(0));
            }
        }
        let manifest = Json::Obj(vec![
            ("format".into(), Json::Str("PRLSTATE1".into())),
            ("latest".into(), Json::Str(name)),
            (
                "history".into(),
                Json::Arr(history.into_iter().map(Json::Str).collect()),
            ),
        ]);
        // atomic update: write + fsync the sidecar, then rename over —
        // readers only ever see a complete manifest naming fsynced states
        let tmp = dir.join(format!("{MANIFEST}.tmp"));
        {
            let bytes = manifest.to_string_compact().into_bytes();
            let mut tf = std::fs::File::create(&tmp)?;
            if fault == Some(CkptFault::ManifestWrite) {
                tf.write_all(&bytes[..bytes.len() / 2])?;
                bail!("injected crash: manifest sidecar torn in {dir:?}");
            }
            tf.write_all(&bytes)?;
            if fault == Some(CkptFault::ManifestFsync) {
                tf.set_len(bytes.len().saturating_sub(4) as u64)?;
                bail!("injected crash: manifest sidecar never fsynced in {dir:?}");
            }
            tf.sync_all()?;
        }
        if fault == Some(CkptFault::ManifestRename) {
            bail!("injected crash: manifest rename never happened in {dir:?}");
        }
        std::fs::rename(&tmp, dir.join(MANIFEST))?;
        // prune only once the new manifest is durable: until the rename
        // lands, the *old* manifest is authoritative and must keep naming
        // files that exist
        for victim in prune {
            std::fs::remove_file(dir.join(&victim)).ok();
        }
        Ok(path)
    }

    /// Load the newest state named by `dir/manifest.json`.
    pub fn load_latest(dir: &Path) -> Result<TrainState> {
        let (latest, _) = read_manifest(dir)
            .with_context(|| format!("no readable {MANIFEST} in {dir:?}"))?;
        Self::load(&dir.join(latest))
    }

    /// Resolve a `[checkpoint] resume_from` value: a directory loads its
    /// manifest's latest state, a file path loads that state directly.
    pub fn load_resume(path: &Path) -> Result<TrainState> {
        if path.is_dir() {
            Self::load_latest(path)
        } else {
            Self::load(path)
        }
    }
}

/// Load parameters from either record type: a `TrainState` (PRLCKPT3 or
/// the older PRLCKPT2, what the trainer writes) or a params-only
/// `Checkpoint` (PRLCKPT1). Returns (variant, step, params). Dispatches
/// on the file magic so a damaged file of either format reports its real
/// parse error instead of a misleading wrong-format message. The
/// `pipeline-rl eval` path and any external consumer should use this
/// instead of guessing.
pub fn load_params_any(path: &Path) -> Result<(String, u64, Vec<HostTensor>)> {
    let mut magic = [0u8; 8];
    {
        let mut f = std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?;
        f.read_exact(&mut magic)
            .with_context(|| format!("{path:?} is too short to be a checkpoint"))?;
    }
    if &magic == MAGIC_STATE || &magic == MAGIC_STATE3 {
        let st = TrainState::load(path)?;
        Ok((st.variant, st.step, st.params))
    } else {
        let ck = Checkpoint::load(path)?;
        Ok((ck.variant, ck.step, ck.params))
    }
}

/// Final accounting of an [`AsyncCheckpointer`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CkptWriterStats {
    /// states fully written (fsynced, manifest updated)
    pub written: u64,
    /// states replaced in the queue before the writer got to them
    /// (latest-wins: a fast trainer never queues more than one)
    pub superseded: u64,
    /// transient write errors absorbed by retry-with-backoff before a
    /// save eventually landed (or was given up on)
    pub retried: u64,
}

#[derive(Default)]
struct CkptPending {
    next: Option<TrainState>,
    closing: bool,
    written: u64,
    superseded: u64,
    retried: u64,
    last_err: Option<String>,
    /// crash injection for the durability property: consumed by the
    /// writer's next save
    fault_next: Option<CkptFault>,
}

struct CkptShared {
    pending: std::sync::Mutex<CkptPending>,
    cv: std::sync::Condvar,
}

/// Off-thread [`TrainState`] writer with a latest-wins queue.
///
/// The trainer's periodic checkpoint used to serialize + write + fsync a
/// full parameter/optimizer snapshot *on the hot thread*, stalling the
/// optimizer step (the ring buffers absorbed it, but the step time spiked
/// every `[checkpoint] every` steps). [`AsyncCheckpointer::submit`] is
/// now just a state hand-off: the writer thread does the serialization
/// and disk I/O. The queue holds at most one state — a newer submission
/// replaces an unwritten older one (latest wins; checkpoints are
/// recovery points, not an archive, so only the freshest matters), and
/// the `superseded` count keeps the books. The manifest is updated only
/// after the state file is fsynced (see [`TrainState::save`] /
/// `save_with_manifest`), so a crash of either thread never publishes a
/// torn state. [`AsyncCheckpointer::finish`] drains the queue before
/// returning — the final state of a run is always on disk.
pub struct AsyncCheckpointer {
    shared: std::sync::Arc<CkptShared>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl AsyncCheckpointer {
    /// `write_retries` bounds the retry-with-backoff on transient
    /// write/fsync/rename errors: each failed save is re-attempted up to
    /// that many more times (2–4–8 ms backoff) before the error is
    /// recorded and surfaced at `finish()`. Injected [`CkptFault`]s are
    /// one-shot — they hit only the first attempt — which is exactly the
    /// transient-error shape the retry is for; a persistent fault (bad
    /// directory, full disk) still fails every attempt and surfaces.
    pub fn new(dir: PathBuf, keep_last: usize, write_retries: usize) -> AsyncCheckpointer {
        let shared = std::sync::Arc::new(CkptShared {
            pending: std::sync::Mutex::new(CkptPending::default()),
            cv: std::sync::Condvar::new(),
        });
        let worker = shared.clone();
        let join = std::thread::Builder::new()
            .name("ckpt-writer".into())
            .spawn(move || loop {
                let (st, fault) = {
                    let mut g = worker.pending.lock().unwrap();
                    loop {
                        if let Some(st) = g.next.take() {
                            break (st, g.fault_next.take());
                        }
                        if g.closing {
                            return;
                        }
                        g = worker.cv.wait(g).unwrap();
                    }
                };
                let mut retries_used = 0u64;
                let res = loop {
                    // the injected fault models a transient error: it is
                    // consumed by the first attempt only
                    let this_fault = if retries_used == 0 { fault } else { None };
                    match st.save_with_manifest_faulted(&dir, keep_last, this_fault) {
                        Ok(p) => break Ok(p),
                        Err(e) => {
                            if retries_used >= write_retries as u64 {
                                break Err(e);
                            }
                            retries_used += 1;
                            std::thread::sleep(std::time::Duration::from_millis(
                                1u64 << retries_used.min(6),
                            ));
                        }
                    }
                };
                let mut g = worker.pending.lock().unwrap();
                g.retried += retries_used;
                match res {
                    Ok(_) => g.written += 1,
                    Err(e) => g.last_err = Some(format!("step {}: {e:#}", st.step)),
                }
                // wake a finish() waiting on the drain
                worker.cv.notify_all();
            })
            .expect("spawning ckpt-writer");
        AsyncCheckpointer { shared, join: Some(join) }
    }

    /// Hand a state to the writer (non-blocking). An unwritten older
    /// state still queued is replaced — latest wins.
    pub fn submit(&self, st: TrainState) {
        let mut g = self.shared.pending.lock().unwrap();
        if g.next.replace(st).is_some() {
            g.superseded += 1;
        }
        self.shared.cv.notify_all();
    }

    /// Inject a crash ([`CkptFault`]) into the writer's *next* save —
    /// the durability property drives every stage of the submit → write
    /// → fsync → rename protocol through this.
    pub fn inject_fault_next(&self, fault: CkptFault) {
        self.shared.pending.lock().unwrap().fault_next = Some(fault);
    }

    /// Drain the queue, stop the writer and join it. Returns the write
    /// accounting; a failed write surfaces here (the run should know its
    /// recovery points are broken).
    pub fn finish(mut self) -> Result<CkptWriterStats> {
        {
            let mut g = self.shared.pending.lock().unwrap();
            g.closing = true;
            self.shared.cv.notify_all();
        }
        if let Some(j) = self.join.take() {
            j.join().ok();
        }
        let g = self.shared.pending.lock().unwrap();
        let stats = CkptWriterStats {
            written: g.written,
            superseded: g.superseded,
            retried: g.retried,
        };
        match &g.last_err {
            Some(e) => bail!("async checkpoint write failed ({e})"),
            None => Ok(stats),
        }
    }
}

impl Drop for AsyncCheckpointer {
    fn drop(&mut self) {
        // error-path teardown (finish() takes the handle on the happy
        // path): stop the writer without blocking the unwinding thread
        // on pending disk I/O beyond the in-flight write
        if let Some(j) = self.join.take() {
            {
                let mut g = self.shared.pending.lock().unwrap();
                g.closing = true;
                self.shared.cv.notify_all();
            }
            j.join().ok();
        }
    }
}

/// Read `dir/manifest.json`: (latest state file name, full history).
/// Public so durability tests (and external tooling) can audit exactly
/// what the manifest claims without going through a full state load.
pub fn read_manifest(dir: &Path) -> Result<(String, Vec<String>)> {
    let text = std::fs::read_to_string(dir.join(MANIFEST))?;
    let j = Json::parse(&text)?;
    let latest = j.req("latest")?.as_str()?.to_string();
    let history = j
        .req("history")?
        .as_arr()?
        .iter()
        .map(|h| Ok(h.as_str()?.to_string()))
        .collect::<Result<Vec<_>>>()?;
    Ok((latest, history))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let ck = Checkpoint {
            variant: "tiny".into(),
            step: 17,
            params: vec![
                HostTensor::from_f32(&[2, 3], vec![1., -2., 3.5, 0., 5., -6.25]),
                HostTensor::from_f32(&[4], vec![9., 8., 7., 6.]),
            ],
        };
        let dir = std::env::temp_dir().join("prl_ckpt_test");
        let path = dir.join("c17.ckpt");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.variant, "tiny");
        assert_eq!(back.step, 17);
        assert_eq!(back.params, ck.params);
        std::fs::remove_dir_all(&dir).ok();
    }

    fn state(step: u64, scale: f32) -> TrainState {
        TrainState {
            variant: "tiny".into(),
            step,
            params: vec![HostTensor::from_f32(&[3], vec![scale, -scale, 0.5 * scale])],
            opt_m: vec![HostTensor::from_f32(&[3], vec![0.1, 0.2, 0.3])],
            opt_v: vec![HostTensor::from_f32(&[3], vec![1e-8, 2e-8, 3e-8])],
            samples_total: 128.0 * step as f64,
            tokens_total: 4096.0 * step as f64,
            rng: [u64::MAX, 0x0123_4567_89ab_cdef, 1, 0],
            engine_rng: [0xfeed_f00d, u64::MAX - 1, 7, step],
            sched_cursor: 40 + step,
        }
    }

    /// Hand-written PRLCKPT2 writer (the pre-cursor format): what an old
    /// checkpoint on disk looks like to the new loader.
    fn write_legacy_v2(st: &TrainState, path: &Path) {
        let index = Json::Obj(vec![
            ("variant".into(), Json::Str(st.variant.clone())),
            ("step".into(), Json::Num(st.step as f64)),
            ("samples_total".into(), Json::Num(st.samples_total)),
            ("tokens_total".into(), Json::Num(st.tokens_total)),
            (
                "rng".into(),
                Json::Arr(st.rng.iter().map(|w| Json::Str(format!("{w:016x}"))).collect()),
            ),
            ("params".into(), shapes_json(&st.params)),
            ("opt_m".into(), shapes_json(&st.opt_m)),
            ("opt_v".into(), shapes_json(&st.opt_v)),
        ]);
        let meta = index.to_string_compact().into_bytes();
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        let mut f = std::io::BufWriter::new(std::fs::File::create(path).unwrap());
        f.write_all(MAGIC_STATE).unwrap();
        f.write_all(&(meta.len() as u32).to_le_bytes()).unwrap();
        f.write_all(&meta).unwrap();
        write_tensor_data(&mut f, &st.params).unwrap();
        write_tensor_data(&mut f, &st.opt_m).unwrap();
        write_tensor_data(&mut f, &st.opt_v).unwrap();
        f.flush().unwrap();
    }

    #[test]
    fn legacy_prlckpt2_loads_with_zeroed_cursors() {
        let dir = std::env::temp_dir().join(format!("prl_v2_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let st = state(3, 2.0);
        let path = dir.join("legacy.state");
        write_legacy_v2(&st, &path);
        let back = TrainState::load(&path).unwrap();
        assert_eq!(back.params, st.params, "tensor payload identical");
        assert_eq!(back.rng, st.rng, "trainer cursor identical");
        assert_eq!(back.engine_rng, [0u64; 4], "v2 carries no engine cursor");
        assert_eq!(back.sched_cursor, 0, "v2 carries no admission cursor");
        // load_params_any dispatches on the v2 magic too
        let (variant, step, params) = load_params_any(&path).unwrap();
        assert_eq!((variant.as_str(), step), ("tiny", 3));
        assert_eq!(params, st.params);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn v3_roundtrips_generation_cursors() {
        let dir = std::env::temp_dir().join(format!("prl_v3_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let st = state(9, 1.5);
        let path = dir.join(TrainState::file_name(9));
        st.save(&path).unwrap();
        let back = TrainState::load(&path).unwrap();
        assert_eq!(back, st, "PRLCKPT3 carries the generation cursors bit-exactly");
        let (variant, step, params) = load_params_any(&path).unwrap();
        assert_eq!((variant.as_str(), step), ("tiny", 9));
        assert_eq!(params, st.params);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn train_state_roundtrip_bit_identical() {
        let dir = std::env::temp_dir().join("prl_state_test");
        let st = state(7, 3.25);
        let path = dir.join(TrainState::file_name(7));
        st.save(&path).unwrap();
        let back = TrainState::load(&path).unwrap();
        assert_eq!(back, st, "full state survives the roundtrip bit-exactly");
        // a TrainState is not a Checkpoint
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_tracks_latest_and_prunes() {
        let dir = std::env::temp_dir().join("prl_manifest_test");
        std::fs::remove_dir_all(&dir).ok();
        for step in [2, 4, 6, 8] {
            state(step, step as f32).save_with_manifest(&dir, 2).unwrap();
        }
        let latest = TrainState::load_latest(&dir).unwrap();
        assert_eq!(latest.step, 8);
        // keep_last = 2: steps 2 and 4 pruned from disk
        assert!(!dir.join(TrainState::file_name(2)).exists());
        assert!(!dir.join(TrainState::file_name(4)).exists());
        assert!(dir.join(TrainState::file_name(6)).exists());
        // resume_from accepts the directory form
        let resumed = TrainState::load_resume(&dir).unwrap();
        assert_eq!(resumed, latest);
        // ... and the explicit-file form
        let explicit = TrainState::load_resume(&dir.join(TrainState::file_name(6))).unwrap();
        assert_eq!(explicit.step, 6);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn async_writer_flushes_latest_on_finish() {
        let dir = std::env::temp_dir().join(format!("prl_actp_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let w = AsyncCheckpointer::new(dir.clone(), 2, 2);
        for step in [2, 4, 6] {
            w.submit(state(step, step as f32));
        }
        let stats = w.finish().unwrap();
        // latest-wins: everything submitted is either on disk or was
        // superseded by a newer state — never silently dropped
        assert_eq!(stats.written + stats.superseded, 3);
        assert!(stats.written >= 1);
        let latest = TrainState::load_latest(&dir).unwrap();
        assert_eq!(latest.step, 6, "the final state always lands");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn async_writer_latest_wins_under_a_fast_producer() {
        let dir = std::env::temp_dir().join(format!("prl_actq_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let w = AsyncCheckpointer::new(dir.clone(), 0, 2);
        // submit a burst without yielding: the queue holds at most one
        for step in 1..=20 {
            w.submit(state(step, 1.0));
        }
        let stats = w.finish().unwrap();
        assert_eq!(stats.written + stats.superseded, 20);
        let latest = TrainState::load_latest(&dir).unwrap();
        assert_eq!(latest.step, 20);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn async_writer_surfaces_write_failures() {
        // a file where the checkpoint dir should be: every write fails —
        // a *persistent* fault, so retry-with-backoff burns its budget
        // and the error still surfaces
        let bad = std::env::temp_dir().join(format!("prl_actbad_{}", std::process::id()));
        std::fs::write(&bad, b"not a directory").unwrap();
        let w = AsyncCheckpointer::new(bad.clone(), 0, 2);
        w.submit(state(1, 1.0));
        assert!(w.finish().is_err(), "broken recovery points must surface");
        std::fs::remove_file(&bad).ok();
    }

    #[test]
    fn async_writer_retries_transient_faults_and_succeeds() {
        // an injected CkptFault is one-shot (transient): with a retry
        // budget the save lands on the second attempt and finish() is
        // clean, with the retry on the books
        let dir = std::env::temp_dir().join(format!("prl_actretry_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let w = AsyncCheckpointer::new(dir.clone(), 0, 2);
        w.inject_fault_next(CkptFault::ManifestRename);
        w.submit(state(5, 2.0));
        let stats = w.finish().expect("transient fault absorbed by retry");
        assert_eq!(stats.written, 1);
        assert_eq!(stats.retried, 1, "exactly one retry was needed");
        let latest = TrainState::load_latest(&dir).unwrap();
        assert_eq!(latest.step, 5);
        std::fs::remove_dir_all(&dir).ok();

        // with a zero budget the same fault surfaces (the pre-retry
        // behavior stays reachable for the crash-window property tests)
        let dir2 = std::env::temp_dir().join(format!("prl_actretry0_{}", std::process::id()));
        std::fs::remove_dir_all(&dir2).ok();
        let w = AsyncCheckpointer::new(dir2.clone(), 0, 0);
        w.inject_fault_next(CkptFault::ManifestRename);
        w.submit(state(5, 2.0));
        assert!(w.finish().is_err(), "zero retry budget must surface the fault");
        std::fs::remove_dir_all(&dir2).ok();
    }

    #[test]
    fn rejects_wrong_magic() {
        let dir = std::env::temp_dir().join("prl_ckpt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, b"NOTACKPTxxxx").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
