//! Checkpoint (de)serialization for parameter / optimizer-state sets.
//!
//! Own compact binary format (offline env — no serde/safetensors):
//!
//! ```text
//! magic  "PRLCKPT1"                       8 bytes
//! meta   u32 json_len, json bytes         variant name, step, tensor index
//! data   for each tensor: f32 LE values   (shapes live in the json index)
//! ```
//!
//! Used by the trainer's periodic checkpointing (whose stall the broker's
//! ring buffers must absorb — see the failure-injection test) and by the
//! Fig 7 KL study, which replays consecutive checkpoints.

use crate::runtime::HostTensor;
use crate::util::Json;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"PRLCKPT1";

pub struct Checkpoint {
    pub variant: String,
    pub step: u64,
    pub params: Vec<HostTensor>,
}

impl Checkpoint {
    pub fn save(&self, path: &Path) -> Result<()> {
        let index = Json::Obj(vec![
            ("variant".into(), Json::Str(self.variant.clone())),
            ("step".into(), Json::Num(self.step as f64)),
            (
                "tensors".into(),
                Json::Arr(
                    self.params
                        .iter()
                        .map(|t| {
                            Json::Arr(
                                t.shape().iter().map(|&d| Json::Num(d as f64)).collect(),
                            )
                        })
                        .collect(),
                ),
            ),
        ]);
        let meta = index.to_string_compact().into_bytes();
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        f.write_all(&(meta.len() as u32).to_le_bytes())?;
        f.write_all(&meta)?;
        for t in &self.params {
            let data = t.f32s().context("checkpoints hold f32 tensors")?;
            // SAFETY-free explicit LE encode
            let mut buf = Vec::with_capacity(data.len() * 4);
            for x in data {
                buf.extend_from_slice(&x.to_le_bytes());
            }
            f.write_all(&buf)?;
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?,
        );
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{path:?} is not a PipelineRL checkpoint");
        }
        let mut len4 = [0u8; 4];
        f.read_exact(&mut len4)?;
        let mut meta = vec![0u8; u32::from_le_bytes(len4) as usize];
        f.read_exact(&mut meta)?;
        let j = Json::parse(std::str::from_utf8(&meta)?)?;
        let variant = j.req("variant")?.as_str()?.to_string();
        let step = j.req("step")?.as_f64()? as u64;
        let mut params = Vec::new();
        for tshape in j.req("tensors")?.as_arr()? {
            let shape: Vec<usize> = tshape
                .as_arr()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<_>>()?;
            let n: usize = shape.iter().product();
            let mut raw = vec![0u8; n * 4];
            f.read_exact(&mut raw)?;
            let data = raw
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            params.push(HostTensor::F32 { shape, data });
        }
        Ok(Checkpoint { variant, step, params })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let ck = Checkpoint {
            variant: "tiny".into(),
            step: 17,
            params: vec![
                HostTensor::from_f32(&[2, 3], vec![1., -2., 3.5, 0., 5., -6.25]),
                HostTensor::from_f32(&[4], vec![9., 8., 7., 6.]),
            ],
        };
        let dir = std::env::temp_dir().join("prl_ckpt_test");
        let path = dir.join("c17.ckpt");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.variant, "tiny");
        assert_eq!(back.step, 17);
        assert_eq!(back.params, ck.params);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_wrong_magic() {
        let dir = std::env::temp_dir().join("prl_ckpt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, b"NOTACKPTxxxx").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
