//! # PipelineRL — faster on-policy RL for long sequence generation
//!
//! Reproduction of Piché et al., *PipelineRL* (2025) as a three-layer
//! Rust + JAX + Pallas stack (see DESIGN.md):
//!
//! * **L3 (this crate)** — the paper's coordination contribution: a
//!   streaming actor → preprocessor → trainer pipeline with **in-flight
//!   weight updates**, plus every substrate it depends on (generation
//!   engine, stream broker, weight bus, synthetic task data, RL math,
//!   analytic performance model, cluster simulator).
//! * **L2/L1 (python/, build-time only)** — the transformer policy and its
//!   Pallas kernels, AOT-lowered to HLO-text artifacts that
//!   [`runtime`] loads and executes via the PJRT CPU client.
//!
//! The crate is organised so that `coordinator` is the only module that
//! knows about the pipeline topology; everything below it is reusable.

pub mod benchkit;
pub mod broker;
pub mod config;
pub mod control;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod gateway;
pub mod metrics;
pub mod model;
pub mod perfmodel;
pub mod rl;
pub mod runtime;
pub mod sched;
pub mod simcluster;
pub mod testkit;
pub mod util;
pub mod weights;

pub use anyhow::{anyhow, bail, Context, Result};
