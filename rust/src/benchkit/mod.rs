//! Bench harness (criterion is unavailable offline; every bench target
//! uses `harness = false` and this module).
//!
//! Two roles:
//! * `time(...)` — micro-benchmarks with warmup + repeated measurement,
//!   reporting mean/std/min (the §Perf hot-path numbers);
//! * `table(...)` / `series(...)` — figure regeneration output: each
//!   bench prints the same rows/series the paper's table or figure
//!   reports, so `cargo bench` regenerates the evaluation section.

use crate::util::timer::{Stats, Stopwatch};

pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ms: f64,
    pub std_ms: f64,
    pub min_ms: f64,
}

/// Time `f` with `warmup` + `iters` measured runs.
pub fn time<F: FnMut()>(name: &str, warmup: u64, iters: u64, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut stats = Stats::new();
    for _ in 0..iters {
        let sw = Stopwatch::new();
        f();
        stats.push(sw.millis());
    }
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_ms: stats.mean(),
        std_ms: stats.std(),
        min_ms: stats.min,
    };
    println!(
        "{:<44} {:>10.3} ms/iter  (±{:>7.3}, min {:>8.3}, n={})",
        r.name, r.mean_ms, r.std_ms, r.min_ms, r.iters
    );
    r
}

/// Print a figure/table header.
pub fn section(title: &str) {
    println!("\n================================================================");
    println!("{title}");
    println!("================================================================");
}

/// Print aligned rows: headers then each row of cells.
pub fn table(headers: &[&str], rows: &[Vec<String>]) {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let fmt_row = |cells: &[String]| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths.get(i).copied().unwrap_or(8) + 2))
            .collect::<String>()
    };
    println!(
        "{}",
        fmt_row(&headers.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    );
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

/// Downsample a (x, v) series to ~n printed points.
pub fn series(name: &str, xs: &[f64], vs: &[f64], n: usize) {
    println!("series: {name} ({} points)", xs.len());
    if xs.is_empty() {
        println!("  (empty)");
        return;
    }
    let stride = (xs.len() / n.max(1)).max(1);
    let mut line_x = String::from("  x: ");
    let mut line_v = String::from("  v: ");
    for i in (0..xs.len()).step_by(stride) {
        line_x.push_str(&format!("{:>9.1}", xs[i]));
        line_v.push_str(&format!("{:>9.3}", vs[i]));
    }
    println!("{line_x}");
    println!("{line_v}");
}

pub fn f(x: f64) -> String {
    format!("{x:.2}")
}

pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_reports_positive() {
        let r = time("noop-ish", 1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(r.mean_ms >= 0.0);
        assert_eq!(r.iters, 5);
    }
}
