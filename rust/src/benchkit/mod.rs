//! Bench harness (criterion is unavailable offline; every bench target
//! uses `harness = false` and this module).
//!
//! Two roles:
//! * `time(...)` — micro-benchmarks with warmup + repeated measurement,
//!   reporting mean/std/min (the §Perf hot-path numbers);
//! * `table(...)` / `series(...)` — figure regeneration output: each
//!   bench prints the same rows/series the paper's table or figure
//!   reports, so `cargo bench` regenerates the evaluation section.
//!
//! **Machine-readable results:** a bench that calls [`json_begin`] gets
//! every subsequent `time()` result and `table()` additionally recorded,
//! and [`json_end`] appends them as one *run* to `BENCH_<name>.json`
//! (next to the crate, or `$PIPELINE_RL_BENCH_DIR`). Runs accumulate
//! across invocations, so the perf trajectory across PRs is a diffable
//! artifact, not just scrollback.

use crate::util::json::Json;
use crate::util::timer::{Stats, Stopwatch};
use std::path::PathBuf;
use std::sync::Mutex;

struct JsonSink {
    name: String,
    dir: PathBuf,
    section: String,
    tables_in_section: usize,
    entries: Vec<(String, Json)>,
}

static SINK: Mutex<Option<JsonSink>> = Mutex::new(None);

fn bench_dir() -> PathBuf {
    std::env::var("PIPELINE_RL_BENCH_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")))
}

/// Start recording results for `BENCH_<name>.json` next to the crate
/// (or `$PIPELINE_RL_BENCH_DIR`). Idempotent per run: a second call
/// discards anything recorded since the first.
pub fn json_begin(name: &str) {
    json_begin_at(name, bench_dir());
}

/// Explicit-directory variant of [`json_begin`] — for benches that want
/// the artifact elsewhere, and for tests that must stay hermetic
/// (mutating `PIPELINE_RL_BENCH_DIR` from a test would race parallel
/// env reads).
pub fn json_begin_at(name: &str, dir: PathBuf) {
    *SINK.lock().unwrap() = Some(JsonSink {
        name: name.to_string(),
        dir,
        section: String::new(),
        tables_in_section: 0,
        entries: Vec::new(),
    });
}

/// Record a derived scalar (e.g. tokens/s) under `key` in the active
/// JSON run. No-op when no sink is active.
pub fn json_note(key: &str, value: f64) {
    if let Some(sink) = SINK.lock().unwrap().as_mut() {
        sink.entries.push((key.to_string(), Json::Num(value)));
    }
}

/// Flush the recorded run, appending it to `BENCH_<name>.json`. Returns
/// the path written, or None when no sink was active.
pub fn json_end() -> Option<PathBuf> {
    let sink = SINK.lock().unwrap().take()?;
    let path = sink.dir.join(format!("BENCH_{}.json", sink.name));
    // append to prior runs when the existing file parses; start fresh
    // (preserving nothing) otherwise
    let mut runs: Vec<Json> = std::fs::read_to_string(&path)
        .ok()
        .and_then(|text| Json::parse(&text).ok())
        .and_then(|doc| doc.get("runs").and_then(|r| r.as_arr().ok().map(|a| a.to_vec())))
        .unwrap_or_default();
    runs.push(Json::obj(vec![("results", Json::Obj(sink.entries))]));
    let n = runs.len();
    let doc = Json::obj(vec![
        ("bench", Json::str(sink.name.clone())),
        ("runs", Json::Arr(runs)),
    ]);
    match std::fs::write(&path, doc.to_string_compact()) {
        Ok(()) => {
            println!("json: appended run {n} to {}", path.display());
            Some(path)
        }
        Err(e) => {
            eprintln!("json: failed to write {}: {e}", path.display());
            None
        }
    }
}

fn json_record(name: &str, value: Json) {
    if let Some(sink) = SINK.lock().unwrap().as_mut() {
        sink.entries.push((name.to_string(), value));
    }
}

pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ms: f64,
    pub std_ms: f64,
    pub min_ms: f64,
}

/// Time `f` with `warmup` + `iters` measured runs.
pub fn time<F: FnMut()>(name: &str, warmup: u64, iters: u64, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut stats = Stats::new();
    for _ in 0..iters {
        let sw = Stopwatch::new();
        f();
        stats.push(sw.millis());
    }
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_ms: stats.mean(),
        std_ms: stats.std(),
        min_ms: stats.min,
    };
    println!(
        "{:<44} {:>10.3} ms/iter  (±{:>7.3}, min {:>8.3}, n={})",
        r.name, r.mean_ms, r.std_ms, r.min_ms, r.iters
    );
    json_record(
        &r.name,
        Json::obj(vec![
            ("mean_ms", Json::Num(r.mean_ms)),
            ("std_ms", Json::Num(r.std_ms)),
            ("min_ms", Json::Num(r.min_ms)),
            ("iters", Json::Num(r.iters as f64)),
        ]),
    );
    r
}

/// Print a figure/table header.
pub fn section(title: &str) {
    println!("\n================================================================");
    println!("{title}");
    println!("================================================================");
    if let Some(sink) = SINK.lock().unwrap().as_mut() {
        sink.section = title.to_string();
        sink.tables_in_section = 0;
    }
}

/// Print aligned rows: headers then each row of cells.
pub fn table(headers: &[&str], rows: &[Vec<String>]) {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let fmt_row = |cells: &[String]| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths.get(i).copied().unwrap_or(8) + 2))
            .collect::<String>()
    };
    println!(
        "{}",
        fmt_row(&headers.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    );
    for row in rows {
        println!("{}", fmt_row(row));
    }
    if let Some(sink) = SINK.lock().unwrap().as_mut() {
        sink.tables_in_section += 1;
        let key = format!("{} [table {}]", sink.section, sink.tables_in_section);
        let jrows: Vec<Json> = rows
            .iter()
            .map(|row| Json::Arr(row.iter().map(|c| Json::str(c.clone())).collect()))
            .collect();
        sink.entries.push((
            key,
            Json::obj(vec![
                (
                    "headers",
                    Json::Arr(headers.iter().map(|h| Json::str(*h)).collect()),
                ),
                ("rows", Json::Arr(jrows)),
            ]),
        ));
    }
}

/// Downsample a (x, v) series to ~n printed points.
pub fn series(name: &str, xs: &[f64], vs: &[f64], n: usize) {
    println!("series: {name} ({} points)", xs.len());
    if xs.is_empty() {
        println!("  (empty)");
        return;
    }
    let stride = (xs.len() / n.max(1)).max(1);
    let mut line_x = String::from("  x: ");
    let mut line_v = String::from("  v: ");
    for i in (0..xs.len()).step_by(stride) {
        line_x.push_str(&format!("{:>9.1}", xs[i]));
        line_v.push_str(&format!("{:>9.3}", vs[i]));
    }
    println!("{line_x}");
    println!("{line_v}");
}

pub fn f(x: f64) -> String {
    format!("{x:.2}")
}

pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_reports_positive() {
        let r = time("noop-ish", 1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(r.mean_ms >= 0.0);
        assert_eq!(r.iters, 5);
    }

    #[test]
    fn json_sink_appends_runs() {
        let dir = std::env::temp_dir().join(format!("prl_benchkit_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();

        // hermetic: explicit dir, no env mutation. Other tests in this
        // binary may call time() concurrently and add extra entries to
        // the active sink — assertions below are presence-based on our
        // own keys, so that interleaving is harmless.
        json_begin_at("sinktest", dir.clone());
        let _ = time("sink entry", 0, 2, || {});
        json_note("sink entry/tokens_per_s", 123.0);
        section("sink section");
        table(&["a", "b"], &[vec!["1".into(), "2".into()]]);
        let path = json_end().expect("sink active");
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("bench").unwrap().as_str().unwrap(), "sinktest");
        let runs1 = doc.get("runs").unwrap().as_arr().unwrap().len();
        let results = doc.get("runs").unwrap().as_arr().unwrap()[runs1 - 1]
            .get("results")
            .unwrap();
        let entry = results.get("sink entry").unwrap();
        assert!(entry.get("mean_ms").unwrap().as_f64().unwrap() >= 0.0);
        assert_eq!(entry.get("iters").unwrap().as_usize().unwrap(), 2);
        assert_eq!(
            results.get("sink entry/tokens_per_s").unwrap().as_f64().unwrap(),
            123.0
        );
        assert!(results.get("sink section [table 1]").is_some());

        // a second run appends rather than overwrites
        json_begin_at("sinktest", dir.clone());
        let _ = time("sink entry", 0, 1, || {});
        json_end().expect("sink active");
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("runs").unwrap().as_arr().unwrap().len(), runs1 + 1);

        assert!(json_end().is_none(), "sink consumed");
        std::fs::remove_dir_all(&dir).ok();
    }
}
