//! Token-lag accounting (paper §2.2 "lag", Fig 3a, Fig 6a).
//!
//! Lag of a token = (trainer's current optimizer step) − (weight version
//! the token was sampled under), in optimizer steps. The paper also
//! quotes lag in *samples* (Fig 6a's 50k-sample lags): multiply by the
//! optimizer batch size B.

use super::rollout::Rollout;

#[derive(Debug, Clone, Default)]
pub struct BatchLag {
    /// max token lag in the batch, optimizer steps
    pub max_steps: u64,
    /// mean token lag, optimizer steps
    pub mean_steps: f64,
    /// max token lag in samples (= steps * batch_size)
    pub max_samples: u64,
    /// per-sequence version span (0 = pure single-policy sequences)
    pub mean_version_span: f64,
    pub n_tokens: usize,
}

/// Compute the lag profile of a set of rollouts about to be trained on at
/// optimizer step `train_version`.
pub fn batch_lag(rollouts: &[&Rollout], train_version: u64, batch_size: usize) -> BatchLag {
    let mut max_steps = 0u64;
    let mut sum_steps = 0f64;
    let mut n = 0usize;
    let mut span_sum = 0f64;
    for r in rollouts {
        for &v in &r.token_version {
            let lag = train_version.saturating_sub(v);
            max_steps = max_steps.max(lag);
            sum_steps += lag as f64;
            n += 1;
        }
        span_sum += r.version_span() as f64;
    }
    BatchLag {
        max_steps,
        mean_steps: if n > 0 { sum_steps / n as f64 } else { 0.0 },
        max_samples: max_steps * batch_size as u64,
        mean_version_span: if rollouts.is_empty() {
            0.0
        } else {
            span_sum / rollouts.len() as f64
        },
        n_tokens: n,
    }
}

/// Running lag series over a training run (one entry per optimizer step)
/// — the data behind Fig 6a.
#[derive(Debug, Default)]
pub struct LagTracker {
    pub per_step: Vec<BatchLag>,
}

impl LagTracker {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, lag: BatchLag) {
        self.per_step.push(lag);
    }

    pub fn max_ever_steps(&self) -> u64 {
        self.per_step.iter().map(|l| l.max_steps).max().unwrap_or(0)
    }

    /// Lag profile of the most recently trained batch.
    pub fn latest(&self) -> Option<&BatchLag> {
        self.per_step.last()
    }

    /// Mean of `mean_steps` over the last `window` batches — the smoothed
    /// token-lag signal the autoscaler's lag guard consumes (a single
    /// batch's lag is spiky: one straggler sequence dominates `max_steps`
    /// and skews `mean_steps` for that batch alone).
    pub fn smoothed_mean_steps(&self, window: usize) -> f64 {
        if self.per_step.is_empty() {
            return 0.0;
        }
        let n = self.per_step.len().min(window.max(1));
        self.per_step[self.per_step.len() - n..]
            .iter()
            .map(|l| l.mean_steps)
            .sum::<f64>()
            / n as f64
    }

    /// Brute-force recount for the property tests: recompute from raw
    /// rollouts and compare with the recorded value. Checks *every*
    /// `BatchLag` field — a fabricated entry that fakes any one of
    /// `max_samples` or `mean_version_span` (the PR 3 bug class) fails
    /// here, not just the steps/token counts.
    pub fn verify_step(
        recorded: &BatchLag,
        rollouts: &[&Rollout],
        train_version: u64,
        batch_size: usize,
    ) -> bool {
        let fresh = batch_lag(rollouts, train_version, batch_size);
        fresh.max_steps == recorded.max_steps
            && fresh.n_tokens == recorded.n_tokens
            && fresh.max_samples == recorded.max_samples
            && (fresh.mean_steps - recorded.mean_steps).abs() < 1e-9
            && (fresh.mean_version_span - recorded.mean_version_span).abs() < 1e-9
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rl::rollout::FinishReason;

    fn rollout(versions: Vec<u64>) -> Rollout {
        let n = versions.len();
        Rollout {
            seq_id: 0,
            problem_id: 0,
            group_id: 0,
            actor_id: 0,
            prompt_tokens: vec![1],
            gen_tokens: vec![5; n],
            behavior_lp: vec![-1.0; n],
            token_version: versions,
            reward: 0.0,
            finish: FinishReason::Eos,
            t_start: 0.0,
            t_end: 0.0,
        }
    }

    #[test]
    fn mixed_policy_lag_profile() {
        // a sequence generated across versions 10..13, trained at 15
        let r = rollout(vec![10, 10, 11, 12, 13]);
        let lag = batch_lag(&[&r], 15, 1024);
        assert_eq!(lag.max_steps, 5);
        assert_eq!(lag.max_samples, 5 * 1024);
        assert!((lag.mean_steps - (5 + 5 + 4 + 3 + 2) as f64 / 5.0).abs() < 1e-12);
        assert_eq!(lag.mean_version_span, 3.0);
    }

    #[test]
    fn conventional_sequences_have_zero_span() {
        let r = rollout(vec![7, 7, 7, 7]);
        let lag = batch_lag(&[&r], 9, 8);
        assert_eq!(lag.mean_version_span, 0.0);
        assert_eq!(lag.max_steps, 2);
    }

    #[test]
    fn tracker_records_max() {
        let mut t = LagTracker::new();
        let r1 = rollout(vec![1, 2]);
        let r2 = rollout(vec![0, 4]);
        t.record(batch_lag(&[&r1], 4, 8));
        t.record(batch_lag(&[&r2], 5, 8));
        assert_eq!(t.max_ever_steps(), 5);
        assert!(LagTracker::verify_step(&t.per_step[1], &[&r2], 5, 8));
    }

    #[test]
    fn verify_step_pins_every_field() {
        let r = rollout(vec![10, 11, 13]);
        let honest = batch_lag(&[&r], 15, 64);
        assert!(LagTracker::verify_step(&honest, &[&r], 15, 64));
        // fabricating any single field must be caught
        let mut fake = honest.clone();
        fake.max_samples = 1;
        assert!(!LagTracker::verify_step(&fake, &[&r], 15, 64));
        let mut fake = honest.clone();
        fake.mean_version_span += 0.5;
        assert!(!LagTracker::verify_step(&fake, &[&r], 15, 64));
        let mut fake = honest.clone();
        fake.max_steps += 1;
        assert!(!LagTracker::verify_step(&fake, &[&r], 15, 64));
        let mut fake = honest;
        fake.mean_steps += 0.25;
        assert!(!LagTracker::verify_step(&fake, &[&r], 15, 64));
    }

    #[test]
    fn latest_and_smoothed_signal() {
        let mut t = LagTracker::new();
        assert!(t.latest().is_none());
        assert_eq!(t.smoothed_mean_steps(4), 0.0, "empty tracker reads 0");
        // mean lags 2.5, 1.5, 0.5 across three single-token batches
        for v in [1u64, 2, 3] {
            let r = rollout(vec![v]);
            t.record(batch_lag(&[&r], 3 + (v - 1) / 2, 8));
        }
        let lags: Vec<f64> = t.per_step.iter().map(|l| l.mean_steps).collect();
        assert_eq!(t.latest().unwrap().mean_steps, lags[2]);
        let want2 = (lags[1] + lags[2]) / 2.0;
        assert!((t.smoothed_mean_steps(2) - want2).abs() < 1e-12);
        // window larger than history falls back to the whole history
        let want_all = lags.iter().sum::<f64>() / 3.0;
        assert!((t.smoothed_mean_steps(99) - want_all).abs() < 1e-12);
        // window 0 clamps to 1 (latest batch)
        assert_eq!(t.smoothed_mean_steps(0), lags[2]);
    }
}
