//! Advantage estimation.
//!
//! Two modes, matching the train artifact's `adv_mode` input:
//!
//! * `Group` — GRPO-style leave-mean baseline computed here in the
//!   preprocessor: rollouts for the same prompt are grouped and each gets
//!   advantage r_i − mean(group) (optionally /std). This is the standard
//!   choice for verifiable math RL (the paper builds on GRPO-family
//!   training).
//! * `Value` — Eq. (4)'s learned per-token value baseline v_phi, computed
//!   *inside* the train graph from the value head; the host passes
//!   adv_mode=1 and the adv_in tensor is ignored.

use std::collections::HashMap;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdvantageMode {
    Group,
    GroupNormalized,
    Value,
}

impl AdvantageMode {
    /// value for the train graph's adv_mode scalar input.
    pub fn graph_flag(&self) -> f32 {
        match self {
            AdvantageMode::Value => 1.0,
            _ => 0.0,
        }
    }
}

/// Compute per-rollout advantages with the group baseline.
/// `groups[i]` = problem id of rollout i; `rewards[i]` its reward.
pub fn group_advantages(
    groups: &[u64],
    rewards: &[f32],
    normalize: bool,
) -> Vec<f32> {
    assert_eq!(groups.len(), rewards.len());
    let mut sums: HashMap<u64, (f64, f64, usize)> = HashMap::new();
    for (&g, &r) in groups.iter().zip(rewards) {
        let e = sums.entry(g).or_insert((0.0, 0.0, 0));
        e.0 += r as f64;
        e.1 += (r as f64) * (r as f64);
        e.2 += 1;
    }
    groups
        .iter()
        .zip(rewards)
        .map(|(&g, &r)| {
            let (s, s2, n) = sums[&g];
            let mean = s / n as f64;
            let mut adv = r as f64 - mean;
            if normalize && n > 1 {
                let var = (s2 / n as f64 - mean * mean).max(0.0);
                let std = var.sqrt();
                if std > 1e-6 {
                    adv /= std;
                }
            }
            adv as f32
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_sum_within_group() {
        let groups = vec![1, 1, 1, 2, 2];
        let rewards = vec![1.0, 0.0, 0.0, 1.0, 1.0];
        let adv = group_advantages(&groups, &rewards, false);
        let g1: f32 = adv[0..3].iter().sum();
        let g2: f32 = adv[3..5].iter().sum();
        assert!(g1.abs() < 1e-6 && g2.abs() < 1e-6);
        assert!(adv[0] > 0.0 && adv[1] < 0.0);
    }

    #[test]
    fn uniform_group_gets_zero_advantage() {
        let adv = group_advantages(&[5, 5, 5], &[1.0, 1.0, 1.0], false);
        assert!(adv.iter().all(|a| a.abs() < 1e-6));
    }

    #[test]
    fn normalization_bounds_scale() {
        let groups = vec![1, 1, 1, 1];
        let rewards = vec![10.0, 0.0, 0.0, 0.0];
        let adv = group_advantages(&groups, &rewards, true);
        for a in &adv {
            assert!(a.abs() < 2.0, "{adv:?}");
        }
    }

    #[test]
    fn singleton_group_is_safe() {
        let adv = group_advantages(&[9], &[1.0], true);
        assert_eq!(adv, vec![0.0]);
    }

    #[test]
    fn graph_flags() {
        assert_eq!(AdvantageMode::Group.graph_flag(), 0.0);
        assert_eq!(AdvantageMode::Value.graph_flag(), 1.0);
    }
}
