//! RL math and bookkeeping shared by every pipeline stage:
//! rollout records, truncated-importance-sampling / ESS statistics
//! (paper Eq. 5–6), per-token weight-version lag accounting (Fig 3a/6a)
//! and advantage estimation (group baseline or value-function input).

pub mod advantage;
pub mod ess;
pub mod lag;
pub mod rollout;

pub use advantage::{group_advantages, AdvantageMode};
pub use ess::{effective_sample_size, truncated_weights};
pub use lag::{BatchLag, LagTracker};
pub use rollout::{FinishReason, Rollout};
