//! Truncated importance weights and Effective Sample Size (Eq. 5–6).
//!
//! Three consumers share this host-side implementation:
//!
//! * the **preprocessor** (`coordinator/preprocessor.rs`) — when
//!   `[rl] is_correction = "truncated"` and a policy scorer is wired
//!   (device-free harnesses and tests), it fills the packed batch's
//!   `is_w` lane with [`truncated_weights`] of the scorer's logprobs vs.
//!   the recorded `behavior_lp`;
//! * the **trainer** (`coordinator/trainer.rs`) — computes the host-side
//!   ESS oracle over the batch's weight lane every optimizer step
//!   (`train/ess_host`), the value the autoscaler's `ess_floor` guard
//!   consumes and the reference the device `ess` metric is checked
//!   against;
//! * the **simulator / benches / tests** — `simcluster`, the onpolicy
//!   bench and the property suite replay the same math device-free.
//!
//! The trainer's AOT graph computes the same quantities on-device for
//! the batch it optimizes (exact at train time); the host path is the
//! oracle and the admission-time approximation.

/// w_i = min(c, exp(lp_pi - lp_mu)) — Eq. (5)'s truncated IS weights.
///
/// The log-ratio is taken in f64 and clamped to `ln(c)` *before*
/// exponentiation, so arbitrarily large logprob gaps saturate exactly at
/// `c` instead of overflowing to `inf` (f32 `exp` overflows past ~88
/// nats). Non-finite inputs (NaN/inf logprobs are corrupt data) produce
/// weight 0.0 — the token is excluded from the gradient rather than
/// trained under a fabricated ratio. Every returned weight is finite and
/// in `[0, c]`.
pub fn truncated_weights(lp_pi: &[f32], lp_mu: &[f32], clip_c: f32) -> Vec<f32> {
    assert_eq!(lp_pi.len(), lp_mu.len());
    let c = clip_c as f64;
    let ln_c = c.ln();
    lp_pi
        .iter()
        .zip(lp_mu)
        .map(|(&p, &m)| {
            let lr = p as f64 - m as f64;
            if !lr.is_finite() {
                return 0.0;
            }
            // clamped in log space: exp never sees an argument > ln(c)
            (lr.min(ln_c).exp().min(c)) as f32
        })
        .collect()
}

/// Normalized ESS = (Σw)² / (N Σw²) — Eq. (6). Returns 1.0 for empty
/// input (vacuously on-policy) and is always in (0, 1].
pub fn effective_sample_size(weights: &[f32]) -> f64 {
    if weights.is_empty() {
        return 1.0;
    }
    let n = weights.len() as f64;
    let sw: f64 = weights.iter().map(|&w| w as f64).sum();
    let sw2: f64 = weights.iter().map(|&w| (w as f64).powi(2)).sum();
    if sw2 == 0.0 {
        return 1.0;
    }
    (sw * sw) / (n * sw2)
}

/// k3 estimator of KL(pi ‖ mu) from per-token logprob pairs:
/// mean(ratio - 1 - log ratio), non-negative, low variance.
pub fn kl_k3(lp_pi: &[f32], lp_mu: &[f32]) -> f64 {
    assert_eq!(lp_pi.len(), lp_mu.len());
    if lp_pi.is_empty() {
        return 0.0;
    }
    let mut acc = 0.0f64;
    for (p, m) in lp_pi.iter().zip(lp_mu) {
        let lr = (p - m) as f64;
        acc += lr.exp() - 1.0 - lr;
    }
    acc / lp_pi.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn on_policy_ess_is_one() {
        let lp = vec![-0.3, -1.2, -2.0];
        let w = truncated_weights(&lp, &lp, 5.0);
        assert!(w.iter().all(|&x| (x - 1.0).abs() < 1e-6));
        assert!((effective_sample_size(&w) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn truncation_clips_at_c() {
        let w = truncated_weights(&[0.0], &[-10.0], 5.0);
        assert_eq!(w, vec![5.0]);
    }

    #[test]
    fn ess_degrades_with_weight_spread() {
        let uniform = vec![1.0; 8];
        let skewed = vec![5.0, 0.01, 0.01, 0.01, 0.01, 0.01, 0.01, 0.01];
        assert!(
            effective_sample_size(&skewed) < effective_sample_size(&uniform)
        );
        assert!(effective_sample_size(&skewed) < 0.2);
    }

    #[test]
    fn ess_bounds() {
        let mut rng = crate::util::Rng::new(1);
        for _ in 0..50 {
            let w: Vec<f32> = (0..64).map(|_| rng.f32() * 5.0 + 1e-3).collect();
            let e = effective_sample_size(&w);
            assert!(e > 0.0 && e <= 1.0 + 1e-9, "{e}");
        }
    }

    #[test]
    fn kl_zero_on_policy_positive_off() {
        let lp = vec![-0.5, -0.7];
        assert_eq!(kl_k3(&lp, &lp), 0.0);
        assert!(kl_k3(&[-0.5, -0.7], &[-1.5, -0.2]) > 0.0);
    }

    #[test]
    fn huge_gaps_saturate_at_c_instead_of_overflowing() {
        // 200 nats overflows f32 exp (~88 nats); the clamp-before-exp
        // path must land exactly on c
        let w = truncated_weights(&[0.0], &[-200.0], 5.0);
        assert_eq!(w, vec![5.0]);
        let w = truncated_weights(&[f32::MAX / 2.0], &[f32::MIN / 2.0], 3.0);
        assert_eq!(w, vec![3.0]);
        // huge gaps the other way underflow to 0, not NaN
        let w = truncated_weights(&[-200.0], &[0.0], 5.0);
        assert_eq!(w, vec![0.0]);
    }

    #[test]
    fn non_finite_inputs_yield_zero_weight_not_c() {
        // a NaN logprob used to clip silently to c (NaN.min(c) == c);
        // corrupt tokens must instead drop out of the gradient
        for (p, m) in [
            (f32::NAN, -1.0),
            (-1.0, f32::NAN),
            (f32::INFINITY, -1.0),
            (-1.0, f32::NEG_INFINITY),
            (f32::INFINITY, f32::INFINITY),
        ] {
            let w = truncated_weights(&[p], &[m], 5.0);
            assert_eq!(w, vec![0.0], "lp_pi={p} lp_mu={m}");
        }
    }

    #[test]
    fn property_no_non_finite_weight_escapes() {
        crate::testkit::check("truncated weights finite", 200, 0x15e5, 64, |c| {
            let n = c.usize_in(1, 32);
            // arbitrary finite logprobs across the full f32 magnitude
            // range, including pairs whose gap overflows f32 exp
            let wild = |c: &mut crate::testkit::Case| -> Vec<f32> {
                (0..n)
                    .map(|_| {
                        let mag = 10f32.powi(c.rng.below(39) as i32 - 19);
                        let s = if c.rng.below(2) == 0 { -1.0 } else { 1.0 };
                        s * mag * c.rng.f32()
                    })
                    .collect()
            };
            let lp_pi = wild(c);
            let lp_mu = wild(c);
            let clip_c = 0.5 + c.rng.f32() * 20.0;
            let w = truncated_weights(&lp_pi, &lp_mu, clip_c);
            for (i, &x) in w.iter().enumerate() {
                if !x.is_finite() || x < 0.0 || x > clip_c + 1e-4 {
                    return Err(format!(
                        "weight {x} escaped [0, {clip_c}] at {i}: \
                         lp_pi={} lp_mu={}",
                        lp_pi[i], lp_mu[i]
                    ));
                }
            }
            let e = effective_sample_size(&w);
            if !e.is_finite() || e < 0.0 || e > 1.0 + 1e-9 {
                return Err(format!("ESS {e} out of (0,1]"));
            }
            Ok(())
        });
    }
}
