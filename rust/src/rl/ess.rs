//! Truncated importance weights and Effective Sample Size (Eq. 5–6).
//!
//! The trainer's AOT graph computes these on-device for the batch it
//! optimizes; this host-side implementation is used by the preprocessor
//! (for admission metrics), the simulator and the test suite, and is the
//! oracle the device metrics are checked against.

/// w_i = min(c, exp(lp_pi - lp_mu)) — Eq. (5)'s truncated IS weights.
pub fn truncated_weights(lp_pi: &[f32], lp_mu: &[f32], clip_c: f32) -> Vec<f32> {
    assert_eq!(lp_pi.len(), lp_mu.len());
    lp_pi
        .iter()
        .zip(lp_mu)
        .map(|(p, m)| (p - m).exp().min(clip_c))
        .collect()
}

/// Normalized ESS = (Σw)² / (N Σw²) — Eq. (6). Returns 1.0 for empty
/// input (vacuously on-policy) and is always in (0, 1].
pub fn effective_sample_size(weights: &[f32]) -> f64 {
    if weights.is_empty() {
        return 1.0;
    }
    let n = weights.len() as f64;
    let sw: f64 = weights.iter().map(|&w| w as f64).sum();
    let sw2: f64 = weights.iter().map(|&w| (w as f64).powi(2)).sum();
    if sw2 == 0.0 {
        return 1.0;
    }
    (sw * sw) / (n * sw2)
}

/// k3 estimator of KL(pi ‖ mu) from per-token logprob pairs:
/// mean(ratio - 1 - log ratio), non-negative, low variance.
pub fn kl_k3(lp_pi: &[f32], lp_mu: &[f32]) -> f64 {
    assert_eq!(lp_pi.len(), lp_mu.len());
    if lp_pi.is_empty() {
        return 0.0;
    }
    let mut acc = 0.0f64;
    for (p, m) in lp_pi.iter().zip(lp_mu) {
        let lr = (p - m) as f64;
        acc += lr.exp() - 1.0 - lr;
    }
    acc / lp_pi.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn on_policy_ess_is_one() {
        let lp = vec![-0.3, -1.2, -2.0];
        let w = truncated_weights(&lp, &lp, 5.0);
        assert!(w.iter().all(|&x| (x - 1.0).abs() < 1e-6));
        assert!((effective_sample_size(&w) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn truncation_clips_at_c() {
        let w = truncated_weights(&[0.0], &[-10.0], 5.0);
        assert_eq!(w, vec![5.0]);
    }

    #[test]
    fn ess_degrades_with_weight_spread() {
        let uniform = vec![1.0; 8];
        let skewed = vec![5.0, 0.01, 0.01, 0.01, 0.01, 0.01, 0.01, 0.01];
        assert!(
            effective_sample_size(&skewed) < effective_sample_size(&uniform)
        );
        assert!(effective_sample_size(&skewed) < 0.2);
    }

    #[test]
    fn ess_bounds() {
        let mut rng = crate::util::Rng::new(1);
        for _ in 0..50 {
            let w: Vec<f32> = (0..64).map(|_| rng.f32() * 5.0 + 1e-3).collect();
            let e = effective_sample_size(&w);
            assert!(e > 0.0 && e <= 1.0 + 1e-9, "{e}");
        }
    }

    #[test]
    fn kl_zero_on_policy_positive_off() {
        let lp = vec![-0.5, -0.7];
        assert_eq!(kl_k3(&lp, &lp), 0.0);
        assert!(kl_k3(&[-0.5, -0.7], &[-1.5, -0.2]) > 0.0);
    }
}
