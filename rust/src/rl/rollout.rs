//! The rollout record that flows actor → preprocessor → trainer.
//!
//! Every generated token carries the *weight version* it was sampled
//! under — the raw material for the paper's lag analysis (Fig 3a, Fig 6a)
//! — and its behavior-policy logprob, the denominator of the truncated
//! importance weights in Eq. (5).

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// generated EOS
    Eos,
    /// ran out of generation budget (max_seq)
    Length,
    /// actor shut down mid-sequence
    Aborted,
    /// cut off mid-generation but *trainable*: the generated prefix
    /// carries full behavior logprobs + version tags (the PR 3
    /// portability layer's `SeqSnapshot` raw material), so under
    /// `[rl] train_truncated = true` the preprocessor admits it as a
    /// partial rollout instead of discarding it (Truncated-PPO style)
    Truncated,
}

#[derive(Debug, Clone)]
pub struct Rollout {
    /// engine-assigned sequence id (unique per engine)
    pub seq_id: u64,
    /// stable problem id (identifies the task instance)
    pub problem_id: u64,
    /// rollout-group id: the `group_size` rollouts sampled for the same
    /// prompt submission share it (group-baseline advantage)
    pub group_id: u64,
    pub actor_id: usize,
    pub prompt_tokens: Vec<i32>,
    /// generated tokens (no BOS, may end with EOS)
    pub gen_tokens: Vec<i32>,
    /// behavior-policy logprob per generated token
    pub behavior_lp: Vec<f32>,
    /// weight version each generated token was sampled under (in-flight
    /// updates make this non-constant within one sequence)
    pub token_version: Vec<u64>,
    pub reward: f32,
    pub finish: FinishReason,
    /// wall-clock seconds when generation of this sequence started/ended
    pub t_start: f64,
    pub t_end: f64,
}

impl Rollout {
    pub fn gen_len(&self) -> usize {
        self.gen_tokens.len()
    }

    pub fn total_len(&self) -> usize {
        self.prompt_tokens.len() + self.gen_tokens.len()
    }

    /// Weight-version span within this sequence (0 for conventional RL
    /// where whole sequences come from a single behavior policy).
    pub fn version_span(&self) -> u64 {
        match (self.token_version.iter().min(), self.token_version.iter().max()) {
            (Some(lo), Some(hi)) => hi - lo,
            _ => 0,
        }
    }

    /// Consistency check: parallel arrays must stay parallel.
    pub fn validate(&self) -> anyhow::Result<()> {
        if self.gen_tokens.len() != self.behavior_lp.len()
            || self.gen_tokens.len() != self.token_version.len()
        {
            anyhow::bail!(
                "rollout arrays disagree: {} tokens, {} lps, {} versions",
                self.gen_tokens.len(),
                self.behavior_lp.len(),
                self.token_version.len()
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(versions: Vec<u64>) -> Rollout {
        let n = versions.len();
        Rollout {
            seq_id: 1,
            problem_id: 1,
            group_id: 1,
            actor_id: 0,
            prompt_tokens: vec![1, 5, 6],
            gen_tokens: vec![7; n],
            behavior_lp: vec![-0.5; n],
            token_version: versions,
            reward: 1.0,
            finish: FinishReason::Eos,
            t_start: 0.0,
            t_end: 1.0,
        }
    }

    #[test]
    fn version_span() {
        assert_eq!(mk(vec![3, 3, 3]).version_span(), 0);
        assert_eq!(mk(vec![3, 4, 7]).version_span(), 4);
        assert_eq!(mk(vec![]).version_span(), 0);
    }

    #[test]
    fn validate_catches_skew() {
        let mut r = mk(vec![1, 2, 3]);
        r.behavior_lp.pop();
        assert!(r.validate().is_err());
    }
}
