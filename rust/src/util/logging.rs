//! Leveled stderr logging with per-component prefixes.
//!
//! Deliberately tiny: PipelineRL components log through a `Logger` handle
//! so tests can silence them and the orchestrator can stamp stage names
//! (actor-0, preproc, trainer) the way the paper's reference
//! implementation tags its pipeline stages.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Trace = 0,
    Debug = 1,
    Info = 2,
    Warn = 3,
    Error = 4,
    Off = 5,
}

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn level() -> u8 {
    LEVEL.load(Ordering::Relaxed)
}

#[derive(Debug, Clone)]
pub struct Logger {
    pub component: String,
    start: Instant,
}

impl Logger {
    pub fn new(component: impl Into<String>) -> Self {
        Logger { component: component.into(), start: Instant::now() }
    }

    pub fn log(&self, lvl: Level, msg: &str) {
        if (lvl as u8) >= level() && level() != Level::Off as u8 {
            eprintln!(
                "[{:9.3}s] [{:>9}] {}",
                self.start.elapsed().as_secs_f64(),
                self.component,
                msg
            );
        }
    }

    pub fn info(&self, msg: &str) {
        self.log(Level::Info, msg);
    }

    pub fn debug(&self, msg: &str) {
        self.log(Level::Debug, msg);
    }

    pub fn warn(&self, msg: &str) {
        self.log(Level::Warn, msg);
    }
}
