//! Shared utilities built from scratch for the offline environment:
//! a JSON parser/writer (manifest + metrics interchange), a PCG64 RNG
//! (sampling noise, data generation), and small timing helpers.

pub mod cli;
pub mod json;
pub mod logging;
pub mod rng;
pub mod timer;

pub use json::Json;
pub use rng::Rng;
