//! Wall-clock helpers and a tiny moving-statistics accumulator used by
//! the metrics layer and the benchkit harness.

use std::time::Instant;

/// Seconds since the first call in this process — the shared wall-clock
/// origin for all metric series (every stage stamps points with it).
pub fn global_seconds() -> f64 {
    static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();
    START.get_or_init(Instant::now).elapsed().as_secs_f64()
}

/// Monotonic stopwatch.
#[derive(Debug, Clone)]
pub struct Stopwatch {
    start: Instant,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Stopwatch { start: Instant::now() }
    }

    pub fn seconds(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn millis(&self) -> f64 {
        self.seconds() * 1e3
    }

    pub fn reset(&mut self) {
        self.start = Instant::now();
    }
}

/// Streaming mean/variance/min/max (Welford).
#[derive(Debug, Clone, Default)]
pub struct Stats {
    pub n: u64,
    mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
}

impl Stats {
    pub fn new() -> Self {
        Stats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_mean_var() {
        let mut s = Stats::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.push(x);
        }
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.var() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }
}
