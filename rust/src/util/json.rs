//! Minimal JSON parser + writer.
//!
//! Built from scratch because the environment is offline (no serde).
//! Covers the full JSON grammar we produce/consume: objects, arrays,
//! strings with escapes, numbers (f64), booleans, null. Key order of
//! objects is preserved (Vec of pairs) so emitted files diff cleanly.

use anyhow::{anyhow, bail, Result};
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { s: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.s.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("expected array"),
        }
    }

    pub fn as_obj(&self) -> Result<&[(String, Json)]> {
        match self {
            Json::Obj(o) => Ok(o),
            _ => bail!("expected object"),
        }
    }

    /// Compact single-line rendering.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if *n == n.trunc() && n.abs() < 1e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{n}");
                    }
                } else {
                    out.push_str("null"); // JSON has no inf/nan
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x)).collect())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    s: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.s.len() && self.s[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.s
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!("expected '{}' at byte {}", b as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.s[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            out.push((k, v));
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                c => bail!("expected ',' or '}}', got '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                c => bail!("expected ',' or ']', got '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let b = self.peek()?;
            self.i += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.s.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.s[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            out.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| anyhow!("bad codepoint"))?,
                            );
                        }
                        c => bail!("bad escape '\\{}'", c as char),
                    }
                }
                b => {
                    // collect a full UTF-8 sequence
                    let len = utf8_len(b);
                    if len == 1 {
                        out.push(b as char);
                    } else {
                        let start = self.i - 1;
                        self.i += len - 1;
                        out.push_str(std::str::from_utf8(&self.s[start..self.i])?);
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.s.len()
            && matches!(self.s[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.s[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, {"b": "c"}], "d": {}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
        assert!(v.get("d").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"x":[1,2.5,"séq"],"y":{"z":false,"w":null}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo ✓\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ✓");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn preserves_key_order() {
        let v = Json::parse(r#"{"z":1,"a":2}"#).unwrap();
        let keys: Vec<_> =
            v.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, ["z", "a"]);
    }
}
