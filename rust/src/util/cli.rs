//! Tiny CLI flag parser (offline env — no clap).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and
//! collects positionals. Used by the binary and the examples.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn parse_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Args {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some(eq) = name.find('=') {
                    out.flags
                        .insert(name[..eq].to_string(), name[eq + 1..].to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(name.to_string(), v);
                } else {
                    out.flags.insert(name.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.flags.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{name} must be an integer, got {v:?}")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{name} must be a number, got {v:?}")),
        }
    }

    pub fn bool(&self, name: &str) -> bool {
        matches!(self.flags.get(name).map(|s| s.as_str()), Some("true") | Some("1") | Some("yes"))
    }

    pub fn require(&self, name: &str) -> Result<&str> {
        match self.flags.get(name) {
            Some(v) => Ok(v),
            None => bail!("missing required flag --{name}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_forms() {
        // note: a bare `--flag` followed by a non-flag token would consume
        // it as the flag's value — boolean flags go last or before another
        // `--` flag (documented ambiguity of space-separated values)
        let a = parse("--x 3 --y=hello pos1 pos2 --flag");
        assert_eq!(a.usize_or("x", 0).unwrap(), 3);
        assert_eq!(a.str_or("y", ""), "hello");
        assert!(a.bool("flag"));
        assert_eq!(a.positional, vec!["pos1", "pos2"]);
    }

    #[test]
    fn defaults_and_errors() {
        let a = parse("--n notanumber");
        assert!(a.usize_or("n", 1).is_err());
        assert_eq!(a.usize_or("m", 7).unwrap(), 7);
        assert!(a.require("missing").is_err());
    }

    #[test]
    fn boolean_before_flag() {
        let a = parse("--v --n 3");
        assert!(a.bool("v"));
        assert_eq!(a.usize_or("n", 0).unwrap(), 3);
    }
}
