//! PCG64 pseudo-random number generator (from scratch: offline env).
//!
//! Deterministic per seed, splittable per component (each actor / task
//! generator / sampler owns its own stream) so PipelineRL runs are
//! reproducible regardless of thread interleaving. The generator is
//! O'Neill's PCG-XSL-RR 128/64.

#[derive(Debug, Clone)]
pub struct Rng {
    state: u128,
    inc: u128,
}

const MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Rng { state: 0, inc };
        rng.state = rng.state.wrapping_mul(MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(MULT).wrapping_add(rng.inc);
        rng
    }

    /// Derive an independent stream (different sequence constant).
    pub fn split(&mut self, tag: u64) -> Rng {
        let seed = self.next_u64();
        Rng::with_stream(seed, tag.wrapping_mul(0x9e37_79b9_7f4a_7c15) | 1)
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        // Lemire's multiply-shift with rejection for unbiasedness.
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (n as u128);
            let lo = m as u64;
            if lo >= n.wrapping_neg() % n {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(hi >= lo);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    /// Standard Gumbel(0, 1) sample — used for in-graph Gumbel-max
    /// sampling in the decode artifact.
    pub fn gumbel(&mut self) -> f32 {
        let u = self.f32().max(1e-12);
        -(-(u.ln())).ln()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Fill a buffer with Gumbel noise (decode hot path helper).
    pub fn fill_gumbel(&mut self, out: &mut [f32]) {
        for o in out.iter_mut() {
            *o = self.gumbel();
        }
    }

    /// Serialize the generator cursor (checkpoint/resume support): the
    /// full PCG state as four little words. Restoring with
    /// [`Rng::from_state_words`] continues the exact same stream.
    pub fn state_words(&self) -> [u64; 4] {
        [
            (self.state >> 64) as u64,
            self.state as u64,
            (self.inc >> 64) as u64,
            self.inc as u64,
        ]
    }

    /// Rebuild a generator from [`Rng::state_words`].
    pub fn from_state_words(w: [u64; 4]) -> Rng {
        Rng {
            state: ((w[0] as u128) << 64) | w[1] as u128,
            inc: ((w[2] as u128) << 64) | w[3] as u128,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_unit_interval() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(4);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5)] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn gumbel_mean_is_euler_mascheroni() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let mean: f64 =
            (0..n).map(|_| r.gumbel() as f64).sum::<f64>() / n as f64;
        assert!((mean - 0.5772).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(6);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn split_streams_are_independent() {
        let mut root = Rng::new(9);
        let mut a = root.split(1);
        let mut b = root.split(2);
        let xs: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn state_words_resume_exact_stream() {
        let mut a = Rng::new(42);
        for _ in 0..37 {
            a.next_u64();
        }
        let saved = a.state_words();
        let ahead: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let mut b = Rng::from_state_words(saved);
        let resumed: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        assert_eq!(ahead, resumed, "restored cursor continues the stream");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng::new(10);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
