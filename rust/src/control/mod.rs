//! Guardrail-driven run control plane: pause / drain / rollback with
//! provably clean recovery.
//!
//! The supervisor (PRs 3–6) can already survive faults it did not choose
//! — actor crashes, trainer kills, corrupt snapshots. This module adds
//! the *operator* side of run management: deliberate, commanded state
//! transitions with the same conservation guarantees the fault paths
//! carry. It maps onto the rsBot milestone-24 "True RL Wave" operational
//! controls contract (pause/resume/rollback/recovery; see SNIPPETS.md §1,
//! issues #1661 "Safety-Constrained RL and Policy Guardrails" and #1663
//! "RL Operations, Rollout Control, and Failure Recovery"):
//!
//! * [`RunController`] — the command channel. `Pause` parks every
//!   in-flight sequence through the `SeqSnapshot`/`MigrationHub` path
//!   (deposited == claimed + discarded books stay closed); `Resume`
//!   reclaims them; `Drain` admits nothing new, lets active sequences
//!   finish, and flushes truncated prefixes under `[rl] train_truncated`;
//!   `Rollback` restores the trainer from a checkpoint manifest through
//!   the `TrainerSlot` failover machinery; `Stop` ends the run cleanly.
//! * [`ControlGate`] — the shared admission gate actors consult every
//!   loop iteration, plus the per-actor load ledger the supervisor uses
//!   to detect drain quiescence.
//! * [`Guardrail`] — the watchdog over the [`MetricsHub`]: non-finite
//!   loss, reward regression over a sliding window, `ess_floor` trip
//!   budget, and token-lag runaway each auto-trigger pause-then-rollback
//!   to the latest healthy checkpoint, within a bounded
//!   retry-with-backoff budget; an exhausted budget fails safe into
//!   `Drained` rather than looping.
//! * [`RunState`] — the `run/state` gauge vocabulary. Every
//!   `run_supervisor` exit path records a terminal value
//!   (completed / failed / drained / rolled_back), so post-mortems can
//!   read how a run ended from the metrics snapshot alone.
//!
//! Guardrail trips additionally write human-readable reports under
//! `target/control/` — CI uploads them as failure artifacts.

use crate::config::ControlConfig;
use crate::metrics::MetricsHub;
use std::collections::{BTreeMap, VecDeque};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Arc, Mutex};

/// Operator commands accepted by the supervisor's control plane.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RunCommand {
    /// Quiesce: actors park their in-flight sequences into the migration
    /// hub (books stay closed) and admit nothing until `Resume`.
    Pause,
    /// Leave `Paused`: actors reclaim parked sequences and admit again.
    Resume,
    /// Stop admitting, let active sequences finish, flush truncated
    /// prefixes under `[rl] train_truncated`, then end the run as
    /// `Drained`.
    Drain,
    /// Pause, then restore the trainer from a checkpoint manifest via
    /// the failover slot. `None` targets the latest manifest state; a
    /// specific step is honored when it is the manifest's latest and
    /// logged (with rollback to latest) otherwise.
    Rollback { checkpoint: Option<u64> },
    /// End the run cleanly (terminal state `Completed`).
    Stop,
}

impl std::fmt::Display for RunCommand {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RunCommand::Pause => write!(f, "pause"),
            RunCommand::Resume => write!(f, "resume"),
            RunCommand::Drain => write!(f, "drain"),
            RunCommand::Rollback { checkpoint: None } => write!(f, "rollback(latest)"),
            RunCommand::Rollback { checkpoint: Some(s) } => write!(f, "rollback(step {s})"),
            RunCommand::Stop => write!(f, "stop"),
        }
    }
}

/// Cloneable command channel into a running supervisor. Commands are
/// applied in submission order at the next supervisor poll.
#[derive(Clone, Default)]
pub struct RunController {
    queue: Arc<Mutex<VecDeque<RunCommand>>>,
}

impl RunController {
    pub fn new() -> RunController {
        RunController::default()
    }

    /// Enqueue a command. Never blocks; the supervisor drains the queue
    /// once per poll.
    pub fn send(&self, cmd: RunCommand) {
        self.queue.lock().unwrap().push_back(cmd);
    }

    /// Take every pending command, in submission order.
    pub fn drain(&self) -> Vec<RunCommand> {
        self.queue.lock().unwrap().drain(..).collect()
    }

    pub fn pending(&self) -> usize {
        self.queue.lock().unwrap().len()
    }
}

/// Admission phase actors observe through the [`ControlGate`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionPhase {
    /// Normal operation: admit, decode, publish.
    Running,
    /// Park: export in-flight sequences to the migration hub, admit and
    /// decode nothing, idle until the phase changes.
    Paused,
    /// Admit nothing new; keep decoding what is already in flight.
    Draining,
}

impl AdmissionPhase {
    fn from_u8(x: u8) -> AdmissionPhase {
        match x {
            1 => AdmissionPhase::Paused,
            2 => AdmissionPhase::Draining,
            _ => AdmissionPhase::Running,
        }
    }

    fn as_u8(self) -> u8 {
        match self {
            AdmissionPhase::Running => 0,
            AdmissionPhase::Paused => 1,
            AdmissionPhase::Draining => 2,
        }
    }
}

struct GateInner {
    phase: AtomicU8,
    /// per-actor in-flight load (active + pending engine sequences),
    /// reported every actor loop iteration; the supervisor's drain
    /// quiescence signal
    loads: Mutex<BTreeMap<usize, usize>>,
}

/// Load-ledger slot the serving gateway reports under ([`ControlGate::
/// report_load`]). Actor incarnations use their pool index; the gateway
/// is a singleton front door, so it gets one fixed id far outside any
/// plausible pool size instead of competing for an index.
pub const GATEWAY_LEDGER_ID: usize = usize::MAX;

/// Shared gate between the supervisor (writer) and the actors (readers).
#[derive(Clone)]
pub struct ControlGate {
    inner: Arc<GateInner>,
}

impl Default for ControlGate {
    fn default() -> ControlGate {
        ControlGate::new()
    }
}

impl ControlGate {
    pub fn new() -> ControlGate {
        ControlGate {
            inner: Arc::new(GateInner {
                phase: AtomicU8::new(AdmissionPhase::Running.as_u8()),
                loads: Mutex::new(BTreeMap::new()),
            }),
        }
    }

    pub fn phase(&self) -> AdmissionPhase {
        AdmissionPhase::from_u8(self.inner.phase.load(Ordering::Relaxed))
    }

    pub fn set_phase(&self, p: AdmissionPhase) {
        self.inner.phase.store(p.as_u8(), Ordering::Relaxed);
    }

    /// True in the only phase that admits new prompt groups.
    pub fn admitting(&self) -> bool {
        self.phase() == AdmissionPhase::Running
    }

    /// Actors report their engine load here once per loop iteration.
    pub fn report_load(&self, actor_id: usize, load: usize) {
        self.inner.loads.lock().unwrap().insert(actor_id, load);
    }

    /// Drop an actor's ledger entry on exit, so a dead incarnation's
    /// stale load can never hold a drain open.
    pub fn clear_load(&self, actor_id: usize) {
        self.inner.loads.lock().unwrap().remove(&actor_id);
    }

    /// Total reported in-flight load across live actors.
    pub fn total_load(&self) -> usize {
        self.inner.loads.lock().unwrap().values().sum()
    }
}

/// `run/state` gauge vocabulary. Live transitions (running / paused /
/// draining / rolled_back) are recorded as they happen; every supervisor
/// exit records one of the four terminal values.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RunState {
    Running,
    Paused,
    Draining,
    Completed,
    Failed,
    Drained,
    RolledBack,
}

/// Metric name of the run-state gauge.
pub const RUN_STATE_GAUGE: &str = "run/state";

impl RunState {
    /// Stable numeric encoding for the gauge (assertable in tests).
    pub fn gauge(self) -> f64 {
        match self {
            RunState::Running => 0.0,
            RunState::Paused => 1.0,
            RunState::Draining => 2.0,
            RunState::Completed => 3.0,
            RunState::Failed => 4.0,
            RunState::Drained => 5.0,
            RunState::RolledBack => 6.0,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            RunState::Running => "running",
            RunState::Paused => "paused",
            RunState::Draining => "draining",
            RunState::Completed => "completed",
            RunState::Failed => "failed",
            RunState::Drained => "drained",
            RunState::RolledBack => "rolled_back",
        }
    }
}

/// Record a run-state transition on the hub.
pub fn record_state(hub: &MetricsHub, s: RunState) {
    hub.set(RUN_STATE_GAUGE, s.gauge());
}

/// Why a guardrail fired.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TripReason {
    /// `train/loss` produced a NaN/inf.
    NonFiniteLoss,
    /// Mean reward over the newest window dropped more than
    /// `control.reward_drop` below the preceding window's mean.
    RewardRegression,
    /// The `ess_floor_trips` counter advanced past
    /// `control.ess_trip_limit` since the last healthy point.
    EssFloor,
    /// `train/mean_lag_smoothed` ran past `control.max_lag_steps`.
    LagRunaway,
    /// Injected (`ChaosKind::GuardrailTrip` or an operator `Rollback`).
    Injected,
}

impl TripReason {
    pub fn name(self) -> &'static str {
        match self {
            TripReason::NonFiniteLoss => "non_finite_loss",
            TripReason::RewardRegression => "reward_regression",
            TripReason::EssFloor => "ess_floor",
            TripReason::LagRunaway => "lag_runaway",
            TripReason::Injected => "injected",
        }
    }
}

/// One guardrail firing: the reason plus a human-readable detail line
/// (written into the `target/control/` report).
#[derive(Debug, Clone)]
pub struct Trip {
    pub reason: TripReason,
    pub detail: String,
}

/// Watchdog over the [`MetricsHub`]. All checks are armed only for
/// metric points that arrived *after* the last [`Guardrail::acknowledge`]
/// — otherwise the very data that justified a rollback would re-trip the
/// guardrail forever on the next poll.
pub struct Guardrail {
    cfg: ControlConfig,
    /// `x` (sample coordinate) of the newest `train/loss` point at the
    /// last acknowledge; points at or before it are spent evidence
    armed_after_x: f64,
    /// `ess_floor_trips` counter value at the last acknowledge
    ess_trips_base: f64,
}

impl Guardrail {
    pub fn new(cfg: ControlConfig) -> Guardrail {
        Guardrail {
            cfg,
            armed_after_x: f64::NEG_INFINITY,
            ess_trips_base: 0.0,
        }
    }

    /// Run every enabled check against the hub's current metrics.
    /// Returns the first trip found (severity order: non-finite loss,
    /// ESS budget, lag runaway, reward regression).
    pub fn check(&mut self, hub: &MetricsHub) -> Option<Trip> {
        // 1. non-finite loss: always on while the control plane runs —
        //    a NaN loss poisons the optimizer state within one step
        if let Some(p) = hub.series_last("train/loss") {
            if p.x > self.armed_after_x && !p.value.is_finite() {
                return Some(Trip {
                    reason: TripReason::NonFiniteLoss,
                    detail: format!("train/loss = {} at x = {}", p.value, p.x),
                });
            }
        }
        // 2. ESS-floor trip budget
        if self.cfg.ess_trip_limit > 0.0 {
            let trips = hub.counter("ess_floor_trips") - self.ess_trips_base;
            if trips > self.cfg.ess_trip_limit {
                return Some(Trip {
                    reason: TripReason::EssFloor,
                    detail: format!(
                        "{trips} ess_floor trips since last healthy point \
                         (limit {})",
                        self.cfg.ess_trip_limit
                    ),
                });
            }
        }
        // 3. token-lag runaway
        if self.cfg.max_lag_steps > 0.0 {
            if let Some(p) = hub.series_last("train/mean_lag_smoothed") {
                if p.x > self.armed_after_x && p.value > self.cfg.max_lag_steps {
                    return Some(Trip {
                        reason: TripReason::LagRunaway,
                        detail: format!(
                            "train/mean_lag_smoothed = {:.3} > {} at x = {}",
                            p.value, self.cfg.max_lag_steps, p.x
                        ),
                    });
                }
            }
        }
        // 4. reward regression over a sliding window: the newest
        //    `window` points vs the `window` before them
        if self.cfg.reward_drop > 0.0 {
            let n = self.cfg.window;
            let pts: Vec<_> = hub
                .series_window("reward_vs_samples", 2 * n)
                .into_iter()
                .filter(|p| p.x > self.armed_after_x)
                .collect();
            if pts.len() == 2 * n {
                let older: f64 = pts[..n].iter().map(|p| p.value).sum::<f64>() / n as f64;
                let newer: f64 = pts[n..].iter().map(|p| p.value).sum::<f64>() / n as f64;
                // only a drop from a positive baseline is a regression —
                // early training hovering near zero reward is not
                if older > 0.0 && newer < older * (1.0 - self.cfg.reward_drop) {
                    return Some(Trip {
                        reason: TripReason::RewardRegression,
                        detail: format!(
                            "mean reward {newer:.4} < {:.4} ({}% drop over \
                             {n}-step windows, limit {}%)",
                            older * (1.0 - self.cfg.reward_drop),
                            ((1.0 - newer / older) * 100.0).round(),
                            self.cfg.reward_drop * 100.0
                        ),
                    });
                }
            }
        }
        None
    }

    /// Re-arm after a completed rollback (or a deliberate operator
    /// override): evidence recorded up to now no longer counts.
    pub fn acknowledge(&mut self, hub: &MetricsHub) {
        self.armed_after_x = hub
            .series_last("train/loss")
            .map(|p| p.x)
            .unwrap_or(f64::NEG_INFINITY)
            .max(self.armed_after_x);
        // the regression window keys off reward_vs_samples' x coordinate
        if let Some(p) = hub.series_last("reward_vs_samples") {
            self.armed_after_x = self.armed_after_x.max(p.x);
        }
        if let Some(p) = hub.series_last("train/mean_lag_smoothed") {
            self.armed_after_x = self.armed_after_x.max(p.x);
        }
        self.ess_trips_base = hub.counter("ess_floor_trips");
    }
}

/// Everything the supervisor needs to run the control plane: the command
/// channel, the shared actor gate, the guardrail watchdog, and the
/// rollback retry budget.
pub struct ControlPlane {
    pub controller: RunController,
    pub gate: ControlGate,
    pub guardrail: Guardrail,
    pub cfg: ControlConfig,
    /// remaining pause-then-rollback attempts; exhausted → fail-safe
    /// transition to `Drained`
    pub rollbacks_left: usize,
}

impl ControlPlane {
    pub fn new(cfg: ControlConfig) -> ControlPlane {
        ControlPlane::with_controller(cfg, RunController::new())
    }

    /// Build around an externally-held [`RunController`] so the caller
    /// keeps a handle to command the run.
    pub fn with_controller(cfg: ControlConfig, controller: RunController) -> ControlPlane {
        ControlPlane {
            controller,
            gate: ControlGate::new(),
            guardrail: Guardrail::new(cfg.clone()),
            rollbacks_left: cfg.rollback_budget,
            cfg,
        }
    }

    /// Exponential backoff before rollback attempt `attempt` (0-based;
    /// the first attempt never waits).
    pub fn backoff(&self, attempt: usize) -> std::time::Duration {
        if attempt == 0 {
            return std::time::Duration::ZERO;
        }
        let shift = (attempt - 1).min(6) as u32;
        std::time::Duration::from_millis(self.cfg.retry_backoff_ms.saturating_mul(1 << shift))
    }
}

/// Write a guardrail trip report under `target/control/` (CI uploads the
/// directory as a failure artifact). Returns the path, or None when the
/// directory cannot be created — reporting must never take the run down.
pub fn write_trip_report(name: &str, trip: &Trip, context: &str) -> Option<PathBuf> {
    let dir = std::path::Path::new("target").join("control");
    std::fs::create_dir_all(&dir).ok()?;
    let path = dir.join(format!("{name}-{}.txt", trip.reason.name()));
    let body = format!(
        "guardrail trip: {}\nreason: {}\ndetail: {}\n\n{}\n",
        name,
        trip.reason.name(),
        trip.detail,
        context
    );
    std::fs::write(&path, body).ok()?;
    Some(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn enabled_cfg() -> ControlConfig {
        let mut cfg = ControlConfig::default();
        cfg.enabled = true;
        cfg
    }

    #[test]
    fn controller_preserves_submission_order() {
        let ctl = RunController::new();
        ctl.send(RunCommand::Pause);
        ctl.send(RunCommand::Rollback { checkpoint: Some(3) });
        ctl.send(RunCommand::Resume);
        assert_eq!(ctl.pending(), 3);
        assert_eq!(
            ctl.drain(),
            vec![
                RunCommand::Pause,
                RunCommand::Rollback { checkpoint: Some(3) },
                RunCommand::Resume,
            ]
        );
        assert_eq!(ctl.pending(), 0);
        assert!(ctl.drain().is_empty());
    }

    #[test]
    fn gate_phases_and_load_ledger() {
        let gate = ControlGate::new();
        assert!(gate.admitting());
        assert_eq!(gate.phase(), AdmissionPhase::Running);
        gate.set_phase(AdmissionPhase::Paused);
        assert!(!gate.admitting());
        gate.set_phase(AdmissionPhase::Draining);
        assert!(!gate.admitting());
        assert_eq!(gate.phase(), AdmissionPhase::Draining);

        gate.report_load(0, 5);
        gate.report_load(1, 3);
        assert_eq!(gate.total_load(), 8);
        gate.report_load(0, 0);
        assert_eq!(gate.total_load(), 3);
        gate.clear_load(1);
        assert_eq!(gate.total_load(), 0);
        // a clone observes the same shared state
        let twin = gate.clone();
        twin.set_phase(AdmissionPhase::Running);
        assert!(gate.admitting());
    }

    #[test]
    fn run_state_gauge_values_are_distinct_and_stable() {
        let all = [
            RunState::Running,
            RunState::Paused,
            RunState::Draining,
            RunState::Completed,
            RunState::Failed,
            RunState::Drained,
            RunState::RolledBack,
        ];
        let mut seen = std::collections::BTreeSet::new();
        for s in all {
            assert!(seen.insert(s.gauge() as i64), "duplicate gauge for {}", s.name());
        }
        // pinned encodings: changing one silently breaks scenario asserts
        assert_eq!(RunState::Completed.gauge(), 3.0);
        assert_eq!(RunState::Failed.gauge(), 4.0);
        assert_eq!(RunState::Drained.gauge(), 5.0);
        assert_eq!(RunState::RolledBack.gauge(), 6.0);
        let hub = MetricsHub::new();
        record_state(&hub, RunState::Drained);
        assert_eq!(hub.series_last(RUN_STATE_GAUGE).unwrap().value, 5.0);
    }

    #[test]
    fn guardrail_trips_on_non_finite_loss_once() {
        let hub = MetricsHub::new();
        let mut g = Guardrail::new(enabled_cfg());
        hub.record("train/loss", 0.0, 1.0, 0.5);
        assert!(g.check(&hub).is_none(), "finite loss is healthy");
        hub.record("train/loss", 0.0, 2.0, f64::NAN);
        let trip = g.check(&hub).expect("NaN loss trips");
        assert_eq!(trip.reason, TripReason::NonFiniteLoss);
        // acknowledged evidence no longer re-trips
        g.acknowledge(&hub);
        assert!(g.check(&hub).is_none(), "spent evidence must not re-trip");
        // but fresh bad data does
        hub.record("train/loss", 0.0, 3.0, f64::INFINITY);
        assert!(g.check(&hub).is_some());
    }

    #[test]
    fn guardrail_reward_regression_window() {
        let hub = MetricsHub::new();
        let mut cfg = enabled_cfg();
        cfg.window = 4;
        cfg.reward_drop = 0.5;
        let mut g = Guardrail::new(cfg);
        // healthy plateau at 0.8
        for i in 0..4 {
            hub.record("reward_vs_samples", 0.0, i as f64, 0.8);
        }
        assert!(g.check(&hub).is_none(), "needs two full windows");
        // collapse to 0.2: a 75% drop over the 4-step window
        for i in 4..8 {
            hub.record("reward_vs_samples", 0.0, i as f64, 0.2);
        }
        let trip = g.check(&hub).expect("reward collapse trips");
        assert_eq!(trip.reason, TripReason::RewardRegression);
        // a shallow dip (0.8 -> 0.6, 25% < 50% limit) stays healthy
        let hub2 = MetricsHub::new();
        let mut cfg2 = enabled_cfg();
        cfg2.window = 4;
        cfg2.reward_drop = 0.5;
        let mut g2 = Guardrail::new(cfg2);
        for i in 0..4 {
            hub2.record("reward_vs_samples", 0.0, i as f64, 0.8);
        }
        for i in 4..8 {
            hub2.record("reward_vs_samples", 0.0, i as f64, 0.6);
        }
        assert!(g2.check(&hub2).is_none());
        // zero-reward early training never counts as a regression
        let hub3 = MetricsHub::new();
        let mut g3 = Guardrail::new(enabled_cfg());
        for i in 0..16 {
            hub3.record("reward_vs_samples", 0.0, i as f64, 0.0);
        }
        assert!(g3.check(&hub3).is_none());
    }

    #[test]
    fn guardrail_ess_budget_and_lag_runaway() {
        let hub = MetricsHub::new();
        let mut cfg = enabled_cfg();
        cfg.ess_trip_limit = 2.0;
        cfg.max_lag_steps = 10.0;
        let mut g = Guardrail::new(cfg);
        hub.add("ess_floor_trips", 2.0);
        assert!(g.check(&hub).is_none(), "at the limit is still healthy");
        hub.add("ess_floor_trips", 1.0);
        let trip = g.check(&hub).expect("budget exceeded");
        assert_eq!(trip.reason, TripReason::EssFloor);
        g.acknowledge(&hub);
        assert!(g.check(&hub).is_none(), "acknowledge rebases the counter");

        hub.record("train/mean_lag_smoothed", 0.0, 1.0, 25.0);
        let trip = g.check(&hub).expect("lag runaway");
        assert_eq!(trip.reason, TripReason::LagRunaway);
        g.acknowledge(&hub);
        assert!(g.check(&hub).is_none());
        // disabled checks (limit 0) never fire
        let mut g_off = Guardrail::new(enabled_cfg());
        assert!(g_off.check(&hub).is_none());
    }

    #[test]
    fn control_plane_backoff_is_bounded_exponential() {
        let mut cfg = enabled_cfg();
        cfg.retry_backoff_ms = 50;
        let plane = ControlPlane::new(cfg);
        assert_eq!(plane.backoff(0).as_millis(), 0, "first attempt is immediate");
        assert_eq!(plane.backoff(1).as_millis(), 50);
        assert_eq!(plane.backoff(2).as_millis(), 100);
        assert_eq!(plane.backoff(3).as_millis(), 200);
        // capped shift: no overflow however deep the retry goes
        assert_eq!(plane.backoff(50).as_millis(), 50 * 64);
        assert_eq!(plane.rollbacks_left, ControlConfig::default().rollback_budget);
    }

    #[test]
    fn trip_reports_land_under_target_control() {
        let trip = Trip {
            reason: TripReason::LagRunaway,
            detail: "train/mean_lag_smoothed = 99".into(),
        };
        let path = write_trip_report("control_mod_unit", &trip, "ctx: unit test")
            .expect("report written");
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(body.contains("lag_runaway"));
        assert!(body.contains("ctx: unit test"));
        std::fs::remove_file(&path).ok();
    }
}
