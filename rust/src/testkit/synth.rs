//! Shared deterministic synthetic trainer for checkpoint/failover tests.
//!
//! An Adam-shaped update on a small parameter set, gradients synthesized
//! from a seeded RNG whose cursor is checkpointed — everything that
//! affects the trajectory lives in [`TrainState`], so "resume from a
//! manifest" is bit-identical iff the state round-trips completely.
//! tests/checkpoint_resume.rs pins that property (and its negative
//! controls); tests/determinism.rs drives the same trainer through the
//! real supervisor's failover slot.

use crate::model::checkpoint::TrainState;
use crate::runtime::HostTensor;
use crate::util::Rng;

pub struct SynthTrainer {
    pub variant: String,
    /// completed optimizer steps
    pub step: u64,
    pub params: Vec<HostTensor>,
    pub m: Vec<HostTensor>,
    pub v: Vec<HostTensor>,
    pub samples: f64,
    pub tokens: f64,
    pub rng: Rng,
}

impl SynthTrainer {
    pub fn new(seed: u64) -> SynthTrainer {
        let n = 6;
        let mut rng = Rng::new(seed);
        let init: Vec<f32> = (0..n).map(|_| rng.f32() - 0.5).collect();
        SynthTrainer {
            variant: "synthetic".into(),
            step: 0,
            params: vec![HostTensor::from_f32(&[n], init)],
            m: vec![HostTensor::zeros_f32(&[n])],
            v: vec![HostTensor::zeros_f32(&[n])],
            samples: 0.0,
            tokens: 0.0,
            rng,
        }
    }

    pub fn step(&mut self) {
        self.step += 1;
        let lr = 0.05f32;
        for i in 0..self.params.len() {
            let n = self.params[i].numel();
            let grads: Vec<f32> = (0..n).map(|_| self.rng.f32() - 0.5).collect();
            let p = self.params[i].f32s_mut().unwrap();
            let m = self.m[i].f32s_mut().unwrap();
            let v = self.v[i].f32s_mut().unwrap();
            for j in 0..p.len() {
                m[j] = 0.9 * m[j] + 0.1 * grads[j];
                v[j] = 0.99 * v[j] + 0.01 * grads[j] * grads[j];
                p[j] -= lr * m[j] / (v[j].sqrt() + 1e-8);
            }
        }
        self.samples += 16.0;
        self.tokens += 512.0;
    }

    pub fn to_state(&self) -> TrainState {
        TrainState {
            variant: self.variant.clone(),
            step: self.step,
            params: self.params.clone(),
            opt_m: self.m.clone(),
            opt_v: self.v.clone(),
            samples_total: self.samples,
            tokens_total: self.tokens,
            rng: self.rng.state_words(),
            // this trainer owns no engine; the generation-side cursors
            // are exercised by the golden harness (testkit::golden)
            engine_rng: [0; 4],
            sched_cursor: 0,
        }
    }

    pub fn from_state(st: TrainState) -> SynthTrainer {
        SynthTrainer {
            variant: st.variant,
            step: st.step,
            params: st.params,
            m: st.opt_m,
            v: st.opt_v,
            samples: st.samples_total,
            tokens: st.tokens_total,
            rng: Rng::from_state_words(st.rng),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_roundtrip_is_lossless_in_memory() {
        let mut t = SynthTrainer::new(11);
        for _ in 0..5 {
            t.step();
        }
        let back = SynthTrainer::from_state(t.to_state());
        assert_eq!(back.step, 5);
        assert_eq!(back.params, t.params);
        assert_eq!(back.m, t.m);
        assert_eq!(back.v, t.v);
        assert_eq!(back.samples, t.samples);
    }
}
