//! Deterministic chaos schedules for the fault-tolerance harness.
//!
//! A [`ChaosSchedule`] is a pure, seed-derived list of failure events,
//! each pinned to an *optimizer-step* timestamp. The coordinator's
//! supervisor (see `coordinator::supervisor`) polls the weight bus's
//! published version — the pipeline's logical clock — and fires every
//! event whose step has passed, in schedule order.
//!
//! Determinism contract: the schedule is a function of its seed alone
//! (`generate(seed, ..) == generate(seed, ..)`), event kinds carry no
//! ambient targets (the supervisor resolves "which actor" from pool
//! state, lowest/highest live id, which is itself deterministic given
//! the event sequence), and every run prints its chaos seed — so a
//! failing schedule replays exactly from the printed seed. Wall-clock
//! interleaving still varies between runs, but the *sequence* of
//! injected faults does not, which is what a reproduction needs.

use crate::util::Rng;
use std::fmt;

/// One failure to inject. Targets are resolved by the supervisor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChaosKind {
    /// abruptly halt the lowest-id live actor (in-flight work migrated
    /// when a migration hub is wired, aborted otherwise); the supervisor
    /// respawns one only if the pool would drop below its floor, and
    /// only while the respawn budget lasts
    KillActor,
    /// SIGTERM-style kill with injected latency: the target is resolved
    /// when the event fires, but its halt lands only `delay_ms` later and
    /// is *not* joined — the actor winds down (exporting its portable
    /// rollouts) while the rest of the pipeline keeps running. Exercises
    /// the slow-kill races that instant kills cannot: weight publishes,
    /// migrations and autoscale decisions interleave with the teardown
    SlowKillActor { delay_ms: u64 },
    /// kill the lowest-id live actor and immediately respawn it
    RestartActor,
    /// grow the pool by one actor (no-op at the ceiling)
    AddActor,
    /// retire the highest-id live actor (no-op at the floor)
    RemoveActor,
    /// every weight-bus publish sleeps this long until healed
    BusDelay { ms: u64 },
    /// heal a previous `BusDelay`
    BusHeal,
    /// stall all rollout-topic publishers for this long
    TopicStall { ms: u64 },
    /// byzantine injection: deposit bit-flipped/truncated `PRLSNAP1`
    /// bytes into the migration hub, as if a corrupt peer (or a torn
    /// transfer) handed off an in-flight rollout. The claim path must
    /// reject it, keep the hub's books balanced, and the claiming actor
    /// must survive. No-op without a migration hub.
    CorruptSnapshot,
    /// kill the trainer mid-run. With trainer failover wired (a
    /// supervisor-owned trainer slot and a checkpoint dir), the
    /// supervisor restarts it from the latest `AsyncCheckpointer`
    /// manifest *without tearing the run down* — actors keep decoding,
    /// topics stay open, and the restored optimizer trajectory continues
    /// from the last durable state. No-op without a trainer slot.
    KillTrainer,
    /// force a guardrail trip: the control plane reacts exactly as if a
    /// live health check (non-finite loss, reward regression, ESS floor,
    /// lag runaway — see `control::Guardrail`) had fired on its own —
    /// pause, roll the trainer back to the latest healthy checkpoint,
    /// resume. No-op without a wired `RunController`.
    GuardrailTrip,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChaosEvent {
    /// fire once the trainer has published this optimizer step
    pub at_step: u64,
    pub kind: ChaosKind,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ChaosSchedule {
    pub seed: u64,
    pub events: Vec<ChaosEvent>,
}

impl ChaosSchedule {
    /// Derive a schedule of `n_events` faults over a run of `total_steps`
    /// optimizer steps. Pure in `seed`: equal seeds give equal schedules.
    pub fn generate(seed: u64, total_steps: u64, n_events: usize) -> ChaosSchedule {
        let mut rng = Rng::with_stream(seed, 0xc4a0);
        let last = total_steps.saturating_sub(1).max(1);
        let mut events = Vec::with_capacity(n_events);
        for _ in 0..n_events {
            let at_step = 1 + rng.below(last as usize) as u64;
            // weighted kinds: churn-heavy (instant and latency-injected
            // kills), with occasional transport faults. Latencies are
            // drawn from the same seeded stream, so jitter replays too.
            let kind = match rng.below(100) {
                0..=19 => ChaosKind::KillActor,
                20..=34 => ChaosKind::SlowKillActor { delay_ms: 2 + rng.below(30) as u64 },
                35..=49 => ChaosKind::RestartActor,
                50..=64 => ChaosKind::AddActor,
                65..=74 => ChaosKind::RemoveActor,
                75..=82 => ChaosKind::BusDelay { ms: 5 + rng.below(45) as u64 },
                83..=86 => ChaosKind::BusHeal,
                87..=91 => ChaosKind::CorruptSnapshot,
                92..=94 => ChaosKind::KillTrainer,
                95..=97 => ChaosKind::GuardrailTrip,
                _ => ChaosKind::TopicStall { ms: 5 + rng.below(45) as u64 },
            };
            events.push(ChaosEvent { at_step, kind });
        }
        events.sort_by_key(|e| e.at_step);
        ChaosSchedule { seed, events }
    }

    /// Hand-written scenario: kill one actor at `kill_step`, bring a
    /// replacement up at `restart_step`. The canonical integration case.
    pub fn kill_then_restart(kill_step: u64, restart_step: u64) -> ChaosSchedule {
        ChaosSchedule {
            seed: 0,
            events: vec![
                ChaosEvent { at_step: kill_step, kind: ChaosKind::KillActor },
                ChaosEvent { at_step: restart_step, kind: ChaosKind::AddActor },
            ],
        }
    }

    /// Hand-written scenario: a latency-injected kill at `kill_step`
    /// whose halt lands `delay_ms` after the event fires — the canonical
    /// slow-kill migration race.
    pub fn slow_kill(kill_step: u64, delay_ms: u64) -> ChaosSchedule {
        ChaosSchedule {
            seed: 0,
            events: vec![ChaosEvent {
                at_step: kill_step,
                kind: ChaosKind::SlowKillActor { delay_ms },
            }],
        }
    }

    /// Hand-written scenario: kill the trainer once the version clock
    /// passes `at_step` — the canonical failover case (the supervisor
    /// restarts it from the latest checkpoint manifest mid-run).
    pub fn kill_trainer(at_step: u64) -> ChaosSchedule {
        ChaosSchedule {
            seed: 0,
            events: vec![ChaosEvent { at_step, kind: ChaosKind::KillTrainer }],
        }
    }

    /// Hand-written scenario: force a guardrail trip once the version
    /// clock passes `at_step` — the canonical pause-then-rollback case
    /// (the control plane rewinds the trainer to the latest healthy
    /// checkpoint and the run continues).
    pub fn guardrail_trip(at_step: u64) -> ChaosSchedule {
        ChaosSchedule {
            seed: 0,
            events: vec![ChaosEvent { at_step, kind: ChaosKind::GuardrailTrip }],
        }
    }

    /// Hand-written scenario: `n` byzantine snapshot deposits starting at
    /// `at_step`, one per step.
    pub fn byzantine(at_step: u64, n: usize) -> ChaosSchedule {
        ChaosSchedule {
            seed: 0,
            events: (0..n as u64)
                .map(|i| ChaosEvent { at_step: at_step + i, kind: ChaosKind::CorruptSnapshot })
                .collect(),
        }
    }

    /// Human-readable replay recipe; printed at run start so a failing
    /// schedule can be reproduced from its seed.
    pub fn describe(&self) -> String {
        let mut s = format!(
            "chaos schedule (seed {}, {} events):",
            self.seed,
            self.events.len()
        );
        for e in &self.events {
            s.push_str(&format!("\n  step {:>4}: {}", e.at_step, e.kind));
        }
        s
    }
}

impl fmt::Display for ChaosKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ChaosKind::KillActor => write!(f, "kill-actor"),
            ChaosKind::SlowKillActor { delay_ms } => {
                write!(f, "slow-kill-actor +{delay_ms}ms")
            }
            ChaosKind::RestartActor => write!(f, "restart-actor"),
            ChaosKind::AddActor => write!(f, "add-actor"),
            ChaosKind::RemoveActor => write!(f, "remove-actor"),
            ChaosKind::BusDelay { ms } => write!(f, "bus-delay {ms}ms"),
            ChaosKind::BusHeal => write!(f, "bus-heal"),
            ChaosKind::TopicStall { ms } => write!(f, "topic-stall {ms}ms"),
            ChaosKind::CorruptSnapshot => write!(f, "corrupt-snapshot"),
            ChaosKind::KillTrainer => write!(f, "kill-trainer"),
            ChaosKind::GuardrailTrip => write!(f, "guardrail-trip"),
        }
    }
}

/// Deterministic byzantine payload for a [`ChaosKind::CorruptSnapshot`]
/// event: a structurally valid `PRLSNAP1` snapshot, bit-flipped at a
/// seed-derived offset *and* truncated by a seed-derived amount — so
/// `SeqSnapshot::from_bytes` always rejects it (truncation alone
/// guarantees that; the bit flip adds in-band damage), and the exact
/// bytes replay from the event's step like every other chaos latency.
pub fn corrupt_snapshot_bytes(seed: u64) -> Vec<u8> {
    let mut rng = Rng::with_stream(seed, 0xbad5_0a9);
    let gen = 1 + rng.below(6);
    let snap = crate::sched::SeqSnapshot {
        seq_id: seed,
        group_id: (0xbad << 40) | seed,
        problem_id: seed,
        prompt: vec![1, 2, 3],
        gen_tokens: (0..gen as i32).collect(),
        behavior_lp: vec![-0.25; gen],
        token_version: vec![1; gen],
        pos: 2 + gen,
        max_new: gen + 4,
        rng_words: [seed; 4],
        t_start: 0.0,
    };
    let mut bytes = snap.to_bytes();
    let at = rng.below(bytes.len());
    bytes[at] ^= 1 << rng.below(8);
    let cut = 1 + rng.below(7);
    bytes.truncate(bytes.len().saturating_sub(cut));
    bytes
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedules_are_seed_deterministic() {
        let a = ChaosSchedule::generate(1234, 50, 8);
        let b = ChaosSchedule::generate(1234, 50, 8);
        assert_eq!(a, b, "same seed must replay the exact same schedule");
        let c = ChaosSchedule::generate(1235, 50, 8);
        assert_ne!(a, c, "different seeds should differ");
    }

    #[test]
    fn events_are_sorted_and_in_range() {
        let s = ChaosSchedule::generate(7, 40, 32);
        assert_eq!(s.events.len(), 32);
        for w in s.events.windows(2) {
            assert!(w[0].at_step <= w[1].at_step);
        }
        for e in &s.events {
            assert!(e.at_step >= 1 && e.at_step < 40, "step {} in range", e.at_step);
        }
    }

    #[test]
    fn describe_names_the_seed() {
        let s = ChaosSchedule::generate(99, 10, 3);
        let d = s.describe();
        assert!(d.contains("seed 99"));
        assert_eq!(d.lines().count(), 4);
    }

    #[test]
    fn generated_slow_kills_carry_seeded_latency() {
        // latency injection must be seed-deterministic and bounded
        let s = ChaosSchedule::generate(0x510_c4a0, 200, 256);
        let delays: Vec<u64> = s
            .events
            .iter()
            .filter_map(|e| match e.kind {
                ChaosKind::SlowKillActor { delay_ms } => Some(delay_ms),
                _ => None,
            })
            .collect();
        assert!(!delays.is_empty(), "weighting must produce slow kills");
        assert!(delays.iter().all(|&d| (2..32).contains(&d)));
        let again = ChaosSchedule::generate(0x510_c4a0, 200, 256);
        assert_eq!(s, again);
    }

    #[test]
    fn slow_kill_scenario_shape() {
        let s = ChaosSchedule::slow_kill(4, 25);
        assert_eq!(s.events.len(), 1);
        assert_eq!(s.events[0].kind, ChaosKind::SlowKillActor { delay_ms: 25 });
        assert!(s.describe().contains("slow-kill-actor +25ms"));
    }

    #[test]
    fn corrupt_snapshot_bytes_always_reject_and_replay() {
        for seed in 0..64u64 {
            let bytes = corrupt_snapshot_bytes(seed);
            assert!(
                crate::sched::SeqSnapshot::from_bytes(&bytes).is_err(),
                "seed {seed}: byzantine bytes must never decode"
            );
            assert_eq!(bytes, corrupt_snapshot_bytes(seed), "payload replays from its seed");
        }
    }

    #[test]
    fn kill_trainer_scenario_shape() {
        let s = ChaosSchedule::kill_trainer(5);
        assert_eq!(s.events.len(), 1);
        assert_eq!(s.events[0].kind, ChaosKind::KillTrainer);
        assert_eq!(s.events[0].at_step, 5);
        assert!(s.describe().contains("kill-trainer"));
    }

    #[test]
    fn generated_schedules_include_trainer_kills() {
        let s = ChaosSchedule::generate(0x7a11, 500, 512);
        assert!(
            s.events.iter().any(|e| e.kind == ChaosKind::KillTrainer),
            "the weighted kinds must produce trainer kills at this sample size"
        );
    }

    #[test]
    fn guardrail_trip_scenario_shape() {
        let s = ChaosSchedule::guardrail_trip(6);
        assert_eq!(s.events.len(), 1);
        assert_eq!(s.events[0].kind, ChaosKind::GuardrailTrip);
        assert_eq!(s.events[0].at_step, 6);
        assert!(s.describe().contains("guardrail-trip"));
    }

    #[test]
    fn generated_schedules_include_guardrail_trips() {
        let s = ChaosSchedule::generate(0x7a11, 500, 512);
        assert!(
            s.events.iter().any(|e| e.kind == ChaosKind::GuardrailTrip),
            "the weighted kinds must produce guardrail trips at this sample size"
        );
    }

    #[test]
    fn byzantine_scenario_shape() {
        let s = ChaosSchedule::byzantine(3, 4);
        assert_eq!(s.events.len(), 4);
        assert!(s.events.iter().all(|e| e.kind == ChaosKind::CorruptSnapshot));
        assert_eq!(s.events[0].at_step, 3);
        assert!(s.describe().contains("corrupt-snapshot"));
    }

    #[test]
    fn degenerate_run_lengths_still_generate() {
        let s = ChaosSchedule::generate(3, 1, 4);
        assert!(s.events.iter().all(|e| e.at_step == 1));
        let empty = ChaosSchedule::generate(3, 20, 0);
        assert!(empty.events.is_empty());
    }
}
