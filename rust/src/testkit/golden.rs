//! Golden-run conformance: deterministic, device-free pipeline runs
//! fingerprinted into a [`RunDigest`].
//!
//! PipelineRL's core claim — in-flight updates keep everything
//! concurrent without corrupting on-policy data — is only testable if a
//! *perturbed* run (crash, preempt, migrate, failover, resume) can be
//! proven **equivalent** to an unperturbed one. The per-sequence
//! equivalence tests (tests/migration.rs, tests/kvmem.rs) prove it for
//! one sequence at a time; this module proves it for a whole run:
//!
//! * [`EventLog`] — an ordered log of digest events (sampled tokens with
//!   their version tags, group completions, optimizer steps with a
//!   parameter hash, weight publishes, RNG cursors, checkpoint cuts),
//!   folded into an FNV-64 [`RunDigest`] as they are recorded. Two runs
//!   with equal digests produced the same data in the same canonical
//!   order; on mismatch [`explain_divergence`] names the first
//!   diverging event.
//!
//! * [`GoldenPipeline`] — a single-threaded, device-free model of the
//!   full pipeline that composes the *real* substrates: admission and
//!   preemption run through [`crate::sched::Scheduler`], kills and
//!   preemptions travel as wire-form `PRLSNAP1` bytes through a real
//!   [`MigrationHub`], checkpoints are real `PRLCKPT3` [`TrainState`]s
//!   with the engine sampling-RNG cursor and the scheduler admission
//!   cursor, written through the real manifest protocol.
//!
//! **Why equivalence is a theorem here, not luck.** The model fixes two
//! invariants that the real system aims for and the digest then checks:
//! (1) every token of a sequence comes from the sequence's *own* RNG
//! stream, whose cursor travels inside its snapshot — so *where* a
//! sequence decodes can never change *what* it decodes; (2) the per-tick
//! event order is canonical (ascending sequence id), so placement is
//! digest-invariant by construction. Under those two rules a perturbed
//! run diverges **iff** the machinery under test (snapshot round-trips,
//! hub bookkeeping, scheduler victim choice, checkpoint cursor
//! fidelity, manifest recovery) loses or corrupts state — which is
//! exactly what the conformance tests in tests/determinism.rs assert
//! it never does.
//!
//! The cluster simulator emits the same event vocabulary on sim time
//! (`SimCfg::digest`), so coarse-grained scenarios get the same
//! replay-stability check.

use crate::engine::BlockAllocator;
use crate::model::checkpoint::TrainState;
use crate::runtime::HostTensor;
use crate::sched::{
    KvLayout, MigrationHub, PreemptPolicy, SchedPolicy, Scheduler, SeqSnapshot, SeqView,
};
use crate::testkit::chaos::{corrupt_snapshot_bytes, ChaosKind, ChaosSchedule};
use crate::util::Rng;
use anyhow::{bail, ensure, Context, Result};
use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::io::Write;
use std::path::{Path, PathBuf};

// ---------------------------------------------------------------------
// digest events
// ---------------------------------------------------------------------

/// One entry of the canonical run fingerprint. Every field is part of
/// the hash — a run that produces the same events in the same order has
/// the same [`RunDigest`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DigestEvent {
    /// one sampled token: which sequence, its index within the generated
    /// stream, the token value, and the weight version it sampled under
    Token { seq: u64, index: u32, tok: i32, version: u64 },
    /// an advantage group completed with this many generated tokens
    GroupComplete { group: u64, tokens: u64 },
    /// one optimizer step, fingerprinted by the post-step parameter hash
    TrainerStep { step: u64, param_hash: u64 },
    /// a weight version became visible to generation
    WeightPublish { version: u64 },
    /// an RNG cursor observation (trainer stream, by convention, once
    /// per optimizer step — the replay anchor)
    RngCursor { words: [u64; 4] },
    /// a checkpoint landed for this step
    CheckpointCut { step: u64 },
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x100_0000_01b3;

/// FNV-1a over a byte slice (the digest hash primitive).
pub fn fnv64(bytes: &[u8]) -> u64 {
    fnv64_fold(FNV_OFFSET, bytes)
}

fn fnv64_fold(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

impl DigestEvent {
    /// Canonical byte encoding (tag + fixed-order LE fields) — what the
    /// digest actually hashes, so the fingerprint is representation-
    /// stable across platforms.
    fn encode(&self, out: &mut Vec<u8>) {
        out.clear();
        match self {
            DigestEvent::Token { seq, index, tok, version } => {
                out.push(0x01);
                out.extend_from_slice(&seq.to_le_bytes());
                out.extend_from_slice(&index.to_le_bytes());
                out.extend_from_slice(&tok.to_le_bytes());
                out.extend_from_slice(&version.to_le_bytes());
            }
            DigestEvent::GroupComplete { group, tokens } => {
                out.push(0x02);
                out.extend_from_slice(&group.to_le_bytes());
                out.extend_from_slice(&tokens.to_le_bytes());
            }
            DigestEvent::TrainerStep { step, param_hash } => {
                out.push(0x03);
                out.extend_from_slice(&step.to_le_bytes());
                out.extend_from_slice(&param_hash.to_le_bytes());
            }
            DigestEvent::WeightPublish { version } => {
                out.push(0x04);
                out.extend_from_slice(&version.to_le_bytes());
            }
            DigestEvent::RngCursor { words } => {
                out.push(0x05);
                for w in words {
                    out.extend_from_slice(&w.to_le_bytes());
                }
            }
            DigestEvent::CheckpointCut { step } => {
                out.push(0x06);
                out.extend_from_slice(&step.to_le_bytes());
            }
        }
    }
}

/// The fingerprint of a run: the folded event hash plus the event count
/// (so an empty suffix can never alias a truncated run).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RunDigest {
    pub hash: u64,
    pub events: u64,
}

impl std::fmt::Display for RunDigest {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:016x}/{}", self.hash, self.events)
    }
}

/// Ordered digest-event log. Events fold into the running hash as they
/// are recorded; the events themselves are retained (unless constructed
/// with [`EventLog::hash_only`]) so a digest mismatch can be explained
/// by its first diverging event instead of just two hex strings.
#[derive(Debug, Clone)]
pub struct EventLog {
    hash: u64,
    count: u64,
    /// absolute index of `events[0]` — a log resumed from a checkpoint
    /// continues the stream without holding the pre-crash prefix
    base: u64,
    events: Option<Vec<DigestEvent>>,
    scratch: Vec<u8>,
}

impl Default for EventLog {
    fn default() -> Self {
        EventLog::new()
    }
}

impl EventLog {
    pub fn new() -> EventLog {
        EventLog {
            hash: FNV_OFFSET,
            count: 0,
            base: 0,
            events: Some(Vec::new()),
            scratch: Vec::new(),
        }
    }

    /// Hash-only log (no event retention) — for long runs where only the
    /// digest matters, e.g. the cluster simulator.
    pub fn hash_only() -> EventLog {
        EventLog { events: None, ..EventLog::new() }
    }

    /// Continue a stream from a checkpointed digest: the hash and count
    /// carry on, the pre-crash events themselves are gone (they died
    /// with the process).
    pub fn resumed(from: RunDigest) -> EventLog {
        EventLog {
            hash: from.hash,
            count: from.events,
            base: from.events,
            events: Some(Vec::new()),
            scratch: Vec::new(),
        }
    }

    pub fn record(&mut self, ev: DigestEvent) {
        let mut scratch = std::mem::take(&mut self.scratch);
        ev.encode(&mut scratch);
        self.hash = fnv64_fold(self.hash, &scratch);
        self.scratch = scratch;
        self.count += 1;
        if let Some(events) = &mut self.events {
            events.push(ev);
        }
    }

    pub fn digest(&self) -> RunDigest {
        RunDigest { hash: self.hash, events: self.count }
    }

    /// Retained events (empty for a hash-only log).
    pub fn events(&self) -> &[DigestEvent] {
        self.events.as_deref().unwrap_or(&[])
    }

    /// Absolute index of the first retained event.
    pub fn base(&self) -> u64 {
        self.base
    }
}

/// Human-readable account of where a perturbed event stream first left
/// the baseline. `perturbed` is the run in segment order (a kill+resume
/// run has two segments: pre-kill and post-resume). Only meaningful for
/// retaining logs.
pub fn explain_divergence(baseline: &EventLog, perturbed: &[&EventLog]) -> String {
    let base_events = baseline.events();
    for part in perturbed {
        for (i, ev) in part.events().iter().enumerate() {
            let at = part.base() as usize + i;
            match base_events.get(at) {
                Some(b) if b == ev => continue,
                Some(b) => {
                    return format!(
                        "first divergence at event {at}: baseline {b:?}, perturbed {ev:?}"
                    );
                }
                None => {
                    return format!(
                        "perturbed run produced extra event {at}: {ev:?} \
                         (baseline ended at {})",
                        base_events.len()
                    );
                }
            }
        }
    }
    let perturbed_total = perturbed.last().map(|p| p.digest().events).unwrap_or(0);
    if (base_events.len() as u64) > perturbed_total {
        return format!(
            "perturbed run stopped early: {perturbed_total} events vs baseline {}; \
             next baseline event: {:?}",
            base_events.len(),
            base_events.get(perturbed_total as usize)
        );
    }
    "event streams match on every retained event (divergence must be in a \
     non-retained prefix)"
        .to_string()
}

/// Persist a failure report for CI to upload (tier1.sh runs the
/// determinism suite repeatedly; on mismatch the seed + digest diff land
/// under target/determinism/). Best-effort: returns the path when the
/// write succeeded.
pub fn write_failure_report(name: &str, seed: u64, body: &str) -> Option<PathBuf> {
    let dir = Path::new("target").join("determinism");
    std::fs::create_dir_all(&dir).ok()?;
    let path = dir.join(format!("{name}-seed-{seed:016x}.txt"));
    let mut f = std::fs::File::create(&path).ok()?;
    writeln!(f, "{name}: replay seed = {seed:#x} ({seed})\n{body}").ok()?;
    Some(path)
}

// ---------------------------------------------------------------------
// the golden pipeline model
// ---------------------------------------------------------------------

/// Configuration of a golden run. One logical *tick* = one decode round
/// for every live sequence, then a trainer drain.
#[derive(Debug, Clone)]
pub struct GoldenCfg {
    pub seed: u64,
    /// optimizer steps to run
    pub steps: u64,
    /// advantage groups consumed per optimizer step
    pub groups_per_step: usize,
    /// sequences per advantage group
    pub group_size: usize,
    /// initial actor count (placement shards; capacity is global)
    pub n_actors: usize,
    /// global in-flight sequence count the admission loop maintains
    pub live_target: usize,
    /// per-sequence generation budget: target lengths draw from
    /// `1..=max_new` off the admission RNG
    pub max_new: usize,
    pub vocab: usize,
    /// trainer publish cadence in optimizer steps: 1 = after every step
    /// (the pipeline default), k > 1 models `run.mode = periodic { k }`
    /// — between publishes tokens keep sampling under the stale version,
    /// which the digest's per-token version tags make visible
    pub publish_every: u64,
    /// checkpoint cadence in optimizer steps (0 = no checkpoints)
    pub checkpoint_every: u64,
    /// checkpoint directory (required for checkpointing / failover)
    pub dir: Option<PathBuf>,
    pub sched: SchedPolicy,
    pub preempt: PreemptPolicy,
    /// guardrail rollbacks allowed before a trip falls through to the
    /// fail-safe drain (mirrors `[control] rollback_budget`)
    pub rollback_budget: usize,
    /// `[kv] layout` analogue: Paged threads a refcounted
    /// [`BlockAllocator`] shadow through every admission, growth and
    /// release the run performs — value-neutral by construction (the
    /// pool is sized to never refuse, and scheduler views bill blocks by
    /// a layout-independent formula), so a paged run must produce the
    /// *same digest* as a dense one, which the conformance tests assert
    pub kv_layout: KvLayout,
    /// page size of the paged shadow (tokens per block); 4 keeps the
    /// 2-token prompt a partial block, so the first divergent write of
    /// every group member exercises a copy-on-write fork
    pub kv_block_size: usize,
    /// `[kv] prefill_chunk` analogue: a dispatch-accounting shadow for
    /// chunked prefill. Seating a sequence bills `ceil(fed / W)` prefill
    /// dispatches instead of `fed` (the positions its existing stream
    /// force-feeds) — value-neutral by construction: no digest event
    /// depends on the billing, so a `W > 1` run must produce the *same
    /// digest* as a `W = 1` one while its dispatch counts drop, which
    /// the conformance tests assert
    pub prefill_chunk: usize,
}

impl GoldenCfg {
    pub fn new(seed: u64) -> GoldenCfg {
        GoldenCfg {
            seed,
            steps: 10,
            groups_per_step: 2,
            group_size: 2,
            n_actors: 3,
            live_target: 6,
            max_new: 6,
            vocab: 97,
            publish_every: 1,
            checkpoint_every: 0,
            dir: None,
            sched: SchedPolicy::Fifo,
            preempt: PreemptPolicy::Youngest,
            rollback_budget: 2,
            kv_layout: KvLayout::Dense,
            kv_block_size: 4,
            prefill_chunk: 1,
        }
    }
}

/// A perturbation schedule: real chaos events fired against the weight
/// version clock, plus tick-indexed forced preemptions (the engine's
/// block-pressure parks have no version-clock analogue, so they key on
/// the tick counter instead).
#[derive(Debug, Clone, Default)]
pub struct Perturbation {
    pub chaos: Option<ChaosSchedule>,
    /// ticks at which one scheduler-chosen victim is parked through the
    /// wire-form snapshot path and re-admitted the same tick
    pub preempt_ticks: Vec<u64>,
    /// control-plane pause windows `[start, end)` in ticks: at `start`
    /// every in-flight sequence parks into the migration hub (books
    /// balanced) and admission closes; at `end` admission reopens and
    /// reclaims. A pause is a uniform time shift of the event stream, so
    /// it is digest-invariant — the conformance tests assert exactly that.
    pub pause_spans: Vec<(u64, u64)>,
}

impl Perturbation {
    pub fn none() -> Perturbation {
        Perturbation::default()
    }

    pub fn chaos(schedule: ChaosSchedule) -> Perturbation {
        Perturbation { chaos: Some(schedule), ..Perturbation::default() }
    }

    /// Control-plane pause windows only (no chaos, no preempts).
    pub fn pauses(spans: Vec<(u64, u64)>) -> Perturbation {
        Perturbation { pause_spans: spans, ..Perturbation::default() }
    }

    fn paused_at(&self, tick: u64) -> bool {
        self.pause_spans.iter().any(|&(start, end)| start <= tick && tick < end)
    }

    /// Seed-derived mixed schedule: `n_chaos` chaos events over the
    /// version clock plus `n_preempts` forced preemptions over roughly
    /// the run's tick horizon. Pure in `seed` — equal seeds replay the
    /// exact same perturbations.
    pub fn generate(
        seed: u64,
        total_steps: u64,
        n_chaos: usize,
        n_preempts: usize,
    ) -> Perturbation {
        let chaos = ChaosSchedule::generate(seed, total_steps, n_chaos);
        let mut rng = Rng::with_stream(seed, 0x9e13_7791);
        let horizon = (total_steps.max(1) as usize) * 8;
        let mut ticks: Vec<u64> =
            (0..n_preempts).map(|_| 1 + rng.below(horizon) as u64).collect();
        ticks.sort_unstable();
        Perturbation { chaos: Some(chaos), preempt_ticks: ticks, ..Perturbation::default() }
    }
}

/// Accounting of one golden run.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct GoldenStats {
    pub ticks: u64,
    pub fresh_admitted: u64,
    /// sequences re-seated from the migration hub (kills, preemptions)
    pub migrated: u64,
    pub preemptions: u64,
    pub trainer_failovers: u64,
    pub corrupt_rejected: u64,
    pub checkpoints: u64,
    /// control-plane pause windows entered
    pub pauses: u64,
    /// sequences parked into the hub by pause windows
    pub parked: u64,
    /// guardrail trips fired (each either rolls back or drains)
    pub guardrail_trips: u64,
    /// trips resolved by rolling back to the latest checkpoint
    pub rollbacks: u64,
    /// trips that fell through to the fail-safe drain (budget exhausted
    /// or no checkpoint to roll back to)
    pub failsafe_drains: u64,
    /// migration-hub conservation books at run end (after the final
    /// discard): `deposited == claimed + discarded` always holds
    pub hub_deposited: u64,
    pub hub_claimed: u64,
    pub hub_discarded: u64,
    /// paged-shadow accounting (0 under the dense layout): copy-on-write
    /// forks performed, and the peak distinct blocks held at any tick
    pub kv_cow_forks: u64,
    pub kv_peak_blocks: u64,
    /// chunked-prefill shadow: decode dispatches spent force-feeding
    /// existing streams at seating (fresh prompts and re-seated
    /// snapshots), and the single-token dispatches those chunks replaced
    /// — mirrors `EngineStats::{prefill_chunks, forced_steps_saved}`
    pub prefill_dispatches: u64,
    pub forced_steps_saved: u64,
}

/// Result of a golden run (completed, or stopped at an injected
/// checkpoint-boundary kill).
#[derive(Debug)]
pub struct GoldenRun {
    pub log: EventLog,
    pub steps_done: u64,
    pub stats: GoldenStats,
    /// Some(step): the run was killed right after this checkpoint landed
    /// (resume with [`GoldenPipeline::resume`])
    pub stopped_at_checkpoint: Option<u64>,
    /// the run ended in the fail-safe drain (guardrail trip with no
    /// rollback path left): live work finished, nothing new admitted
    pub drained: bool,
}

/// One in-flight sequence of the model. Its token stream comes from its
/// *own* RNG (cursor travels in its snapshot), so placement and
/// migration cannot change what it generates — the invariant the digest
/// then verifies end to end.
struct GSeq {
    uid: u64,
    group: u64,
    target: usize,
    toks: Vec<i32>,
    versions: Vec<u64>,
    rng: Rng,
}

impl GSeq {
    fn fresh(cfg: &GoldenCfg, uid: u64, group: u64, target: usize) -> GSeq {
        GSeq {
            uid,
            group,
            target,
            toks: Vec::new(),
            versions: Vec::new(),
            rng: Rng::with_stream(cfg.seed ^ 0x601d_5eed, uid + 1),
        }
    }

    /// `bs` is the block size the view bills KV in. Deliberately the
    /// same worst-case fill in both layouts (never the paged shadow's
    /// share-aware count): the victim rule must pick identically under
    /// dense and paged, or the layouts could not be digest-equivalent.
    fn view(&self, bs: usize) -> SeqView {
        SeqView {
            seq_id: self.uid,
            group_id: self.group,
            total_len: 2 + self.toks.len(),
            gen_len: self.toks.len(),
            // a resumed sequence sits one short of its stream length
            pos: if self.toks.is_empty() { 0 } else { 1 + self.toks.len() },
            kv_blocks: (2 + self.toks.len()).div_ceil(bs),
        }
    }

    /// Portable form: the real `PRLSNAP1` record. The target length is
    /// encoded in the prompt (problems regenerate from their id in the
    /// real system; here the prompt *is* the problem) and the sampling
    /// cursor rides in `rng_words`.
    fn to_snapshot(&self) -> SeqSnapshot {
        let gen = self.toks.len();
        SeqSnapshot {
            seq_id: self.uid,
            group_id: self.group,
            problem_id: self.uid,
            prompt: vec![1, self.target as i32],
            gen_tokens: self.toks.clone(),
            behavior_lp: vec![-0.125; gen],
            token_version: self.versions.clone(),
            pos: 1 + gen,
            max_new: self.target,
            rng_words: self.rng.state_words(),
            t_start: 0.0,
        }
    }

    fn from_snapshot(s: &SeqSnapshot) -> Result<GSeq> {
        ensure!(
            s.prompt.len() == 2 && s.prompt[0] == 1,
            "not a golden-model snapshot (prompt {:?})",
            s.prompt
        );
        Ok(GSeq {
            uid: s.seq_id,
            group: s.group_id,
            target: s.prompt[1] as usize,
            toks: s.gen_tokens.clone(),
            versions: s.token_version.clone(),
            rng: Rng::from_state_words(s.rng_words),
        })
    }
}

/// The paged-layout shadow: a real [`BlockAllocator`] fed every
/// admission, growth and release the golden run performs, with the
/// conservation invariants checked every tick. It must be value-neutral
/// — the pool is sized so it can never refuse work the model admits
/// (any refusal panics the run instead of silently diverging), so the
/// only thing the paged arm can change versus the dense arm is
/// *allocator state*, never a digest event.
struct GoldenKv {
    alloc: BlockAllocator,
}

impl GoldenKv {
    fn build(cfg: &GoldenCfg) -> Option<GoldenKv> {
        if cfg.kv_layout != KvLayout::Paged {
            return None;
        }
        let per_seq = (2 + cfg.max_new).div_ceil(cfg.kv_block_size);
        // generous: live_target residents plus CoW fork headroom — the
        // shadow must never refuse what the model admits
        let blocks = (cfg.live_target + cfg.group_size) * per_seq * 2 + 8;
        Some(GoldenKv { alloc: BlockAllocator::new(blocks, cfg.kv_block_size) })
    }

    /// Admission: fresh sequences (nothing generated) share their
    /// group's prompt blocks, exactly like the engine's admit path;
    /// anything with generated tokens re-enters private.
    fn seat(&mut self, s: &GSeq) {
        let total = 2 + s.toks.len();
        let r = if s.toks.is_empty() {
            self.alloc.admit_shared(s.uid, s.group, total)
        } else {
            self.alloc.admit(s.uid, total)
        };
        r.expect("golden kv shadow refused an admission its pool must cover");
    }

    fn grow(&mut self, uid: u64, total: usize) {
        let ok = self
            .alloc
            .grow(uid, total)
            .expect("golden kv shadow lost track of a live sequence");
        assert!(ok, "golden kv pool sized to never run dry, but grow failed");
    }

    fn release(&mut self, uid: u64) {
        self.alloc.release(uid).expect("golden kv shadow released an unknown sequence");
    }
}

const GOLDEN_VARIANT: &str = "golden";
const TRAINER_PARAMS: usize = 8;

/// The model trainer: an Adam-shaped update whose gradient mixes the
/// trainer RNG with a hash of the consumed batch, so the parameter
/// trajectory — and therefore the digest — is sensitive to *which*
/// groups trained in *which* order, not just to how many.
///
/// Deliberately *not* [`crate::testkit::synth::SynthTrainer`]: this one
/// couples the gradient to the batch content (the digest-sensitivity
/// requirement), tracks plain `f32` vectors, hashes its parameters, and
/// its exact arithmetic is pinned by the equivalence digests — folding
/// the two together would put a gradient-hook parameter on the shared
/// trainer's API and risk perturbing a verified trajectory for no
/// behavioral gain. If `TrainState` grows a field, the compiler flags
/// both `to_state` sites.
struct GTrainer {
    step: u64,
    params: Vec<f32>,
    m: Vec<f32>,
    v: Vec<f32>,
    samples: f64,
    tokens: f64,
    rng: Rng,
}

impl GTrainer {
    fn new(seed: u64) -> GTrainer {
        let mut init = Rng::with_stream(seed, 0x7124_1e12);
        GTrainer {
            step: 0,
            params: (0..TRAINER_PARAMS).map(|_| init.f32() - 0.5).collect(),
            m: vec![0.0; TRAINER_PARAMS],
            v: vec![0.0; TRAINER_PARAMS],
            samples: 0.0,
            tokens: 0.0,
            rng: Rng::with_stream(seed, 0x7124_57e9),
        }
    }

    fn update(&mut self, batch: &[(u64, u64)], group_size: usize) {
        let mut bytes = Vec::with_capacity(batch.len() * 16);
        for (gid, toks) in batch {
            bytes.extend_from_slice(&gid.to_le_bytes());
            bytes.extend_from_slice(&toks.to_le_bytes());
        }
        let bh = fnv64(&bytes);
        let lr = 0.05f32;
        for i in 0..self.params.len() {
            let data = ((bh >> ((i % 8) * 8)) & 0xff) as f32 / 1024.0 - 0.124;
            let g = (self.rng.f32() - 0.5) + data;
            self.m[i] = 0.9 * self.m[i] + 0.1 * g;
            self.v[i] = 0.99 * self.v[i] + 0.01 * g * g;
            self.params[i] -= lr * self.m[i] / (self.v[i].sqrt() + 1e-8);
        }
        self.step += 1;
        self.samples += (batch.len() * group_size) as f64;
        self.tokens += batch.iter().map(|(_, t)| *t as f64).sum::<f64>();
    }

    fn param_hash(&self) -> u64 {
        let mut bytes = Vec::with_capacity(self.params.len() * 4);
        for p in &self.params {
            bytes.extend_from_slice(&p.to_le_bytes());
        }
        fnv64(&bytes)
    }

    /// `PRLCKPT3` form: the trainer trajectory plus the generation-side
    /// cursors the caller passes in.
    fn to_state(&self, engine_rng: [u64; 4], sched_cursor: u64) -> TrainState {
        TrainState {
            variant: GOLDEN_VARIANT.into(),
            step: self.step,
            params: vec![HostTensor::from_f32(&[self.params.len()], self.params.clone())],
            opt_m: vec![HostTensor::from_f32(&[self.m.len()], self.m.clone())],
            opt_v: vec![HostTensor::from_f32(&[self.v.len()], self.v.clone())],
            samples_total: self.samples,
            tokens_total: self.tokens,
            rng: self.rng.state_words(),
            engine_rng,
            sched_cursor,
        }
    }

    fn from_state(st: &TrainState) -> Result<GTrainer> {
        ensure!(
            st.variant == GOLDEN_VARIANT,
            "state is for variant {:?}, not the golden model",
            st.variant
        );
        let f32s = |ts: &[HostTensor]| -> Result<Vec<f32>> {
            ensure!(ts.len() == 1, "golden trainer state holds one tensor per slot");
            Ok(ts[0].f32s()?.to_vec())
        };
        Ok(GTrainer {
            step: st.step,
            params: f32s(&st.params)?,
            m: f32s(&st.opt_m)?,
            v: f32s(&st.opt_v)?,
            samples: st.samples_total,
            tokens: st.tokens_total,
            rng: Rng::from_state_words(st.rng),
        })
    }
}

/// Namespace for running golden pipelines (see module docs).
pub struct GoldenPipeline;

struct Golden<'a> {
    cfg: &'a GoldenCfg,
    pert: &'a Perturbation,
    actors: BTreeMap<usize, Vec<GSeq>>,
    next_actor_id: usize,
    hub: MigrationHub,
    pending: Vec<GSeq>,
    scheduler: Box<dyn Scheduler>,
    /// the "engine RNG": draws each fresh sequence's target length; its
    /// cursor is what PRLCKPT3 carries as `engine_rng`
    admission_rng: Rng,
    /// the scheduler admission cursor: sequences ever admitted (== the
    /// next local sequence id); PRLCKPT3's `sched_cursor`
    next_uid: u64,
    group_ctr: u64,
    group_fill: usize,
    /// incomplete groups: gid -> (finished members, token sum)
    gdone: BTreeMap<u64, (usize, u64)>,
    /// completed groups awaiting the trainer: (gid, token sum)
    inbox: VecDeque<(u64, u64)>,
    trainer: GTrainer,
    version: u64,
    tick: u64,
    next_chaos: usize,
    next_preempt: usize,
    log: EventLog,
    stats: GoldenStats,
    /// guardrail rollbacks still allowed (counts down from the budget)
    rollbacks_left: usize,
    /// chaos-schedule indices whose guardrail trip already fired. A
    /// rollback restores `next_chaos` from the checkpoint, so the replay
    /// re-walks the schedule — this set (deliberately *not* part of the
    /// restored image) is what keeps the causing trip from refiring.
    tripped: BTreeSet<usize>,
    /// inside a control-plane pause window (admission closed, everything
    /// parked in the hub)
    paused: bool,
    /// fail-safe drain: nothing new admitted, live work runs to finish
    draining: bool,
    /// paged-layout allocator shadow (None under the dense layout)
    kv: Option<GoldenKv>,
}

impl GoldenPipeline {
    /// Run to completion under a perturbation schedule.
    pub fn run(cfg: &GoldenCfg, pert: &Perturbation) -> Result<GoldenRun> {
        let mut g = Golden::fresh(cfg, pert);
        g.log.record(DigestEvent::WeightPublish { version: g.version });
        g.run_loop(None)
    }

    /// Run until the checkpoint for step `stop_after` has landed, then
    /// stop dead — the in-memory pipeline state is discarded, modeling a
    /// process kill *at* a checkpoint boundary. Resume with
    /// [`GoldenPipeline::resume`].
    pub fn run_until_checkpoint(
        cfg: &GoldenCfg,
        pert: &Perturbation,
        stop_after: u64,
    ) -> Result<GoldenRun> {
        ensure!(
            cfg.checkpoint_every > 0 && cfg.dir.is_some(),
            "run_until_checkpoint needs checkpointing enabled"
        );
        ensure!(
            stop_after >= cfg.checkpoint_every && stop_after % cfg.checkpoint_every == 0,
            "stop_after ({stop_after}) must land on the checkpoint cadence ({})",
            cfg.checkpoint_every
        );
        let mut g = Golden::fresh(cfg, pert);
        g.log.record(DigestEvent::WeightPublish { version: g.version });
        g.run_loop(Some(stop_after))
    }

    /// Resume a killed run from its checkpoint directory: the `PRLCKPT3`
    /// state restores the trainer trajectory, the engine sampling-RNG
    /// cursor, and the scheduler admission cursor; the aux sidecar
    /// restores the digest continuation, the group/inbox bookkeeping and
    /// every in-flight sequence (as wire-form `PRLSNAP1` bytes that
    /// re-enter through the migration hub). The resumed run finishes
    /// with the same [`RunDigest`] as an uninterrupted one.
    pub fn resume(cfg: &GoldenCfg, pert: &Perturbation) -> Result<GoldenRun> {
        let dir = cfg.dir.as_ref().context("resume needs GoldenCfg::dir")?;
        let st = TrainState::load_latest(dir).context("loading golden resume state")?;
        ensure!(
            st.engine_rng != [0u64; 4],
            "state carries no generation cursors (PRLCKPT2-era?) — a zero PCG \
             cursor is degenerate and cannot continue the sampling stream"
        );
        let aux = read_aux(dir, st.step).context("loading golden aux sidecar")?;
        let mut g = Golden::fresh(cfg, pert);
        g.trainer = GTrainer::from_state(&st)?;
        g.admission_rng = Rng::from_state_words(st.engine_rng);
        g.next_uid = st.sched_cursor;
        g.version = aux.version;
        g.tick = aux.tick;
        g.group_ctr = aux.group_ctr;
        g.group_fill = aux.group_fill as usize;
        g.next_chaos = aux.fired_chaos as usize;
        g.next_preempt = aux.fired_preempts as usize;
        g.inbox = aux.inbox;
        g.gdone = aux.gdone;
        for bytes in aux.snaps {
            g.hub.deposit_raw(bytes);
        }
        g.log = EventLog::resumed(RunDigest { hash: aux.hash, events: aux.events });
        // a checkpoint cut inside a pause window restores parked: the
        // in-flight sequences are already in the hub, so the resumed run
        // must not re-park — only reopen admission when the window ends
        g.paused = pert.paused_at(aux.tick);
        g.run_loop(None)
    }
}

impl<'a> Golden<'a> {
    fn fresh(cfg: &'a GoldenCfg, pert: &'a Perturbation) -> Golden<'a> {
        assert!(cfg.steps > 0 && cfg.groups_per_step > 0 && cfg.group_size > 0);
        assert!(cfg.n_actors > 0 && cfg.live_target > 0 && cfg.max_new > 0 && cfg.vocab > 1);
        Golden {
            cfg,
            pert,
            actors: (0..cfg.n_actors).map(|id| (id, Vec::new())).collect(),
            next_actor_id: cfg.n_actors,
            hub: MigrationHub::new(),
            pending: Vec::new(),
            scheduler: cfg.sched.build_with_preempt(cfg.preempt),
            admission_rng: Rng::with_stream(cfg.seed, 0xad31_5510),
            next_uid: 0,
            group_ctr: 0,
            group_fill: 0,
            gdone: BTreeMap::new(),
            inbox: VecDeque::new(),
            trainer: GTrainer::new(cfg.seed),
            version: 1,
            tick: 0,
            next_chaos: 0,
            next_preempt: 0,
            log: EventLog::new(),
            stats: GoldenStats::default(),
            rollbacks_left: cfg.rollback_budget,
            tripped: BTreeSet::new(),
            paused: false,
            draining: false,
            kv: GoldenKv::build(cfg),
        }
    }

    fn live_count(&self) -> usize {
        self.actors.values().map(|v| v.len()).sum()
    }

    fn run_loop(mut self, stop_after: Option<u64>) -> Result<GoldenRun> {
        // a resume may land mid-drain (the uninterrupted run kept
        // consuming ready batches right after the checkpoint): finish the
        // trainer work before the next generation round
        if self.drain_trainer(stop_after)? {
            return Ok(self.finish(stop_after));
        }
        let deadline = self.tick + self.cfg.steps * 1000 + 1000;
        while self.trainer.step < self.cfg.steps {
            ensure!(
                self.tick < deadline,
                "golden run stopped making progress (step {} of {})",
                self.trainer.step,
                self.cfg.steps
            );
            self.tick += 1;
            self.stats.ticks += 1;
            if let Some(kv) = &self.kv {
                kv.alloc
                    .check_invariants()
                    .expect("golden kv shadow broke block conservation");
                self.stats.kv_peak_blocks =
                    self.stats.kv_peak_blocks.max(kv.alloc.held_blocks() as u64);
            }
            // control-plane pause windows: on entry every in-flight
            // sequence parks into the hub with its RNG cursor; while
            // paused nothing admits or generates (the trainer stays idle
            // too — the previous tick's drain already consumed every
            // ready batch), so the window is a pure time shift
            let in_pause = self.pert.paused_at(self.tick);
            if in_pause && !self.paused {
                self.paused = true;
                self.park_all();
            } else if !in_pause && self.paused {
                self.paused = false;
            }
            // admission first, perturbations second, then a re-admission
            // pass: kills and preemptions always strike a *full* pool (so
            // every kill provably moves live sequences — the hand-off
            // machinery is exercised on every seed, not just lucky ones)
            // and their deposits re-seat within the same tick, which is
            // what keeps perturbations content-invariant
            if !self.paused {
                self.admit()?;
            }
            self.fire_chaos()?;
            if self.trainer.step >= self.cfg.steps {
                break; // a rollback's replay drain finished the run
            }
            self.fire_preempts();
            if !self.paused {
                self.admit()?;
                self.generate();
            }
            if self.drain_trainer(stop_after)? {
                break;
            }
            if self.draining
                && self.live_count() == 0
                && self.pending.is_empty()
                && self.hub.depth() == 0
            {
                break; // fail-safe drain complete: nothing left in flight
            }
        }
        Ok(self.finish(stop_after))
    }

    fn finish(mut self, stop_after: Option<u64>) -> GoldenRun {
        if let Some(kv) = &self.kv {
            kv.alloc.check_invariants().expect("golden kv shadow ends conserving blocks");
            self.stats.kv_cow_forks = kv.alloc.cow_forks();
        }
        self.stats.corrupt_rejected = self.hub.corrupt_rejected();
        self.hub.discard_all();
        self.stats.hub_deposited = self.hub.deposited();
        self.stats.hub_claimed = self.hub.claimed();
        self.stats.hub_discarded = self.hub.discarded();
        let stopped = stop_after
            .filter(|&k| self.trainer.step >= k && self.trainer.step < self.cfg.steps);
        GoldenRun {
            steps_done: self.trainer.step,
            stats: self.stats,
            stopped_at_checkpoint: stopped,
            drained: self.draining,
            log: self.log,
        }
    }

    // ---- perturbations ----

    fn fire_chaos(&mut self) -> Result<()> {
        // copy the &'a reference out so the schedule borrow is tied to
        // the perturbation's lifetime, not to &mut self
        let pert: &Perturbation = self.pert;
        let Some(schedule) = &pert.chaos else { return Ok(()) };
        while self.next_chaos < schedule.events.len()
            && self.version > schedule.events[self.next_chaos].at_step
        {
            let ev = schedule.events[self.next_chaos];
            self.next_chaos += 1;
            match ev.kind {
                // a slow kill's latency has no logical-time meaning here:
                // both resolve to "the busiest live shard dies, its
                // sequences travel as bytes through the hub"
                ChaosKind::KillActor | ChaosKind::SlowKillActor { .. } => {
                    self.kill_busiest();
                    if self.actors.is_empty() {
                        self.add_actor();
                    }
                }
                ChaosKind::RestartActor => {
                    self.kill_busiest();
                    self.add_actor();
                }
                ChaosKind::AddActor => {
                    if self.actors.len() < self.cfg.n_actors + 4 {
                        self.add_actor();
                    }
                }
                ChaosKind::RemoveActor => {
                    if self.actors.len() > 1 {
                        self.kill_highest();
                    }
                }
                // transport latency does not exist on logical time; the
                // digest claim is precisely that *content* is
                // latency-invariant
                ChaosKind::BusDelay { .. } | ChaosKind::BusHeal | ChaosKind::TopicStall { .. } => {}
                ChaosKind::CorruptSnapshot => {
                    // byzantine bytes enter the same hub the real deposits
                    // use; the claim path must reject them without
                    // perturbing anything digest-visible
                    self.hub.deposit_raw(corrupt_snapshot_bytes(ev.at_step));
                }
                ChaosKind::KillTrainer => self.trainer_failover()?,
                ChaosKind::GuardrailTrip => {
                    // a rollback rewinds next_chaos, so the replay walks
                    // this index again — the tripped set (not part of the
                    // restored image) keeps the causing trip from refiring
                    // without checkpoint wiring a trip is a no-op, like
                    // an unwired KillTrainer
                    let idx = self.next_chaos - 1;
                    if self.tripped.insert(idx) && self.cfg.dir.is_some() {
                        self.guardrail_trip()?;
                    }
                }
            }
        }
        Ok(())
    }

    /// A guardrail trip: roll back to the latest checkpoint — the exact
    /// restore [`GoldenPipeline::resume`] performs, in-process — or, when
    /// the rollback budget is exhausted or there is nothing to roll back
    /// to, fall through to the fail-safe drain.
    ///
    /// The restore discards every in-flight sequence (hub books stay
    /// balanced: the depth is *discarded*, never leaked), rewinds the
    /// digest to the checkpoint's continuation, and replays. Replay is
    /// deterministic from the restored cursors, so the run's final digest
    /// equals that of a run in which the trip never fired — rollback is a
    /// pure retry, which is what the conformance tests assert.
    fn guardrail_trip(&mut self) -> Result<()> {
        self.stats.guardrail_trips += 1;
        if self.rollbacks_left == 0 {
            return self.fail_safe();
        }
        let Some(dir) = self.cfg.dir.clone() else { return self.fail_safe() };
        let Ok(st) = TrainState::load_latest(&dir) else {
            return self.fail_safe(); // tripped before the first checkpoint
        };
        if st.engine_rng == [0u64; 4] {
            return self.fail_safe(); // degenerate cursors cannot replay
        }
        let aux = read_aux(&dir, st.step).context("loading rollback aux sidecar")?;
        self.rollbacks_left -= 1;
        self.stats.rollbacks += 1;
        // discard in-flight work and restore the checkpoint image, field
        // for field what resume() does after Golden::fresh
        self.actors = (0..self.cfg.n_actors).map(|id| (id, Vec::new())).collect();
        self.next_actor_id = self.cfg.n_actors;
        self.pending.clear();
        self.hub.discard_all();
        self.scheduler = self.cfg.sched.build_with_preempt(self.cfg.preempt);
        self.trainer = GTrainer::from_state(&st)?;
        self.admission_rng = Rng::from_state_words(st.engine_rng);
        self.next_uid = st.sched_cursor;
        self.version = aux.version;
        self.tick = aux.tick;
        self.group_ctr = aux.group_ctr;
        self.group_fill = aux.group_fill as usize;
        self.next_chaos = aux.fired_chaos as usize;
        self.next_preempt = aux.fired_preempts as usize;
        self.inbox = aux.inbox;
        self.gdone = aux.gdone;
        for bytes in aux.snaps {
            self.hub.deposit_raw(bytes);
        }
        self.log = EventLog::resumed(RunDigest { hash: aux.hash, events: aux.events });
        self.paused = self.pert.paused_at(self.tick);
        // a rollback is a process restart: the device KV died with it, so
        // the paged shadow starts empty (claims re-admit through seat)
        self.kv = GoldenKv::build(self.cfg);
        // the resume() twin finishes the checkpoint tick's trainer drain
        // before its first generation round — replay must match its order
        self.drain_trainer(None)?;
        Ok(())
    }

    /// Fail-safe: stop admitting, let live sequences finish, then stop.
    fn fail_safe(&mut self) -> Result<()> {
        if !self.draining {
            self.draining = true;
            self.stats.failsafe_drains += 1;
        }
        Ok(())
    }

    /// Pause entry: park every in-flight sequence (live and pending) into
    /// the migration hub as wire-form bytes, in canonical id order. The
    /// cursors travel in the snapshots, so reopening admission resumes
    /// the exact streams.
    fn park_all(&mut self) {
        let mut all: Vec<GSeq> = Vec::new();
        for seqs in self.actors.values_mut() {
            all.append(seqs);
        }
        all.append(&mut self.pending);
        all.sort_by_key(|s| s.uid);
        self.stats.pauses += 1;
        self.stats.parked += all.len() as u64;
        for s in &all {
            if let Some(kv) = &mut self.kv {
                // pending sequences were never seated, so only the live
                // ones hold blocks — release is keyed by uid either way
                if kv.alloc.capacity_tokens(s.uid).is_some() {
                    kv.release(s.uid);
                }
            }
            self.hub.deposit_raw(s.to_snapshot().to_bytes());
        }
    }

    /// In-process trainer failover: only the trainer restarts — from the
    /// latest manifest state — while generation keeps its live state.
    /// With a checkpoint every step the restored trajectory is the
    /// current one bit-for-bit, which is what the failover-equivalence
    /// test asserts through the digest.
    fn trainer_failover(&mut self) -> Result<()> {
        self.trainer = match &self.cfg.dir {
            Some(dir) => match TrainState::load_latest(dir) {
                Ok(st) => GTrainer::from_state(&st)?,
                // killed before the first checkpoint: restart from the
                // initial (seed-derived) state, like a cold trainer boot
                Err(_) => GTrainer::new(self.cfg.seed),
            },
            None => return Ok(()), // no failover wiring: event is a no-op
        };
        self.stats.trainer_failovers += 1;
        Ok(())
    }

    fn fire_preempts(&mut self) {
        while self.next_preempt < self.pert.preempt_ticks.len()
            && self.pert.preempt_ticks[self.next_preempt] <= self.tick
        {
            self.next_preempt += 1;
            if self.live_count() <= 1 {
                continue; // never park the last live sequence
            }
            // the real victim rule picks; the park travels the wire-form
            // snapshot path and re-enters through admission this tick
            let mut where_of: Vec<(usize, usize)> = Vec::new();
            let mut views: Vec<SeqView> = Vec::new();
            for (&id, seqs) in &self.actors {
                for (i, s) in seqs.iter().enumerate() {
                    where_of.push((id, i));
                    views.push(s.view(self.cfg.kv_block_size));
                }
            }
            let Some(vi) = self.scheduler.pick_victim(&views, 0) else { continue };
            let (aid, idx) = where_of[vi];
            let victim = self.actors.get_mut(&aid).expect("victim shard live").remove(idx);
            if let Some(kv) = &mut self.kv {
                kv.release(victim.uid);
            }
            self.hub.deposit_raw(victim.to_snapshot().to_bytes());
            self.stats.preemptions += 1;
        }
    }

    /// Kill victim: the busiest shard (most live sequences, lowest id on
    /// ties). Deterministic, and — because kills fire against a full pool
    /// — guaranteed to have work in flight, so every kill exercises the
    /// serialize → hub → decode → resume path.
    fn kill_busiest(&mut self) {
        let victim = self
            .actors
            .iter()
            .max_by_key(|(id, v)| (v.len(), std::cmp::Reverse(**id)))
            .map(|(id, _)| *id);
        if let Some(id) = victim {
            self.kill_actor(id);
        }
    }

    fn kill_highest(&mut self) {
        if let Some(&id) = self.actors.keys().next_back() {
            self.kill_actor(id);
        }
    }

    /// A killed shard's in-flight sequences cross the "process boundary"
    /// as wire-form `PRLSNAP1` bytes — so every kill exercises the full
    /// serialize → hub → decode → resume machinery, not a shortcut.
    fn kill_actor(&mut self, id: usize) {
        let Some(mut seqs) = self.actors.remove(&id) else { return };
        seqs.sort_by_key(|s| s.uid);
        for s in seqs {
            if let Some(kv) = &mut self.kv {
                kv.release(s.uid);
            }
            self.hub.deposit_raw(s.to_snapshot().to_bytes());
        }
    }

    fn add_actor(&mut self) {
        let id = self.next_actor_id;
        self.next_actor_id += 1;
        self.actors.insert(id, Vec::new());
    }

    // ---- admission ----

    /// Seat a sequence on the least-loaded shard (lowest id on ties).
    /// Placement is canonicalized out of the digest, so this rule only
    /// has to be deterministic, not clever.
    fn seat(&mut self, seq: GSeq) {
        if let Some(kv) = &mut self.kv {
            kv.seat(&seq);
        }
        // chunked-prefill dispatch shadow: seating force-feeds the
        // sequence's existing stream — the 2-token prompt for a fresh
        // admission, BOS + prompt + salvaged prefix for a re-seated
        // snapshot. W-wide chunks cover it in ceil(fed / W) dispatches.
        // Value-neutral: nothing below logs a digest event off this.
        let w = self.cfg.prefill_chunk.max(1);
        let fed = if seq.toks.is_empty() { 2 } else { 1 + seq.toks.len() };
        let disp = fed.div_ceil(w) as u64;
        self.stats.prefill_dispatches += disp;
        self.stats.forced_steps_saved += fed as u64 - disp;
        let id = self
            .actors
            .iter()
            .min_by_key(|(id, v)| (v.len(), **id))
            .map(|(id, _)| *id)
            .expect("pool never empty");
        self.actors.get_mut(&id).expect("chosen shard live").push(seq);
    }

    fn admit(&mut self) -> Result<()> {
        // portable arrivals first: claims decode the wire bytes (corrupt
        // deposits are rejected inside the hub with the books balanced)
        let live = self.live_count();
        let need = self.cfg.live_target.saturating_sub(live + self.pending.len());
        if need > 0 {
            for snap in self.hub.claim(need) {
                self.pending.push(GSeq::from_snapshot(&snap)?);
                self.stats.migrated += 1;
            }
        }
        // the real admission policy orders the pending queue; fresh
        // prompts fill whatever capacity remains
        while self.live_count() < self.cfg.live_target {
            if self.pending.is_empty() {
                if self.draining {
                    break; // fail-safe drain: nothing new is admitted
                }
                let seq = self.fresh_seq();
                self.seat(seq);
                continue;
            }
            let views: Vec<SeqView> =
                self.pending.iter().map(|s| s.view(self.cfg.kv_block_size)).collect();
            let Some(idx) = self.scheduler.pick(&views, &|_| true) else {
                bail!("scheduler refused to admit with an always-open gate");
            };
            let seq = self.pending.remove(idx);
            self.seat(seq);
        }
        Ok(())
    }

    fn fresh_seq(&mut self) -> GSeq {
        if self.group_fill == 0 {
            self.group_ctr += 1;
        }
        let group = 1000 + self.group_ctr;
        self.group_fill = (self.group_fill + 1) % self.cfg.group_size;
        let uid = self.next_uid;
        self.next_uid += 1;
        let target = 1 + self.admission_rng.below(self.cfg.max_new);
        self.stats.fresh_admitted += 1;
        GSeq::fresh(self.cfg, uid, group, target)
    }

    // ---- generation ----

    fn generate(&mut self) {
        // canonical per-tick order: ascending sequence id, independent of
        // placement — a migrated sequence logs exactly where it would have
        let mut order: Vec<(u64, usize)> = self
            .actors
            .iter()
            .flat_map(|(&id, seqs)| seqs.iter().map(move |s| (s.uid, id)))
            .collect();
        order.sort_unstable();
        for (uid, aid) in order {
            let seqs = self.actors.get_mut(&aid).expect("shard live");
            let s = seqs.iter_mut().find(|s| s.uid == uid).expect("seq resident");
            let tok = s.rng.below(self.cfg.vocab) as i32;
            s.toks.push(tok);
            s.versions.push(self.version);
            let total = 2 + s.toks.len();
            if let Some(kv) = &mut self.kv {
                // the engine's growth check: back the new token with a
                // block, forking a shared prompt block on first
                // divergence
                kv.grow(uid, total);
            }
            self.log.record(DigestEvent::Token {
                seq: uid,
                index: (s.toks.len() - 1) as u32,
                tok,
                version: self.version,
            });
        }
        // finishes, in ascending-id order across all shards
        let mut done: Vec<GSeq> = Vec::new();
        for seqs in self.actors.values_mut() {
            let mut i = 0;
            while i < seqs.len() {
                if seqs[i].toks.len() >= seqs[i].target {
                    let s = seqs.remove(i);
                    if let Some(kv) = &mut self.kv {
                        kv.release(s.uid);
                    }
                    done.push(s);
                } else {
                    i += 1;
                }
            }
        }
        done.sort_by_key(|s| s.uid);
        for s in done {
            let entry = self.gdone.entry(s.group).or_insert((0, 0));
            entry.0 += 1;
            entry.1 += s.toks.len() as u64;
            if entry.0 == self.cfg.group_size {
                let tokens = entry.1;
                self.gdone.remove(&s.group);
                self.log.record(DigestEvent::GroupComplete { group: s.group, tokens });
                self.inbox.push_back((s.group, tokens));
            }
        }
    }

    // ---- trainer ----

    /// Consume every ready batch. Returns true when an injected
    /// checkpoint-boundary kill stopped the run.
    fn drain_trainer(&mut self, stop_after: Option<u64>) -> Result<bool> {
        while self.trainer.step < self.cfg.steps && self.inbox.len() >= self.cfg.groups_per_step {
            let batch: Vec<(u64, u64)> =
                self.inbox.drain(..self.cfg.groups_per_step).collect();
            self.trainer.update(&batch, self.cfg.group_size);
            self.log.record(DigestEvent::TrainerStep {
                step: self.trainer.step,
                param_hash: self.trainer.param_hash(),
            });
            self.log.record(DigestEvent::RngCursor { words: self.trainer.rng.state_words() });
            // publish cadence: every step at publish_every = 1 (pipeline),
            // every k-th step otherwise (periodic mode's bounded staleness)
            if self.trainer.step % self.cfg.publish_every.max(1) == 0 {
                self.version = self.trainer.step + 1;
                self.log.record(DigestEvent::WeightPublish { version: self.version });
            }
            if self.cfg.checkpoint_every > 0
                && self.trainer.step % self.cfg.checkpoint_every == 0
            {
                self.checkpoint()?;
                if stop_after == Some(self.trainer.step) {
                    return Ok(true); // the process dies here
                }
            }
        }
        Ok(false)
    }

    /// A checkpoint is the digest cut plus everything a resumed process
    /// needs: the `PRLCKPT3` state (trainer trajectory + engine RNG
    /// cursor + admission cursor) through the real manifest protocol,
    /// and an aux sidecar with the digest continuation, group/inbox
    /// bookkeeping and the in-flight sequences as `PRLSNAP1` bytes. The
    /// sidecar is fsynced *before* the manifest names its step — the
    /// same durability-before-visibility rule as the state file.
    fn checkpoint(&mut self) -> Result<()> {
        let dir = self.cfg.dir.as_ref().context("checkpointing needs GoldenCfg::dir")?;
        self.log.record(DigestEvent::CheckpointCut { step: self.trainer.step });
        self.write_aux(dir)?;
        let st = self.trainer.to_state(self.admission_rng.state_words(), self.next_uid);
        st.save_with_manifest(dir, 0)?;
        self.stats.checkpoints += 1;
        Ok(())
    }

    fn write_aux(&mut self, dir: &Path) -> Result<()> {
        let mut b: Vec<u8> = Vec::new();
        b.extend_from_slice(b"PRLGOLD1");
        let digest = self.log.digest();
        for x in [
            digest.hash,
            digest.events,
            self.version,
            self.tick,
            self.group_ctr,
            self.group_fill as u64,
            self.next_chaos as u64,
            self.next_preempt as u64,
        ] {
            b.extend_from_slice(&x.to_le_bytes());
        }
        b.extend_from_slice(&(self.inbox.len() as u32).to_le_bytes());
        for (gid, toks) in &self.inbox {
            b.extend_from_slice(&gid.to_le_bytes());
            b.extend_from_slice(&toks.to_le_bytes());
        }
        b.extend_from_slice(&(self.gdone.len() as u32).to_le_bytes());
        for (gid, (done, toks)) in &self.gdone {
            b.extend_from_slice(&gid.to_le_bytes());
            b.extend_from_slice(&(*done as u64).to_le_bytes());
            b.extend_from_slice(&toks.to_le_bytes());
        }
        // in-flight sequences in canonical id order: live, then pending,
        // then anything still queued in the hub (claims re-deposit below)
        let mut snaps: Vec<Vec<u8>> = Vec::new();
        let mut live: Vec<&GSeq> = self.actors.values().flatten().collect();
        live.sort_by_key(|s| s.uid);
        for s in live {
            snaps.push(s.to_snapshot().to_bytes());
        }
        let mut queued: Vec<&GSeq> = self.pending.iter().collect();
        queued.sort_by_key(|s| s.uid);
        for s in queued {
            snaps.push(s.to_snapshot().to_bytes());
        }
        for snap in self.hub.claim(usize::MAX) {
            let bytes = snap.to_bytes();
            self.hub.deposit_raw(bytes.clone());
            snaps.push(bytes);
        }
        b.extend_from_slice(&(snaps.len() as u32).to_le_bytes());
        for s in &snaps {
            b.extend_from_slice(&(s.len() as u32).to_le_bytes());
            b.extend_from_slice(s);
        }
        std::fs::create_dir_all(dir)?;
        let path = dir.join(aux_name(self.trainer.step));
        let mut f = std::fs::File::create(&path)?;
        f.write_all(&b)?;
        f.sync_all()?;
        Ok(())
    }
}

fn aux_name(step: u64) -> String {
    format!("step{step:05}.aux")
}

struct Aux {
    hash: u64,
    events: u64,
    version: u64,
    tick: u64,
    group_ctr: u64,
    group_fill: u64,
    fired_chaos: u64,
    fired_preempts: u64,
    inbox: VecDeque<(u64, u64)>,
    gdone: BTreeMap<u64, (usize, u64)>,
    snaps: Vec<Vec<u8>>,
}

fn aux_take<'b>(bytes: &'b [u8], at: &mut usize, n: usize) -> Result<&'b [u8]> {
    ensure!(*at + n <= bytes.len(), "aux sidecar truncated at offset {at}");
    let s = &bytes[*at..*at + n];
    *at += n;
    Ok(s)
}

fn aux_u64(bytes: &[u8], at: &mut usize) -> Result<u64> {
    Ok(u64::from_le_bytes(aux_take(bytes, at, 8)?.try_into().expect("8 bytes")))
}

fn aux_u32(bytes: &[u8], at: &mut usize) -> Result<u32> {
    Ok(u32::from_le_bytes(aux_take(bytes, at, 4)?.try_into().expect("4 bytes")))
}

fn read_aux(dir: &Path, step: u64) -> Result<Aux> {
    let path = dir.join(aux_name(step));
    let bytes = std::fs::read(&path).with_context(|| format!("reading {path:?}"))?;
    let b = bytes.as_slice();
    let mut at = 0usize;
    ensure!(
        aux_take(b, &mut at, 8)? == b"PRLGOLD1",
        "{path:?} is not a golden aux sidecar"
    );
    let hash = aux_u64(b, &mut at)?;
    let events = aux_u64(b, &mut at)?;
    let version = aux_u64(b, &mut at)?;
    let tick = aux_u64(b, &mut at)?;
    let group_ctr = aux_u64(b, &mut at)?;
    let group_fill = aux_u64(b, &mut at)?;
    let fired_chaos = aux_u64(b, &mut at)?;
    let fired_preempts = aux_u64(b, &mut at)?;
    let n_inbox = aux_u32(b, &mut at)? as usize;
    let mut inbox = VecDeque::with_capacity(n_inbox);
    for _ in 0..n_inbox {
        let gid = aux_u64(b, &mut at)?;
        let toks = aux_u64(b, &mut at)?;
        inbox.push_back((gid, toks));
    }
    let n_gdone = aux_u32(b, &mut at)? as usize;
    let mut gdone = BTreeMap::new();
    for _ in 0..n_gdone {
        let gid = aux_u64(b, &mut at)?;
        let done = aux_u64(b, &mut at)? as usize;
        let toks = aux_u64(b, &mut at)?;
        gdone.insert(gid, (done, toks));
    }
    let n_snaps = aux_u32(b, &mut at)? as usize;
    let mut snaps = Vec::with_capacity(n_snaps);
    for _ in 0..n_snaps {
        let len = aux_u32(b, &mut at)? as usize;
        snaps.push(aux_take(b, &mut at, len)?.to_vec());
    }
    ensure!(at == bytes.len(), "aux sidecar has trailing bytes");
    Ok(Aux {
        hash,
        events,
        version,
        tick,
        group_ctr,
        group_fill,
        fired_chaos,
        fired_preempts,
        inbox,
        gdone,
        snaps,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_encodings_are_distinct_and_stable() {
        let evs = [
            DigestEvent::Token { seq: 1, index: 0, tok: 5, version: 1 },
            DigestEvent::Token { seq: 1, index: 0, tok: 5, version: 2 },
            DigestEvent::GroupComplete { group: 1, tokens: 5 },
            DigestEvent::TrainerStep { step: 1, param_hash: 5 },
            DigestEvent::WeightPublish { version: 1 },
            DigestEvent::RngCursor { words: [1, 0, 5, 0] },
            DigestEvent::CheckpointCut { step: 1 },
        ];
        let mut seen = Vec::new();
        for ev in evs {
            let mut log = EventLog::new();
            log.record(ev);
            let d = log.digest();
            assert!(!seen.contains(&d.hash), "encoding collision for {ev:?}");
            seen.push(d.hash);
        }
    }

    #[test]
    fn event_log_resume_continues_the_hash() {
        let evs = [
            DigestEvent::WeightPublish { version: 1 },
            DigestEvent::Token { seq: 0, index: 0, tok: 9, version: 1 },
            DigestEvent::TrainerStep { step: 1, param_hash: 42 },
        ];
        let mut whole = EventLog::new();
        for ev in evs {
            whole.record(ev);
        }
        let mut first = EventLog::new();
        first.record(evs[0]);
        first.record(evs[1]);
        let mut second = EventLog::resumed(first.digest());
        second.record(evs[2]);
        assert_eq!(second.digest(), whole.digest(), "split log folds to the same digest");
        assert_eq!(second.base(), 2);
    }

    #[test]
    fn explain_divergence_names_the_first_mismatch() {
        let mut a = EventLog::new();
        let mut b = EventLog::new();
        a.record(DigestEvent::WeightPublish { version: 1 });
        b.record(DigestEvent::WeightPublish { version: 1 });
        a.record(DigestEvent::Token { seq: 3, index: 0, tok: 7, version: 1 });
        b.record(DigestEvent::Token { seq: 3, index: 0, tok: 8, version: 1 });
        let why = explain_divergence(&a, &[&b]);
        assert!(why.contains("event 1"), "{why}");
        assert!(why.contains("tok: 7") && why.contains("tok: 8"), "{why}");
    }

    #[test]
    fn golden_run_is_seed_deterministic() {
        let cfg = GoldenCfg::new(0x90_1d_e2);
        let a = GoldenPipeline::run(&cfg, &Perturbation::none()).unwrap();
        let b = GoldenPipeline::run(&cfg, &Perturbation::none()).unwrap();
        assert_eq!(a.log.digest(), b.log.digest(), "same seed, same digest");
        assert_eq!(a.steps_done, cfg.steps);
        assert!(a.stats.fresh_admitted > 0 && a.stats.ticks > 0);

        let other = GoldenCfg::new(0x90_1d_e3);
        let c = GoldenPipeline::run(&other, &Perturbation::none()).unwrap();
        assert_ne!(a.log.digest(), c.log.digest(), "different seed, different digest");
    }

    #[test]
    fn digest_is_sensitive_to_version_tags() {
        // the same tokens trained under a different publish cadence must
        // not alias: version tags are part of every Token event
        let mut cfg = GoldenCfg::new(7);
        let a = GoldenPipeline::run(&cfg, &Perturbation::none()).unwrap();
        cfg.groups_per_step = 3; // later publishes => different tags
        let b = GoldenPipeline::run(&cfg, &Perturbation::none()).unwrap();
        assert_ne!(a.log.digest(), b.log.digest());
    }

    #[test]
    fn periodic_publish_cadence_is_digest_visible() {
        // publish_every > 1 keeps tokens on stale version tags between
        // publishes — a different run, not an alias of the pipeline one,
        // and still seed-deterministic
        let mut cfg = GoldenCfg::new(0x9e10);
        let base = GoldenPipeline::run(&cfg, &Perturbation::none()).unwrap();
        cfg.publish_every = 3;
        let per = GoldenPipeline::run(&cfg, &Perturbation::none()).unwrap();
        assert_ne!(base.log.digest(), per.log.digest(), "stale version tags must show");
        let again = GoldenPipeline::run(&cfg, &Perturbation::none()).unwrap();
        assert_eq!(per.log.digest(), again.log.digest());
    }

    #[test]
    fn kill_and_migrate_is_digest_equivalent() {
        // the in-module smoke test of the tentpole claim (the full
        // matrix lives in tests/determinism.rs): a mid-run shard kill
        // whose sequences travel as bytes through the hub changes nothing
        let cfg = GoldenCfg::new(0xbee5);
        let base = GoldenPipeline::run(&cfg, &Perturbation::none()).unwrap();
        let pert = Perturbation::chaos(ChaosSchedule::kill_then_restart(2, 4));
        let run = GoldenPipeline::run(&cfg, &pert).unwrap();
        assert!(run.stats.migrated > 0, "the kill had sequences in flight");
        assert_eq!(
            base.log.digest(),
            run.log.digest(),
            "{}",
            explain_divergence(&base.log, &[&run.log])
        );
    }

    #[test]
    fn corrupt_deposits_never_perturb_the_digest() {
        let cfg = GoldenCfg::new(0x0bad);
        let base = GoldenPipeline::run(&cfg, &Perturbation::none()).unwrap();
        let pert = Perturbation::chaos(ChaosSchedule::byzantine(1, 4));
        let run = GoldenPipeline::run(&cfg, &pert).unwrap();
        assert_eq!(run.stats.corrupt_rejected, 4, "all poison rejected at claim");
        assert_eq!(base.log.digest(), run.log.digest());
    }

    #[test]
    fn pause_window_is_digest_invariant() {
        // a control-plane pause is a uniform time shift: everything parks
        // into the hub with its cursors, admission closes, and on resume
        // the event stream continues exactly where it left off
        let cfg = GoldenCfg::new(0x9a05e);
        let base = GoldenPipeline::run(&cfg, &Perturbation::none()).unwrap();
        let pert = Perturbation::pauses(vec![(4, 10), (14, 17)]);
        let run = GoldenPipeline::run(&cfg, &pert).unwrap();
        assert_eq!(run.stats.pauses, 2, "both pause windows entered");
        assert!(run.stats.parked > 0, "the pauses had sequences in flight");
        assert_eq!(run.steps_done, cfg.steps);
        assert_eq!(
            run.stats.hub_deposited,
            run.stats.hub_claimed + run.stats.hub_discarded,
            "pause parking must close the conservation books"
        );
        assert_eq!(
            base.log.digest(),
            run.log.digest(),
            "{}",
            explain_divergence(&base.log, &[&run.log])
        );
    }

    #[test]
    fn guardrail_rollback_is_a_pure_retry() {
        // trip → roll back to the latest checkpoint → replay: the final
        // digest equals the same run with the trip never firing, because
        // the restore is exactly the resume() image and replay is
        // deterministic from the restored cursors
        let tmp = std::env::temp_dir().join(format!("prl_gold_rb_{}", std::process::id()));
        let (dir_a, dir_b) = (tmp.join("base"), tmp.join("trip"));
        std::fs::remove_dir_all(&tmp).ok();
        let mut cfg = GoldenCfg::new(0x6a8d);
        cfg.steps = 8;
        cfg.checkpoint_every = 2;
        cfg.dir = Some(dir_a);
        let base = GoldenPipeline::run(&cfg, &Perturbation::none()).unwrap();
        cfg.dir = Some(dir_b);
        let pert = Perturbation::chaos(ChaosSchedule::guardrail_trip(4));
        let run = GoldenPipeline::run(&cfg, &pert).unwrap();
        assert_eq!(run.stats.guardrail_trips, 1);
        assert_eq!(run.stats.rollbacks, 1, "the trip resolved by rolling back");
        assert!(!run.drained, "budget left: no fail-safe drain");
        assert_eq!(run.steps_done, cfg.steps);
        assert_eq!(
            base.log.digest(),
            run.log.digest(),
            "{}",
            explain_divergence(&base.log, &[&run.log])
        );
        std::fs::remove_dir_all(&tmp).ok();
    }

    #[test]
    fn trip_without_a_checkpoint_drains_fail_safe() {
        // checkpointing is wired but the trip fires before the first cut
        // lands: nothing to roll back to, so the run drains — admission
        // closes, live sequences finish, the books balance — and the
        // drained outcome is itself deterministic
        let tmp = std::env::temp_dir().join(format!("prl_gold_fs_{}", std::process::id()));
        std::fs::remove_dir_all(&tmp).ok();
        let mut cfg = GoldenCfg::new(0xd8a1);
        cfg.checkpoint_every = 4; // first cut at step 4 ...
        cfg.dir = Some(tmp.clone());
        let pert = Perturbation::chaos(ChaosSchedule::guardrail_trip(1)); // ... trip at version 2
        let run = GoldenPipeline::run(&cfg, &pert).unwrap();
        assert!(run.drained, "no checkpoint to roll back to: fail-safe drain");
        assert_eq!(run.stats.failsafe_drains, 1);
        assert_eq!(run.stats.rollbacks, 0);
        assert!(run.steps_done < cfg.steps, "the drain stopped the run early");
        assert_eq!(
            run.stats.hub_deposited,
            run.stats.hub_claimed + run.stats.hub_discarded,
            "the drain must close the conservation books"
        );
        std::fs::remove_dir_all(&tmp).ok();
        let again = GoldenPipeline::run(&cfg, &pert).unwrap();
        assert_eq!(run.log.digest(), again.log.digest(), "drained runs replay exactly");
        std::fs::remove_dir_all(&tmp).ok();
    }

    #[test]
    fn exhausted_rollback_budget_falls_through_to_drain() {
        use crate::testkit::chaos::ChaosEvent;
        let tmp = std::env::temp_dir().join(format!("prl_gold_budget_{}", std::process::id()));
        std::fs::remove_dir_all(&tmp).ok();
        let mut cfg = GoldenCfg::new(0xb4d6e7);
        cfg.steps = 8;
        cfg.checkpoint_every = 2;
        cfg.rollback_budget = 2;
        cfg.dir = Some(tmp.clone());
        let trips = ChaosSchedule {
            seed: 0,
            events: vec![
                ChaosEvent { at_step: 2, kind: ChaosKind::GuardrailTrip },
                ChaosEvent { at_step: 3, kind: ChaosKind::GuardrailTrip },
                ChaosEvent { at_step: 5, kind: ChaosKind::GuardrailTrip },
            ],
        };
        let run = GoldenPipeline::run(&cfg, &Perturbation::chaos(trips)).unwrap();
        assert_eq!(run.stats.guardrail_trips, 3, "each trip fires exactly once");
        assert_eq!(run.stats.rollbacks, 2, "the budget allows two rollbacks");
        assert_eq!(run.stats.failsafe_drains, 1, "the third trip drains");
        assert!(run.drained);
        std::fs::remove_dir_all(&tmp).ok();
    }

    #[test]
    fn aux_sidecar_roundtrips() {
        let dir = std::env::temp_dir().join(format!("prl_gold_aux_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let mut cfg = GoldenCfg::new(0xa0a0);
        cfg.steps = 4;
        cfg.checkpoint_every = 2;
        cfg.dir = Some(dir.clone());
        let run = GoldenPipeline::run(&cfg, &Perturbation::none()).unwrap();
        assert_eq!(run.stats.checkpoints, 2);
        let aux = read_aux(&dir, 4).unwrap();
        assert!(aux.events > 0 && aux.version == 5);
        for s in &aux.snaps {
            SeqSnapshot::from_bytes(s).expect("sidecar snapshots decode");
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
