//! Miniature property-testing kit (proptest is unavailable offline).
//!
//! `check` runs a property over `n` pseudo-random cases derived from a
//! base seed; on failure it reports the failing case seed so the exact
//! case can be replayed with `check_one`. Shrinking is approximated by
//! re-running the failing case at progressively smaller "size" hints.
//!
//! The [`chaos`] submodule extends the same replay-from-seed philosophy
//! to whole-pipeline failure injection: seeded, deterministic schedules
//! of actor kills / restarts / transport faults that the coordinator's
//! supervisor executes against a live run.

pub mod chaos;
pub mod golden;
pub mod synth;

pub use chaos::{ChaosEvent, ChaosKind, ChaosSchedule};
pub use golden::{DigestEvent, EventLog, RunDigest};

use crate::util::Rng;

/// Run a seeded test body and guarantee the replay seed reaches the
/// failure output. Chaos scenarios used to print their seed through the
/// supervisor's schedule banner — which only happens *after* the schedule
/// is materialized and a supervisor is running, so an assertion that
/// fired earlier (building the harness, pre-flight checks) or on a path
/// with no supervisor lost the one number needed to replay it. Every
/// seeded chaos/determinism test should wrap its body in this instead:
/// on panic the seed is printed unconditionally, then the panic resumes.
pub fn with_seed<T>(name: &str, seed: u64, body: impl FnOnce(u64) -> T) -> T {
    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| body(seed))) {
        Ok(v) => v,
        Err(payload) => {
            // seed 0 is the hand-written-scenario convention
            // (ChaosSchedule::{kill_then_restart, slow_kill, ...}): the
            // schedule is fully deterministic, nothing to re-derive
            if seed == 0 {
                eprintln!("REPLAY {name}: hand-written deterministic scenario (seed 0)");
            } else {
                eprintln!("REPLAY {name}: failing seed = {seed:#x} ({seed})");
            }
            std::panic::resume_unwind(payload);
        }
    }
}

/// Integration-test gate: true when a PJRT runtime + AOT artifacts are
/// present; otherwise prints a `SKIP <test>` line with the reason and
/// returns false so the test can bail early. See tier1.sh for how to
/// unlock the gated tests.
pub fn runtime_or_skip(test: &str) -> bool {
    if crate::runtime::runtime_available() {
        return true;
    }
    eprintln!(
        "SKIP {test}: PJRT runtime / AOT artifacts unavailable (env-gated, see tier1.sh)"
    );
    false
}

/// Size-aware case context handed to properties.
pub struct Case {
    pub rng: Rng,
    /// size hint in [1, max_size] — generators should scale with it
    pub size: usize,
}

impl Case {
    pub fn vec_f32(&mut self, max_len: usize, lo: f32, hi: f32) -> Vec<f32> {
        let len = self.rng.below(max_len.min(self.size.max(1)) + 1);
        (0..len).map(|_| lo + self.rng.f32() * (hi - lo)).collect()
    }

    pub fn vec_u64(&mut self, max_len: usize, hi: u64) -> Vec<u64> {
        let len = self.rng.below(max_len.min(self.size.max(1)) + 1);
        (0..len).map(|_| self.rng.below(hi as usize) as u64).collect()
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below(hi - lo + 1)
    }
}

/// Run `prop` over `n` cases. Panics with the failing seed on error.
pub fn check<F>(name: &str, n: usize, base_seed: u64, max_size: usize, prop: F)
where
    F: Fn(&mut Case) -> Result<(), String>,
{
    for i in 0..n {
        let case_seed = base_seed.wrapping_mul(0x9e37_79b9_7f4a_7c15).wrapping_add(i as u64);
        // grow size over the run, like proptest
        let size = 1 + (i * max_size) / n.max(1);
        if let Err(msg) = run_case(case_seed, size, &prop) {
            // "shrink": retry the same seed at smaller sizes to find the
            // smallest size that still fails
            let mut smallest = (size, msg.clone());
            let mut s = size / 2;
            while s >= 1 {
                if let Err(m) = run_case(case_seed, s, &prop) {
                    smallest = (s, m);
                    s /= 2;
                } else {
                    break;
                }
            }
            panic!(
                "property '{name}' failed (case {i}, seed {case_seed}, size {}): {}",
                smallest.0, smallest.1
            );
        }
    }
}

fn run_case<F>(seed: u64, size: usize, prop: &F) -> Result<(), String>
where
    F: Fn(&mut Case) -> Result<(), String>,
{
    let mut case = Case { rng: Rng::new(seed), size };
    prop(&mut case)
}

/// Replay a single case (debugging helper).
pub fn check_one<F>(seed: u64, size: usize, prop: F) -> Result<(), String>
where
    F: Fn(&mut Case) -> Result<(), String>,
{
    run_case(seed, size, &prop)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("reverse twice is identity", 50, 1, 64, |c| {
            let v = c.vec_f32(32, -1.0, 1.0);
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            if v == w {
                Ok(())
            } else {
                Err("mismatch".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_seed() {
        check("always fails", 5, 2, 8, |_| Err("nope".into()));
    }

    #[test]
    fn with_seed_passes_value_through() {
        let v = with_seed("unit", 42, |s| s * 2);
        assert_eq!(v, 84);
    }

    #[test]
    fn with_seed_reprints_seed_and_repanics() {
        let caught = std::panic::catch_unwind(|| {
            with_seed("unit", 7, |_| panic!("inner failure"));
        });
        assert!(caught.is_err(), "the original panic must propagate");
    }

    #[test]
    fn sizes_grow() {
        let max_seen = std::cell::Cell::new(0usize);
        check("observe sizes", 20, 3, 40, |c| {
            max_seen.set(max_seen.get().max(c.size));
            Ok(())
        });
        assert!(max_seen.get() > 20);
    }
}
