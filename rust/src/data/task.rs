//! Problem generation, chain-of-thought traces and the reward verifier.
//!
//! Format (char-level tokenized, alphabet in model/tokenizer.rs):
//!
//! ```text
//! prompt:      "q:47+85=\n"
//! completion:  "c:7+5=12\n"      (one mechanical CoT line per step)
//!              "c:4+8+1=13\n"
//!              "a:132\n"          (final answer line)
//!              <eos>
//! ```
//!
//! Reward (paper §5): 1.0 for a correct final answer, 0.0 otherwise, plus
//! a soft penalty as the generation approaches the max length budget.

use crate::util::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TaskKind {
    /// Multi-digit addition with column-carry CoT.
    Add,
    /// Subtraction (a >= b) with place-value decomposition CoT.
    Sub,
    /// a + b - c chains, reusing Add/Sub traces coarsely.
    Chain,
    /// single-digit × multi-digit multiplication via partial products.
    Mul,
    /// Digit-copy diagnostic (trivially learnable; sanity checks).
    Copy,
}

impl TaskKind {
    pub fn all() -> &'static [TaskKind] {
        &[TaskKind::Add, TaskKind::Sub, TaskKind::Chain, TaskKind::Mul, TaskKind::Copy]
    }

    pub fn name(&self) -> &'static str {
        match self {
            TaskKind::Add => "add",
            TaskKind::Sub => "sub",
            TaskKind::Chain => "chain",
            TaskKind::Mul => "mul",
            TaskKind::Copy => "copy",
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct Problem {
    pub kind: TaskKind,
    pub prompt: String,
    /// ground-truth final answer (the integer as text)
    pub answer: String,
    /// full worked trace (CoT lines + answer line), used for SFT
    pub trace: String,
    /// stable problem id (for grouping rollouts per prompt)
    pub id: u64,
}

impl Problem {
    /// prompt + trace — the supervised training text.
    pub fn sft_text(&self) -> String {
        format!("{}{}", self.prompt, self.trace)
    }
}

/// Deterministic problem generator.
#[derive(Debug, Clone)]
pub struct TaskGen {
    pub kinds: Vec<TaskKind>,
    /// max operand magnitude (e.g. 99 => up to 2-digit problems)
    pub max_operand: i64,
}

impl TaskGen {
    pub fn new(kinds: Vec<TaskKind>, max_operand: i64) -> Self {
        assert!(max_operand >= 9);
        TaskGen { kinds, max_operand }
    }

    pub fn curriculum_small() -> Self {
        TaskGen::new(vec![TaskKind::Add, TaskKind::Copy], 99)
    }

    pub fn curriculum_full() -> Self {
        TaskGen::new(TaskKind::all().to_vec(), 99)
    }

    /// Generate the problem with the given id (deterministic in id).
    pub fn problem(&self, id: u64) -> Problem {
        let mut rng = Rng::with_stream(id, 0x7a5b_1ed0);
        let kind = *rng.choice(&self.kinds);
        match kind {
            TaskKind::Add => self.gen_add(id, &mut rng),
            TaskKind::Sub => self.gen_sub(id, &mut rng),
            TaskKind::Chain => self.gen_chain(id, &mut rng),
            TaskKind::Mul => self.gen_mul(id, &mut rng),
            TaskKind::Copy => self.gen_copy(id, &mut rng),
        }
    }

    fn gen_add(&self, id: u64, rng: &mut Rng) -> Problem {
        let a = rng.range(1, self.max_operand);
        let b = rng.range(1, self.max_operand);
        let trace = add_trace(a, b);
        Problem {
            kind: TaskKind::Add,
            prompt: format!("q:{a}+{b}=\n"),
            answer: (a + b).to_string(),
            trace,
            id,
        }
    }

    fn gen_sub(&self, id: u64, rng: &mut Rng) -> Problem {
        let x = rng.range(1, self.max_operand);
        let y = rng.range(1, self.max_operand);
        let (a, b) = if x >= y { (x, y) } else { (y, x) };
        let trace = sub_trace(a, b);
        Problem {
            kind: TaskKind::Sub,
            prompt: format!("q:{a}-{b}=\n"),
            answer: (a - b).to_string(),
            trace,
            id,
        }
    }

    fn gen_chain(&self, id: u64, rng: &mut Rng) -> Problem {
        let a = rng.range(1, self.max_operand);
        let b = rng.range(1, self.max_operand);
        let c = rng.range(1, (a + b).min(self.max_operand));
        let s1 = a + b;
        let s2 = s1 - c;
        let trace = format!("c:{a}+{b}={s1}\nc:{s1}-{c}={s2}\na:{s2}\n");
        Problem {
            kind: TaskKind::Chain,
            prompt: format!("q:{a}+{b}-{c}=\n"),
            answer: s2.to_string(),
            trace,
            id,
        }
    }

    fn gen_mul(&self, id: u64, rng: &mut Rng) -> Problem {
        let a = rng.range(2, 9);
        let b = rng.range(2, self.max_operand);
        // partial products per digit place of b, then sum
        let db = digits_rev(b);
        let mut lines = String::new();
        let mut acc = 0i64;
        for (p, &d) in db.iter().enumerate() {
            if d == 0 {
                continue;
            }
            let part = a * d * 10i64.pow(p as u32);
            let next = acc + part;
            if acc == 0 {
                lines.push_str(&format!("c:{a}*{}={part}\n", d * 10i64.pow(p as u32)));
            } else {
                lines.push_str(&format!(
                    "c:{a}*{}={part}\nc:{acc}+{part}={next}\n",
                    d * 10i64.pow(p as u32)
                ));
            }
            acc = next;
        }
        lines.push_str(&format!("a:{}\n", a * b));
        Problem {
            kind: TaskKind::Mul,
            prompt: format!("q:{a}*{b}=\n"),
            answer: (a * b).to_string(),
            trace: lines,
            id,
        }
    }

    fn gen_copy(&self, id: u64, rng: &mut Rng) -> Problem {
        let a = rng.range(1, self.max_operand);
        Problem {
            kind: TaskKind::Copy,
            prompt: format!("q:copy {a}=\n"),
            answer: a.to_string(),
            trace: format!("a:{a}\n"),
            id,
        }
    }
}

/// Column-addition CoT: one line per digit column, carrying.
fn add_trace(a: i64, b: i64) -> String {
    let da = digits_rev(a);
    let db = digits_rev(b);
    let n = da.len().max(db.len());
    let mut carry = 0i64;
    let mut lines = String::new();
    for i in 0..n {
        let x = da.get(i).copied().unwrap_or(0);
        let y = db.get(i).copied().unwrap_or(0);
        let s = x + y + carry;
        if carry > 0 {
            lines.push_str(&format!("c:{x}+{y}+{carry}={s}\n"));
        } else {
            lines.push_str(&format!("c:{x}+{y}={s}\n"));
        }
        carry = s / 10;
    }
    lines.push_str(&format!("a:{}\n", a + b));
    lines
}

/// Place-value subtraction CoT: peel off b one digit-place at a time.
fn sub_trace(a: i64, b: i64) -> String {
    debug_assert!(a >= b);
    let mut lines = String::new();
    let mut cur = a;
    let db = digits_rev(b);
    for (p, &d) in db.iter().enumerate().rev() {
        if d == 0 {
            continue;
        }
        let step = d * 10i64.pow(p as u32);
        let next = cur - step;
        lines.push_str(&format!("c:{cur}-{step}={next}\n"));
        cur = next;
    }
    lines.push_str(&format!("a:{}\n", a - b));
    lines
}

fn digits_rev(mut x: i64) -> Vec<i64> {
    if x == 0 {
        return vec![0];
    }
    let mut out = Vec::new();
    while x > 0 {
        out.push(x % 10);
        x /= 10;
    }
    out
}

// ---------------------------------------------------------------------------
// reward
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct RewardCfg {
    pub correct: f32,
    pub incorrect: f32,
    /// fraction of the generation budget after which the soft length
    /// penalty starts (paper: "soft penalty ... close to max seq length")
    pub length_penalty_start: f32,
    /// max penalty magnitude at 100% of budget
    pub length_penalty_max: f32,
}

impl Default for RewardCfg {
    fn default() -> Self {
        RewardCfg {
            correct: 1.0,
            incorrect: 0.0,
            length_penalty_start: 0.85,
            length_penalty_max: 0.5,
        }
    }
}

impl RewardCfg {
    /// Compute the reward for a generated completion.
    ///
    /// `completion` is the decoded text after the prompt (EOS stripped);
    /// `gen_len` the number of generated tokens, `budget` the max allowed.
    pub fn reward(&self, problem: &Problem, completion: &str, gen_len: usize, budget: usize) -> f32 {
        let correct = extract_answer(completion)
            .map(|ans| ans == problem.answer)
            .unwrap_or(false);
        let base = if correct { self.correct } else { self.incorrect };
        base - self.length_penalty(gen_len, budget)
    }

    pub fn length_penalty(&self, gen_len: usize, budget: usize) -> f32 {
        let frac = gen_len as f32 / budget.max(1) as f32;
        if frac <= self.length_penalty_start {
            0.0
        } else {
            let over = (frac - self.length_penalty_start)
                / (1.0 - self.length_penalty_start);
            self.length_penalty_max * over.min(1.0)
        }
    }
}

/// Parse the final `a:<int>` line of a completion.
pub fn extract_answer(completion: &str) -> Option<String> {
    completion
        .lines()
        .rev()
        .find_map(|l| l.strip_prefix("a:"))
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty() && s.chars().all(|c| c.is_ascii_digit()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Tokenizer;

    #[test]
    fn deterministic_per_id() {
        let g = TaskGen::curriculum_full();
        assert_eq!(g.problem(42), g.problem(42));
        assert_ne!(g.problem(42), g.problem(43));
    }

    #[test]
    fn add_trace_is_correct_and_parsable() {
        let t = add_trace(47, 85);
        assert_eq!(t, "c:7+5=12\nc:4+8+1=13\na:132\n");
        assert_eq!(extract_answer(&t).unwrap(), "132");
    }

    #[test]
    fn sub_trace_ends_with_answer() {
        let t = sub_trace(85, 47);
        assert!(t.ends_with("a:38\n"), "{t}");
        assert_eq!(extract_answer(&t).unwrap(), "38");
    }

    #[test]
    fn traces_verify_for_many_ids() {
        let g = TaskGen::curriculum_full();
        let cfg = RewardCfg::default();
        for id in 0..500 {
            let p = g.problem(id);
            let r = cfg.reward(&p, &p.trace, 10, 100);
            assert_eq!(r, 1.0, "trace must earn full reward: {p:?}");
        }
    }

    #[test]
    fn wrong_answer_gets_zero() {
        let g = TaskGen::curriculum_full();
        let p = g.problem(7);
        let cfg = RewardCfg::default();
        assert_eq!(cfg.reward(&p, "a:99999999\n", 10, 100), 0.0);
        assert_eq!(cfg.reward(&p, "gibberish", 10, 100), 0.0);
    }

    #[test]
    fn length_penalty_kicks_in_smoothly() {
        let cfg = RewardCfg::default();
        assert_eq!(cfg.length_penalty(50, 100), 0.0);
        assert_eq!(cfg.length_penalty(85, 100), 0.0);
        let p90 = cfg.length_penalty(90, 100);
        let p100 = cfg.length_penalty(100, 100);
        assert!(p90 > 0.0 && p90 < p100);
        assert!((p100 - cfg.length_penalty_max).abs() < 1e-6);
    }

    #[test]
    fn all_texts_tokenizable() {
        let g = TaskGen::curriculum_full();
        let tk = Tokenizer::new();
        for id in 0..200 {
            let p = g.problem(id);
            tk.encode(&p.sft_text()).expect("trace must tokenize");
        }
    }

    #[test]
    fn extract_answer_takes_last_answer_line() {
        assert_eq!(extract_answer("a:1\nc:x\na:2\n").unwrap(), "2");
        assert_eq!(extract_answer("a: 42 \n").unwrap(), "42");
        assert!(extract_answer("a:\n").is_none());
        assert!(extract_answer("a:12x\n").is_none());
    }
}
