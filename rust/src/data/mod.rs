//! Synthetic arithmetic-reasoning tasks — the OpenReasoner-Zero stand-in
//! (DESIGN.md §2 substitution table).
//!
//! The paper trains a base model to emit long-form chain-of-thought for
//! math problems with a verifiable 0/1 answer reward plus a soft penalty
//! near the maximum sequence length (§5). This module reproduces that
//! task *shape* at CPU scale: deterministic problem generators with
//! mechanical chain-of-thought traces (for the SFT warmup that stands in
//! for base-model pretraining), a held-out eval split, and an exact-match
//! verifier with the same reward structure.

pub mod dataset;
pub mod task;

pub use dataset::{Dataset, Split};
pub use task::{Problem, RewardCfg, TaskGen, TaskKind};
