//! Train/eval problem pools with a deterministic held-out split.
//!
//! The paper trains on 17k OpenReasoner-Zero problems and evaluates on
//! MATH500/AIME24. Here the generator space is effectively unbounded, so
//! we carve a deterministic id-space split: ids hashing into the eval
//! residue class are *never* served for training, giving Table 1's
//! protocol (eval on problems the policy never saw) at any pool size.

use super::task::{Problem, TaskGen};
use crate::util::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    Train,
    Eval,
}

/// The eval split is ids ≡ 0 (mod EVAL_MODULUS).
const EVAL_MODULUS: u64 = 13;

#[derive(Debug, Clone)]
pub struct Dataset {
    gen: TaskGen,
    /// size of the training pool (paper: 17k problems); sampling cycles it
    pool: usize,
    rng: Rng,
}

impl Dataset {
    pub fn new(gen: TaskGen, pool: usize, seed: u64) -> Self {
        Dataset { gen, pool, rng: Rng::with_stream(seed, 0xda7a) }
    }

    fn id_for(split: Split, index: u64) -> u64 {
        match split {
            // skip over the eval residue class
            Split::Train => {
                let block = index / (EVAL_MODULUS - 1);
                let off = index % (EVAL_MODULUS - 1);
                block * EVAL_MODULUS + off + 1
            }
            Split::Eval => index * EVAL_MODULUS,
        }
    }

    /// Deterministic problem by split-local index.
    pub fn get(&self, split: Split, index: u64) -> Problem {
        self.gen.problem(Self::id_for(split, index))
    }

    /// Sample a training problem uniformly from the pool.
    pub fn sample_train(&mut self) -> Problem {
        let idx = self.rng.below(self.pool) as u64;
        self.get(Split::Train, idx)
    }

    /// The fixed eval suite (index 0..n) — Table 1's benchmark stand-in.
    pub fn eval_suite(&self, n: usize) -> Vec<Problem> {
        (0..n as u64).map(|i| self.get(Split::Eval, i)).collect()
    }

    pub fn pool_size(&self) -> usize {
        self.pool
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::task::TaskKind;

    fn ds() -> Dataset {
        Dataset::new(TaskGen::curriculum_full(), 1000, 7)
    }

    #[test]
    fn splits_are_disjoint() {
        let d = ds();
        let eval_ids: std::collections::HashSet<u64> =
            (0..200).map(|i| Dataset::id_for(Split::Eval, i)).collect();
        for i in 0..2000 {
            let tid = Dataset::id_for(Split::Train, i);
            assert!(!eval_ids.contains(&tid), "train id {tid} leaked into eval");
        }
    }

    #[test]
    fn train_ids_unique() {
        let mut seen = std::collections::HashSet::new();
        for i in 0..5000 {
            assert!(seen.insert(Dataset::id_for(Split::Train, i)));
        }
    }

    #[test]
    fn sampling_is_deterministic_per_seed() {
        let mut a = ds();
        let mut b = ds();
        for _ in 0..50 {
            assert_eq!(a.sample_train(), b.sample_train());
        }
    }

    #[test]
    fn eval_suite_stable() {
        let d = ds();
        let s1 = d.eval_suite(20);
        let s2 = d.eval_suite(20);
        assert_eq!(s1, s2);
        assert!(s1.iter().any(|p| p.kind == TaskKind::Add));
    }
}
