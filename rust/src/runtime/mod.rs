//! PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! One `Runtime` per worker thread (the xla crate's handles are not
//! `Send` — see [`tensor::HostTensor`] for the cross-thread story): each
//! actor / trainer / preprocessor thread constructs its own PJRT CPU
//! client and compiles the executables it needs, exactly like each GPU
//! pool in the paper runs its own vLLM / DeepSpeed instance.
//!
//! The interchange format is HLO *text* (`HloModuleProto::from_text_file`)
//! — see aot.py for why serialized protos do not work here.

pub mod manifest;
pub mod tensor;

pub use manifest::{Dtype, IoSpec, Manifest, ParamSpec, Variant};
pub use tensor::HostTensor;

use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use xla::{Literal, PjRtClient, PjRtLoadedExecutable};

/// True when a PJRT backend *and* the AOT artifacts are both present, i.e.
/// a [`Runtime`] can actually be constructed. Engine-dependent tests call
/// this to skip (with a printed reason) in environments built against the
/// vendored no-PJRT `xla` stub or lacking `artifacts/` — see tier1.sh.
pub fn runtime_available() -> bool {
    static AVAIL: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *AVAIL.get_or_init(|| match Runtime::new() {
        Ok(_) => true,
        Err(e) => {
            eprintln!("runtime unavailable (engine tests will skip): {e:#}");
            false
        }
    })
}

/// Default artifacts directory, overridable via `PIPELINE_RL_ARTIFACTS`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("PIPELINE_RL_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| {
            // resolve relative to the crate root so tests/benches work from
            // any working directory
            let mut p = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
            p.push("artifacts");
            p
        })
}

/// A compiled AOT graph.
pub struct Graph {
    pub name: String,
    exe: PjRtLoadedExecutable,
    /// client handle for input-buffer staging (see `run`)
    client: PjRtClient,
    /// expected number of runtime (non-param) inputs, for error messages
    pub n_inputs: usize,
}

impl Graph {
    /// Execute with host literals; returns the flattened output tuple.
    /// Accepts owned literals or references (`&[Literal]` / `&[&Literal]`).
    ///
    /// NOTE: this stages inputs into device buffers itself and runs
    /// `execute_b` rather than the crate's literal-based `execute`: the
    /// latter leaks every input device buffer (`buffer.release()` with no
    /// matching free in xla_rs.cc `execute`), which at one decode step per
    /// token adds up to GBs per minute. Managing `PjRtBuffer` handles on
    /// this side gives them proper Drop semantics.
    pub fn run<L: std::borrow::Borrow<Literal>>(&self, inputs: &[L]) -> Result<Vec<Literal>> {
        let staged = inputs
            .iter()
            .map(|l| self.client.buffer_from_host_literal(None, l.borrow()))
            .collect::<Result<Vec<_>, _>>()
            .with_context(|| format!("staging inputs for '{}'", self.name))?;
        self.run_buffers(&staged)
    }

    /// Execute with pre-staged device buffers (hot-path variant: callers
    /// can keep loop-invariant inputs, e.g. model weights, resident).
    pub fn run_buffers<B: std::borrow::Borrow<xla::PjRtBuffer>>(
        &self,
        inputs: &[B],
    ) -> Result<Vec<Literal>> {
        let bufs = self
            .exe
            .execute_b(inputs)
            .with_context(|| format!("executing graph '{}'", self.name))?;
        let lit = bufs[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: single tuple literal out.
        Ok(lit.to_tuple()?)
    }

    /// Stage a literal into a device buffer on this graph's client.
    pub fn stage(&self, lit: &Literal) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_literal(None, lit)?)
    }

    /// Execute and read outputs as HostTensors.
    pub fn run_host(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let lits = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<Vec<_>>>()?;
        let outs = self.run(&lits)?;
        outs.iter().map(HostTensor::from_literal).collect()
    }
}

/// Per-thread runtime: PJRT client + manifest + compiled-graph cache.
pub struct Runtime {
    pub client: PjRtClient,
    pub manifest: Manifest,
    dir: PathBuf,
    cache: HashMap<String, std::rc::Rc<Graph>>,
}

impl Runtime {
    pub fn new() -> Result<Runtime> {
        Self::with_dir(&artifacts_dir())
    }

    pub fn with_dir(dir: &Path) -> Result<Runtime> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        let manifest = Manifest::load(dir)?;
        Ok(Runtime { client, manifest, dir: dir.to_path_buf(), cache: HashMap::new() })
    }

    /// Load + compile (memoized) the `graph` of `variant`.
    pub fn graph(&mut self, variant: &str, graph: &str) -> Result<std::rc::Rc<Graph>> {
        let key = format!("{variant}/{graph}");
        if let Some(g) = self.cache.get(&key) {
            return Ok(g.clone());
        }
        let v = self.manifest.variant(variant)?;
        let Some(file) = v.artifacts.get(graph) else {
            bail!("variant '{variant}' has no artifact '{graph}'");
        };
        let path = self.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {key}"))?;
        let n_inputs = v.inputs.get(graph).map(|s| s.len()).unwrap_or(0);
        let g = std::rc::Rc::new(Graph {
            name: key.clone(),
            exe,
            client: self.client.clone(),
            n_inputs,
        });
        self.cache.insert(key, g.clone());
        Ok(g)
    }

    /// Run the init graph: seed -> fresh parameter set (host side).
    pub fn init_params(&mut self, variant: &str, seed: i32) -> Result<Vec<HostTensor>> {
        let g = self.graph(variant, "init")?;
        g.run_host(&[HostTensor::scalar_i32(seed)])
    }

    /// Zero-filled Adam state matching the variant's parameter shapes.
    pub fn zero_opt_state(&self, variant: &str) -> Result<Vec<HostTensor>> {
        let v = self.manifest.variant(variant)?;
        Ok(v.params
            .iter()
            .map(|p| HostTensor::zeros_f32(&p.shape))
            .collect())
    }
}

/// Validate that a host tensor set matches the variant's parameter specs.
pub fn check_params(v: &Variant, params: &[HostTensor]) -> Result<()> {
    if params.len() != v.params.len() {
        bail!(
            "param count mismatch: got {}, manifest says {}",
            params.len(),
            v.params.len()
        );
    }
    for (t, spec) in params.iter().zip(&v.params) {
        if t.shape() != spec.shape.as_slice() {
            bail!(
                "param '{}' shape mismatch: got {:?}, want {:?}",
                spec.name,
                t.shape(),
                spec.shape
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod perf_probe {
    use super::*;

    /// Timing breakdown of one decode execution (run with --ignored):
    /// staging vs execute vs readback. Guides the §Perf pass.
    #[test]
    #[ignore]
    fn decode_breakdown_base() {
        let mut rt = Runtime::new().unwrap();
        let v = rt.manifest.variant("base").unwrap().clone();
        let g = rt.graph("base", "decode").unwrap();
        let params = rt.init_params("base", 1).unwrap();
        let kv = HostTensor::zeros_f32(&v.kv_shape());
        let b = v.gen_batch;
        let mut lits: Vec<Literal> =
            params.iter().map(|t| t.to_literal().unwrap()).collect();
        lits.push(kv.to_literal().unwrap());
        lits.push(HostTensor::zeros_i32(&[b]).to_literal().unwrap());
        lits.push(HostTensor::from_i32(&[b], vec![1; b]).to_literal().unwrap());
        lits.push(HostTensor::zeros_f32(&[b, v.vocab]).to_literal().unwrap());
        lits.push(HostTensor::zeros_i32(&[b]).to_literal().unwrap());
        lits.push(HostTensor::from_f32(&[b], vec![1.0; b]).to_literal().unwrap());
        lits.push(HostTensor::scalar_f32(1.0).to_literal().unwrap());

        for round in 0..5 {
            let t0 = std::time::Instant::now();
            let staged: Vec<xla::PjRtBuffer> =
                lits.iter().map(|l| g.stage(l).unwrap()).collect();
            let t1 = std::time::Instant::now();
            let bufs = g.exe.execute_b(&staged).unwrap();
            let t2 = std::time::Instant::now();
            let lit = bufs[0][0].to_literal_sync().unwrap();
            let outs = lit.to_tuple().unwrap();
            let t3 = std::time::Instant::now();
            eprintln!(
                "round {round}: stage {:.1}ms execute {:.1}ms readback {:.1}ms ({} outs)",
                (t1 - t0).as_secs_f64() * 1e3,
                (t2 - t1).as_secs_f64() * 1e3,
                (t3 - t2).as_secs_f64() * 1e3,
                outs.len()
            );
        }
    }
}
