//! PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! One `Runtime` per worker thread (the xla crate's handles are not
//! `Send` — see [`tensor::HostTensor`] for the cross-thread story): each
//! actor / trainer / preprocessor thread constructs its own PJRT CPU
//! client and compiles the executables it needs, exactly like each GPU
//! pool in the paper runs its own vLLM / DeepSpeed instance.
//!
//! The interchange format is HLO *text* (`HloModuleProto::from_text_file`)
//! — see aot.py for why serialized protos do not work here.

pub mod manifest;
pub mod tensor;

pub use manifest::{Dtype, IoSpec, Manifest, ParamSpec, Variant};
pub use tensor::HostTensor;

use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use xla::{Literal, PjRtClient, PjRtLoadedExecutable};

/// True when a PJRT backend *and* the AOT artifacts are both present, i.e.
/// a [`Runtime`] can actually be constructed. Engine-dependent tests call
/// this to skip (with a printed reason) in environments built against the
/// vendored no-PJRT `xla` stub or lacking `artifacts/` — see tier1.sh.
pub fn runtime_available() -> bool {
    static AVAIL: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *AVAIL.get_or_init(|| match Runtime::new() {
        Ok(_) => true,
        Err(e) => {
            eprintln!("runtime unavailable (engine tests will skip): {e:#}");
            false
        }
    })
}

/// Default artifacts directory, overridable via `PIPELINE_RL_ARTIFACTS`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("PIPELINE_RL_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| {
            // resolve relative to the crate root so tests/benches work from
            // any working directory
            let mut p = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
            p.push("artifacts");
            p
        })
}

/// A compiled AOT graph.
pub struct Graph {
    pub name: String,
    exe: PjRtLoadedExecutable,
    /// client handle for input-buffer staging (see `run`)
    client: PjRtClient,
    /// expected number of runtime (non-param) inputs, for error messages
    pub n_inputs: usize,
}

/// One graph output that may still be device-resident.
///
/// The buffer-native execute path ([`Graph::run_buffers_b`]) keeps every
/// output as a [`PjRtBuffer`] when the PJRT client untuples results; on
/// builds where the executable returns a single tuple (the aot.py
/// `return_tuple=True` lowering read back through `to_literal_sync`),
/// outputs normalize to host [`Literal`]s instead. Callers that thread an
/// output straight into the next execute (the engine's KV cache) branch on
/// the variant; callers that only read scalars use [`DeviceVal::read_vec`].
#[derive(Debug)]
pub enum DeviceVal {
    /// still on device — feed it back as an input without a host round-trip
    Buf(xla::PjRtBuffer),
    /// host literal (tuple-readback fallback; also the no-PJRT stub path)
    Lit(Literal),
}

impl DeviceVal {
    pub fn is_device(&self) -> bool {
        matches!(self, DeviceVal::Buf(_))
    }

    /// Read this output back to the host (D2H for `Buf`, free for `Lit`).
    pub fn read_vec<T: xla::NativeType>(&self) -> Result<Vec<T>> {
        match self {
            DeviceVal::Buf(b) => Ok(b.to_literal_sync()?.to_vec::<T>()?),
            DeviceVal::Lit(l) => Ok(l.to_vec::<T>()?),
        }
    }
}

enum Slot {
    Val(DeviceVal),
    Taken,
}

/// Outputs of a buffer-native execute, with *selective* readback.
///
/// Two shapes are normalized behind one API:
///
/// * **untupled** — the PJRT client returned one `PjRtBuffer` per graph
///   output. `read_vec(i)` reads back only output `i`; `take(i)` hands the
///   buffer over still device-resident. This is the decode hot path: the
///   KV output never crosses the host boundary.
/// * **tupled fallback** — the executable returned a single tuple buffer.
///   The first access reads the tuple back once and splits it into host
///   literals (exactly what the legacy `run_buffers` did), so the API
///   still works, just without the device-residency win.
pub struct ExecOut {
    slots: Vec<Slot>,
    untupled: bool,
}

impl ExecOut {
    fn from_buffers(row: Vec<xla::PjRtBuffer>) -> ExecOut {
        let untupled = row.len() > 1;
        ExecOut {
            slots: row.into_iter().map(|b| Slot::Val(DeviceVal::Buf(b))).collect(),
            untupled,
        }
    }

    /// Build from host literals (the tuple-fallback shape; also used by
    /// device-free tests of the selective-readback logic).
    pub fn from_literals(lits: Vec<Literal>) -> ExecOut {
        ExecOut {
            slots: lits.into_iter().map(|l| Slot::Val(DeviceVal::Lit(l))).collect(),
            untupled: false,
        }
    }

    /// Number of addressable outputs *as currently known* — 1 until a
    /// tupled fallback is split by the first access.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// True when outputs arrived as separate device buffers.
    pub fn untupled(&self) -> bool {
        self.untupled
    }

    /// Normalize a single tuple buffer/literal into per-output literals so
    /// index `i` is addressable. No-op when already untupled/split.
    fn ensure_addressable(&mut self, i: usize) -> Result<()> {
        if i < self.slots.len() && (self.slots.len() > 1 || i != 0) {
            return Ok(());
        }
        if self.slots.len() == 1 {
            // the lone slot may be the whole output tuple: split lazily
            let lit = match &self.slots[0] {
                Slot::Val(DeviceVal::Buf(b)) => b.to_literal_sync()?,
                Slot::Val(DeviceVal::Lit(l)) => l.clone(),
                Slot::Taken => bail!("output 0 already taken"),
            };
            match lit.to_tuple() {
                Ok(parts) => {
                    self.slots = parts.into_iter().map(|l| Slot::Val(DeviceVal::Lit(l))).collect();
                }
                Err(_) => {
                    // genuinely a single array output
                    self.slots[0] = Slot::Val(DeviceVal::Lit(lit));
                }
            }
        }
        if i >= self.slots.len() {
            bail!("output index {i} out of range ({} outputs)", self.slots.len());
        }
        Ok(())
    }

    /// Read output `i` back to the host. In untupled mode this touches
    /// only that output's buffer.
    pub fn read_vec<T: xla::NativeType>(&mut self, i: usize) -> Result<Vec<T>> {
        self.ensure_addressable(i)?;
        match &self.slots[i] {
            Slot::Val(v) => v.read_vec::<T>(),
            Slot::Taken => bail!("output {i} already taken"),
        }
    }

    /// Take ownership of output `i` without reading it back (device-
    /// resident in untupled mode). Each output can be taken once.
    pub fn take(&mut self, i: usize) -> Result<DeviceVal> {
        self.ensure_addressable(i)?;
        match std::mem::replace(&mut self.slots[i], Slot::Taken) {
            Slot::Val(v) => Ok(v),
            Slot::Taken => bail!("output {i} already taken"),
        }
    }
}

impl Graph {
    /// Execute with host literals; returns the flattened output tuple.
    /// Accepts owned literals or references (`&[Literal]` / `&[&Literal]`).
    ///
    /// NOTE: this stages inputs into device buffers itself and runs
    /// `execute_b` rather than the crate's literal-based `execute`: the
    /// latter leaks every input device buffer (`buffer.release()` with no
    /// matching free in xla_rs.cc `execute`), which at one decode step per
    /// token adds up to GBs per minute. Managing `PjRtBuffer` handles on
    /// this side gives them proper Drop semantics.
    pub fn run<L: std::borrow::Borrow<Literal>>(&self, inputs: &[L]) -> Result<Vec<Literal>> {
        let staged = inputs
            .iter()
            .map(|l| self.client.buffer_from_host_literal(None, l.borrow()))
            .collect::<Result<Vec<_>, _>>()
            .with_context(|| format!("staging inputs for '{}'", self.name))?;
        self.run_buffers(&staged)
    }

    /// Execute with pre-staged device buffers (hot-path variant: callers
    /// can keep loop-invariant inputs, e.g. model weights, resident).
    pub fn run_buffers<B: std::borrow::Borrow<xla::PjRtBuffer>>(
        &self,
        inputs: &[B],
    ) -> Result<Vec<Literal>> {
        let bufs = self
            .exe
            .execute_b(inputs)
            .with_context(|| format!("executing graph '{}'", self.name))?;
        let lit = bufs[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: single tuple literal out.
        Ok(lit.to_tuple()?)
    }

    /// Buffer-native execute: outputs stay as device buffers when the
    /// client untuples results (see [`ExecOut`]). This is the decode hot
    /// path — the caller reads back only the outputs it needs and threads
    /// device-resident ones (the KV cache) into the next step.
    ///
    /// `donated` marks input indices whose buffers the caller will not
    /// reuse after this call (the KV/pool operand). True donation is a
    /// compile-time property — aot.py lowers both decode graphs with
    /// `donate_argnums` on the cache operand, so their HLO carries a real
    /// `input_output_alias={ {DECODE_KV_OUT}: (P, {}, may-alias) }`
    /// header (asserted by python/tests/test_aot.py and recorded in the
    /// manifest's `aliases`) and PJRT satisfies the update in place. The
    /// hook sanity-checks the indices so a call site that forgets to
    /// declare the handover fails loudly rather than silently copying.
    pub fn run_buffers_b<B: std::borrow::Borrow<xla::PjRtBuffer>>(
        &self,
        inputs: &[B],
        donated: &[usize],
    ) -> Result<ExecOut> {
        for &d in donated {
            if d >= inputs.len() {
                bail!("donated index {d} out of range ({} inputs)", inputs.len());
            }
        }
        let mut rows = self
            .exe
            .execute_b(inputs)
            .with_context(|| format!("executing graph '{}'", self.name))?;
        if rows.is_empty() {
            bail!("graph '{}' returned no output rows", self.name);
        }
        Ok(ExecOut::from_buffers(rows.swap_remove(0)))
    }

    /// Stage a literal into a device buffer on this graph's client.
    pub fn stage(&self, lit: &Literal) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_literal(None, lit)?)
    }

    /// Execute and read outputs as HostTensors.
    pub fn run_host(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let lits = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<Vec<_>>>()?;
        let outs = self.run(&lits)?;
        outs.iter().map(HostTensor::from_literal).collect()
    }
}

/// Index of the decode graph's KV-cache output (outputs: `next_tok[B]`,
/// `chosen_lp[B]`, `lp_all[B,V]`, `kv'`, `ent[B]` — model.py contract).
pub const DECODE_KV_OUT: usize = 3;

/// The six per-step decode operands, in graph operand order (they follow
/// the parameter set and the KV cache).
pub struct DecodeInputs<'a> {
    pub pos: &'a Literal,
    pub cur: &'a Literal,
    pub gumbel: &'a Literal,
    pub ftok: &'a Literal,
    pub fmask: &'a Literal,
    pub temp: &'a Literal,
}

/// Block-table-aware staging contract for one decode dispatch.
///
/// The decode graph scatters K/V at `pos[b]` for *every* row, every step
/// (model.py's unconditional write). The engine's paged allocator
/// ([`crate::engine::BlockAllocator`]) decides which cache positions a
/// sequence is actually entitled to write; this plan carries that
/// entitlement to the dispatch boundary so [`run_decode_step`] can refuse
/// a staging whose writes are not backed by allocated blocks — the bug
/// class where bookkeeping (double-free, premature release, a missed
/// park) and the device cache drift apart, caught loudly at the one choke
/// point every decode path shares instead of as silent KV corruption.
pub struct StagePlan<'a> {
    /// the off-cache parking position idle/stalled/parked rows must use
    pub park: i32,
    /// host-side copy of the `pos` operand, row-parallel
    pub pos: &'a [i32],
    /// per-row allocated KV capacity in tokens (block table length ×
    /// block size); 0 for rows with no live sequence
    pub cap: &'a [usize],
}

impl StagePlan<'_> {
    /// Every row either parks or writes a block-backed position.
    fn validate(&self) -> Result<()> {
        if self.pos.len() != self.cap.len() {
            bail!(
                "stage plan shape skew: {} positions vs {} capacities",
                self.pos.len(),
                self.cap.len()
            );
        }
        for (row, (&pos, &cap)) in self.pos.iter().zip(self.cap).enumerate() {
            if pos == self.park {
                continue;
            }
            if pos < 0 || pos as usize >= cap {
                bail!(
                    "row {row} stages a KV write at position {pos} with only {cap} \
                     block-backed tokens (allocator and cache drifted apart)"
                );
            }
        }
        Ok(())
    }
}

/// Result of [`run_decode_step`]: the remaining outputs (the KV output is
/// already moved back into the caller's `kv` slot), whether the KV had to
/// be restaged from a host literal, and the stage/execute timing split
/// the §Perf breakdown tracks.
pub struct DecodeStep {
    pub outs: ExecOut,
    pub kv_restaged: bool,
    pub stage_us: u64,
    pub execute_us: u64,
    /// time spent moving the KV output back out of `outs` — ~0 when the
    /// client untuples (a buffer handover), but on single-tuple fallback
    /// builds this is the whole-output sync readback and dominates the
    /// step: it belongs in the caller's readback accounting, not hidden
    /// between the timing windows
    pub kv_take_us: u64,
}

/// One decode-graph dispatch with the canonical operand assembly.
///
/// This is the single home of the input-assembly sequence that used to be
/// triplicated across `Engine::step`, `Engine::recompute_kv` and the
/// `decode_breakdown_resident` probe (and that the snapshot-import replay
/// would have copied a fourth time): stage the six per-step literals,
/// feed the KV back device-resident when it already lives there (staging
/// it — and reporting `kv_restaged` — when host-resident), execute with
/// donation intent declared on the KV operand, and thread the returned KV
/// (output [`DECODE_KV_OUT`]) back into `kv` for the next step.
///
/// `plan`, when given, is the block-table-aware staging contract: the
/// host-side write positions are checked against the allocator's per-row
/// block capacities *before* the dispatch (see [`StagePlan`]). Callers
/// without paged bookkeeping (probes, benches) pass `None`.
///
/// NOTE: buffer staging is asynchronous on the TFRT CPU client — the
/// caller's literals in `inp` (and a host-resident `kv`) must live across
/// this call, which the reference parameters make structural.
pub fn run_decode_step(
    graph: &Graph,
    param_bufs: &[&xla::PjRtBuffer],
    kv: &mut DeviceVal,
    inp: DecodeInputs<'_>,
    plan: Option<&StagePlan<'_>>,
) -> Result<DecodeStep> {
    if let Some(p) = plan {
        p.validate()?;
    }
    let t_stage = std::time::Instant::now();
    let pos_b = graph.stage(inp.pos)?;
    let cur_b = graph.stage(inp.cur)?;
    let gum_b = graph.stage(inp.gumbel)?;
    let ftok_b = graph.stage(inp.ftok)?;
    let fmask_b = graph.stage(inp.fmask)?;
    let temp_b = graph.stage(inp.temp)?;
    // steady state feeds the previous step's KV output buffer straight
    // back; only a host-resident KV (init/recompute replay/fallback)
    // costs a staging
    let kv_staged: xla::PjRtBuffer;
    let kv_restaged;
    let kv_ref: &xla::PjRtBuffer = match &*kv {
        DeviceVal::Buf(buf) => {
            kv_restaged = false;
            buf
        }
        DeviceVal::Lit(l) => {
            kv_restaged = true;
            kv_staged = graph.stage(l)?;
            &kv_staged
        }
    };
    let mut inputs: Vec<&xla::PjRtBuffer> = param_bufs.to_vec();
    let kv_idx = inputs.len();
    inputs.push(kv_ref);
    inputs.extend([&pos_b, &cur_b, &gum_b, &ftok_b, &fmask_b, &temp_b]);
    let stage_us = t_stage.elapsed().as_micros() as u64;

    let t_exec = std::time::Instant::now();
    let mut outs = graph.run_buffers_b(&inputs, &[kv_idx])?;
    let execute_us = t_exec.elapsed().as_micros() as u64;
    drop(inputs);
    let t_take = std::time::Instant::now();
    *kv = outs.take(DECODE_KV_OUT)?;
    let kv_take_us = t_take.elapsed().as_micros() as u64;
    Ok(DecodeStep { outs, kv_restaged, stage_us, execute_us, kv_take_us })
}

/// The paged graph's extra operands (between the pool and `pos`): the
/// `[B, NB]` block table and the per-row copy-on-write lanes.
pub struct PagedInputs<'a> {
    pub table: &'a Literal,
    pub copy_src: &'a Literal,
    pub copy_dst: &'a Literal,
}

/// Block-table entitlement for one paged dispatch — the paged analogue
/// of [`StagePlan`]'s capacity check, over the *addresses* instead of
/// the lengths. The graph gathers/scatters through every table entry it
/// is handed, so an entry pointing at a freed block (or a live row left
/// parked at trash) is silent cross-sequence KV corruption; this plan
/// refuses the dispatch instead.
pub struct TablePlan<'a> {
    /// KV page size in tokens
    pub block_size: usize,
    /// table entries per row (NB = max_seq / block_size)
    pub blocks_per_row: usize,
    /// device pool blocks; the last index is the sacrificial trash block
    pub pool_blocks: usize,
    /// `[B, NB]` row-major block-table lane
    pub table: &'a [i32],
    /// per-row CoW copy lanes (trash -> trash for copy-free rows)
    pub copy_src: &'a [i32],
    pub copy_dst: &'a [i32],
}

impl TablePlan<'_> {
    /// Every entry addresses the pool, every position a live row writes
    /// or attends is backed by a real (non-trash) block, and the copy
    /// lanes stay in range.
    fn validate(&self, park: i32, pos: &[i32]) -> Result<()> {
        let trash = self.pool_blocks as i32 - 1;
        if self.table.len() != pos.len() * self.blocks_per_row {
            bail!(
                "table plan shape skew: {} entries for {} rows x {} blocks",
                self.table.len(),
                pos.len(),
                self.blocks_per_row
            );
        }
        if self.copy_src.len() != pos.len() || self.copy_dst.len() != pos.len() {
            bail!("copy lanes must be one entry per row");
        }
        for (row, &p) in pos.iter().enumerate() {
            let lane = &self.table[row * self.blocks_per_row..(row + 1) * self.blocks_per_row];
            for (i, &b) in lane.iter().enumerate() {
                if b < 0 || b > trash {
                    bail!("row {row} table entry {i} addresses block {b} outside the pool");
                }
            }
            if p == park {
                continue;
            }
            // the row writes at p and attends 0..p: every covering page
            // must be a real block, not the parking target
            let need = p as usize / self.block_size + 1;
            for (i, &b) in lane.iter().take(need).enumerate() {
                if b == trash {
                    bail!(
                        "row {row} stages position {p} but table entry {i} still \
                         parks at the trash block (allocator and table lane drifted)"
                    );
                }
            }
        }
        for (row, (&s, &d)) in self.copy_src.iter().zip(self.copy_dst).enumerate() {
            if s < 0 || s > trash || d < 0 || d > trash {
                bail!("row {row} copy lane ({s} -> {d}) addresses outside the pool");
            }
        }
        Ok(())
    }
}

/// One `decode_paged` dispatch: the paged twin of [`run_decode_step`].
///
/// Operand order after the unrolled parameters: the block pool (the
/// donated cache operand, flat index P), then `table, copy_src,
/// copy_dst`, then the six per-step operands. The pool buffer is threaded
/// back from output [`DECODE_KV_OUT`] exactly like the dense KV — with
/// the `input_output_alias` aot.py emits, that handover is a true
/// in-place device update of the pool.
pub fn run_decode_step_paged(
    graph: &Graph,
    param_bufs: &[&xla::PjRtBuffer],
    pool: &mut DeviceVal,
    paged: PagedInputs<'_>,
    inp: DecodeInputs<'_>,
    plan: Option<&StagePlan<'_>>,
    tables: Option<&TablePlan<'_>>,
) -> Result<DecodeStep> {
    if let Some(p) = plan {
        p.validate()?;
        if let Some(t) = tables {
            t.validate(p.park, p.pos)?;
        }
    }
    let t_stage = std::time::Instant::now();
    let table_b = graph.stage(paged.table)?;
    let csrc_b = graph.stage(paged.copy_src)?;
    let cdst_b = graph.stage(paged.copy_dst)?;
    let pos_b = graph.stage(inp.pos)?;
    let cur_b = graph.stage(inp.cur)?;
    let gum_b = graph.stage(inp.gumbel)?;
    let ftok_b = graph.stage(inp.ftok)?;
    let fmask_b = graph.stage(inp.fmask)?;
    let temp_b = graph.stage(inp.temp)?;
    let pool_staged: xla::PjRtBuffer;
    let kv_restaged;
    let pool_ref: &xla::PjRtBuffer = match &*pool {
        DeviceVal::Buf(buf) => {
            kv_restaged = false;
            buf
        }
        DeviceVal::Lit(l) => {
            kv_restaged = true;
            pool_staged = graph.stage(l)?;
            &pool_staged
        }
    };
    let mut inputs: Vec<&xla::PjRtBuffer> = param_bufs.to_vec();
    let pool_idx = inputs.len();
    inputs.push(pool_ref);
    inputs.extend([&table_b, &csrc_b, &cdst_b]);
    inputs.extend([&pos_b, &cur_b, &gum_b, &ftok_b, &fmask_b, &temp_b]);
    let stage_us = t_stage.elapsed().as_micros() as u64;

    let t_exec = std::time::Instant::now();
    let mut outs = graph.run_buffers_b(&inputs, &[pool_idx])?;
    let execute_us = t_exec.elapsed().as_micros() as u64;
    drop(inputs);
    let t_take = std::time::Instant::now();
    *pool = outs.take(DECODE_KV_OUT)?;
    let kv_take_us = t_take.elapsed().as_micros() as u64;
    Ok(DecodeStep { outs, kv_restaged, stage_us, execute_us, kv_take_us })
}

/// The chunked-prefill operands, in `prefill_chunk` graph order (after
/// the parameter set and the cache): per-row chunk start position `[B]`,
/// the `[B, W]` forced-token matrix, per-row valid length `[B]`, then the
/// same sampling tail the decode graphs take (`gumbel, ftok, fmask,
/// temp`). Rows with `vlen = 0` are inert — the graph parks their
/// scatters and forwards lane 0 like a legacy parked row.
pub struct ChunkInputs<'a> {
    pub start: &'a Literal,
    pub ctoks: &'a Literal,
    pub vlen: &'a Literal,
    pub gumbel: &'a Literal,
    pub ftok: &'a Literal,
    pub fmask: &'a Literal,
    pub temp: &'a Literal,
}

/// One `prefill_chunk` dispatch: W forced tokens per row in one
/// executable launch (ceil(P/W) dispatches for a P-token prefix instead
/// of P decode steps). Cache threading, donation and timing match
/// [`run_decode_step`] exactly — the chunk graph keeps the decode output
/// contract (KV at [`DECODE_KV_OUT`]).
///
/// `plan.pos`, when given, must carry each row's *last* written cache
/// position (`start + vlen - 1`, or `park` for inert rows): the chunk
/// writes `start..=last` and attends `0..=last`, so the existing
/// capacity check over the furthest write covers every lane.
pub fn run_prefill_chunk(
    graph: &Graph,
    param_bufs: &[&xla::PjRtBuffer],
    kv: &mut DeviceVal,
    inp: ChunkInputs<'_>,
    plan: Option<&StagePlan<'_>>,
) -> Result<DecodeStep> {
    if let Some(p) = plan {
        p.validate()?;
    }
    let t_stage = std::time::Instant::now();
    let start_b = graph.stage(inp.start)?;
    let ctoks_b = graph.stage(inp.ctoks)?;
    let vlen_b = graph.stage(inp.vlen)?;
    let gum_b = graph.stage(inp.gumbel)?;
    let ftok_b = graph.stage(inp.ftok)?;
    let fmask_b = graph.stage(inp.fmask)?;
    let temp_b = graph.stage(inp.temp)?;
    let kv_staged: xla::PjRtBuffer;
    let kv_restaged;
    let kv_ref: &xla::PjRtBuffer = match &*kv {
        DeviceVal::Buf(buf) => {
            kv_restaged = false;
            buf
        }
        DeviceVal::Lit(l) => {
            kv_restaged = true;
            kv_staged = graph.stage(l)?;
            &kv_staged
        }
    };
    let mut inputs: Vec<&xla::PjRtBuffer> = param_bufs.to_vec();
    let kv_idx = inputs.len();
    inputs.push(kv_ref);
    inputs.extend([&start_b, &ctoks_b, &vlen_b, &gum_b, &ftok_b, &fmask_b, &temp_b]);
    let stage_us = t_stage.elapsed().as_micros() as u64;

    let t_exec = std::time::Instant::now();
    let mut outs = graph.run_buffers_b(&inputs, &[kv_idx])?;
    let execute_us = t_exec.elapsed().as_micros() as u64;
    drop(inputs);
    let t_take = std::time::Instant::now();
    *kv = outs.take(DECODE_KV_OUT)?;
    let kv_take_us = t_take.elapsed().as_micros() as u64;
    Ok(DecodeStep { outs, kv_restaged, stage_us, execute_us, kv_take_us })
}

/// One `prefill_chunk_paged` dispatch: the paged twin of
/// [`run_prefill_chunk`]. Operand order after the parameters: the block
/// pool (donated), `table, copy_src, copy_dst`, then the chunk operands.
/// Inert lanes scatter into the pool's trash block, so the same
/// [`TablePlan`] entitlement check applies over the last written
/// positions.
pub fn run_prefill_chunk_paged(
    graph: &Graph,
    param_bufs: &[&xla::PjRtBuffer],
    pool: &mut DeviceVal,
    paged: PagedInputs<'_>,
    inp: ChunkInputs<'_>,
    plan: Option<&StagePlan<'_>>,
    tables: Option<&TablePlan<'_>>,
) -> Result<DecodeStep> {
    if let Some(p) = plan {
        p.validate()?;
        if let Some(t) = tables {
            t.validate(p.park, p.pos)?;
        }
    }
    let t_stage = std::time::Instant::now();
    let table_b = graph.stage(paged.table)?;
    let csrc_b = graph.stage(paged.copy_src)?;
    let cdst_b = graph.stage(paged.copy_dst)?;
    let start_b = graph.stage(inp.start)?;
    let ctoks_b = graph.stage(inp.ctoks)?;
    let vlen_b = graph.stage(inp.vlen)?;
    let gum_b = graph.stage(inp.gumbel)?;
    let ftok_b = graph.stage(inp.ftok)?;
    let fmask_b = graph.stage(inp.fmask)?;
    let temp_b = graph.stage(inp.temp)?;
    let pool_staged: xla::PjRtBuffer;
    let kv_restaged;
    let pool_ref: &xla::PjRtBuffer = match &*pool {
        DeviceVal::Buf(buf) => {
            kv_restaged = false;
            buf
        }
        DeviceVal::Lit(l) => {
            kv_restaged = true;
            pool_staged = graph.stage(l)?;
            &pool_staged
        }
    };
    let mut inputs: Vec<&xla::PjRtBuffer> = param_bufs.to_vec();
    let pool_idx = inputs.len();
    inputs.push(pool_ref);
    inputs.extend([&table_b, &csrc_b, &cdst_b]);
    inputs.extend([&start_b, &ctoks_b, &vlen_b, &gum_b, &ftok_b, &fmask_b, &temp_b]);
    let stage_us = t_stage.elapsed().as_micros() as u64;

    let t_exec = std::time::Instant::now();
    let mut outs = graph.run_buffers_b(&inputs, &[pool_idx])?;
    let execute_us = t_exec.elapsed().as_micros() as u64;
    drop(inputs);
    let t_take = std::time::Instant::now();
    *pool = outs.take(DECODE_KV_OUT)?;
    let kv_take_us = t_take.elapsed().as_micros() as u64;
    Ok(DecodeStep { outs, kv_restaged, stage_us, execute_us, kv_take_us })
}

/// Per-thread runtime: PJRT client + manifest + compiled-graph cache.
pub struct Runtime {
    pub client: PjRtClient,
    pub manifest: Manifest,
    dir: PathBuf,
    cache: HashMap<String, std::rc::Rc<Graph>>,
}

impl Runtime {
    pub fn new() -> Result<Runtime> {
        Self::with_dir(&artifacts_dir())
    }

    pub fn with_dir(dir: &Path) -> Result<Runtime> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        let manifest = Manifest::load(dir)?;
        Ok(Runtime { client, manifest, dir: dir.to_path_buf(), cache: HashMap::new() })
    }

    /// Load + compile (memoized) the `graph` of `variant`.
    pub fn graph(&mut self, variant: &str, graph: &str) -> Result<std::rc::Rc<Graph>> {
        let key = format!("{variant}/{graph}");
        if let Some(g) = self.cache.get(&key) {
            return Ok(g.clone());
        }
        let v = self.manifest.variant(variant)?;
        let Some(file) = v.artifacts.get(graph) else {
            bail!("variant '{variant}' has no artifact '{graph}'");
        };
        let path = self.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {key}"))?;
        let n_inputs = v.inputs.get(graph).map(|s| s.len()).unwrap_or(0);
        let g = std::rc::Rc::new(Graph {
            name: key.clone(),
            exe,
            client: self.client.clone(),
            n_inputs,
        });
        self.cache.insert(key, g.clone());
        Ok(g)
    }

    /// Run the init graph: seed -> fresh parameter set (host side).
    pub fn init_params(&mut self, variant: &str, seed: i32) -> Result<Vec<HostTensor>> {
        let g = self.graph(variant, "init")?;
        g.run_host(&[HostTensor::scalar_i32(seed)])
    }

    /// Zero-filled Adam state matching the variant's parameter shapes.
    pub fn zero_opt_state(&self, variant: &str) -> Result<Vec<HostTensor>> {
        let v = self.manifest.variant(variant)?;
        Ok(v.params
            .iter()
            .map(|p| HostTensor::zeros_f32(&p.shape))
            .collect())
    }
}

/// Validate that a host tensor set matches the variant's parameter specs.
pub fn check_params(v: &Variant, params: &[HostTensor]) -> Result<()> {
    if params.len() != v.params.len() {
        bail!(
            "param count mismatch: got {}, manifest says {}",
            params.len(),
            v.params.len()
        );
    }
    for (t, spec) in params.iter().zip(&v.params) {
        if t.shape() != spec.shape.as_slice() {
            bail!(
                "param '{}' shape mismatch: got {:?}, want {:?}",
                spec.name,
                t.shape(),
                spec.shape
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod exec_out_tests {
    use super::*;

    fn tuple_out() -> ExecOut {
        // shape of the decode graph's fallback: one tuple literal
        let tup = Literal::tuple(vec![
            Literal::vec1(&[7i32, 8]),
            Literal::vec1(&[-0.5f32, -0.25]),
            Literal::vec1(&[0.0f32; 4]),
            Literal::vec1(&[1.0f32; 8]),
            Literal::vec1(&[0.1f32, 0.2]),
        ]);
        ExecOut::from_literals(vec![tup])
    }

    #[test]
    fn tuple_fallback_splits_lazily() {
        let mut out = tuple_out();
        assert_eq!(out.len(), 1, "unsplit until first access");
        assert!(!out.untupled());
        assert_eq!(out.read_vec::<i32>(0).unwrap(), vec![7, 8]);
        assert_eq!(out.len(), 5, "first access splits the tuple");
        assert_eq!(out.read_vec::<f32>(1).unwrap(), vec![-0.5, -0.25]);
    }

    #[test]
    fn take_hands_over_each_output_once() {
        let mut out = tuple_out();
        let kv = out.take(3).unwrap();
        assert!(!kv.is_device(), "fallback outputs are host literals");
        assert_eq!(kv.read_vec::<f32>().unwrap().len(), 8);
        assert!(out.take(3).is_err(), "second take must fail");
        // untaken outputs remain readable
        assert_eq!(out.read_vec::<i32>(0).unwrap(), vec![7, 8]);
    }

    #[test]
    fn out_of_range_index_errors() {
        let mut out = tuple_out();
        assert!(out.read_vec::<i32>(5).is_err());
        let mut single = ExecOut::from_literals(vec![Literal::vec1(&[1i32])]);
        assert_eq!(single.read_vec::<i32>(0).unwrap(), vec![1]);
        assert!(single.read_vec::<i32>(1).is_err(), "single array output is not a tuple");
    }

    #[test]
    fn pre_split_literals_address_directly() {
        let mut out = ExecOut::from_literals(vec![
            Literal::vec1(&[1i32]),
            Literal::vec1(&[2.0f32]),
        ]);
        assert_eq!(out.read_vec::<f32>(1).unwrap(), vec![2.0]);
        assert_eq!(out.read_vec::<i32>(0).unwrap(), vec![1]);
    }
}

#[cfg(test)]
mod stage_plan_tests {
    use super::*;

    #[test]
    fn parked_and_backed_rows_pass() {
        let plan = StagePlan { park: 95, pos: &[95, 0, 7], cap: &[0, 4, 8] };
        plan.validate().unwrap();
    }

    #[test]
    fn unbacked_write_is_refused() {
        let plan = StagePlan { park: 95, pos: &[4], cap: &[4] };
        assert!(plan.validate().is_err(), "position 4 needs 5 tokens of capacity");
        let plan = StagePlan { park: 95, pos: &[0], cap: &[0] };
        assert!(plan.validate().is_err(), "no live sequence, no write");
        let plan = StagePlan { park: 95, pos: &[-3], cap: &[8] };
        assert!(plan.validate().is_err(), "negative positions are never backed");
        let plan = StagePlan { park: 95, pos: &[0, 1], cap: &[4] };
        assert!(plan.validate().is_err(), "shape skew is refused");
    }

    // TablePlan geometry shared by the paged tests: 2 rows x 3 blocks of
    // 4 tokens over a 7-block pool (trash = 6)
    fn tp<'a>(table: &'a [i32], csrc: &'a [i32], cdst: &'a [i32]) -> TablePlan<'a> {
        TablePlan {
            block_size: 4,
            blocks_per_row: 3,
            pool_blocks: 7,
            table,
            copy_src: csrc,
            copy_dst: cdst,
        }
    }

    #[test]
    fn paged_backed_rows_and_parked_rows_pass() {
        // row 0 parked (all trash), row 1 writing position 5 (pages 0-1
        // real, tail parked)
        let table = [6, 6, 6, 0, 2, 6];
        let plan = tp(&table, &[6, 6], &[6, 6]);
        plan.validate(95, &[95, 5]).unwrap();
        // a staged CoW copy between real blocks passes too
        let plan = tp(&table, &[2, 6], &[4, 6]);
        plan.validate(95, &[95, 5]).unwrap();
    }

    #[test]
    fn paged_unbacked_or_out_of_pool_entries_are_refused() {
        // live row whose covering page still parks at trash
        let table = [6, 6, 6, 0, 6, 6];
        assert!(
            tp(&table, &[6, 6], &[6, 6]).validate(95, &[95, 5]).is_err(),
            "position 5 needs page 1 backed by a real block"
        );
        // entry addressing outside the pool
        let table = [6, 6, 6, 0, 7, 6];
        assert!(tp(&table, &[6, 6], &[6, 6]).validate(95, &[95, 5]).is_err());
        let table = [6, 6, 6, 0, -1, 6];
        assert!(tp(&table, &[6, 6], &[6, 6]).validate(95, &[95, 5]).is_err());
        // copy lane outside the pool
        let table = [6, 6, 6, 0, 2, 6];
        assert!(tp(&table, &[9, 6], &[0, 6]).validate(95, &[95, 5]).is_err());
        // shape skew: 1 row of positions against 2 rows of table
        assert!(tp(&table, &[6], &[6]).validate(95, &[95]).is_err());
    }
}

#[cfg(test)]
mod perf_probe {
    use super::*;

    /// Timing breakdown of one decode execution (run with --ignored):
    /// staging vs execute vs readback. Guides the §Perf pass.
    #[test]
    #[ignore]
    fn decode_breakdown_base() {
        let mut rt = Runtime::new().unwrap();
        let v = rt.manifest.variant("base").unwrap().clone();
        let g = rt.graph("base", "decode").unwrap();
        let params = rt.init_params("base", 1).unwrap();
        let kv = HostTensor::zeros_f32(&v.kv_shape());
        let b = v.gen_batch;
        let mut lits: Vec<Literal> =
            params.iter().map(|t| t.to_literal().unwrap()).collect();
        lits.push(kv.to_literal().unwrap());
        lits.push(HostTensor::zeros_i32(&[b]).to_literal().unwrap());
        lits.push(HostTensor::from_i32(&[b], vec![1; b]).to_literal().unwrap());
        lits.push(HostTensor::zeros_f32(&[b, v.vocab]).to_literal().unwrap());
        lits.push(HostTensor::zeros_i32(&[b]).to_literal().unwrap());
        lits.push(HostTensor::from_f32(&[b], vec![1.0; b]).to_literal().unwrap());
        lits.push(HostTensor::scalar_f32(1.0).to_literal().unwrap());

        for round in 0..5 {
            let t0 = std::time::Instant::now();
            let staged: Vec<xla::PjRtBuffer> =
                lits.iter().map(|l| g.stage(l).unwrap()).collect();
            let t1 = std::time::Instant::now();
            let bufs = g.exe.execute_b(&staged).unwrap();
            let t2 = std::time::Instant::now();
            let lit = bufs[0][0].to_literal_sync().unwrap();
            let outs = lit.to_tuple().unwrap();
            let t3 = std::time::Instant::now();
            eprintln!(
                "round {round}: stage {:.1}ms execute {:.1}ms readback {:.1}ms ({} outs)",
                (t1 - t0).as_secs_f64() * 1e3,
                (t2 - t1).as_secs_f64() * 1e3,
                (t3 - t2).as_secs_f64() * 1e3,
                outs.len()
            );
        }
    }

    /// Device-resident counterpart of `decode_breakdown_base`: weights and
    /// KV stay on device, only next_tok/chosen_lp are read back. The delta
    /// between the two probes is the §Perf number recorded in ROADMAP.md.
    #[test]
    #[ignore]
    fn decode_breakdown_resident() {
        let mut rt = Runtime::new().unwrap();
        let v = rt.manifest.variant("base").unwrap().clone();
        let g = rt.graph("base", "decode").unwrap();
        let params = rt.init_params("base", 1).unwrap();
        let b = v.gen_batch;

        // loop-invariant: parameter buffers staged once
        let param_lits: Vec<Literal> = params.iter().map(|t| t.to_literal().unwrap()).collect();
        let param_bufs: Vec<xla::PjRtBuffer> =
            param_lits.iter().map(|l| g.stage(l).unwrap()).collect();
        let kv_lit = HostTensor::zeros_f32(&v.kv_shape()).to_literal().unwrap();
        let mut kv = DeviceVal::Buf(g.stage(&kv_lit).unwrap());

        // per-step literals (small: O(B) + gumbel)
        let pos_l = HostTensor::zeros_i32(&[b]).to_literal().unwrap();
        let cur_l = HostTensor::from_i32(&[b], vec![1; b]).to_literal().unwrap();
        let gum_l = HostTensor::zeros_f32(&[b, v.vocab]).to_literal().unwrap();
        let ftok_l = HostTensor::zeros_i32(&[b]).to_literal().unwrap();
        let fmask_l = HostTensor::from_f32(&[b], vec![1.0; b]).to_literal().unwrap();
        let temp_l = HostTensor::scalar_f32(1.0).to_literal().unwrap();

        // input assembly + dispatch shared with Engine::step /
        // Engine::recompute_kv via run_decode_step — the probe measures
        // exactly the hot-path code
        for round in 0..5 {
            let param_refs: Vec<&xla::PjRtBuffer> = param_bufs.iter().collect();
            let d = run_decode_step(
                &g,
                &param_refs,
                &mut kv,
                DecodeInputs {
                    pos: &pos_l,
                    cur: &cur_l,
                    gumbel: &gum_l,
                    ftok: &ftok_l,
                    fmask: &fmask_l,
                    temp: &temp_l,
                },
                None,
            )
            .unwrap();
            let mut out = d.outs;
            let t2 = std::time::Instant::now();
            let next = out.read_vec::<i32>(0).unwrap();
            let lps = out.read_vec::<f32>(1).unwrap();
            let t3 = std::time::Instant::now();
            eprintln!(
                "round {round}: stage {:.1}ms execute {:.1}ms selective-readback {:.1}ms \
                 (kv on device: {}, restaged: {}, {} next, {} lps)",
                d.stage_us as f64 / 1e3,
                d.execute_us as f64 / 1e3,
                (t3 - t2).as_secs_f64() * 1e3 + d.kv_take_us as f64 / 1e3,
                kv.is_device(),
                d.kv_restaged,
                next.len(),
                lps.len(),
            );
        }
    }
}
