//! `HostTensor` — the Send-able host-side tensor used everywhere outside
//! a device thread.
//!
//! The xla crate's `Literal`/`PjRtBuffer` wrap raw C pointers and are not
//! `Send`; PipelineRL's stages are OS threads that exchange data through
//! the broker and the weight bus, so everything that crosses a thread
//! boundary is a `HostTensor` (plain `Vec` + shape). This mirrors the
//! paper's architecture faithfully: weights crossing the trainer→actor
//! boundary are a *serialized transfer* (NCCL broadcast there, a memcpy
//! here), and rollouts crossing actor→trainer are plain data.

use anyhow::{bail, Result};
use xla::Literal;

#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostTensor {
    pub fn zeros_f32(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        HostTensor::F32 { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn zeros_i32(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        HostTensor::I32 { shape: shape.to_vec(), data: vec![0; n] }
    }

    pub fn scalar_f32(x: f32) -> Self {
        HostTensor::F32 { shape: vec![], data: vec![x] }
    }

    pub fn scalar_i32(x: i32) -> Self {
        HostTensor::I32 { shape: vec![], data: vec![x] }
    }

    pub fn from_f32(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::F32 { shape: shape.to_vec(), data }
    }

    pub fn from_i32(shape: &[usize], data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::I32 { shape: shape.to_vec(), data }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => shape,
        }
    }

    pub fn numel(&self) -> usize {
        self.shape().iter().product()
    }

    /// Size in bytes (both dtypes are 4-byte).
    pub fn nbytes(&self) -> usize {
        self.numel() * 4
    }

    pub fn f32s(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("expected f32 tensor"),
        }
    }

    pub fn i32s(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            _ => bail!("expected i32 tensor"),
        }
    }

    pub fn f32s_mut(&mut self) -> Result<&mut Vec<f32>> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("expected f32 tensor"),
        }
    }

    /// Device-thread only: build an xla Literal.
    pub fn to_literal(&self) -> Result<Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32 { data, .. } => {
                if dims.is_empty() {
                    Literal::scalar(data[0])
                } else {
                    Literal::vec1(data).reshape(&dims)?
                }
            }
            HostTensor::I32 { data, .. } => {
                if dims.is_empty() {
                    Literal::scalar(data[0])
                } else {
                    Literal::vec1(data).reshape(&dims)?
                }
            }
        };
        Ok(lit)
    }

    /// Device-thread only: read a Literal back to host.
    pub fn from_literal(lit: &Literal) -> Result<HostTensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(HostTensor::F32 {
                shape: dims,
                data: lit.to_vec::<f32>()?,
            }),
            xla::ElementType::S32 => Ok(HostTensor::I32 {
                shape: dims,
                data: lit.to_vec::<i32>()?,
            }),
            t => bail!("unsupported literal element type {t:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_bytes() {
        let t = HostTensor::zeros_f32(&[3, 4]);
        assert_eq!(t.numel(), 12);
        assert_eq!(t.nbytes(), 48);
        let s = HostTensor::scalar_i32(7);
        assert_eq!(s.numel(), 1);
        assert_eq!(s.i32s().unwrap(), &[7]);
    }

    #[test]
    fn dtype_mismatch_errors() {
        let t = HostTensor::zeros_f32(&[2]);
        assert!(t.i32s().is_err());
    }

    #[test]
    fn literal_roundtrip() {
        let t = HostTensor::from_f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);

        let ti = HostTensor::from_i32(&[4], vec![9, 8, 7, 6]);
        let lit = ti.to_literal().unwrap();
        assert_eq!(HostTensor::from_literal(&lit).unwrap(), ti);
    }
}
