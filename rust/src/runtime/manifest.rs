//! Parse `artifacts/manifest.json` — the contract between the python
//! compile path (aot.py) and this runtime. The manifest pins every static
//! dimension of every AOT graph so the rust side can build correctly
//! shaped literals without ever importing python.

use crate::util::Json;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

#[derive(Debug, Clone, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

/// One graph's cache-donation record: aot.py declares that the flat
/// operand at `param` is returned at output tuple index `output` and is
/// safe to update in place (`input_output_alias` in the lowered HLO).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AliasSpec {
    pub param: usize,
    pub output: usize,
}

#[derive(Debug, Clone)]
pub struct Variant {
    pub name: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub max_seq: usize,
    pub gen_batch: usize,
    pub train_batch: usize,
    pub seq_len: usize,
    pub vocab: usize,
    pub n_params: usize,
    /// paged-pool geometry (0 when the manifest predates the paged
    /// decode graph): page size in tokens, pages per row
    /// (`max_seq / kv_block_size`), and total device pool blocks
    /// including the trailing trash block
    pub kv_block_size: usize,
    pub kv_blocks_per_row: usize,
    pub kv_pool_blocks: usize,
    /// compiled chunk width W of the `prefill_chunk` graphs (0 when the
    /// manifest predates chunked prefill): the engine's
    /// `[kv] prefill_chunk` must not exceed it
    pub prefill_chunk: usize,
    /// graph name -> donated cache operand record (empty for manifests
    /// written before donation landed)
    pub aliases: BTreeMap<String, AliasSpec>,
    pub params: Vec<ParamSpec>,
    pub artifacts: BTreeMap<String, String>,
    pub inputs: BTreeMap<String, Vec<IoSpec>>,
}

impl Variant {
    /// Elements in the KV cache tensor [L, 2, B, Tmax, H, hd].
    pub fn kv_numel(&self) -> usize {
        self.n_layers * 2 * self.gen_batch * self.max_seq * self.n_heads * self.head_dim
    }

    pub fn kv_shape(&self) -> Vec<usize> {
        vec![self.n_layers, 2, self.gen_batch, self.max_seq, self.n_heads, self.head_dim]
    }

    /// True when the manifest carries the paged-pool geometry (i.e. it
    /// was written by an aot.py that lowers `decode_paged`).
    pub fn has_paged_pool(&self) -> bool {
        self.kv_block_size > 0 && self.kv_pool_blocks > 0
    }

    /// Paged pool tensor [n_blocks, L, 2, block_size, H, hd] — the
    /// `decode_paged` cache operand. The last block index is the trash
    /// block parked rows scatter into.
    pub fn kv_pool_shape(&self) -> Vec<usize> {
        vec![
            self.kv_pool_blocks,
            self.n_layers,
            2,
            self.kv_block_size,
            self.n_heads,
            self.head_dim,
        ]
    }

    pub fn kv_pool_numel(&self) -> usize {
        self.kv_pool_shape().iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub variants: BTreeMap<String, Variant>,
    pub metric_names: Vec<String>,
    pub sft_metric_names: Vec<String>,
    pub pad_id: i32,
    pub bos_id: i32,
    pub eos_id: i32,
    pub vocab_size: usize,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text)?;
        let mut variants = BTreeMap::new();
        for (name, vj) in j.req("variants")?.as_obj()? {
            variants.insert(name.clone(), parse_variant(name, vj)?);
        }
        Ok(Manifest {
            variants,
            metric_names: str_arr(j.req("metric_names")?)?,
            sft_metric_names: str_arr(j.req("sft_metric_names")?)?,
            pad_id: j.req("pad_id")?.as_f64()? as i32,
            bos_id: j.req("bos_id")?.as_f64()? as i32,
            eos_id: j.req("eos_id")?.as_f64()? as i32,
            vocab_size: j.req("vocab_size")?.as_usize()?,
        })
    }

    pub fn variant(&self, name: &str) -> Result<&Variant> {
        self.variants
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown variant '{name}'"))
    }

    /// Index of a metric in the train-graph metrics vector.
    pub fn metric_index(&self, name: &str) -> Option<usize> {
        self.metric_names.iter().position(|m| m == name)
    }
}

fn parse_variant(name: &str, v: &Json) -> Result<Variant> {
    let params = v
        .req("params")?
        .as_arr()?
        .iter()
        .map(|p| {
            Ok(ParamSpec {
                name: p.req("name")?.as_str()?.to_string(),
                shape: usize_arr(p.req("shape")?)?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let mut artifacts = BTreeMap::new();
    for (k, f) in v.req("artifacts")?.as_obj()? {
        artifacts.insert(k.clone(), f.as_str()?.to_string());
    }
    let mut inputs = BTreeMap::new();
    for (g, sig) in v.req("inputs")?.as_obj()? {
        let specs = sig
            .as_arr()?
            .iter()
            .map(|s| {
                Ok(IoSpec {
                    name: s.req("name")?.as_str()?.to_string(),
                    shape: usize_arr(s.req("shape")?)?,
                    dtype: match s.req("dtype")?.as_str()? {
                        "f32" => Dtype::F32,
                        "i32" => Dtype::I32,
                        d => anyhow::bail!("unknown dtype {d}"),
                    },
                })
            })
            .collect::<Result<Vec<_>>>()?;
        inputs.insert(g.clone(), specs);
    }
    // optional paged-pool fields: absent in manifests written before the
    // paged decode graph, so their absence must not fail the parse
    let opt_usize = |key: &str| -> Result<usize> {
        v.get(key).map(|x| x.as_usize()).transpose().map(|o| o.unwrap_or(0))
    };
    let mut aliases = BTreeMap::new();
    if let Some(a) = v.get("aliases") {
        for (g, rec) in a.as_obj()? {
            aliases.insert(
                g.clone(),
                AliasSpec {
                    param: rec.req("param")?.as_usize()?,
                    output: rec.req("output")?.as_usize()?,
                },
            );
        }
    }
    Ok(Variant {
        name: name.to_string(),
        d_model: v.req("d_model")?.as_usize()?,
        n_layers: v.req("n_layers")?.as_usize()?,
        n_heads: v.req("n_heads")?.as_usize()?,
        head_dim: v.req("head_dim")?.as_usize()?,
        max_seq: v.req("max_seq")?.as_usize()?,
        gen_batch: v.req("gen_batch")?.as_usize()?,
        train_batch: v.req("train_batch")?.as_usize()?,
        seq_len: v.req("seq_len")?.as_usize()?,
        vocab: v.req("vocab")?.as_usize()?,
        n_params: v.req("n_params")?.as_usize()?,
        kv_block_size: opt_usize("kv_block_size")?,
        kv_blocks_per_row: opt_usize("kv_blocks_per_row")?,
        kv_pool_blocks: opt_usize("kv_pool_blocks")?,
        prefill_chunk: opt_usize("prefill_chunk")?,
        aliases,
        params,
        artifacts,
        inputs,
    })
}

fn usize_arr(j: &Json) -> Result<Vec<usize>> {
    j.as_arr()?.iter().map(|x| x.as_usize()).collect()
}

fn str_arr(j: &Json) -> Result<Vec<String>> {
    j.as_arr()?
        .iter()
        .map(|x| Ok(x.as_str()?.to_string()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const SNIPPET: &str = r#"{
      "variants": {
        "tiny": {
          "d_model": 32, "n_layers": 2, "n_heads": 2, "head_dim": 16,
          "max_seq": 96, "gen_batch": 4, "train_batch": 4, "seq_len": 96,
          "vocab": 64, "n_params": 27744,
          "params": [{"name": "embed", "shape": [64, 32]}],
          "artifacts": {"decode": "tiny_decode.hlo.txt"},
          "inputs": {"decode": [
            {"name": "pos", "shape": [4], "dtype": "i32"}]}
        }
      },
      "metric_names": ["loss", "ess"],
      "sft_metric_names": ["loss"],
      "pad_id": 0, "bos_id": 1, "eos_id": 2, "vocab_size": 64
    }"#;

    #[test]
    fn parses_snippet() {
        let m = Manifest::parse(SNIPPET).unwrap();
        let v = m.variant("tiny").unwrap();
        assert_eq!(v.gen_batch, 4);
        assert_eq!(v.params[0].numel(), 64 * 32);
        assert_eq!(v.kv_shape(), vec![2, 2, 4, 96, 2, 16]);
        assert_eq!(v.inputs["decode"][0].dtype, Dtype::I32);
        assert_eq!(m.metric_index("ess"), Some(1));
        // pre-paged manifest: geometry absent, not a parse error
        assert!(!v.has_paged_pool());
        assert!(v.aliases.is_empty());
        // pre-chunk manifest: width absent -> 0 (no chunk graphs)
        assert_eq!(v.prefill_chunk, 0);
    }

    #[test]
    fn parses_paged_pool_fields() {
        // the same variant as aot.py now writes it: pool geometry plus
        // the cache-donation records for both decode graphs
        let text = SNIPPET.replace(
            r#""n_params": 27744,"#,
            r#""n_params": 27744,
          "kv_block_size": 16, "kv_blocks_per_row": 6, "kv_pool_blocks": 25,
          "prefill_chunk": 8,
          "aliases": {"decode": {"param": 19, "output": 3},
                      "decode_paged": {"param": 19, "output": 3}},"#,
        );
        let m = Manifest::parse(&text).unwrap();
        let v = m.variant("tiny").unwrap();
        assert!(v.has_paged_pool());
        assert_eq!(v.prefill_chunk, 8);
        assert_eq!(v.kv_block_size * v.kv_blocks_per_row, v.max_seq);
        // pool covers every row densely plus the trash block
        assert_eq!(v.kv_pool_blocks, v.gen_batch * v.kv_blocks_per_row + 1);
        assert_eq!(v.kv_pool_shape(), vec![25, 2, 2, 16, 2, 16]);
        assert_eq!(v.kv_pool_numel(), 25 * 2 * 2 * 16 * 2 * 16);
        assert_eq!(
            v.aliases["decode_paged"],
            AliasSpec { param: 19, output: 3 }
        );
    }

    #[test]
    fn unknown_variant_errors() {
        let m = Manifest::parse(SNIPPET).unwrap();
        assert!(m.variant("huge").is_err());
    }
}
