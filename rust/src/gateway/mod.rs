//! Serving gateway: QoS-classed user inference and rollouts on one
//! engine (ROADMAP direction 1, "serving gateway for user-facing
//! inference during training" — the rsBot `tau-gateway`/M24 "True RL
//! Pipeline In Production" track, SNIPPETS.md §1).
//!
//! The paper's pipeline keeps the generation fleet saturated with
//! rollouts; production wants the *same* weights answering user traffic
//! without a second deployment. [`Gateway`] is the front door that makes
//! one [`GenerationService`] serve both:
//!
//! * **QoS classes** ([`QosClass`]) — `interactive` requests carry an
//!   admission-to-first-token SLO; `batch` (rollouts, offline bulk) is
//!   throughput traffic. Interactive admits first.
//! * **Continuous-batching admission** — the gateway hands the service a
//!   request only when a decode slot is free, so the engine never builds
//!   an internal queue it cannot shed; the gateway owns the bounded
//!   per-class queues and their backpressure policy
//!   (**shed-oldest-batch-first**: overflow evicts the oldest queued
//!   batch entry, falling back to the oldest interactive entry only when
//!   no batch work is queued).
//! * **Latency-sensitive preemption** — when every slot is busy, an
//!   interactive arrival evicts the *youngest* active batch sequence
//!   through the existing snapshot park machinery
//!   ([`GenerationService::preempt_victim`], the engine side of
//!   `sched::PreemptPolicy::Youngest`): the victim's generated prefix,
//!   logprobs, version tags and RNG cursor land in a gateway-owned
//!   [`MigrationHub`] and are re-imported when headroom returns, so **no
//!   rollout token is lost** — the hub's conservation books
//!   (`deposited == claimed + discarded + depth`) are asserted by the
//!   acceptance scenario.
//! * **Per-tenant KV budgets** — external tenants are capped at
//!   `tenant_kv_frac` of the service's KV blocks (estimated per
//!   admission from [`GenerationService::kv_pressure`]); the house
//!   tenant [`ROLLOUT_TENANT`] — the training run itself — is exempt.
//! * **Drain/pause semantics** — wired to the PR 7 control plane
//!   ([`ControlGate`]): `Draining` rejects new submissions and finishes
//!   what is in flight; `Paused` additionally parks everything to the
//!   hub and decodes nothing; the gateway reports its in-custody load
//!   under [`GATEWAY_LEDGER_ID`] so a drain can observe quiescence.
//!
//! `[gateway] enabled = false` (the default) constructs no gateway at
//! all — existing runs are bit-for-bit identical, pinned by a golden
//! digest under the tier1 seed rotation (tests/determinism.rs).
//!
//! [`SimService`] is the device-free reference implementation of
//! [`GenerationService`] (deterministic hash tokens, real
//! `BlockAllocator` accounting, optional golden-digest hook): it backs
//! the conformance suite, the open-loop SLO acceptance scenario
//! (tests/gateway.rs, driven by `simcluster::arrival` traces) and
//! `benches/gateway.rs`, none of which need PJRT.

use crate::config::GatewayConfig;
use crate::control::{AdmissionPhase, ControlGate, GATEWAY_LEDGER_ID};
use crate::data::task::Problem;
use crate::engine::{
    BlockAllocator, CompletionRequest, GenerationService, KvPressure, QosClass, ROLLOUT_TENANT,
};
use crate::metrics::MetricsHub;
use crate::rl::{FinishReason, Rollout};
use crate::runtime::HostTensor;
use crate::sched::{MigrationHub, PreemptPolicy, SeqSnapshot, SeqView};
use crate::testkit::{DigestEvent, EventLog, RunDigest};
use anyhow::{bail, Result};
use std::collections::{BTreeMap, VecDeque};
use std::sync::Arc;

/// Admission ledger entry for one request, from arrival to completion.
/// Ticks are gateway step counts (the gateway's only clock), so every
/// latency derived from them is deterministic and device-free.
#[derive(Debug, Clone)]
pub struct RequestTicket {
    pub qos: QosClass,
    pub tenant: u64,
    pub problem_id: u64,
    /// gateway tick at submission
    pub arrived_tick: u64,
    /// gateway tick the request entered the service (None = still queued
    /// or shed)
    pub admitted_tick: Option<u64>,
    /// service-side sequence id, re-pointed on every park/reclaim cycle
    pub engine_seq: Option<u64>,
    /// gateway tick the rollout completed (or the ticket was shed)
    pub finished_tick: Option<u64>,
    /// dropped by backpressure before ever reaching the service
    pub shed: bool,
    /// KV blocks charged against the tenant budget while admitted
    pub kv_est: usize,
}

/// Event counters mirrored into [`MetricsHub`] when one is attached.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct GatewayStats {
    pub submitted_interactive: u64,
    pub submitted_batch: u64,
    pub admitted_interactive: u64,
    pub admitted_batch: u64,
    pub finished_interactive: u64,
    pub finished_batch: u64,
    /// submissions refused because the control plane is draining/paused
    pub rejected_not_admitting: u64,
    pub shed_batch: u64,
    pub shed_interactive: u64,
    /// batch sequences parked to make room for interactive arrivals
    pub qos_preemptions: u64,
    /// parked sequences re-imported once headroom returned
    pub reclaimed: u64,
}

/// The QoS-classed front door (module docs). Wraps any
/// [`GenerationService`] and is itself one, so the coordinator, the
/// conformance suite and the benches drive a `Gateway<Engine>` and a
/// bare `Engine` through the same trait.
pub struct Gateway<S: GenerationService> {
    svc: S,
    cfg: GatewayConfig,
    /// queued ticket ids, per class, arrival order
    q_interactive: VecDeque<u64>,
    q_batch: VecDeque<u64>,
    /// queued (not yet admitted) requests by ticket id
    queued: BTreeMap<u64, CompletionRequest>,
    tickets: BTreeMap<u64, RequestTicket>,
    next_ticket: u64,
    /// gateway-owned park for QoS-preempted / pause-parked sequences
    parked: Arc<MigrationHub>,
    /// problems held for re-import after a park, refcounted per ticket
    problems: BTreeMap<u64, (Problem, usize)>,
    /// service seq id -> ticket id, for every admitted sequence
    seq_ticket: BTreeMap<u64, u64>,
    /// parked snapshot's (old) seq id -> ticket id, until reclaimed
    parked_tickets: BTreeMap<u64, u64>,
    /// service seq id -> class, the preemption filter's view
    active: BTreeMap<u64, QosClass>,
    /// KV blocks currently charged per external tenant
    tenant_blocks: BTreeMap<u64, usize>,
    gate: Option<ControlGate>,
    hub: Option<MetricsHub>,
    tick: u64,
    /// the Paused park already ran for the current pause episode
    paused_parked: bool,
    stats: GatewayStats,
}

impl<S: GenerationService> Gateway<S> {
    pub fn new(svc: S, cfg: GatewayConfig) -> Self {
        Gateway {
            svc,
            cfg,
            q_interactive: VecDeque::new(),
            q_batch: VecDeque::new(),
            queued: BTreeMap::new(),
            tickets: BTreeMap::new(),
            next_ticket: 1,
            parked: Arc::new(MigrationHub::new()),
            problems: BTreeMap::new(),
            seq_ticket: BTreeMap::new(),
            parked_tickets: BTreeMap::new(),
            active: BTreeMap::new(),
            tenant_blocks: BTreeMap::new(),
            gate: None,
            hub: None,
            tick: 0,
            paused_parked: false,
            stats: GatewayStats::default(),
        }
    }

    /// Wire the control plane's admission gate (pause/drain semantics +
    /// the in-custody load ledger).
    pub fn with_control(mut self, gate: ControlGate) -> Self {
        self.gate = Some(gate);
        self
    }

    /// Attach a metrics sink: per-class queue-depth / admit-wait /
    /// latency series and admit/shed/preempt counters.
    pub fn with_metrics(mut self, hub: MetricsHub) -> Self {
        self.hub = Some(hub);
        self
    }

    pub fn svc(&self) -> &S {
        &self.svc
    }

    pub fn svc_mut(&mut self) -> &mut S {
        &mut self.svc
    }

    /// The gateway-owned park (QoS-preempted and pause-parked work).
    pub fn parked(&self) -> &MigrationHub {
        &self.parked
    }

    pub fn stats(&self) -> &GatewayStats {
        &self.stats
    }

    pub fn ticket(&self, id: u64) -> Option<&RequestTicket> {
        self.tickets.get(&id)
    }

    pub fn tickets(&self) -> &BTreeMap<u64, RequestTicket> {
        &self.tickets
    }

    pub fn tick(&self) -> u64 {
        self.tick
    }

    /// (interactive, batch) queue depths.
    pub fn queue_depths(&self) -> (usize, usize) {
        (self.q_interactive.len(), self.q_batch.len())
    }

    /// Everything the gateway is responsible for right now: queued +
    /// parked + in the service. This is what the load ledger reports —
    /// a drain is quiescent only when all three are empty.
    pub fn in_custody(&self) -> usize {
        self.queued.len() + self.parked.depth() + self.svc.load()
    }

    fn free_slots(&self) -> usize {
        self.svc.slots().saturating_sub(self.svc.load())
    }

    /// Per-admission KV charge: an even split of the pool across slots
    /// (the a-priori estimate — the service's allocator enforces the
    /// real accounting; this bound is the *leasing* policy).
    fn kv_estimate(&self) -> usize {
        let total = self.svc.kv_pressure().total_blocks;
        (total / self.svc.slots().max(1)).max(1)
    }

    fn tenant_budget(&self) -> usize {
        let total = self.svc.kv_pressure().total_blocks;
        ((self.cfg.tenant_kv_frac * total as f64).floor() as usize).max(1)
    }

    fn tenant_fits(&self, tenant: u64, est: usize) -> bool {
        if tenant == ROLLOUT_TENANT {
            return true;
        }
        let held = self.tenant_blocks.get(&tenant).copied().unwrap_or(0);
        held + est <= self.tenant_budget()
    }

    /// Close a ticket's books: release the tenant KV charge and the
    /// problem refcount. `shed` marks backpressure drops and failed
    /// re-imports (work that left custody without completing).
    fn release_ticket(&mut self, tid: u64, shed: bool) {
        let (tenant, est, problem_id) = {
            let Some(t) = self.tickets.get_mut(&tid) else { return };
            t.shed = shed;
            t.finished_tick = Some(self.tick);
            let out = (t.tenant, t.kv_est, t.problem_id);
            t.kv_est = 0;
            out
        };
        if tenant != ROLLOUT_TENANT && est > 0 {
            let drop_entry = match self.tenant_blocks.get_mut(&tenant) {
                Some(held) => {
                    *held = held.saturating_sub(est);
                    *held == 0
                }
                None => false,
            };
            if drop_entry {
                self.tenant_blocks.remove(&tenant);
            }
        }
        if let Some(entry) = self.problems.get_mut(&problem_id) {
            entry.1 = entry.1.saturating_sub(1);
            if entry.1 == 0 {
                self.problems.remove(&problem_id);
            }
        }
    }

    /// Move one queued ticket into the service (caller verified a free
    /// slot and the tenant budget).
    fn admit_ticket(&mut self, tid: u64) -> Result<()> {
        let req = self.queued.remove(&tid).expect("queued request for ticket");
        let qos = req.qos;
        let tenant = req.tenant;
        let est = self.kv_estimate();
        let seq = self.svc.submit(req)?;
        let wait = {
            let t = self.tickets.get_mut(&tid).expect("ticket exists while queued");
            t.admitted_tick = Some(self.tick);
            t.engine_seq = Some(seq);
            t.kv_est = if tenant == ROLLOUT_TENANT { 0 } else { est };
            (self.tick - t.arrived_tick) as f64
        };
        self.seq_ticket.insert(seq, tid);
        self.active.insert(seq, qos);
        if tenant != ROLLOUT_TENANT {
            *self.tenant_blocks.entry(tenant).or_insert(0) += est;
        }
        match qos {
            QosClass::Interactive => self.stats.admitted_interactive += 1,
            QosClass::Batch => self.stats.admitted_batch += 1,
        }
        if let Some(h) = &self.hub {
            let t = self.tick as f64;
            h.record(&format!("gateway/admit_wait_{}", qos.name()), t, t, wait);
            h.add(&format!("gateway/admitted_{}", qos.name()), 1.0);
        }
        Ok(())
    }

    /// Park the youngest active batch sequence into the gateway hub to
    /// free a slot for an interactive arrival. Returns false when no
    /// batch sequence is active (interactive work is never evicted for
    /// interactive work).
    fn preempt_one_batch(&mut self) -> bool {
        let allowed: Vec<u64> = self
            .active
            .iter()
            .filter(|(_, q)| **q == QosClass::Batch)
            .map(|(s, _)| *s)
            .collect();
        if allowed.is_empty() {
            return false;
        }
        let Some(snap) = self.svc.preempt_victim(&allowed) else {
            return false;
        };
        if let Some(tid) = self.seq_ticket.remove(&snap.seq_id) {
            self.parked_tickets.insert(snap.seq_id, tid);
        }
        self.active.remove(&snap.seq_id);
        self.stats.qos_preemptions += 1;
        if let Some(h) = &self.hub {
            h.add("gateway/qos_preemptions", 1.0);
        }
        self.parked.deposit(vec![snap]);
        true
    }

    /// Re-import parked sequences while slots are free (oldest first —
    /// the hub is FIFO). Runs while Running *and* Draining: parked work
    /// is already-admitted in-flight work, and draining keeps decoding
    /// what is in flight.
    fn reclaim_parked(&mut self) -> Result<()> {
        while self.free_slots() > 0 {
            let Some(snap) = self.parked.claim(1).pop() else { break };
            let Some((problem, _)) = self.problems.get(&snap.problem_id) else {
                // not a deposit we made (no problem held): refuse it and
                // keep the books balanced — it lands in `discarded`
                self.parked.reject(&snap);
                continue;
            };
            let problem = problem.clone();
            match self.svc.import_snapshot(&snap, problem) {
                Ok(new_seq) => {
                    let tid = self.parked_tickets.remove(&snap.seq_id);
                    let qos = tid
                        .and_then(|tid| self.tickets.get(&tid).map(|t| t.qos))
                        .unwrap_or(QosClass::Batch);
                    if let Some(tid) = tid {
                        self.seq_ticket.insert(new_seq, tid);
                        if let Some(t) = self.tickets.get_mut(&tid) {
                            t.engine_seq = Some(new_seq);
                        }
                    }
                    self.active.insert(new_seq, qos);
                    self.stats.reclaimed += 1;
                    if let Some(h) = &self.hub {
                        h.add("gateway/reclaimed", 1.0);
                    }
                }
                Err(_) => {
                    // importer refused (config skew, malformed): move the
                    // tokens to the discarded column and close the ticket
                    self.parked.reject(&snap);
                    if let Some(tid) = self.parked_tickets.remove(&snap.seq_id) {
                        self.release_ticket(tid, true);
                    }
                }
            }
        }
        Ok(())
    }

    fn report_load(&self) {
        if let Some(g) = &self.gate {
            g.report_load(GATEWAY_LEDGER_ID, self.in_custody());
        }
    }
}

impl<S: GenerationService> GenerationService for Gateway<S> {
    /// Enqueue under backpressure. Returns a gateway **ticket id** (not
    /// a service sequence id — the request has not reached the service
    /// yet); track it via [`Gateway::ticket`].
    fn submit(&mut self, req: CompletionRequest) -> Result<u64> {
        if let Some(g) = &self.gate {
            if !g.admitting() {
                self.stats.rejected_not_admitting += 1;
                if let Some(h) = &self.hub {
                    h.add("gateway/rejected", 1.0);
                }
                bail!("gateway is not admitting (phase {:?})", g.phase());
            }
        }
        // bounded admission buffer: both class queues share one total
        // bound; overflow sheds the oldest *batch* entry first and
        // touches interactive only when no batch work is queued
        while self.q_interactive.len() + self.q_batch.len()
            >= self.cfg.interactive_queue + self.cfg.batch_queue
        {
            let Some(vtid) = self
                .q_batch
                .pop_front()
                .or_else(|| self.q_interactive.pop_front())
            else {
                break;
            };
            self.queued.remove(&vtid);
            let vqos = self.tickets[&vtid].qos;
            match vqos {
                QosClass::Batch => self.stats.shed_batch += 1,
                QosClass::Interactive => self.stats.shed_interactive += 1,
            }
            if let Some(h) = &self.hub {
                h.add(&format!("gateway/shed_{}", vqos.name()), 1.0);
            }
            self.release_ticket(vtid, true);
        }
        let tid = self.next_ticket;
        self.next_ticket += 1;
        self.problems
            .entry(req.problem.id)
            .and_modify(|e| e.1 += 1)
            .or_insert_with(|| (req.problem.clone(), 1));
        self.tickets.insert(
            tid,
            RequestTicket {
                qos: req.qos,
                tenant: req.tenant,
                problem_id: req.problem.id,
                arrived_tick: self.tick,
                admitted_tick: None,
                engine_seq: None,
                finished_tick: None,
                shed: false,
                kv_est: 0,
            },
        );
        match req.qos {
            QosClass::Interactive => {
                self.q_interactive.push_back(tid);
                self.stats.submitted_interactive += 1;
            }
            QosClass::Batch => {
                self.q_batch.push_back(tid);
                self.stats.submitted_batch += 1;
            }
        }
        if let Some(h) = &self.hub {
            h.add(&format!("gateway/submitted_{}", req.qos.name()), 1.0);
        }
        self.queued.insert(tid, req);
        Ok(tid)
    }

    fn init_process_group(&mut self, group: &str) -> Result<()> {
        self.svc.init_process_group(group)
    }

    fn request_weight_update(&mut self, version: u64, params: &[HostTensor]) -> Result<()> {
        self.svc.request_weight_update(version, params)
    }

    /// One gateway tick: pump admission (interactive first, preempting
    /// batch when configured; then reclaim parked work; then batch),
    /// record metrics, then advance the wrapped service one step.
    fn step(&mut self) -> Result<Vec<Rollout>> {
        self.tick += 1;
        let phase = self
            .gate
            .as_ref()
            .map(|g| g.phase())
            .unwrap_or(AdmissionPhase::Running);
        if phase == AdmissionPhase::Paused {
            if !self.paused_parked {
                // park *everything* in flight to the hub; queued work
                // stays queued (it never reached the service)
                let snaps = self.svc.export_snapshots();
                for s in &snaps {
                    if let Some(tid) = self.seq_ticket.remove(&s.seq_id) {
                        self.parked_tickets.insert(s.seq_id, tid);
                    }
                    self.active.remove(&s.seq_id);
                }
                self.parked.deposit(snaps);
                self.paused_parked = true;
            }
            self.report_load();
            return Ok(Vec::new());
        }
        self.paused_parked = false;
        let admitting = phase == AdmissionPhase::Running;

        if admitting {
            // interactive admission, evicting batch when slots are full
            loop {
                let est = self.kv_estimate();
                let Some(qpos) = self
                    .q_interactive
                    .iter()
                    .position(|tid| self.tenant_fits(self.tickets[tid].tenant, est))
                else {
                    break;
                };
                if self.free_slots() == 0 && !(self.cfg.preempt && self.preempt_one_batch()) {
                    break;
                }
                if self.free_slots() == 0 {
                    break; // preemption freed nothing the service admits
                }
                let tid = self.q_interactive.remove(qpos).expect("position valid");
                self.admit_ticket(tid)?;
            }
        }
        self.reclaim_parked()?;
        if admitting {
            // batch admission fills whatever headroom is left
            while self.free_slots() > 0 {
                let est = self.kv_estimate();
                let Some(qpos) = self
                    .q_batch
                    .iter()
                    .position(|tid| self.tenant_fits(self.tickets[tid].tenant, est))
                else {
                    break;
                };
                let tid = self.q_batch.remove(qpos).expect("position valid");
                self.admit_ticket(tid)?;
            }
        }
        if let Some(h) = &self.hub {
            let t = self.tick as f64;
            h.record("gateway/queue_interactive", t, t, self.q_interactive.len() as f64);
            h.record("gateway/queue_batch", t, t, self.q_batch.len() as f64);
            h.record("gateway/parked", t, t, self.parked.depth() as f64);
        }

        let done = self.svc.step()?;
        for r in &done {
            self.active.remove(&r.seq_id);
            if let Some(tid) = self.seq_ticket.remove(&r.seq_id) {
                let (qos, admitted) = {
                    let t = &self.tickets[&tid];
                    (t.qos, t.admitted_tick.unwrap_or(self.tick))
                };
                match qos {
                    QosClass::Interactive => self.stats.finished_interactive += 1,
                    QosClass::Batch => self.stats.finished_batch += 1,
                }
                if let Some(h) = &self.hub {
                    let t = self.tick as f64;
                    h.record(
                        &format!("gateway/latency_{}", qos.name()),
                        t,
                        t,
                        (self.tick - admitted) as f64,
                    );
                    h.add(&format!("gateway/finished_{}", qos.name()), 1.0);
                }
                self.release_ticket(tid, false);
            }
        }
        // report *after* the service step so a drain that just finished
        // its last sequence is observed as quiescent this very tick
        self.report_load();
        Ok(done)
    }

    fn load(&self) -> usize {
        self.in_custody()
    }

    fn slots(&self) -> usize {
        self.svc.slots()
    }

    /// Drain the service *and* the gateway park — the caller takes
    /// custody of every in-flight sequence. Queued (never-admitted)
    /// requests stay queued; they hold no engine state to export.
    fn export_snapshots(&mut self) -> Vec<SeqSnapshot> {
        let mut out = self.svc.export_snapshots();
        for s in &out {
            if let Some(tid) = self.seq_ticket.remove(&s.seq_id) {
                self.parked_tickets.insert(s.seq_id, tid);
            }
            self.active.remove(&s.seq_id);
        }
        loop {
            let got = self.parked.claim(64);
            if got.is_empty() {
                break;
            }
            out.extend(got);
        }
        out
    }

    fn import_snapshot(&mut self, snap: &SeqSnapshot, problem: Problem) -> Result<u64> {
        let seq = self.svc.import_snapshot(snap, problem.clone())?;
        if let Some(tid) = self.parked_tickets.remove(&snap.seq_id) {
            // one of ours coming home: re-point its ticket
            let qos = self.tickets.get(&tid).map(|t| t.qos).unwrap_or_default();
            if let Some(t) = self.tickets.get_mut(&tid) {
                t.engine_seq = Some(seq);
            }
            self.seq_ticket.insert(seq, tid);
            self.active.insert(seq, qos);
        } else {
            // adopted from another service instance: open a book for it
            // so finish accounting and the preemption filter stay total
            let tid = self.next_ticket;
            self.next_ticket += 1;
            self.problems
                .entry(problem.id)
                .and_modify(|e| e.1 += 1)
                .or_insert_with(|| (problem.clone(), 1));
            self.tickets.insert(
                tid,
                RequestTicket {
                    qos: QosClass::Batch,
                    tenant: ROLLOUT_TENANT,
                    problem_id: problem.id,
                    arrived_tick: self.tick,
                    admitted_tick: Some(self.tick),
                    engine_seq: Some(seq),
                    finished_tick: None,
                    shed: false,
                    kv_est: 0,
                },
            );
            self.seq_ticket.insert(seq, tid);
            self.active.insert(seq, QosClass::Batch);
        }
        Ok(seq)
    }

    fn kv_pressure(&self) -> KvPressure {
        self.svc.kv_pressure()
    }

    fn preempt_victim(&mut self, allowed: &[u64]) -> Option<SeqSnapshot> {
        let snap = self.svc.preempt_victim(allowed)?;
        if let Some(tid) = self.seq_ticket.remove(&snap.seq_id) {
            // the caller takes custody; remember the ticket in case the
            // snapshot comes back through import_snapshot
            self.parked_tickets.insert(snap.seq_id, tid);
        }
        self.active.remove(&snap.seq_id);
        Some(snap)
    }
}

// ---------------------------------------------------------------------
// Device-free reference service
// ---------------------------------------------------------------------

fn avalanche(mut x: u64) -> u64 {
    x ^= x >> 33;
    x = x.wrapping_mul(0xff51afd7ed558ccd);
    x ^= x >> 33;
    x = x.wrapping_mul(0xc4ceb9fe1a85ec53);
    x ^ (x >> 33)
}

#[derive(Debug, Clone)]
struct SimSeq {
    seq_id: u64,
    group_id: u64,
    problem_id: u64,
    prompt: Vec<i32>,
    gen: Vec<i32>,
    lp: Vec<f32>,
    ver: Vec<u64>,
    /// deterministic generation length, a pure function of (seed,
    /// problem id) — the sim analogue of "the model decides when to stop"
    target_gen: usize,
    t_start: f64,
}

impl SimSeq {
    fn total_len(&self) -> usize {
        self.prompt.len() + self.gen.len()
    }
}

/// Device-free [`GenerationService`]: continuous batching over a fixed
/// slot pool, FIFO seating, one deterministic hash token per active row
/// per step, real [`BlockAllocator`] KV accounting, lossless
/// export/import/preempt through [`SeqSnapshot`], and an optional
/// golden-digest hook ([`SimService::with_digest`]) recording the exact
/// event stream an `Engine` run would. Everything the gateway tests,
/// the SLO acceptance scenario and `benches/gateway.rs` need, with no
/// PJRT runtime.
pub struct SimService {
    slots: Vec<Option<SimSeq>>,
    pending: VecDeque<SimSeq>,
    alloc: BlockAllocator,
    max_seq: usize,
    max_new: usize,
    seed: u64,
    next_seq: u64,
    step_no: u64,
    version: u64,
    preemptions: u64,
    /// seq id -> step_no its first token was generated (SLO probe)
    first_token: BTreeMap<u64, u64>,
    digest: Option<EventLog>,
}

impl SimService {
    pub fn new(slots: usize, max_seq: usize, block_size: usize, max_new: usize, seed: u64) -> Self {
        SimService {
            slots: (0..slots).map(|_| None).collect(),
            pending: VecDeque::new(),
            alloc: BlockAllocator::for_slots(slots, max_seq, block_size),
            max_seq,
            max_new: max_new.max(1),
            seed,
            next_seq: 1,
            step_no: 0,
            version: 0,
            preemptions: 0,
            first_token: BTreeMap::new(),
            digest: None,
        }
    }

    /// Record every generated token and completion into a golden
    /// [`EventLog`] — the digest-identity tests compare these.
    pub fn with_digest(mut self, log: EventLog) -> Self {
        self.digest = Some(log);
        self
    }

    pub fn digest(&self) -> Option<RunDigest> {
        self.digest.as_ref().map(|l| l.digest())
    }

    /// The full event log (when digesting) — so digest mismatches can be
    /// explained by their first diverging event, not just two hashes.
    pub fn event_log(&self) -> Option<&EventLog> {
        self.digest.as_ref()
    }

    /// Step number the sequence produced its first token (the service
    /// half of the admission-to-first-token SLO).
    pub fn first_token_step(&self, seq_id: u64) -> Option<u64> {
        self.first_token.get(&seq_id).copied()
    }

    pub fn step_no(&self) -> u64 {
        self.step_no
    }

    /// Deterministic generation length for `(seed, problem id)` — public
    /// so tests and benches can classify problems a priori (short
    /// interactive turns vs long rollouts) without running them.
    pub fn target_len(seed: u64, problem_id: u64, max_new: usize) -> usize {
        Self::target_for(seed, problem_id, max_new)
    }

    fn target_for(seed: u64, problem_id: u64, max_new: usize) -> usize {
        1 + (avalanche(seed ^ problem_id.wrapping_mul(0x9e3779b97f4a7c15)) % max_new as u64)
            as usize
    }

    fn token(seed: u64, seq_id: u64, idx: usize) -> i32 {
        (avalanche(seed ^ seq_id.rotate_left(17) ^ (idx as u64).wrapping_mul(0x100000001b3))
            % 50000) as i32
            + 2
    }

    fn snap_of(seq: &SimSeq) -> SeqSnapshot {
        SeqSnapshot {
            seq_id: seq.seq_id,
            group_id: seq.group_id,
            problem_id: seq.problem_id,
            prompt: seq.prompt.clone(),
            gen_tokens: seq.gen.clone(),
            behavior_lp: seq.lp.clone(),
            token_version: seq.ver.clone(),
            pos: if seq.gen.is_empty() { 0 } else { seq.total_len() - 1 },
            max_new: seq.target_gen.max(seq.gen.len()).max(1),
            rng_words: [0; 4],
            t_start: seq.t_start,
        }
    }
}

impl GenerationService for SimService {
    fn submit(&mut self, req: CompletionRequest) -> Result<u64> {
        if req.prompt_tokens.is_empty() {
            bail!("empty prompt");
        }
        if req.prompt_tokens.len() + 1 > self.max_seq {
            bail!(
                "prompt of {} tokens cannot generate within max_seq {}",
                req.prompt_tokens.len(),
                self.max_seq
            );
        }
        let seq_id = self.next_seq;
        self.next_seq += 1;
        let cap = self.max_seq - req.prompt_tokens.len();
        let target = Self::target_for(self.seed, req.problem.id, self.max_new).min(cap);
        self.pending.push_back(SimSeq {
            seq_id,
            group_id: req.group_id,
            problem_id: req.problem.id,
            prompt: req.prompt_tokens,
            gen: Vec::new(),
            lp: Vec::new(),
            ver: Vec::new(),
            target_gen: target,
            t_start: self.step_no as f64,
        });
        Ok(seq_id)
    }

    fn init_process_group(&mut self, _group: &str) -> Result<()> {
        Ok(())
    }

    fn request_weight_update(&mut self, version: u64, _params: &[HostTensor]) -> Result<()> {
        self.version = version;
        Ok(())
    }

    fn step(&mut self) -> Result<Vec<Rollout>> {
        self.step_no += 1;
        // seat pending FIFO into the lowest free slots; head-of-line
        // blocks under KV pressure (FIFO admission, like the engine)
        for i in 0..self.slots.len() {
            if self.slots[i].is_some() {
                continue;
            }
            let Some(front) = self.pending.front() else { break };
            if !self.alloc.can_admit(front.total_len()) {
                break;
            }
            let seq = self.pending.pop_front().expect("checked front");
            self.alloc.admit(seq.seq_id, seq.total_len())?;
            self.slots[i] = Some(seq);
        }
        let mut done = Vec::new();
        for i in 0..self.slots.len() {
            let Some(seq) = &mut self.slots[i] else { continue };
            if !self.alloc.grow(seq.seq_id, seq.total_len() + 1)? {
                continue; // block pressure: stall in place this step
            }
            let idx = seq.gen.len();
            let tok = Self::token(self.seed, seq.seq_id, idx);
            seq.gen.push(tok);
            seq.lp.push(-0.5 - 0.01 * (tok % 17) as f32);
            seq.ver.push(self.version);
            if idx == 0 {
                self.first_token.insert(seq.seq_id, self.step_no);
            }
            if let Some(log) = &mut self.digest {
                log.record(DigestEvent::Token {
                    seq: seq.seq_id,
                    index: idx as u32,
                    tok,
                    version: self.version,
                });
            }
            if seq.gen.len() >= seq.target_gen {
                let seq = self.slots[i].take().expect("active row");
                self.alloc.release(seq.seq_id)?;
                if let Some(log) = &mut self.digest {
                    log.record(DigestEvent::GroupComplete {
                        group: seq.group_id,
                        tokens: seq.gen.len() as u64,
                    });
                }
                done.push(Rollout {
                    seq_id: seq.seq_id,
                    problem_id: seq.problem_id,
                    group_id: seq.group_id,
                    actor_id: 0,
                    prompt_tokens: seq.prompt,
                    gen_tokens: seq.gen,
                    behavior_lp: seq.lp,
                    token_version: seq.ver,
                    reward: 0.0,
                    finish: FinishReason::Eos,
                    t_start: seq.t_start,
                    t_end: self.step_no as f64,
                });
            }
        }
        Ok(done)
    }

    fn load(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count() + self.pending.len()
    }

    fn slots(&self) -> usize {
        self.slots.len()
    }

    fn export_snapshots(&mut self) -> Vec<SeqSnapshot> {
        let mut out = Vec::new();
        for i in 0..self.slots.len() {
            if let Some(seq) = self.slots[i].take() {
                let _ = self.alloc.release(seq.seq_id);
                out.push(Self::snap_of(&seq));
            }
        }
        for seq in std::mem::take(&mut self.pending) {
            out.push(Self::snap_of(&seq));
        }
        out
    }

    fn import_snapshot(&mut self, snap: &SeqSnapshot, problem: Problem) -> Result<u64> {
        snap.validate()?;
        if problem.id != snap.problem_id {
            bail!(
                "snapshot belongs to problem {}, got problem {}",
                snap.problem_id,
                problem.id
            );
        }
        if snap.total_len() + 1 > self.max_seq {
            bail!(
                "snapshot of {} tokens cannot resume within max_seq {}",
                snap.total_len(),
                self.max_seq
            );
        }
        let seq_id = self.next_seq;
        self.next_seq += 1;
        let cap = self.max_seq - snap.prompt.len();
        // same stopping rule as a fresh submit, but a resumed sequence
        // always generates at least one more token (it was mid-flight)
        let target = Self::target_for(self.seed, snap.problem_id, self.max_new)
            .min(cap)
            .max(snap.gen_tokens.len() + 1);
        self.pending.push_back(SimSeq {
            seq_id,
            group_id: snap.group_id,
            problem_id: snap.problem_id,
            prompt: snap.prompt.clone(),
            gen: snap.gen_tokens.clone(),
            lp: snap.behavior_lp.clone(),
            ver: snap.token_version.clone(),
            target_gen: target,
            t_start: snap.t_start,
        });
        Ok(seq_id)
    }

    fn kv_pressure(&self) -> KvPressure {
        KvPressure {
            total_blocks: self.alloc.total_blocks(),
            free_blocks: self.alloc.free_blocks(),
            held_blocks: self.alloc.held_blocks(),
            saved_blocks: self.alloc.shared_saved_blocks(),
            preemptions: self.preemptions,
        }
    }

    fn preempt_victim(&mut self, allowed: &[u64]) -> Option<SeqSnapshot> {
        let mut slot_of = Vec::new();
        let mut views = Vec::new();
        for (i, s) in self.slots.iter().enumerate() {
            let Some(s) = s else { continue };
            if !allowed.contains(&s.seq_id) {
                continue;
            }
            slot_of.push(i);
            views.push(SeqView {
                seq_id: s.seq_id,
                group_id: s.group_id,
                total_len: s.total_len(),
                gen_len: s.gen.len(),
                pos: if s.gen.is_empty() { 0 } else { s.total_len() - 1 },
                kv_blocks: s.total_len().div_ceil(self.alloc.block_size()),
            });
        }
        let vidx = PreemptPolicy::Youngest.pick(&views)?;
        let seq = self.slots[slot_of[vidx]].take().expect("victim slot active");
        self.alloc.release(seq.seq_id).ok()?;
        self.preemptions += 1;
        Some(Self::snap_of(&seq))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::task::TaskKind;

    const SEED: u64 = 0xBEEF;

    fn problem(id: u64) -> Problem {
        Problem {
            kind: TaskKind::Add,
            prompt: format!("p{id}"),
            answer: "a".into(),
            trace: String::new(),
            id,
        }
    }

    fn batch_req(id: u64) -> CompletionRequest {
        CompletionRequest::rollout(problem(id), vec![2, 3, 4], id)
    }

    fn inter_req(id: u64, tenant: u64) -> CompletionRequest {
        CompletionRequest::interactive(problem(id), vec![2, 3, 4], id, tenant)
    }

    fn sim(slots: usize) -> SimService {
        SimService::new(slots, 32, 4, 6, SEED)
    }

    /// Problem ids whose deterministic sim generation length is >= 3
    /// under the shared test seed, so multi-step scenarios cannot race a
    /// one-token completion.
    fn long_pids(n: usize) -> Vec<u64> {
        (1u64..10_000)
            .filter(|p| SimService::target_for(SEED, *p, 6) >= 3)
            .take(n)
            .collect()
    }

    fn run_until_done<S: GenerationService>(svc: &mut S, max_steps: usize) -> Vec<Rollout> {
        let mut out = Vec::new();
        for _ in 0..max_steps {
            out.extend(svc.step().unwrap());
            if svc.load() == 0 {
                break;
            }
        }
        out
    }

    #[test]
    fn sim_service_is_deterministic() {
        let run = |seed| {
            let mut s = SimService::new(2, 32, 4, 6, seed);
            for i in 1..=4 {
                s.submit(batch_req(i)).unwrap();
            }
            run_until_done(&mut s, 200)
                .into_iter()
                .map(|r| (r.seq_id, r.gen_tokens))
                .collect::<Vec<_>>()
        };
        assert_eq!(run(7), run(7), "same seed, same streams");
        assert_ne!(run(7), run(8), "different seed, different streams");
    }

    #[test]
    fn sim_service_export_import_preserves_tokens() {
        let pids = long_pids(2);
        let mut s = sim(2);
        s.submit(batch_req(pids[0])).unwrap();
        s.submit(batch_req(pids[1])).unwrap();
        s.step().unwrap();
        let snaps = s.export_snapshots();
        assert_eq!(snaps.len(), 2);
        assert_eq!(s.load(), 0);
        for sn in &snaps {
            sn.validate().unwrap();
            assert_eq!(sn.gen_tokens.len(), 1, "one step generated one token each");
            s.import_snapshot(sn, problem(sn.problem_id)).unwrap();
        }
        let done = run_until_done(&mut s, 200);
        assert_eq!(done.len(), 2);
        for r in &done {
            // the parked prefix survives at the front of the rollout
            let sn = snaps.iter().find(|s| s.group_id == r.group_id).unwrap();
            assert_eq!(&r.gen_tokens[..sn.gen_tokens.len()], &sn.gen_tokens[..]);
        }
    }

    #[test]
    fn gateway_passes_batch_traffic_through_fifo() {
        let mut gw = Gateway::new(sim(2), GatewayConfig::default());
        let mut tids = Vec::new();
        for i in 1..=5 {
            tids.push(gw.submit(batch_req(i)).unwrap());
        }
        let done = run_until_done(&mut gw, 300);
        assert_eq!(done.len(), 5);
        let st = *gw.stats();
        assert_eq!(st.submitted_batch, 5);
        assert_eq!(st.admitted_batch, 5);
        assert_eq!(st.finished_batch, 5);
        assert_eq!(st.qos_preemptions, 0);
        assert_eq!(st.shed_batch, 0);
        for tid in tids {
            let t = gw.ticket(tid).unwrap();
            assert!(t.finished_tick.is_some() && !t.shed);
            assert!(t.admitted_tick.unwrap() >= t.arrived_tick);
        }
        assert_eq!(gw.in_custody(), 0);
        assert!(gw.parked().depth() == 0 && gw.parked().deposited() == 0);
    }

    #[test]
    fn overflow_sheds_oldest_batch_first() {
        let mut cfg = GatewayConfig::default();
        cfg.interactive_queue = 1;
        cfg.batch_queue = 2;
        // zero-slot service: nothing ever admits, the queues only fill
        let mut gw = Gateway::new(sim(0), cfg);
        let b1 = gw.submit(batch_req(1)).unwrap();
        let b2 = gw.submit(batch_req(2)).unwrap();
        let i1 = gw.submit(inter_req(3, 9)).unwrap();
        // buffer full (3 of 3): next submit sheds the OLDEST BATCH entry
        let b3 = gw.submit(batch_req(4)).unwrap();
        assert!(gw.ticket(b1).unwrap().shed);
        assert!(!gw.ticket(b2).unwrap().shed && !gw.ticket(i1).unwrap().shed);
        assert_eq!(gw.stats().shed_batch, 1);
        // drain the batch queue with interactive floods: batch goes
        // first, interactive is last to be touched
        let i2 = gw.submit(inter_req(5, 9)).unwrap();
        let i3 = gw.submit(inter_req(6, 9)).unwrap();
        assert!(gw.ticket(b2).unwrap().shed && gw.ticket(b3).unwrap().shed);
        assert!(!gw.ticket(i1).unwrap().shed);
        // only interactive left: now the oldest interactive is shed
        let i4 = gw.submit(inter_req(7, 9)).unwrap();
        assert!(gw.ticket(i1).unwrap().shed);
        assert_eq!(gw.stats().shed_interactive, 1);
        assert!(!gw.ticket(i2).unwrap().shed);
        let _ = (i3, i4);
    }

    #[test]
    fn interactive_preempts_batch_and_nothing_is_lost() {
        let pids = long_pids(3);
        let mut gw = Gateway::new(sim(2), GatewayConfig::default());
        gw.submit(batch_req(pids[0])).unwrap();
        gw.submit(batch_req(pids[1])).unwrap();
        gw.step().unwrap(); // both batch seated, one token each
        assert_eq!(gw.svc().load(), 2);
        gw.submit(inter_req(pids[2], 9)).unwrap();
        gw.step().unwrap();
        let st = *gw.stats();
        assert_eq!(st.qos_preemptions, 1, "a batch victim was parked");
        assert_eq!(st.admitted_interactive, 1);
        assert_eq!(gw.parked().deposited(), 1);
        let (dep_tok, _) = gw.parked().token_counts();
        assert!(dep_tok >= 1, "the victim's generated prefix was salvaged");
        // run to completion: the parked batch sequence reclaims a slot
        // once the interactive one finishes, and every request completes
        let done = run_until_done(&mut gw, 400);
        assert_eq!(done.len(), 3, "all three rollouts completed");
        let st = *gw.stats();
        assert_eq!(st.reclaimed, 1);
        assert_eq!(st.finished_interactive, 1);
        assert_eq!(st.finished_batch, 2);
        // conservation: everything deposited was claimed back
        let hub = gw.parked();
        assert_eq!(hub.deposited(), hub.claimed() + hub.discarded() + hub.depth() as u64);
        assert_eq!(hub.depth(), 0);
        assert_eq!(hub.discarded(), 0);
        let (dep, cl) = hub.token_counts();
        assert_eq!(dep, cl, "zero salvageable tokens lost");
        assert_eq!(gw.in_custody(), 0);
    }

    #[test]
    fn preempt_disabled_makes_interactive_wait() {
        let pids = long_pids(2);
        let mut cfg = GatewayConfig::default();
        cfg.preempt = false;
        // single slot: the per-admission estimate is the whole pool, so
        // an external tenant needs the full-pool lease to admit at all
        cfg.tenant_kv_frac = 1.0;
        let mut gw = Gateway::new(sim(1), cfg);
        gw.submit(batch_req(pids[0])).unwrap();
        gw.step().unwrap();
        gw.submit(inter_req(pids[1], 9)).unwrap();
        gw.step().unwrap();
        assert_eq!(gw.stats().qos_preemptions, 0);
        assert_eq!(gw.stats().admitted_interactive, 0, "waits for the slot");
        let done = run_until_done(&mut gw, 400);
        assert_eq!(done.len(), 2);
        assert_eq!(gw.stats().admitted_interactive, 1);
    }

    #[test]
    fn tenant_kv_budget_gates_admission() {
        let mut cfg = GatewayConfig::default();
        // per-admission estimate is total/slots = 1/4 of the pool; a
        // budget of one quarter admits exactly one concurrent request
        // for the tenant
        cfg.tenant_kv_frac = 0.25;
        let mut gw = Gateway::new(sim(4), cfg);
        gw.submit(inter_req(1, 7)).unwrap();
        gw.submit(inter_req(2, 7)).unwrap();
        gw.step().unwrap();
        assert_eq!(
            gw.stats().admitted_interactive,
            1,
            "second request exceeds tenant 7's KV lease"
        );
        // the house tenant is exempt: rollouts still admit freely
        gw.submit(batch_req(3)).unwrap();
        gw.step().unwrap();
        assert_eq!(gw.stats().admitted_batch, 1);
        // once the first finishes, the lease frees and the second admits
        let done = run_until_done(&mut gw, 400);
        assert_eq!(done.len(), 3);
        assert_eq!(gw.stats().admitted_interactive, 2);
    }

    #[test]
    fn draining_rejects_new_work_but_finishes_in_flight() {
        let pids = long_pids(1);
        let gate = ControlGate::new();
        let mut gw = Gateway::new(sim(2), GatewayConfig::default()).with_control(gate.clone());
        gw.submit(batch_req(pids[0])).unwrap();
        gw.step().unwrap();
        gate.set_phase(AdmissionPhase::Draining);
        assert!(gw.submit(batch_req(2)).is_err());
        assert_eq!(gw.stats().rejected_not_admitting, 1);
        let done = run_until_done(&mut gw, 200);
        assert_eq!(done.len(), 1, "in-flight work still completes");
        assert_eq!(gate.total_load(), 0, "ledger reports quiescence");
    }

    #[test]
    fn pause_parks_everything_and_resume_reclaims() {
        let pids = long_pids(2);
        let gate = ControlGate::new();
        let mut gw = Gateway::new(sim(2), GatewayConfig::default()).with_control(gate.clone());
        gw.submit(batch_req(pids[0])).unwrap();
        gw.submit(batch_req(pids[1])).unwrap();
        gw.step().unwrap();
        gate.set_phase(AdmissionPhase::Paused);
        let out = gw.step().unwrap();
        assert!(out.is_empty(), "paused gateway decodes nothing");
        assert_eq!(gw.svc().load(), 0, "everything left the service");
        assert_eq!(gw.parked().depth(), 2);
        gw.step().unwrap(); // idempotent: no double park
        assert_eq!(gw.parked().deposited(), 2);
        gate.set_phase(AdmissionPhase::Running);
        let done = run_until_done(&mut gw, 400);
        assert_eq!(done.len(), 2);
        assert_eq!(gw.stats().reclaimed, 2);
        let hub = gw.parked();
        assert_eq!(hub.deposited(), hub.claimed());
        let (dep, cl) = hub.token_counts();
        assert_eq!(dep, cl, "pause/resume lost no salvaged tokens");
    }

    #[test]
    fn gateway_export_drains_service_and_park() {
        let pids = long_pids(3);
        let mut cfg = GatewayConfig::default();
        cfg.tenant_kv_frac = 1.0; // single slot: see preempt_disabled test
        let mut gw = Gateway::new(sim(1), cfg);
        gw.submit(batch_req(pids[0])).unwrap();
        gw.submit(batch_req(pids[1])).unwrap();
        gw.step().unwrap(); // first batch seated; second queued in the gateway
        gw.submit(inter_req(pids[2], 9)).unwrap();
        gw.step().unwrap(); // preempts the seated batch into the park
        assert_eq!(gw.parked().depth(), 1);
        let snaps = gw.export_snapshots();
        // interactive from the service + the parked batch victim
        assert_eq!(snaps.len(), 2);
        assert_eq!(gw.svc().load(), 0);
        assert_eq!(gw.parked().depth(), 0);
        // bring one home: its ticket re-attaches with its class
        let victim = snaps.iter().find(|s| s.group_id == pids[0]).unwrap();
        gw.import_snapshot(victim, problem(pids[0])).unwrap();
        let done = run_until_done(&mut gw, 400);
        // the re-imported victim plus the still-queued batch request
        assert_eq!(done.len(), 2);
    }

    #[test]
    fn metrics_hub_sees_gateway_series() {
        let hub = MetricsHub::new();
        let mut cfg = GatewayConfig::default();
        cfg.tenant_kv_frac = 1.0; // single slot: see preempt_disabled test
        let mut gw = Gateway::new(sim(1), cfg).with_metrics(hub.clone());
        gw.submit(batch_req(1)).unwrap();
        gw.submit(inter_req(2, 9)).unwrap();
        let _ = run_until_done(&mut gw, 300);
        assert_eq!(hub.counter("gateway/submitted_batch"), 1.0);
        assert_eq!(hub.counter("gateway/submitted_interactive"), 1.0);
        assert_eq!(hub.counter("gateway/finished_interactive"), 1.0);
        assert!(!hub.series("gateway/queue_interactive").points.is_empty());
        assert_eq!(hub.series("gateway/admit_wait_interactive").points.len(), 1);
        assert_eq!(hub.series("gateway/latency_batch").points.len(), 1);
    }
}
