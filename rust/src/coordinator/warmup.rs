//! SFT warmup — the "base model" stand-in (DESIGN.md §2).
//!
//! The paper starts RL from a pretrained Qwen base model. We create the
//! equivalent by supervised training on worked chain-of-thought traces
//! (the task generator emits the ground-truth trace for every problem),
//! using the AOT sft graph. The warmed-up model emits well-formed
//! `c:`/`a:` lines with imperfect arithmetic — exactly the "base model
//! that can format but must learn to reason" starting point RL needs.

use super::packing::Packer;
use crate::config::RunConfig;
use crate::data::{task::TaskGen, Dataset};
use crate::metrics::MetricsHub;
use crate::model::Tokenizer;
use crate::rl::{FinishReason, Rollout};
use crate::runtime::{HostTensor, Runtime};
use crate::util::logging::Logger;
use crate::util::timer::global_seconds;
use anyhow::{Context, Result};

/// Run `cfg.sft_steps` of supervised warmup; returns the parameters.
pub fn run_sft(rt: &mut Runtime, cfg: &RunConfig, hub: &MetricsHub) -> Result<Vec<HostTensor>> {
    let log = Logger::new("sft");
    let variant = rt.manifest.variant(&cfg.variant)?.clone();
    let graph = rt.graph(&cfg.variant, "sft")?;
    let p = variant.params.len();
    let tokenizer = Tokenizer::new();
    let task_gen = TaskGen::new(cfg.task.kinds.clone(), cfg.task.max_operand);
    let mut dataset = Dataset::new(task_gen, cfg.task.pool, cfg.seed ^ 0x5f7);

    let mut params = rt.init_params(&cfg.variant, cfg.seed as i32)?;
    let mut m = rt.zero_opt_state(&cfg.variant)?;
    let mut v = rt.zero_opt_state(&cfg.variant)?;

    let (b, t) = (variant.train_batch, variant.seq_len);
    for step in 1..=cfg.sft_steps {
        // pack ground-truth traces as pseudo-rollouts (mask covers trace)
        let mut packer = Packer::new(b, t);
        loop {
            let problem = dataset.sample_train();
            let prompt = tokenizer.encode(&problem.prompt)?;
            let mut trace = tokenizer.encode(&problem.trace)?;
            trace.push(crate::model::tokenizer::EOS_ID);
            let n = trace.len();
            let pseudo = Rollout {
                seq_id: 0,
                problem_id: problem.id,
                group_id: 0,
                actor_id: 0,
                prompt_tokens: std::iter::once(crate::model::tokenizer::BOS_ID)
                    .chain(prompt)
                    .collect(),
                gen_tokens: trace,
                behavior_lp: vec![0.0; n],
                token_version: vec![0; n],
                reward: 0.0,
                finish: FinishReason::Eos,
                t_start: 0.0,
                t_end: 0.0,
            };
            if !packer.try_add(&pseudo, 0.0) {
                break;
            }
            if packer.fill_fraction() > 0.9 {
                break;
            }
        }
        let batch = packer.flush();

        let mut inputs: Vec<HostTensor> = Vec::with_capacity(3 * p + 6);
        inputs.extend(params.iter().cloned());
        inputs.extend(m.iter().cloned());
        inputs.extend(v.iter().cloned());
        inputs.push(HostTensor::scalar_f32(step as f32));
        inputs.push(HostTensor::from_i32(&[b, t], batch.tokens));
        inputs.push(HostTensor::from_i32(&[b, t], batch.seg));
        inputs.push(HostTensor::from_i32(&[b, t], batch.pos));
        inputs.push(HostTensor::from_f32(&[b, t], batch.mask));
        inputs.push(HostTensor::scalar_f32(cfg.sft_lr as f32));
        let mut out = graph.run_host(&inputs).context("sft step")?;
        let metrics = out.split_off(3 * p).remove(0);
        let v_new = out.split_off(2 * p);
        let m_new = out.split_off(p);
        params = out;
        m = m_new;
        v = v_new;

        let loss = metrics.f32s()?[0] as f64;
        hub.record("sft/loss", global_seconds(), step as f64, loss);
        if cfg.log_every > 0 && step % cfg.log_every == 0 {
            log.info(&format!("sft step {step:4} loss {loss:.4}"));
        }
    }
    Ok(params)
}
