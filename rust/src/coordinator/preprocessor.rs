//! Preprocessor stage (paper Fig. 4, middle box).
//!
//! Consumes the rollout stream, completes advantage groups, computes
//! group-baseline advantages, packs sequences online into fixed [B, T]
//! training batches and publishes them to the trainer topic.
//!
//! **Conventional mode** implements the paper's §5 tweak: it accumulates
//! the whole RL step's buffer (every sequence the Generate phase
//! produced), shuffles it, packs it into ~G batches, marks the last one,
//! and only then releases them — reproducing Alg. 1's lag structure
//! exactly (batch j trained at lag j).

use super::conv::ConvSync;
use super::packing::{Packer, TrainBatch};
use crate::broker::{Publisher, RecvError, Subscriber};
use crate::config::{Mode, RunConfig};
use crate::metrics::MetricsHub;
use crate::rl::{group_advantages, AdvantageMode, FinishReason, Rollout};
use crate::util::logging::Logger;
use crate::util::Rng;
use anyhow::Result;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

pub struct PreprocessorArgs {
    pub cfg: RunConfig,
    /// train-graph batch geometry (rows, seq_len) from the manifest
    pub b: usize,
    pub t: usize,
    pub rollout_rx: Subscriber<Rollout>,
    pub batch_tx: Publisher<TrainBatch>,
    pub hub: MetricsHub,
    pub stop: Arc<AtomicBool>,
    pub conv: Option<Arc<ConvSync>>,
}

pub fn run_preprocessor(args: PreprocessorArgs) -> Result<()> {
    let PreprocessorArgs { cfg, b, t, rollout_rx, batch_tx, hub, stop, conv } = args;
    let log = Logger::new("preproc");
    match cfg.mode {
        Mode::Pipeline => run_pipeline(&cfg, b, t, rollout_rx, batch_tx, hub, stop, log),
        Mode::Conventional { g } => run_conventional(
            &cfg,
            g,
            b,
            t,
            rollout_rx,
            batch_tx,
            hub,
            stop,
            conv.expect("conventional mode requires ConvSync"),
            log,
        ),
    }
}

/// Collect rollouts into groups; on completion compute advantages and
/// return (rollout, advantage) pairs ready for packing.
struct GroupCollector {
    group_size: usize,
    normalize: bool,
    pending: HashMap<u64, Vec<Rollout>>,
}

impl GroupCollector {
    fn new(cfg: &RunConfig) -> Self {
        GroupCollector {
            group_size: cfg.group_size,
            normalize: cfg.advantage == AdvantageMode::GroupNormalized,
            pending: HashMap::new(),
        }
    }

    fn add(&mut self, r: Rollout, hub: &MetricsHub) -> Vec<(Rollout, f32)> {
        // aborted/empty rollouts still count towards group completion but
        // are filtered out of the advantage computation
        if matches!(r.finish, FinishReason::Aborted) || r.gen_tokens.is_empty() {
            hub.add("rollouts_discarded", 1.0);
        }
        let gid = r.group_id;
        self.pending.entry(gid).or_default().push(r);
        self.maybe_complete(hub, gid)
    }

    fn maybe_complete(&mut self, hub: &MetricsHub, gid: u64) -> Vec<(Rollout, f32)> {
        let done = self
            .pending
            .get(&gid)
            .map(|v| v.len() >= self.group_size)
            .unwrap_or(false);
        if !done {
            return Vec::new();
        }
        let members: Vec<Rollout> = self
            .pending
            .remove(&gid)
            .unwrap()
            .into_iter()
            .filter(|r| {
                !r.gen_tokens.is_empty() && !matches!(r.finish, FinishReason::Aborted)
            })
            .collect();
        if members.is_empty() {
            return Vec::new();
        }
        let groups: Vec<u64> = members.iter().map(|r| r.group_id).collect();
        let rewards: Vec<f32> = members.iter().map(|r| r.reward).collect();
        let advs = group_advantages(&groups, &rewards, self.normalize);
        hub.add("groups_completed", 1.0);
        members.into_iter().zip(advs).collect()
    }

    fn n_pending(&self) -> usize {
        self.pending.len()
    }
}

#[allow(clippy::too_many_arguments)]
fn run_pipeline(
    cfg: &RunConfig,
    b: usize,
    t: usize,
    rollout_rx: Subscriber<Rollout>,
    batch_tx: Publisher<TrainBatch>,
    hub: MetricsHub,
    stop: Arc<AtomicBool>,
    log: Logger,
) -> Result<()> {
    let mut collector = GroupCollector::new(cfg);
    let mut packer = Packer::new(b, t);
    let mut ready: Vec<(Rollout, f32)> = Vec::new();
    loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        match rollout_rx.recv(Duration::from_millis(100)) {
            Ok(r) => ready.extend(collector.add(r, &hub)),
            Err(RecvError::Closed) => break,
            Err(RecvError::Timeout) => {
                // trickle flush: don't let a partial batch starve the trainer
                if !packer.is_empty() && ready.is_empty() && send(&mut packer, &batch_tx, &hub, false)? {
                    break;
                }
                continue;
            }
        }
        // pack everything that fits; flush when full
        let i = 0;
        while i < ready.len() {
            let (r, adv) = &ready[i];
            if packer.try_add(r, *adv) {
                ready.swap_remove(i);
            } else if !packer.is_empty() {
                if send(&mut packer, &batch_tx, &hub, false)? {
                    return Ok(());
                }
            } else {
                // single rollout longer than T — cannot ever fit
                hub.add("rollouts_too_long", 1.0);
                ready.swap_remove(i);
            }
        }
        // target fill reached? ship it
        if packer.fill_fraction() >= 0.85 && send(&mut packer, &batch_tx, &hub, false)? {
            break;
        }
    }
    log.debug(&format!(
        "preprocessor stopping ({} groups pending)",
        collector.n_pending()
    ));
    Ok(())
}

#[allow(clippy::too_many_arguments)]
fn run_conventional(
    cfg: &RunConfig,
    _g: usize,
    b: usize,
    t: usize,
    rollout_rx: Subscriber<Rollout>,
    batch_tx: Publisher<TrainBatch>,
    hub: MetricsHub,
    stop: Arc<AtomicBool>,
    conv: Arc<ConvSync>,
    log: Logger,
) -> Result<()> {
    let mut collector = GroupCollector::new(cfg);
    let mut rng = Rng::with_stream(cfg.seed, 0x5f00);
    loop {
        // accumulate the whole Generate phase's buffer
        let mut buffer: Vec<(Rollout, f32)> = Vec::new();
        loop {
            if stop.load(Ordering::Relaxed) {
                return Ok(());
            }
            match rollout_rx.recv(Duration::from_millis(50)) {
                Ok(r) => buffer.extend(collector.add(r, &hub)),
                Err(RecvError::Closed) => return Ok(()),
                Err(RecvError::Timeout) => {}
            }
            // phase flipped to Train once every sequence landed
            if conv.wait_train(Duration::from_millis(0)).is_some()
                && rollout_rx.depth() == 0
            {
                break;
            }
        }
        if buffer.is_empty() {
            continue;
        }
        // Alg. 1: shuffle the B*G buffer, then release the step's batches
        rng.shuffle(&mut buffer);
        hub.record(
            "conv/buffer_seqs",
            crate::util::timer::global_seconds(),
            hub.counter("groups_completed"),
            buffer.len() as f64,
        );
        // Alg. 1 splits the B·G buffer into exactly G optimizer batches:
        // chunk the shuffled buffer rather than packing to density (the
        // trainer must take G steps per RL step).
        let mut packer = Packer::new(b, t);
        let mut batches = Vec::new();
        let chunk = buffer.len().div_ceil(_g.max(1)).max(1);
        for group in buffer.chunks(chunk) {
            for (r, adv) in group {
                if !packer.try_add(r, *adv) {
                    if !packer.is_empty() {
                        batches.push(packer.flush());
                    }
                    if !packer.try_add(r, *adv) {
                        hub.add("rollouts_too_long", 1.0);
                    }
                }
            }
            if !packer.is_empty() {
                batches.push(packer.flush());
            }
        }
        let n = batches.len();
        log.debug(&format!("releasing {n} conventional batches"));
        for (i, mut batch) in batches.into_iter().enumerate() {
            batch.last_of_rl_step = i + 1 == n;
            hub.add("batches_packed", 1.0);
            if batch_tx.send(batch).is_err() {
                return Ok(()); // trainer disconnected: shutdown
            }
        }
    }
}

/// Returns true when the trainer has disconnected (graceful shutdown).
fn send(
    packer: &mut Packer,
    batch_tx: &Publisher<TrainBatch>,
    hub: &MetricsHub,
    last: bool,
) -> Result<bool> {
    let mut batch = packer.flush();
    batch.last_of_rl_step = last;
    hub.add("batches_packed", 1.0);
    hub.record(
        "preproc/batch_fill",
        crate::util::timer::global_seconds(),
        hub.counter("batches_packed"),
        batch.fill(),
    );
    // a send failure means the trainer is done and disconnected: shut down
    Ok(batch_tx.send(batch).is_err())
}
