//! Preprocessor stage (paper Fig. 4, middle box).
//!
//! Consumes the rollout stream, completes advantage groups, computes
//! group-baseline advantages, packs sequences online into fixed [B, T]
//! training batches and publishes them to the trainer topic.
//!
//! **IS-correction weight lane:** with `[rl] is_correction =
//! "truncated"` and a [`PolicyScorer`] wired (device-free harnesses and
//! tests; the real orchestrator passes `None` and lets the train graph
//! correct exactly at train time), each admitted rollout's per-token
//! truncated weights `min(c, exp(lp_pi - lp_mu))` are computed here and
//! packed into the batch's `is_w` lane; the batch is flagged
//! `host_weighted` so the trainer tells the graph to consume the lane.
//!
//! **Truncated rollouts** (`[rl] train_truncated = true`): sequences cut
//! off mid-generation arrive as `FinishReason::Truncated` and are
//! admitted as full group members (they count toward completion *and*
//! enter the advantage baseline). Conservation books guarantee a trained
//! prefix and its later continuation are never both trained: the
//! collector remembers each admitted prefix's (group, length, token
//! hash) and drops any later rollout in the same group whose generated
//! tokens extend one.
//!
//! **Periodic mode** shares the pipeline path — grouping, packing and
//! shipping are identical; only the trainer's publish cadence differs.
//!
//! **Conventional mode** implements the paper's §5 tweak: it accumulates
//! the whole RL step's buffer (every sequence the Generate phase
//! produced), shuffles it, packs it into ~G batches, marks the last one,
//! and only then releases them — reproducing Alg. 1's lag structure
//! exactly (batch j trained at lag j).

use super::conv::ConvSync;
use super::packing::{Packer, TrainBatch};
use crate::broker::{Publisher, RecvError, Subscriber};
use crate::config::{IsCorrection, Mode, RunConfig};
use crate::metrics::MetricsHub;
use crate::rl::{
    effective_sample_size, group_advantages, truncated_weights, AdvantageMode, FinishReason,
    Rollout,
};
use crate::util::logging::Logger;
use crate::util::Rng;
use anyhow::Result;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Current-policy scorer: returns lp_pi per generated token (parallel to
/// `gen_tokens`). Device-free harnesses wire synthetic scorers; the real
/// orchestrator passes `None` — the AOT train graph recomputes lp_pi
/// under the exact parameters being optimized, which is strictly fresher
/// than anything the preprocessor could score with.
pub type PolicyScorer = Arc<dyn Fn(&Rollout) -> Vec<f32> + Send + Sync>;

pub struct PreprocessorArgs {
    pub cfg: RunConfig,
    /// train-graph batch geometry (rows, seq_len) from the manifest
    pub b: usize,
    pub t: usize,
    pub rollout_rx: Subscriber<Rollout>,
    pub batch_tx: Publisher<TrainBatch>,
    pub hub: MetricsHub,
    pub stop: Arc<AtomicBool>,
    pub conv: Option<Arc<ConvSync>>,
    /// optional host-side lp_pi source for the `is_w` weight lane
    pub scorer: Option<PolicyScorer>,
}

pub fn run_preprocessor(args: PreprocessorArgs) -> Result<()> {
    let PreprocessorArgs { cfg, b, t, rollout_rx, batch_tx, hub, stop, conv, scorer } = args;
    let log = Logger::new("preproc");
    match cfg.mode {
        Mode::Pipeline | Mode::Periodic { .. } => {
            run_pipeline(&cfg, b, t, rollout_rx, batch_tx, hub, stop, scorer, log)
        }
        Mode::Conventional { g } => run_conventional(
            &cfg,
            g,
            b,
            t,
            rollout_rx,
            batch_tx,
            hub,
            stop,
            conv.expect("conventional mode requires ConvSync"),
            scorer,
            log,
        ),
    }
}

/// Per-token truncated IS weights for the batch's `is_w` lane, when the
/// config asks for correction and a scorer is wired. Records the
/// rollout's host-side ESS to `preproc/rollout_ess` (the admission
/// metric `rl::ess`'s module doc promises).
pub(crate) fn is_weights(
    cfg: &RunConfig,
    scorer: Option<&PolicyScorer>,
    r: &Rollout,
    hub: &MetricsHub,
) -> Option<Vec<f32>> {
    let scorer = scorer?;
    if cfg.is_correction != IsCorrection::Truncated || r.gen_tokens.is_empty() {
        return None;
    }
    let lp_pi = scorer(r);
    assert_eq!(
        lp_pi.len(),
        r.gen_tokens.len(),
        "policy scorer must return one logprob per generated token"
    );
    let w = truncated_weights(&lp_pi, &r.behavior_lp, cfg.clip_c as f32);
    hub.record(
        "preproc/rollout_ess",
        crate::util::timer::global_seconds(),
        hub.counter("rollouts_weighted"),
        effective_sample_size(&w),
    );
    hub.add("rollouts_weighted", 1.0);
    Some(w)
}

struct PendingGroup {
    members: Vec<Rollout>,
    /// first arrival — orders overflow eviction (oldest first)
    t_first: Instant,
    /// last arrival — the staleness clock: a group still receiving
    /// members is alive however long it takes, a group whose missing
    /// members were ring-evicted stops progressing and goes stale
    t_last: Instant,
}

/// Collect rollouts into groups; on completion compute advantages and
/// return (rollout, advantage) pairs ready for packing.
///
/// **Stranded-group eviction:** a group normally completes when all
/// `group_size` members arrive, but a saturated `DropOldest` ring can
/// evict some members (typically a killed actor's `Aborted` rollouts)
/// before the preprocessor sees them — without a guard, the surviving
/// groupmates would sit in `pending` forever and their work would be
/// lost. Two bounds force-complete incomplete groups from whatever
/// members did arrive: a *staleness* timeout measured from the group's
/// last arrival (so healthy-but-slow groups that keep progressing are
/// never split) and a hard cap on the pending map (oldest evicted
/// first). Advantages are computed over the present members only,
/// exactly as a completed group with filtered aborted members would be.
/// Members that straggle in *after* their group was force-completed are
/// dropped (a bounded memory of recently evicted gids prevents them
/// from re-pending as a fragment group that could never complete).
pub struct GroupCollector {
    group_size: usize,
    normalize: bool,
    /// force-complete groups with no new member for this long (None = never)
    timeout: Option<Duration>,
    /// pending-map cap; beyond it the oldest groups are force-completed
    /// (0 = unbounded)
    max_pending: usize,
    /// admit `FinishReason::Truncated` partial rollouts as trainable
    /// members (`[rl] train_truncated`); off = treat them like Aborted
    train_truncated: bool,
    pending: HashMap<u64, PendingGroup>,
    /// recently force-completed gids (insertion order, bounded) — late
    /// members of these are discarded instead of re-pending
    evicted: std::collections::VecDeque<u64>,
    /// conservation books for truncated training: group → admitted
    /// prefixes as (gen length, FNV-1a hash of the gen tokens). A later
    /// rollout in the same group whose generated tokens extend a
    /// recorded prefix is dropped — a prefix and its continuation must
    /// never both be trained (the actor's publish path already makes
    /// this exclusive at the source; the books are the defensive,
    /// testable invariant)
    trained_prefixes: HashMap<u64, Vec<(usize, u64)>>,
    /// insertion order of `trained_prefixes` keys (bounds the ledger)
    prefix_order: std::collections::VecDeque<u64>,
    /// throttle for the O(pending) staleness scan on busy paths
    last_scan: Instant,
}

/// How many force-completed gids to remember for late-member discard.
const EVICTED_MEMORY: usize = 1024;

/// FNV-1a over token streams — the prefix identity in the conservation
/// books (cheap, deterministic, no allocation).
fn fnv64_tokens(toks: &[i32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &t in toks {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    }
    h
}

impl GroupCollector {
    pub fn new(cfg: &RunConfig) -> Self {
        GroupCollector::with_limits(
            cfg.group_size,
            cfg.advantage == AdvantageMode::GroupNormalized,
            cfg.group_timeout_s,
            cfg.max_pending_groups,
        )
        .admit_truncated(cfg.train_truncated)
    }

    pub fn with_limits(
        group_size: usize,
        normalize: bool,
        timeout_s: f64,
        max_pending: usize,
    ) -> Self {
        GroupCollector {
            group_size,
            normalize,
            timeout: (timeout_s > 0.0).then(|| Duration::from_secs_f64(timeout_s)),
            max_pending,
            train_truncated: false,
            pending: HashMap::new(),
            evicted: std::collections::VecDeque::new(),
            trained_prefixes: HashMap::new(),
            prefix_order: std::collections::VecDeque::new(),
            last_scan: Instant::now(),
        }
    }

    /// Builder toggle for `[rl] train_truncated` (see field docs).
    pub fn admit_truncated(mut self, on: bool) -> Self {
        self.train_truncated = on;
        self
    }

    /// Is this rollout trainable under the current admission rules?
    fn trainable(&self, r: &Rollout) -> bool {
        if r.gen_tokens.is_empty() {
            return false;
        }
        match r.finish {
            FinishReason::Aborted => false,
            FinishReason::Truncated => self.train_truncated,
            _ => true,
        }
    }

    /// Does `gen` extend (or equal) a truncated prefix this collector
    /// already admitted for training in group `gid`?
    fn extends_trained_prefix(&self, gid: u64, gen: &[i32]) -> bool {
        self.trained_prefixes.get(&gid).is_some_and(|v| {
            v.iter()
                .any(|&(len, h)| gen.len() >= len && fnv64_tokens(&gen[..len]) == h)
        })
    }

    /// Record an admitted truncated prefix in the conservation books
    /// (bounded: oldest groups forgotten first).
    fn remember_trained_prefix(&mut self, gid: u64, gen: &[i32]) {
        if !self.trained_prefixes.contains_key(&gid) {
            if self.prefix_order.len() >= EVICTED_MEMORY {
                if let Some(old) = self.prefix_order.pop_front() {
                    self.trained_prefixes.remove(&old);
                }
            }
            self.prefix_order.push_back(gid);
        }
        self.trained_prefixes
            .entry(gid)
            .or_default()
            .push((gen.len(), fnv64_tokens(gen)));
    }

    pub fn add(&mut self, r: Rollout, hub: &MetricsHub) -> Vec<(Rollout, f32)> {
        let gid = r.group_id;
        // a straggler whose group was already force-completed: its
        // groupmates' advantages are long since computed — re-pending it
        // would create a fragment group that can never complete
        if self.evicted.contains(&gid) {
            hub.add("rollouts_late_after_eviction", 1.0);
            return Vec::new();
        }
        if self.train_truncated && !r.gen_tokens.is_empty() {
            // conservation: never train both a truncated prefix and a
            // later continuation of it. Dropped continuations don't count
            // toward completion — the prefix already took the group slot,
            // and stranded-group eviction salvages any imbalance.
            if self.extends_trained_prefix(gid, &r.gen_tokens) {
                hub.add("rollouts_continuation_dropped", 1.0);
                return Vec::new();
            }
            if matches!(r.finish, FinishReason::Truncated) {
                self.remember_trained_prefix(gid, &r.gen_tokens);
                hub.add("rollouts_truncated_admitted", 1.0);
            }
        }
        // untrainable rollouts (aborted/empty — and truncated while the
        // dial is off) still count towards group completion but are
        // filtered out of the advantage computation
        if !self.trainable(&r) {
            hub.add("rollouts_discarded", 1.0);
        }
        let now = Instant::now();
        let g = self
            .pending
            .entry(gid)
            .or_insert_with(|| PendingGroup { members: Vec::new(), t_first: now, t_last: now });
        g.t_last = now;
        g.members.push(r);
        self.maybe_complete(hub, gid)
    }

    fn maybe_complete(&mut self, hub: &MetricsHub, gid: u64) -> Vec<(Rollout, f32)> {
        let done = self
            .pending
            .get(&gid)
            .map(|g| g.members.len() >= self.group_size)
            .unwrap_or(false);
        if !done {
            return Vec::new();
        }
        self.complete(hub, gid)
    }

    /// Remove `gid` unconditionally and compute advantages over whatever
    /// members arrived (aborted/empty members filtered as usual).
    fn complete(&mut self, hub: &MetricsHub, gid: u64) -> Vec<(Rollout, f32)> {
        let Some(g) = self.pending.remove(&gid) else {
            return Vec::new();
        };
        let members: Vec<Rollout> =
            g.members.into_iter().filter(|r| self.trainable(r)).collect();
        if members.is_empty() {
            return Vec::new();
        }
        let groups: Vec<u64> = members.iter().map(|r| r.group_id).collect();
        let rewards: Vec<f32> = members.iter().map(|r| r.reward).collect();
        let advs = group_advantages(&groups, &rewards, self.normalize);
        hub.add("groups_completed", 1.0);
        members.into_iter().zip(advs).collect()
    }

    /// Remember a force-completed gid (bounded) so stragglers are
    /// discarded rather than re-pended as an uncompletable fragment.
    fn remember_evicted(&mut self, gid: u64) {
        if self.evicted.len() >= EVICTED_MEMORY {
            self.evicted.pop_front();
        }
        self.evicted.push_back(gid);
    }

    /// Apply both eviction bounds: force-complete stale groups (no new
    /// member for `timeout` — an O(pending) scan, call from idle paths),
    /// then trim to the cap. Returns the salvaged (rollout, advantage)
    /// pairs, ready for packing.
    pub fn evict_stale(&mut self, hub: &MetricsHub) -> Vec<(Rollout, f32)> {
        self.last_scan = Instant::now();
        let mut out = Vec::new();
        if let Some(to) = self.timeout {
            let stale: Vec<u64> = self
                .pending
                .iter()
                .filter(|(_, g)| g.t_last.elapsed() >= to)
                .map(|(&gid, _)| gid)
                .collect();
            for gid in stale {
                hub.add("groups_evicted_stale", 1.0);
                self.remember_evicted(gid);
                out.extend(self.complete(hub, gid));
            }
        }
        out.extend(self.evict_overflow(hub));
        out
    }

    /// Busy-path variant: always enforces the (cheap) cap, and runs the
    /// O(pending) staleness scan at most once per quarter-timeout — so a
    /// sustained rollout stream that never idles the receive loop still
    /// salvages stranded groups.
    pub fn evict_stale_throttled(&mut self, hub: &MetricsHub) -> Vec<(Rollout, f32)> {
        if let Some(to) = self.timeout {
            if self.last_scan.elapsed() >= to / 4 {
                return self.evict_stale(hub);
            }
        }
        self.evict_overflow(hub)
    }

    /// Enforce only the pending-map cap, oldest groups first. Cheap when
    /// under the cap (a single len check) — safe to call per message.
    pub fn evict_overflow(&mut self, hub: &MetricsHub) -> Vec<(Rollout, f32)> {
        if self.max_pending == 0 || self.pending.len() <= self.max_pending {
            return Vec::new();
        }
        let excess = self.pending.len() - self.max_pending;
        let mut by_age: Vec<(u64, Instant)> =
            self.pending.iter().map(|(&gid, g)| (gid, g.t_first)).collect();
        by_age.sort_by_key(|&(_, t)| t);
        let mut out = Vec::new();
        for &(gid, _) in by_age.iter().take(excess) {
            hub.add("groups_evicted_overflow", 1.0);
            self.remember_evicted(gid);
            out.extend(self.complete(hub, gid));
        }
        out
    }

    pub fn n_pending(&self) -> usize {
        self.pending.len()
    }
}

#[allow(clippy::too_many_arguments)]
fn run_pipeline(
    cfg: &RunConfig,
    b: usize,
    t: usize,
    rollout_rx: Subscriber<Rollout>,
    batch_tx: Publisher<TrainBatch>,
    hub: MetricsHub,
    stop: Arc<AtomicBool>,
    scorer: Option<PolicyScorer>,
    log: Logger,
) -> Result<()> {
    let mut collector = GroupCollector::new(cfg);
    let mut packer = Packer::new(b, t);
    // (rollout, advantage, optional is_w lane) — weights are computed
    // once at admission, not per pack attempt
    let mut ready: Vec<(Rollout, f32, Option<Vec<f32>>)> = Vec::new();
    let weigh = |pairs: Vec<(Rollout, f32)>, hub: &MetricsHub| {
        pairs
            .into_iter()
            .map(|(r, a)| {
                let w = is_weights(cfg, scorer.as_ref(), &r, hub);
                (r, a, w)
            })
            .collect::<Vec<_>>()
    };
    loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        match rollout_rx.recv(Duration::from_millis(100)) {
            Ok(r) => {
                // a rollout *finished* (not aborted) by a different actor
                // than its group opener is a migrated prefix that
                // completed elsewhere — the group itself is intact, so
                // this is observability, not special-casing. Known
                // undercount: a migration adopted by a restarted
                // incarnation of the *same* slot is invisible here (the
                // slot id matches); the MigrationHub's deposited/claimed
                // books are the exact accounting
                if super::actor::group_opener(r.group_id) != r.actor_id as u64 + 1
                    && !matches!(r.finish, FinishReason::Aborted)
                {
                    hub.add("rollouts_completed_after_migration", 1.0);
                }
                ready.extend(weigh(collector.add(r, &hub), &hub));
                // a sustained stream never hits the Timeout arm below, so
                // stranded-group salvage must also run here (cap check is
                // cheap; the staleness scan is time-throttled)
                ready.extend(weigh(collector.evict_stale_throttled(&hub), &hub));
            }
            Err(RecvError::Closed) => break,
            Err(RecvError::Timeout) => {
                // idle: salvage groups stranded by ring eviction of their
                // missing members (see GroupCollector docs)
                ready.extend(weigh(collector.evict_stale(&hub), &hub));
                // trickle flush: don't let a partial batch starve the trainer
                if ready.is_empty() {
                    if !packer.is_empty() && send(&mut packer, &batch_tx, &hub, false)? {
                        break;
                    }
                    continue;
                }
            }
        }
        // pack everything that fits; flush when full
        let i = 0;
        while i < ready.len() {
            let (r, adv, w) = &ready[i];
            if packer.try_add_weighted(r, *adv, w.as_deref()) {
                ready.swap_remove(i);
            } else if !packer.is_empty() {
                if send(&mut packer, &batch_tx, &hub, false)? {
                    return Ok(());
                }
            } else {
                // single rollout longer than T — cannot ever fit
                hub.add("rollouts_too_long", 1.0);
                ready.swap_remove(i);
            }
        }
        // target fill reached? ship it
        if packer.fill_fraction() >= 0.85 && send(&mut packer, &batch_tx, &hub, false)? {
            break;
        }
    }
    log.debug(&format!(
        "preprocessor stopping ({} groups pending)",
        collector.n_pending()
    ));
    Ok(())
}

#[allow(clippy::too_many_arguments)]
fn run_conventional(
    cfg: &RunConfig,
    _g: usize,
    b: usize,
    t: usize,
    rollout_rx: Subscriber<Rollout>,
    batch_tx: Publisher<TrainBatch>,
    hub: MetricsHub,
    stop: Arc<AtomicBool>,
    conv: Arc<ConvSync>,
    scorer: Option<PolicyScorer>,
    log: Logger,
) -> Result<()> {
    let mut collector = GroupCollector::new(cfg);
    let mut rng = Rng::with_stream(cfg.seed, 0x5f00);
    loop {
        // accumulate the whole Generate phase's buffer
        let mut buffer: Vec<(Rollout, f32)> = Vec::new();
        loop {
            if stop.load(Ordering::Relaxed) {
                return Ok(());
            }
            match rollout_rx.recv(Duration::from_millis(50)) {
                Ok(r) => {
                    buffer.extend(collector.add(r, &hub));
                    buffer.extend(collector.evict_stale_throttled(&hub));
                }
                Err(RecvError::Closed) => return Ok(()),
                Err(RecvError::Timeout) => {
                    buffer.extend(collector.evict_stale(&hub));
                }
            }
            // phase flipped to Train once every sequence landed
            if conv.wait_train(Duration::from_millis(0)).is_some()
                && rollout_rx.depth() == 0
            {
                break;
            }
        }
        if buffer.is_empty() {
            continue;
        }
        // Alg. 1: shuffle the B*G buffer, then release the step's batches
        rng.shuffle(&mut buffer);
        hub.record(
            "conv/buffer_seqs",
            crate::util::timer::global_seconds(),
            hub.counter("groups_completed"),
            buffer.len() as f64,
        );
        // Alg. 1 splits the B·G buffer into exactly G optimizer batches:
        // chunk the shuffled buffer rather than packing to density (the
        // trainer must take G steps per RL step).
        let mut packer = Packer::new(b, t);
        let mut batches = Vec::new();
        let chunk = buffer.len().div_ceil(_g.max(1)).max(1);
        for group in buffer.chunks(chunk) {
            for (r, adv) in group {
                let w = is_weights(cfg, scorer.as_ref(), r, &hub);
                if !packer.try_add_weighted(r, *adv, w.as_deref()) {
                    if !packer.is_empty() {
                        batches.push(packer.flush());
                    }
                    if !packer.try_add_weighted(r, *adv, w.as_deref()) {
                        hub.add("rollouts_too_long", 1.0);
                    }
                }
            }
            if !packer.is_empty() {
                batches.push(packer.flush());
            }
        }
        let n = batches.len();
        log.debug(&format!("releasing {n} conventional batches"));
        for (i, mut batch) in batches.into_iter().enumerate() {
            batch.last_of_rl_step = i + 1 == n;
            hub.add("batches_packed", 1.0);
            if batch_tx.send(batch).is_err() {
                return Ok(()); // trainer disconnected: shutdown
            }
        }
    }
}

/// Returns true when the trainer has disconnected (graceful shutdown).
fn send(
    packer: &mut Packer,
    batch_tx: &Publisher<TrainBatch>,
    hub: &MetricsHub,
    last: bool,
) -> Result<bool> {
    let mut batch = packer.flush();
    batch.last_of_rl_step = last;
    hub.add("batches_packed", 1.0);
    hub.record(
        "preproc/batch_fill",
        crate::util::timer::global_seconds(),
        hub.counter("batches_packed"),
        batch.fill(),
    );
    // a send failure means the trainer is done and disconnected: shut down
    Ok(batch_tx.send(batch).is_err())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rollout(gid: u64, gen: Vec<i32>, reward: f32, finish: FinishReason) -> Rollout {
        let n = gen.len();
        Rollout {
            seq_id: 1,
            problem_id: 1,
            group_id: gid,
            actor_id: 0,
            prompt_tokens: vec![1, 4],
            gen_tokens: gen,
            behavior_lp: vec![-0.25; n],
            token_version: vec![3; n],
            reward,
            finish,
            t_start: 0.0,
            t_end: 0.0,
        }
    }

    #[test]
    fn truncated_treated_like_aborted_when_dial_off() {
        let hub = MetricsHub::new();
        let mut gc = GroupCollector::with_limits(2, false, 0.0, 0);
        assert!(gc.add(rollout(7, vec![5, 6], 1.0, FinishReason::Truncated), &hub).is_empty());
        let done = gc.add(rollout(7, vec![8, 9], 1.0, FinishReason::Eos), &hub);
        // the truncated member counted toward completion but was filtered
        assert_eq!(done.len(), 1);
        assert!(matches!(done[0].0.finish, FinishReason::Eos));
        assert_eq!(hub.counter("rollouts_discarded"), 1.0);
    }

    #[test]
    fn truncated_admitted_as_full_member_when_dial_on() {
        let hub = MetricsHub::new();
        let mut gc = GroupCollector::with_limits(2, false, 0.0, 0).admit_truncated(true);
        assert!(gc.add(rollout(7, vec![5, 6], 0.0, FinishReason::Truncated), &hub).is_empty());
        let done = gc.add(rollout(7, vec![8, 9], 1.0, FinishReason::Eos), &hub);
        assert_eq!(done.len(), 2, "truncated prefix trains alongside its groupmate");
        assert_eq!(hub.counter("rollouts_truncated_admitted"), 1.0);
        assert_eq!(hub.counter("rollouts_discarded"), 0.0);
        // group baseline includes the truncated member's reward:
        // advantages are ±0.5 around the (0.0 + 1.0)/2 mean
        let mut advs: Vec<f32> = done.iter().map(|(_, a)| *a).collect();
        advs.sort_by(f32::total_cmp);
        assert_eq!(advs, vec![-0.5, 0.5]);
    }

    #[test]
    fn continuation_of_trained_prefix_is_dropped() {
        let hub = MetricsHub::new();
        let mut gc = GroupCollector::with_limits(2, false, 0.0, 0).admit_truncated(true);
        assert!(gc.add(rollout(9, vec![5, 6], 0.0, FinishReason::Truncated), &hub).is_empty());
        // a later rollout extending the trained prefix [5, 6] must not
        // train those tokens again — dropped, no group progress
        let dup = gc.add(rollout(9, vec![5, 6, 7], 1.0, FinishReason::Eos), &hub);
        assert!(dup.is_empty());
        assert_eq!(hub.counter("rollouts_continuation_dropped"), 1.0);
        assert_eq!(gc.n_pending(), 1, "dropped continuation takes no group slot");
        // an unrelated sibling (different tokens) completes the group
        let done = gc.add(rollout(9, vec![8, 6, 7], 1.0, FinishReason::Eos), &hub);
        assert_eq!(done.len(), 2);
    }

    #[test]
    fn is_weights_respects_dial_and_scorer() {
        let hub = MetricsHub::new();
        let mut cfg = RunConfig::default();
        cfg.clip_c = 2.0;
        let r = rollout(1, vec![5, 6, 7], 1.0, FinishReason::Eos);
        // no scorer → no lane, regardless of the dial
        assert!(is_weights(&cfg, None, &r, &hub).is_none());
        // scorer + truncated correction → clamped ratios
        let scorer: PolicyScorer = Arc::new(|r: &Rollout| {
            // lp_pi = behavior + [0, +10, -1]: on-policy, way-up, down
            let d = [0.0f32, 10.0, -1.0];
            r.behavior_lp.iter().zip(d).map(|(b, d)| b + d).collect()
        });
        let w = is_weights(&cfg, Some(&scorer), &r, &hub).unwrap();
        assert!((w[0] - 1.0).abs() < 1e-6);
        assert_eq!(w[1], 2.0, "clipped at c");
        assert!((w[2] - (-1.0f32).exp()).abs() < 1e-6);
        assert_eq!(hub.counter("rollouts_weighted"), 1.0);
        // dial off → no lane even with a scorer
        cfg.is_correction = IsCorrection::None;
        assert!(is_weights(&cfg, Some(&scorer), &r, &hub).is_none());
    }
}
