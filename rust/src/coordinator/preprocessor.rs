//! Preprocessor stage (paper Fig. 4, middle box).
//!
//! Consumes the rollout stream, completes advantage groups, computes
//! group-baseline advantages, packs sequences online into fixed [B, T]
//! training batches and publishes them to the trainer topic.
//!
//! **Conventional mode** implements the paper's §5 tweak: it accumulates
//! the whole RL step's buffer (every sequence the Generate phase
//! produced), shuffles it, packs it into ~G batches, marks the last one,
//! and only then releases them — reproducing Alg. 1's lag structure
//! exactly (batch j trained at lag j).

use super::conv::ConvSync;
use super::packing::{Packer, TrainBatch};
use crate::broker::{Publisher, RecvError, Subscriber};
use crate::config::{Mode, RunConfig};
use crate::metrics::MetricsHub;
use crate::rl::{group_advantages, AdvantageMode, FinishReason, Rollout};
use crate::util::logging::Logger;
use crate::util::Rng;
use anyhow::Result;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

pub struct PreprocessorArgs {
    pub cfg: RunConfig,
    /// train-graph batch geometry (rows, seq_len) from the manifest
    pub b: usize,
    pub t: usize,
    pub rollout_rx: Subscriber<Rollout>,
    pub batch_tx: Publisher<TrainBatch>,
    pub hub: MetricsHub,
    pub stop: Arc<AtomicBool>,
    pub conv: Option<Arc<ConvSync>>,
}

pub fn run_preprocessor(args: PreprocessorArgs) -> Result<()> {
    let PreprocessorArgs { cfg, b, t, rollout_rx, batch_tx, hub, stop, conv } = args;
    let log = Logger::new("preproc");
    match cfg.mode {
        Mode::Pipeline => run_pipeline(&cfg, b, t, rollout_rx, batch_tx, hub, stop, log),
        Mode::Conventional { g } => run_conventional(
            &cfg,
            g,
            b,
            t,
            rollout_rx,
            batch_tx,
            hub,
            stop,
            conv.expect("conventional mode requires ConvSync"),
            log,
        ),
    }
}

struct PendingGroup {
    members: Vec<Rollout>,
    /// first arrival — orders overflow eviction (oldest first)
    t_first: Instant,
    /// last arrival — the staleness clock: a group still receiving
    /// members is alive however long it takes, a group whose missing
    /// members were ring-evicted stops progressing and goes stale
    t_last: Instant,
}

/// Collect rollouts into groups; on completion compute advantages and
/// return (rollout, advantage) pairs ready for packing.
///
/// **Stranded-group eviction:** a group normally completes when all
/// `group_size` members arrive, but a saturated `DropOldest` ring can
/// evict some members (typically a killed actor's `Aborted` rollouts)
/// before the preprocessor sees them — without a guard, the surviving
/// groupmates would sit in `pending` forever and their work would be
/// lost. Two bounds force-complete incomplete groups from whatever
/// members did arrive: a *staleness* timeout measured from the group's
/// last arrival (so healthy-but-slow groups that keep progressing are
/// never split) and a hard cap on the pending map (oldest evicted
/// first). Advantages are computed over the present members only,
/// exactly as a completed group with filtered aborted members would be.
/// Members that straggle in *after* their group was force-completed are
/// dropped (a bounded memory of recently evicted gids prevents them
/// from re-pending as a fragment group that could never complete).
pub struct GroupCollector {
    group_size: usize,
    normalize: bool,
    /// force-complete groups with no new member for this long (None = never)
    timeout: Option<Duration>,
    /// pending-map cap; beyond it the oldest groups are force-completed
    /// (0 = unbounded)
    max_pending: usize,
    pending: HashMap<u64, PendingGroup>,
    /// recently force-completed gids (insertion order, bounded) — late
    /// members of these are discarded instead of re-pending
    evicted: std::collections::VecDeque<u64>,
    /// throttle for the O(pending) staleness scan on busy paths
    last_scan: Instant,
}

/// How many force-completed gids to remember for late-member discard.
const EVICTED_MEMORY: usize = 1024;

impl GroupCollector {
    pub fn new(cfg: &RunConfig) -> Self {
        GroupCollector::with_limits(
            cfg.group_size,
            cfg.advantage == AdvantageMode::GroupNormalized,
            cfg.group_timeout_s,
            cfg.max_pending_groups,
        )
    }

    pub fn with_limits(
        group_size: usize,
        normalize: bool,
        timeout_s: f64,
        max_pending: usize,
    ) -> Self {
        GroupCollector {
            group_size,
            normalize,
            timeout: (timeout_s > 0.0).then(|| Duration::from_secs_f64(timeout_s)),
            max_pending,
            pending: HashMap::new(),
            evicted: std::collections::VecDeque::new(),
            last_scan: Instant::now(),
        }
    }

    pub fn add(&mut self, r: Rollout, hub: &MetricsHub) -> Vec<(Rollout, f32)> {
        let gid = r.group_id;
        // a straggler whose group was already force-completed: its
        // groupmates' advantages are long since computed — re-pending it
        // would create a fragment group that can never complete
        if self.evicted.contains(&gid) {
            hub.add("rollouts_late_after_eviction", 1.0);
            return Vec::new();
        }
        // aborted/empty rollouts still count towards group completion but
        // are filtered out of the advantage computation
        if matches!(r.finish, FinishReason::Aborted) || r.gen_tokens.is_empty() {
            hub.add("rollouts_discarded", 1.0);
        }
        let now = Instant::now();
        let g = self
            .pending
            .entry(gid)
            .or_insert_with(|| PendingGroup { members: Vec::new(), t_first: now, t_last: now });
        g.t_last = now;
        g.members.push(r);
        self.maybe_complete(hub, gid)
    }

    fn maybe_complete(&mut self, hub: &MetricsHub, gid: u64) -> Vec<(Rollout, f32)> {
        let done = self
            .pending
            .get(&gid)
            .map(|g| g.members.len() >= self.group_size)
            .unwrap_or(false);
        if !done {
            return Vec::new();
        }
        self.complete(hub, gid)
    }

    /// Remove `gid` unconditionally and compute advantages over whatever
    /// members arrived (aborted/empty members filtered as usual).
    fn complete(&mut self, hub: &MetricsHub, gid: u64) -> Vec<(Rollout, f32)> {
        let Some(g) = self.pending.remove(&gid) else {
            return Vec::new();
        };
        let members: Vec<Rollout> = g
            .members
            .into_iter()
            .filter(|r| {
                !r.gen_tokens.is_empty() && !matches!(r.finish, FinishReason::Aborted)
            })
            .collect();
        if members.is_empty() {
            return Vec::new();
        }
        let groups: Vec<u64> = members.iter().map(|r| r.group_id).collect();
        let rewards: Vec<f32> = members.iter().map(|r| r.reward).collect();
        let advs = group_advantages(&groups, &rewards, self.normalize);
        hub.add("groups_completed", 1.0);
        members.into_iter().zip(advs).collect()
    }

    /// Remember a force-completed gid (bounded) so stragglers are
    /// discarded rather than re-pended as an uncompletable fragment.
    fn remember_evicted(&mut self, gid: u64) {
        if self.evicted.len() >= EVICTED_MEMORY {
            self.evicted.pop_front();
        }
        self.evicted.push_back(gid);
    }

    /// Apply both eviction bounds: force-complete stale groups (no new
    /// member for `timeout` — an O(pending) scan, call from idle paths),
    /// then trim to the cap. Returns the salvaged (rollout, advantage)
    /// pairs, ready for packing.
    pub fn evict_stale(&mut self, hub: &MetricsHub) -> Vec<(Rollout, f32)> {
        self.last_scan = Instant::now();
        let mut out = Vec::new();
        if let Some(to) = self.timeout {
            let stale: Vec<u64> = self
                .pending
                .iter()
                .filter(|(_, g)| g.t_last.elapsed() >= to)
                .map(|(&gid, _)| gid)
                .collect();
            for gid in stale {
                hub.add("groups_evicted_stale", 1.0);
                self.remember_evicted(gid);
                out.extend(self.complete(hub, gid));
            }
        }
        out.extend(self.evict_overflow(hub));
        out
    }

    /// Busy-path variant: always enforces the (cheap) cap, and runs the
    /// O(pending) staleness scan at most once per quarter-timeout — so a
    /// sustained rollout stream that never idles the receive loop still
    /// salvages stranded groups.
    pub fn evict_stale_throttled(&mut self, hub: &MetricsHub) -> Vec<(Rollout, f32)> {
        if let Some(to) = self.timeout {
            if self.last_scan.elapsed() >= to / 4 {
                return self.evict_stale(hub);
            }
        }
        self.evict_overflow(hub)
    }

    /// Enforce only the pending-map cap, oldest groups first. Cheap when
    /// under the cap (a single len check) — safe to call per message.
    pub fn evict_overflow(&mut self, hub: &MetricsHub) -> Vec<(Rollout, f32)> {
        if self.max_pending == 0 || self.pending.len() <= self.max_pending {
            return Vec::new();
        }
        let excess = self.pending.len() - self.max_pending;
        let mut by_age: Vec<(u64, Instant)> =
            self.pending.iter().map(|(&gid, g)| (gid, g.t_first)).collect();
        by_age.sort_by_key(|&(_, t)| t);
        let mut out = Vec::new();
        for &(gid, _) in by_age.iter().take(excess) {
            hub.add("groups_evicted_overflow", 1.0);
            self.remember_evicted(gid);
            out.extend(self.complete(hub, gid));
        }
        out
    }

    pub fn n_pending(&self) -> usize {
        self.pending.len()
    }
}

#[allow(clippy::too_many_arguments)]
fn run_pipeline(
    cfg: &RunConfig,
    b: usize,
    t: usize,
    rollout_rx: Subscriber<Rollout>,
    batch_tx: Publisher<TrainBatch>,
    hub: MetricsHub,
    stop: Arc<AtomicBool>,
    log: Logger,
) -> Result<()> {
    let mut collector = GroupCollector::new(cfg);
    let mut packer = Packer::new(b, t);
    let mut ready: Vec<(Rollout, f32)> = Vec::new();
    loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        match rollout_rx.recv(Duration::from_millis(100)) {
            Ok(r) => {
                // a rollout *finished* (not aborted) by a different actor
                // than its group opener is a migrated prefix that
                // completed elsewhere — the group itself is intact, so
                // this is observability, not special-casing. Known
                // undercount: a migration adopted by a restarted
                // incarnation of the *same* slot is invisible here (the
                // slot id matches); the MigrationHub's deposited/claimed
                // books are the exact accounting
                if super::actor::group_opener(r.group_id) != r.actor_id as u64 + 1
                    && !matches!(r.finish, FinishReason::Aborted)
                {
                    hub.add("rollouts_completed_after_migration", 1.0);
                }
                ready.extend(collector.add(r, &hub));
                // a sustained stream never hits the Timeout arm below, so
                // stranded-group salvage must also run here (cap check is
                // cheap; the staleness scan is time-throttled)
                ready.extend(collector.evict_stale_throttled(&hub));
            }
            Err(RecvError::Closed) => break,
            Err(RecvError::Timeout) => {
                // idle: salvage groups stranded by ring eviction of their
                // missing members (see GroupCollector docs)
                ready.extend(collector.evict_stale(&hub));
                // trickle flush: don't let a partial batch starve the trainer
                if ready.is_empty() {
                    if !packer.is_empty() && send(&mut packer, &batch_tx, &hub, false)? {
                        break;
                    }
                    continue;
                }
            }
        }
        // pack everything that fits; flush when full
        let i = 0;
        while i < ready.len() {
            let (r, adv) = &ready[i];
            if packer.try_add(r, *adv) {
                ready.swap_remove(i);
            } else if !packer.is_empty() {
                if send(&mut packer, &batch_tx, &hub, false)? {
                    return Ok(());
                }
            } else {
                // single rollout longer than T — cannot ever fit
                hub.add("rollouts_too_long", 1.0);
                ready.swap_remove(i);
            }
        }
        // target fill reached? ship it
        if packer.fill_fraction() >= 0.85 && send(&mut packer, &batch_tx, &hub, false)? {
            break;
        }
    }
    log.debug(&format!(
        "preprocessor stopping ({} groups pending)",
        collector.n_pending()
    ));
    Ok(())
}

#[allow(clippy::too_many_arguments)]
fn run_conventional(
    cfg: &RunConfig,
    _g: usize,
    b: usize,
    t: usize,
    rollout_rx: Subscriber<Rollout>,
    batch_tx: Publisher<TrainBatch>,
    hub: MetricsHub,
    stop: Arc<AtomicBool>,
    conv: Arc<ConvSync>,
    log: Logger,
) -> Result<()> {
    let mut collector = GroupCollector::new(cfg);
    let mut rng = Rng::with_stream(cfg.seed, 0x5f00);
    loop {
        // accumulate the whole Generate phase's buffer
        let mut buffer: Vec<(Rollout, f32)> = Vec::new();
        loop {
            if stop.load(Ordering::Relaxed) {
                return Ok(());
            }
            match rollout_rx.recv(Duration::from_millis(50)) {
                Ok(r) => {
                    buffer.extend(collector.add(r, &hub));
                    buffer.extend(collector.evict_stale_throttled(&hub));
                }
                Err(RecvError::Closed) => return Ok(()),
                Err(RecvError::Timeout) => {
                    buffer.extend(collector.evict_stale(&hub));
                }
            }
            // phase flipped to Train once every sequence landed
            if conv.wait_train(Duration::from_millis(0)).is_some()
                && rollout_rx.depth() == 0
            {
                break;
            }
        }
        if buffer.is_empty() {
            continue;
        }
        // Alg. 1: shuffle the B*G buffer, then release the step's batches
        rng.shuffle(&mut buffer);
        hub.record(
            "conv/buffer_seqs",
            crate::util::timer::global_seconds(),
            hub.counter("groups_completed"),
            buffer.len() as f64,
        );
        // Alg. 1 splits the B·G buffer into exactly G optimizer batches:
        // chunk the shuffled buffer rather than packing to density (the
        // trainer must take G steps per RL step).
        let mut packer = Packer::new(b, t);
        let mut batches = Vec::new();
        let chunk = buffer.len().div_ceil(_g.max(1)).max(1);
        for group in buffer.chunks(chunk) {
            for (r, adv) in group {
                if !packer.try_add(r, *adv) {
                    if !packer.is_empty() {
                        batches.push(packer.flush());
                    }
                    if !packer.try_add(r, *adv) {
                        hub.add("rollouts_too_long", 1.0);
                    }
                }
            }
            if !packer.is_empty() {
                batches.push(packer.flush());
            }
        }
        let n = batches.len();
        log.debug(&format!("releasing {n} conventional batches"));
        for (i, mut batch) in batches.into_iter().enumerate() {
            batch.last_of_rl_step = i + 1 == n;
            hub.add("batches_packed", 1.0);
            if batch_tx.send(batch).is_err() {
                return Ok(()); // trainer disconnected: shutdown
            }
        }
    }
}

/// Returns true when the trainer has disconnected (graceful shutdown).
fn send(
    packer: &mut Packer,
    batch_tx: &Publisher<TrainBatch>,
    hub: &MetricsHub,
    last: bool,
) -> Result<bool> {
    let mut batch = packer.flush();
    batch.last_of_rl_step = last;
    hub.add("batches_packed", 1.0);
    hub.record(
        "preproc/batch_fill",
        crate::util::timer::global_seconds(),
        hub.counter("batches_packed"),
        batch.fill(),
    );
    // a send failure means the trainer is done and disconnected: shut down
    Ok(batch_tx.send(batch).is_err())
}
