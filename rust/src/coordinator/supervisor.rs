//! Elastic, fault-tolerant actor-pool supervision.
//!
//! The seed topology spawned every stage exactly once and could survive
//! nothing; production-scale async RL (LlamaRL-style generator churn)
//! needs the actor tier to be **elastic**. This module provides:
//!
//! * [`ActorPool`] — a supervised set of actor threads. Each incarnation
//!   gets its own `halt` kill-switch next to the global `stop`, so one
//!   actor can be killed / restarted / added / removed mid-run. New
//!   actors *hot-join*: they clone a live rollout [`Publisher`] (the pool
//!   keeps the topic open, so the publishers-dropped → `RecvError::Closed`
//!   path never fires mid-run) and register on the [`WeightBus`] process
//!   group, picking up the latest published weights.
//! * [`run_supervisor`] — the monitor loop: reaps crashed actors and
//!   restarts them within a restart budget, tops the pool back up to its
//!   floor, and fires the events of a deterministic
//!   [`ChaosSchedule`](crate::testkit::chaos::ChaosSchedule) against the
//!   pipeline's logical clock (the weight bus's published version).
//!   With a [`MigrationHub`] wired, every kill path (chaos, descale,
//!   autoscale-down) hands the victim's in-flight sequences to the
//!   surviving actors instead of aborting them; with an [`AutoScaler`],
//!   the pool resizes from live signals (rollout-queue backlog, supply
//!   saturation, token lag, batch fill) instead of only chaos events —
//!   `pool_size`, `autoscale_ups`/`autoscale_downs`,
//!   `migrations_completed` and `snapshot_tokens_salvaged` land in the
//!   [`MetricsHub`] for scenario assertions.
//! * [`TrainerSlot`] — supervisor-owned **trainer failover**
//!   (`[elastic] trainer_failover`): a `ChaosKind::KillTrainer` event or
//!   a trainer crash restarts the trainer *in process* from the latest
//!   `AsyncCheckpointer` manifest state, within its own restart budget —
//!   actors keep decoding and the topics stay open throughout
//!   (`trainer_failovers` / `trainer_crashes` counters). The supervisor
//!   then returns the (possibly respawned) trainer's final parameters.
//! * **Run control plane** (`[control] enabled`, see [`crate::control`]):
//!   the supervisor additionally drains a [`RunController`] command
//!   queue (pause/resume/drain/rollback/stop), polls a [`Guardrail`]
//!   watchdog each iteration, and executes pause-then-rollback through
//!   the same [`TrainerSlot`] failover machinery — with bounded
//!   retry-with-backoff and a fail-safe transition to `Drained` when the
//!   rollback budget is exhausted. Every exit path records a terminal
//!   `run/state` gauge.
//!
//! [`RunController`]: crate::control::RunController
//! [`Guardrail`]: crate::control::Guardrail
//!
//! The pool is deliberately generic over a [`SpawnFn`] closure rather
//! than hard-wired to [`super::actor::run_actor`]: the chaos tests drive
//! the very same supervision machinery with synthetic actors, so the
//! kill/restart/hot-attach logic is exercised even in environments where
//! the PJRT engine is unavailable.

use super::trainer::TrainerExit;
use crate::broker::Publisher;
use crate::control::{
    record_state, write_trip_report, AdmissionPhase, ControlPlane, RunCommand, RunState, Trip,
    TripReason,
};
use crate::metrics::MetricsHub;
use crate::rl::Rollout;
use crate::runtime::HostTensor;
use crate::sched::{AutoScaler, MigrationHub, ScaleDecision, ScaleSignals};
use crate::testkit::chaos::{ChaosKind, ChaosSchedule};
use crate::util::logging::Logger;
use crate::weights::WeightBus;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Identity handed to each spawned actor incarnation.
pub struct ActorCtx {
    pub actor_id: usize,
    /// restart count of this slot (0 = first spawn)
    pub generation: u64,
    /// global run shutdown flag
    pub stop: Arc<AtomicBool>,
    /// kill-switch for this incarnation only
    pub halt: Arc<AtomicBool>,
}

/// Actor body. Must poll `ctx.stop` / `ctx.halt` and return promptly when
/// either is raised.
pub type SpawnFn = Arc<dyn Fn(ActorCtx) -> Result<()> + Send + Sync + 'static>;

struct Slot {
    halt: Arc<AtomicBool>,
    join: JoinHandle<Result<()>>,
    generation: u64,
}

/// Supervised, resizable set of actor threads.
pub struct ActorPool {
    spawn: SpawnFn,
    stop: Arc<AtomicBool>,
    hub: MetricsHub,
    log: Logger,
    slots: BTreeMap<usize, Slot>,
    next_id: usize,
    min_actors: usize,
    max_actors: usize,
    max_restarts: usize,
    restarts_used: usize,
    /// propagate the first crash instead of restarting (plain,
    /// non-elastic runs keep the fail-on-actor-error semantics)
    fail_fast: bool,
    last_crash: Option<String>,
}

impl ActorPool {
    /// Build a pool and spawn `initial` actors (ids `0..initial`).
    pub fn new(
        spawn: SpawnFn,
        stop: Arc<AtomicBool>,
        hub: MetricsHub,
        initial: usize,
        min_actors: usize,
        max_actors: usize,
        max_restarts: usize,
        fail_fast: bool,
    ) -> Result<ActorPool> {
        let mut pool = ActorPool {
            spawn,
            stop,
            hub,
            log: Logger::new("actorpool"),
            slots: BTreeMap::new(),
            next_id: 0,
            min_actors,
            max_actors,
            max_restarts,
            restarts_used: 0,
            fail_fast,
            last_crash: None,
        };
        for _ in 0..initial {
            pool.add_actor()?;
        }
        Ok(pool)
    }

    /// Message of the most recent crash seen by [`ActorPool::reap`].
    pub fn last_crash(&self) -> Option<&str> {
        self.last_crash.as_deref()
    }

    fn spawn_slot(&mut self, actor_id: usize, generation: u64) -> Result<()> {
        let halt = Arc::new(AtomicBool::new(false));
        let ctx = ActorCtx {
            actor_id,
            generation,
            stop: self.stop.clone(),
            halt: halt.clone(),
        };
        let body = self.spawn.clone();
        let join = std::thread::Builder::new()
            .name(format!("actor-{actor_id}.g{generation}"))
            .spawn(move || body(ctx))
            .with_context(|| format!("spawning actor-{actor_id}"))?;
        self.slots.insert(actor_id, Slot { halt, join, generation });
        Ok(())
    }

    /// Grow the pool by one actor. Returns the new id, or None at the
    /// `max_actors` ceiling.
    pub fn add_actor(&mut self) -> Result<Option<usize>> {
        if self.slots.len() >= self.max_actors {
            return Ok(None);
        }
        let id = self.next_id;
        self.next_id += 1;
        self.spawn_slot(id, 0)?;
        self.hub.add("actors_spawned", 1.0);
        Ok(Some(id))
    }

    /// Raise an actor's kill switch *without* joining its thread: the
    /// actor winds down on its own time (exporting its portable in-flight
    /// rollouts) while the rest of the system keeps running, and
    /// [`ActorPool::reap`] collects the exit later as a clean retirement.
    /// Models a SIGTERM-style slow kill — the race window between the
    /// signal and the death is exactly what the chaos harness's
    /// `SlowKillActor` events exercise. Returns true only when this call
    /// *newly* raised the halt (false for unknown ids and for an actor
    /// already winding down — callers count retirements off this).
    pub fn halt_async(&mut self, actor_id: usize) -> bool {
        match self.slots.get(&actor_id) {
            Some(slot) => !slot.halt.swap(true, Ordering::Relaxed),
            None => false,
        }
    }

    /// Halt one actor and join its thread. The actor's own halt path
    /// decides the fate of its in-flight sequences (snapshot export when
    /// migration is wired, abort otherwise). Returns false for unknown
    /// ids. A crash surfaced at join time is recorded, not propagated —
    /// killing an already-dying actor is not an error.
    pub fn kill_actor(&mut self, actor_id: usize) -> bool {
        let Some(slot) = self.slots.remove(&actor_id) else {
            return false;
        };
        slot.halt.store(true, Ordering::Relaxed);
        match slot.join.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => self.log.warn(&format!("actor-{actor_id} died on kill: {e:#}")),
            Err(_) => self.log.warn(&format!("actor-{actor_id} panicked")),
        }
        self.hub.add("actors_killed", 1.0);
        true
    }

    /// Kill + immediately respawn the same slot (next generation).
    pub fn restart_actor(&mut self, actor_id: usize) -> Result<bool> {
        let generation = match self.slots.get(&actor_id) {
            Some(s) => s.generation + 1,
            None => return Ok(false),
        };
        self.kill_actor(actor_id);
        self.spawn_slot(actor_id, generation)?;
        self.hub.add("actor_restarts", 1.0);
        Ok(true)
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    pub fn min_actors(&self) -> usize {
        self.min_actors
    }

    /// Lower (or raise) the pool's floor mid-run. The forced-drain path
    /// drops it to zero so [`ActorPool::reap`] stops topping halted
    /// actors back up while the run winds down.
    pub fn set_min_actors(&mut self, n: usize) {
        self.min_actors = n;
    }

    /// Snapshot of the live slot ids.
    pub fn live_ids(&self) -> Vec<usize> {
        self.slots.keys().copied().collect()
    }

    pub fn lowest_live(&self) -> Option<usize> {
        self.slots.keys().next().copied()
    }

    pub fn highest_live(&self) -> Option<usize> {
        self.slots.keys().next_back().copied()
    }

    /// Collect actors whose threads have exited. Crashed ones are
    /// restarted while the shared respawn budget lasts (with
    /// `fail_fast`, the first crash is returned as an error instead);
    /// clean exits are retired. Afterwards the pool is topped back up
    /// towards `min_actors` — floor top-ups draw from the same budget,
    /// so a persistent fault cannot produce an unbounded crash loop.
    /// Returns the number of restarts performed.
    pub fn reap(&mut self) -> Result<usize> {
        let finished: Vec<usize> = self
            .slots
            .iter()
            .filter(|(_, s)| s.join.is_finished())
            .map(|(&id, _)| id)
            .collect();
        let mut restarted = 0;
        for id in finished {
            let slot = self.slots.remove(&id).unwrap();
            let crash = match slot.join.join() {
                Ok(Ok(())) => None,
                Ok(Err(e)) => Some(format!("actor-{id} crashed: {e:#}")),
                Err(_) => Some(format!("actor-{id} panicked")),
            };
            if let Some(why) = crash {
                self.log.warn(&why);
                self.hub.add("actor_crashes", 1.0);
                if self.fail_fast {
                    self.last_crash = Some(why.clone());
                    anyhow::bail!("{why}");
                }
                self.last_crash = Some(why);
                if self.restarts_used < self.max_restarts {
                    self.restarts_used += 1;
                    self.spawn_slot(id, slot.generation + 1)?;
                    self.hub.add("actor_restarts", 1.0);
                    restarted += 1;
                    self.log.info(&format!(
                        "restarted actor-{id} (generation {}, budget {}/{})",
                        slot.generation + 1,
                        self.restarts_used,
                        self.max_restarts
                    ));
                } else {
                    self.log.warn(&format!(
                        "actor-{id} abandoned: respawn budget ({}) exhausted",
                        self.max_restarts
                    ));
                    self.hub.add("actor_slots_abandoned", 1.0);
                }
            }
        }
        // elastic floor: keep at least min_actors generating. Budgeted,
        // so a fault that keeps killing fresh actors eventually empties
        // the pool and the supervisor escalates instead of thrashing.
        while self.slots.len() < self.min_actors
            && !self.stop.load(Ordering::Relaxed)
            && self.restarts_used < self.max_restarts
        {
            self.restarts_used += 1;
            if self.add_actor()?.is_none() {
                break;
            }
        }
        Ok(restarted)
    }

    /// Halt everything and join. First actor error is propagated.
    pub fn shutdown(mut self) -> Result<()> {
        for slot in self.slots.values() {
            slot.halt.store(true, Ordering::Relaxed);
        }
        let mut first_err = None;
        for (id, slot) in std::mem::take(&mut self.slots) {
            match slot.join.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) if first_err.is_none() => first_err = Some(e),
                Ok(Err(_)) => {}
                Err(_) if first_err.is_none() => {
                    first_err = Some(anyhow::anyhow!("actor-{id} panicked"))
                }
                Err(_) => {}
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

/// Identity handed to each trainer incarnation (the trainer analogue of
/// [`ActorCtx`]).
pub struct TrainerCtx {
    /// restart count of the trainer slot (0 = first spawn)
    pub generation: u64,
    /// kill-switch for this incarnation only
    pub halt: Arc<AtomicBool>,
    /// respawns set this: resume from the latest checkpoint manifest
    /// instead of the run's initial state
    pub resume_latest: bool,
}

/// Trainer body. Must poll its `halt` (and the global stop) and return
/// promptly when either is raised.
pub type TrainerSpawnFn = Arc<dyn Fn(TrainerCtx) -> Result<TrainerExit> + Send + Sync + 'static>;

/// A supervisor-owned trainer: the ROADMAP "trainer failover" follow-on.
/// When the trainer is killed (`ChaosKind::KillTrainer`) or crashes, the
/// supervisor respawns it with `resume_latest = true` — the replacement
/// reloads the newest [`crate::model::checkpoint::TrainState`] named by
/// the checkpoint manifest and continues the run *in process*: actors
/// keep decoding, topics stay open, nothing is torn down. (The resumed
/// trainer may republish versions below the bus's latest while it
/// re-runs the steps since the last checkpoint; actors ignore versions
/// they already have, so the republish window is harmless.)
pub struct TrainerSlot {
    spawn: TrainerSpawnFn,
    halt: Arc<AtomicBool>,
    join: Option<JoinHandle<Result<TrainerExit>>>,
    generation: u64,
    /// remaining failover budget (restarts after kills or crashes)
    restarts_left: usize,
    log: Logger,
}

impl TrainerSlot {
    /// Spawn the first trainer incarnation with a failover budget.
    pub fn new(spawn: TrainerSpawnFn, restart_budget: usize) -> Result<TrainerSlot> {
        let mut slot = TrainerSlot {
            spawn,
            halt: Arc::new(AtomicBool::new(false)),
            join: None,
            generation: 0,
            restarts_left: restart_budget,
            log: Logger::new("trainslot"),
        };
        slot.spawn_incarnation(false)?;
        Ok(slot)
    }

    fn spawn_incarnation(&mut self, resume_latest: bool) -> Result<()> {
        self.halt = Arc::new(AtomicBool::new(false));
        let ctx = TrainerCtx {
            generation: self.generation,
            halt: self.halt.clone(),
            resume_latest,
        };
        let body = self.spawn.clone();
        self.join = Some(
            std::thread::Builder::new()
                .name(format!("trainer.g{}", self.generation))
                .spawn(move || body(ctx))
                .context("spawning trainer")?,
        );
        Ok(())
    }

    /// True when a restart is still within budget.
    fn can_restart(&self) -> bool {
        self.restarts_left > 0
    }

    /// Kill the live incarnation (halt + join) and respawn a successor
    /// that resumes from the latest checkpoint manifest. If the dying
    /// incarnation had already *completed*, its final parameters are
    /// returned instead and nothing is respawned — killing a finished
    /// trainer is not a failover.
    fn restart(&mut self) -> Result<Option<Vec<HostTensor>>> {
        self.halt.store(true, Ordering::Relaxed);
        if let Some(j) = self.join.take() {
            match j.join() {
                Ok(Ok(TrainerExit::Completed(params))) => return Ok(Some(params)),
                Ok(Ok(TrainerExit::Halted)) => {}
                // a kill racing a crash: the failover below covers both,
                // but the dying incarnation's error — e.g. a checkpoint
                // writer reporting broken recovery points — must not
                // vanish silently
                Ok(Err(e)) => self.log.warn(&format!(
                    "trainer generation {} died during failover kill: {e:#}",
                    self.generation
                )),
                Err(_) => self.log.warn(&format!(
                    "trainer generation {} panicked during failover kill",
                    self.generation
                )),
            }
        }
        self.restarts_left -= 1;
        self.generation += 1;
        self.spawn_incarnation(true)?;
        Ok(None)
    }

    /// Non-blocking: collect the incarnation's exit if its thread has
    /// finished.
    fn poll(&mut self) -> Option<Result<TrainerExit>> {
        if self.join.as_ref().is_some_and(|j| j.is_finished()) {
            let j = self.join.take().expect("checked above");
            return Some(match j.join() {
                Ok(r) => r,
                Err(_) => Err(anyhow::anyhow!("trainer panicked")),
            });
        }
        None
    }

    /// Blocking teardown: join whatever incarnation is live (the global
    /// stop is already raised, so it returns promptly) and surface its
    /// final parameters / error.
    fn finish(&mut self) -> Result<Option<Vec<HostTensor>>> {
        match self.join.take() {
            Some(j) => match j.join() {
                Ok(Ok(TrainerExit::Completed(params))) => Ok(Some(params)),
                Ok(Ok(TrainerExit::Halted)) => Ok(None),
                Ok(Err(e)) => Err(e),
                Err(_) => Err(anyhow::anyhow!("trainer panicked")),
            },
            None => Ok(None),
        }
    }
}

pub struct SupervisorArgs {
    pub pool: ActorPool,
    pub bus: WeightBus,
    /// live handle onto the rollout topic: keeps it open for hot-attach,
    /// is the injection point for `TopicStall` chaos, and supplies the
    /// autoscaler's supply-saturation signal
    pub rollout_tx: Publisher<Rollout>,
    pub schedule: Option<ChaosSchedule>,
    pub stop: Arc<AtomicBool>,
    pub hub: MetricsHub,
    pub poll: Duration,
    /// portable-rollout hand-off queue shared with the actors; its depth
    /// is the autoscaler's rollout-queue backlog signal. None = legacy
    /// abort-on-kill behavior
    pub migrate: Option<Arc<MigrationHub>>,
    /// signal-driven pool resize (replaces chaos-only resize); None =
    /// fixed topology outside chaos events
    pub autoscale: Option<AutoScaler>,
    /// supervisor-owned trainer (trainer failover): the supervisor
    /// restarts a killed/crashed trainer from the latest checkpoint
    /// manifest and returns its final parameters. None = the orchestrator
    /// owns the trainer thread (plain runs)
    pub trainer: Option<TrainerSlot>,
    /// run control plane (`[control] enabled`): operator commands
    /// (pause/resume/drain/rollback/stop) plus the guardrail watchdog
    /// that auto-triggers pause-then-rollback. None = no control plane
    pub control: Option<ControlPlane>,
}

/// Supervision loop. Runs until `stop` is raised (trainer done), then
/// shuts the pool down. Chaos events fire once the weight bus's published
/// version passes their step — the logical clock shared with the trainer
/// — so a schedule replays in the same order on every run of its seed.
///
/// Returns the trainer's final parameters when the supervisor owns the
/// trainer slot (trainer failover mode), None otherwise.
pub fn run_supervisor(args: SupervisorArgs) -> Result<Option<Vec<HostTensor>>> {
    let SupervisorArgs {
        mut pool,
        bus,
        rollout_tx,
        schedule,
        stop,
        hub,
        poll,
        migrate,
        mut autoscale,
        mut trainer,
        mut control,
    } = args;
    let mut final_params: Option<Vec<HostTensor>> = None;
    let log = Logger::new("superv");
    // run/state gauge: transitions recorded live, a terminal value on
    // every exit path (completed / failed / drained / rolled_back)
    record_state(&hub, RunState::Running);
    let mut terminal: Option<RunState> = None;
    let mut drain_deadline: Option<Instant> = None;
    let mut drain_forced = false;
    let events = schedule
        .as_ref()
        .map(|s| s.events.clone())
        .unwrap_or_default();
    if let Some(s) = &schedule {
        log.info(&s.describe());
    }
    let mut next_event = 0usize;
    // slow kills in flight: (deadline, actor id) — the halt lands when
    // the deadline passes, the actor winds down asynchronously after that
    let mut slow_kills: Vec<(Instant, usize)> = Vec::new();
    let autoscale_every = match &autoscale {
        Some(a) => Duration::from_millis(a.cfg().eval_every_ms.max(1)),
        None => Duration::from_secs(3600),
    };
    let mut last_autoscale = Instant::now();

    loop {
        let stopping = stop.load(Ordering::Relaxed);
        let clock = bus.latest_version();
        while !stopping && next_event < events.len() && clock > events[next_event].at_step {
            let ev = events[next_event];
            next_event += 1;
            hub.add("chaos_events_fired", 1.0);
            log.info(&format!("firing at step {}: {}", ev.at_step, ev.kind));
            match ev.kind {
                ChaosKind::KillActor => {
                    if let Some(id) = pool.lowest_live() {
                        pool.kill_actor(id);
                    }
                }
                ChaosKind::SlowKillActor { delay_ms } => {
                    // target resolved at fire time (deterministic given
                    // the event sequence); the halt itself lands later,
                    // racing the rest of the pipeline
                    if let Some(id) = pool.lowest_live() {
                        slow_kills.push((Instant::now() + Duration::from_millis(delay_ms), id));
                    }
                }
                ChaosKind::RestartActor => {
                    if let Some(id) = pool.lowest_live() {
                        if let Err(e) = pool.restart_actor(id) {
                            unwind_pool(pool, &stop, &hub, &migrate, trainer.take());
                            return Err(e);
                        }
                    }
                }
                ChaosKind::AddActor => {
                    if let Err(e) = pool.add_actor() {
                        unwind_pool(pool, &stop, &hub, &migrate, trainer.take());
                        return Err(e);
                    }
                }
                ChaosKind::RemoveActor => {
                    if pool.len() > pool.min_actors() {
                        if let Some(id) = pool.highest_live() {
                            pool.kill_actor(id);
                            hub.add("actors_removed", 1.0);
                        }
                    }
                }
                ChaosKind::BusDelay { ms } => bus.set_publish_delay_ms(ms),
                ChaosKind::BusHeal => bus.set_publish_delay_ms(0),
                ChaosKind::TopicStall { ms } => {
                    rollout_tx.stall_for(Duration::from_millis(ms))
                }
                ChaosKind::CorruptSnapshot => {
                    // byzantine: bit-flipped PRLSNAP1 bytes enter the
                    // migration hub as if a corrupt peer deposited an
                    // in-flight rollout; the claim path must reject them
                    // with the books balanced and the claimer alive
                    if let Some(hub_m) = &migrate {
                        hub_m.deposit_raw(crate::testkit::chaos::corrupt_snapshot_bytes(
                            ev.at_step,
                        ));
                        hub.add("chaos_corrupt_snapshots_injected", 1.0);
                    }
                }
                ChaosKind::KillTrainer => {
                    // trainer failover: halt + join the live incarnation
                    // and respawn it from the latest checkpoint manifest
                    // — the run (actors, topics, migration hub) is never
                    // torn down. No-op without a supervisor-owned trainer
                    // or once the failover budget is spent.
                    match trainer.as_ref().map(|s| s.can_restart()) {
                        Some(true) => {
                            let res =
                                trainer.as_mut().expect("slot present").restart();
                            match res {
                                Ok(Some(params)) => {
                                    // the kill raced completion: the run
                                    // is simply done
                                    final_params = Some(params);
                                    stop.store(true, Ordering::Relaxed);
                                }
                                Ok(None) => {
                                    hub.add("trainer_failovers", 1.0);
                                    log.info(
                                        "trainer killed; failover from the \
                                         latest checkpoint manifest",
                                    );
                                }
                                Err(e) => {
                                    unwind_pool(
                                        pool, &stop, &hub, &migrate, trainer.take(),
                                    );
                                    return Err(e);
                                }
                            }
                        }
                        Some(false) => {
                            log.warn("kill-trainer skipped: failover budget spent")
                        }
                        None => {
                            log.info("kill-trainer no-op: no supervisor-owned trainer")
                        }
                    }
                }
                ChaosKind::GuardrailTrip => {
                    // forced guardrail firing: exercises the very same
                    // pause-then-rollback path a metric-driven trip takes.
                    // No-op without a control plane (like KillTrainer
                    // without a supervisor-owned trainer).
                    match control.as_mut() {
                        Some(ctl) => {
                            hub.add("guardrail_trips", 1.0);
                            hub.add("chaos_guardrail_trips", 1.0);
                            let trip = Trip {
                                reason: TripReason::Injected,
                                detail: format!(
                                    "chaos-injected guardrail trip at version clock {}",
                                    ev.at_step
                                ),
                            };
                            write_trip_report("chaos_guardrail_trip", &trip, "");
                            if attempt_rollback(
                                ctl,
                                &mut trainer,
                                &hub,
                                &log,
                                &stop,
                                &mut final_params,
                                &trip,
                            ) == RollbackOutcome::FailSafe
                            {
                                start_drain(
                                    ctl,
                                    &hub,
                                    &log,
                                    &mut drain_deadline,
                                    &mut drain_forced,
                                );
                            }
                        }
                        None => {
                            log.info("guardrail-trip no-op: control plane not attached")
                        }
                    }
                }
            }
        }
        // ---- control plane: operator commands + guardrail watchdog ----
        if let Some(ctl) = control.as_mut() {
            for cmd in ctl.controller.drain() {
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                log.info(&format!("control command: {cmd}"));
                match cmd {
                    RunCommand::Pause => {
                        if ctl.gate.phase() == AdmissionPhase::Running {
                            ctl.gate.set_phase(AdmissionPhase::Paused);
                            record_state(&hub, RunState::Paused);
                            hub.add("control_pauses", 1.0);
                        }
                    }
                    RunCommand::Resume => {
                        if ctl.gate.phase() == AdmissionPhase::Paused {
                            ctl.gate.set_phase(AdmissionPhase::Running);
                            record_state(&hub, RunState::Running);
                            hub.add("control_resumes", 1.0);
                        }
                    }
                    RunCommand::Drain => {
                        if ctl.gate.phase() != AdmissionPhase::Draining {
                            start_drain(ctl, &hub, &log, &mut drain_deadline, &mut drain_forced);
                        }
                    }
                    RunCommand::Rollback { checkpoint } => {
                        let trip = Trip {
                            reason: TripReason::Injected,
                            detail: match checkpoint {
                                Some(step) => format!(
                                    "operator rollback to step {step} (restored \
                                     through the latest manifest state)"
                                ),
                                None => "operator rollback to the latest manifest state"
                                    .into(),
                            },
                        };
                        if attempt_rollback(
                            ctl,
                            &mut trainer,
                            &hub,
                            &log,
                            &stop,
                            &mut final_params,
                            &trip,
                        ) == RollbackOutcome::FailSafe
                        {
                            start_drain(ctl, &hub, &log, &mut drain_deadline, &mut drain_forced);
                        }
                    }
                    RunCommand::Stop => {
                        hub.add("control_stops", 1.0);
                        stop.store(true, Ordering::Relaxed);
                    }
                }
            }
            // watchdog: only while actually running — a paused or
            // draining run produces no fresh evidence worth tripping on
            if !stopping
                && !stop.load(Ordering::Relaxed)
                && ctl.gate.phase() == AdmissionPhase::Running
            {
                if let Some(trip) = ctl.guardrail.check(&hub) {
                    hub.add("guardrail_trips", 1.0);
                    log.warn(&format!(
                        "guardrail trip: {} — {}",
                        trip.reason.name(),
                        trip.detail
                    ));
                    if let Some(p) =
                        write_trip_report(trip.reason.name(), &trip, &format!("clock {clock}"))
                    {
                        log.info(&format!("trip report: {}", p.display()));
                    }
                    if attempt_rollback(
                        ctl,
                        &mut trainer,
                        &hub,
                        &log,
                        &stop,
                        &mut final_params,
                        &trip,
                    ) == RollbackOutcome::FailSafe
                    {
                        start_drain(ctl, &hub, &log, &mut drain_deadline, &mut drain_forced);
                    }
                }
            }
        }
        // land expired slow kills (async: reap collects the exit later)
        if !slow_kills.is_empty() {
            let now = Instant::now();
            slow_kills.retain(|&(due, id)| {
                if due <= now {
                    if pool.halt_async(id) {
                        hub.add("chaos_slow_kills_landed", 1.0);
                    }
                    false
                } else {
                    true
                }
            });
        }
        // signal-driven resize (the OPPO-style rebalancing loop)
        if let Some(scaler) = &mut autoscale {
            if !stopping && last_autoscale.elapsed() >= autoscale_every {
                last_autoscale = Instant::now();
                let supply = rollout_tx.stats();
                let sig = ScaleSignals {
                    backlog: migrate.as_ref().map(|m| m.depth()).unwrap_or(0),
                    supply_depth: supply.depth,
                    supply_capacity: rollout_tx.capacity(),
                    token_lag: hub
                        .series_last("train/mean_lag_smoothed")
                        .map(|p| p.value)
                        .unwrap_or(0.0),
                    batch_fill: hub
                        .series_last("batch_fill")
                        .map(|p| p.value)
                        .unwrap_or(1.0),
                    // batch ESS for the ess_floor guard: prefer the device
                    // metric, fall back to the trainer's host oracle;
                    // before the first trained batch report 1.0 (fully
                    // on-policy) so the guard doesn't pin itself shut
                    ess: hub
                        .series_last("train/ess")
                        .or_else(|| hub.series_last("train/ess_host"))
                        .map(|p| p.value)
                        .unwrap_or(1.0),
                    pool: pool.len(),
                };
                match scaler.decide(&sig) {
                    ScaleDecision::Up => match pool.add_actor() {
                        Ok(Some(id)) => {
                            hub.add("autoscale_ups", 1.0);
                            log.info(&format!(
                                "autoscale up: +actor-{id} (backlog {}, pool {})",
                                sig.backlog,
                                pool.len()
                            ));
                        }
                        Ok(None) => {} // at the ceiling
                        Err(e) => {
                            // spawn failure (resource exhaustion): unwind
                            // like the fail-fast reap path so live actors
                            // halt and the migration books still close
                            unwind_pool(pool, &stop, &hub, &migrate, trainer.take());
                            return Err(e);
                        }
                    },
                    ScaleDecision::Down => {
                        if pool.len() > pool.min_actors() {
                            if let Some(id) = pool.highest_live() {
                                // async SIGTERM-style retirement: the
                                // victim deposits its in-flight sequences
                                // into the migration hub and exits on its
                                // own time; reap() collects it. Joining
                                // here (kill_actor) would freeze chaos
                                // firing / slow-kill deadlines / reap for
                                // the whole wind-down. The still-counted
                                // dying actor cannot re-trigger: the
                                // scaler's cooldown spans the wind-down
                                // and halt_async reports an already-
                                // halted victim as false.
                                if pool.halt_async(id) {
                                    hub.add("autoscale_downs", 1.0);
                                    log.info(&format!(
                                        "autoscale down: -actor-{id} (supply {}/{}, pool {})",
                                        sig.supply_depth,
                                        sig.supply_capacity,
                                        pool.len()
                                    ));
                                }
                            }
                        }
                    }
                    ScaleDecision::Hold => {}
                }
            }
        }
        // supervisor-owned trainer: completion stops the run; a crash
        // fails over to the latest checkpoint within the restart budget
        match trainer.as_mut().and_then(|s| s.poll()) {
            None => {}
            Some(Ok(TrainerExit::Completed(params))) => {
                final_params = Some(params);
                stop.store(true, Ordering::Relaxed);
            }
            Some(outcome) => {
                let why = match outcome {
                    Ok(TrainerExit::Halted) => {
                        anyhow::anyhow!("trainer halted outside a supervisor restart")
                    }
                    Err(e) => e,
                    Ok(TrainerExit::Completed(_)) => unreachable!("handled above"),
                };
                hub.add("trainer_crashes", 1.0);
                log.warn(&format!("trainer died: {why:#}"));
                if trainer.as_ref().is_some_and(|s| s.can_restart()) {
                    let res = trainer.as_mut().expect("slot present").restart();
                    match res {
                        Ok(Some(params)) => {
                            final_params = Some(params);
                            stop.store(true, Ordering::Relaxed);
                        }
                        Ok(None) => {
                            hub.add("trainer_failovers", 1.0);
                            log.info(
                                "trainer crash failover: resumed from the latest \
                                 checkpoint manifest",
                            );
                        }
                        Err(e) => {
                            unwind_pool(pool, &stop, &hub, &migrate, trainer.take());
                            return Err(e);
                        }
                    }
                } else {
                    unwind_pool(pool, &stop, &hub, &migrate, trainer.take());
                    return Err(why);
                }
            }
        }
        if let Err(e) = pool.reap() {
            // fail-fast crash (plain runs): unwind the whole topology
            // before surfacing the actor's error
            unwind_pool(pool, &stop, &hub, &migrate, trainer.take());
            return Err(e);
        }
        hub.set("pool_size", pool.len() as f64);
        // ---- drain progress ----
        let draining = control
            .as_ref()
            .is_some_and(|c| c.gate.phase() == AdmissionPhase::Draining);
        if draining && !stop.load(Ordering::Relaxed) {
            let ctl = control.as_ref().expect("checked above");
            // quiesced: no actor holds in-flight sequences and nothing
            // portable is parked in the migration hub
            let quiet = ctl.gate.total_load() == 0
                && migrate.as_ref().map_or(true, |m| m.depth() == 0);
            let expired = drain_deadline.is_some_and(|d| Instant::now() >= d);
            if !drain_forced {
                if quiet {
                    terminal = Some(RunState::Drained);
                    stop.store(true, Ordering::Relaxed);
                    log.info("drain complete: run quiesced");
                } else if expired {
                    // grace expired with stragglers: force the wind-down.
                    // Halting with the global stop still low routes each
                    // actor through its migrating exit — truncated
                    // prefixes flush as trainable rollouts under
                    // `[rl] train_truncated`, the rest deposit into the
                    // hub with the conservation books closed.
                    drain_forced = true;
                    drain_deadline = Some(Instant::now() + DRAIN_GRACE);
                    pool.set_min_actors(0);
                    for id in pool.live_ids() {
                        pool.halt_async(id);
                    }
                    hub.add("control_drains_forced", 1.0);
                    log.warn("drain grace expired: force-halting actors to flush prefixes");
                }
            } else if pool.is_empty() || expired {
                terminal = Some(RunState::Drained);
                stop.store(true, Ordering::Relaxed);
                log.info("forced drain complete");
            }
        }
        if !stop.load(Ordering::Relaxed) && pool.is_empty() && !draining {
            // no live actors and no respawn budget left: unwind the run
            // instead of letting the trainer wait on rollouts forever
            let why = pool
                .last_crash()
                .map(str::to_string)
                .unwrap_or_else(|| "all actors exited".into());
            unwind_pool(pool, &stop, &hub, &migrate, trainer.take());
            anyhow::bail!("actor pool has no live actors left ({why})");
        }
        if stopping {
            break;
        }
        std::thread::sleep(poll);
    }
    // trainer teardown first: stop is raised, so a live incarnation
    // returns promptly, and its error — the likely root cause — outranks
    // pool-shutdown noise
    let trainer_res = match &mut trainer {
        Some(slot) => slot.finish(),
        None => Ok(None),
    };
    let out = pool.shutdown();
    discard_leftover_snapshots(&hub, &migrate);
    // terminal run/state: a drained run stays Drained; a tail error is a
    // Failed run even though the books above already closed
    match (trainer_res, out) {
        (Ok(joined), Ok(())) => {
            record_state(&hub, terminal.unwrap_or(RunState::Completed));
            Ok(final_params.or(joined))
        }
        (Err(e), _) | (Ok(_), Err(e)) => {
            record_state(&hub, RunState::Failed);
            Err(e)
        }
    }
    // rollout_tx (and the pool's SpawnFn publisher clone) drop here,
    // closing the topic so the preprocessor drains and exits.
}

/// How long a drain waits for in-flight sequences before force-halting
/// the stragglers (and then again for the forced wind-down itself).
const DRAIN_GRACE: Duration = Duration::from_secs(10);

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum RollbackOutcome {
    /// Trainer restored from the checkpoint manifest; run resumed.
    RolledBack,
    /// The restart raced the trainer's completion: the run is done.
    Completed,
    /// Budget exhausted (or no restartable trainer): the caller must
    /// fail safe into a drain.
    FailSafe,
}

/// Pause-then-rollback: quiesce the actors through the gate (they park
/// in-flight sequences into the migration hub with the conservation
/// books closed), then restore the trainer from the latest checkpoint
/// manifest through the failover slot, retrying with exponential
/// backoff within the control plane's rollback budget. Never errors:
/// an unrecoverable rollback degrades to [`RollbackOutcome::FailSafe`].
fn attempt_rollback(
    ctl: &mut ControlPlane,
    trainer: &mut Option<TrainerSlot>,
    hub: &MetricsHub,
    log: &Logger,
    stop: &Arc<AtomicBool>,
    final_params: &mut Option<Vec<HostTensor>>,
    trip: &Trip,
) -> RollbackOutcome {
    // quiesce first so no actor trains forward on the poisoned policy
    // while the trainer is being restored
    ctl.gate.set_phase(AdmissionPhase::Paused);
    record_state(hub, RunState::Paused);
    let mut attempt = 0usize;
    loop {
        let restartable = trainer.as_ref().is_some_and(|s| s.can_restart());
        if !restartable || ctl.rollbacks_left == 0 {
            log.warn(&format!(
                "rollback for {} abandoned ({}): failing safe into a drain",
                trip.reason.name(),
                if restartable { "rollback budget exhausted" } else { "no restartable trainer" }
            ));
            hub.add("control_failsafe_drains", 1.0);
            return RollbackOutcome::FailSafe;
        }
        ctl.rollbacks_left -= 1;
        std::thread::sleep(ctl.backoff(attempt));
        match trainer.as_mut().expect("checked above").restart() {
            Ok(Some(params)) => {
                *final_params = Some(params);
                stop.store(true, Ordering::Relaxed);
                return RollbackOutcome::Completed;
            }
            Ok(None) => {
                hub.add("control_rollbacks", 1.0);
                hub.add("trainer_failovers", 1.0);
                record_state(hub, RunState::RolledBack);
                // the evidence that justified this rollback is spent —
                // without the acknowledge, the same points would re-trip
                // the guardrail on the very next poll, forever
                ctl.guardrail.acknowledge(hub);
                ctl.gate.set_phase(AdmissionPhase::Running);
                log.info(&format!(
                    "rolled back to the latest checkpoint manifest ({}); run resumed",
                    trip.reason.name()
                ));
                return RollbackOutcome::RolledBack;
            }
            Err(e) => {
                log.warn(&format!(
                    "rollback attempt {} failed: {e:#}; retrying with backoff",
                    attempt + 1
                ));
                attempt += 1;
            }
        }
    }
}

/// Enter the draining phase: admissions close, active sequences run to
/// completion, and the grace clock starts (see the drain-progress block
/// in [`run_supervisor`]).
fn start_drain(
    ctl: &ControlPlane,
    hub: &MetricsHub,
    log: &Logger,
    drain_deadline: &mut Option<Instant>,
    drain_forced: &mut bool,
) {
    ctl.gate.set_phase(AdmissionPhase::Draining);
    record_state(hub, RunState::Draining);
    hub.add("control_drains", 1.0);
    *drain_deadline = Some(Instant::now() + DRAIN_GRACE);
    *drain_forced = false;
    log.info("draining: admissions closed; letting in-flight sequences finish");
}

/// Fail-path teardown: raise `stop`, join the supervisor-owned trainer
/// (if any), halt + join every actor, close the migration books. Every
/// error exit from [`run_supervisor`] must go through here (the normal
/// exit runs the same sequence inline at the tail) so `deposited ==
/// claimed + discarded` holds even on failed runs — where the accounting
/// matters most.
fn unwind_pool(
    pool: ActorPool,
    stop: &Arc<AtomicBool>,
    hub: &MetricsHub,
    migrate: &Option<Arc<MigrationHub>>,
    trainer: Option<TrainerSlot>,
) {
    stop.store(true, Ordering::Relaxed);
    if let Some(mut slot) = trainer {
        slot.finish().ok();
    }
    pool.shutdown().ok();
    discard_leftover_snapshots(hub, migrate);
    record_state(hub, RunState::Failed);
}

/// Snapshots still queued once every actor is down are deliberately
/// discarded — the accounting counter closes the no-token-lost books
/// (deposited == claimed + discarded). Runs on *every* supervisor exit,
/// including the fail-fast and dead-pool error paths: the books matter
/// most when diagnosing a failed run.
fn discard_leftover_snapshots(hub: &MetricsHub, migrate: &Option<Arc<MigrationHub>>) {
    if let Some(hub_m) = migrate {
        let n = hub_m.discard_all();
        if n > 0 {
            hub.add("migration_snaps_discarded", n as f64);
        }
    }
}
