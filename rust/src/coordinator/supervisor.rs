//! Elastic, fault-tolerant actor-pool supervision.
//!
//! The seed topology spawned every stage exactly once and could survive
//! nothing; production-scale async RL (LlamaRL-style generator churn)
//! needs the actor tier to be **elastic**. This module provides:
//!
//! * [`ActorPool`] — a supervised set of actor threads. Each incarnation
//!   gets its own `halt` kill-switch next to the global `stop`, so one
//!   actor can be killed / restarted / added / removed mid-run. New
//!   actors *hot-join*: they clone a live rollout [`Publisher`] (the pool
//!   keeps the topic open, so the publishers-dropped → `RecvError::Closed`
//!   path never fires mid-run) and register on the [`WeightBus`] process
//!   group, picking up the latest published weights.
//! * [`run_supervisor`] — the monitor loop: reaps crashed actors and
//!   restarts them within a restart budget, tops the pool back up to its
//!   floor, and fires the events of a deterministic
//!   [`ChaosSchedule`](crate::testkit::chaos::ChaosSchedule) against the
//!   pipeline's logical clock (the weight bus's published version).
//!
//! The pool is deliberately generic over a [`SpawnFn`] closure rather
//! than hard-wired to [`super::actor::run_actor`]: the chaos tests drive
//! the very same supervision machinery with synthetic actors, so the
//! kill/restart/hot-attach logic is exercised even in environments where
//! the PJRT engine is unavailable.

use crate::broker::Publisher;
use crate::metrics::MetricsHub;
use crate::rl::Rollout;
use crate::testkit::chaos::{ChaosKind, ChaosSchedule};
use crate::util::logging::Logger;
use crate::weights::WeightBus;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Identity handed to each spawned actor incarnation.
pub struct ActorCtx {
    pub actor_id: usize,
    /// restart count of this slot (0 = first spawn)
    pub generation: u64,
    /// global run shutdown flag
    pub stop: Arc<AtomicBool>,
    /// kill-switch for this incarnation only
    pub halt: Arc<AtomicBool>,
}

/// Actor body. Must poll `ctx.stop` / `ctx.halt` and return promptly when
/// either is raised.
pub type SpawnFn = Arc<dyn Fn(ActorCtx) -> Result<()> + Send + Sync + 'static>;

struct Slot {
    halt: Arc<AtomicBool>,
    join: JoinHandle<Result<()>>,
    generation: u64,
}

/// Supervised, resizable set of actor threads.
pub struct ActorPool {
    spawn: SpawnFn,
    stop: Arc<AtomicBool>,
    hub: MetricsHub,
    log: Logger,
    slots: BTreeMap<usize, Slot>,
    next_id: usize,
    min_actors: usize,
    max_actors: usize,
    max_restarts: usize,
    restarts_used: usize,
    /// propagate the first crash instead of restarting (plain,
    /// non-elastic runs keep the fail-on-actor-error semantics)
    fail_fast: bool,
    last_crash: Option<String>,
}

impl ActorPool {
    /// Build a pool and spawn `initial` actors (ids `0..initial`).
    pub fn new(
        spawn: SpawnFn,
        stop: Arc<AtomicBool>,
        hub: MetricsHub,
        initial: usize,
        min_actors: usize,
        max_actors: usize,
        max_restarts: usize,
        fail_fast: bool,
    ) -> Result<ActorPool> {
        let mut pool = ActorPool {
            spawn,
            stop,
            hub,
            log: Logger::new("actorpool"),
            slots: BTreeMap::new(),
            next_id: 0,
            min_actors,
            max_actors,
            max_restarts,
            restarts_used: 0,
            fail_fast,
            last_crash: None,
        };
        for _ in 0..initial {
            pool.add_actor()?;
        }
        Ok(pool)
    }

    /// Message of the most recent crash seen by [`ActorPool::reap`].
    pub fn last_crash(&self) -> Option<&str> {
        self.last_crash.as_deref()
    }

    fn spawn_slot(&mut self, actor_id: usize, generation: u64) -> Result<()> {
        let halt = Arc::new(AtomicBool::new(false));
        let ctx = ActorCtx {
            actor_id,
            generation,
            stop: self.stop.clone(),
            halt: halt.clone(),
        };
        let body = self.spawn.clone();
        let join = std::thread::Builder::new()
            .name(format!("actor-{actor_id}.g{generation}"))
            .spawn(move || body(ctx))
            .with_context(|| format!("spawning actor-{actor_id}"))?;
        self.slots.insert(actor_id, Slot { halt, join, generation });
        Ok(())
    }

    /// Grow the pool by one actor. Returns the new id, or None at the
    /// `max_actors` ceiling.
    pub fn add_actor(&mut self) -> Result<Option<usize>> {
        if self.slots.len() >= self.max_actors {
            return Ok(None);
        }
        let id = self.next_id;
        self.next_id += 1;
        self.spawn_slot(id, 0)?;
        self.hub.add("actors_spawned", 1.0);
        Ok(Some(id))
    }

    /// Halt one actor and join its thread. In-flight sequences are
    /// aborted by the actor's own halt path. Returns false for unknown
    /// ids. A crash surfaced at join time is recorded, not propagated —
    /// killing an already-dying actor is not an error.
    pub fn kill_actor(&mut self, actor_id: usize) -> bool {
        let Some(slot) = self.slots.remove(&actor_id) else {
            return false;
        };
        slot.halt.store(true, Ordering::Relaxed);
        match slot.join.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => self.log.warn(&format!("actor-{actor_id} died on kill: {e:#}")),
            Err(_) => self.log.warn(&format!("actor-{actor_id} panicked")),
        }
        self.hub.add("actors_killed", 1.0);
        true
    }

    /// Kill + immediately respawn the same slot (next generation).
    pub fn restart_actor(&mut self, actor_id: usize) -> Result<bool> {
        let generation = match self.slots.get(&actor_id) {
            Some(s) => s.generation + 1,
            None => return Ok(false),
        };
        self.kill_actor(actor_id);
        self.spawn_slot(actor_id, generation)?;
        self.hub.add("actor_restarts", 1.0);
        Ok(true)
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    pub fn min_actors(&self) -> usize {
        self.min_actors
    }

    pub fn lowest_live(&self) -> Option<usize> {
        self.slots.keys().next().copied()
    }

    pub fn highest_live(&self) -> Option<usize> {
        self.slots.keys().next_back().copied()
    }

    /// Collect actors whose threads have exited. Crashed ones are
    /// restarted while the shared respawn budget lasts (with
    /// `fail_fast`, the first crash is returned as an error instead);
    /// clean exits are retired. Afterwards the pool is topped back up
    /// towards `min_actors` — floor top-ups draw from the same budget,
    /// so a persistent fault cannot produce an unbounded crash loop.
    /// Returns the number of restarts performed.
    pub fn reap(&mut self) -> Result<usize> {
        let finished: Vec<usize> = self
            .slots
            .iter()
            .filter(|(_, s)| s.join.is_finished())
            .map(|(&id, _)| id)
            .collect();
        let mut restarted = 0;
        for id in finished {
            let slot = self.slots.remove(&id).unwrap();
            let crash = match slot.join.join() {
                Ok(Ok(())) => None,
                Ok(Err(e)) => Some(format!("actor-{id} crashed: {e:#}")),
                Err(_) => Some(format!("actor-{id} panicked")),
            };
            if let Some(why) = crash {
                self.log.warn(&why);
                self.hub.add("actor_crashes", 1.0);
                if self.fail_fast {
                    self.last_crash = Some(why.clone());
                    anyhow::bail!("{why}");
                }
                self.last_crash = Some(why);
                if self.restarts_used < self.max_restarts {
                    self.restarts_used += 1;
                    self.spawn_slot(id, slot.generation + 1)?;
                    self.hub.add("actor_restarts", 1.0);
                    restarted += 1;
                    self.log.info(&format!(
                        "restarted actor-{id} (generation {}, budget {}/{})",
                        slot.generation + 1,
                        self.restarts_used,
                        self.max_restarts
                    ));
                } else {
                    self.log.warn(&format!(
                        "actor-{id} abandoned: respawn budget ({}) exhausted",
                        self.max_restarts
                    ));
                    self.hub.add("actor_slots_abandoned", 1.0);
                }
            }
        }
        // elastic floor: keep at least min_actors generating. Budgeted,
        // so a fault that keeps killing fresh actors eventually empties
        // the pool and the supervisor escalates instead of thrashing.
        while self.slots.len() < self.min_actors
            && !self.stop.load(Ordering::Relaxed)
            && self.restarts_used < self.max_restarts
        {
            self.restarts_used += 1;
            if self.add_actor()?.is_none() {
                break;
            }
        }
        Ok(restarted)
    }

    /// Halt everything and join. First actor error is propagated.
    pub fn shutdown(mut self) -> Result<()> {
        for slot in self.slots.values() {
            slot.halt.store(true, Ordering::Relaxed);
        }
        let mut first_err = None;
        for (id, slot) in std::mem::take(&mut self.slots) {
            match slot.join.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) if first_err.is_none() => first_err = Some(e),
                Ok(Err(_)) => {}
                Err(_) if first_err.is_none() => {
                    first_err = Some(anyhow::anyhow!("actor-{id} panicked"))
                }
                Err(_) => {}
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

pub struct SupervisorArgs {
    pub pool: ActorPool,
    pub bus: WeightBus,
    /// live handle onto the rollout topic: keeps it open for hot-attach
    /// and is the injection point for `TopicStall` chaos
    pub rollout_tx: Publisher<Rollout>,
    pub schedule: Option<ChaosSchedule>,
    pub stop: Arc<AtomicBool>,
    pub hub: MetricsHub,
    pub poll: Duration,
}

/// Supervision loop. Runs until `stop` is raised (trainer done), then
/// shuts the pool down. Chaos events fire once the weight bus's published
/// version passes their step — the logical clock shared with the trainer
/// — so a schedule replays in the same order on every run of its seed.
pub fn run_supervisor(args: SupervisorArgs) -> Result<()> {
    let SupervisorArgs { mut pool, bus, rollout_tx, schedule, stop, hub, poll } = args;
    let log = Logger::new("superv");
    let events = schedule
        .as_ref()
        .map(|s| s.events.clone())
        .unwrap_or_default();
    if let Some(s) = &schedule {
        log.info(&s.describe());
    }
    let mut next_event = 0usize;

    loop {
        let stopping = stop.load(Ordering::Relaxed);
        let clock = bus.latest_version();
        while !stopping && next_event < events.len() && clock > events[next_event].at_step {
            let ev = events[next_event];
            next_event += 1;
            hub.add("chaos_events_fired", 1.0);
            log.info(&format!("firing at step {}: {}", ev.at_step, ev.kind));
            match ev.kind {
                ChaosKind::KillActor => {
                    if let Some(id) = pool.lowest_live() {
                        pool.kill_actor(id);
                    }
                }
                ChaosKind::RestartActor => {
                    if let Some(id) = pool.lowest_live() {
                        pool.restart_actor(id)?;
                    }
                }
                ChaosKind::AddActor => {
                    pool.add_actor()?;
                }
                ChaosKind::RemoveActor => {
                    if pool.len() > pool.min_actors() {
                        if let Some(id) = pool.highest_live() {
                            pool.kill_actor(id);
                            hub.add("actors_removed", 1.0);
                        }
                    }
                }
                ChaosKind::BusDelay { ms } => bus.set_publish_delay_ms(ms),
                ChaosKind::BusHeal => bus.set_publish_delay_ms(0),
                ChaosKind::TopicStall { ms } => {
                    rollout_tx.stall_for(Duration::from_millis(ms))
                }
            }
        }
        if let Err(e) = pool.reap() {
            // fail-fast crash (plain runs): unwind the whole topology
            // before surfacing the actor's error
            stop.store(true, Ordering::Relaxed);
            pool.shutdown().ok();
            return Err(e);
        }
        if !stop.load(Ordering::Relaxed) && pool.is_empty() {
            // no live actors and no respawn budget left: unwind the run
            // instead of letting the trainer wait on rollouts forever
            stop.store(true, Ordering::Relaxed);
            let why = pool
                .last_crash()
                .map(str::to_string)
                .unwrap_or_else(|| "all actors exited".into());
            pool.shutdown().ok();
            anyhow::bail!("actor pool has no live actors left ({why})");
        }
        if stopping {
            break;
        }
        std::thread::sleep(poll);
    }
    pool.shutdown()
    // rollout_tx (and the pool's SpawnFn publisher clone) drop here,
    // closing the topic so the preprocessor drains and exits.
}
