//! Conventional-RL phase synchronization (Algorithm 1).
//!
//! Conventional RL alternates generation and training globally. The sync
//! object gates the actors: during a **Generate** phase each actor takes
//! prompt groups from a shared quota, finishes *every* in-flight sequence
//! (reproducing the batch-drain tail of Fig 2b), and when the last
//! sequence lands the phase flips to **Train**; actors then block until
//! the trainer has run the RL step's optimizer steps and published the
//! new weights.

use std::sync::{Condvar, Mutex};
use std::time::Duration;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Generate,
    Train,
}

#[derive(Debug)]
struct ConvState {
    phase: Phase,
    /// prompt groups still available to take this phase
    groups_to_submit: usize,
    /// sequences submitted but not yet finished
    outstanding: usize,
    /// sequences finished this phase
    finished: usize,
}

#[derive(Debug)]
pub struct ConvSync {
    state: Mutex<ConvState>,
    cv: Condvar,
}

impl ConvSync {
    /// Starts in a Generate phase with `groups` prompt groups.
    pub fn new(groups: usize) -> Self {
        ConvSync {
            state: Mutex::new(ConvState {
                phase: Phase::Generate,
                groups_to_submit: groups,
                outstanding: 0,
                finished: 0,
            }),
            cv: Condvar::new(),
        }
    }

    pub fn phase(&self) -> Phase {
        self.state.lock().unwrap().phase
    }

    /// Actor: claim one prompt group (of `group_size` sequences).
    pub fn try_take_group(&self, group_size: usize) -> bool {
        let mut s = self.state.lock().unwrap();
        if s.phase == Phase::Generate && s.groups_to_submit > 0 {
            s.groups_to_submit -= 1;
            s.outstanding += group_size;
            true
        } else {
            false
        }
    }

    /// Actor: report one finished sequence. Flips to Train when the quota
    /// is exhausted and nothing is in flight.
    pub fn report_finished(&self) {
        let mut s = self.state.lock().unwrap();
        s.outstanding = s.outstanding.saturating_sub(1);
        s.finished += 1;
        if s.phase == Phase::Generate && s.groups_to_submit == 0 && s.outstanding == 0 {
            s.phase = Phase::Train;
            self.cv.notify_all();
        }
    }

    /// Actor: true while it should keep stepping its engine (quota left
    /// or sequences still draining).
    pub fn generating(&self) -> bool {
        let s = self.state.lock().unwrap();
        s.phase == Phase::Generate
    }

    /// Actor: block while the trainer works. Returns promptly on timeout
    /// so stop flags can be polled.
    pub fn wait_generate(&self, timeout: Duration) {
        let s = self.state.lock().unwrap();
        let _ = self
            .cv
            .wait_timeout_while(s, timeout, |s| s.phase == Phase::Train)
            .unwrap();
    }

    /// Preprocessor/trainer: block until the Generate phase has fully
    /// drained (phase == Train). Returns the number of finished seqs.
    pub fn wait_train(&self, timeout: Duration) -> Option<usize> {
        let s = self.state.lock().unwrap();
        let (s, res) = self
            .cv
            .wait_timeout_while(s, timeout, |s| s.phase == Phase::Generate)
            .unwrap();
        if res.timed_out() && s.phase == Phase::Generate {
            None
        } else {
            Some(s.finished)
        }
    }

    /// Trainer: open the next Generate phase with a fresh quota.
    pub fn begin_generate(&self, groups: usize) {
        let mut s = self.state.lock().unwrap();
        s.phase = Phase::Generate;
        s.groups_to_submit = groups;
        s.outstanding = 0;
        s.finished = 0;
        drop(s);
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::thread;

    #[test]
    fn quota_then_drain_flips_phase() {
        let c = ConvSync::new(2);
        assert!(c.try_take_group(3));
        assert!(c.try_take_group(3));
        assert!(!c.try_take_group(3), "quota exhausted");
        assert_eq!(c.phase(), Phase::Generate);
        for _ in 0..5 {
            c.report_finished();
        }
        assert_eq!(c.phase(), Phase::Generate, "one still in flight");
        c.report_finished();
        assert_eq!(c.phase(), Phase::Train);
    }

    #[test]
    fn begin_generate_resets() {
        let c = ConvSync::new(1);
        assert!(c.try_take_group(1));
        c.report_finished();
        assert_eq!(c.phase(), Phase::Train);
        c.begin_generate(4);
        assert_eq!(c.phase(), Phase::Generate);
        assert!(c.try_take_group(1));
    }

    #[test]
    fn waiters_wake_on_flip() {
        let c = Arc::new(ConvSync::new(1));
        let c2 = c.clone();
        let waiter = thread::spawn(move || c2.wait_train(Duration::from_secs(5)));
        thread::sleep(Duration::from_millis(30));
        assert!(c.try_take_group(2));
        c.report_finished();
        c.report_finished();
        assert_eq!(waiter.join().unwrap(), Some(2));

        // actors wake when training ends
        let c3 = c.clone();
        let actor = thread::spawn(move || {
            c3.wait_generate(Duration::from_secs(5));
            c3.phase()
        });
        thread::sleep(Duration::from_millis(30));
        c.begin_generate(1);
        assert_eq!(actor.join().unwrap(), Phase::Generate);
    }

    #[test]
    fn wait_train_times_out_while_generating() {
        let c = ConvSync::new(5);
        assert_eq!(c.wait_train(Duration::from_millis(20)), None);
    }
}
