//! Orchestrator: builds the pipeline topology from a [`RunConfig`],
//! runs the SFT warmup, spawns the stage threads, and collects the
//! [`RunReport`].
//!
//! Thread topology (each stage constructs its own PJRT runtime — the
//! xla handles are not Send, and the paper's stages each own their own
//! accelerator pool anyway):
//!
//! ```text
//!   main ── sft warmup ── publish v1 ──┬── actor-0 .. actor-(A-1)
//!                                      ├── preprocessor
//!                                      └── trainer (returns final params)
//! ```

use super::actor::{run_actor, ActorArgs};
use super::conv::ConvSync;
use super::packing::TrainBatch;
use super::preprocessor::{run_preprocessor, PreprocessorArgs};
use super::trainer::{run_trainer, TrainerArgs};
use super::warmup;
use crate::broker::{topic, Policy};
use crate::config::{Mode, RunConfig};
use crate::metrics::{MetricsHub, RunReport};
use crate::rl::Rollout;
use crate::runtime::{HostTensor, Runtime};
use crate::util::logging::Logger;
use crate::util::timer::global_seconds;
use crate::weights::WeightBus;
use anyhow::{Context, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

pub struct RunSummary {
    pub report: RunReport,
    pub final_params: Vec<HostTensor>,
    pub initial_params: Vec<HostTensor>,
    pub wall_seconds: f64,
}

/// Run a full PipelineRL (or Conventional-RL) training job.
///
/// `warm_params`: reuse an existing warmed-up parameter set (e.g. so that
/// pipeline/conventional comparisons start from the *same* base model);
/// None runs the SFT warmup.
pub fn run(cfg: RunConfig, warm_params: Option<Vec<HostTensor>>) -> Result<RunSummary> {
    cfg.validate()?;
    let log = Logger::new("orchestr");
    let hub = MetricsHub::new();
    let t0 = global_seconds();

    // ---- warmup (base-model stand-in) ----
    let initial_params = match warm_params {
        Some(p) => p,
        None => {
            let mut rt = Runtime::new().context("orchestrator runtime")?;
            log.info(&format!(
                "sft warmup: {} steps on variant {}",
                cfg.sft_steps, cfg.variant
            ));
            warmup::run_sft(&mut rt, &cfg, &hub)?
        }
    };

    // ---- topology ----
    let bus = WeightBus::new();
    bus.publish(1, Arc::new(initial_params.clone()));
    let (rollout_tx, rollout_rx) =
        topic::<Rollout>("rollouts", cfg.rollout_queue, cfg.rollout_policy);
    let (batch_tx, batch_rx) =
        topic::<TrainBatch>("batches", cfg.batch_queue, Policy::Block);
    let stop = Arc::new(AtomicBool::new(false));

    let (b, t) = {
        let rt = Runtime::new()?; // manifest only; cheap
        let v = rt.manifest.variant(&cfg.variant)?;
        (v.train_batch, v.seq_len)
    };

    // conventional quota: ~G optimizer batches' worth of sequences
    let conv_groups = match cfg.mode {
        Mode::Conventional { g } => (g * b).div_ceil(cfg.group_size).max(1),
        Mode::Pipeline => 0,
    };
    let conv = match cfg.mode {
        Mode::Conventional { .. } => Some(Arc::new(ConvSync::new(conv_groups))),
        Mode::Pipeline => None,
    };

    // ---- spawn stages ----
    let mut actor_handles = Vec::new();
    for actor_id in 0..cfg.n_actors {
        let args = ActorArgs {
            actor_id,
            cfg: cfg.clone(),
            bus: bus.clone(),
            rollout_tx: rollout_tx.clone(),
            hub: hub.clone(),
            stop: stop.clone(),
            conv: conv.clone(),
        };
        actor_handles.push(
            std::thread::Builder::new()
                .name(format!("actor-{actor_id}"))
                .spawn(move || run_actor(args))?,
        );
    }
    drop(rollout_tx); // actors hold the only publishers now

    let pre_args = PreprocessorArgs {
        cfg: cfg.clone(),
        b,
        t,
        rollout_rx,
        batch_tx,
        hub: hub.clone(),
        stop: stop.clone(),
        conv: conv.clone(),
    };
    let pre_handle = std::thread::Builder::new()
        .name("preproc".into())
        .spawn(move || run_preprocessor(pre_args))?;

    let trainer_args = TrainerArgs {
        cfg: cfg.clone(),
        initial_params: initial_params.clone(),
        batch_rx,
        bus: bus.clone(),
        hub: hub.clone(),
        stop: stop.clone(),
        conv: conv.clone(),
        conv_groups,
    };
    let trainer_handle = std::thread::Builder::new()
        .name("trainer".into())
        .spawn(move || run_trainer(trainer_args))?;

    // ---- run to completion ----
    let final_params = trainer_handle
        .join()
        .map_err(|_| anyhow::anyhow!("trainer panicked"))??;
    stop.store(true, Ordering::Relaxed);
    for h in actor_handles {
        h.join().map_err(|_| anyhow::anyhow!("actor panicked"))??;
    }
    pre_handle
        .join()
        .map_err(|_| anyhow::anyhow!("preprocessor panicked"))??;

    let wall = global_seconds() - t0;
    hub.add("wall_seconds", wall);
    hub.add("weight_bus_bytes", bus.bytes_fetched() as f64);
    hub.add("weight_bus_publishes", bus.publishes() as f64);
    log.info(&format!(
        "run complete: mode={} wall={:.1}s samples={}",
        cfg.mode.name(),
        wall,
        hub.counter("samples_trained")
    ));

    Ok(RunSummary {
        report: hub.snapshot(),
        final_params,
        initial_params,
        wall_seconds: wall,
    })
}
