//! Orchestrator: builds the pipeline topology from a [`RunConfig`],
//! runs the SFT warmup (or loads a resume state), spawns the stage
//! threads, and collects the [`RunReport`].
//!
//! Thread topology (each stage constructs its own PJRT runtime — the
//! xla handles are not Send, and the paper's stages each own their own
//! accelerator pool anyway):
//!
//! ```text
//!   main ── warmup/resume ── publish v ──┬── supervisor ── actor pool
//!                                        ├── preprocessor
//!                                        └── trainer (returns final params)
//! ```
//!
//! Actors always run under the [`super::supervisor::ActorPool`] and its
//! supervisor thread. In plain runs the pool is fixed-size and
//! fail-fast (an actor error unwinds the run, as before); with
//! `[elastic] enabled = true` (or a chaos schedule) the supervisor
//! instead restarts crashes within a respawn budget, resizes the pool,
//! and injects the schedule's faults against the weight-bus version
//! clock. Elastic pipeline runs additionally get **partial-rollout
//! migration** (a killed/descaled actor's in-flight sequences re-enqueue
//! through a shared `sched::MigrationHub` instead of aborting) and, with
//! `[autoscale] enabled = true`, **signal-driven pool resize**
//! (`sched::AutoScaler` watching rollout-queue backlog, supply
//! saturation, token lag and batch fill).
//!
//! With `[checkpoint] resume_from` set, the warmup is skipped entirely:
//! the checkpoint's parameters are published at version `step + 1` and
//! the trainer continues the optimizer trajectory from the saved state.

use super::actor::{run_actor, ActorArgs};
use super::conv::ConvSync;
use super::packing::TrainBatch;
use super::preprocessor::{run_preprocessor, PreprocessorArgs};
use super::supervisor::{
    run_supervisor, ActorPool, SpawnFn, SupervisorArgs, TrainerSlot, TrainerSpawnFn,
};
use super::trainer::{run_trainer, TrainerArgs, TrainerExit};
use super::warmup;
use crate::broker::{topic, Policy};
use crate::config::{Mode, RunConfig};
use crate::control::{ControlPlane, RunController};
use crate::metrics::{MetricsHub, RunReport};
use crate::model::checkpoint::{read_manifest, TrainState};
use crate::rl::Rollout;
use crate::runtime::{HostTensor, Runtime};
use crate::sched::{AutoScaler, MigrationHub};
use crate::testkit::chaos::ChaosSchedule;
use crate::util::logging::Logger;
use crate::util::timer::global_seconds;
use crate::weights::WeightBus;
use anyhow::{Context, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// True when an error chain bottoms out in io::ErrorKind::NotFound —
/// the one load failure that legitimately means "no checkpoint has
/// landed yet" on the trainer-failover respawn path.
fn io_not_found(e: &anyhow::Error) -> bool {
    e.root_cause()
        .downcast_ref::<std::io::Error>()
        .is_some_and(|io| io.kind() == std::io::ErrorKind::NotFound)
}

pub struct RunSummary {
    pub report: RunReport,
    pub final_params: Vec<HostTensor>,
    pub initial_params: Vec<HostTensor>,
    pub wall_seconds: f64,
}

/// Run a full PipelineRL (or Conventional-RL) training job.
///
/// `warm_params`: reuse an existing warmed-up parameter set (e.g. so that
/// pipeline/conventional comparisons start from the *same* base model);
/// None runs the SFT warmup.
pub fn run(cfg: RunConfig, warm_params: Option<Vec<HostTensor>>) -> Result<RunSummary> {
    run_with_chaos(cfg, warm_params, None)
}

/// [`run`], optionally under a deterministic chaos schedule. Passing a
/// schedule implies supervision even when `[elastic]` is not enabled.
pub fn run_with_chaos(
    cfg: RunConfig,
    warm_params: Option<Vec<HostTensor>>,
    chaos: Option<ChaosSchedule>,
) -> Result<RunSummary> {
    run_controlled(cfg, warm_params, chaos, None)
}

/// [`run_with_chaos`] with an externally-held [`RunController`]: the
/// caller keeps the command channel and can pause / resume / drain /
/// roll back / stop the run while it executes. The controller is only
/// wired when `[control] enabled = true`; it is ignored otherwise.
pub fn run_controlled(
    cfg: RunConfig,
    warm_params: Option<Vec<HostTensor>>,
    chaos: Option<ChaosSchedule>,
    controller: Option<RunController>,
) -> Result<RunSummary> {
    cfg.validate()?;
    if chaos.is_some() && !matches!(cfg.mode, Mode::Pipeline) {
        anyhow::bail!(
            "chaos injection requires pipeline mode (conventional RL's phase \
             barrier cannot survive actor churn)"
        );
    }
    let log = Logger::new("orchestr");
    let hub = MetricsHub::new();
    // gauge so dashboards can tell gateway-fronted runs apart at a
    // glance; with the default `[gateway] enabled = false` the run's
    // trajectory is golden-digest-identical to pre-gateway builds
    hub.set(
        "gateway/enabled",
        if cfg.gateway.enabled { 1.0 } else { 0.0 },
    );
    let t0 = global_seconds();

    // ---- resume state (skips warmup entirely) ----
    let resume = match &cfg.checkpoint.resume_from {
        Some(src) => {
            let st = TrainState::load_resume(std::path::Path::new(src))
                .with_context(|| format!("loading resume state from {src:?}"))?;
            log.info(&format!(
                "resuming {} from optimizer step {} ({src})",
                st.variant, st.step
            ));
            Some(st)
        }
        None => None,
    };

    // ---- warmup (base-model stand-in) ----
    let initial_params = match (&resume, warm_params) {
        (Some(st), _) => st.params.clone(),
        (None, Some(p)) => p,
        (None, None) => {
            let mut rt = Runtime::new().context("orchestrator runtime")?;
            log.info(&format!(
                "sft warmup: {} steps on variant {}",
                cfg.sft_steps, cfg.variant
            ));
            warmup::run_sft(&mut rt, &cfg, &hub)?
        }
    };

    // ---- topology ----
    let bus = WeightBus::new();
    let start_version = resume.as_ref().map(|st| st.step + 1).unwrap_or(1);
    bus.publish(start_version, Arc::new(initial_params.clone()));
    let (rollout_tx, rollout_rx) =
        topic::<Rollout>("rollouts", cfg.rollout_queue, cfg.rollout_policy);
    let (batch_tx, batch_rx) =
        topic::<TrainBatch>("batches", cfg.batch_queue, Policy::Block);
    let stop = Arc::new(AtomicBool::new(false));

    let (b, t) = {
        let rt = Runtime::new()?; // manifest only; cheap
        let v = rt.manifest.variant(&cfg.variant)?;
        (v.train_batch, v.seq_len)
    };

    // conventional quota: ~G optimizer batches' worth of sequences.
    // Periodic mode has no phase barrier — actors stream exactly like
    // pipeline; only the trainer's publish cadence differs.
    let conv_groups = match cfg.mode {
        Mode::Conventional { g } => (g * b).div_ceil(cfg.group_size).max(1),
        Mode::Pipeline | Mode::Periodic { .. } => 0,
    };
    let conv = match cfg.mode {
        Mode::Conventional { .. } => Some(Arc::new(ConvSync::new(conv_groups))),
        Mode::Pipeline | Mode::Periodic { .. } => None,
    };

    // ---- actor pool ----
    // Always supervised: the supervisor thread is what closes the rollout
    // topic and unwinds the run if the pool dies (the SpawnFn below keeps
    // a publisher alive, so actor exits alone can no longer close it).
    // `elastic` merely selects tolerant bounds; plain runs get a
    // fixed-size, fail-fast pool that preserves the original
    // actor-error-fails-the-run semantics.
    let elastic = cfg.elastic.enabled || chaos.is_some();
    // portable in-flight rollouts: supervised pipeline runs hand a killed
    // or descaled actor's sequences to the survivors through this hub
    // (`[elastic] migrate = false` restores abort-on-kill)
    let migrate = if elastic && cfg.elastic.migrate && matches!(cfg.mode, Mode::Pipeline) {
        Some(Arc::new(MigrationHub::new()))
    } else {
        None
    };
    let autoscale = if elastic && cfg.autoscale.enabled && matches!(cfg.mode, Mode::Pipeline) {
        Some(AutoScaler::new(cfg.autoscale.clone()))
    } else {
        None
    };
    // run control plane: operator commands + guardrail watchdog. Only
    // built when enabled (validated: requires trainer failover, which
    // transitively requires elastic + pipeline + durable checkpoints)
    let control = if cfg.control.enabled {
        Some(match controller {
            Some(c) => ControlPlane::with_controller(cfg.control.clone(), c),
            None => ControlPlane::new(cfg.control.clone()),
        })
    } else {
        None
    };
    let control_gate = control.as_ref().map(|c| c.gate.clone());
    let spawn: SpawnFn = {
        let cfg = cfg.clone();
        let bus = bus.clone();
        let hub = hub.clone();
        let conv = conv.clone();
        let rollout_tx = rollout_tx.clone();
        let migrate = migrate.clone();
        let control_gate = control_gate.clone();
        Arc::new(move |ctx| {
            run_actor(ActorArgs {
                actor_id: ctx.actor_id,
                cfg: cfg.clone(),
                bus: bus.clone(),
                rollout_tx: rollout_tx.clone(),
                hub: hub.clone(),
                stop: ctx.stop,
                halt: ctx.halt,
                generation: ctx.generation,
                migrate: migrate.clone(),
                conv: conv.clone(),
                control: control_gate.clone(),
            })
        })
    };
    let (min_a, max_a, max_restarts) = if elastic {
        (
            cfg.elastic.min_actors,
            cfg.elastic.max_actors.max(cfg.n_actors),
            cfg.elastic.max_restarts,
        )
    } else {
        (cfg.n_actors, cfg.n_actors, 0)
    };
    let pool = ActorPool::new(
        spawn,
        stop.clone(),
        hub.clone(),
        cfg.n_actors,
        min_a,
        max_a,
        max_restarts,
        !elastic, // fail_fast
    )?;

    let pre_args = PreprocessorArgs {
        cfg: cfg.clone(),
        b,
        t,
        rollout_rx,
        batch_tx,
        hub: hub.clone(),
        stop: stop.clone(),
        conv: conv.clone(),
        // real runs leave the host scorer unset: the device train graph
        // recomputes truncated IS weights from current-policy logprobs at
        // train time (is_flag = 1), which is exactly fresh. A host scorer
        // (is_flag = 2) is for device-free harnesses and tests.
        scorer: None,
    };
    let pre_handle = std::thread::Builder::new()
        .name("preproc".into())
        .spawn(move || run_preprocessor(pre_args))?;

    // ---- trainer: orchestrator-owned thread (plain runs) or a
    // supervisor-owned TrainerSlot (trainer failover: a killed/crashed
    // trainer respawns from the latest checkpoint manifest without
    // tearing the run down) ----
    let failover = elastic && cfg.elastic.trainer_failover;
    let mut trainer_slot: Option<TrainerSlot> = None;
    let mut trainer_handle = None;
    if failover {
        let cfg_t = cfg.clone();
        let bus_t = bus.clone();
        let hub_t = hub.clone();
        let stop_t = stop.clone();
        let conv_t = conv.clone();
        // Shared (not per-incarnation) copies of the start state: one
        // clone at setup, reachable only by a respawn that lands before
        // the first checkpoint. Deliberately retained for the whole run
        // (one extra params copy + one TrainState on small models) —
        // there is no in-process "first checkpoint landed" hook here,
        // and a take-on-first-use scheme would either lose the state a
        // pre-checkpoint respawn still needs or add a lock + panic path
        // for a marginal win.
        let initial_t: Arc<Vec<HostTensor>> =
            Arc::new(if resume.is_some() { Vec::new() } else { initial_params.clone() });
        let resume_t: Arc<Option<TrainState>> = Arc::new(resume);
        let spawn: TrainerSpawnFn = Arc::new(move |ctx| {
            // Respawns resume from the manifest. Only a genuinely absent
            // *manifest* (no checkpoint has ever landed) falls back to
            // the run's own start state — any other failure, including a
            // readable manifest naming a missing state file, means
            // checkpointed progress exists but cannot be recovered, and
            // silently restarting from step 0 would discard the whole
            // optimizer trajectory.
            let resume_state = if ctx.resume_latest {
                let dir = cfg_t
                    .checkpoint
                    .dir
                    .as_ref()
                    .expect("validated: trainer failover requires a checkpoint dir");
                let dir = std::path::Path::new(dir);
                match read_manifest(dir) {
                    Err(e) if io_not_found(&e) => resume_t.as_ref().clone(),
                    _ => Some(TrainState::load_resume(dir).context(
                        "trainer failover: a checkpoint manifest exists but the \
                         latest state cannot be loaded",
                    )?),
                }
            } else {
                resume_t.as_ref().clone()
            };
            run_trainer(TrainerArgs {
                initial_params: if resume_state.is_some() {
                    Vec::new()
                } else {
                    initial_t.as_ref().clone()
                },
                cfg: cfg_t.clone(),
                batch_rx: batch_rx.clone(),
                bus: bus_t.clone(),
                hub: hub_t.clone(),
                stop: stop_t.clone(),
                halt: ctx.halt,
                conv: conv_t.clone(),
                conv_groups,
                resume: resume_state,
            })
        });
        trainer_slot = Some(TrainerSlot::new(spawn, cfg.elastic.trainer_restarts)?);
    } else {
        let trainer_args = TrainerArgs {
            // on resume the trainer takes its params from the state
            // instead; don't ship a third copy of the weights
            initial_params: if resume.is_some() { Vec::new() } else { initial_params.clone() },
            cfg: cfg.clone(),
            batch_rx,
            bus: bus.clone(),
            hub: hub.clone(),
            stop: stop.clone(),
            halt: Arc::new(AtomicBool::new(false)), // nobody halts plain trainers
            conv: conv.clone(),
            conv_groups,
            resume,
        };
        trainer_handle = Some(
            std::thread::Builder::new()
                .name("trainer".into())
                .spawn(move || run_trainer(trainer_args))?,
        );
    }

    // The pool (via its SpawnFn) holds the rollout topic open from here
    // on; the supervisor's shutdown path closes it so the preprocessor
    // drains and exits.
    let sup_args = SupervisorArgs {
        pool,
        bus: bus.clone(),
        rollout_tx: rollout_tx.clone(),
        schedule: chaos,
        stop: stop.clone(),
        hub: hub.clone(),
        poll: Duration::from_millis(cfg.elastic.poll_ms.max(1)),
        migrate,
        autoscale,
        trainer: trainer_slot,
        control,
    };
    let sup_handle = std::thread::Builder::new()
        .name("superv".into())
        .spawn(move || run_supervisor(sup_args))?;
    drop(rollout_tx);

    // ---- run to completion ----
    let final_params = match trainer_handle {
        // Plain runs: join the trainer but raise `stop` and tear the
        // other stages down *before* propagating any trainer error —
        // otherwise a failing trainer (e.g. a resume/variant mismatch)
        // would leak a supervisor that keeps restarting actors forever.
        // Propagation order after that: trainer, preprocessor,
        // supervisor — the supervisor's "pool died" escalation is
        // usually a symptom, so upstream root causes surface first.
        Some(handle) => {
            let trainer_out = handle
                .join()
                .map_err(|_| anyhow::anyhow!("trainer panicked"));
            stop.store(true, Ordering::Relaxed);
            let sup_out = sup_handle
                .join()
                .map_err(|_| anyhow::anyhow!("supervisor panicked"));
            let pre_out = pre_handle
                .join()
                .map_err(|_| anyhow::anyhow!("preprocessor panicked"));
            let exit = trainer_out??;
            pre_out??;
            sup_out??;
            match exit {
                TrainerExit::Completed(params) => params,
                TrainerExit::Halted => {
                    anyhow::bail!("trainer halted without a supervisor-owned slot")
                }
            }
        }
        // Failover runs: the supervisor owns the trainer — it raises
        // `stop` itself once the (possibly respawned) trainer completes
        // and returns the final parameters.
        None => {
            let sup_out = sup_handle
                .join()
                .map_err(|_| anyhow::anyhow!("supervisor panicked"));
            stop.store(true, Ordering::Relaxed);
            let pre_out = pre_handle
                .join()
                .map_err(|_| anyhow::anyhow!("preprocessor panicked"));
            let params = sup_out??;
            pre_out??;
            params.context("supervisor exited without the trainer's final parameters")?
        }
    };

    let wall = global_seconds() - t0;
    hub.add("wall_seconds", wall);
    hub.add("weight_bus_bytes", bus.bytes_fetched() as f64);
    hub.add("weight_bus_publishes", bus.publishes() as f64);
    log.info(&format!(
        "run complete: mode={} wall={:.1}s samples={}",
        cfg.mode.name(),
        wall,
        hub.counter("samples_trained")
    ));

    Ok(RunSummary {
        report: hub.snapshot(),
        final_params,
        initial_params,
        wall_seconds: wall,
    })
}
