//! Fig 7 machinery — KL divergence of mixed-policy (in-flight) sampling
//! distributions vs the on-policy checkpoint (§5.1).
//!
//! Shared by `examples/kl_inflight.rs` and `benches/fig7_kl.rs`.

use crate::config::RunConfig;
use crate::data::task::TaskGen;
use crate::data::Dataset;
use crate::engine::engine::DistRow;
use crate::engine::{CompletionRequest, Engine, EngineCfg, GenerationService};
use crate::model::Tokenizer;
use crate::rl::Rollout;
use crate::runtime::{HostTensor, Runtime};
use crate::util::Rng;
use anyhow::Result;
use std::collections::HashMap;

/// How weights evolve during the replay.
pub enum Swap {
    /// PipelineRL: advance one checkpoint every max_new/g decode steps.
    InFlight { recompute: bool },
    /// Conventional: the whole sequence sampled from the start checkpoint.
    None,
}

/// Generate under the (mixed or fixed) behavior policy starting from
/// checkpoint `start`, capture every sampled token's full distribution,
/// then teacher-force the sequences through checkpoint `start + g` and
/// return the mean per-token KL(behavior ‖ on-policy).
pub fn replay_kl(
    cfg: &RunConfig,
    load: &dyn Fn(usize) -> Result<Vec<HostTensor>>,
    start: usize,
    g: usize,
    swap: Swap,
) -> Result<f64> {
    let mut rt = Runtime::new()?;
    let mut ecfg = EngineCfg::new(&cfg.variant);
    ecfg.max_new_tokens = cfg.max_new_tokens;
    ecfg.capture_dist = true;
    if let Swap::InFlight { recompute } = swap {
        ecfg.recompute_kv_on_update = recompute;
    }
    let params0 = load(start)?;
    let mut engine = Engine::new(
        &mut rt,
        ecfg,
        &params0,
        0,
        Rng::new(start as u64 * 1009 + g as u64),
    )?;
    engine.set_weights(0, &params0)?;

    // submit one eval problem per slot
    let task_gen = TaskGen::new(cfg.task.kinds.clone(), cfg.task.max_operand);
    let dataset = Dataset::new(task_gen, cfg.task.pool, 99);
    let tokenizer = Tokenizer::new();
    let n = engine.n_slots();
    for (i, p) in dataset.eval_suite(n).into_iter().enumerate() {
        let toks = tokenizer.encode(&p.prompt)?;
        engine.submit(CompletionRequest::rollout(p, toks, i as u64))?;
    }

    let interval = (cfg.max_new_tokens / g.max(1)).max(1);
    let mut decode_steps = 0usize;
    let mut next_ck = 1usize;
    let mut finished: Vec<Rollout> = Vec::new();
    while finished.len() < n {
        let out = engine.step()?;
        if out.idle {
            break;
        }
        finished.extend(out.finished);
        decode_steps += 1;
        if matches!(swap, Swap::InFlight { .. })
            && decode_steps % interval == 0
            && next_ck <= g
        {
            engine.set_weights(next_ck as u64, &load(start + next_ck)?)?;
            next_ck += 1;
        }
    }
    let captured = std::mem::take(&mut engine.captured);
    let final_params = load(start + g)?;
    score_kl(&mut rt, cfg, &final_params, &finished, &captured)
}

/// Teacher-force each sequence through `final_params` (score_full) and
/// average the full-distribution KL against the captured behavior rows.
pub fn score_kl(
    rt: &mut Runtime,
    cfg: &RunConfig,
    final_params: &[HostTensor],
    rollouts: &[Rollout],
    captured: &[DistRow],
) -> Result<f64> {
    let v = rt.manifest.variant(&cfg.variant)?.clone();
    let graph = rt.graph(&cfg.variant, "score_full")?;
    let (b, t, vs) = (v.train_batch, v.seq_len, v.vocab);

    let by_seq: HashMap<u64, &Rollout> = rollouts.iter().map(|r| (r.seq_id, r)).collect();
    let mut total_kl = 0.0f64;
    let mut n_pts = 0usize;

    let mut seq_ids: Vec<u64> = by_seq.keys().copied().collect();
    seq_ids.sort_unstable();
    for chunk in seq_ids.chunks(b) {
        let mut tokens = vec![0i32; b * t];
        let mut seg = vec![0i32; b * t];
        let mut pos = vec![0i32; b * t];
        for (row, &sid) in chunk.iter().enumerate() {
            let r = by_seq[&sid];
            let stream: Vec<i32> = r
                .prompt_tokens
                .iter()
                .chain(r.gen_tokens.iter())
                .copied()
                .collect();
            for (i, &tok) in stream.iter().take(t).enumerate() {
                tokens[row * t + i] = tok;
                seg[row * t + i] = 1;
                pos[row * t + i] = i as i32;
            }
        }
        let mut inputs: Vec<HostTensor> = final_params.to_vec();
        inputs.push(HostTensor::from_i32(&[b, t], tokens));
        inputs.push(HostTensor::from_i32(&[b, t], seg));
        inputs.push(HostTensor::from_i32(&[b, t], pos));
        let out = graph.run_host(&inputs)?;
        let logdist = out[1].f32s()?; // [b, t, V]
        for (row, &sid) in chunk.iter().enumerate() {
            let r = by_seq[&sid];
            let plen = r.prompt_tokens.len();
            for c in captured.iter().filter(|c| c.seq_id == sid) {
                // the slot predicting gen token j sits at plen + j - 1
                let slot = match (plen + c.gen_index).checked_sub(1) {
                    Some(s) if s + 1 < t => s,
                    _ => continue,
                };
                let on = &logdist[(row * t + slot) * vs..(row * t + slot + 1) * vs];
                let mut kl = 0.0f64;
                for (lm, lo) in c.logdist.iter().zip(on) {
                    let p = (*lm as f64).exp();
                    kl += p * (*lm as f64 - *lo as f64);
                }
                total_kl += kl.max(0.0);
                n_pts += 1;
            }
        }
    }
    Ok(if n_pts > 0 { total_kl / n_pts as f64 } else { 0.0 })
}
