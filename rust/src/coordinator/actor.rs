//! Actor stage (Alg. 2 lines 1–14).
//!
//! Owns one generation engine (= one generation GPU pool in the paper).
//! Loop: poll the weight bus — on a new version, stage it *incrementally*
//! into the engine's shadow buffer set, a few tensor chunks per decode
//! step (`run.weight_stage_chunk`), and swap atomically at a step
//! boundary: the transfer overlaps with decoding and the swap itself is
//! a pointer exchange, so `weight_updates` no longer implies a decode
//! stall. A publish that lands mid-transfer is picked up immediately
//! after the in-progress transfer commits — transfers always run to
//! completion, keeping version progress monotone (and livelock-free
//! under a fast trainer). `weight_stage_chunk = 0` restores the eager
//! stall-and-swap path as an ablation baseline. Meanwhile: keep the
//! engine saturated with prompt groups; step the engine; verify rewards
//! of finished sequences and stream them to the preprocessor.
//!
//! In conventional mode the actor instead takes prompt groups from a
//! shared quota and, once exhausted, *drains* all in-flight sequences
//! before blocking for the training phase (Alg. 1's alternation,
//! including the Fig 2b batch-drain tail).

use super::conv::ConvSync;
use crate::broker::Publisher;
use crate::config::{Mode, RunConfig};
use crate::control::{AdmissionPhase, ControlGate};
use crate::data::{Dataset, task::TaskGen};
use crate::engine::{CompletionRequest, Engine, EngineCfg, GenerationService};
use crate::metrics::MetricsHub;
use crate::model::Tokenizer;
use crate::rl::{FinishReason, Rollout};
use crate::runtime::Runtime;
use crate::sched::MigrationHub;
use crate::util::logging::Logger;
use crate::util::Rng;
use crate::weights::{WeightBus, WeightFetch};
use anyhow::{Context, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Bit offset of the opener field in group ids. Layout (see `run_actor`):
/// `[actor_id + 1 : bits 40..] [generation & 0xff : bits 32..40]
/// [counter : bits 0..32]`.
const GROUP_OPENER_SHIFT: u64 = 40;

/// The actor slot (as `actor_id + 1`) that opened a rollout group — the
/// inverse of the `group_base` encoding below, kept next to it so the
/// layout can only change in one place. The preprocessor compares this
/// against the finishing `actor_id` to spot migrated completions.
pub fn group_opener(group_id: u64) -> u64 {
    group_id >> GROUP_OPENER_SHIFT
}

pub struct ActorArgs {
    pub actor_id: usize,
    pub cfg: RunConfig,
    pub bus: WeightBus,
    pub rollout_tx: Publisher<Rollout>,
    pub hub: MetricsHub,
    /// global run shutdown
    pub stop: Arc<AtomicBool>,
    /// per-actor kill switch (elastic pool: this incarnation only)
    pub halt: Arc<AtomicBool>,
    /// restart count of this slot; folded into group ids so a restarted
    /// actor can never collide with its previous incarnation's groups
    pub generation: u64,
    /// portable-rollout hand-off: claim orphaned snapshots each loop,
    /// deposit our own in-flight sequences when killed/descaled. None =
    /// legacy abort-on-halt behavior (plain runs, `[elastic] migrate =
    /// false`, conventional mode)
    pub migrate: Option<Arc<MigrationHub>>,
    pub conv: Option<Arc<ConvSync>>,
    /// run control plane gate (`[control] enabled`): pause parks the
    /// in-flight sequences through the migration hub, drain closes
    /// admission while active sequences finish. None = ungated
    pub control: Option<ControlGate>,
}

pub fn run_actor(args: ActorArgs) -> Result<()> {
    let ActorArgs {
        actor_id,
        cfg,
        bus,
        rollout_tx,
        hub,
        stop,
        halt,
        generation,
        migrate,
        conv,
        control,
    } = args;
    let log = Logger::new(format!("actor-{actor_id}"));
    let group_name = format!("actor-{actor_id}");
    let tokenizer = Tokenizer::new();
    let mut rt = Runtime::new().context("actor runtime")?;

    // join the weight-transfer process group and wait for initial weights.
    // Registration is idempotent, so a restarted actor hot-joins under the
    // same name and picks up whatever version the trainer last published.
    bus.init_process_group(&group_name);
    let initial = loop {
        if let Some(w) = bus.fetch_if_newer(0) {
            break w;
        }
        if stop.load(Ordering::Relaxed) || halt.load(Ordering::Relaxed) {
            bus.leave_process_group(&group_name);
            return Ok(());
        }
        std::thread::sleep(Duration::from_millis(2));
    };

    let mut ecfg = EngineCfg::new(&cfg.variant);
    ecfg.temperature = cfg.temperature as f32;
    ecfg.max_new_tokens = cfg.max_new_tokens;
    ecfg.sched = cfg.sched;
    // `[kv]`: paged-memory layer — device layout, block granularity,
    // oversubscription, block-pressure preemption, coalesced replay
    ecfg.kv_layout = cfg.kv.layout;
    ecfg.block_size = cfg.kv.block_size;
    ecfg.overcommit = cfg.kv.overcommit;
    ecfg.preempt = cfg.kv.preempt;
    ecfg.replay_batch = cfg.kv.replay_batch;
    let mut engine = Engine::new(
        &mut rt,
        ecfg,
        &initial.params,
        actor_id,
        Rng::with_stream(cfg.seed ^ 0xac70, actor_id as u64 + 1),
    )?;
    engine.set_weights(initial.version, &initial.params)?;
    log.debug(&format!("engine ready at version {}", initial.version));

    let task_gen = TaskGen::new(cfg.task.kinds.clone(), cfg.task.max_operand);
    let mut dataset = Dataset::new(task_gen.clone(), cfg.task.pool, cfg.seed + actor_id as u64);
    // id layout: [actor+1 : bits 40..] [generation & 0xff : bits 32..40]
    // [counter : bits 0..32] — unique across restarts of the same slot
    let group_base =
        ((actor_id as u64 + 1) << GROUP_OPENER_SHIFT) | ((generation & 0xff) << 32);
    let mut group_counter: u64 = 0;
    // target: slots full + one group queued so freed slots refill instantly
    let target_load = engine.n_slots() + cfg.group_size;
    let mut version = initial.version;
    let mut steps_since_fill_metric = 0usize;
    // in-progress overlapped weight transfer (None = up to date / eager).
    // Overlapping only makes sense in pipeline mode: conventional RL's
    // per-phase updates land while the engine is empty (nothing to
    // overlap with), and a mid-sequence commit would break Alg. 1's
    // strict on-policyness — so conventional always swaps eagerly.
    let overlap_chunk = match cfg.mode {
        // periodic mode decodes straight through publishes exactly like
        // pipeline — only the trainer's publish cadence differs
        Mode::Pipeline | Mode::Periodic { .. } => cfg.weight_stage_chunk,
        Mode::Conventional { .. } => 0,
    };
    let mut staging: Option<WeightFetch> = None;
    // fractional carry of the simulated per-chunk broadcast pause
    let mut pause_debt_us: f64 = 0.0;
    // whether this incarnation currently sits parked behind a control-
    // plane pause (in-flight sequences exported to the migration hub)
    let mut parked = false;

    loop {
        if stop.load(Ordering::Relaxed) || halt.load(Ordering::Relaxed) {
            break;
        }

        // ---- control gate: pause parks, resume reclaims ----
        if let Some(gate) = &control {
            if gate.phase() == AdmissionPhase::Paused {
                if !parked {
                    parked = true;
                    // park: in-flight sequences leave as portable
                    // snapshots through the conservation-booked migration
                    // hub (the resume path reclaims them via the ordinary
                    // migrated-claim block below); without a hub they
                    // simply stall in place until resume
                    if let Some(hub_m) = &migrate {
                        let snaps = engine.export_snapshots();
                        if !snaps.is_empty() {
                            let tokens: usize =
                                snaps.iter().map(|s| s.salvaged_tokens()).sum();
                            hub.add("control_seqs_parked", snaps.len() as f64);
                            hub.add("control_tokens_parked", tokens as f64);
                            hub_m.deposit(snaps);
                        }
                    }
                }
                gate.report_load(actor_id, engine.load());
                std::thread::sleep(Duration::from_millis(2));
                continue;
            }
            if parked {
                parked = false;
                hub.add("control_unparks", 1.0);
            }
        }

        // ---- in-flight weight update (pipeline) / per-phase (conv) ----
        if overlap_chunk == 0 {
            // eager baseline: stall for the whole transfer, then swap
            if let Some(w) = bus.fetch_if_newer(version) {
                if cfg.weight_transfer_ms > 0.0 {
                    // simulated NCCL broadcast pause
                    std::thread::sleep(Duration::from_micros(
                        (cfg.weight_transfer_ms * 1000.0) as u64,
                    ));
                }
                engine.set_weights(w.version, &w.params)?;
                version = w.version;
                hub.add("weight_updates_received", 1.0);
            }
        } else {
            // overlapped path. An in-progress transfer always runs to
            // completion even when a newer version lands mid-stage: the
            // commit stays monotone and the actor then immediately starts
            // on the newest version. (Abort-and-restart on every newer
            // publish would livelock under a trainer that publishes
            // faster than one transfer completes — the actor would never
            // commit anything.)
            if staging.is_none() {
                if let Some(f) = bus.begin_fetch(version) {
                    engine.begin_weight_update(f.version(), f.n_params())?;
                    pause_debt_us = 0.0;
                    staging = Some(f);
                }
            }
            if let Some(f) = &mut staging {
                // spread the simulated broadcast pause over the chunks so
                // the transfer model matches the overlap it measures; a
                // fractional per-chunk share accumulates as debt so the
                // total sleep matches the eager path's
                let pause_per_chunk_us = if cfg.weight_transfer_ms > 0.0 {
                    cfg.weight_transfer_ms * 1000.0 / f.n_params().max(1) as f64
                } else {
                    0.0
                };
                for _ in 0..overlap_chunk {
                    let Some((_, t)) = f.next_chunk() else { break };
                    pause_debt_us += pause_per_chunk_us;
                    if pause_debt_us >= 1.0 {
                        let whole = pause_debt_us as u64;
                        std::thread::sleep(Duration::from_micros(whole));
                        pause_debt_us -= whole as f64;
                    }
                    engine.stage_weight_tensor(t)?;
                }
            }
            if staging.as_ref().is_some_and(|f| f.done()) {
                let v = staging.take().expect("checked above").version();
                // step-boundary swap: a pointer exchange, zero decode stall
                if engine.commit_weights()?.is_some() {
                    version = v;
                    hub.add("weight_updates_received", 1.0);
                }
            }
        }

        // ---- migrated work: adopt orphaned in-flight rollouts first ----
        // (before fresh admission, so salvaged prefixes — whose tokens
        // accrue lag while queued — get slot capacity ahead of new
        // prompts; the engine-side scheduler orders them within the
        // pending queue)
        if let Some(hub_m) = &migrate {
            if hub_m.depth() > 0 {
                let room = target_load.saturating_sub(engine.load());
                for snap in hub_m.claim(room) {
                    let salvaged = snap.salvaged_tokens();
                    let problem = task_gen.problem(snap.problem_id);
                    match engine.import_snapshot(&snap, problem) {
                        Ok(_) => {
                            // "completed" = the hand-off completed (the
                            // snapshot is adopted into a live engine); a
                            // sequence that migrates twice counts twice.
                            // End-to-end completion is tracked by the
                            // preprocessor's
                            // rollouts_completed_after_migration.
                            hub.add("migrations_completed", 1.0);
                            hub.add("snapshot_tokens_salvaged", salvaged as f64);
                        }
                        Err(e) => {
                            // a snapshot this engine cannot host (config
                            // skew, malformed deposit): account it as
                            // deliberately discarded — erroring out here
                            // would drop every other claimed snapshot
                            // unaccounted and burn a restart-budget slot
                            log.warn(&format!("rejecting migrated snapshot: {e:#}"));
                            hub_m.reject(&snap);
                            hub.add("migration_snaps_rejected", 1.0);
                            hub.add("migration_snaps_discarded", 1.0);
                        }
                    }
                }
            }
        }

        // ---- admission ----
        match (&cfg.mode, &conv) {
            (Mode::Pipeline | Mode::Periodic { .. }, _) => {
                // the draining phase closes admission while the engine
                // runs its remaining sequences to completion
                if control.as_ref().map_or(true, |g| g.admitting()) {
                    while engine.load() < target_load {
                        submit_group(&mut engine, &mut dataset, &tokenizer, &cfg,
                                     group_base, &mut group_counter)?;
                    }
                }
            }
            (Mode::Conventional { .. }, Some(sync)) => {
                if !sync.generating() {
                    // training phase: engine must be empty; wait
                    debug_assert_eq!(engine.load(), 0);
                    sync.wait_generate(Duration::from_millis(20));
                    continue;
                }
                while engine.load() < target_load && sync.try_take_group(cfg.group_size) {
                    submit_group(&mut engine, &mut dataset, &tokenizer, &cfg,
                                 group_base, &mut group_counter)?;
                }
            }
            (Mode::Conventional { .. }, None) => {
                anyhow::bail!("conventional mode requires a ConvSync")
            }
        }

        // drain-quiescence signal: the supervisor sums these to know when
        // every in-flight sequence has finished
        if let Some(gate) = &control {
            gate.report_load(actor_id, engine.load());
        }

        // ---- decode step ----
        let out = engine.step()?;
        if out.idle {
            std::thread::sleep(Duration::from_millis(1));
            continue;
        }
        hub.add("gen_tokens_sampled", out.tokens_sampled as f64);
        steps_since_fill_metric += 1;
        if steps_since_fill_metric >= 16 {
            steps_since_fill_metric = 0;
            let t = now(&hub);
            let steps = engine.stats.steps as f64;
            hub.record(
                &format!("actor{actor_id}/active_slots"),
                t,
                steps,
                engine.n_active() as f64,
            );
            // KV-memory pressure: free pool + blocks saved by prefix
            // sharing (the oversubscription headroom both signals feed)
            hub.record(
                &format!("actor{actor_id}/kv_free_blocks"),
                t,
                steps,
                engine.kv_free_blocks() as f64,
            );
            hub.record(
                &format!("actor{actor_id}/kv_shared_saved_blocks"),
                t,
                steps,
                engine.kv_shared_saved_blocks() as f64,
            );
            // chunked-prefill accounting: dispatch counts and the split
            // prefill/decode execute times, so ingestion cost is visible
            // separately from steady-state decode latency
            hub.record(
                &format!("actor{actor_id}/prefill_chunks"),
                t,
                steps,
                engine.stats.prefill_chunks as f64,
            );
            hub.record(
                &format!("actor{actor_id}/forced_steps_saved"),
                t,
                steps,
                engine.stats.forced_steps_saved as f64,
            );
            hub.record(
                &format!("actor{actor_id}/prefill_us"),
                t,
                steps,
                engine.stats.prefill_us as f64,
            );
        }

        // ---- finished sequences: verify reward, publish ----
        for mut r in out.finished {
            let problem = dataset_problem(&task_gen, r.problem_id);
            let completion = tokenizer.decode(&r.gen_tokens);
            r.reward = cfg.reward.reward(
                &problem,
                &completion,
                r.gen_len(),
                cfg.max_new_tokens,
            );
            hub.add("gen_seqs_finished", 1.0);
            if matches!(r.finish, FinishReason::Eos) {
                hub.add("gen_seqs_eos", 1.0);
            }
            if let Some(sync) = &conv {
                sync.report_finished();
            }
            match rollout_tx.send(r) {
                Ok(dropped) if dropped > 0 => {
                    hub.add("rollouts_dropped_ring", dropped as f64);
                }
                Ok(_) => {}
                Err(_) => {
                    if let Some(gate) = &control {
                        gate.clear_load(actor_id);
                    }
                    bus.leave_process_group(&group_name);
                    return Ok(()); // preprocessor gone: shutdown
                }
            }
        }
    }

    // Wind-down. Two cases:
    //
    // * **Kill/descale mid-run** (halt raised, run continuing) with a
    //   migration hub: export every in-flight sequence as a portable
    //   snapshot and deposit it for a surviving/replacement actor —
    //   group ids and generated prefixes intact, so the preprocessor's
    //   advantage groups complete normally and no salvageable token is
    //   lost. Nothing is published as Aborted.
    // * **Run shutdown** (global stop) or no hub: the legacy path —
    //   abort in-flight sequences and publish them as `Aborted` rollouts
    //   so pending advantage groups can still complete (aborted members
    //   count toward group size but are filtered out of the advantage
    //   computation). Best effort: a saturated DropOldest ring may still
    //   evict these before the preprocessor sees them — the
    //   preprocessor's bounded-pending eviction (GroupCollector
    //   timeout/cap) then salvages the stranded groupmates.
    let migrating = !stop.load(Ordering::Relaxed) && migrate.is_some();
    if migrating {
        let hub_m = migrate.as_ref().expect("checked above");
        let mut snaps = engine.export_snapshots();
        if cfg.train_truncated {
            // `[rl] train_truncated`: sequences that already generated a
            // prefix leave as *trainable* `Truncated` rollouts instead of
            // portable snapshots — the prefix is graded on what it wrote
            // so far and trains now rather than migrating to finish
            // later. Publishing XOR depositing per sequence means a
            // truncated prefix and its continuation can never both train;
            // the preprocessor's prefix ledger backstops the invariant
            // against replayed deposits. Prefix-less sequences (still in
            // prompt prefill) carry no trainable tokens and migrate as
            // before.
            let (publish, deposit): (Vec<_>, Vec<_>) =
                snaps.into_iter().partition(|s| !s.gen_tokens.is_empty());
            snaps = deposit;
            for snap in publish {
                let problem = task_gen.problem(snap.problem_id);
                let completion = tokenizer.decode(&snap.gen_tokens);
                let reward = cfg.reward.reward(
                    &problem,
                    &completion,
                    snap.gen_tokens.len(),
                    cfg.max_new_tokens,
                );
                hub.add("rollouts_truncated_published", 1.0);
                hub.add("truncated_tokens_published", snap.gen_tokens.len() as f64);
                let r = Rollout {
                    seq_id: snap.seq_id,
                    problem_id: snap.problem_id,
                    group_id: snap.group_id,
                    actor_id,
                    prompt_tokens: snap.prompt,
                    gen_tokens: snap.gen_tokens,
                    behavior_lp: snap.behavior_lp,
                    token_version: snap.token_version,
                    reward,
                    finish: FinishReason::Truncated,
                    t_start: snap.t_start,
                    t_end: now(&hub),
                };
                if rollout_tx.send(r).is_err() {
                    break; // preprocessor already gone
                }
            }
        }
        if !snaps.is_empty() {
            let tokens: usize = snaps.iter().map(|s| s.salvaged_tokens()).sum();
            hub.add("migration_snaps_exported", snaps.len() as f64);
            hub.add("migration_tokens_exported", tokens as f64);
            hub_m.deposit(snaps);
        }
    } else {
        let aborted = engine.drain();
        if !aborted.is_empty() {
            hub.add("rollouts_aborted_on_halt", aborted.len() as f64);
            for r in aborted {
                if let Some(sync) = &conv {
                    sync.report_finished();
                }
                if rollout_tx.send(r).is_err() {
                    break; // preprocessor already gone
                }
            }
        }
    }
    // lifetime KV-memory counters of this incarnation's engine (summed
    // across actors/incarnations by the hub)
    if engine.stats.preemptions > 0 {
        hub.add("kv_preemptions", engine.stats.preemptions as f64);
    }
    if engine.kv_cow_forks() > 0 {
        hub.add("kv_cow_forks", engine.kv_cow_forks() as f64);
    }
    // a dead incarnation's stale load must never hold a drain open
    if let Some(gate) = &control {
        gate.clear_load(actor_id);
    }
    bus.leave_process_group(&group_name);
    log.debug("actor stopping");
    Ok(())
}

fn submit_group(
    engine: &mut Engine,
    dataset: &mut Dataset,
    tokenizer: &Tokenizer,
    cfg: &RunConfig,
    group_base: u64,
    group_counter: &mut u64,
) -> Result<()> {
    let problem = dataset.sample_train();
    let prompt = tokenizer
        .encode(&problem.prompt)
        .context("task prompt must tokenize")?;
    let group_id = group_base | *group_counter;
    *group_counter += 1;
    for _ in 0..cfg.group_size {
        // batch-class house-tenant submission — the legacy add_request
        // path bit-for-bit, but through the same trait surface the
        // serving gateway fronts (so an actor can run behind one)
        engine.submit(CompletionRequest::rollout(
            problem.clone(),
            prompt.clone(),
            group_id,
        ))?;
    }
    Ok(())
}

/// Problems regenerate deterministically from their id — no need to ship
/// the full Problem through the rollout.
fn dataset_problem(gen: &TaskGen, id: u64) -> crate::data::task::Problem {
    gen.problem(id)
}

fn now(_hub: &MetricsHub) -> f64 {
    // wall-clock origin is per-hub; use a process-global origin instead
    crate::util::timer::global_seconds()
}
