//! Greedy-decoding evaluation harness (Table 1 protocol).
//!
//! Loads a parameter set into a fresh engine, greedy-decodes the held-out
//! eval suite and reports exact-match success rates, overall and per task
//! kind — our analogue of MATH500 / AIME24 accuracy.

use crate::config::RunConfig;
use crate::data::task::{extract_answer, Problem, TaskGen};
use crate::data::Dataset;
use crate::engine::{CompletionRequest, Engine, EngineCfg, GenerationService};
use crate::model::Tokenizer;
use crate::runtime::{HostTensor, Runtime};
use crate::util::Rng;
use anyhow::Result;
use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct EvalReport {
    pub n: usize,
    pub correct: usize,
    pub by_kind: BTreeMap<&'static str, (usize, usize)>, // kind -> (correct, n)
    pub mean_gen_len: f64,
    pub eos_rate: f64,
}

impl EvalReport {
    pub fn success_rate(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.correct as f64 / self.n as f64
        }
    }
}

/// Evaluate `params` on the first `n` problems of the eval split.
pub fn evaluate(
    rt: &mut Runtime,
    cfg: &RunConfig,
    params: &[HostTensor],
    n: usize,
) -> Result<EvalReport> {
    let task_gen = TaskGen::new(cfg.task.kinds.clone(), cfg.task.max_operand);
    let dataset = Dataset::new(task_gen, cfg.task.pool, cfg.seed);
    let problems = dataset.eval_suite(n);
    evaluate_problems(rt, cfg, params, &problems)
}

pub fn evaluate_problems(
    rt: &mut Runtime,
    cfg: &RunConfig,
    params: &[HostTensor],
    problems: &[Problem],
) -> Result<EvalReport> {
    let tokenizer = Tokenizer::new();
    let mut ecfg = EngineCfg::new(&cfg.variant);
    ecfg.max_new_tokens = cfg.max_new_tokens;
    ecfg.greedy = true;
    let mut engine = Engine::new(rt, ecfg, params, usize::MAX, Rng::new(0))?;
    engine.set_weights(1, params)?;

    for (i, p) in problems.iter().enumerate() {
        let toks = tokenizer.encode(&p.prompt)?;
        engine.submit(CompletionRequest::rollout(p.clone(), toks, i as u64))?;
    }

    let mut report = EvalReport { n: problems.len(), ..Default::default() };
    let mut finished = 0usize;
    let mut sum_len = 0usize;
    let mut eos = 0usize;
    // map problem instances back by id (ids are unique within the suite)
    let by_id: BTreeMap<u64, &Problem> =
        problems.iter().map(|p| (p.id, p)).collect();
    while finished < problems.len() {
        let out = engine.step()?;
        if out.idle {
            break;
        }
        for r in out.finished {
            finished += 1;
            sum_len += r.gen_len();
            if matches!(r.finish, crate::rl::FinishReason::Eos) {
                eos += 1;
            }
            let problem = by_id[&r.problem_id];
            let completion = tokenizer.decode(&r.gen_tokens);
            let ok = extract_answer(&completion)
                .map(|a| a == problem.answer)
                .unwrap_or(false);
            let e = report.by_kind.entry(problem.kind.name()).or_insert((0, 0));
            e.1 += 1;
            if ok {
                e.0 += 1;
                report.correct += 1;
            }
        }
    }
    report.mean_gen_len = if finished > 0 { sum_len as f64 / finished as f64 } else { 0.0 };
    report.eos_rate = if finished > 0 { eos as f64 / finished as f64 } else { 0.0 };
    Ok(report)
}
