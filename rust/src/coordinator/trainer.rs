//! Trainer stage (Alg. 2 lines 15–22).
//!
//! Pulls packed batches, runs the AOT train graph (fused IS-REINFORCE
//! loss + Adam — one PJRT execution per optimizer step), then publishes
//! the new weight version:
//!
//! * pipeline mode — publish after **every** optimizer step
//!   (`request_weight_update`, the in-flight mechanism);
//! * periodic mode — publish after every k-th step (bounded asynchrony
//!   between the two extremes, the ablation axis of Fig 6);
//! * conventional mode — publish only when the RL step's last batch is
//!   done, then reopen the Generate phase.
//!
//! Records the full metric suite: loss/ESS/KL/clip from the device
//! metrics vector, token-lag profiles computed from the per-token weight
//! versions (Fig 6a), reward-vs-samples and reward-vs-time (Fig 5).
//! Additionally computes a host-side ESS oracle (Eq. 6) over the batch's
//! IS-weight lane — `train/ess_host` — which the supervisor feeds to the
//! autoscaler's `ess_floor` guard, and which backs the step log when the
//! compiled artifact exports no "ess" device metric.
//!
//! **Checkpoint/resume:** every `[checkpoint] every` steps the trainer
//! snapshots a full [`TrainState`] (params + both Adam moments + the
//! sample/token counters) under `[checkpoint] dir` and updates the
//! directory manifest. The snapshot is handed to an
//! [`AsyncCheckpointer`] writer thread (latest-wins queue, manifest
//! updated only after the state file fsyncs), so checkpoint I/O no
//! longer stalls optimizer steps; the final state is always flushed
//! before the trainer returns. When [`TrainerArgs::resume`] is set the
//! trainer continues from `state.step + 1` with the restored optimizer
//! trajectory — identical inputs then produce bit-identical parameters
//! (see tests/checkpoint_resume.rs).

use super::conv::ConvSync;
use super::packing::TrainBatch;
use crate::broker::{RecvError, Subscriber};
use crate::config::{Mode, RunConfig};
use crate::metrics::MetricsHub;
use crate::model::checkpoint::{AsyncCheckpointer, TrainState};
use crate::rl::{effective_sample_size, BatchLag, LagTracker};
use crate::runtime::{HostTensor, Runtime};
use crate::util::logging::Logger;
use crate::util::timer::global_seconds;
use crate::weights::WeightBus;
use anyhow::{Context, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

pub struct TrainerArgs {
    pub cfg: RunConfig,
    pub initial_params: Vec<HostTensor>,
    pub batch_rx: Subscriber<TrainBatch>,
    pub bus: WeightBus,
    pub hub: MetricsHub,
    pub stop: Arc<AtomicBool>,
    /// kill-switch for this trainer *incarnation* only (supervisor-driven
    /// failover: the run keeps going while a replacement resumes from the
    /// latest checkpoint). Plain runs pass a flag nobody raises.
    pub halt: Arc<AtomicBool>,
    pub conv: Option<Arc<ConvSync>>,
    /// groups per conventional Generate phase (quota)
    pub conv_groups: usize,
    /// resume from this state instead of starting at step 1
    pub resume: Option<TrainState>,
}

/// How a trainer incarnation ended.
#[derive(Debug)]
pub enum TrainerExit {
    /// ran to completion (all steps, run stop, or upstream close): the
    /// final parameters
    Completed(Vec<HostTensor>),
    /// this incarnation's halt switch was raised (trainer failover): the
    /// checkpoint writer was drained, so the supervisor can respawn from
    /// the latest manifest
    Halted,
}

/// Returns how the incarnation ended (final parameters on completion).
pub fn run_trainer(args: TrainerArgs) -> Result<TrainerExit> {
    let TrainerArgs {
        cfg, initial_params, batch_rx, bus, hub, stop, halt, conv, conv_groups, resume,
    } = args;
    let log = Logger::new("trainer");
    let mut rt = Runtime::new().context("trainer runtime")?;
    let variant = rt.manifest.variant(&cfg.variant)?.clone();
    let graph = rt.graph(&cfg.variant, "train")?;
    let metric_names = rt.manifest.metric_names.clone();
    let p = variant.params.len();

    let (mut params, mut m, mut v, start_step, mut samples_total, mut tokens_total) =
        match resume {
            Some(st) => {
                if st.variant != cfg.variant {
                    anyhow::bail!(
                        "resume state is for variant {:?}, run wants {:?}",
                        st.variant,
                        cfg.variant
                    );
                }
                log.info(&format!(
                    "resuming from step {} ({} samples trained so far)",
                    st.step, st.samples_total
                ));
                hub.add("resumed_from_step", st.step as f64);
                (
                    st.params,
                    st.opt_m,
                    st.opt_v,
                    st.step as usize + 1,
                    st.samples_total,
                    st.tokens_total,
                )
            }
            None => (
                initial_params,
                rt.zero_opt_state(&cfg.variant)?,
                rt.zero_opt_state(&cfg.variant)?,
                1,
                0.0,
                0.0,
            ),
        };

    // running lag series (Fig 6a) + the smoothed live signal the
    // supervisor's autoscaler polls via the hub. The smoothing window is
    // preloaded from the hub's own history: the hub outlives trainer
    // incarnations, so a failover respawn continues the smoothed signal
    // instead of restarting it from 0.0 (which let the autoscaler's lag
    // guard trivially pass for up to a full window after a trainer death).
    const LAG_SMOOTH_WINDOW: usize = 8;
    let mut lag_tracker = preload_lag_tracker(&hub, LAG_SMOOTH_WINDOW);
    if !lag_tracker.per_step.is_empty() {
        log.info(&format!(
            "lag tracker preloaded {} batches from hub history (smoothed {:.3})",
            lag_tracker.per_step.len(),
            lag_tracker.smoothed_mean_steps(LAG_SMOOTH_WINDOW)
        ));
    }

    // off-thread checkpoint writer: the hot loop only hands states over
    let mut ckpt: Option<AsyncCheckpointer> = match (&cfg.checkpoint.dir, cfg.checkpoint.every) {
        (Some(dir), every) if every > 0 => {
            Some(AsyncCheckpointer::new(
                std::path::PathBuf::from(dir),
                cfg.checkpoint.keep_last,
                cfg.checkpoint.write_retries,
            ))
        }
        _ => None,
    };

    for step in start_step..=cfg.rl_steps {
        // ---- get a batch ----
        let batch = loop {
            if stop.load(Ordering::Relaxed) {
                finish_checkpoints(ckpt.take(), &hub)?;
                return Ok(TrainerExit::Completed(params));
            }
            if halt.load(Ordering::Relaxed) {
                // failover kill: drain the checkpoint writer so the
                // freshest durable state is on disk, then step aside —
                // the supervisor respawns a successor from the manifest
                log.info(&format!("halted at step {step} (trainer failover)"));
                finish_checkpoints(ckpt.take(), &hub)?;
                return Ok(TrainerExit::Halted);
            }
            match batch_rx.recv(Duration::from_millis(200)) {
                Ok(b) => break b,
                Err(RecvError::Closed) => {
                    finish_checkpoints(ckpt.take(), &hub)?;
                    return Ok(TrainerExit::Completed(params));
                }
                Err(RecvError::Timeout) => continue,
            }
        };

        // ---- lag profile (Fig 6a): version v trained at step s has lag s - v
        let mut max_lag = 0u64;
        let mut sum_lag = 0f64;
        let mut n_lag = 0usize;
        for i in 0..batch.versions.len() {
            if batch.mask[i] == 1.0 {
                let lag = (step as u64).saturating_sub(batch.versions[i]);
                max_lag = max_lag.max(lag);
                sum_lag += lag as f64;
                n_lag += 1;
            }
        }
        // per-sequence weight-version span (the in-flight-update signature
        // behind Fig 6a): a (row, segment) pair identifies one packed
        // sequence — span = max − min version over its trained tokens
        let mut span_sum = 0f64;
        let mut span_n = 0usize;
        for row in 0..batch.b {
            let base = row * batch.t;
            let mut cur_seg = 0i32; // 0 = padding, never a real segment
            let (mut lo, mut hi) = (0u64, 0u64);
            for k in 0..batch.t {
                if batch.mask[base + k] != 1.0 {
                    continue;
                }
                let seg = batch.seg[base + k];
                let v = batch.versions[base + k];
                if seg != cur_seg {
                    if cur_seg != 0 {
                        span_sum += (hi - lo) as f64;
                        span_n += 1;
                    }
                    cur_seg = seg;
                    lo = v;
                    hi = v;
                } else {
                    lo = lo.min(v);
                    hi = hi.max(v);
                }
            }
            if cur_seg != 0 {
                span_sum += (hi - lo) as f64;
                span_n += 1;
            }
        }
        let mean_version_span = if span_n > 0 { span_sum / span_n as f64 } else { 0.0 };

        // ---- optimizer step ----
        let (b, t) = (batch.b, batch.t);
        let mut inputs: Vec<HostTensor> = Vec::with_capacity(3 * p + 14);
        inputs.extend(params.iter().cloned());
        inputs.extend(m.iter().cloned());
        inputs.extend(v.iter().cloned());
        inputs.push(HostTensor::scalar_f32(step as f32));
        inputs.push(HostTensor::from_i32(&[b, t], batch.tokens.clone()));
        inputs.push(HostTensor::from_i32(&[b, t], batch.seg.clone()));
        inputs.push(HostTensor::from_i32(&[b, t], batch.pos.clone()));
        inputs.push(HostTensor::from_f32(&[b, t], batch.behavior_lp.clone()));
        inputs.push(HostTensor::from_f32(&[b, t], batch.adv.clone()));
        inputs.push(HostTensor::from_f32(&[b, t], batch.reward.clone()));
        inputs.push(HostTensor::from_f32(&[b, t], batch.mask.clone()));
        inputs.push(HostTensor::from_f32(&[b, t], batch.is_w.clone()));
        inputs.push(HostTensor::scalar_f32(cfg.lr as f32));
        inputs.push(HostTensor::scalar_f32(cfg.clip_c as f32));
        inputs.push(HostTensor::scalar_f32(cfg.advantage.graph_flag()));
        inputs.push(HostTensor::scalar_f32(cfg.vf_coef as f32));
        // IS-correction selector: 0 = uncorrected, 1 = device recomputes
        // truncated weights from current-policy logprobs, 2 = take the
        // host-filled is_w lane verbatim (preprocessor already scored it)
        inputs.push(HostTensor::scalar_f32(
            cfg.is_correction.graph_flag(batch.host_weighted),
        ));
        let mut out = graph.run_host(&inputs).context("train step")?;
        let metrics = out.split_off(3 * p).remove(0);
        let v_new = out.split_off(2 * p);
        let m_new = out.split_off(p);
        params = out;
        m = m_new;
        v = v_new;

        // ---- metrics ----
        samples_total += batch.n_seqs as f64;
        tokens_total += batch.n_gen_tokens as f64;
        let tnow = global_seconds();
        let mvec = metrics.f32s()?;
        for (name, &val) in metric_names.iter().zip(mvec) {
            hub.record(&format!("train/{name}"), tnow, step as f64, val as f64);
        }
        let mean_lag = if n_lag > 0 { sum_lag / n_lag as f64 } else { 0.0 };
        lag_tracker.record(BatchLag {
            max_steps: max_lag,
            mean_steps: mean_lag,
            max_samples: max_lag * b as u64,
            mean_version_span,
            n_tokens: n_lag,
        });
        hub.record("train/max_lag", tnow, step as f64, max_lag as f64);
        hub.record("train/mean_lag", tnow, step as f64, mean_lag);
        hub.record("train/mean_version_span", tnow, step as f64, mean_version_span);
        hub.record(
            "train/mean_lag_smoothed",
            tnow,
            step as f64,
            lag_tracker.smoothed_mean_steps(LAG_SMOOTH_WINDOW),
        );
        hub.record("reward_vs_samples", tnow, samples_total, batch.mean_reward());
        hub.record("reward_vs_time", tnow, tnow, batch.mean_reward());
        hub.record("samples_vs_time", tnow, tnow, samples_total);
        hub.record("tokens_vs_time", tnow, tnow, tokens_total);
        hub.record("batch_fill", tnow, step as f64, batch.fill());
        hub.add("samples_trained", batch.n_seqs as f64);

        // ---- host-side ESS oracle (Eq. 6) over the packed weight lane.
        // With correction off (or no scorer upstream) the lane is all-1.0
        // and the oracle reads a flat 1.0; otherwise it is the live
        // off-policyness signal the autoscaler's ess_floor guard consumes.
        let lane: Vec<f32> = batch
            .is_w
            .iter()
            .zip(&batch.mask)
            .filter(|&(_, &mk)| mk == 1.0)
            .map(|(&w, _)| w)
            .collect();
        let ess_host = effective_sample_size(&lane);
        hub.record("train/ess_host", tnow, step as f64, ess_host);
        if !metric_names.iter().any(|n| n == "ess") {
            // artifact exports no device ESS — the oracle is the only source
            hub.record("train/ess", tnow, step as f64, ess_host);
        }
        if cfg.ess_floor > 0.0 && ess_host < cfg.ess_floor {
            hub.add("ess_floor_trips", 1.0);
        }

        if cfg.log_every > 0 && step % cfg.log_every == 0 {
            let ess_s = ess_display(&metric_names, mvec, Some(ess_host));
            log.info(&format!(
                "step {step:4} loss {:+.4} ess {ess_s} reward {:+.3} max_lag {max_lag} samples {samples_total}",
                mvec[0],
                batch.mean_reward()
            ));
        }

        // ---- publish weights ----
        if should_publish(&cfg.mode, step, batch.last_of_rl_step) {
            bus.publish(step as u64 + 1, Arc::new(params.clone()));
            if let (Mode::Conventional { .. }, Some(sync)) = (&cfg.mode, &conv) {
                sync.begin_generate(conv_groups);
            }
        }

        // ---- checkpoint (handed off; serialization + fsync run on the
        // writer thread, not this one) ----
        if cfg.checkpoint.every > 0 && step % cfg.checkpoint.every == 0 {
            if let Some(w) = &ckpt {
                w.submit(TrainState {
                    variant: cfg.variant.clone(),
                    step: step as u64,
                    params: params.clone(),
                    opt_m: m.clone(),
                    opt_v: v.clone(),
                    samples_total,
                    tokens_total,
                    // the real trainer owns no RNG and no engine; the
                    // deterministic harnesses (tests/checkpoint_resume.rs,
                    // testkit::golden) fill these cursors
                    rng: [0; 4],
                    engine_rng: [0; 4],
                    sched_cursor: 0,
                });
                hub.add("checkpoints_submitted", 1.0);
            }
        }
    }
    finish_checkpoints(ckpt.take(), &hub)?;
    log.info(&format!(
        "training done: {} steps, {} samples",
        cfg.rl_steps, samples_total
    ));
    Ok(TrainerExit::Completed(params))
}

/// Drain + join the async checkpoint writer and record its books. Every
/// trainer exit path runs through this, so the run's final submitted
/// state is on disk (and a broken writer fails the run loudly) before
/// `run_trainer` returns.
fn finish_checkpoints(ckpt: Option<AsyncCheckpointer>, hub: &MetricsHub) -> Result<()> {
    if let Some(w) = ckpt {
        let stats = w.finish()?;
        hub.add("checkpoints_written", stats.written as f64);
        hub.add("checkpoints_superseded", stats.superseded as f64);
    }
    Ok(())
}

/// Publish cadence per mode — the run-mode dial in one place: pipeline
/// publishes after every optimizer step (maximum freshness), periodic
/// after every k-th step (bounded asynchrony), conventional only when
/// the RL step's last batch is done (fully synchronous loop).
pub(crate) fn should_publish(mode: &Mode, step: usize, last_of_rl_step: bool) -> bool {
    match mode {
        Mode::Pipeline => true,
        Mode::Periodic { k } => step % (*k).max(1) == 0,
        Mode::Conventional { .. } => last_of_rl_step,
    }
}

/// What the step log prints for ESS. The old code indexed
/// `metric_names.position("ess").unwrap_or(0)`, so an artifact whose
/// metric vector lacks "ess" silently printed the *loss* labelled as
/// ess. Now: the device metric when the artifact exports one, else the
/// host oracle (marked `*`), else `n/a`.
pub(crate) fn ess_display(metric_names: &[String], mvec: &[f32], host_ess: Option<f64>) -> String {
    match metric_names.iter().position(|n| n == "ess") {
        Some(i) if i < mvec.len() => format!("{:.3}", mvec[i]),
        _ => match host_ess {
            Some(e) => format!("{e:.3}*"),
            None => "n/a".to_string(),
        },
    }
}

/// Rebuild a [`LagTracker`]'s smoothing window from the metrics hub.
/// The hub outlives trainer incarnations, so after a failover respawn
/// `train/mean_lag_smoothed` continues where the dead incarnation left
/// off instead of restarting from 0.0. Only the fields the smoothed
/// signal and `max_ever_steps` consume are reconstructed exactly;
/// `max_samples`/`n_tokens` are not recoverable from the hub series and
/// stay 0 (they feed no live decision).
pub(crate) fn preload_lag_tracker(hub: &MetricsHub, window: usize) -> LagTracker {
    let mut tracker = LagTracker::new();
    let mean = hub.series("train/mean_lag");
    let maxs = hub.series("train/max_lag");
    let spans = hub.series("train/mean_version_span");
    let n = mean.points.len();
    for i in n.saturating_sub(window)..n {
        tracker.record(BatchLag {
            max_steps: maxs.points.get(i).map(|p| p.value as u64).unwrap_or(0),
            mean_steps: mean.points[i].value,
            max_samples: 0,
            mean_version_span: spans.points.get(i).map(|p| p.value).unwrap_or(0.0),
            n_tokens: 0,
        });
    }
    tracker
}

#[cfg(test)]
mod tests {
    use super::*;

    fn names(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn publish_cadence_mode_matrix() {
        // pipeline: every step, regardless of batch position
        assert!((1..=6).all(|s| should_publish(&Mode::Pipeline, s, false)));
        // conventional: only the RL step's last batch
        let conv = Mode::Conventional { g: 4 };
        assert!(!should_publish(&conv, 3, false));
        assert!(should_publish(&conv, 3, true));
        // periodic k=3: steps 3 and 6, nothing in between — and
        // last_of_rl_step plays no role
        let m = Mode::Periodic { k: 3 };
        let got: Vec<bool> = (1..=6).map(|s| should_publish(&m, s, true)).collect();
        assert_eq!(got, [false, false, true, false, false, true]);
        // k=1 degenerates to pipeline cadence
        assert!((1..=5).all(|s| should_publish(&Mode::Periodic { k: 1 }, s, false)));
    }

    #[test]
    fn ess_display_never_mislabels_loss() {
        let mvec = [0.42_f32, 7.0, 0.9];
        // device metric present: index it
        let n = names(&["loss", "pg_loss", "ess"]);
        assert_eq!(ess_display(&n, &mvec, Some(0.5)), "0.900");
        // absent: fall back to the host oracle — never to mvec[0] (the
        // old unwrap_or(0) bug printed the loss labelled as ess)
        let n = names(&["loss", "pg_loss"]);
        assert_eq!(ess_display(&n, &mvec, Some(0.512)), "0.512*");
        // ...or to n/a when there is no oracle either
        assert_eq!(ess_display(&n, &mvec, None), "n/a");
        // "ess" listed but the device vector is too short: same fallback
        let n = names(&["loss", "pg_loss", "x", "ess"]);
        assert_eq!(ess_display(&n, &mvec, None), "n/a");
    }

    #[test]
    fn lag_tracker_preload_continues_smoothed_signal() {
        let hub = MetricsHub::new();
        // a prior incarnation recorded 10 steps of lag history
        let mut prior = LagTracker::new();
        for s in 1..=10u64 {
            prior.record(BatchLag {
                max_steps: s + 2,
                mean_steps: s as f64,
                max_samples: 64,
                mean_version_span: 0.5,
                n_tokens: 7,
            });
            hub.record("train/mean_lag", s as f64, s as f64, s as f64);
            hub.record("train/max_lag", s as f64, s as f64, (s + 2) as f64);
            hub.record("train/mean_version_span", s as f64, s as f64, 0.5);
        }
        let reborn = preload_lag_tracker(&hub, 8);
        assert_eq!(reborn.per_step.len(), 8, "only the smoothing window is rebuilt");
        assert!(
            (reborn.smoothed_mean_steps(8) - prior.smoothed_mean_steps(8)).abs() < 1e-12,
            "smoothed signal is continuous across the respawn"
        );
        assert_eq!(reborn.max_ever_steps(), 12);
        assert!(
            (reborn.latest().unwrap().mean_version_span - 0.5).abs() < 1e-12,
            "version span survives the round trip"
        );
        // a hub with no history yields a fresh-start tracker
        assert!(preload_lag_tracker(&MetricsHub::new(), 8).per_step.is_empty());
    }
}
